//! Quickstart: compress a model with NSVD-I and print perplexities.
//!
//! Run: `cargo run --release --example quickstart`

use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::data::corpus::paper_label;

fn main() -> anyhow::Result<()> {
    // 1. A pipeline over the AOT artifacts (run `make artifacts` first).
    let mut config = PipelineConfig::default_for_model("llama-t");
    config.eval_windows = 32; // keep the demo fast
    let mut pipeline = Pipeline::new(config)?;

    // 2. The paper's headline setting: NSVD-I, 30% compression, k1 = 0.95k.
    let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha: 0.95 };

    // 3. calibrate → decompose → evaluate on all eight datasets.
    let report = pipeline.run(&spec)?;

    println!(
        "compressed {} with {} at {:.0}%: {} → {} params",
        report.model,
        report.method,
        report.ratio * 100.0,
        report.dense_params,
        report.compressed_params
    );
    for r in &report.results {
        println!("  {:<16} perplexity {:>8.2}", paper_label(&r.dataset), r.ppl());
    }
    Ok(())
}
