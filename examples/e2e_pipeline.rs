//! End-to-end driver — proves all layers compose on a real workload.
//!
//! Exercises the full system exactly as a downstream user would:
//!   1. open the PJRT runtime over the AOT artifacts (L2/L1 products);
//!   2. calibrate on 256 random wiki-train sequences (PJRT gram executable);
//!   3. compress llama-t with NSVD-I at 30% (the paper's headline setting);
//!   4. evaluate perplexity on all eight test sets with the padded-rank
//!      low-rank executable, next to the dense baseline and ASVD-I;
//!   5. serve 200 batched scoring requests over the compressed model and
//!      report latency/throughput.
//!
//! The output of this run is recorded in EXPERIMENTS.md §e2e.
//!
//! Run: `cargo run --release --example e2e_pipeline`

use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::coordinator::server;
use nsvd::data::corpus::{paper_label, Registry};
use nsvd::util::timer::Timer;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string()),
    );
    let total = Timer::start();

    println!("== [1/5] opening PJRT runtime ==");
    let mut cfg = PipelineConfig::default_for_model("llama-t");
    cfg.artifacts_dir = artifacts.clone();
    cfg.eval_windows = 64;
    let mut pipeline = Pipeline::new(cfg)?;
    println!(
        "model llama-t: d={} layers={} compressible params={}",
        pipeline.model_cfg.d_model,
        pipeline.model_cfg.n_layers,
        pipeline.model_cfg.compressible_params()
    );

    println!("\n== [2/5] calibrating (256 wiki-train sequences) ==");
    let t = Timer::start();
    pipeline.calibrate()?;
    println!("calibration done in {:.1}s", t.elapsed_s());

    println!("\n== [3/5] compressing: dense baseline, ASVD-I, NSVD-I @30% ==");
    let t = Timer::start();
    let dense = pipeline.run_dense()?;
    let asvd = pipeline.run(&CompressionSpec::new(Method::AsvdI, 0.30))?;
    let nsvd = pipeline.run(&CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha: 0.95 })?;
    println!("three evaluations done in {:.1}s", t.elapsed_s());

    println!("\n== [4/5] perplexity across the eight domains ==");
    println!("{:<16} {:>10} {:>10} {:>10}", "dataset", "Original", "ASVD-I", "NSVD-I");
    for (i, r) in dense.results.iter().enumerate() {
        println!(
            "{:<16} {:>10.2} {:>10.2} {:>10.2}",
            paper_label(&r.dataset),
            r.ppl(),
            asvd.results[i].ppl(),
            nsvd.results[i].ppl()
        );
    }
    println!(
        "params: dense {} → compressed {} ({:.1}% removed)",
        nsvd.dense_params,
        nsvd.compressed_params,
        (1.0 - nsvd.compressed_params as f64 / nsvd.dense_params as f64) * 100.0
    );

    println!("\n== [5/5] serving 200 batched scoring requests (compressed model) ==");
    let cm = pipeline.compress(&CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha: 0.95 })?;
    let rt = pipeline.runtime().expect("PJRT runtime");
    let eval = rt.serve_evaluator("llama-t", &cm)?;
    let registry = Registry::new(&artifacts);
    let corpus = registry.load("alpaca", "test")?;
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    let producer = server::spawn_load(corpus.tokens.clone(), eval.seq(), 200, 0.0, req_tx);
    let metrics = server::serve(&eval, req_rx, resp_tx, server::BatchPolicy::default())?;
    producer.join().ok();
    let responses: Vec<_> = resp_rx.iter().collect();
    println!("{}", metrics.summary());
    let mean_ppl =
        responses.iter().map(|r| r.ppl).sum::<f64>() / responses.len().max(1) as f64;
    println!("mean served ppl: {mean_ppl:.2} over {} responses", responses.len());

    println!("\ne2e complete in {:.1}s", total.elapsed_s());
    Ok(())
}
