//! Text generation from the compressed model — dense vs NSVD-compressed
//! side by side, with KV-cached incremental decoding.
//!
//! Run: `cargo run --release --example generate_text`

use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::model::forward::NoOverride;
use nsvd::model::generate::{generate, SampleConfig};
use nsvd::util::timer::Timer;

fn printable(bytes: &[u8]) -> String {
    bytes
        .iter()
        .map(|&b| if (32..127).contains(&b) || b == b'\n' { b as char } else { '·' })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let mut config = PipelineConfig::default_for_model("llama-t");
    config.use_pjrt = true;
    let mut pipeline = Pipeline::new(config)?;
    let cm = pipeline.compress(&CompressionSpec {
        method: Method::NsvdI,
        ratio: 0.30,
        alpha: 0.95,
    })?;

    let prompt = b"the history of the ";
    let sc = SampleConfig { temperature: 0.8, top_k: 20, seed: 7 };

    let t = Timer::start();
    let dense = generate(
        &pipeline.model_cfg, &pipeline.weights, &NoOverride, prompt, 120, sc,
    )?;
    let dense_s = t.elapsed_s();
    let t = Timer::start();
    let compressed = generate(&pipeline.model_cfg, &pipeline.weights, &cm, prompt, 120, sc)?;
    let comp_s = t.elapsed_s();

    println!("prompt: {:?}\n", printable(prompt));
    println!("— dense ({dense_s:.2}s, {:.0} tok/s) —", 120.0 / dense_s);
    println!("{}\n", printable(&dense));
    println!(
        "— NSVD-I @30% ({comp_s:.2}s, {:.0} tok/s) —",
        120.0 / comp_s
    );
    println!("{}", printable(&compressed));
    Ok(())
}
