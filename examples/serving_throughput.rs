//! Serving throughput — the deployment payoff of compression.
//!
//! Compresses llama-t with NSVD-I at 30%, then drives the dynamic batcher
//! with open-loop load at increasing request rates, reporting latency
//! percentiles, batch fill, and throughput at each rate — the classic
//! serving-system load curve.
//!
//! Run: `cargo run --release --example serving_throughput`

use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::coordinator::server::{self, BatchPolicy};
use nsvd::data::corpus::Registry;

fn main() -> anyhow::Result<()> {
    let config = PipelineConfig::default_for_model("llama-t");
    let artifacts = config.artifacts_dir.clone();
    let mut pipeline = Pipeline::new(config)?;
    let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha: 0.95 };
    println!("compressing llama-t (NSVD-I @30%)...");
    let cm = pipeline.compress(&spec)?;
    let rt = pipeline.runtime().expect("PJRT runtime required");
    let eval = rt.serve_evaluator("llama-t", &cm)?;
    let corpus = Registry::new(&artifacts).load("c4", "test")?;

    println!(
        "\n{:>9} | {:>9} {:>9} {:>9} | {:>9} {:>6}",
        "load rps", "p50 ms", "p99 ms", "max ms", "thru rps", "fill"
    );
    for rate in [50.0, 100.0, 200.0, 0.0] {
        let n = 160;
        let (req_tx, req_rx) = std::sync::mpsc::channel();
        let (resp_tx, resp_rx) = std::sync::mpsc::channel();
        let producer =
            server::spawn_load(corpus.tokens.clone(), eval.seq(), n, rate, req_tx);
        let metrics = server::serve(&eval, req_rx, resp_tx, BatchPolicy::default())?;
        producer.join().ok();
        let _responses: Vec<_> = resp_rx.iter().collect();
        let lat = metrics.latency();
        let label = if rate == 0.0 { "max".to_string() } else { format!("{rate:.0}") };
        println!(
            "{:>9} | {:>9.1} {:>9.1} {:>9.1} | {:>9.1} {:>6.2}",
            label,
            lat.p50 * 1e3,
            lat.p99 * 1e3,
            lat.max * 1e3,
            metrics.throughput_rps(),
            metrics.mean_batch_fill()
        );
    }
    println!("\n('max' = closed-loop: producer enqueues as fast as possible →");
    println!(" the batcher fills to the executable's batch size of 8)");
    Ok(())
}
