//! Serving throughput — the deployment payoff of compression, now on the
//! continuous-batching GENERATION server (`nsvd::serve`).
//!
//! N concurrent closed-loop client threads fan generation requests into
//! the step-level batcher; every active sequence contributes token rows
//! per decode step, and each projection runs as ONE GEMM over the stacked
//! rows.  KV lives in a paged pool (pages fault in on demand — no
//! worst-case reservation) and every client here sends the SAME prompt,
//! so after the first prefill the prefix trie serves the prompt's full
//! pages from cache.  The run compares dense weights against an
//! NSVD-shaped low-rank override at each client count, printing decode
//! tokens/s, the p95 end-to-end latency, batch fill, and the prefix hit
//! rate — the numbers a serving deployment is sized by.  A third variant
//! runs the same low-rank factors quantized to per-group int8
//! (`--factor-dtype int8` in `serve-gen`), decoding through the integer
//! GEMM microkernel with its dequant-fused epilogue.
//!
//! A final section switches to OPEN-loop Poisson clients offering load
//! past the measured capacity, demonstrating graceful overload: a bounded
//! queue plus per-request deadlines turn the excess into explicit shed /
//! deadline terminals while the higher-priority tenant keeps completing.
//!
//! Artifact-free on purpose (random weights, synthetic low-rank factors):
//! the point is the serving system's scaling, not model quality.  Use
//! `cargo run --release -- serve-gen` for the real compressed model.
//!
//! Run: `cargo run --release --example serving_throughput`

use nsvd::bench::{
    drive_concurrent, drive_open_loop, goodput_tokens_per_s, synthetic_nsvd, synthetic_nsvd_int8,
    OpenLoopTenant,
};
use nsvd::coordinator::metrics::GenServerMetrics;
use nsvd::model::config::ModelConfig;
use nsvd::model::forward::{random_weights, LinearOverride, NoOverride};
use nsvd::model::generate::SampleConfig;
use nsvd::model::weights::Weights;
use nsvd::serve::GenConfig;

/// Drive the server with `clients` closed-loop producer threads sending
/// `per_client` requests each; the calling thread is the scheduler
/// (shared harness: `nsvd::bench::drive_concurrent`).
fn drive(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    clients: usize,
    per_client: usize,
    prompt: &[u8],
    max_new: usize,
) -> GenServerMetrics {
    // The old scheduler reserved 8 worst-case sequences of pages; since
    // every client sends the same prompt, the trie stores the prompt's
    // full pages ONCE and each sequence only needs its private tail —
    // this pool is ~25% smaller yet still runs all 8 slots concurrently.
    let page_size = 16;
    let per_seq = (prompt.len() + max_new - 1).div_ceil(page_size);
    let shared = prompt.len() / page_size;
    let gen_cfg = GenConfig {
        max_batch: 8,
        pages: shared + 8 * (per_seq - shared),
        page_size,
        prefill_chunk: 16,
        prefix_share: true,
        workers: 0,
        ..GenConfig::default()
    };
    let (metrics, _stats) = drive_concurrent(
        cfg,
        weights,
        overrides,
        &gen_cfg,
        clients,
        clients * per_client,
        &|i| {
            (
                prompt.to_vec(),
                max_new,
                SampleConfig { temperature: 0.8, top_k: 20, seed: i as u64 },
            )
        },
    )
    .expect("serve_generation");
    metrics
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::builtin("llama-t")?;
    let weights = random_weights(&cfg, 1);
    let cm = synthetic_nsvd(&cfg, 0.30, 0.95, 2);
    let cm_q = synthetic_nsvd_int8(&cfg, 0.30, 0.95, 2);
    let prompt: Vec<u8> = b"the history of the ".to_vec();
    let (per_client, max_new) = (4usize, 32usize);

    println!(
        "continuous-batching generation server — llama-t, {max_new} new tokens/request, \
         paged KV (smaller than the old worst-case reservation), shared prompt"
    );
    println!(
        "\n{:>8} | {:>12} {:>9} {:>6} | {:>12} {:>9} {:>6} | {:>12} {:>9} {:>6} | {:>5} {:>5}",
        "clients", "dense tok/s", "p95 ms", "fill", "nsvd tok/s", "p95 ms", "fill",
        "int8 tok/s", "p95 ms", "fill", "hit", "occ"
    );
    for clients in [1usize, 2, 4, 8] {
        let dense = drive(&cfg, &weights, &NoOverride, clients, per_client, &prompt, max_new);
        let nsvd = drive(&cfg, &weights, &cm, clients, per_client, &prompt, max_new);
        let int8 = drive(&cfg, &weights, &cm_q, clients, per_client, &prompt, max_new);
        println!(
            "{:>8} | {:>12.1} {:>9.1} {:>6.2} | {:>12.1} {:>9.1} {:>6.2} | \
             {:>12.1} {:>9.1} {:>6.2} | {:>5.2} {:>5.2}",
            clients,
            dense.tokens_per_s(),
            dense.latency().p95 * 1e3,
            dense.mean_batch_fill(),
            nsvd.tokens_per_s(),
            nsvd.latency().p95 * 1e3,
            nsvd.mean_batch_fill(),
            int8.tokens_per_s(),
            int8.latency().p95 * 1e3,
            int8.mean_batch_fill(),
            nsvd.prefix_hit_rate(),
            nsvd.mean_page_occupancy(),
        );
    }
    println!(
        "\n(closed-loop clients: each sends its next request when the previous\n\
         stream finishes — batch fill, and with it decode tokens/s, grows with\n\
         the client count because every step's projections run as one GEMM\n\
         over the stacked rows.  `hit` is the fraction of prompt positions\n\
         served from the prefix trie instead of prefilled; `occ` the mean\n\
         fraction of the pool's pages in use.)"
    );

    // ---- graceful overload: open-loop Poisson load past capacity ----
    // Closed-loop clients above self-throttle, so they can never overload
    // the server.  Here two open-loop tenant streams keep offering work at
    // 1x and then 4x the capacity just measured, against a bounded queue
    // and a per-request deadline: raw throughput stays pinned at capacity
    // while the shed / deadline counters absorb the excess — that is the
    // graceful-overload contract (`serve-gen --rate ... --queue-cap ...`).
    println!("\ngraceful overload — open-loop Poisson arrivals, queue_cap=8, deadline=250ms");
    let nsvd_cap = drive(&cfg, &weights, &cm, 8, per_client, &prompt, max_new);
    let cap_rps = (nsvd_cap.tokens_per_s() / max_new as f64).max(0.5);
    let page_size = 16;
    let per_seq = (prompt.len() + max_new - 1).div_ceil(page_size);
    let shared = prompt.len() / page_size;
    let over_cfg = GenConfig {
        max_batch: 8,
        pages: shared + 8 * (per_seq - shared),
        page_size,
        prefill_chunk: 16,
        prefix_share: true,
        workers: 0,
        queue_cap: 8,
        ..GenConfig::default()
    };
    println!(
        "{:>8} | {:>11} {:>12} {:>9} | {:>5} {:>9} {:>8}",
        "offered", "raw tok/s", "goodput t/s", "complete", "shed", "deadline", "rejected"
    );
    for mult in [1usize, 4] {
        let tenants = [
            OpenLoopTenant {
                tenant: 0,
                rate: cap_rps * mult as f64 / 2.0,
                requests: 16,
                priority: 1,
                deadline: Some(0.25),
                prompt_len: (8, 24),
                max_new: (8, max_new + 1),
            },
            OpenLoopTenant {
                tenant: 1,
                rate: cap_rps * mult as f64 / 2.0,
                requests: 16,
                priority: 0,
                deadline: Some(0.25),
                prompt_len: (8, 24),
                max_new: (8, max_new + 1),
            },
        ];
        let (m, stats) = drive_open_loop(&cfg, &weights, &cm, &over_cfg, 17, &tenants)?;
        println!(
            "{:>7}x | {:>11.1} {:>12.1} {:>9} | {:>5} {:>9} {:>8}",
            mult,
            m.tokens_per_s(),
            goodput_tokens_per_s(&stats, m.wall_s),
            m.completed,
            m.shed,
            m.deadline_exceeded,
            m.rejected,
        );
    }
    println!(
        "\n(the higher-priority tenant 0 keeps completing under overload while\n\
         tenant 1's excess is shed or expires — per-tenant accounting is in\n\
         `serve-gen`'s tenant table.)"
    );
    Ok(())
}
