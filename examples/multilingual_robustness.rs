//! Multilingual robustness — the scenario that motivates NSVD.
//!
//! Calibration comes from English wiki text, but the deployed model must
//! serve Chinese and Japanese traffic.  This example sweeps compression
//! ratios and reports the out-of-distribution degradation of ASVD-I
//! (SVD-LLM) next to NSVD-I — reproducing the paper's §4.1 "Robustness"
//! analysis on our substituted corpora.
//!
//! Run: `cargo run --release --example multilingual_robustness`

use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};

fn main() -> anyhow::Result<()> {
    let mut config = PipelineConfig::default_for_model("llama-t");
    config.eval_windows = 48;
    let mut pipeline = Pipeline::new(config)?;

    // Baseline similarity picture (Table 2): how OOD are CN/JP?
    println!("== activation similarity vs the (English) calibration set ==");
    for report in pipeline.similarity_analysis()? {
        println!("  {:<12} {:.2} ± {:.2}", report.dataset, report.mean, report.std);
    }

    println!("\n== OOD perplexity under compression (CMRC-CN / AlpacaEval-JP) ==");
    println!(
        "{:>6} | {:>10} {:>10} | {:>10} {:>10} | {:>8}",
        "ratio", "ASVD-I CN", "NSVD-I CN", "ASVD-I JP", "NSVD-I JP", "CN gain"
    );
    for ratio in [0.2, 0.3, 0.4, 0.5] {
        let asvd = pipeline.run(&CompressionSpec::new(Method::AsvdI, ratio))?;
        let nsvd = pipeline.run(&CompressionSpec {
            method: Method::NsvdI,
            ratio,
            // The paper's Table 3 finding: smaller α helps OOD most.
            alpha: 0.85,
        })?;
        let a_cn = asvd.ppl("cmrc_cn").unwrap_or(f64::NAN);
        let n_cn = nsvd.ppl("cmrc_cn").unwrap_or(f64::NAN);
        let a_jp = asvd.ppl("alpaca_jp").unwrap_or(f64::NAN);
        let n_jp = nsvd.ppl("alpaca_jp").unwrap_or(f64::NAN);
        println!(
            "{:>5.0}% | {:>10.2} {:>10.2} | {:>10.2} {:>10.2} | {:>7.1}%",
            ratio * 100.0,
            a_cn,
            n_cn,
            a_jp,
            n_jp,
            (a_cn - n_cn) / a_cn * 100.0
        );
    }
    println!("\n(positive CN gain = NSVD-I recovers out-of-distribution quality)");
    Ok(())
}
