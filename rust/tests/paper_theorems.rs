//! Theorem-level integration tests on REAL calibrated Grams.
//!
//! The unit tests check the paper's identities on synthetic activations;
//! these re-verify them on the actual calibration statistics of the trained
//! llama-t model — where Grams are ill-conditioned in exactly the way that
//! breaks naive implementations.
//!
//! Skipped when `artifacts/` is missing.

use nsvd::compress::allocate::{self, LayerProfile};
use nsvd::compress::methods::{compress_layer, layer_error, CompressionSpec, Method};
use nsvd::compress::ranks;
use nsvd::compress::whiten::Whitener;
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::linalg::matrix::Matrix;
use nsvd::linalg::svd::svd_thin;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn calibrated_pipeline(dir: PathBuf) -> Pipeline {
    let mut cfg = PipelineConfig::default_for_model("llama-t");
    cfg.artifacts_dir = dir;
    cfg.calib_samples = 64; // enough for the identities, fast
    let mut p = Pipeline::new(cfg).unwrap();
    p.calibrate().unwrap();
    p
}

#[test]
fn theorem2_on_real_grams_truncation_loss_equals_sigma_tail() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pipeline = calibrated_pipeline(dir);
    let stats = pipeline.calibrate().unwrap().clone();
    // Pick one attention weight and one MLP weight.
    for name in ["blocks.0.attn.wq", "blocks.1.mlp.w_down"] {
        let tensor = pipeline.weights.get(name).unwrap().clone();
        let tap_stats = stats.for_linear(name).unwrap();
        let a = Matrix::from_f32(tensor.dims[0], tensor.dims[1], &tensor.data).transpose();
        let w = Whitener::cholesky(tap_stats);
        // Theorem 2's S satisfies S Sᵀ = G + ridge·I (the PSD-safe ridge is
        // part of S on real, rank-deficient Grams — ASVD puts the residual
        // in G's near-null space, so the raw-G loss is NOT the identity).
        let ridge = match &w {
            Whitener::Chol { ridge, .. } => *ridge,
            _ => unreachable!(),
        };
        let mut ridged = tap_stats.clone();
        for i in 0..ridged.gram.rows {
            ridged.gram[(i, i)] += ridge;
        }
        let aw = w.whiten(&a);
        let svd = svd_thin(&aw);
        let k = svd.s.len() / 3;
        let spec = CompressionSpec::new(Method::AsvdI, 0.0);
        let plan = ranks::RankPlan { k, k1: k, k2: 0 };
        let layer = compress_layer(&tensor, tap_stats, &spec, &plan).unwrap();
        let err = layer_error(&tensor, &ridged, &layer);
        let tail = svd.tail_norm(k);
        let rel = (err.activation - tail).abs() / tail.max(1e-9);
        // The f32 factor cast perturbs the identity; 2% is the envelope.
        assert!(
            rel < 0.02,
            "{name}: activation loss {} vs σ-tail {tail} (rel {rel}, ridge {ridge})",
            err.activation
        );
    }
}

#[test]
fn theorem3_equivalence_on_real_grams() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pipeline = calibrated_pipeline(dir);
    let stats = pipeline.calibrate().unwrap().clone();
    let name = "blocks.2.attn.wv";
    let tensor = pipeline.weights.get(name).unwrap().clone();
    let tap_stats = stats.for_linear(name).unwrap();
    let plan = ranks::plan(128, 128, 0.30, 1.0);
    let l1 = compress_layer(&tensor, tap_stats, &CompressionSpec::new(Method::AsvdI, 0.3), &plan).unwrap();
    let l2 = compress_layer(&tensor, tap_stats, &CompressionSpec::new(Method::AsvdII, 0.3), &plan).unwrap();
    // Equivalent approximations → near-identical activation-weighted error.
    let e1 = layer_error(&tensor, tap_stats, &l1).activation;
    let e2 = layer_error(&tensor, tap_stats, &l2).activation;
    let rel = (e1 - e2).abs() / e1.max(1e-9);
    assert!(rel < 0.05, "ASVD-I loss {e1} vs ASVD-II loss {e2} (rel {rel})");
}

#[test]
fn nested_budget_invariant_on_real_model() {
    // Every method must hit the exact same parameter count at a given ratio —
    // the like-for-like contract behind every table.
    let Some(dir) = artifacts_dir() else { return };
    let mut pipeline = calibrated_pipeline(dir);
    let mut counts = Vec::new();
    for method in [Method::Svd, Method::AsvdI, Method::NsvdI, Method::NidI] {
        let spec = CompressionSpec { method, ratio: 0.30, alpha: 0.9 };
        let cm = pipeline.compress(&spec).unwrap();
        counts.push(cm.params());
    }
    assert!(
        counts.windows(2).all(|w| w[0] == w[1]),
        "parameter counts diverged across methods: {counts:?}"
    );
}

#[test]
fn global_rank_allocation_beats_uniform_on_weighted_error() {
    // The adaptive-rank extension: allocating one global budget by whitened
    // spectral mass must not increase the TOTAL activation-weighted error
    // relative to uniform per-layer ratios (it reallocates rank from
    // fast-decaying layers to heavy-tailed ones).
    let Some(dir) = artifacts_dir() else { return };
    let mut pipeline = calibrated_pipeline(dir);
    let stats = pipeline.calibrate().unwrap().clone();
    let names: Vec<(String, usize, usize)> = pipeline.model_cfg.linear_shapes.clone();
    // Whitened spectra per layer.
    let mut profiles = Vec::new();
    for (name, n_in, n_out) in &names {
        let t = pipeline.weights.get(name).unwrap();
        let s = stats.for_linear(name).unwrap();
        let a = Matrix::from_f32(*n_in, *n_out, &t.data).transpose();
        let w = Whitener::cholesky(s);
        let svd = svd_thin(&w.whiten(&a));
        profiles.push(LayerProfile {
            name: name.clone(),
            m: *n_out,
            n: *n_in,
            spectrum: svd.s,
        });
    }
    let ratio = 0.40;
    let ks = allocate::spectrum_ranks(&profiles, ratio, None);
    let global_plans: Vec<ranks::RankPlan> =
        ks.iter().map(|&k| ranks::split_k(k, 1.0)).collect();
    let spec = CompressionSpec::new(Method::AsvdI, ratio);
    let mut uniform_err = 0.0;
    let mut global_err = 0.0;
    let mut uniform_params = 0usize;
    let mut global_params = 0usize;
    for (i, (name, n_in, n_out)) in names.iter().enumerate() {
        let t = pipeline.weights.get(name).unwrap().clone();
        let s = stats.for_linear(name).unwrap();
        let up = ranks::plan(*n_out, *n_in, ratio, 1.0);
        let lu = compress_layer(&t, s, &spec, &up).unwrap();
        uniform_err += layer_error(&t, s, &lu).activation.powi(2);
        uniform_params += lu.params();
        let lg = compress_layer(&t, s, &spec, &global_plans[i]).unwrap();
        global_err += layer_error(&t, s, &lg).activation.powi(2);
        global_params += lg.params();
    }
    // Same or smaller budget...
    let dense: usize = names.iter().map(|(_, a, b)| a * b).sum();
    assert!(global_params <= ((1.0 - ratio) * dense as f64) as usize + dense / 100);
    // ...and no worse total weighted error (allow 2% slack for greedy
    // granularity vs the uniform floor-rounding).
    assert!(
        global_err <= uniform_err * 1.02,
        "global {global_err} vs uniform {uniform_err} \
         (params {global_params} vs {uniform_params})"
    );
}
