//! Integration tests over the REAL artifacts: PJRT execution vs the native
//! forward.  These pin the whole python→HLO→rust chain.
//!
//! Skipped (with a loud message) when `artifacts/` has not been built —
//! run `make artifacts` first.

use nsvd::calib::collector::{collect_native, TapStats};
use nsvd::compress::methods::{compress_layer, CompressionSpec, Method};
use nsvd::compress::ranks;
use nsvd::compress::lowrank::CompressedModel;
use nsvd::data::batch::Batcher;
use nsvd::data::corpus::Registry;
use nsvd::model::weights::Weights;
use nsvd::runtime::exec::Runtime;
use nsvd::util::rng::Rng;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn dense_pjrt_matches_native_forward() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let reg = Registry::new(&dir);
    let corpus = reg.load("wiki", "test").unwrap();
    let batch = rt.manifest.eval_batch;
    let seq = rt.manifest.seq;
    let eval = rt.dense_evaluator("llama-t", batch).unwrap();
    let tb = &Batcher::new(batch, seq).eval_batches(&corpus, batch)[0];
    let pjrt = eval.loss(tb).unwrap();

    let cfg = rt.manifest.model("llama-t").unwrap();
    let weights = Weights::load(&rt.manifest.weights_path("llama-t").unwrap()).unwrap();
    let (nll, count) = nsvd::model::forward::loss(
        cfg,
        &weights,
        &nsvd::model::forward::NoOverride,
        &tb.tokens,
        tb.batch,
        tb.seq,
        tb.valid_rows,
    )
    .unwrap();
    assert_eq!(pjrt.count as usize, count);
    let rel = (pjrt.sum_nll - nll).abs() / nll.abs().max(1.0);
    assert!(
        rel < 2e-3,
        "PJRT nll {} vs native {} (rel {rel})",
        pjrt.sum_nll,
        nll
    );
}

#[test]
fn gram_artifact_matches_native_collection() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let reg = Registry::new(&dir);
    let corpus = reg.calibration().unwrap();
    let batch = rt.manifest.eval_batch;
    let seq = rt.manifest.seq;
    let mut rng = Rng::new(17);
    let batches = Batcher::new(batch, seq).calibration_batches(&corpus, batch * 2, &mut rng);

    let runner = rt.gram_runner("llama-t").unwrap();
    let mut pjrt_stats = TapStats::default();
    for tb in &batches {
        runner.accumulate(tb, &mut pjrt_stats).unwrap();
    }

    let cfg = rt.manifest.model("llama-t").unwrap();
    let weights = Weights::load(&rt.manifest.weights_path("llama-t").unwrap()).unwrap();
    let native_stats = collect_native(cfg, &weights, &batches).unwrap();

    assert_eq!(pjrt_stats.taps.len(), native_stats.taps.len());
    for (tap, ns) in &native_stats.taps {
        let ps = &pjrt_stats.taps[tap];
        assert_eq!(ps.rows, ns.rows, "{tap} rows");
        let rel = ps.gram.dist(&ns.gram) / ns.gram.fro_norm().max(1.0);
        assert!(rel < 5e-3, "{tap}: gram rel diff {rel}");
        let abs_rel: f64 = ps
            .abs_sum
            .iter()
            .zip(&ns.abs_sum)
            .map(|(a, b)| (a - b).abs() / b.abs().max(1.0))
            .fold(0.0, f64::max);
        assert!(abs_rel < 5e-3, "{tap}: abs_sum rel diff {abs_rel}");
    }
}

#[test]
fn lowrank_pjrt_matches_native_compressed_forward() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let reg = Registry::new(&dir);
    let corpus = reg.calibration().unwrap();
    let batch = rt.manifest.eval_batch;
    let seq = rt.manifest.seq;
    let cfg = rt.manifest.model("llama-t").unwrap();
    let weights = Weights::load(&rt.manifest.weights_path("llama-t").unwrap()).unwrap();

    // Calibrate (native — small sample is fine for a parity check)...
    let mut rng = Rng::new(18);
    let cal_batches = Batcher::new(batch, seq).calibration_batches(&corpus, batch, &mut rng);
    let stats = collect_native(cfg, &weights, &cal_batches).unwrap();

    // ...compress at 30% with NSVD-I...
    let spec = CompressionSpec::new(Method::NsvdI, 0.30);
    let mut cm = CompressedModel::default();
    for (name, n_in, n_out) in &cfg.linear_shapes {
        let t = weights.get(name).unwrap();
        let s = stats.for_linear(name).unwrap();
        let plan = ranks::plan(*n_out, *n_in, spec.ratio, spec.effective_alpha());
        cm.insert(name, compress_layer(t, s, &spec, &plan).unwrap());
    }

    // ...and compare PJRT lowrank execution vs native compressed forward.
    let eval = rt.lowrank_evaluator("llama-t", batch, &cm).unwrap();
    let test = reg.load("wiki", "test").unwrap();
    let tb = &Batcher::new(batch, seq).eval_batches(&test, batch)[0];
    let pjrt = eval.loss(tb).unwrap();
    let (nll, count) = nsvd::model::forward::loss(
        cfg, &weights, &cm, &tb.tokens, tb.batch, tb.seq, tb.valid_rows,
    )
    .unwrap();
    assert_eq!(pjrt.count as usize, count);
    let rel = (pjrt.sum_nll - nll).abs() / nll.abs().max(1.0);
    assert!(rel < 2e-3, "lowrank PJRT {} vs native {nll} (rel {rel})", pjrt.sum_nll);
}

#[test]
fn all_manifest_artifacts_compile_and_files_exist() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    rt.manifest.verify_files().unwrap();
    // Six models across three families at multiple scales.
    for m in ["llama-t", "llama-s", "llama-m", "vicuna-t", "opt-t", "mistral-t"] {
        assert!(rt.manifest.models.contains_key(m), "missing model {m}");
    }
    // Eight corpora present.
    let reg = Registry::new(&dir);
    assert_eq!(reg.eval_sets().unwrap().len(), 8);
}

#[test]
fn trained_models_beat_uniform_on_their_domains() {
    // The trained zoo must be meaningfully better than the 256-way uniform
    // baseline (ppl 256) on English, and not catastrophically bad on CJK.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let reg = Registry::new(&dir);
    let batch = rt.manifest.eval_batch;
    let seq = rt.manifest.seq;
    let eval = rt.dense_evaluator("llama-t", batch).unwrap();
    for (domain, bound) in [("wiki", 40.0), ("cmrc_cn", 200.0)] {
        let corpus = reg.load(domain, "test").unwrap();
        let mut sum = 0.0;
        let mut tok = 0.0;
        for tb in Batcher::new(batch, seq)
            .eval_batches(&corpus, batch * 2)
            .iter()
            .filter(|tb| tb.valid_rows == tb.batch)
        {
            let out = eval.loss(tb).unwrap();
            sum += out.sum_nll;
            tok += out.count;
        }
        let ppl = (sum / tok).exp();
        assert!(ppl < bound, "{domain}: ppl {ppl} (expected < {bound})");
        assert!(ppl > 1.0);
    }
}
