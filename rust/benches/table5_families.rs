//! Table 5 regenerator-bench: model families (vicuna/mistral/opt) at 30%.

use nsvd::bench::{artifacts_dir, table_windows, Suite};
use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::data::corpus::DOMAIN_NAMES;

fn main() {
    let mut suite = Suite::from_args("table5_families");
    let Some(dir) = artifacts_dir() else { return };
    let models: &[&str] =
        if suite.quick() { &["opt-t"] } else { &["vicuna-t", "mistral-t", "opt-t"] };
    for model in models {
        let mut cfg = PipelineConfig::default_for_model(model);
        cfg.artifacts_dir = dir.clone();
        cfg.eval_windows = table_windows(suite.quick());
        let mut pipeline = Pipeline::new(cfg).unwrap();
        pipeline.calibrate().unwrap();
        for (method, alpha) in [(Method::Asvd0, 1.0), (Method::AsvdI, 1.0), (Method::NsvdI, 0.95)] {
            let name = format!("{model}_{}", method.label());
            let spec = CompressionSpec { method, ratio: 0.30, alpha };
            let mut report = None;
            suite.bench(&name, 1, || {
                report = Some(pipeline.run(&spec).unwrap());
            });
            if let Some(r) = report {
                for d in DOMAIN_NAMES {
                    suite.record_metric(&name, &format!("ppl_{d}"), r.ppl(d).unwrap_or(f64::NAN));
                }
            }
        }
    }
    suite.finish();
}
