//! Perf + quality: the global spectrum-driven rank allocator.
//!
//! Artifact-free (synthetic llama-t weights + calibration stats, synthetic
//! byte corpus), so it runs everywhere.  Sections:
//!
//! * `allocate_profile_*`  — wall-clock of the parallel whitened-spectrum
//!   profiling pass at 1 worker vs all cores, plus a bit-identity pin
//!   across worker counts;
//! * `allocate_greedy`     — wall-clock of the serial water-filling pass,
//!   with the uniform-vs-spectrum total whitened tail error recorded (and
//!   asserted ≤ 1) for ratios 20–50%;
//! * `allocate_ppl_*`      — a small budget-vs-perplexity sweep through the
//!   native evaluator: uniform vs spectrum at the same parameter budget.
//!
//! The stable summary is written to the top-level `BENCH_allocate.json`
//! (same convention as `BENCH_gemm.json` / `BENCH_decompose.json`);
//! regenerate with `cargo bench --bench perf_allocate`.

use nsvd::bench::Suite;
use nsvd::calib::collector::TapStats;
use nsvd::compress::allocate::{self, AllocConfig, AllocStrategy};
use nsvd::compress::engine::{CompressionEngine, EngineConfig, WhitenerCache};
use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::compress::whiten::CalibStats;
use nsvd::data::corpus::Corpus;
use nsvd::eval::perplexity::{evaluate_with_workers, pooled_ppl, EvalBackend};
use nsvd::linalg::matrix::Matrix;
use nsvd::linalg::rsvd::SvdPolicy;
use nsvd::model::config::ModelConfig;
use nsvd::model::weights::{Tensor, Weights};
use nsvd::util::rng::Rng;
use nsvd::util::threads::default_workers;

fn stats(n: usize, rng: &mut Rng) -> CalibStats {
    let x = Matrix::randn(4 * n, n, 1.0, rng);
    let mut s = CalibStats::new(n);
    s.gram = x.gram();
    s.abs_sum = (0..n).map(|j| (0..4 * n).map(|i| x[(i, j)].abs()).sum()).collect();
    s.rows = 4 * n;
    s
}

/// Synthetic llama-t with deliberately heterogeneous layer spectra: blocks
/// get geometrically shrinking weight scales, so a global allocator has
/// something real to exploit (uniform ratios waste rank on the quiet tail).
fn synthetic_model(rng: &mut Rng) -> (ModelConfig, Weights, TapStats) {
    let cfg = ModelConfig::builtin("llama-t").unwrap();
    let mut weights = Weights::default();
    for (name, n_in, n_out) in &cfg.linear_shapes {
        let block: usize = name
            .split('.')
            .nth(1)
            .and_then(|b| b.parse().ok())
            .unwrap_or(0);
        let scale = 0.05 * 0.5f64.powi(block as i32);
        weights.tensors.insert(
            name.clone(),
            Tensor {
                dims: vec![*n_in, *n_out],
                data: Matrix::randn(*n_in, *n_out, scale, rng).to_f32(),
            },
        );
    }
    let mut taps = TapStats::default();
    for tap in cfg.tap_names() {
        let dim = if tap.ends_with("mlp_down_in") { cfg.d_ff } else { cfg.d_model };
        taps.taps.insert(tap, stats(dim, rng));
    }
    (cfg, weights, taps)
}

fn main() {
    let mut suite = Suite::from_args("perf_allocate");
    let mut rng = Rng::new(5);
    let (cfg, weights, taps) = synthetic_model(&mut rng);
    let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha: 0.95 };
    let cores = default_workers();

    let engine_at = |workers: usize| {
        CompressionEngine::new(EngineConfig { workers, svd: SvdPolicy::exact() })
    };
    // Whiteners are built once up front so the profile benches time the
    // spectra, not the (cached-across-sweeps) eigen/Cholesky setup.
    let mut cache = WhitenerCache::default();
    let profiles = engine_at(1)
        .profile_spectra(&cfg, &weights, &taps, &spec, &mut cache)
        .unwrap();

    // ---- Profiling pass wall-clock: serial vs all cores ----
    suite.bench("allocate_profile_w1", 3, || {
        std::hint::black_box(
            engine_at(1).profile_spectra(&cfg, &weights, &taps, &spec, &mut cache).unwrap(),
        );
    });
    if cores > 1 {
        suite.bench(&format!("allocate_profile_w{cores}"), 3, || {
            std::hint::black_box(
                engine_at(cores)
                    .profile_spectra(&cfg, &weights, &taps, &spec, &mut cache)
                    .unwrap(),
            );
        });
    }
    // Bit-identity pin: spectra at any worker count match the serial pass.
    if suite.enabled("allocate_profile") {
        let wide = engine_at(4.min(cores.max(2)))
            .profile_spectra(&cfg, &weights, &taps, &spec, &mut cache)
            .unwrap();
        for (a, b) in profiles.iter().zip(&wide) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.spectrum, b.spectrum, "{}: spectra must be bit-identical", a.name);
        }
        println!("      allocate_profile: spectra bit-identical across worker counts");
    }

    // ---- Serial water-filling wall-clock + uniform-vs-spectrum quality ----
    suite.bench("allocate_greedy", 20, || {
        std::hint::black_box(allocate::spectrum_ranks(&profiles, 0.30, None));
    });
    if suite.enabled("allocate_greedy") {
        for &ratio in &[0.20, 0.30, 0.40, 0.50] {
            let ks = allocate::spectrum_ranks(&profiles, ratio, None);
            let uks: Vec<usize> = profiles
                .iter()
                .map(|p| nsvd::compress::ranks::k_budget(p.m, p.n, ratio))
                .collect();
            let spent: usize = profiles.iter().zip(&ks).map(|(p, &k)| p.cost() * k).sum();
            let budget = allocate::uniform_budget(&profiles, ratio);
            assert!(spent <= budget, "spectrum overspent at ρ={ratio}");
            let ts = allocate::total_tail_sq(&profiles, &ks);
            let tu = allocate::total_tail_sq(&profiles, &uks);
            assert!(ts <= tu + 1e-12 * (1.0 + tu), "spectrum lost to uniform at ρ={ratio}");
            let rel = if tu > 0.0 { ts / tu } else { 1.0 };
            println!(
                "      ρ={ratio:.2}: tail²(spectrum)/tail²(uniform) = {rel:.4} \
                 (params {spent} of {budget})"
            );
            suite.record_metric(
                "allocate_greedy",
                &format!("tail_ratio_r{:02.0}", ratio * 100.0),
                rel,
            );
        }
    }

    // ---- Budget-vs-perplexity through the native evaluator ----
    // Tiny eval (synthetic bytes, few windows) — this tracks the plumbing
    // end to end; the quality signal lives in the tail ratios above.
    let eval_name = "allocate_ppl_sweep";
    if suite.enabled(eval_name) {
        let corpus = Corpus {
            name: "synthetic".into(),
            tokens: (0..4096usize).map(|i| (i * 31 % 251) as u8).collect(),
        };
        let windows = if suite.quick() { 4 } else { 8 };
        let engine = engine_at(cores);
        for (strategy, label) in
            [(AllocStrategy::Uniform, "uniform"), (AllocStrategy::Spectrum, "spectrum")]
        {
            let plans = engine
                .plan_model(
                    &cfg,
                    &weights,
                    &taps,
                    &spec,
                    &AllocConfig { strategy, ..Default::default() },
                    &mut cache,
                )
                .unwrap();
            let cm = engine
                .compress_model_planned(&cfg, &weights, &taps, &spec, &plans, &mut cache)
                .unwrap();
            let backend =
                EvalBackend::Native { cfg: &cfg, weights: &weights, compressed: Some(&cm) };
            let result =
                evaluate_with_workers(&backend, &corpus, 4, 32, windows, cores).unwrap();
            let ppl = pooled_ppl(&[result]);
            println!(
                "      {label}: params={} pooled ppl={ppl:.2} (ρ=30%, {windows} windows)",
                cm.params()
            );
            suite.record_metric(eval_name, &format!("ppl_{label}_r30"), ppl);
            suite.record_metric(eval_name, &format!("params_{label}_r30"), cm.params() as f64);
        }
    }

    // Stable top-level summary, matching the BENCH_gemm.json convention.
    // Skipped under a filter that excludes the allocate benches and in
    // --quick mode, so partial runs never clobber the tracked numbers.
    if suite.enabled("allocate") && !suite.quick() {
        suite.write_summary(std::path::Path::new("BENCH_allocate.json"), "allocate");
    }
    suite.finish();
}
