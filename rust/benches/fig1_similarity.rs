//! Table 2 + Figure 1 regenerator-bench: activation similarity analysis.

use nsvd::bench::{artifacts_dir, table_windows, Suite};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};

fn main() {
    let mut suite = Suite::from_args("fig1_similarity");
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = PipelineConfig::default_for_model("llama-t");
    cfg.artifacts_dir = dir;
    cfg.eval_windows = table_windows(suite.quick());
    let mut pipeline = Pipeline::new(cfg).unwrap();
    let mut reports = Vec::new();
    suite.bench("similarity_all_domains", 1, || {
        reports = pipeline.similarity_analysis().unwrap();
    });
    for r in &reports {
        suite.record_metric("similarity_all_domains", &format!("mean_{}", r.dataset), r.mean);
        suite.record_metric("similarity_all_domains", &format!("std_{}", r.dataset), r.std);
        println!("Figure 1 [{}]:\n{}", r.dataset, r.ascii_histogram(10, 30));
    }
    suite.finish();
}
