//! Perf: the linalg substrate's hot kernels across the sizes the
//! decomposition path actually hits (d_model 128-256, d_ff up to 384).

use nsvd::bench::Suite;
use nsvd::linalg::chol::cholesky_psd;
use nsvd::linalg::eig::sym_eig;
use nsvd::linalg::id::interpolative;
use nsvd::linalg::matrix::Matrix;
use nsvd::linalg::qr::{qr_pivoted, qr_thin};
use nsvd::linalg::svd::svd_thin;
use nsvd::util::rng::Rng;

fn main() {
    let mut suite = Suite::from_args("perf_linalg");
    let mut rng = Rng::new(1);
    for &n in &[128usize, 256, 384] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        suite.bench_throughput(&format!("matmul_{n}"), 5, flops, || {
            std::hint::black_box(a.matmul(&b));
        });
        suite.bench(&format!("svd_{n}"), 3, || {
            std::hint::black_box(svd_thin(&a));
        });
        let gram = a.matmul_nt(&a);
        suite.bench(&format!("eig_{n}"), 3, || {
            std::hint::black_box(sym_eig(&gram));
        });
        suite.bench(&format!("cholesky_{n}"), 5, || {
            std::hint::black_box(cholesky_psd(&gram, 1e-8));
        });
        suite.bench(&format!("qr_{n}"), 5, || {
            std::hint::black_box(qr_thin(&a));
        });
        suite.bench(&format!("qr_pivoted_{n}"), 3, || {
            std::hint::black_box(qr_pivoted(&a));
        });
        suite.bench(&format!("id_k32_{n}"), 3, || {
            std::hint::black_box(interpolative(&a, 32));
        });
    }
    suite.finish();
}
