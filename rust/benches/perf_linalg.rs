//! Perf: the linalg substrate's hot kernels across the sizes the
//! decomposition path actually hits (d_model 128-256, d_ff up to 384),
//! plus the jacobi-vs-randomized truncated-SVD comparison that motivates
//! the `SvdPolicy` fast path, plus the unified tiled+packed GEMM kernel
//! vs the retired naive loop (parity smoke + GFLOP/s + worker scaling;
//! summarized into the top-level `BENCH_gemm.json`), plus the level-3
//! factorization substrate: packed SYRK vs the TN Gram, blocked compact-WY
//! QR vs the retired unblocked path, and tournament vs cyclic Jacobi.
//! Also: the int8 quantized GEMM (bit-parity vs the naive i8 oracle, then
//! GFLOP/s vs the f32 kernel at equal shapes).  `ci.sh` runs the `gemm`,
//! `int8`, `syrk`, and `qr_parity` benches in `--quick` mode as
//! bit/tolerance parity smokes; every run prints the detected CPU features
//! so logs record which microkernel tier (scalar/AVX2/AVX-512/NEON) ran.

use nsvd::bench::Suite;
use nsvd::linalg::chol::cholesky_psd;
use nsvd::linalg::eig::{sym_eig, sym_eig_ordered};
use nsvd::linalg::gemm;
use nsvd::linalg::id::interpolative;
use nsvd::linalg::jacobi::JacobiOrdering;
use nsvd::linalg::matrix::Matrix;
use nsvd::linalg::qr::{qr_pivoted, qr_pivoted_unblocked, qr_thin, qr_thin_unblocked};
use nsvd::linalg::quant;
use nsvd::linalg::rsvd::{decaying_matrix as decaying, svd_for_rank, SvdPolicy};
use nsvd::linalg::svd::{svd_thin, svd_thin_ordered};
use nsvd::util::rng::Rng;
use nsvd::util::timer::Timer;

fn main() {
    let mut suite = Suite::from_args("perf_linalg");
    let mut rng = Rng::new(1);
    // Record which microkernel tier this machine dispatches to — the int8
    // and f32 SIMD numbers below are meaningless without it in the log.
    println!("cpu: {}", gemm::cpu_features());

    // ---- Unified tiled+packed GEMM kernel vs the retired naive loop ----
    // Parity smoke runs first (ci.sh invokes `-- gemm --quick`, so a kernel
    // regression fails fast); then GFLOP/s, measured speedup-vs-naive, the
    // row-parallel worker scaling, and the f32 forward-pass instantiation.
    let gemm_sizes: &[usize] = if suite.quick() { &[128] } else { &[128, 256, 512] };
    for &n in gemm_sizes {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        if suite.enabled("gemm_parity") {
            let mut c_naive = vec![0.0; n * n];
            gemm::naive_nn(n, n, n, &a.data, &b.data, &mut c_naive);
            let c_tiled = a.matmul(&b);
            let err = c_naive
                .iter()
                .zip(&c_tiled.data)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-12 * (1.0 + n as f64), "gemm parity @{n}: max err {err:e}");
            let mut c_par = vec![0.0; n * n];
            gemm::gemm_nn(n, n, n, &a.data, &b.data, &mut c_par, 4);
            assert_eq!(c_par, c_tiled.data, "gemm @{n}: 4 workers not bit-identical");
            println!("gemm_parity_{n}: OK (max err {err:.2e}, 4-worker bit-identical)");
        }
        let flops = 2.0 * (n as f64).powi(3);
        suite.bench_throughput(&format!("gemm_naive_f64_{n}"), 5, flops, || {
            let mut c = vec![0.0; n * n];
            gemm::naive_nn(n, n, n, &a.data, &b.data, &mut c);
            std::hint::black_box(c);
        });
        suite.bench_throughput(&format!("gemm_tiled_f64_{n}"), 5, flops, || {
            std::hint::black_box(a.matmul(&b));
        });
        // Speedup from the robust means the two benches above collected
        // (warmup + multiple iterations), not a fresh single-shot timing.
        if let (Some(naive_s), Some(tiled_s)) = (
            suite.mean_of(&format!("gemm_naive_f64_{n}")),
            suite.mean_of(&format!("gemm_tiled_f64_{n}")),
        ) {
            suite.record_metric(
                &format!("gemm_tiled_f64_{n}"),
                "speedup_vs_naive",
                naive_s / tiled_s.max(1e-12),
            );
        }
        for workers in [2usize, 4] {
            suite.bench_throughput(&format!("gemm_tiled_f64_{n}_w{workers}"), 5, flops, || {
                let mut c = vec![0.0; n * n];
                gemm::gemm_nn(n, n, n, &a.data, &b.data, &mut c, workers);
                std::hint::black_box(c);
            });
        }
        let af = a.to_f32();
        let bf = b.to_f32();
        suite.bench_throughput(&format!("gemm_tiled_f32_{n}"), 5, flops, || {
            let mut c = vec![0.0f32; n * n];
            gemm::gemm_nn(n, n, n, &af, &bf, &mut c, 1);
            std::hint::black_box(c);
        });
    }
    // ---- Int8 quantized GEMM: parity smoke + GFLOP/s vs the f32 kernel ----
    // Parity first (ci.sh runs `-- int8 --quick`): the tiled/SIMD int8
    // kernel must be BIT-identical to the naive `gemm_i8_ref` oracle at
    // workers {1, 4}, under both the dispatched ISA and a forced-scalar
    // run, so a SIMD regression can never hide behind the dispatcher.
    let int8_sizes: &[usize] = if suite.quick() { &[128] } else { &[128, 256, 512] };
    for &n in int8_sizes {
        let (m, k) = (n, n);
        let group = quant::DEFAULT_GROUP;
        let xf: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let wf: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let (aq, a_scales) = quant::quantize_row_groups(&xf, m, k, group);
        let wq = quant::quantize_columns(&wf, k, n, group);
        if suite.enabled(&format!("gemm_int8_parity_{n}")) {
            let mut want = vec![0.0f32; m * n];
            gemm::gemm_i8_ref(m, k, n, &aq, &a_scales, &wq.data, &wq.scales, group, &mut want);
            for forced_scalar in [false, true] {
                let _g = forced_scalar.then(|| gemm::scoped_isa(gemm::Isa::Scalar));
                for workers in [1usize, 4] {
                    let mut got = vec![0.0f32; m * n];
                    gemm::gemm_i8_nn(
                        m, k, n, &aq, &a_scales, &wq.data, &wq.scales, group, &mut got, workers,
                    );
                    assert_eq!(
                        got, want,
                        "int8 parity @{n} w={workers} forced_scalar={forced_scalar}"
                    );
                }
            }
            println!(
                "gemm_int8_parity_{n}: OK (bit-identical to ref, workers 1 and 4, \
                 dispatched and forced-scalar)"
            );
        }
        let flops = 2.0 * (n as f64).powi(3);
        suite.bench_throughput(&format!("gemm_int8_{n}"), 5, flops, || {
            let mut c = vec![0.0f32; m * n];
            gemm::gemm_i8_nn(
                m, k, n, &aq, &a_scales, &wq.data, &wq.scales, group, &mut c, 1,
            );
            std::hint::black_box(c);
        });
        if let (Some(f32_s), Some(i8_s)) = (
            suite.mean_of(&format!("gemm_tiled_f32_{n}")),
            suite.mean_of(&format!("gemm_int8_{n}")),
        ) {
            suite.record_metric(
                &format!("gemm_int8_{n}"),
                "speedup_vs_f32",
                f32_s / i8_s.max(1e-12),
            );
        }
        for workers in [2usize, 4] {
            suite.bench_throughput(&format!("gemm_int8_{n}_w{workers}"), 5, flops, || {
                let mut c = vec![0.0f32; m * n];
                gemm::gemm_i8_nn(
                    m, k, n, &aq, &a_scales, &wq.data, &wq.scales, group, &mut c, workers,
                );
                std::hint::black_box(c);
            });
        }
    }

    // ---- Packed SYRK vs the TN Gram path (half the flops + threads) ----
    // Parity smoke first (ci.sh runs `-- syrk --quick`): the SYRK upper
    // triangle must be BIT-identical to gemm_tn(A, A) at workers {1, 4}.
    let syrk_sizes: &[usize] = if suite.quick() { &[128] } else { &[256, 512] };
    for &n in syrk_sizes {
        let rows = n; // square-ish Gram: k = n sample rows of dimension n
        let a = Matrix::randn(rows, n, 1.0, &mut rng);
        if suite.enabled(&format!("syrk_parity_{n}")) {
            let mut want = vec![0.0; n * n];
            gemm::gemm_tn(n, rows, n, &a.data, &a.data, &mut want, 1);
            for workers in [1usize, 4] {
                let mut got = vec![0.0; n * n];
                gemm::syrk_tn(n, rows, &a.data, &mut got, workers);
                for i in 0..n {
                    for j in i..n {
                        assert_eq!(
                            got[i * n + j],
                            want[i * n + j],
                            "syrk parity @{n} w={workers}: ({i},{j})"
                        );
                    }
                }
            }
            println!("syrk_parity_{n}: OK (upper triangle bit-identical, workers 1 and 4)");
        }
        // Gram flops: n²·rows for the full TN product, half for SYRK — both
        // annotated with the FULL product's flops so the throughput numbers
        // are directly comparable.
        let flops = 2.0 * (n as f64) * (n as f64) * rows as f64;
        suite.bench_throughput(&format!("syrk_baseline_tn_{n}"), 5, flops, || {
            let mut c = vec![0.0; n * n];
            gemm::gemm_tn(n, rows, n, &a.data, &a.data, &mut c, 1);
            std::hint::black_box(c);
        });
        suite.bench_throughput(&format!("syrk_{n}"), 5, flops, || {
            let mut c = vec![0.0; n * n];
            gemm::syrk_tn(n, rows, &a.data, &mut c, 1);
            std::hint::black_box(c);
        });
        if let (Some(tn_s), Some(syrk_s)) = (
            suite.mean_of(&format!("syrk_baseline_tn_{n}")),
            suite.mean_of(&format!("syrk_{n}")),
        ) {
            suite.record_metric(&format!("syrk_{n}"), "speedup_vs_tn", tn_s / syrk_s.max(1e-12));
        }
        for workers in [2usize, 4] {
            suite.bench_throughput(&format!("syrk_{n}_w{workers}"), 5, flops, || {
                let mut c = vec![0.0; n * n];
                gemm::syrk_tn(n, rows, &a.data, &mut c, workers);
                std::hint::black_box(c);
            });
        }
    }

    // ---- Blocked compact-WY QR vs the retired unblocked path ----
    // Parity smoke (ci.sh runs `-- qr_parity --quick`): Q/R agreement to
    // rounding, orthogonality at the acceptance bar, exact pivot agreement.
    let qr_parity_sizes: &[usize] = if suite.quick() { &[128] } else { &[256] };
    for &n in qr_parity_sizes {
        if !suite.enabled(&format!("qr_parity_{n}")) {
            continue;
        }
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let (qb, rb) = qr_thin(&a);
        let (qu, ru) = qr_thin_unblocked(&a);
        let scale = 1.0 + a.fro_norm();
        assert!(qb.dist(&qu) < 1e-10 * scale, "qr parity @{n}: Q diverged");
        assert!(rb.dist(&ru) < 1e-10 * scale, "qr parity @{n}: R diverged");
        let orth = qb.matmul_tn(&qb).dist(&Matrix::identity(n));
        assert!(orth < 1e-12 * n as f64, "qr parity @{n}: ‖QᵀQ−I‖ = {orth:e}");
        let (_, rpb, pb) = qr_pivoted(&a);
        let (_, rpu, pu) = qr_pivoted_unblocked(&a);
        assert_eq!(pb, pu, "qr parity @{n}: pivots diverged");
        assert_eq!(rpb.data, rpu.data, "qr parity @{n}: pivoted R not bit-identical");
        println!("qr_parity_{n}: OK (Q/R agree, ‖QᵀQ−I‖ = {orth:.2e}, pivots exact)");
    }

    for &n in &[128usize, 256, 384] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        suite.bench_throughput(&format!("matmul_{n}"), 5, flops, || {
            std::hint::black_box(a.matmul(&b));
        });
        suite.bench(&format!("svd_{n}"), 3, || {
            std::hint::black_box(svd_thin(&a));
        });
        let gram = a.matmul_nt(&a);
        suite.bench(&format!("eig_{n}"), 3, || {
            std::hint::black_box(sym_eig(&gram));
        });
        suite.bench(&format!("cholesky_{n}"), 5, || {
            std::hint::black_box(cholesky_psd(&gram, 1e-8));
        });
        suite.bench(&format!("qr_{n}"), 5, || {
            std::hint::black_box(qr_thin(&a));
        });
        suite.bench(&format!("qr_unblocked_{n}"), 5, || {
            std::hint::black_box(qr_thin_unblocked(&a));
        });
        if let (Some(unb), Some(blk)) =
            (suite.mean_of(&format!("qr_unblocked_{n}")), suite.mean_of(&format!("qr_{n}")))
        {
            suite.record_metric(&format!("qr_{n}"), "speedup_vs_unblocked", unb / blk.max(1e-12));
        }
        suite.bench(&format!("qr_pivoted_{n}"), 3, || {
            std::hint::black_box(qr_pivoted(&a));
        });
        suite.bench(&format!("id_k32_{n}"), 3, || {
            std::hint::black_box(interpolative(&a, 32));
        });
    }

    // ---- Tournament vs cyclic Jacobi (SVD + eig), serial and w=4 ----
    {
        let n = 256usize;
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let gram = a.matmul_nt(&a);
        suite.bench(&format!("jacobi_svd_cyclic_{n}"), 3, || {
            std::hint::black_box(svd_thin(&a));
        });
        for workers in [1usize, 4] {
            suite.bench(&format!("jacobi_svd_tournament_w{workers}_{n}"), 3, || {
                std::hint::black_box(svd_thin_ordered(&a, JacobiOrdering::Tournament, workers));
            });
        }
        if let (Some(cyc), Some(tor)) = (
            suite.mean_of(&format!("jacobi_svd_cyclic_{n}")),
            suite.mean_of(&format!("jacobi_svd_tournament_w4_{n}")),
        ) {
            suite.record_metric(
                &format!("jacobi_svd_tournament_w4_{n}"),
                "speedup_vs_cyclic",
                cyc / tor.max(1e-12),
            );
        }
        suite.bench(&format!("jacobi_eig_cyclic_{n}"), 3, || {
            std::hint::black_box(sym_eig(&gram));
        });
        for workers in [1usize, 4] {
            suite.bench(&format!("jacobi_eig_tournament_w{workers}_{n}"), 3, || {
                std::hint::black_box(sym_eig_ordered(&gram, JacobiOrdering::Tournament, workers));
            });
        }
        if let (Some(cyc), Some(tor)) = (
            suite.mean_of(&format!("jacobi_eig_cyclic_{n}")),
            suite.mean_of(&format!("jacobi_eig_tournament_w4_{n}")),
        ) {
            suite.record_metric(
                &format!("jacobi_eig_tournament_w4_{n}"),
                "speedup_vs_cyclic",
                cyc / tor.max(1e-12),
            );
        }
    }

    // ---- Truncated SVD: exact Jacobi vs the randomized fast path ----
    // Rank k = n/4 (the ISSUE's "rank well below min(m,n)" regime) on a
    // decaying-spectrum matrix, where the 2% certificate passes and the
    // sketch genuinely replaces Jacobi rather than falling back.
    for &n in &[128usize, 256, 384] {
        let a = decaying(n, n, 0.93, &mut rng);
        let k = n / 4;
        let exact = SvdPolicy::exact();
        let auto = SvdPolicy::auto();
        suite.bench(&format!("svd_exact_trunc_k{k}_{n}"), 3, || {
            std::hint::black_box(svd_for_rank(&a, k, &exact));
        });
        suite.bench(&format!("rsvd_k{k}_{n}"), 3, || {
            std::hint::black_box(svd_for_rank(&a, k, &auto));
        });
        if suite.enabled(&format!("rsvd_k{k}_{n}")) {
            let t = Timer::start();
            let se = svd_for_rank(&a, k, &exact);
            let exact_s = t.elapsed_s();
            let t = Timer::start();
            let sr = svd_for_rank(&a, k, &auto);
            let rsvd_s = t.elapsed_s();
            let err_e = se.u.scale_cols(&se.s).matmul_nt(&se.v).dist(&a);
            let err_r = sr.u.scale_cols(&sr.s).matmul_nt(&sr.v).dist(&a);
            println!(
                "      rsvd_{n}: jacobi {exact_s:.3}s vs rsvd {rsvd_s:.3}s \
                 ({:.1}x), err {err_e:.3e} vs {err_r:.3e}",
                exact_s / rsvd_s.max(1e-12)
            );
            suite.record_metric(
                &format!("rsvd_k{k}_{n}"),
                "speedup_vs_jacobi",
                exact_s / rsvd_s.max(1e-12),
            );
            suite.record_metric(
                &format!("rsvd_k{k}_{n}"),
                "rel_err_excess",
                err_r / err_e.max(1e-300) - 1.0,
            );
        }
        // A tall shape (the wo / w_down layers are rectangular).
        let tall = decaying(2 * n, n / 2, 0.9, &mut rng);
        suite.bench(&format!("rsvd_tall_{}x{}_k{}", 2 * n, n / 2, n / 8), 3, || {
            std::hint::black_box(svd_for_rank(&tall, n / 8, &auto));
        });
    }
    // Stable top-level summary (GFLOP/s per shape, speedup vs naive) so the
    // kernel's perf trajectory is tracked across PRs.  Skipped when a filter
    // excludes the gemm benches AND in --quick mode (the ci.sh smoke), so a
    // partial or low-iteration run never clobbers the full numbers.
    if suite.enabled("gemm") && !suite.quick() {
        suite.write_summary(std::path::Path::new("BENCH_gemm.json"), "gemm");
    }
    suite.finish();
}
