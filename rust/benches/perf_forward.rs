//! Perf: forward-pass engines — native f32 vs PJRT dense vs PJRT low-rank —
//! in tokens/second at the eval batch shape, plus the batch-parallel native
//! evaluator's worker scaling (runs without artifacts: random weights,
//! synthetic corpus).

use nsvd::bench::{artifacts_dir, Suite};
use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::data::batch::Batcher;
use nsvd::data::corpus::{Corpus, Registry};
use nsvd::eval::perplexity::{evaluate_with_workers, EvalBackend};
use nsvd::model::config::ModelConfig;
use nsvd::model::forward::random_weights;

fn main() {
    let mut suite = Suite::from_args("perf_forward");

    // ---- Native evaluator worker scaling (no artifacts needed) ----
    // Independent TokenBatches fan out over the worker pool; each forward
    // pass runs the f32 GEMM kernel with its ThreadBudget share.  The
    // result is bit-identical at every worker count, so this measures pure
    // wall-clock scaling of the eval side.
    {
        let cfg = ModelConfig::builtin("llama-t").expect("builtin llama-t");
        let weights = random_weights(&cfg, 1);
        let corpus = Corpus {
            name: "synthetic".into(),
            tokens: (0..1usize << 15).map(|i| (i * 31 % 251) as u8).collect(),
        };
        let (batch, seq) = (8usize, 64usize.min(cfg.max_seq));
        // ≥ 4×batch windows so the OUTER batch fan-out genuinely reaches 4
        // workers (2 batches would cap outer at 2 and measure inner-GEMM
        // scaling instead).
        let windows = if suite.quick() { 16 } else { 32 };
        let backend = EvalBackend::Native { cfg: &cfg, weights: &weights, compressed: None };
        let tokens_per_iter = (windows * seq) as f64;
        for workers in [1usize, 2, 4] {
            suite.bench_throughput(&format!("native_eval_w{workers}"), 3, tokens_per_iter, || {
                std::hint::black_box(
                    evaluate_with_workers(&backend, &corpus, batch, seq, windows, workers)
                        .unwrap(),
                );
            });
        }
    }

    let Some(dir) = artifacts_dir() else {
        suite.finish();
        return;
    };
    let mut cfg = PipelineConfig::default_for_model("llama-t");
    cfg.artifacts_dir = dir.clone();
    let mut pipeline = Pipeline::new(cfg).unwrap();
    let registry = Registry::new(&dir);
    let corpus = registry.load("wiki", "test").unwrap();
    let batch = pipeline.batch();
    let seq = pipeline.seq();
    let tb = Batcher::new(batch, seq).eval_batches(&corpus, batch)[0].clone();
    let tokens_per_iter = (batch * seq) as f64;

    let rt = pipeline.runtime().unwrap();
    let dense = rt.dense_evaluator("llama-t", batch).unwrap();
    suite.bench_throughput("pjrt_dense_fwd", 10, tokens_per_iter, || {
        std::hint::black_box(dense.loss(&tb).unwrap());
    });

    let cm = pipeline
        .compress(&CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha: 0.95 })
        .unwrap();
    let rt = pipeline.runtime().unwrap();
    let lowrank = rt.lowrank_evaluator("llama-t", batch, &cm).unwrap();
    suite.bench_throughput("pjrt_lowrank_fwd", 10, tokens_per_iter, || {
        std::hint::black_box(lowrank.loss(&tb).unwrap());
    });

    let backend = EvalBackend::Native {
        cfg: &pipeline.model_cfg,
        weights: &pipeline.weights,
        compressed: None,
    };
    suite.bench_throughput("native_dense_fwd", 3, tokens_per_iter, || {
        std::hint::black_box(backend.loss(&tb).unwrap());
    });
    suite.finish();
}
