//! Perf: forward-pass engines — native f32 vs PJRT dense vs PJRT low-rank —
//! in tokens/second at the eval batch shape.

use nsvd::bench::{artifacts_dir, Suite};
use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::data::batch::Batcher;
use nsvd::data::corpus::Registry;
use nsvd::eval::perplexity::EvalBackend;

fn main() {
    let mut suite = Suite::from_args("perf_forward");
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = PipelineConfig::default_for_model("llama-t");
    cfg.artifacts_dir = dir.clone();
    let mut pipeline = Pipeline::new(cfg).unwrap();
    let registry = Registry::new(&dir);
    let corpus = registry.load("wiki", "test").unwrap();
    let batch = pipeline.batch();
    let seq = pipeline.seq();
    let tb = Batcher::new(batch, seq).eval_batches(&corpus, batch)[0].clone();
    let tokens_per_iter = (batch * seq) as f64;

    let rt = pipeline.runtime().unwrap();
    let dense = rt.dense_evaluator("llama-t", batch).unwrap();
    suite.bench_throughput("pjrt_dense_fwd", 10, tokens_per_iter, || {
        std::hint::black_box(dense.loss(&tb).unwrap());
    });

    let cm = pipeline
        .compress(&CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha: 0.95 })
        .unwrap();
    let rt = pipeline.runtime().unwrap();
    let lowrank = rt.lowrank_evaluator("llama-t", batch, &cm).unwrap();
    suite.bench_throughput("pjrt_lowrank_fwd", 10, tokens_per_iter, || {
        std::hint::black_box(lowrank.loss(&tb).unwrap());
    });

    let backend = EvalBackend::Native {
        cfg: &pipeline.model_cfg,
        weights: &pipeline.weights,
        compressed: None,
    };
    suite.bench_throughput("native_dense_fwd", 3, tokens_per_iter, || {
        std::hint::black_box(backend.loss(&tb).unwrap());
    });
    suite.finish();
}
