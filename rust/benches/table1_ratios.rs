//! Table 1 regenerator-bench: methods × ratios on llama-t.
//!
//! Times the full pipeline per (method, ratio) cell and records the
//! perplexity metrics the table reports.  Rows are printed in the paper's
//! layout by `nsvd table 1`; here we persist the raw numbers to
//! target/bench-results/table1_ratios.json.

use nsvd::bench::{artifacts_dir, table_windows, Suite};
use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::data::corpus::DOMAIN_NAMES;

fn main() {
    let mut suite = Suite::from_args("table1_ratios");
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = PipelineConfig::default_for_model("llama-t");
    cfg.artifacts_dir = dir;
    cfg.eval_windows = table_windows(suite.quick());
    let mut pipeline = Pipeline::new(cfg).unwrap();
    pipeline.calibrate().unwrap();
    let ratios: &[f64] = if suite.quick() { &[0.3] } else { &[0.1, 0.2, 0.3, 0.4, 0.5] };
    for &ratio in ratios {
        for method in Method::table1() {
            let name = format!("{}_r{:02.0}", method.label(), ratio * 100.0);
            if !suite.enabled(&name) {
                continue;
            }
            let spec = CompressionSpec { method, ratio, alpha: 0.95 };
            let mut report = None;
            suite.bench(&name, 1, || {
                report = Some(pipeline.run(&spec).unwrap());
            });
            if let Some(r) = report {
                for d in DOMAIN_NAMES {
                    suite.record_metric(&name, &format!("ppl_{d}"), r.ppl(d).unwrap_or(f64::NAN));
                }
            }
        }
    }
    suite.finish();
}
