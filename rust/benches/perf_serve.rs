//! Perf: continuous-batching generation server — decode tokens/s vs batch
//! size for dense vs NSVD-shaped low-rank overrides, plus the
//! batched-vs-sequential parity smoke.
//!
//! Artifact-free (random weights, synthetic factors): the subject is the
//! serving system — the slotted KV pool, the step scheduler, and the
//! one-GEMM-per-weight batched decode — not model quality.
//!
//! The stable summary is written to the top-level `BENCH_serve.json`
//! (same convention as `BENCH_gemm.json` / `BENCH_allocate.json`): decode
//! tokens/s per batch size and the batched-over-b1 speedup, so the decode
//! throughput trajectory is tracked across PRs.  The acceptance number is
//! `speedup_vs_b1 > 1` for b > 1 on multi-core hardware.
//!
//!   cargo bench --bench perf_serve              # full run, refreshes JSON
//!   cargo bench --bench perf_serve -- parity --quick   # ci.sh smoke

use nsvd::bench::{drive_preloaded, synthetic_nsvd, tiny_model, Suite};
use nsvd::model::config::ModelConfig;
use nsvd::model::forward::{random_weights, LinearOverride, NoOverride};
use nsvd::model::generate::{generate, SampleConfig};
use nsvd::model::weights::Weights;
use nsvd::serve::GenConfig;

/// Deterministic synthetic prompt for request `i` — the SINGLE source for
/// both the served requests and the parity expectations below.
fn bench_prompt(i: usize, len: usize) -> Vec<u8> {
    (0..len).map(|t| ((t * 31 + i * 7) % 256) as u8).collect()
}

fn bench_sample(i: usize) -> SampleConfig {
    SampleConfig { temperature: 0.8, top_k: 16, seed: i as u64 }
}

/// Serve `n_req` preloaded requests to completion on this thread; returns
/// the streamed outputs (request order) and generated-token count.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    n_req: usize,
    prompt_len: usize,
    max_new: usize,
    max_batch: usize,
    workers: usize,
) -> (Vec<Vec<u8>>, usize) {
    let reqs = (0..n_req)
        .map(|i| (bench_prompt(i, prompt_len), max_new, bench_sample(i)))
        .collect();
    let gen_cfg = GenConfig {
        max_batch,
        slots: max_batch,
        slot_cap: prompt_len + max_new,
        workers,
    };
    let (outs, metrics) = drive_preloaded(cfg, weights, overrides, &gen_cfg, reqs);
    (outs, metrics.generated)
}

fn main() {
    let mut suite = Suite::from_args("perf_serve");
    let quick = suite.quick();

    // ---- parity smoke: served tokens == sequential generate, bit-exact,
    // at batch sizes {1, 3, 8} × workers {1, 4} (ci.sh runs this filter) ----
    if suite.enabled("serve_parity") {
        let (cfg, weights) = tiny_model("llama-t", 3);
        let cm = synthetic_nsvd(&cfg, 0.30, 0.95, 4);
        suite.bench("serve_parity", 1, || {
            for overrides in [&NoOverride as &dyn LinearOverride, &cm] {
                for &b in &[1usize, 3, 8] {
                    for &workers in &[1usize, 4] {
                        let (outs, _) =
                            run_batch(&cfg, &weights, overrides, 8, 5, 6, b, workers);
                        for (i, out) in outs.iter().enumerate() {
                            let expect = generate(
                                &cfg,
                                &weights,
                                overrides,
                                &bench_prompt(i, 5),
                                6,
                                bench_sample(i),
                            )
                            .unwrap();
                            assert_eq!(
                                *out, expect,
                                "parity failure: batch={b} workers={workers} request {i}"
                            );
                        }
                    }
                }
            }
        });
        suite.record_metric("serve_parity", "parity_ok", 1.0);
    }

    // ---- decode throughput vs batch size, dense vs NSVD override ----
    let cfg = ModelConfig::builtin("llama-t").unwrap();
    let weights = random_weights(&cfg, 1);
    let cm = synthetic_nsvd(&cfg, 0.30, 0.95, 2);
    let max_new = if quick { 8 } else { 48 };
    // prompt_len 1: the single prompt token's step already samples, so
    // EVERY timed step generates one token per active row — tokens/s here
    // is pure decode throughput, not diluted by prefill steps.  (The
    // parity smoke above uses longer prompts to exercise prefill.)
    let prompt_len = 1;
    for (variant, overrides) in
        [("dense", &NoOverride as &dyn LinearOverride), ("nsvd", &cm)]
    {
        for b in [1usize, 2, 4, 8] {
            let name = format!("serve_decode_b{b}_{variant}");
            if !suite.enabled(&name) {
                continue;
            }
            let tokens_per_iter = (b * max_new) as f64;
            // Plain bench(), not bench_throughput(): write_summary would
            // report `items` as (meaningless) gflops in the tracked JSON.
            suite.bench(&name, if quick { 1 } else { 3 }, || {
                let (_, generated) =
                    run_batch(&cfg, &weights, overrides, b, prompt_len, max_new, b, 0);
                assert_eq!(generated, b * max_new);
            });
            if let Some(mb) = suite.mean_of(&name).filter(|&m| m > 0.0) {
                let tps = tokens_per_iter / mb;
                suite.record_metric(&name, "tokens_per_s", tps);
                // Batched tokens/s over batch-1 tokens/s on the same
                // hardware — the continuous-batching win (only computable
                // when the b1 bench ran under the current filter).
                if let Some(m1) = suite
                    .mean_of(&format!("serve_decode_b1_{variant}"))
                    .filter(|&m| m > 0.0)
                {
                    suite.record_metric(&name, "speedup_vs_b1", tps / (max_new as f64 / m1));
                }
            }
        }
    }

    // Stable top-level summary, matching the BENCH_gemm.json convention.
    // Skipped under a filter that excludes the decode benches and in
    // --quick mode, so the ci.sh parity smoke never clobbers the tracked
    // throughput numbers.
    if suite.enabled("serve_decode_b1_dense") && !suite.quick() {
        suite.write_summary(std::path::Path::new("BENCH_serve.json"), "serve");
    }
    suite.finish();
}
