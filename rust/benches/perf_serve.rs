//! Perf: continuous-batching generation server — decode tokens/s vs batch
//! size for dense vs NSVD-shaped low-rank overrides (f32 AND per-group
//! int8 factors riding the integer GEMM), the batched-vs-sequential parity
//! smoke (which pins the batched int8 decode against the sequential int8
//! `generate` reference, bit-for-bit), the paged-vs-contiguous
//! memory-efficiency comparison, and the overload sweep (goodput vs
//! Poisson open-loop offered load at 1x/2x/4x calibrated capacity with a
//! bounded queue and per-request deadlines).
//!
//! Artifact-free (random weights, synthetic factors): the subject is the
//! serving system — the paged KV pool, the prefix trie, the step
//! scheduler, and the one-GEMM-per-weight batched decode — not model
//! quality.
//!
//! The stable summary is written to the top-level `BENCH_serve.json`
//! (same convention as `BENCH_gemm.json` / `BENCH_allocate.json`): decode
//! tokens/s per batch size, the batched-over-b1 speedup, the equal-memory
//! contiguous-vs-paged rows (sustained concurrency, slots-per-GB, tok/s),
//! and the compressed-KV-cache rows (kv-ratio 0.5 parity smoke, the
//! >= 1.8x slots-at-equal-memory admission ratio, tok/s at equal memory).
//! Acceptance: `speedup_vs_b1 > 1` for b > 1 on multi-core hardware, the
//! half-memory paged pool sustaining strictly more concurrent sequences
//! than the old worst-case reservation fits, and
//! `admit_ratio_at_equal_mem >= 1.8` at kv-ratio 0.5.
//!
//!   cargo bench --bench perf_serve              # full run, refreshes JSON
//!   cargo bench --bench perf_serve -- parity --quick   # ci.sh smoke
//!   cargo bench --bench perf_serve -- paged --quick    # ci.sh gate 4f
//!   cargo bench --bench perf_serve -- kv --quick       # ci.sh gate 4i
//!   cargo bench --bench perf_serve -- obs --quick      # obs overhead report

use nsvd::bench::{
    drive_concurrent, drive_concurrent_kv, drive_open_loop, drive_preloaded, drive_preloaded_kv,
    goodput_tokens_per_s, synthetic_nsvd, synthetic_nsvd_int8, tiny_model, OpenLoopTenant, Suite,
};
use nsvd::compress::compress_kv_plain;
use nsvd::linalg::rsvd::SvdPolicy;
use nsvd::model::config::ModelConfig;
use nsvd::model::forward::{random_weights, LinearOverride, NoOverride};
use nsvd::model::generate::{generate, generate_kv, SampleConfig};
use nsvd::model::weights::Weights;
use nsvd::serve::{GenConfig, KvPool};

/// Deterministic synthetic prompt for request `i` — the SINGLE source for
/// both the served requests and the parity expectations below.
fn bench_prompt(i: usize, len: usize) -> Vec<u8> {
    (0..len).map(|t| ((t * 31 + i * 7) % 256) as u8).collect()
}

fn bench_sample(i: usize) -> SampleConfig {
    SampleConfig { temperature: 0.8, top_k: 16, seed: i as u64 }
}

/// Serve `n_req` preloaded requests to completion on this thread; returns
/// the streamed outputs (request order) and generated-token count.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    n_req: usize,
    prompt_len: usize,
    max_new: usize,
    max_batch: usize,
    workers: usize,
) -> (Vec<Vec<u8>>, usize) {
    let reqs = (0..n_req)
        .map(|i| (bench_prompt(i, prompt_len), max_new, bench_sample(i)))
        .collect();
    // Worst-case-sized pool (the old contiguous reservation): these
    // benches measure decode throughput, not memory pressure — the paged
    // section below is where the pool is squeezed.
    let page_size = 4;
    let gen_cfg = GenConfig {
        max_batch,
        pages: max_batch * (prompt_len + max_new - 1).div_ceil(page_size),
        page_size,
        prefill_chunk: 0,
        prefix_share: true,
        workers,
        ..GenConfig::default()
    };
    let (outs, metrics) = drive_preloaded(cfg, weights, overrides, &gen_cfg, reqs);
    (outs, metrics.generated)
}

fn main() {
    let mut suite = Suite::from_args("perf_serve");
    let quick = suite.quick();

    // ---- parity smoke: served tokens == sequential generate, bit-exact,
    // at batch sizes {1, 3, 8} × workers {1, 4} (ci.sh runs this filter) ----
    if suite.enabled("serve_parity") {
        let (cfg, weights) = tiny_model("llama-t", 3);
        let cm = synthetic_nsvd(&cfg, 0.30, 0.95, 4);
        // Same factors quantized to int8: the sequential `generate` run
        // below IS the pinned single-request int8 reference every batched
        // (b, workers) combination must reproduce bit-for-bit.
        let cm_q = synthetic_nsvd_int8(&cfg, 0.30, 0.95, 4);
        suite.bench("serve_parity", 1, || {
            for overrides in [&NoOverride as &dyn LinearOverride, &cm, &cm_q] {
                for &b in &[1usize, 3, 8] {
                    for &workers in &[1usize, 4] {
                        let (outs, _) =
                            run_batch(&cfg, &weights, overrides, 8, 5, 6, b, workers);
                        for (i, out) in outs.iter().enumerate() {
                            let expect = generate(
                                &cfg,
                                &weights,
                                overrides,
                                &bench_prompt(i, 5),
                                6,
                                bench_sample(i),
                            )
                            .unwrap();
                            assert_eq!(
                                *out, expect,
                                "parity failure: batch={b} workers={workers} request {i}"
                            );
                        }
                    }
                }
            }
        });
        suite.record_metric("serve_parity", "parity_ok", 1.0);
    }

    // ---- decode throughput vs batch size, dense vs NSVD override ----
    let cfg = ModelConfig::builtin("llama-t").unwrap();
    let weights = random_weights(&cfg, 1);
    let cm = synthetic_nsvd(&cfg, 0.30, 0.95, 2);
    let cm_q = synthetic_nsvd_int8(&cfg, 0.30, 0.95, 2);
    let max_new = if quick { 8 } else { 48 };
    // prompt_len 1: the single prompt token's step already samples, so
    // EVERY timed step generates one token per active row — tokens/s here
    // is pure decode throughput, not diluted by prefill steps.  (The
    // parity smoke above uses longer prompts to exercise prefill.)
    let prompt_len = 1;
    for (variant, overrides) in [
        ("dense", &NoOverride as &dyn LinearOverride),
        ("nsvd", &cm),
        ("nsvd_int8", &cm_q),
    ] {
        for b in [1usize, 2, 4, 8] {
            let name = format!("serve_decode_b{b}_{variant}");
            if !suite.enabled(&name) {
                continue;
            }
            let tokens_per_iter = (b * max_new) as f64;
            // Plain bench(), not bench_throughput(): write_summary would
            // report `items` as (meaningless) gflops in the tracked JSON.
            suite.bench(&name, if quick { 1 } else { 3 }, || {
                let (_, generated) =
                    run_batch(&cfg, &weights, overrides, b, prompt_len, max_new, b, 0);
                assert_eq!(generated, b * max_new);
            });
            if let Some(mb) = suite.mean_of(&name).filter(|&m| m > 0.0) {
                let tps = tokens_per_iter / mb;
                suite.record_metric(&name, "tokens_per_s", tps);
                // Batched tokens/s over batch-1 tokens/s on the same
                // hardware — the continuous-batching win (only computable
                // when the b1 bench ran under the current filter).
                if let Some(m1) = suite
                    .mean_of(&format!("serve_decode_b1_{variant}"))
                    .filter(|&m| m > 0.0)
                {
                    suite.record_metric(&name, "speedup_vs_b1", tps / (max_new as f64 / m1));
                }
            }
        }
    }

    // ---- paged-vs-contiguous at EQUAL memory: the admission win ----
    // One shared prompt (the prefix trie dedupes it) and closed-loop
    // clients keeping the server saturated.  `half_pages` is HALF the old
    // worst-case reservation; the pre-paging scheduler in that memory
    // would run exactly `old_equiv_slots` sequences, hard.  The paged pool
    // must sustain strictly more at the same byte budget.
    if suite.enabled("serve_paged") {
        let (n_req, prompt_len, max_new) =
            if quick { (8usize, 16usize, 8usize) } else { (16, 16, 32) };
        let total = 3 * n_req;
        let page_size = 4;
        let rows_worst = prompt_len + max_new - 1;
        let full_pages = n_req * rows_worst.div_ceil(page_size);
        let half_pages = (full_pages / 2).max(1);
        let old_equiv_slots = ((half_pages * page_size) / rows_worst).max(1);
        let shared_prompt = bench_prompt(0, prompt_len);
        let make = |i: usize| (shared_prompt.clone(), max_new, bench_sample(i));
        let mut paged_m = None;
        suite.bench("serve_paged_half_pool", 1, || {
            let gen_cfg = GenConfig {
                max_batch: n_req,
                pages: half_pages,
                page_size,
                prefill_chunk: 8,
                prefix_share: true,
                workers: 0,
                ..GenConfig::default()
            };
            let (m, stats) =
                drive_concurrent(&cfg, &weights, &cm, &gen_cfg, n_req, total, &make).unwrap();
            assert_eq!(m.completed, total, "all requests must complete under pressure");
            assert!(stats.iter().all(|s| s.generated == max_new));
            paged_m = Some(m);
        });
        let mut contig_m = None;
        suite.bench("serve_paged_contig_equiv", 1, || {
            let gen_cfg = GenConfig {
                max_batch: old_equiv_slots,
                pages: half_pages,
                page_size,
                prefill_chunk: 0,
                prefix_share: false,
                workers: 0,
                ..GenConfig::default()
            };
            let (m, _) =
                drive_concurrent(&cfg, &weights, &cm, &gen_cfg, n_req, total, &make).unwrap();
            assert_eq!(m.completed, total);
            contig_m = Some(m);
        });
        if let (Some(p), Some(c)) = (paged_m, contig_m) {
            // Pool memory: K + V pages across all layers, f32.
            let page_bytes = (2 * cfg.n_layers * page_size * cfg.d_model * 4) as f64;
            let pool_gb = half_pages as f64 * page_bytes / 1e9;
            assert!(
                p.mean_batch_fill() > old_equiv_slots as f64,
                "half-memory paged pool must sustain more than the {old_equiv_slots} \
                 worst-case-reserved slots (got mean fill {:.2})",
                p.mean_batch_fill()
            );
            suite.record_metric("serve_paged_half_pool", "tokens_per_s", p.tokens_per_s());
            suite.record_metric("serve_paged_half_pool", "mean_concurrent", p.mean_batch_fill());
            suite.record_metric("serve_paged_half_pool", "peak_concurrent", p.peak_active as f64);
            suite.record_metric("serve_paged_half_pool", "slots_per_gb", p.peak_active as f64 / pool_gb);
            suite.record_metric("serve_paged_half_pool", "prefix_hit_rate", p.prefix_hit_rate());
            suite.record_metric("serve_paged_half_pool", "preemptions", p.preemptions as f64);
            suite.record_metric("serve_paged_contig_equiv", "tokens_per_s", c.tokens_per_s());
            suite.record_metric("serve_paged_contig_equiv", "mean_concurrent", c.mean_batch_fill());
            suite.record_metric(
                "serve_paged_contig_equiv",
                "slots_per_gb",
                old_equiv_slots as f64 / pool_gb,
            );
        }
    }

    // ---- compressed KV cache (--kv-ratio): parity smoke + the
    // equal-memory admission win (ci.sh gate 4i runs the `kv` filter) ----
    if suite.enabled("serve_kv_smoke") {
        let (pcfg, pweights) = tiny_model("llama-t", 3);
        let kvc = compress_kv_plain(&pcfg, &pweights, 0.5, &SvdPolicy::exact()).unwrap();
        suite.bench("serve_kv_smoke", 1, || {
            // Served bits at kv-ratio 0.5 must equal the sequential
            // generate_kv run under the same factors, per request.
            let reqs = (0..6)
                .map(|i| (bench_prompt(i, 5), 6usize, bench_sample(i)))
                .collect();
            let gen_cfg = GenConfig {
                max_batch: 4,
                pages: 6 * (5 + 6 - 1usize).div_ceil(4),
                page_size: 4,
                prefill_chunk: 3,
                prefix_share: true,
                workers: 0,
                ..GenConfig::default()
            };
            let (outs, _) =
                drive_preloaded_kv(&pcfg, &pweights, &NoOverride, Some(&kvc), &gen_cfg, reqs);
            for (i, out) in outs.iter().enumerate() {
                let expect = generate_kv(
                    &pcfg,
                    &pweights,
                    &NoOverride,
                    Some(&kvc),
                    &bench_prompt(i, 5),
                    6,
                    bench_sample(i),
                )
                .unwrap();
                assert_eq!(*out, expect, "kv parity failure: request {i}");
            }
        });
        suite.record_metric("serve_kv_smoke", "parity_ok", 1.0);
    }

    // Half-width latents (kv-ratio 0.5) halve the bytes every committed
    // token position occupies across all layers, so an equal byte budget
    // admits ~2x the sequences.  The slot ratio is deterministic from the
    // pool geometry (asserted >= 1.8x); the served runs measure what the
    // extra pages buy in sustained concurrency and tok/s at equal memory.
    if suite.enabled("serve_kv_equal_mem") {
        let (n_req, prompt_len, max_new) =
            if quick { (8usize, 16usize, 8usize) } else { (16, 16, 32) };
        let total = 2 * n_req;
        let page_size = 4;
        let rows_worst = prompt_len + max_new - 1;
        let dense_pages = ((n_req * rows_worst.div_ceil(page_size)) / 2).max(1);
        let kvc = compress_kv_plain(&cfg, &weights, 0.5, &SvdPolicy::exact()).unwrap();
        // Bytes per committed token position, all layers, from the pool
        // geometry itself.
        let dense_slot =
            KvPool::with_kvc(&cfg, 1, page_size, None).page_bytes() as f64 / page_size as f64;
        let kv_slot = KvPool::with_kvc(&cfg, 1, page_size, Some(&kvc)).page_bytes() as f64
            / page_size as f64;
        let admit_ratio = dense_slot / kv_slot;
        assert!(
            admit_ratio >= 1.8,
            "kv-ratio 0.5 must fit >= 1.8x token slots at equal memory (got {admit_ratio:.2})"
        );
        suite.record_metric("serve_kv_equal_mem", "admit_ratio_at_equal_mem", admit_ratio);
        suite.record_metric("serve_kv_equal_mem", "dense_slots_per_gb", 1e9 / dense_slot);
        suite.record_metric("serve_kv_equal_mem", "kv_slots_per_gb", 1e9 / kv_slot);
        // Same byte budget on both sides: the latent pool gets the pages
        // the narrower rows free up.
        let kv_pages = ((dense_pages as f64 * admit_ratio) as usize).max(dense_pages);
        let shared_prompt = bench_prompt(0, prompt_len);
        let make = |i: usize| (shared_prompt.clone(), max_new, bench_sample(i));
        let mut dense_m = None;
        suite.bench("serve_kv_equal_mem_dense", 1, || {
            let gen_cfg = GenConfig {
                max_batch: n_req,
                pages: dense_pages,
                page_size,
                prefill_chunk: 8,
                prefix_share: true,
                workers: 0,
                ..GenConfig::default()
            };
            let (m, _) =
                drive_concurrent(&cfg, &weights, &cm, &gen_cfg, n_req, total, &make).unwrap();
            assert_eq!(m.completed, total, "all requests must complete under pressure");
            dense_m = Some(m);
        });
        let mut kv_m = None;
        suite.bench("serve_kv_equal_mem_r05", 1, || {
            let gen_cfg = GenConfig {
                max_batch: n_req,
                pages: kv_pages,
                page_size,
                prefill_chunk: 8,
                prefix_share: true,
                workers: 0,
                ..GenConfig::default()
            };
            let (m, _) =
                drive_concurrent_kv(&cfg, &weights, &cm, Some(&kvc), &gen_cfg, n_req, total, &make)
                    .unwrap();
            assert_eq!(m.completed, total, "all requests must complete under pressure");
            kv_m = Some(m);
        });
        if let (Some(d), Some(k)) = (dense_m, kv_m) {
            suite.record_metric("serve_kv_equal_mem_dense", "tokens_per_s", d.tokens_per_s());
            suite.record_metric("serve_kv_equal_mem_dense", "mean_concurrent", d.mean_batch_fill());
            suite.record_metric("serve_kv_equal_mem_dense", "slots_per_gb", d.kv_slots_per_gb());
            suite.record_metric("serve_kv_equal_mem_r05", "tokens_per_s", k.tokens_per_s());
            suite.record_metric("serve_kv_equal_mem_r05", "mean_concurrent", k.mean_batch_fill());
            suite.record_metric("serve_kv_equal_mem_r05", "slots_per_gb", k.kv_slots_per_gb());
        }
    }

    // ---- observability overhead: obs off vs on, same tiny serve ----
    // Report-only (timing noise at this scale would make a hard threshold
    // flaky): the contract that matters — disabled obs is one relaxed
    // atomic load, enabled obs never perturbs the generated bits — is
    // asserted here (identical outputs) and pinned by the obs-on/off serve
    // fuzz test; the printed tok/s pair just makes the overhead visible in
    // CI logs.
    if suite.enabled("serve_obs_overhead") {
        let b = 4;
        let obs_new = if quick { 8 } else { 24 };
        let mut outs_off = None;
        suite.bench("serve_obs_overhead_off", 1, || {
            nsvd::obs::set_enabled(false);
            let (outs, generated) = run_batch(&cfg, &weights, &cm, b, 1, obs_new, b, 0);
            assert_eq!(generated, b * obs_new);
            outs_off = Some(outs);
        });
        let mut outs_on = None;
        let mut spans = 0usize;
        suite.bench("serve_obs_overhead_on", 1, || {
            nsvd::obs::reset();
            nsvd::obs::set_enabled(true);
            let (outs, generated) = run_batch(&cfg, &weights, &cm, b, 1, obs_new, b, 0);
            assert_eq!(generated, b * obs_new);
            spans = nsvd::obs::trace::snapshot_events().len();
            nsvd::obs::set_enabled(false);
            nsvd::obs::reset();
            outs_on = Some(outs);
        });
        assert_eq!(outs_off, outs_on, "obs on/off must be bit-identical");
        if let (Some(off), Some(on)) = (
            suite.mean_of("serve_obs_overhead_off").filter(|&m| m > 0.0),
            suite.mean_of("serve_obs_overhead_on").filter(|&m| m > 0.0),
        ) {
            let tok = (b * obs_new) as f64;
            println!(
                "  obs overhead: off {:.0} tok/s, on {:.0} tok/s ({:+.1}%), {spans} events recorded",
                tok / off,
                tok / on,
                (off / on - 1.0) * 100.0
            );
            suite.record_metric("serve_obs_overhead_on", "events_recorded", spans as f64);
        }
    }

    // ---- overload sweep: goodput vs offered load at 1x/2x/4x capacity ----
    // Calibrate the server's sustainable request rate closed-loop
    // (unbounded queue, no deadlines), then offer Poisson open-loop load
    // at multiples of it with a bounded queue and per-request deadlines.
    // Raw throughput saturates at capacity no matter the offered load;
    // the point of the QoS layer is that *goodput* (tokens of requests
    // that completed in deadline) degrades gracefully while the shed /
    // deadline counters absorb the excess instead of latency exploding.
    if suite.enabled("serve_overload") {
        let (n_req, prompt_len, max_new) =
            if quick { (8usize, 4usize, 6usize) } else { (24, 8, 16) };
        let page_size = 4;
        let base = GenConfig {
            max_batch: (n_req / 2).max(1),
            pages: n_req * (prompt_len + max_new - 1).div_ceil(page_size),
            page_size,
            prefill_chunk: 8,
            prefix_share: true,
            workers: 0,
            ..GenConfig::default()
        };
        let make = |i: usize| (bench_prompt(i, prompt_len), max_new, bench_sample(i));
        let (cal, _) = drive_concurrent(
            &cfg,
            &weights,
            &cm,
            &base,
            (n_req / 2).max(1),
            n_req,
            &make,
        )
        .unwrap();
        let cap_rps = (cal.tokens_per_s() / max_new as f64).max(0.5);
        // Deadline: generous at capacity (4x the calibrated mean latency),
        // so 1x load mostly completes while 4x load must shed or expire.
        let deadline_s = (cal.latency().mean * 4.0).max(0.05);
        suite.record_metric("serve_overload", "capacity_rps", cap_rps);
        // The sweep itself runs against a bounded queue so overload turns
        // into explicit rejection/shedding instead of unbounded buildup.
        let sweep_cfg = GenConfig { queue_cap: (n_req / 2).max(2), ..base };
        for mult in [1usize, 2, 4] {
            let name = format!("serve_overload_{mult}x");
            let tenants = [OpenLoopTenant {
                tenant: 0,
                rate: cap_rps * mult as f64,
                requests: n_req,
                priority: 0,
                deadline: Some(deadline_s),
                prompt_len: ((prompt_len / 2).max(1), prompt_len + 1),
                max_new: ((max_new / 2).max(1), max_new + 1),
            }];
            let mut run = None;
            suite.bench(&name, 1, || {
                let (m, stats) =
                    drive_open_loop(&cfg, &weights, &cm, &sweep_cfg, 17, &tenants).unwrap();
                run = Some((m, stats));
            });
            if let Some((m, stats)) = run {
                suite.record_metric(&name, "offered_rps", cap_rps * mult as f64);
                suite.record_metric(&name, "goodput_tok_s", goodput_tokens_per_s(&stats, m.wall_s));
                suite.record_metric(&name, "raw_tok_s", m.tokens_per_s());
                suite.record_metric(&name, "shed", m.shed as f64);
                suite.record_metric(&name, "deadline_exceeded", m.deadline_exceeded as f64);
                suite.record_metric(&name, "rejected", m.rejected as f64);
                suite.record_metric(&name, "peak_queue", m.peak_queue as f64);
            }
        }
    }

    // Stable top-level summary, matching the BENCH_gemm.json convention.
    // Skipped under a filter that excludes the decode benches and in
    // --quick mode, so the ci.sh parity smoke never clobbers the tracked
    // throughput numbers.
    if suite.enabled("serve_decode_b1_dense") && !suite.quick() {
        suite.write_summary(std::path::Path::new("BENCH_serve.json"), "serve");
    }
    suite.finish();
}
