//! Perf: compress_layer throughput per method on a llama-t-shaped weight,
//! and whole-model decomposition time.

use nsvd::bench::Suite;
use nsvd::compress::methods::{compress_layer, CompressionSpec, Method};
use nsvd::compress::ranks;
use nsvd::compress::whiten::CalibStats;
use nsvd::linalg::matrix::Matrix;
use nsvd::model::weights::Tensor;
use nsvd::util::rng::Rng;

fn stats(n: usize, rng: &mut Rng) -> CalibStats {
    let x = Matrix::randn(4 * n, n, 1.0, rng);
    let mut s = CalibStats::new(n);
    s.gram = x.matmul_tn(&x);
    s.abs_sum = (0..n).map(|j| (0..4 * n).map(|i| x[(i, j)].abs()).sum()).collect();
    s.rows = 4 * n;
    s
}

fn main() {
    let mut suite = Suite::from_args("perf_decompose");
    let mut rng = Rng::new(2);
    let (n_in, n_out) = (128usize, 256usize); // llama-t MLP shape
    let w = Tensor {
        dims: vec![n_in, n_out],
        data: Matrix::randn(n_in, n_out, 0.05, &mut rng).to_f32(),
    };
    let st = stats(n_in, &mut rng);
    for method in [
        Method::Svd, Method::Asvd0, Method::AsvdI, Method::AsvdII,
        Method::AsvdIII, Method::NsvdI, Method::NsvdII, Method::NidI,
    ] {
        let spec = CompressionSpec { method, ratio: 0.30, alpha: 0.95 };
        let plan = ranks::plan(n_out, n_in, 0.30, spec.effective_alpha());
        suite.bench(&format!("layer_{}", method.label()), 3, || {
            std::hint::black_box(compress_layer(&w, &st, &spec, &plan).unwrap());
        });
    }
    suite.finish();
}
