//! Perf: compress_layer throughput per method on a llama-t-shaped weight,
//! whitener construction wall-clock, whole-model decomposition serial vs
//! the sharded engine, and the exact-vs-randomized SVD policy at the model
//! level — summarized into the top-level `BENCH_decompose.json` (same
//! convention as `BENCH_gemm.json`) so the decomposition path's perf
//! trajectory is visible per PR.
//!
//! The whole-model section also verifies (and prints) that the sharded
//! exact path reproduces the serial loop's factors bit-for-bit.

use nsvd::bench::Suite;
use nsvd::calib::collector::TapStats;
use nsvd::compress::engine::{
    compress_model_serial, CompressionEngine, EngineConfig, WhitenerCache,
};
use nsvd::compress::lowrank::CompressedModel;
use nsvd::compress::methods::{compress_layer, CompressionSpec, Method};
use nsvd::compress::ranks;
use nsvd::compress::whiten::{CalibStats, Whitener};
use nsvd::linalg::matrix::Matrix;
use nsvd::linalg::rsvd::SvdPolicy;
use nsvd::model::config::ModelConfig;
use nsvd::model::weights::{Tensor, Weights};
use nsvd::util::rng::Rng;
use nsvd::util::threads::default_workers;

fn stats(n: usize, rng: &mut Rng) -> CalibStats {
    let x = Matrix::randn(4 * n, n, 1.0, rng);
    let mut s = CalibStats::new(n);
    s.gram = x.gram(); // XᵀX through the packed SYRK kernel
    s.abs_sum = (0..n).map(|j| (0..4 * n).map(|i| x[(i, j)].abs()).sum()).collect();
    s.rows = 4 * n;
    s
}

/// Synthetic llama-t: random weights for every compressible linear, random
/// full-rank calibration stats for every tap.
fn synthetic_model(rng: &mut Rng) -> (ModelConfig, Weights, TapStats) {
    let cfg = ModelConfig::builtin("llama-t").unwrap();
    let mut weights = Weights::default();
    for (name, n_in, n_out) in &cfg.linear_shapes {
        weights.tensors.insert(
            name.clone(),
            Tensor {
                dims: vec![*n_in, *n_out],
                data: Matrix::randn(*n_in, *n_out, 0.05, rng).to_f32(),
            },
        );
    }
    let mut taps = TapStats::default();
    for tap in cfg.tap_names() {
        let dim = if tap.ends_with("mlp_down_in") { cfg.d_ff } else { cfg.d_model };
        taps.taps.insert(tap, stats(dim, rng));
    }
    (cfg, weights, taps)
}

fn engine_compress(
    cfg: &ModelConfig,
    weights: &Weights,
    taps: &TapStats,
    spec: &CompressionSpec,
    workers: usize,
    svd: SvdPolicy,
) -> CompressedModel {
    let engine = CompressionEngine::new(EngineConfig { workers, svd });
    let mut cache = WhitenerCache::default();
    engine.compress_model(cfg, weights, taps, spec, &mut cache).unwrap()
}

fn max_factor_diff(a: &CompressedModel, b: &CompressedModel) -> f32 {
    let mut worst = 0.0f32;
    for (name, la) in &a.layers {
        let lb = b.get(name).expect("layer sets match");
        for (x, y) in la.p1.iter().zip(&lb.p1).chain(la.q1.iter().zip(&lb.q1)) {
            worst = worst.max((x - y).abs());
        }
    }
    worst
}

fn main() {
    let mut suite = Suite::from_args("perf_decompose");
    let mut rng = Rng::new(2);

    // ---- Gram accumulation + whitener construction wall-clock ----
    // The calibration fan-in (SYRK-buffered accumulate) and the stage-1
    // whiteners (Cholesky / eigendecomposition of the Gram) are the
    // decomposition pipeline's setup cost; tracked per dimension.
    for &n in &[128usize, 256] {
        let rows = 4 * n;
        let x: Vec<f32> = (0..rows * n).map(|_| rng.normal() as f32).collect();
        suite.bench(&format!("decompose_gram_accumulate_{n}"), 3, || {
            let mut ts = TapStats::default();
            ts.accumulate("t", &x, rows, n);
            ts.finalize();
            std::hint::black_box(ts);
        });
        let st = stats(n, &mut rng);
        suite.bench(&format!("decompose_whiten_chol_{n}"), 3, || {
            std::hint::black_box(Whitener::cholesky(&st));
        });
        suite.bench(&format!("decompose_whiten_eig_{n}"), 3, || {
            std::hint::black_box(Whitener::eigen(&st));
        });
    }

    // ---- Per-layer factorization throughput by method ----
    let (n_in, n_out) = (128usize, 256usize); // llama-t MLP shape
    let w = Tensor {
        dims: vec![n_in, n_out],
        data: Matrix::randn(n_in, n_out, 0.05, &mut rng).to_f32(),
    };
    let st = stats(n_in, &mut rng);
    for method in [
        Method::Svd, Method::Asvd0, Method::AsvdI, Method::AsvdII,
        Method::AsvdIII, Method::NsvdI, Method::NsvdII, Method::NidI,
    ] {
        let spec = CompressionSpec { method, ratio: 0.30, alpha: 0.95 };
        let plan = ranks::plan(n_out, n_in, 0.30, spec.effective_alpha());
        suite.bench(&format!("decompose_layer_{}", method.label()), 3, || {
            std::hint::black_box(compress_layer(&w, &st, &spec, &plan).unwrap());
        });
    }

    // ---- Whole-model: serial loop vs the sharded engine ----
    let (cfg, weights, taps) = synthetic_model(&mut rng);
    let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha: 0.95 };
    let cores = default_workers();
    suite.bench("decompose_model_serial", 3, || {
        std::hint::black_box(compress_model_serial(&cfg, &weights, &taps, &spec).unwrap());
    });
    suite.bench("decompose_model_engine_w1", 3, || {
        std::hint::black_box(engine_compress(&cfg, &weights, &taps, &spec, 1, SvdPolicy::exact()));
    });
    // On a single-core box w{cores} would duplicate the w1 name/measurement.
    if cores > 1 {
        suite.bench(&format!("decompose_model_engine_w{cores}"), 3, || {
            std::hint::black_box(engine_compress(
                &cfg, &weights, &taps, &spec, cores, SvdPolicy::exact(),
            ));
        });
    }
    suite.bench(&format!("decompose_model_engine_w{cores}_rsvd"), 3, || {
        std::hint::black_box(engine_compress(
            &cfg, &weights, &taps, &spec, cores, SvdPolicy::auto(),
        ));
    });
    // Equality pin: sharded exact == serial, bit for bit, at every width run.
    let mut widths = vec![1usize];
    if cores > 1 {
        widths.push(cores);
    }
    let serial = compress_model_serial(&cfg, &weights, &taps, &spec).unwrap();
    for workers in widths {
        let bench_name = format!("decompose_model_engine_w{workers}");
        if !suite.enabled(&bench_name) {
            continue;
        }
        let sharded = engine_compress(&cfg, &weights, &taps, &spec, workers, SvdPolicy::exact());
        let diff = max_factor_diff(&serial, &sharded);
        println!("      {bench_name} vs serial: max |Δfactor| = {diff:e} (expect 0)");
        assert_eq!(diff, 0.0, "sharded exact engine must reproduce the serial loop");
        suite.record_metric(&bench_name, "max_diff_vs_serial", diff as f64);
    }
    // Stable top-level summary (whiten + factorize wall-clock, serial vs
    // sharded, exact vs rsvd), matching the BENCH_gemm.json convention.
    // Skipped under a filter that excludes the decompose benches and in
    // --quick mode, so partial runs never clobber the tracked numbers.
    if suite.enabled("decompose") && !suite.quick() {
        suite.write_summary(std::path::Path::new("BENCH_decompose.json"), "decompose");
    }
    suite.finish();
}
