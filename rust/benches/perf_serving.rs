//! Perf: serving loop — throughput and latency vs batcher wait policy.

use nsvd::bench::{artifacts_dir, Suite};
use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::coordinator::server::{self, BatchPolicy};
use nsvd::data::corpus::Registry;

fn main() {
    let mut suite = Suite::from_args("perf_serving");
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = PipelineConfig::default_for_model("llama-t");
    cfg.artifacts_dir = dir.clone();
    let mut pipeline = Pipeline::new(cfg).unwrap();
    let cm = pipeline
        .compress(&CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha: 0.95 })
        .unwrap();
    let rt = pipeline.runtime().unwrap();
    let eval = rt.serve_evaluator("llama-t", &cm).unwrap();
    let corpus = Registry::new(&dir).load("c4", "test").unwrap();
    let n = if suite.quick() { 40 } else { 160 };
    for wait_ms in [0.5, 2.0, 8.0] {
        let name = format!("closed_loop_wait{wait_ms}ms");
        if !suite.enabled(&name) {
            continue;
        }
        let mut thru = 0.0;
        let mut p99 = 0.0;
        suite.bench_throughput(&name, 1, n as f64, || {
            let (req_tx, req_rx) = std::sync::mpsc::channel();
            let (resp_tx, resp_rx) = std::sync::mpsc::channel();
            let producer =
                server::spawn_load(corpus.tokens.clone(), eval.seq(), n, 0.0, req_tx);
            let metrics = server::serve(
                &eval, req_rx, resp_tx,
                BatchPolicy { max_wait_s: wait_ms / 1e3 },
            )
            .unwrap();
            producer.join().ok();
            let _: Vec<_> = resp_rx.iter().collect();
            thru = metrics.throughput_rps();
            p99 = metrics.latency().p99;
        });
        suite.record_metric(&name, "throughput_rps", thru);
        suite.record_metric(&name, "latency_p99_s", p99);
    }
    suite.finish();
}
