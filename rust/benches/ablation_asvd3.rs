//! §3 ablation bench: ASVD-II vs ASVD-III (Theorem 4 "failure trial").

use nsvd::bench::{artifacts_dir, table_windows, Suite};
use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::data::corpus::DOMAIN_NAMES;

fn main() {
    let mut suite = Suite::from_args("ablation_asvd3");
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = PipelineConfig::default_for_model("llama-t");
    cfg.artifacts_dir = dir;
    cfg.eval_windows = table_windows(suite.quick());
    let mut pipeline = Pipeline::new(cfg).unwrap();
    pipeline.calibrate().unwrap();
    for method in [Method::AsvdII, Method::AsvdIII] {
        let name = method.label().to_string();
        let spec = CompressionSpec::new(method, 0.30);
        let mut report = None;
        suite.bench(&name, 1, || {
            report = Some(pipeline.run(&spec).unwrap());
        });
        if let Some(r) = report {
            for d in DOMAIN_NAMES {
                suite.record_metric(&name, &format!("ppl_{d}"), r.ppl(d).unwrap_or(f64::NAN));
            }
        }
    }
    suite.finish();
}
