//! Table 3 regenerator-bench: NSVD-I k1 sweep at 30% on llama-t.

use nsvd::bench::{artifacts_dir, table_windows, Suite};
use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::data::corpus::DOMAIN_NAMES;

fn main() {
    let mut suite = Suite::from_args("table3_k1_sweep");
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = PipelineConfig::default_for_model("llama-t");
    cfg.artifacts_dir = dir;
    cfg.eval_windows = table_windows(suite.quick());
    let mut pipeline = Pipeline::new(cfg).unwrap();
    pipeline.calibrate().unwrap();
    let alphas: &[f64] = if suite.quick() { &[0.95, 0.80] } else { &[0.99, 0.95, 0.90, 0.85, 0.80] };
    // Reference baseline.
    let asvd = pipeline.run(&CompressionSpec::new(Method::AsvdI, 0.30)).unwrap();
    for d in DOMAIN_NAMES {
        suite.record_metric("asvd_i_baseline", &format!("ppl_{d}"), asvd.ppl(d).unwrap_or(f64::NAN));
    }
    for &alpha in alphas {
        let name = format!("nsvd_i_a{:.0}", alpha * 100.0);
        let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.30, alpha };
        let mut report = None;
        suite.bench(&name, 1, || {
            report = Some(pipeline.run(&spec).unwrap());
        });
        if let Some(r) = report {
            for d in DOMAIN_NAMES {
                suite.record_metric(&name, &format!("ppl_{d}"), r.ppl(d).unwrap_or(f64::NAN));
            }
        }
    }
    suite.finish();
}
