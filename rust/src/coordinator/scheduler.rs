//! Experiment scheduler: sweeps (method × ratio × α) jobs over a pipeline.
//!
//! PJRT executables are not `Send` (the client is `Rc`-based), so jobs that
//! execute on-device run sequentially on the owning thread; the scheduler's
//! contribution is job bookkeeping — deterministic ordering, failure
//! isolation, progress reporting.  The CPU-bound decomposition inside each
//! job is parallel: `Pipeline::compress` routes through the sharded
//! [`crate::compress::engine::CompressionEngine`], which fans layer jobs
//! out over `PipelineConfig::workers` threads (whiteners built once per tap
//! and shared read-only via `Arc`) and applies the configured
//! [`crate::linalg::rsvd::SvdPolicy`] — so a sweep's wall-clock is
//! evaluation-dominated on multi-core machines.

use super::pipeline::{CompressionReport, Pipeline};
use crate::compress::methods::{CompressionSpec, Method};
use crate::util::timer::Timer;
use anyhow::Result;

/// One experiment cell.
#[derive(Clone, Debug)]
pub struct Job {
    pub name: String,
    pub spec: CompressionSpec,
}

impl Job {
    pub fn new(method: Method, ratio: f64, alpha: f64) -> Job {
        Job {
            name: format!("{}@{:.0}%/α={alpha}", method.label(), ratio * 100.0),
            spec: CompressionSpec { method, ratio, alpha },
        }
    }
}

/// Outcome of one job (reports keep going even if a cell fails).
#[derive(Debug)]
pub struct JobOutcome {
    pub job: Job,
    pub elapsed_s: f64,
    pub result: Result<CompressionReport>,
}

/// Run jobs sequentially over a pipeline, with progress logging.
/// Calibration is shared (cached inside the pipeline), so the per-job cost
/// is decomposition + evaluation only.
pub fn run_jobs(pipeline: &mut Pipeline, jobs: &[Job]) -> Vec<JobOutcome> {
    let mut outcomes = Vec::with_capacity(jobs.len());
    for (i, job) in jobs.iter().enumerate() {
        let t = Timer::start();
        crate::info!(
            "scheduler",
            "[{}/{}] {} (model {})",
            i + 1,
            jobs.len(),
            job.name,
            pipeline.config.model
        );
        let result = {
            let mut sp = crate::obs::span("pipeline.job");
            if sp.is_recording() {
                sp.arg_str("job", &job.name);
            }
            pipeline.run(&job.spec)
        };
        let elapsed_s = t.elapsed_s();
        if let Err(e) = &result {
            crate::warnln!("scheduler", "{} FAILED: {e:#}", job.name);
        }
        outcomes.push(JobOutcome { job: job.clone(), elapsed_s, result });
    }
    outcomes
}

/// The standard sweeps of the paper's tables.
pub mod sweeps {
    use super::*;

    /// Table 1: methods × ratios (α = 0.95 for NSVD rows).
    pub fn table1(ratios: &[f64]) -> Vec<Job> {
        let mut jobs = Vec::new();
        for &r in ratios {
            for m in Method::table1() {
                jobs.push(Job::new(m, r, 0.95));
            }
        }
        jobs
    }

    /// Table 3: NSVD-I with α ∈ {0.99, 0.95, 0.90, 0.85, 0.80} at 30%.
    pub fn table3() -> Vec<Job> {
        [0.99, 0.95, 0.90, 0.85, 0.80]
            .iter()
            .map(|&a| Job::new(Method::NsvdI, 0.30, a))
            .collect()
    }

    /// Table 4: NID-I with α ∈ {0.99, 0.95, 0.90} at 30%.
    pub fn table4() -> Vec<Job> {
        [0.99, 0.95, 0.90]
            .iter()
            .map(|&a| Job::new(Method::NidI, 0.30, a))
            .collect()
    }

    /// Tables 5/6 per-model jobs: baselines + NSVD-I at 30%.
    pub fn model_comparison() -> Vec<Job> {
        vec![
            Job::new(Method::Asvd0, 0.30, 1.0),
            Job::new(Method::AsvdI, 0.30, 1.0),
            Job::new(Method::NsvdI, 0.30, 0.95),
        ]
    }

    /// §3 ablation: ASVD-II vs ASVD-III.
    pub fn ablation() -> Vec<Job> {
        vec![
            Job::new(Method::AsvdII, 0.30, 1.0),
            Job::new(Method::AsvdIII, 0.30, 1.0),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_sweep_has_methods_times_ratios() {
        let jobs = sweeps::table1(&[0.1, 0.3]);
        assert_eq!(jobs.len(), 12);
        assert!(jobs[0].name.contains("SVD@10%"));
    }

    #[test]
    fn table3_alphas() {
        let jobs = sweeps::table3();
        assert_eq!(jobs.len(), 5);
        assert!(jobs.iter().all(|j| j.spec.method == Method::NsvdI));
        assert!(jobs.iter().all(|j| (j.spec.ratio - 0.3).abs() < 1e-12));
    }

    #[test]
    fn ablation_pairs_asvd_2_and_3() {
        let jobs = sweeps::ablation();
        assert_eq!(jobs[0].spec.method, Method::AsvdII);
        assert_eq!(jobs[1].spec.method, Method::AsvdIII);
    }
}
