//! Serving loop: request queue → dynamic batcher → per-row scoring.
//!
//! The deployment story the paper motivates: the COMPRESSED model serves
//! scoring requests.  Requests arrive on an mpsc channel from any number of
//! producer threads.  Threading contract: the PJRT client and its compiled
//! executables are not `Send`, so *execution* stays on the one thread that
//! owns the [`ServeEvaluator`] — but nothing else in the system is
//! single-threaded: producers fan in from arbitrary threads, and the
//! decomposition that builds the served model runs on the sharded
//! `compress::engine` worker pool (whiteners shared via `Arc`).  The loop
//! groups requests into batches:
//!
//! * block for the first request;
//! * drain more until the batch is full or `max_wait` elapses;
//! * pad the remainder with copies of row 0 (per-row outputs → padding rows
//!   are discarded, unlike the sum-reduced eval executables);
//! * execute, deliver per-request responses, record metrics.

use super::metrics::ServerMetrics;
use crate::data::batch::TokenBatch;
use crate::runtime::exec::ServeEvaluator;
use crate::util::timer::Timer;
use anyhow::Result;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// A scoring request: perplexity of one token window.
#[derive(Clone, Debug)]
pub struct ScoreRequest {
    pub id: u64,
    /// Exactly `seq` tokens (the producer is responsible for windowing).
    pub tokens: Vec<u8>,
    pub enqueued: Instant,
}

/// The response delivered to the requester.
#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub id: u64,
    pub nll: f64,
    pub tokens: f64,
    pub ppl: f64,
    pub latency_s: f64,
}

/// Dynamic batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum time to wait for more requests after the first one arrives,
    /// in **seconds** (the `_s` suffix is the crate-wide unit convention;
    /// the CLI's `--max-wait-ms` flag is converted before it lands here).
    /// The default, `0.002` (2 ms), trades ≤2 ms of added latency for much
    /// fuller batches under load.
    pub max_wait_s: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_wait_s: 0.002 }
    }
}

/// Run the serving loop until the request channel closes.
/// Returns the accumulated metrics.
pub fn serve(
    eval: &ServeEvaluator,
    requests: Receiver<ScoreRequest>,
    responses: Sender<ScoreResponse>,
    policy: BatchPolicy,
) -> Result<ServerMetrics> {
    let batch = eval.batch();
    let seq = eval.seq();
    let mut metrics = ServerMetrics::default();
    let wall = Timer::start();
    loop {
        // Block for the first request; channel closed → drain out.
        let first = match requests.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + Duration::from_secs_f64(policy.max_wait_s);
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match requests.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Build the batch: pad with copies of row 0 (discarded afterwards).
        let mut rows: Vec<&[u8]> = pending.iter().map(|r| r.tokens.as_slice()).collect();
        while rows.len() < batch {
            rows.push(pending[0].tokens.as_slice());
        }
        for r in &rows {
            assert_eq!(r.len(), seq, "requests must be exactly seq tokens");
        }
        let tb = TokenBatch::from_rows(&rows, batch, seq);
        let exec_t = Timer::start();
        let scored = eval.score(&tb)?;
        let _exec_s = exec_t.elapsed_s();
        let now = Instant::now();
        for (req, &(nll, cnt)) in pending.iter().zip(scored.iter()) {
            let latency = now.duration_since(req.enqueued).as_secs_f64();
            metrics.latency_s.push(latency);
            metrics
                .queue_wait_s
                .push(latency - _exec_s.min(latency));
            let _ = responses.send(ScoreResponse {
                id: req.id,
                nll,
                tokens: cnt,
                ppl: (nll / cnt.max(1.0)).exp(),
                latency_s: latency,
            });
        }
        metrics.completed += pending.len();
        metrics.batches += 1;
        metrics.batch_fill.push(pending.len() as f64);
    }
    metrics.wall_s = wall.elapsed_s();
    Ok(metrics)
}

/// Offline load generator: emits `n` requests windowed from a corpus at
/// roughly `rate_rps`, from a separate thread.  Returns the join handle.
pub fn spawn_load(
    tokens: Vec<u8>,
    seq: usize,
    n: usize,
    rate_rps: f64,
    tx: Sender<ScoreRequest>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let windows: Vec<Vec<u8>> = tokens
            .chunks_exact(seq)
            .map(|w| w.to_vec())
            .collect();
        if windows.is_empty() {
            return;
        }
        let gap = if rate_rps > 0.0 {
            Duration::from_secs_f64(1.0 / rate_rps)
        } else {
            Duration::ZERO
        };
        for i in 0..n {
            let w = windows[i % windows.len()].clone();
            let req = ScoreRequest { id: i as u64, tokens: w, enqueued: Instant::now() };
            if tx.send(req).is_err() {
                return;
            }
            if !gap.is_zero() {
                std::thread::sleep(gap);
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_policy_default_is_small() {
        assert!(BatchPolicy::default().max_wait_s < 0.05);
    }

    #[test]
    fn load_generator_emits_n_requests() {
        let (tx, rx) = std::sync::mpsc::channel();
        let tokens: Vec<u8> = (0..1000).map(|i| (i % 256) as u8).collect();
        let h = spawn_load(tokens, 100, 25, 0.0, tx);
        h.join().unwrap();
        let got: Vec<_> = rx.iter().collect();
        assert_eq!(got.len(), 25);
        assert!(got.iter().all(|r| r.tokens.len() == 100));
        // Ids are sequential.
        assert_eq!(got[24].id, 24);
    }
}
