//! L3 coordinator — the orchestration layer.
//!
//! * [`pipeline`]  — the post-training compression pipeline: calibrate →
//!   whiten → decompose → rebuild → evaluate, with cached calibration;
//!   decomposition fans out through the sharded
//!   [`crate::compress::engine::CompressionEngine`].
//! * [`scheduler`] — multi-job experiment scheduler
//!   (used by the table regenerators to sweep ratios/methods).
//! * [`server`]    — the scoring serving loop: request queue, dynamic
//!   batcher over the per-row serving executable, latency metrics.  (The
//!   continuous-batching *generation* server lives in [`crate::serve`].)
//! * [`reports`]   — renders the paper's tables (markdown + JSON) and the
//!   serving latency-percentile blocks.
//! * [`metrics`]   — latency/throughput instrumentation for both servers
//!   (percentiles from sorted sample buffers).

pub mod metrics;
pub mod pipeline;
pub mod reports;
pub mod scheduler;
pub mod server;

pub use pipeline::{Pipeline, PipelineConfig};
pub use reports::Table;
