//! The compression pipeline: calibrate → decompose → evaluate.
//!
//! Mirrors the paper's protocol:
//! 1. sample 256 random sequences from the WikiText-2 (wiki) train split;
//! 2. accumulate per-tap activation Grams through the dense model;
//! 3. decompose every compressible weight with the requested method at the
//!    requested ratio/α;
//! 4. evaluate perplexity on the eight test sets.
//!
//! Calibration is computed once per `Pipeline` and shared across all
//! method/ratio sweeps (the expensive part is the forward, not the SVDs).

use crate::calib::collector::{collect_native, TapStats};
use crate::calib::similarity::{similarity_stats, SimilarityReport};
use crate::compress::allocate::{AllocConfig, AllocStrategy, LayerProfile, ALPHA_GRID};
use crate::compress::engine::{CompressionEngine, EngineConfig, WhitenerCache};
use crate::compress::kv::{compress_kv_with, kv_override_model, KvBuildSpec};
use crate::compress::lowrank::{CompressedModel, FactorDtype};
use crate::compress::methods::CompressionSpec;
use crate::compress::whiten::Whitener;
use crate::linalg::quant::DEFAULT_GROUP;
use crate::compress::ranks;
use crate::model::kvc::KvCompression;
use crate::data::batch::Batcher;
use crate::data::corpus::{Corpus, Registry, DOMAIN_NAMES};
use crate::eval::perplexity::{
    evaluate, evaluate_with_workers, pooled_ppl, EvalBackend, PerplexityResult,
};
use crate::linalg::rsvd::SvdPolicy;
use crate::model::config::ModelConfig;
use crate::model::weights::Weights;
use crate::runtime::exec::Runtime;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    /// Calibration sample count (paper: 256 sequences).
    pub calib_samples: usize,
    /// Eval windows per dataset (rounded down to full batches on PJRT).
    pub eval_windows: usize,
    /// Use the PJRT executables (true) or the native forward (false).
    pub use_pjrt: bool,
    pub seed: u64,
    /// Decomposition worker threads (`0` = all cores).  Output is identical
    /// for every worker count; this only changes wall-clock.  The engine
    /// splits this ONE budget between its layer fan-out and the parallel
    /// GEMMs inside each job ([`crate::util::threads::ThreadBudget`]).
    pub workers: usize,
    /// Evaluation worker threads for the native backend (`0` = all cores):
    /// independent `TokenBatch`es are scored concurrently, splitting the
    /// budget with the f32 GEMMs inside each forward pass.  Bit-identical
    /// for every worker count; ignored on the PJRT path (the client is
    /// pinned to one thread).
    pub eval_workers: usize,
    /// Truncated-SVD policy for the decomposition engine.  The default
    /// ([`SvdPolicy::exact`]) reproduces the serial pipeline bit-for-bit;
    /// [`SvdPolicy::auto`] enables the certified randomized fast path.
    pub svd: SvdPolicy,
    /// Rank allocation strategy (`--allocate`).  `Uniform` (default) is the
    /// paper protocol and bit-identical to the pre-allocator planner;
    /// `Spectrum` water-fills one global parameter budget across layers by
    /// whitened spectral mass ([`crate::compress::allocate`]), spending no
    /// more parameters than the uniform plan.  Identical results at every
    /// worker count either way.
    pub allocate: AllocStrategy,
    /// Replace the single global α with a per-layer (k₁, k₂) split chosen
    /// by the auto-tune mini-sweep (`--alpha auto`; nested methods only).
    pub alpha_auto: bool,
    /// Factor storage dtype (`--factor-dtype`).  `Int8` re-encodes the
    /// compressed factors as per-group symmetric int8 riding the integer
    /// GEMM kernel — native backend only (the PJRT executables marshal f32
    /// factors), enforced at [`Pipeline::new`].
    pub factor_dtype: FactorDtype,
    /// KV-cache latent ratio (`--kv-ratio`): fraction of the K/V row width
    /// stored per token in the serving pool's pages (`1.0` = the
    /// uncompressed cache, the default).  Factors come from
    /// [`Pipeline::build_kv_compression`] — the calibrated whitened
    /// truncation with ASVD query-side scaling on `wk` — and the quality
    /// axis reads off the `kv-cache` rows [`Pipeline::run_budget_sweep`]
    /// emits when this is `< 1.0`.
    pub kv_ratio: f64,
}

impl PipelineConfig {
    pub fn default_for_model(model: &str) -> PipelineConfig {
        PipelineConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            model: model.to_string(),
            calib_samples: 256,
            eval_windows: 64,
            use_pjrt: true,
            seed: 0xC0FFEE,
            workers: 0,
            eval_workers: 1,
            svd: SvdPolicy::exact(),
            allocate: AllocStrategy::Uniform,
            alpha_auto: false,
            factor_dtype: FactorDtype::F32,
            kv_ratio: 1.0,
        }
    }
}

/// Report from one full pipeline run.
#[derive(Clone, Debug)]
pub struct CompressionReport {
    pub model: String,
    pub method: String,
    pub ratio: f64,
    pub alpha: f64,
    pub dense_params: usize,
    pub compressed_params: usize,
    /// Factor storage dtype label (`f32` | `int8`).
    pub dtype: &'static str,
    /// Factor storage bytes (dtype-aware; int8 includes scales).
    pub factor_bytes: usize,
    pub results: Vec<PerplexityResult>,
}

impl CompressionReport {
    pub fn ppl(&self, dataset: &str) -> Option<f64> {
        self.results.iter().find(|r| r.dataset == dataset).map(|r| r.ppl())
    }
}

/// One point of a budget-vs-perplexity sweep ([`Pipeline::run_budget_sweep`]).
#[derive(Clone, Debug)]
pub struct BudgetSweepPoint {
    /// Requested compression ratio (sets the global parameter budget).
    pub ratio: f64,
    /// Allocation strategy label (`uniform` | `spectrum`), or `kv-cache`
    /// for the KV-latent quality rows (`--kv-ratio < 1`): same ratio axis,
    /// but the row scores the wk/wv-only latent view ([`kv_override_model`])
    /// — pooled ppl vs kv-ratio on the same curve as the weight sweep.
    pub strategy: &'static str,
    /// Parameters actually stored by the compressed model.
    pub compressed_params: usize,
    /// Factor storage dtype label (`f32` | `int8`) — the sweep's dtype
    /// axis: with `--factor-dtype int8` each ratio emits both rows, so
    /// the int8 quality delta reads off the same curve.
    pub dtype: &'static str,
    /// Factor storage bytes (scales included for int8).
    pub factor_bytes: usize,
    /// Token-weighted perplexity pooled over every eval dataset
    /// ([`pooled_ppl`]).
    pub ppl: f64,
}

/// The pipeline: owns the runtime, weights, and cached calibration.
pub struct Pipeline {
    pub config: PipelineConfig,
    pub model_cfg: ModelConfig,
    pub weights: Weights,
    rt: Option<Runtime>,
    registry: Registry,
    calib: Option<TapStats>,
    /// (whitener kind, tap) → whitener — reused across layers AND across
    /// sweep jobs (whiteners are ratio/α-independent; the eigendecomposition
    /// of a d_ff-sized Gram costs seconds, so this dominates sweep setup).
    /// `Arc`-backed so the sharded engine's worker threads can share it.
    whitener_cache: WhitenerCache,
    /// whitener kind → per-layer whitened spectra.  Spectra depend only on
    /// `(weights, whitener)`, never on the ratio or α, so ratio sweeps and
    /// repeated spectrum-mode compressions profile each layer exactly once.
    spectra_cache: HashMap<String, Vec<LayerProfile>>,
}

impl Pipeline {
    pub fn new(config: PipelineConfig) -> Result<Pipeline> {
        anyhow::ensure!(
            !(config.use_pjrt && config.factor_dtype == FactorDtype::Int8),
            "--factor-dtype int8 requires the native backend (add --native): \
             the PJRT executables marshal f32 factors"
        );
        let rt = if config.use_pjrt {
            Some(Runtime::open(&config.artifacts_dir).context("opening PJRT runtime")?)
        } else {
            None
        };
        let (model_cfg, weights) = match &rt {
            Some(rt) => {
                let cfg = rt.manifest.model(&config.model)?.clone();
                let w = Weights::load(&rt.manifest.weights_path(&config.model)?)?;
                (cfg, w)
            }
            None => {
                // Native-only: manifest still describes models and weights.
                let manifest =
                    crate::runtime::artifacts::Manifest::load(&config.artifacts_dir)?;
                let cfg = manifest.model(&config.model)?.clone();
                let w = Weights::load(&manifest.weights_path(&config.model)?)?;
                (cfg, w)
            }
        };
        let registry = Registry::new(&config.artifacts_dir);
        Ok(Pipeline {
            config,
            model_cfg,
            weights,
            rt,
            registry,
            calib: None,
            whitener_cache: Default::default(),
            spectra_cache: Default::default(),
        })
    }

    pub fn batch(&self) -> usize {
        self.rt.as_ref().map(|rt| rt.manifest.eval_batch).unwrap_or(8)
    }

    pub fn seq(&self) -> usize {
        self.rt.as_ref().map(|rt| rt.manifest.seq).unwrap_or(self.model_cfg.max_seq)
    }

    /// Calibration stats (computed once, cached).
    pub fn calibrate(&mut self) -> Result<&TapStats> {
        if self.calib.is_none() {
            let _sp = crate::obs::span("pipeline.calibrate");
            let corpus = self.registry.calibration()?;
            let stats = self.collect_stats(&corpus, self.config.calib_samples, true)?;
            self.calib = Some(stats);
        }
        Ok(self.calib.as_ref().unwrap())
    }

    /// Collect tap stats over a corpus (random windows if `random`, else
    /// sequential eval windows) — used for calibration AND similarity.
    pub fn collect_stats(&self, corpus: &Corpus, windows: usize, random: bool) -> Result<TapStats> {
        let batch = self.batch();
        let seq = self.seq();
        let batcher = Batcher::new(batch, seq);
        let mut rng = Rng::new(self.config.seed);
        let batches = if random {
            batcher.calibration_batches(corpus, windows, &mut rng)
        } else {
            let mut bs = batcher.eval_batches(corpus, windows);
            bs.retain(|tb| tb.valid_rows == tb.batch);
            bs
        };
        match &self.rt {
            Some(rt) => {
                let runner = rt.gram_runner(&self.config.model)?;
                let mut stats = TapStats::default();
                for tb in &batches {
                    runner.accumulate(tb, &mut stats)?;
                }
                stats.finalize();
                Ok(stats)
            }
            None => {
                // Hand the pipeline's thread budget to the GEMMs under the
                // native collection: both the f32 forward and the SYRK
                // Gram flushes read the per-thread knob, and both are
                // bit-identical at every worker count.
                let _gemm = crate::linalg::gemm::scoped_workers(
                    crate::util::threads::ThreadBudget::new(self.config.workers).total(),
                );
                collect_native(&self.model_cfg, &self.weights, &batches)
            }
        }
    }

    /// Decompose every compressible weight with `spec` via the sharded
    /// [`CompressionEngine`]: stage-1 whiteners are computed once per
    /// (method-class, tap) — wq/wk/wv share one, repeat jobs in a sweep pay
    /// zero whitening cost — and layer jobs fan out over
    /// `config.workers` threads with the configured SVD policy.  With
    /// `--factor-dtype int8` the factors come back quantized.
    pub fn compress(&mut self, spec: &CompressionSpec) -> Result<CompressedModel> {
        let mut sp = crate::obs::span("pipeline.compress");
        if sp.is_recording() {
            sp.arg_str("method", spec.method.label()).arg_f64("ratio", spec.ratio);
        }
        let cm = self.compress_f32(spec)?;
        Ok(match self.config.factor_dtype {
            FactorDtype::F32 => cm,
            FactorDtype::Int8 => cm.quantize(DEFAULT_GROUP),
        })
    }

    /// The decomposition itself, always in f32 — the sweep quantizes a copy
    /// per point so both dtype rows come from ONE decomposition.
    fn compress_f32(&mut self, spec: &CompressionSpec) -> Result<CompressedModel> {
        self.calibrate()?;
        let stats = self.calib.as_ref().unwrap();
        let engine = CompressionEngine::new(EngineConfig {
            workers: self.config.workers,
            svd: self.config.svd.clone(),
        });
        if self.config.allocate == AllocStrategy::Uniform && !self.config.alpha_auto {
            // The paper protocol — untouched fast path, bit-identical to
            // the pre-allocator pipeline.
            return engine.compress_model(
                &self.model_cfg,
                &self.weights,
                stats,
                spec,
                &mut self.whitener_cache,
            );
        }
        let alloc = AllocConfig {
            strategy: self.config.allocate,
            alpha_auto: self.config.alpha_auto,
            k_caps: self.pjrt_rank_caps(spec),
        };
        // Spectra depend only on (weights, whitener kind), so one profiling
        // pass serves every ratio/α of a sweep.
        let profiles: Option<&[LayerProfile]> = if self.config.allocate == AllocStrategy::Spectrum
        {
            let kind = spec.method.whitener_kind().to_string();
            if !self.spectra_cache.contains_key(&kind) {
                let p = engine.profile_spectra(
                    &self.model_cfg,
                    &self.weights,
                    stats,
                    spec,
                    &mut self.whitener_cache,
                )?;
                self.spectra_cache.insert(kind.clone(), p);
            }
            Some(self.spectra_cache.get(&kind).unwrap().as_slice())
        } else {
            None
        };
        let plans = engine.plan_model_with_profiles(
            &self.model_cfg,
            &self.weights,
            stats,
            spec,
            &alloc,
            profiles,
            &mut self.whitener_cache,
        )?;
        engine.compress_model_planned(
            &self.model_cfg,
            &self.weights,
            stats,
            spec,
            &plans,
            &mut self.whitener_cache,
        )
    }

    /// Per-layer total-rank caps for the spectrum allocator when factors
    /// must fit the fixed-shape PJRT executables ([`ranks::max_k_for_alpha`]);
    /// the native forward has no padded buffers, so no cap applies.  With
    /// `--alpha auto` the cap must hold for every candidate split, so the
    /// most restrictive grid α wins.
    fn pjrt_rank_caps(&self, spec: &CompressionSpec) -> Option<Vec<usize>> {
        if self.rt.is_none() {
            return None;
        }
        let auto = self.config.alpha_auto && spec.method.is_nested();
        Some(
            self.model_cfg
                .linear_shapes
                .iter()
                .map(|(_, n_in, n_out)| {
                    if auto {
                        ALPHA_GRID
                            .iter()
                            .map(|&a| ranks::max_k_for_alpha(*n_out, *n_in, a))
                            .min()
                            .unwrap_or(1)
                    } else {
                        ranks::max_k_for_alpha(*n_out, *n_in, spec.effective_alpha())
                    }
                })
                .collect(),
        )
    }

    /// Evaluate a (possibly compressed) model on all eight test sets.
    pub fn evaluate_all(&self, cm: Option<&CompressedModel>) -> Result<Vec<PerplexityResult>> {
        let mut sp = crate::obs::span("pipeline.evaluate");
        if sp.is_recording() {
            sp.arg_str("what", if cm.is_some() { "compressed" } else { "dense" });
        }
        let batch = self.batch();
        let seq = self.seq();
        let mut out = Vec::new();
        // Build the evaluator once; reuse across datasets.
        match (&self.rt, cm) {
            (Some(rt), Some(cm)) => {
                let eval = rt.lowrank_evaluator(&self.config.model, batch, cm)?;
                for domain in DOMAIN_NAMES {
                    let corpus = self.registry.load(domain, "test")?;
                    out.push(evaluate(
                        &EvalBackend::PjrtLowRank(&eval),
                        &corpus, batch, seq, self.config.eval_windows,
                    )?);
                }
            }
            (Some(rt), None) => {
                let eval = rt.dense_evaluator(&self.config.model, batch)?;
                for domain in DOMAIN_NAMES {
                    let corpus = self.registry.load(domain, "test")?;
                    out.push(evaluate(
                        &EvalBackend::PjrtDense(&eval),
                        &corpus, batch, seq, self.config.eval_windows,
                    )?);
                }
            }
            (None, cm) => {
                for domain in DOMAIN_NAMES {
                    let corpus = self.registry.load(domain, "test")?;
                    out.push(evaluate_with_workers(
                        &EvalBackend::Native {
                            cfg: &self.model_cfg,
                            weights: &self.weights,
                            compressed: cm,
                        },
                        &corpus, batch, seq, self.config.eval_windows,
                        self.config.eval_workers,
                    )?);
                }
            }
        }
        Ok(out)
    }

    /// Full run: calibrate → compress → evaluate all datasets.
    pub fn run(&mut self, spec: &CompressionSpec) -> Result<CompressionReport> {
        let cm = self.compress(spec)?;
        let results = self.evaluate_all(Some(&cm))?;
        Ok(CompressionReport {
            model: self.config.model.clone(),
            method: spec.method.label().to_string(),
            ratio: spec.ratio,
            alpha: spec.effective_alpha(),
            dense_params: self.model_cfg.compressible_params(),
            compressed_params: cm.params(),
            dtype: self.config.factor_dtype.label(),
            factor_bytes: cm.factor_bytes(),
            results,
        })
    }

    /// Build the serving KV compression at `config.kv_ratio`: the same
    /// stage-1 whitener `spec.method` uses for weights (from each layer's
    /// `attn_in` calibration Gram, shared with wq/wk/wv weight jobs via the
    /// whitener cache) plus ASVD query-side scaling on `wk`, spectrum-aware
    /// rank allocation when `--allocate spectrum`.  Returns `None` at
    /// ratio ≥ 1.0 — serving then keeps the uncompressed pool path.
    pub fn build_kv_compression(
        &mut self,
        spec: &CompressionSpec,
    ) -> Result<Option<KvCompression>> {
        if self.config.kv_ratio >= 1.0 {
            return Ok(None);
        }
        let ratio = self.config.kv_ratio;
        self.build_kv_at(spec, ratio).map(Some)
    }

    /// The KV factorization at an explicit latent ratio — shared by
    /// [`Pipeline::build_kv_compression`] (serving) and the sweep's
    /// `kv-cache` quality rows, so both score/serve identical factors.
    fn build_kv_at(&mut self, spec: &CompressionSpec, ratio: f64) -> Result<KvCompression> {
        self.calibrate()?;
        let stats = self.calib.as_ref().unwrap();
        let kind = spec.method.whitener_kind();
        // Warm the shared cache: one whitener per attn_in tap, reused by
        // (and from) the weight-compression jobs of the same method class.
        for i in 0..self.model_cfg.n_layers {
            let tap = ModelConfig::tap_for_linear(&format!("blocks.{i}.attn.wk"));
            let key = (kind.to_string(), tap.clone());
            if !self.whitener_cache.contains_key(&key) {
                let tap_stats = stats.taps.get(&tap).ok_or_else(|| {
                    anyhow::anyhow!("no calibration stats for KV factors (tap {tap})")
                })?;
                self.whitener_cache
                    .insert(key, Arc::new(spec.method.stage1_whitener(tap_stats)));
            }
        }
        let cache = &self.whitener_cache;
        let whitener = |layer: usize| -> Option<Arc<Whitener>> {
            let tap = ModelConfig::tap_for_linear(&format!("blocks.{layer}.attn.wk"));
            cache.get(&(kind.to_string(), tap)).cloned()
        };
        let kv_spec = KvBuildSpec {
            ratio,
            spectrum: self.config.allocate == AllocStrategy::Spectrum,
            query_scale: true,
        };
        compress_kv_with(&self.model_cfg, &self.weights, &whitener, &kv_spec, &self.config.svd)
    }

    /// Score the KV latent view ([`kv_override_model`]) on every eval set —
    /// numerically exactly what the paged pool serves at this ratio.
    /// Native backend only: the wk/wv-only view (zero-width stage 2, latent
    /// ranks above the executables' rank caps) does not fit the fixed-shape
    /// PJRT factor buffers.  Serving itself (`serve-gen --kv-ratio`) is
    /// always native and has no such restriction.
    pub fn evaluate_kv_view(&self, kvc: &KvCompression) -> Result<Vec<PerplexityResult>> {
        anyhow::ensure!(
            self.rt.is_none(),
            "--kv-ratio quality evaluation requires the native backend (add --native): \
             the wk/wv-only latent view does not fit the fixed-shape PJRT executables"
        );
        self.evaluate_all(Some(&kv_override_model(kvc)))
    }

    /// Sweep the global parameter budget (one compression ratio per point)
    /// under the configured allocation strategy and return the
    /// budget-vs-perplexity curve — the axis on which `--allocate spectrum`
    /// is compared against the uniform protocol.  The whitener cache and
    /// (in spectrum mode) the per-layer spectra cache are shared across
    /// points — spectra are ratio-independent, so profiling runs once and
    /// each extra ratio costs only its decompositions + eval.
    pub fn run_budget_sweep(
        &mut self,
        spec: &CompressionSpec,
        ratios: &[f64],
    ) -> Result<Vec<BudgetSweepPoint>> {
        let mut out = Vec::with_capacity(ratios.len());
        for &ratio in ratios {
            let point_spec = CompressionSpec { ratio, ..*spec };
            let cm = self.compress_f32(&point_spec)?;
            let results = self.evaluate_all(Some(&cm))?;
            out.push(BudgetSweepPoint {
                ratio,
                strategy: self.config.allocate.label(),
                compressed_params: cm.params(),
                dtype: FactorDtype::F32.label(),
                factor_bytes: cm.factor_bytes(),
                ppl: pooled_ppl(&results),
            });
            if self.config.factor_dtype == FactorDtype::Int8 {
                // The dtype axis: same decomposition, re-encoded — the ppl
                // gap between the paired rows IS the int8 quality delta.
                let cm_q = cm.quantize(DEFAULT_GROUP);
                let results_q = self.evaluate_all(Some(&cm_q))?;
                out.push(BudgetSweepPoint {
                    ratio,
                    strategy: self.config.allocate.label(),
                    compressed_params: cm_q.params(),
                    dtype: FactorDtype::Int8.label(),
                    factor_bytes: cm_q.factor_bytes(),
                    ppl: pooled_ppl(&results_q),
                });
            }
            if self.config.kv_ratio < 1.0 {
                // The KV axis (`--kv-ratio < 1` opts in): the same sweep
                // ratio applied to the cache latent width.  The wk/wv-only
                // low-rank view scores exactly what the paged pool serves
                // ([`kv_override_model`]), so this row IS pooled ppl vs
                // kv-ratio on the shared curve.
                let kvc = self.build_kv_at(spec, ratio)?;
                let results_kv = self.evaluate_kv_view(&kvc)?;
                out.push(BudgetSweepPoint {
                    ratio,
                    strategy: "kv-cache",
                    compressed_params: kvc.params(),
                    dtype: FactorDtype::F32.label(),
                    factor_bytes: kvc.factor_bytes(),
                    ppl: pooled_ppl(&results_kv),
                });
            }
        }
        Ok(out)
    }

    /// Dense (uncompressed) baseline row.
    pub fn run_dense(&self) -> Result<CompressionReport> {
        let results = self.evaluate_all(None)?;
        Ok(CompressionReport {
            model: self.config.model.clone(),
            method: "Original".to_string(),
            ratio: 0.0,
            alpha: 1.0,
            dense_params: self.model_cfg.compressible_params(),
            compressed_params: self.model_cfg.compressible_params(),
            dtype: FactorDtype::F32.label(),
            factor_bytes: 4 * self.model_cfg.compressible_params(),
            results,
        })
    }

    /// Table 2 / Figure 1: per-dataset activation similarity vs calibration.
    pub fn similarity_analysis(&mut self) -> Result<Vec<SimilarityReport>> {
        self.calibrate()?;
        let windows = self.config.eval_windows;
        // Borrow dance: clone the calibration stats handle before the loop.
        let calib = self.calib.clone().unwrap();
        let mut reports = Vec::new();
        for domain in DOMAIN_NAMES {
            let corpus = self.registry.load(domain, "test")?;
            let eval_stats = self.collect_stats(&corpus, windows, false)?;
            reports.push(similarity_stats(domain, &calib, &eval_stats));
        }
        Ok(reports)
    }

    /// Access the runtime (serving needs the serve executable).
    pub fn runtime(&self) -> Option<&Runtime> {
        self.rt.as_ref()
    }
}
