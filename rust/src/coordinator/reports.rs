//! Table rendering: the paper's tables as markdown (+ JSON for benches).
//!
//! Layout mirrors the paper: one row per method, one column per dataset,
//! best-per-column in bold, and blue-text relative improvement vs the best
//! baseline rendered as `(±x.x%)`.

use crate::data::corpus::{paper_label, DOMAIN_NAMES};
use crate::util::json::Json;
use crate::util::timer::Stats;

/// A generic table (headers + rows of strings).
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: Vec<String>) -> Table {
        Table { title: title.to_string(), headers, rows: Vec::new() }
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "row width mismatch");
        self.rows.push(row);
    }

    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        s.push_str(&format!("|{}\n", "---|".repeat(self.headers.len())));
        for row in &self.rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("title", self.title.as_str());
        obj.set(
            "headers",
            Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        obj.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        obj
    }
}

/// Format a perplexity like the paper (2 decimals, thousands unseparated).
pub fn fmt_ppl(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.2}")
    } else {
        "inf".to_string()
    }
}

/// One method's row of per-dataset perplexities.
#[derive(Clone, Debug)]
pub struct MethodRow {
    pub label: String,
    /// Perplexity per dataset, in `DOMAIN_NAMES` order.
    pub ppl: Vec<f64>,
    /// Is this row one of the NSVD/NID contributions (gets improvement %)?
    pub is_ours: bool,
}

/// Render a paper-style method×dataset block.
///
/// `baseline`: index of the best-performing-baseline row used as the
/// reference for the improvement percentages (the paper uses ASVD-I).
/// The "Avg. Impro." column averages over all datasets EXCEPT wiki
/// (the calibration domain), exactly as the paper does.
pub fn render_method_block(title: &str, rows: &[MethodRow], baseline: usize) -> Table {
    let mut headers = vec!["Method".to_string()];
    headers.extend(DOMAIN_NAMES.iter().map(|d| paper_label(d).to_string()));
    headers.push("Avg. Impro.".to_string());
    let mut table = Table::new(title, headers);

    // Best value per dataset for bolding.
    let n = DOMAIN_NAMES.len();
    let mut best = vec![f64::INFINITY; n];
    for row in rows {
        for (j, &p) in row.ppl.iter().enumerate() {
            if p < best[j] {
                best[j] = p;
            }
        }
    }
    for row in rows {
        let mut cells = vec![row.label.clone()];
        let mut improvements = Vec::new();
        for (j, &p) in row.ppl.iter().enumerate() {
            let mut cell = fmt_ppl(p);
            if (p - best[j]).abs() < 1e-12 {
                cell = format!("**{cell}**");
            }
            if row.is_ours {
                let base = rows[baseline].ppl[j];
                let delta = (p - base) / base * 100.0;
                let arrow = if delta <= 0.0 { "↓" } else { "↑" };
                cell.push_str(&format!(" ({arrow}{:.1}%)", delta.abs()));
                if j > 0 {
                    // Skip wiki (index 0 = calibration domain) in the average.
                    improvements.push(-delta);
                }
            }
            cells.push(cell);
        }
        if row.is_ours && !improvements.is_empty() {
            let avg = improvements.iter().sum::<f64>() / improvements.len() as f64;
            cells.push(format!("{avg:.1}%"));
        } else {
            cells.push("-".to_string());
        }
        table.push_row(cells);
    }
    table
}

/// Render serving latency percentiles as a table: one row per labeled
/// series, p50/p95/p99 (plus mean/max) in milliseconds from the sorted
/// sample buffer behind [`Stats`].  Used by both the scoring server
/// (`serve`) and the generation server (`serve-gen`) CLI modes.
pub fn render_latency_block(title: &str, rows: &[(String, Stats)]) -> Table {
    let headers = ["Series", "n", "mean ms", "p50 ms", "p95 ms", "p99 ms", "max ms"]
        .iter()
        .map(|h| h.to_string())
        .collect();
    let mut table = Table::new(title, headers);
    for (label, s) in rows {
        table.push_row(vec![
            label.clone(),
            s.n.to_string(),
            format!("{:.2}", s.mean * 1e3),
            format!("{:.2}", s.p50 * 1e3),
            format!("{:.2}", s.p95 * 1e3),
            format!("{:.2}", s.p99 * 1e3),
            format!("{:.2}", s.max * 1e3),
        ]);
    }
    table
}

/// Render the generation server's per-tenant accounting as a table: one
/// row per tenant id, terminal-outcome counts plus generated tokens and
/// the tenant's share of server throughput.  Used by `serve-gen` when the
/// workload stamps tenant ids (all-default workloads collapse to one
/// tenant-0 row).
pub fn render_tenant_block(
    title: &str,
    metrics: &crate::coordinator::metrics::GenServerMetrics,
) -> Table {
    let headers =
        ["Tenant", "requests", "completed", "cancelled", "rejected", "shed", "deadline", "faulted", "tokens", "tok/s"]
            .iter()
            .map(|h| h.to_string())
            .collect();
    let mut table = Table::new(title, headers);
    for (&tenant, t) in &metrics.tenants {
        table.push_row(vec![
            tenant.to_string(),
            t.requests.to_string(),
            t.completed.to_string(),
            t.cancelled.to_string(),
            t.rejected.to_string(),
            t.shed.to_string(),
            t.deadline_exceeded.to_string(),
            t.faulted.to_string(),
            t.generated.to_string(),
            format!("{:.1}", metrics.tenant_tokens_per_s(tenant)),
        ]);
    }
    table
}

/// Render per-request lifecycle timelines from a trace snapshot: one row
/// per request id seen in `serve.request.*` instants, with millisecond
/// offsets since the obs epoch, scheduler churn counts, and the terminal
/// reason.  The human-readable companion to `--trace-out` in `serve-gen`.
pub fn render_request_timeline(title: &str, events: &[crate::obs::TraceEvent]) -> Table {
    #[derive(Default)]
    struct Life {
        queued: Option<u64>,
        admitted: Option<u64>,
        preempts: u64,
        resumes: u64,
        done: Option<u64>,
        reason: String,
        generated: u64,
    }
    let mut lives: std::collections::BTreeMap<u64, Life> = Default::default();
    for e in events {
        if !e.instant || !e.name.starts_with("serve.request.") {
            continue;
        }
        let Some(req) = e.arg_u64("req") else { continue };
        let l = lives.entry(req).or_default();
        match e.name {
            "serve.request.queued" => l.queued = Some(e.ts_us),
            // A preempted request is re-admitted via `resumed`; keep the
            // first admission as THE admission instant.
            "serve.request.admitted" => l.admitted = l.admitted.or(Some(e.ts_us)),
            "serve.request.preempted" => l.preempts += 1,
            "serve.request.resumed" => l.resumes += 1,
            "serve.request.done" => {
                l.done = Some(e.ts_us);
                l.reason = e.arg_str("reason").unwrap_or("?").to_string();
                l.generated = e.arg_u64("generated").unwrap_or(0);
            }
            _ => {}
        }
    }
    let headers =
        ["Request", "queued ms", "admitted ms", "preempts", "resumes", "done ms", "reason", "tokens"]
            .iter()
            .map(|h| h.to_string())
            .collect();
    let mut table = Table::new(title, headers);
    let ms = |t: Option<u64>| t.map_or("-".to_string(), |us| format!("{:.2}", us as f64 / 1e3));
    for (req, l) in &lives {
        table.push_row(vec![
            req.to_string(),
            ms(l.queued),
            ms(l.admitted),
            l.preempts.to_string(),
            l.resumes.to_string(),
            ms(l.done),
            if l.reason.is_empty() { "-".to_string() } else { l.reason.clone() },
            l.generated.to_string(),
        ]);
    }
    table
}

/// Write a table to `target/reports/<slug>.md` and `.json`.
pub fn save_table(table: &Table, slug: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target/reports");
    std::fs::create_dir_all(dir)?;
    let md = dir.join(format!("{slug}.md"));
    std::fs::write(&md, table.to_markdown())?;
    std::fs::write(dir.join(format!("{slug}.json")), table.to_json().to_string_pretty())?;
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_structure() {
        let mut t = Table::new("Demo", vec!["A".into(), "B".into()]);
        t.push_row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| A | B |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_is_enforced() {
        let mut t = Table::new("x", vec!["A".into()]);
        t.push_row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn method_block_marks_best_and_improvement() {
        let rows = vec![
            MethodRow { label: "ASVD-I".into(), ppl: vec![10.0; 8], is_ours: false },
            MethodRow {
                label: "NSVD-I".into(),
                ppl: vec![11.0, 9.0, 9.0, 9.0, 9.0, 9.0, 5.0, 5.0],
                is_ours: true,
            },
        ];
        let t = render_method_block("Table 1 (30%)", &rows, 0);
        let md = t.to_markdown();
        // NSVD best on 7 sets → bold; improvement annotations present.
        assert!(md.contains("**9.00**"));
        assert!(md.contains("(↓10.0%)"));
        assert!(md.contains("(↑10.0%)")); // wiki got worse
        // Avg improvement over non-wiki sets: (10+10+10+10+10+50+50)/7 = 21.4%.
        assert!(md.contains("21.4%"), "md:\n{md}");
    }

    #[test]
    fn latency_block_reports_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 1e3).collect();
        let t = render_latency_block(
            "Serving latency",
            &[("end-to-end".to_string(), Stats::from(&samples))],
        );
        let md = t.to_markdown();
        assert!(md.contains("p50 ms"));
        assert!(md.contains("p95 ms"));
        assert!(md.contains("p99 ms"));
        // 95th percentile of 1..=100 ms is 95 ms.
        assert!(md.contains("95.00"), "md:\n{md}");
        assert!(md.contains("99.00"));
    }

    #[test]
    fn tenant_block_rows_per_tenant() {
        use crate::coordinator::metrics::GenServerMetrics;
        use crate::serve::stream::FinishReason;
        let mut m = GenServerMetrics::default();
        m.record_terminal(1, FinishReason::Completed, 5);
        m.record_terminal(1, FinishReason::Shed, 2);
        m.record_terminal(3, FinishReason::DeadlineExceeded, 0);
        let t = render_tenant_block("Per-tenant serving", &m);
        let md = t.to_markdown();
        assert_eq!(t.rows.len(), 2, "md:\n{md}");
        assert!(md.contains("| 1 | 2 | 1 | 0 | 0 | 1 | 0 | 0 | 7 | 0.0 |"), "md:\n{md}");
        assert!(md.contains("| 3 | 1 | 0 | 0 | 0 | 0 | 1 | 0 | 0 | 0.0 |"), "md:\n{md}");
    }

    #[test]
    fn request_timeline_folds_lifecycle_instants() {
        use crate::obs::{ArgValue, TraceEvent};
        let ev = |name: &'static str, ts_us: u64, args: Vec<(&'static str, ArgValue)>| TraceEvent {
            name,
            ts_us,
            dur_us: 0,
            instant: true,
            tid: 1,
            id: ts_us,
            parent: 0,
            args,
        };
        let events = vec![
            ev("serve.request.queued", 1000, vec![("req", ArgValue::U64(7))]),
            ev("serve.request.admitted", 2000, vec![("req", ArgValue::U64(7))]),
            ev("serve.request.preempted", 3000, vec![("req", ArgValue::U64(7))]),
            ev("serve.request.resumed", 4000, vec![("req", ArgValue::U64(7))]),
            ev(
                "serve.request.done",
                9000,
                vec![
                    ("req", ArgValue::U64(7)),
                    ("reason", ArgValue::Str("completed".into())),
                    ("generated", ArgValue::U64(5)),
                ],
            ),
            ev("serve.request.queued", 1500, vec![("req", ArgValue::U64(8))]),
        ];
        let t = render_request_timeline("Request timeline", &events);
        let md = t.to_markdown();
        assert_eq!(t.rows.len(), 2, "md:\n{md}");
        assert!(md.contains("| 7 | 1.00 | 2.00 | 1 | 1 | 9.00 | completed | 5 |"), "md:\n{md}");
        assert!(md.contains("| 8 | 1.50 | - | 0 | 0 | - | - | 0 |"), "md:\n{md}");
    }

    #[test]
    fn json_roundtrip() {
        let mut t = Table::new("T", vec!["A".into()]);
        t.push_row(vec!["x".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str().unwrap(), "T");
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
