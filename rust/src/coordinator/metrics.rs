//! Serving metrics: latency, queue wait, batch-size distribution — for the
//! scoring server ([`ServerMetrics`]) and the continuous-batching
//! generation server ([`GenServerMetrics`]).
//!
//! Both keep full sample buffers and report latency percentiles
//! (p50/p95/p99 via [`Stats`], which sorts the buffer) rather than means:
//! serving tails are what capacity planning cares about, and a mean hides
//! the convoy effects dynamic batching can introduce.  Every percentile
//! family goes through the same [`Stats`] type and every summary line
//! reports p99 — TTFT included.
//!
//! Both structs also export to the observability registry
//! (`to_registry`): canonical `serve.*` metric names shared with the
//! scheduler's live instrumentation, so a final exact summary can replace
//! the live snapshot's entries via `Registry::replace_from` before a
//! Prometheus dump.

use crate::serve::stream::FinishReason;
use crate::util::timer::Stats;
use std::collections::BTreeMap;

/// Accumulates serving-side observations.
#[derive(Clone, Debug, Default)]
pub struct ServerMetrics {
    /// End-to-end request latency (seconds).
    pub latency_s: Vec<f64>,
    /// Time spent queued before batching (seconds).
    pub queue_wait_s: Vec<f64>,
    /// Rows actually used per executed batch.
    pub batch_fill: Vec<f64>,
    /// Total requests completed.
    pub completed: usize,
    /// Total batches executed.
    pub batches: usize,
    /// Wall-clock of the serving window (seconds).
    pub wall_s: f64,
}

impl ServerMetrics {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn latency(&self) -> Stats {
        Stats::from(&self.latency_s)
    }

    pub fn queue_wait(&self) -> Stats {
        Stats::from(&self.queue_wait_s)
    }

    pub fn mean_batch_fill(&self) -> f64 {
        if self.batch_fill.is_empty() {
            0.0
        } else {
            self.batch_fill.iter().sum::<f64>() / self.batch_fill.len() as f64
        }
    }

    pub fn summary(&self) -> String {
        let lat = self.latency();
        format!(
            "requests={} batches={} throughput={:.1} req/s mean_fill={:.2} \
             latency p50={:.1}ms p95={:.1}ms p99={:.1}ms max={:.1}ms",
            self.completed,
            self.batches,
            self.throughput_rps(),
            self.mean_batch_fill(),
            lat.p50 * 1e3,
            lat.p95 * 1e3,
            lat.p99 * 1e3,
            lat.max * 1e3,
        )
    }

    /// Fold these metrics into an observability registry for Prometheus
    /// export ([`crate::obs::export::prometheus_text`]).
    pub fn to_registry(&self) -> crate::obs::Registry {
        let mut r = crate::obs::Registry::default();
        r.counter_add("serve.requests.completed", self.completed as u64);
        r.counter_add("serve.batches", self.batches as u64);
        r.gauge_set("serve.throughput_rps", self.throughput_rps());
        r.gauge_set("serve.batch_fill_mean", self.mean_batch_fill());
        r.gauge_set("serve.wall_seconds", self.wall_s);
        for &v in &self.latency_s {
            r.observe("serve.latency_seconds", v);
        }
        for &v in &self.queue_wait_s {
            r.observe("serve.queue_wait_seconds", v);
        }
        r
    }
}

/// Cap on each percentile sample buffer of [`GenServerMetrics`]: beyond
/// it the buffers turn into rings over the most recent observations, so a
/// generation server that runs indefinitely holds bounded metric memory
/// (~0.5 MB) while its counters stay exact.
pub const GEN_MAX_SAMPLES: usize = 16_384;

/// Accumulated observations of the continuous-batching generation server
/// ([`crate::serve::batcher::serve_generation`]).
///
/// The sample buffers are bounded ([`GEN_MAX_SAMPLES`] most recent via
/// [`GenServerMetrics::record_step`] / [`record_finish`]); the scalar
/// counters are exact over the whole serving window.
///
/// [`record_finish`]: GenServerMetrics::record_finish
#[derive(Clone, Debug, Default)]
pub struct GenServerMetrics {
    /// End-to-end request latency: enqueue → finished (seconds;
    /// bounded ring, most recent [`GEN_MAX_SAMPLES`]).
    pub latency_s: Vec<f64>,
    /// Time to first generated token per request (seconds; bounded ring).
    pub ttft_s: Vec<f64>,
    /// Wall-clock of each batched decode step (seconds; bounded ring).
    pub step_s: Vec<f64>,
    /// Active sequences per executed step (bounded ring).
    pub batch_fill: Vec<f64>,
    /// KV-pool page occupancy per executed step, `pages_in_use / pages`
    /// in `[0, 1]` (bounded ring).
    pub page_occupancy: Vec<f64>,
    /// Requests retired after admission (completed + cancelled + shed /
    /// deadline-killed / faulted mid-stream).
    pub completed: usize,
    /// Requests retired because the client dropped its stream receiver.
    pub cancelled: usize,
    /// Requests refused at admission (bad prompt, infeasible page need,
    /// or arriving at a full bounded queue as the least-urgent work).
    pub rejected: usize,
    /// Requests dropped by the overload policy to make room for more
    /// urgent work ([`FinishReason::Shed`]).
    pub shed: usize,
    /// Requests killed because their deadline expired
    /// ([`FinishReason::DeadlineExceeded`]).
    pub deadline_exceeded: usize,
    /// Requests retired by the watchdog after a panic or injected fault
    /// in their step rows ([`FinishReason::Faulted`]).
    pub faulted: usize,
    /// Most requests ever waiting in the bounded admission queue.
    pub peak_queue: usize,
    /// Per-tenant terminal and token accounting, keyed by
    /// [`crate::serve::GenRequest::tenant`].
    pub tenants: BTreeMap<u32, TenantMetrics>,
    /// Sequences evicted back to the queue on pool exhaustion (each later
    /// resumes; double-counted if preempted twice).
    pub preemptions: usize,
    /// Most sequences concurrently active in any one step — what a paged
    /// pool raises over worst-case reservation at equal memory.
    pub peak_active: usize,
    /// Prompt positions served from the prefix trie instead of prefill.
    pub prefix_hit_tokens: u64,
    /// Prompt positions that had to be prefilled (trie miss or disabled).
    pub prefix_miss_tokens: u64,
    /// Prompt rows fed through chunked prefill (excludes replayed and
    /// prefix-shared positions).
    pub prefill_rows: usize,
    /// Bytes one committed token position occupies across every layer's
    /// K+V pages (`KvPool::page_bytes / page_size`) — shrinks ~(r/d)×
    /// under `--kv-ratio` compression.  Stamped once at server start;
    /// 0 means "not stamped" (hand-built metrics).
    pub kv_slot_bytes: f64,
    /// Bytes held by the KV-compression projection factors themselves
    /// (0 when the cache is uncompressed) — the fixed cost the smaller
    /// latent pages amortize.
    pub kv_factor_bytes: usize,
    /// Total tokens generated (across all requests).
    pub generated: usize,
    /// Batched decode steps executed.
    pub steps: usize,
    /// Wall-clock of the serving window (seconds).
    pub wall_s: f64,
}

/// One tenant's slice of the serving window: how many of its requests hit
/// each terminal and how many tokens it generated.  All counters are
/// exact (no sampling).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TenantMetrics {
    /// Requests that reached any terminal (admitted or not).
    pub requests: usize,
    /// Requests that generated their full `max_new`.
    pub completed: usize,
    /// Requests whose client hung up mid-stream.
    pub cancelled: usize,
    /// Requests refused at admission.
    pub rejected: usize,
    /// Requests dropped by the overload policy.
    pub shed: usize,
    /// Requests killed at deadline expiry.
    pub deadline_exceeded: usize,
    /// Requests retired by the watchdog.
    pub faulted: usize,
    /// Tokens generated for this tenant.
    pub generated: u64,
}

impl GenServerMetrics {
    fn push_capped(buf: &mut Vec<f64>, count: usize, v: f64) {
        if buf.len() < GEN_MAX_SAMPLES {
            buf.push(v);
        } else {
            buf[count % GEN_MAX_SAMPLES] = v;
        }
    }

    /// Record one executed decode step (wall-clock, active sequences, and
    /// pool page occupancy in `[0, 1]`); bumps `steps`, tracks the peak
    /// concurrency, and feeds the bounded sample rings.
    pub fn record_step(&mut self, step_s: f64, fill: f64, occupancy: f64) {
        Self::push_capped(&mut self.step_s, self.steps, step_s);
        Self::push_capped(&mut self.batch_fill, self.steps, fill);
        Self::push_capped(&mut self.page_occupancy, self.steps, occupancy);
        self.peak_active = self.peak_active.max(fill as usize);
        self.steps += 1;
    }

    /// Record one retired request (completed or cancelled); bumps
    /// `completed` and feeds the bounded latency/TTFT rings.
    pub fn record_finish(&mut self, latency_s: f64, ttft_s: f64) {
        Self::push_capped(&mut self.latency_s, self.completed, latency_s);
        Self::push_capped(&mut self.ttft_s, self.completed, ttft_s);
        self.completed += 1;
    }

    /// Record one request's terminal event: bumps the global per-reason
    /// counter and the tenant's bucket.  Called exactly once per request
    /// (the scheduler funnels every exit path through one `Done` sender),
    /// so `tenants[t].requests` equals the requests tenant `t` submitted.
    pub fn record_terminal(&mut self, tenant: u32, finish: FinishReason, generated: usize) {
        match finish {
            FinishReason::Completed => {}
            FinishReason::Cancelled => self.cancelled += 1,
            FinishReason::Rejected => self.rejected += 1,
            FinishReason::Shed => self.shed += 1,
            FinishReason::DeadlineExceeded => self.deadline_exceeded += 1,
            FinishReason::Faulted => self.faulted += 1,
        }
        let t = self.tenants.entry(tenant).or_default();
        t.requests += 1;
        t.generated += generated as u64;
        match finish {
            FinishReason::Completed => t.completed += 1,
            FinishReason::Cancelled => t.cancelled += 1,
            FinishReason::Rejected => t.rejected += 1,
            FinishReason::Shed => t.shed += 1,
            FinishReason::DeadlineExceeded => t.deadline_exceeded += 1,
            FinishReason::Faulted => t.faulted += 1,
        }
    }

    /// One tenant's generated tokens per second of serving wall-clock
    /// (0 for unknown tenants or before `wall_s` is stamped).
    pub fn tenant_tokens_per_s(&self, tenant: u32) -> f64 {
        match self.tenants.get(&tenant) {
            Some(t) if self.wall_s > 0.0 => t.generated as f64 / self.wall_s,
            _ => 0.0,
        }
    }

    /// Generated tokens per second of serving wall-clock — THE number
    /// continuous batching exists to raise.
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// End-to-end latency percentiles (sorted-sample buffer).
    pub fn latency(&self) -> Stats {
        Stats::from(&self.latency_s)
    }

    /// Time-to-first-token percentiles.
    pub fn ttft(&self) -> Stats {
        Stats::from(&self.ttft_s)
    }

    /// Per-step wall-clock percentiles.
    pub fn step(&self) -> Stats {
        Stats::from(&self.step_s)
    }

    /// Mean active sequences per step (the continuous-batching fill),
    /// over the bounded sample window.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batch_fill.is_empty() {
            0.0
        } else {
            self.batch_fill.iter().sum::<f64>() / self.batch_fill.len() as f64
        }
    }

    /// Mean pool page occupancy per step in `[0, 1]`, over the bounded
    /// sample window.
    pub fn mean_page_occupancy(&self) -> f64 {
        if self.page_occupancy.is_empty() {
            0.0
        } else {
            self.page_occupancy.iter().sum::<f64>() / self.page_occupancy.len() as f64
        }
    }

    /// KV slots (committed token positions, all layers) one GB of page
    /// memory holds — the capacity axis `--kv-ratio` compression raises.
    /// 0 until `kv_slot_bytes` is stamped by the server.
    pub fn kv_slots_per_gb(&self) -> f64 {
        if self.kv_slot_bytes > 0.0 {
            1e9 / self.kv_slot_bytes
        } else {
            0.0
        }
    }

    /// Fraction of prompt positions served from the prefix trie instead of
    /// being prefilled (0 when sharing is off or no prompt was seen).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hit_tokens + self.prefix_miss_tokens;
        if total > 0 {
            self.prefix_hit_tokens as f64 / total as f64
        } else {
            0.0
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let lat = self.latency();
        let ttft = self.ttft();
        format!(
            "requests={} rejected={} cancelled={} preempted={} shed={} \
             deadline={} faulted={} tokens={} \
             steps={} tok/s={:.1} mean_fill={:.2} peak_active={} \
             occupancy={:.2} prefix_hit={:.2} latency p50={:.1}ms \
             p95={:.1}ms p99={:.1}ms ttft p50={:.1}ms p95={:.1}ms p99={:.1}ms",
            self.completed,
            self.rejected,
            self.cancelled,
            self.preemptions,
            self.shed,
            self.deadline_exceeded,
            self.faulted,
            self.generated,
            self.steps,
            self.tokens_per_s(),
            self.mean_batch_fill(),
            self.peak_active,
            self.mean_page_occupancy(),
            self.prefix_hit_rate(),
            lat.p50 * 1e3,
            lat.p95 * 1e3,
            lat.p99 * 1e3,
            ttft.p50 * 1e3,
            ttft.p95 * 1e3,
            ttft.p99 * 1e3,
        )
    }

    /// Fold the full serving window into an observability registry.  The
    /// canonical names match the scheduler's live instrumentation, so
    /// stamping these exact end-state values over a live snapshot
    /// (`Registry::replace_from`) de-duplicates the final export; the
    /// histograms are rebuilt from the bounded sample rings.
    pub fn to_registry(&self) -> crate::obs::Registry {
        let mut r = crate::obs::Registry::default();
        let completed_full: usize = self.tenants.values().map(|t| t.completed).sum();
        r.counter_add("serve.requests.completed", completed_full as u64);
        r.counter_add("serve.requests.served", self.completed as u64);
        r.counter_add("serve.requests.cancelled", self.cancelled as u64);
        r.counter_add("serve.requests.rejected", self.rejected as u64);
        r.counter_add("serve.requests.shed", self.shed as u64);
        r.counter_add("serve.requests.deadline_exceeded", self.deadline_exceeded as u64);
        r.counter_add("serve.requests.faulted", self.faulted as u64);
        r.counter_add("serve.sched.preemptions", self.preemptions as u64);
        r.counter_add("serve.steps", self.steps as u64);
        r.counter_add("serve.tokens.generated", self.generated as u64);
        r.counter_add("serve.prefill.rows", self.prefill_rows as u64);
        r.counter_add("serve.prefix.hit_tokens", self.prefix_hit_tokens);
        r.counter_add("serve.prefix.miss_tokens", self.prefix_miss_tokens);
        for (t, tm) in &self.tenants {
            r.counter_add(&format!("serve.tenant.requests{{tenant=\"{t}\"}}"), tm.requests as u64);
            r.counter_add(&format!("serve.tenant.generated{{tenant=\"{t}\"}}"), tm.generated);
        }
        r.gauge_set("serve.queue.peak", self.peak_queue as f64);
        r.gauge_set("serve.active.peak", self.peak_active as f64);
        r.gauge_set("serve.pool.kv_slot_bytes", self.kv_slot_bytes);
        r.gauge_set("serve.pool.kv_factor_bytes", self.kv_factor_bytes as f64);
        r.gauge_set("serve.prefix.hit_rate", self.prefix_hit_rate());
        r.gauge_set("serve.tokens_per_s", self.tokens_per_s());
        r.gauge_set("serve.wall_seconds", self.wall_s);
        for &v in &self.latency_s {
            r.observe("serve.latency_seconds", v);
        }
        for &v in &self.ttft_s {
            r.observe("serve.ttft_seconds", v);
        }
        for &v in &self.step_s {
            r.observe("serve.step_seconds", v);
        }
        for &v in &self.batch_fill {
            r.observe("serve.batch_fill", v);
        }
        for &v in &self.page_occupancy {
            r.observe("serve.pool.occupancy_ratio", v);
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_fill() {
        let m = ServerMetrics {
            latency_s: vec![0.01, 0.02],
            queue_wait_s: vec![0.001, 0.002],
            batch_fill: vec![8.0, 4.0],
            completed: 12,
            batches: 2,
            wall_s: 2.0,
        };
        assert_eq!(m.throughput_rps(), 6.0);
        assert_eq!(m.mean_batch_fill(), 6.0);
        assert!(m.summary().contains("requests=12"));
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = ServerMetrics::default();
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.mean_batch_fill(), 0.0);
    }

    #[test]
    fn serve_gen_metrics_tokens_per_s_and_percentiles() {
        let m = GenServerMetrics {
            latency_s: vec![0.010, 0.020, 0.040, 0.080],
            ttft_s: vec![0.004, 0.006, 0.005, 0.007],
            step_s: vec![0.001; 10],
            batch_fill: vec![2.0, 4.0],
            page_occupancy: vec![0.25, 0.75],
            completed: 4,
            cancelled: 1,
            rejected: 2,
            preemptions: 3,
            prefix_hit_tokens: 30,
            prefix_miss_tokens: 10,
            generated: 120,
            steps: 10,
            wall_s: 2.0,
            ..Default::default()
        };
        assert_eq!(m.tokens_per_s(), 60.0);
        assert_eq!(m.mean_batch_fill(), 3.0);
        assert_eq!(m.mean_page_occupancy(), 0.5);
        assert_eq!(m.prefix_hit_rate(), 0.75);
        // Percentiles come from the sorted sample buffer, not the mean.
        assert_eq!(m.latency().p50, 0.020);
        assert_eq!(m.latency().p95, 0.080);
        assert_eq!(m.latency().p99, 0.080);
        let s = m.summary();
        assert!(s.contains("requests=4"));
        assert!(s.contains("rejected=2"));
        assert!(s.contains("preempted=3"));
        assert!(s.contains("prefix_hit=0.75"));
        assert!(s.contains("p95="));
    }

    #[test]
    fn serve_gen_sample_buffers_are_bounded() {
        let mut m = GenServerMetrics::default();
        for i in 0..GEN_MAX_SAMPLES + 100 {
            m.record_step(i as f64, 1.0, 0.5);
            m.record_finish(i as f64, i as f64 / 2.0);
        }
        assert_eq!(m.steps, GEN_MAX_SAMPLES + 100);
        assert_eq!(m.completed, GEN_MAX_SAMPLES + 100);
        assert_eq!(m.step_s.len(), GEN_MAX_SAMPLES);
        assert_eq!(m.latency_s.len(), GEN_MAX_SAMPLES);
        assert_eq!(m.page_occupancy.len(), GEN_MAX_SAMPLES);
        // The ring overwrote the oldest entries with the most recent.
        assert_eq!(m.step_s[0], GEN_MAX_SAMPLES as f64);
        assert_eq!(m.step_s[99], (GEN_MAX_SAMPLES + 99) as f64);
        assert_eq!(m.step_s[100], 100.0);
    }

    #[test]
    fn serve_gen_peak_active_tracks_max_fill() {
        let mut m = GenServerMetrics::default();
        for &fill in &[1.0, 5.0, 3.0] {
            m.record_step(0.001, fill, 0.1);
        }
        assert_eq!(m.peak_active, 5);
    }

    #[test]
    fn serve_gen_record_terminal_buckets_by_tenant_and_reason() {
        let mut m = GenServerMetrics::default();
        m.record_terminal(1, FinishReason::Completed, 10);
        m.record_terminal(1, FinishReason::Shed, 2);
        m.record_terminal(2, FinishReason::Rejected, 0);
        m.record_terminal(2, FinishReason::DeadlineExceeded, 3);
        m.record_terminal(2, FinishReason::Faulted, 1);
        m.record_terminal(1, FinishReason::Cancelled, 4);
        assert_eq!(m.shed, 1);
        assert_eq!(m.deadline_exceeded, 1);
        assert_eq!(m.faulted, 1);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.completed, 0, "record_terminal never bumps completed — record_finish does");
        let t1 = &m.tenants[&1];
        assert_eq!(
            (t1.requests, t1.completed, t1.shed, t1.cancelled, t1.generated),
            (3, 1, 1, 1, 16)
        );
        let t2 = &m.tenants[&2];
        assert_eq!(
            (t2.requests, t2.rejected, t2.deadline_exceeded, t2.faulted, t2.generated),
            (3, 1, 1, 1, 4)
        );
        m.wall_s = 2.0;
        assert_eq!(m.tenant_tokens_per_s(1), 8.0);
        assert_eq!(m.tenant_tokens_per_s(2), 2.0);
        assert_eq!(m.tenant_tokens_per_s(9), 0.0);
        let s = m.summary();
        assert!(s.contains("shed=1"));
        assert!(s.contains("deadline=1"));
        assert!(s.contains("faulted=1"));
    }

    #[test]
    fn serve_gen_tenant_rates_are_zero_without_wall_clock() {
        let mut m = GenServerMetrics::default();
        m.record_terminal(4, FinishReason::Completed, 100);
        assert_eq!(m.tenant_tokens_per_s(4), 0.0, "no wall_s stamped yet");
    }

    #[test]
    fn kv_compress_slots_per_gb_from_slot_bytes() {
        let mut m = GenServerMetrics::default();
        assert_eq!(m.kv_slots_per_gb(), 0.0, "unstamped metrics report 0");
        // 4 layers × (d + d) f32 at d = 64 → 2048 B per committed slot.
        m.kv_slot_bytes = 2048.0;
        assert_eq!(m.kv_slots_per_gb(), 1e9 / 2048.0);
        // Quarter-rank latents shrink the slot 4×, so a GB admits 4× the
        // slots — the ratio perf_serve's equal-memory row asserts on.
        let mut c = m.clone();
        c.kv_slot_bytes = 512.0;
        assert_eq!(c.kv_slots_per_gb() / m.kv_slots_per_gb(), 4.0);
    }

    #[test]
    fn to_registry_exports_counters_gauges_and_hists() {
        let mut m = GenServerMetrics::default();
        m.record_finish(0.010, 0.004);
        m.record_finish(0.030, 0.008);
        m.record_step(0.002, 2.0, 0.5);
        m.record_terminal(7, FinishReason::Completed, 12);
        m.record_terminal(7, FinishReason::Shed, 3);
        m.preemptions = 4;
        m.generated = 15;
        m.wall_s = 1.5;
        let r = m.to_registry();
        assert_eq!(r.counter("serve.requests.served"), 2);
        assert_eq!(r.counter("serve.requests.completed"), 1);
        assert_eq!(r.counter("serve.requests.shed"), 1);
        assert_eq!(r.counter("serve.sched.preemptions"), 4);
        assert_eq!(r.counter("serve.tenant.requests{tenant=\"7\"}"), 2);
        assert_eq!(r.counter("serve.tenant.generated{tenant=\"7\"}"), 15);
        assert_eq!(r.gauge("serve.wall_seconds"), Some(1.5));
        assert_eq!(r.hist("serve.latency_seconds").map(|h| h.count()), Some(2));
        assert_eq!(r.hist("serve.ttft_seconds").map(|h| h.count()), Some(2));
        assert_eq!(r.hist("serve.step_seconds").map(|h| h.count()), Some(1));
        // Replacing a live snapshot's entries with these exact values
        // must overwrite, not add (the de-duplication contract).
        let mut live = crate::obs::Registry::default();
        live.counter_add("serve.requests.served", 99);
        live.counter_add("kernel.gemm.flops", 1000);
        live.replace_from(&r);
        assert_eq!(live.counter("serve.requests.served"), 2);
        assert_eq!(live.counter("kernel.gemm.flops"), 1000);
    }

    #[test]
    fn scoring_metrics_to_registry() {
        let m = ServerMetrics {
            latency_s: vec![0.01, 0.02],
            queue_wait_s: vec![0.001],
            batch_fill: vec![8.0],
            completed: 2,
            batches: 1,
            wall_s: 1.0,
        };
        let r = m.to_registry();
        assert_eq!(r.counter("serve.requests.completed"), 2);
        assert_eq!(r.gauge("serve.throughput_rps"), Some(2.0));
        assert_eq!(r.hist("serve.queue_wait_seconds").map(|h| h.count()), Some(1));
    }

    #[test]
    fn gen_summary_reports_ttft_p99() {
        let mut m = GenServerMetrics::default();
        for i in 0..100 {
            m.record_finish(0.010 + i as f64 * 1e-4, 0.004);
        }
        let s = m.summary();
        let ttft_part = s.split("ttft").nth(1).unwrap();
        assert!(ttft_part.contains("p99="), "ttft segment must report p99: {s}");
    }

    #[test]
    fn serve_gen_empty_metrics_are_safe() {
        let m = GenServerMetrics::default();
        assert_eq!(m.tokens_per_s(), 0.0);
        assert_eq!(m.mean_batch_fill(), 0.0);
        assert_eq!(m.mean_page_occupancy(), 0.0);
        assert_eq!(m.prefix_hit_rate(), 0.0);
        assert_eq!(m.latency().n, 0);
        assert!(m.summary().contains("requests=0"));
    }
}
