//! Serving metrics: latency, queue wait, batch-size distribution.

use crate::util::timer::Stats;

/// Accumulates serving-side observations.
#[derive(Clone, Debug, Default)]
pub struct ServerMetrics {
    /// End-to-end request latency (seconds).
    pub latency_s: Vec<f64>,
    /// Time spent queued before batching (seconds).
    pub queue_wait_s: Vec<f64>,
    /// Rows actually used per executed batch.
    pub batch_fill: Vec<f64>,
    /// Total requests completed.
    pub completed: usize,
    /// Total batches executed.
    pub batches: usize,
    /// Wall-clock of the serving window (seconds).
    pub wall_s: f64,
}

impl ServerMetrics {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn latency(&self) -> Stats {
        Stats::from(&self.latency_s)
    }

    pub fn queue_wait(&self) -> Stats {
        Stats::from(&self.queue_wait_s)
    }

    pub fn mean_batch_fill(&self) -> f64 {
        if self.batch_fill.is_empty() {
            0.0
        } else {
            self.batch_fill.iter().sum::<f64>() / self.batch_fill.len() as f64
        }
    }

    pub fn summary(&self) -> String {
        let lat = self.latency();
        format!(
            "requests={} batches={} throughput={:.1} req/s mean_fill={:.2} \
             latency p50={:.1}ms p99={:.1}ms max={:.1}ms",
            self.completed,
            self.batches,
            self.throughput_rps(),
            self.mean_batch_fill(),
            lat.p50 * 1e3,
            lat.p99 * 1e3,
            lat.max * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_fill() {
        let m = ServerMetrics {
            latency_s: vec![0.01, 0.02],
            queue_wait_s: vec![0.001, 0.002],
            batch_fill: vec![8.0, 4.0],
            completed: 12,
            batches: 2,
            wall_s: 2.0,
        };
        assert_eq!(m.throughput_rps(), 6.0);
        assert_eq!(m.mean_batch_fill(), 6.0);
        assert!(m.summary().contains("requests=12"));
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = ServerMetrics::default();
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.mean_batch_fill(), 0.0);
    }
}
