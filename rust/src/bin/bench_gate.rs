//! Bench regression gate: compare committed `BENCH_*.json` baselines
//! against freshly regenerated results and fail on significant slowdown.
//!
//! ```text
//! bench_gate <baseline_dir> <current_dir> [tolerance]
//! ```
//!
//! Both directories hold `BENCH_*.json` files as written by the perf
//! benches (`{"suite": ..., "note": ..., "results": [{"name": ...,
//! <metric>: <number>, ...}, ...]}`).  For every file present in
//! `current_dir` with a same-named baseline, rows are matched by `name`
//! and each recognized metric compared:
//!
//! - higher-is-better (`gflops`, `*_per_s`, `tok_s`, `speedup*`,
//!   `throughput*`): fail when `current < baseline * (1 - tolerance)`
//! - lower-is-better (`mean_s`, `p50_s`, `p95_s`, `p99_s`, `*latency*`,
//!   `wall_s`): fail when `current > baseline * (1 + tolerance)`
//!
//! Files marked as placeholders (a `note` containing `PLACEHOLDER`, or an
//! empty `results` array) are skipped on either side — the gate only
//! bites once real numbers are committed.  Unknown metric keys and rows
//! missing from one side are reported but never fail the gate, so benches
//! can add rows without breaking CI.  Exit status: 0 clean, 1 regression,
//! 2 usage/IO error.

use nsvd::util::json::{self, Json};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const DEFAULT_TOLERANCE: f64 = 0.10;

/// Metric direction, inferred from the key name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Direction {
    HigherBetter,
    LowerBetter,
    Ignore,
}

fn direction(key: &str) -> Direction {
    // Higher-better patterns first: "per_s" must win over the bare "_s"
    // suffix check below.
    const HIGHER: &[&str] = &["gflops", "per_s", "tok_s", "speedup", "throughput"];
    const LOWER: &[&str] = &["mean_s", "p50_s", "p90_s", "p95_s", "p99_s", "latency", "wall_s"];
    if HIGHER.iter().any(|p| key.contains(p)) {
        return Direction::HigherBetter;
    }
    if LOWER.iter().any(|p| key.contains(p)) {
        return Direction::LowerBetter;
    }
    Direction::Ignore
}

/// A single metric regression (or note) found while comparing one file.
#[derive(Debug)]
struct Finding {
    row: String,
    key: String,
    baseline: f64,
    current: f64,
    regressed: bool,
}

/// True when a parsed BENCH document should be skipped by the gate.
fn is_placeholder(doc: &Json) -> bool {
    let noted = doc
        .get("note")
        .and_then(|n| n.as_str())
        .map_or(false, |n| n.contains("PLACEHOLDER"));
    let empty = doc
        .get("results")
        .and_then(|r| r.as_arr())
        .map_or(true, |r| r.is_empty());
    noted || empty
}

fn row_name(row: &Json) -> Option<&str> {
    row.get("name").and_then(|n| n.as_str())
}

/// Compare two parsed BENCH documents; returns per-metric findings for
/// every row name present in both `results` arrays.
fn compare_docs(baseline: &Json, current: &Json, tolerance: f64) -> Vec<Finding> {
    let empty: &[Json] = &[];
    let base_rows = baseline.get("results").and_then(|r| r.as_arr()).unwrap_or(empty);
    let cur_rows = current.get("results").and_then(|r| r.as_arr()).unwrap_or(empty);
    let mut findings = Vec::new();
    for b in base_rows {
        let Some(name) = row_name(b) else { continue };
        let Some(c) = cur_rows.iter().find(|r| row_name(r) == Some(name)) else {
            continue; // row dropped or renamed: reported by the caller, not a failure
        };
        let Json::Obj(bm) = b else { continue };
        for (key, bv) in bm {
            let dir = direction(key);
            if dir == Direction::Ignore {
                continue;
            }
            let (Some(bx), Some(cx)) = (bv.as_f64(), c.get(key).and_then(|v| v.as_f64())) else {
                continue;
            };
            if !(bx.is_finite() && cx.is_finite()) || bx <= 0.0 {
                continue; // zero/absent baselines carry no signal
            }
            let regressed = match dir {
                Direction::HigherBetter => cx < bx * (1.0 - tolerance),
                Direction::LowerBetter => cx > bx * (1.0 + tolerance),
                Direction::Ignore => unreachable!(),
            };
            findings.push(Finding {
                row: name.to_string(),
                key: key.clone(),
                baseline: bx,
                current: cx,
                regressed,
            });
        }
    }
    findings
}

fn load(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: read failed: {e}", path.display()))?;
    json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

fn bench_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("{}: read_dir failed: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            out.push(entry.path());
        }
    }
    out.sort();
    Ok(out)
}

fn run(baseline_dir: &Path, current_dir: &Path, tolerance: f64) -> Result<bool, String> {
    let files = bench_files(current_dir)?;
    if files.is_empty() {
        println!("bench_gate: no BENCH_*.json in {}", current_dir.display());
        return Ok(true);
    }
    let mut regressions = 0usize;
    let mut compared = 0usize;
    for cur_path in &files {
        let file_name = cur_path.file_name().unwrap().to_string_lossy().to_string();
        let base_path = baseline_dir.join(&file_name);
        if !base_path.exists() {
            println!("  {file_name}: no baseline — skipped (new suite)");
            continue;
        }
        let cur = load(cur_path)?;
        let base = load(&base_path)?;
        if is_placeholder(&base) || is_placeholder(&cur) {
            println!("  {file_name}: placeholder — skipped");
            continue;
        }
        let findings = compare_docs(&base, &cur, tolerance);
        if findings.is_empty() {
            println!("  {file_name}: no comparable metrics — skipped");
            continue;
        }
        compared += findings.len();
        for f in findings.iter().filter(|f| f.regressed) {
            regressions += 1;
            println!(
                "  REGRESSION {file_name} {}/{}: baseline {:.4} -> current {:.4} ({:+.1}%)",
                f.row,
                f.key,
                f.baseline,
                f.current,
                (f.current / f.baseline - 1.0) * 100.0
            );
        }
        let ok = findings.iter().filter(|f| !f.regressed).count();
        println!("  {file_name}: {ok}/{} metrics within {:.0}%", findings.len(), tolerance * 100.0);
    }
    if regressions > 0 {
        println!("bench_gate: {regressions} regression(s) across {compared} compared metrics");
        Ok(false)
    } else {
        println!("bench_gate: OK ({compared} metrics compared)");
        Ok(true)
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() < 2 || args.len() > 3 {
        eprintln!("usage: bench_gate <baseline_dir> <current_dir> [tolerance]");
        return ExitCode::from(2);
    }
    let tolerance = match args.get(2) {
        Some(t) => match t.parse::<f64>() {
            Ok(x) if x >= 0.0 && x < 1.0 => x,
            _ => {
                eprintln!("bench_gate: tolerance must be a fraction in [0, 1), got {t:?}");
                return ExitCode::from(2);
            }
        },
        None => DEFAULT_TOLERANCE,
    };
    println!(
        "bench_gate: {} vs {} (tolerance {:.0}%)",
        Path::new(&args[0]).display(),
        Path::new(&args[1]).display(),
        tolerance * 100.0
    );
    match run(Path::new(&args[0]), Path::new(&args[1]), tolerance) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(msg) => {
            eprintln!("bench_gate: {msg}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(note: &str, rows: &str) -> Json {
        json::parse(&format!(r#"{{"suite": "s", "note": "{note}", "results": {rows}}}"#)).unwrap()
    }

    #[test]
    fn direction_classifies_metric_keys() {
        assert_eq!(direction("gflops"), Direction::HigherBetter);
        assert_eq!(direction("tokens_per_s"), Direction::HigherBetter);
        assert_eq!(direction("speedup_vs_naive"), Direction::HigherBetter);
        assert_eq!(direction("mean_s"), Direction::LowerBetter);
        assert_eq!(direction("p99_s"), Direction::LowerBetter);
        assert_eq!(direction("ttft_latency_ms"), Direction::LowerBetter);
        assert_eq!(direction("n"), Direction::Ignore);
        assert_eq!(direction("workers"), Direction::Ignore);
    }

    #[test]
    fn placeholder_detection_note_and_empty_results() {
        assert!(is_placeholder(&doc("PLACEHOLDER — pending", r#"[{"name": "a", "gflops": 1}]"#)));
        assert!(is_placeholder(&doc("real", "[]")));
        assert!(!is_placeholder(&doc("real", r#"[{"name": "a", "gflops": 1}]"#)));
    }

    #[test]
    fn regression_detection_in_both_directions() {
        let base = doc("real", r#"[{"name": "a", "gflops": 100.0, "mean_s": 1.0, "n": 512}]"#);
        // gflops down 20% (fail), mean_s up 20% (fail).
        let bad = doc("real", r#"[{"name": "a", "gflops": 80.0, "mean_s": 1.2, "n": 512}]"#);
        let findings = compare_docs(&base, &bad, 0.10);
        assert_eq!(findings.len(), 2, "n must be ignored: {findings:?}");
        assert!(findings.iter().all(|f| f.regressed));
        // Within tolerance both ways passes.
        let ok = doc("real", r#"[{"name": "a", "gflops": 95.0, "mean_s": 1.05, "n": 512}]"#);
        assert!(compare_docs(&base, &ok, 0.10).iter().all(|f| !f.regressed));
        // Improvements never fail.
        let fast = doc("real", r#"[{"name": "a", "gflops": 200.0, "mean_s": 0.5}]"#);
        assert!(compare_docs(&base, &fast, 0.10).iter().all(|f| !f.regressed));
    }

    #[test]
    fn missing_rows_and_zero_baselines_are_skipped() {
        let base = doc("real", r#"[{"name": "a", "gflops": 0.0}, {"name": "b", "gflops": 10.0}]"#);
        let cur = doc("real", r#"[{"name": "a", "gflops": 5.0}]"#);
        // Row "b" absent from current and row "a" has a zero baseline:
        // nothing comparable, nothing failed.
        assert!(compare_docs(&base, &cur, 0.10).is_empty());
    }
}
