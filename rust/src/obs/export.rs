//! Export: Chrome trace-event JSON (Perfetto-loadable), Prometheus text
//! exposition, and an optional stdlib `TcpListener` `/metrics` endpoint.
//!
//! The trace writer emits the Chrome `traceEvents` array format — open the
//! file at <https://ui.perfetto.dev> (or `chrome://tracing`) to get a
//! per-thread flame view of a run.  Spans become `ph:"X"` complete events
//! and lifecycle markers become `ph:"i"` thread-scoped instants; span ids
//! and parent links ride in `args` so the hierarchy survives even where
//! the viewer only nests by time.  Serialization goes through
//! [`crate::util::json`], whose `BTreeMap` objects give stable field
//! ordering — the golden test below pins the exact bytes.
//!
//! The Prometheus writer emits text exposition 0.0.4: counters as
//! `_total`, histograms as cumulative `_bucket{le=...}` series plus
//! `_sum`/`_count`.  Registry keys are dotted (`kernel.gemm.flops`) with
//! optional `{label="v"}` suffixes passed through; dots sanitize to
//! underscores and everything gets an `nsvd_` prefix.

use super::metrics::Registry;
use super::trace::{ArgValue, TraceEvent};
use crate::util::json::Json;
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn arg_to_json(v: &ArgValue) -> Json {
    match v {
        ArgValue::U64(n) => Json::Num(*n as f64),
        ArgValue::F64(x) => Json::Num(*x),
        ArgValue::Str(s) => Json::Str(s.clone()),
    }
}

fn event_to_json(ev: &TraceEvent) -> Json {
    let mut args = Json::obj();
    args.set("id", ev.id as f64);
    args.set("parent", ev.parent as f64);
    for (k, v) in &ev.args {
        args.set(k, arg_to_json(v));
    }
    let mut o = Json::obj();
    o.set("args", args)
        .set("cat", ev.cat())
        .set("name", ev.name)
        .set("pid", 1.0)
        .set("tid", ev.tid as f64)
        .set("ts", ev.ts_us as f64);
    if ev.instant {
        o.set("ph", "i").set("s", "t");
    } else {
        o.set("ph", "X").set("dur", ev.dur_us as f64);
    }
    o
}

/// Build the Chrome trace-event document for `events`.  `dropped` (from
/// [`super::trace::dropped_events`]) lands in `metadata` so a truncated
/// trace is self-describing.
pub fn chrome_trace_json(events: &[TraceEvent], dropped: u64) -> Json {
    let mut meta = Json::obj();
    meta.set("dropped_events", dropped as f64).set("tool", "nsvd");
    let mut doc = Json::obj();
    doc.set("displayTimeUnit", "ms")
        .set("metadata", meta)
        .set("traceEvents", Json::Arr(events.iter().map(event_to_json).collect()));
    doc
}

/// Snapshot the recorded trace and write it to `path` as compact Chrome
/// trace JSON.  The `--trace-out` implementation.
pub fn write_chrome_trace(path: &std::path::Path) -> std::io::Result<()> {
    let events = super::trace::snapshot_events();
    let doc = chrome_trace_json(&events, super::trace::dropped_events());
    std::fs::write(path, doc.to_string_compact())
}

/// Sanitize a registry key into a Prometheus metric name: split off any
/// `{label}` suffix, map non-`[a-zA-Z0-9_:]` to `_`, prefix `nsvd_`.
fn prom_name(key: &str) -> (String, Option<&str>) {
    let (base, labels) = match key.find('{') {
        Some(i) => (&key[..i], Some(&key[i..])),
        None => (key, None),
    };
    let mut name = String::with_capacity(base.len() + 5);
    name.push_str("nsvd_");
    for c in base.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            name.push(c);
        } else {
            name.push('_');
        }
    }
    (name, labels)
}

/// Merge an extra `le="..."` label into an optional existing `{...}` set.
fn with_le(labels: Option<&str>, le: &str) -> String {
    match labels {
        Some(l) => {
            // l is "{a=\"b\"}" — splice before the closing brace.
            format!("{},le=\"{}\"}}", &l[..l.len() - 1], le)
        }
        None => format!("{{le=\"{le}\"}}"),
    }
}

/// Render `reg` as Prometheus text exposition (version 0.0.4).  Counters
/// export with a `_total` suffix, histograms as cumulative buckets.
pub fn prometheus_text(reg: &Registry) -> String {
    let mut out = String::new();
    let mut last_type_line = String::new();
    let mut typed = |out: &mut String, name: &str, kind: &str| {
        let line = format!("# TYPE {name} {kind}\n");
        if line != last_type_line {
            out.push_str(&line);
            last_type_line = line;
        }
    };
    for (key, v) in reg.counters() {
        let (name, labels) = prom_name(key);
        let full = format!("{name}_total");
        typed(&mut out, &full, "counter");
        let _ = writeln!(out, "{full}{} {v}", labels.unwrap_or(""));
    }
    for (key, v) in reg.gauges() {
        let (name, labels) = prom_name(key);
        typed(&mut out, &name, "gauge");
        let _ = writeln!(out, "{name}{} {v}", labels.unwrap_or(""));
    }
    for (key, h) in reg.hists() {
        let (name, labels) = prom_name(key);
        typed(&mut out, &name, "histogram");
        for (le, cum) in h.cumulative_buckets() {
            let _ = writeln!(out, "{name}_bucket{} {cum}", with_le(labels, &format!("{le}")));
        }
        let _ = writeln!(out, "{name}_bucket{} {}", with_le(labels, "+Inf"), h.count());
        let _ = writeln!(out, "{name}_sum{} {}", labels.unwrap_or(""), h.sum());
        let _ = writeln!(out, "{name}_count{} {}", labels.unwrap_or(""), h.count());
    }
    out
}

/// Snapshot the metrics registry and write the Prometheus text to `path`
/// — the `--metrics-out` implementation.  `extra` entries REPLACE
/// same-named live entries ([`Registry::replace_from`]), so callers can
/// stamp an exact end-of-run summary (e.g. `GenServerMetrics::to_registry`)
/// over the scheduler's live counters without double counting.
pub fn write_prometheus(path: &std::path::Path, extra: Option<&Registry>) -> std::io::Result<()> {
    let mut reg = super::metrics::snapshot();
    if let Some(e) = extra {
        reg.replace_from(e);
    }
    std::fs::write(path, prometheus_text(&reg))
}

/// A background `/metrics` scrape endpoint on `127.0.0.1:port` (stdlib
/// `TcpListener`, no HTTP library): every connection gets a `200` with the
/// current global registry as Prometheus text.  Serves whatever has been
/// folded into the global registry so far — per-thread buffers of live
/// threads surface on their next fold.  Dropping the endpoint stops the
/// listener thread (it polls a stop flag between nonblocking accepts).
pub struct MetricsEndpoint {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsEndpoint {
    /// Bind and start serving.  `port` 0 picks an ephemeral port (tests);
    /// [`Self::addr`] reports what was bound.
    pub fn start(port: u16) -> std::io::Result<MetricsEndpoint> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("nsvd-metrics".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            let _ = stream.set_nonblocking(false);
                            let _ = stream
                                .set_read_timeout(Some(std::time::Duration::from_millis(250)));
                            // Drain (best-effort) the request head; the
                            // response is the same for every path.
                            let mut buf = [0u8; 1024];
                            let _ = stream.read(&mut buf);
                            let body = prometheus_text(&super::metrics::global_snapshot());
                            let resp = format!(
                                "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                                 Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                                body.len(),
                                body
                            );
                            let _ = stream.write_all(resp.as_bytes());
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(25));
                        }
                        Err(_) => std::thread::sleep(std::time::Duration::from_millis(25)),
                    }
                }
            })?;
        Ok(MetricsEndpoint { addr, stop, handle: Some(handle) })
    }

    /// The bound address (useful when started with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop the listener thread and wait for it.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsEndpoint {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceEvent;

    fn ev(
        name: &'static str,
        ts: u64,
        dur: u64,
        instant: bool,
        id: u64,
        parent: u64,
        args: Vec<(&'static str, ArgValue)>,
    ) -> TraceEvent {
        TraceEvent { name, ts_us: ts, dur_us: dur, instant, tid: 1, id, parent, args }
    }

    #[test]
    fn obs_chrome_trace_golden_bytes() {
        // Field ordering is pinned: util::json objects are BTreeMaps, so
        // keys serialize sorted and the exact bytes below are stable.
        let events = vec![
            ev(
                "engine.compress_model",
                10,
                100,
                false,
                1,
                0,
                vec![("model", ArgValue::Str("tiny".into()))],
            ),
            ev("kernel.gemm", 20, 30, false, 2, 1, vec![("m", ArgValue::U64(8))]),
            ev("serve.request.queued", 25, 0, true, 3, 1, vec![("req", ArgValue::U64(7))]),
        ];
        let doc = chrome_trace_json(&events, 0);
        let expected = concat!(
            r#"{"displayTimeUnit":"ms","metadata":{"dropped_events":0,"tool":"nsvd"},"#,
            r#""traceEvents":["#,
            r#"{"args":{"id":1,"model":"tiny","parent":0},"cat":"engine","dur":100,"#,
            r#""name":"engine.compress_model","ph":"X","pid":1,"tid":1,"ts":10},"#,
            r#"{"args":{"id":2,"m":8,"parent":1},"cat":"kernel","dur":30,"#,
            r#""name":"kernel.gemm","ph":"X","pid":1,"tid":1,"ts":20},"#,
            r#"{"args":{"id":3,"parent":1,"req":7},"cat":"serve","#,
            r#""name":"serve.request.queued","ph":"i","pid":1,"s":"t","tid":1,"ts":25}"#,
            r#"]}"#,
        );
        assert_eq!(doc.to_string_compact(), expected);
        // And it round-trips through our own parser with the parent/child
        // linkage intact.
        let back = crate::util::json::parse(&doc.to_string_compact()).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 3);
        let child = &evs[1];
        assert_eq!(child.get("args").unwrap().get("parent").unwrap().as_f64(), Some(1.0));
        assert_eq!(child.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[2].get("ph").unwrap().as_str(), Some("i"));
    }

    #[test]
    fn obs_prometheus_text_format() {
        let mut reg = Registry::new();
        reg.counter_add("kernel.gemm.flops", 1024);
        reg.counter_add("serve.requests.completed", 3);
        reg.counter_add("serve.tenant.requests{tenant=\"1\"}", 2);
        reg.counter_add("serve.tenant.requests{tenant=\"2\"}", 1);
        reg.gauge_set("serve.pool.occupancy", 0.75);
        reg.observe("serve.step_seconds", 0.5);
        reg.observe("serve.step_seconds", 2.0);
        let text = prometheus_text(&reg);
        assert!(text.contains("# TYPE nsvd_kernel_gemm_flops_total counter\n"));
        assert!(text.contains("nsvd_kernel_gemm_flops_total 1024\n"));
        assert!(text.contains("nsvd_serve_tenant_requests_total{tenant=\"1\"} 2\n"));
        assert!(text.contains("nsvd_serve_tenant_requests_total{tenant=\"2\"} 1\n"));
        // One TYPE line for the labeled family, not one per label set.
        assert_eq!(text.matches("# TYPE nsvd_serve_tenant_requests_total").count(), 1);
        assert!(text.contains("# TYPE nsvd_serve_pool_occupancy gauge\n"));
        assert!(text.contains("nsvd_serve_pool_occupancy 0.75\n"));
        assert!(text.contains("# TYPE nsvd_serve_step_seconds histogram\n"));
        assert!(text.contains("nsvd_serve_step_seconds_bucket{le=\"0.5\"} 1\n"));
        assert!(text.contains("nsvd_serve_step_seconds_bucket{le=\"2\"} 2\n"));
        assert!(text.contains("nsvd_serve_step_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("nsvd_serve_step_seconds_sum 2.5\n"));
        assert!(text.contains("nsvd_serve_step_seconds_count 2\n"));
    }

    #[test]
    fn obs_metrics_endpoint_serves_scrapes() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        crate::obs::reset();
        crate::obs::metrics::counter_add("kernel.gemm.flops", 42);
        let _ = crate::obs::metrics::snapshot(); // fold into the global copy
        crate::obs::set_enabled(false);
        let mut ep = MetricsEndpoint::start(0).expect("bind ephemeral port");
        let mut conn = std::net::TcpStream::connect(ep.addr()).expect("connect");
        conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).expect("read response");
        ep.stop();
        crate::obs::reset();
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "got: {resp}");
        assert!(resp.contains("nsvd_kernel_gemm_flops_total 42"), "got: {resp}");
    }

    /// End-to-end trace-export smoke (ci gate 4j greps for `trace_export`):
    /// build synthetic factors under an `engine.` span, serve a tiny batch
    /// through the real generation server, export, and check the document
    /// round-trips through `util::json` with spans from all three layers.
    #[test]
    fn obs_trace_export_end_to_end_smoke() {
        use crate::model::generate::SampleConfig;
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        crate::obs::reset();
        let (cfg, w) = crate::bench::tiny_model("llama-t", 7);
        let cm = {
            let mut sp = crate::obs::span("engine.build_factors");
            sp.arg_str("kind", "synthetic");
            crate::bench::synthetic_nsvd(&cfg, 0.5, 0.5, 11)
        };
        let gen = crate::serve::GenConfig {
            max_batch: 2,
            pages: 16,
            page_size: 4,
            prefill_chunk: 4,
            workers: 1,
            ..crate::serve::GenConfig::default()
        };
        let reqs: Vec<(Vec<u8>, usize, SampleConfig)> = (0..2)
            .map(|i| {
                (
                    vec![1 + i as u8, 2, 3],
                    3,
                    SampleConfig { temperature: 0.8, top_k: 8, seed: i as u64 },
                )
            })
            .collect();
        let (outs, _m) = crate::bench::drive_preloaded(&cfg, &w, &cm, &gen, reqs);
        assert_eq!(outs.len(), 2);
        let events = crate::obs::trace::snapshot_events();
        let doc = chrome_trace_json(&events, crate::obs::trace::dropped_events());
        crate::obs::set_enabled(false);
        crate::obs::reset();
        let text = doc.to_string_compact();
        let back = crate::util::json::parse(&text).expect("trace JSON parses");
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty());
        for cat in ["engine", "kernel", "serve"] {
            assert!(
                evs.iter().any(|e| e.get("cat").and_then(|c| c.as_str()) == Some(cat)),
                "no {cat} spans in the exported trace"
            );
        }
    }
}
