//! Zero-dependency observability: tracing spans, a metrics registry, and
//! profile export — threaded through every hot path of the compression and
//! serving stacks.
//!
//! Three pillars:
//!
//! * [`trace`] — lightweight spans ([`span`]`("engine.decompose_layer")`
//!   returns a guard that records start/stop on a lock-free per-thread
//!   ring) and instant events ([`instant`]), with parent linkage carried
//!   across the scoped spawns of [`crate::util::threads`] via
//!   [`current_context`] / [`adopt_context`].
//! * [`metrics`] — a typed [`Registry`] of counters, gauges, and
//!   log-bucketed [`Histogram`]s, mergeable across threads: hot-path
//!   updates buffer in a per-thread registry that folds into the global
//!   one when the thread exits (or at [`metrics::snapshot`]).
//! * [`export`] — a Chrome trace-event JSON writer (Perfetto-loadable,
//!   built on [`crate::util::json`]), a Prometheus text-exposition dump,
//!   and an optional stdlib-`TcpListener` `/metrics` scrape endpoint.
//!
//! **Overhead contract.**  Recording is DISABLED by default and gated on
//! one relaxed atomic load: every instrumentation site starts with
//! `if !obs::enabled() { return no-op }`, so a disabled span is a single
//! predictable branch and no allocation, no clock read, no lock.  The
//! parity/fuzz suites pass bit-identically with recording on and off
//! (instrumentation only wraps timing and metadata around the existing
//! float paths — it never reorders an operation), pinned by
//! `serve_obs_on_off_bit_identity_quick` in the serve fuzz battery and the
//! overhead smoke below.
//!
//! Span taxonomy (the `cat` a span exports under is its name's prefix):
//!
//! | prefix     | recorded where                                         |
//! |------------|--------------------------------------------------------|
//! | `engine.`  | per-layer whiten / profile / decompose / α-tune jobs   |
//! | `kernel.`  | GEMM / SYRK / QR / Jacobi entry points (dims, flops)   |
//! | `calib.`   | calibration collection and Gram finalize               |
//! | `eval.`    | perplexity evaluation batches                          |
//! | `serve.`   | scheduler steps, phases, request lifecycle events      |
//! | `pipeline.`| coordinator stages (calibrate / compress / evaluate)   |

pub mod export;
pub mod metrics;
pub mod trace;

pub use metrics::{Histogram, Registry};
pub use trace::{
    adopt_context, current_context, instant, span, ArgValue, Context, ContextGuard, Span,
    TraceEvent,
};

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is recording on?  One relaxed atomic load — THE disabled-path cost of
/// every instrumentation site.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on or off.  Enabling stamps the shared monotonic epoch
/// ([`crate::util::timer::epoch`]) so the first span does not pay the
/// one-time `OnceLock` initialization inside a measured region.
pub fn set_enabled(on: bool) {
    if on {
        crate::util::timer::epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Drop everything recorded so far: the calling thread's trace ring and
/// metric buffer, the global sinks, and the drop counters.  Buffers of
/// OTHER live threads are untouched (they fold in when those threads
/// exit); call between runs on the thread that owns the workload, after
/// its scoped workers have joined.
pub fn reset() {
    trace::clear();
    metrics::clear();
}

#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    // Tests that toggle the global ENABLED flag serialize on this lock so
    // a concurrently running disabled-path assertion never races a test
    // that just turned recording on.  Poisoning is ignored on purpose — a
    // panicked obs test must not cascade into every other obs test.
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    match LOCK.lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_disabled_by_default_and_toggleable() {
        let _l = test_lock();
        assert!(!enabled(), "recording must be off by default");
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
    }

    #[test]
    fn obs_disabled_span_overhead_smoke() {
        // The perf smoke of the overhead contract: a disabled span is one
        // relaxed load + a no-op guard.  The bound is deliberately loose
        // (1 µs/call averaged over 100k calls — two orders of magnitude
        // above reality) so a loaded CI box never flakes, while an
        // accidental lock or allocation on the disabled path still fails.
        let _l = test_lock();
        set_enabled(false);
        reset();
        let n = 100_000u64;
        let t = crate::util::Timer::start();
        for i in 0..n {
            let mut sp = span("kernel.gemm");
            if sp.is_recording() {
                sp.arg_u64("i", i);
            }
            metrics::counter_add("kernel.gemm.flops", i);
        }
        let per_call_us = t.elapsed_s() * 1e6 / n as f64;
        assert!(
            per_call_us < 1.0,
            "disabled span overhead {per_call_us:.3} µs/call — the no-op path regressed"
        );
        assert!(trace::snapshot_events().is_empty(), "disabled spans must record nothing");
    }
}
