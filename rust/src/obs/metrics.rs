//! The typed metrics registry: counters, gauges, and log-bucketed
//! mergeable histograms.
//!
//! A [`Registry`] is a value — [`Registry::merge`] folds one into another,
//! and merging is associative and commutative (counter adds, gauge
//! last-write-wins with the right operand winning, histogram bucket adds;
//! histogram `sum` is an f64 accumulation, associative to rounding).  The
//! process keeps one global registry plus a buffered per-thread registry
//! for hot-path updates ([`counter_add`] / [`observe`]): threads fold
//! their buffer into the global one when they exit, and [`snapshot`]
//! folds the calling thread's buffer and returns a copy of the global
//! state.  Gauges ([`gauge_set`]) write straight to the global registry —
//! they are low-frequency and last-write-wins buffering across threads
//! would be ill-defined.
//!
//! All update entry points are gated on [`super::enabled`]: disabled, each
//! is one relaxed atomic load.
//!
//! Key convention: dotted lowercase (`kernel.gemm.flops`), with optional
//! Prometheus-style labels appended verbatim (`serve.tenant.requests{tenant="3"}`)
//! which the exporter passes through.

use crate::util::timer::Stats;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Number of log-spaced histogram buckets.
pub const HIST_BUCKETS: usize = 64;

/// A log-bucketed histogram: bucket `i` counts observations `v` with
/// `v <= 2^(i-31)` (and above the previous bound), covering `~5e-10` to
/// `~4e9` — microseconds to hours when observing seconds.  Merging adds
/// bucket counts, so per-thread histograms fold losslessly; quantiles are
/// upper-bounded by the matched bucket's bound and clamped to the exact
/// observed `[min, max]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            counts: vec![0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// Upper bound of bucket `i`: `2^(i-31)`.
fn bucket_bound(i: usize) -> f64 {
    (i as f64 - 31.0).exp2()
}

fn bucket_index(v: f64) -> usize {
    if !(v > 0.0) {
        return 0;
    }
    // Smallest i with v <= 2^(i-31): ceil(log2 v) + 31.
    (v.log2().ceil() as i64 + 31).clamp(0, HIST_BUCKETS as i64 - 1) as usize
}

impl Histogram {
    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold `other` into `self` (bucket-wise add).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count > 0 {
            self.sum / self.count as f64
        } else {
            0.0
        }
    }

    /// Nearest-rank quantile over the buckets: the bound of the bucket
    /// holding the `ceil(q·count)`-th observation, clamped to the observed
    /// `[min, max]` so small samples do not report a wildly padded bound.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((self.count as f64 * q).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_bound(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Cumulative `(upper_bound, count)` pairs for non-empty prefixes —
    /// the Prometheus `_bucket{le=...}` series (trailing all-zero buckets
    /// collapsed into the final `+Inf`, which the exporter adds).
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if c > 0 {
                out.push((bucket_bound(i), cum));
            }
        }
        out
    }

    /// Summary [`Stats`] over the histogram (percentiles at bucket
    /// resolution) — the one display shape every latency table uses.
    pub fn stats(&self) -> Stats {
        if self.count == 0 {
            return Stats::default();
        }
        let mean = self.mean();
        let var = (self.sum_sq / self.count as f64 - mean * mean).max(0.0);
        Stats {
            n: self.count as usize,
            mean,
            std: var.sqrt(),
            min: self.min,
            max: self.max,
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
        }
    }
}

/// A typed registry of named counters, gauges, and histograms.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add `v` to counter `name` (created at 0).
    pub fn counter_add(&mut self, name: &str, v: u64) {
        match self.counters.get_mut(name) {
            Some(c) => *c += v,
            None => {
                self.counters.insert(name.to_string(), v);
            }
        }
    }

    /// Set gauge `name` to `v` (last write wins).
    pub fn gauge_set(&mut self, name: &str, v: f64) {
        match self.gauges.get_mut(name) {
            Some(g) => *g = v,
            None => {
                self.gauges.insert(name.to_string(), v);
            }
        }
    }

    /// Record `v` into histogram `name`.
    pub fn observe(&mut self, name: &str, v: f64) {
        match self.hists.get_mut(name) {
            Some(h) => h.observe(v),
            None => {
                let mut h = Histogram::default();
                h.observe(v);
                self.hists.insert(name.to_string(), h);
            }
        }
    }

    /// Fold `other` into `self`: counters add, gauges take `other`'s value
    /// (right-biased last-write-wins), histograms merge bucket-wise.
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            self.counter_add(k, *v);
        }
        for (k, v) in &other.gauges {
            self.gauge_set(k, *v);
        }
        for (k, h) in &other.hists {
            match self.hists.get_mut(k) {
                Some(mine) => mine.merge(h),
                None => {
                    self.hists.insert(k.clone(), h.clone());
                }
            }
        }
    }

    /// Right-biased overwrite: every entry of `other` replaces any entry
    /// of the same name here — counters and histograms included, unlike
    /// [`Registry::merge`], which adds/folds.  Used to stamp a final,
    /// exact summary (e.g. `GenServerMetrics::to_registry`) over the live
    /// approximations collected under the same canonical names.
    pub fn replace_from(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            self.counters.insert(k.clone(), *v);
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.hists {
            self.hists.insert(k.clone(), h.clone());
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// Iterate counters in key order (the exporter's traversal).
    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Iterate gauges in key order.
    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Iterate histograms in key order.
    pub fn hists(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.hists.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }
}

fn global() -> &'static Mutex<Registry> {
    static GLOBAL: std::sync::OnceLock<Mutex<Registry>> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(|| Mutex::new(Registry::default()))
}

fn lock_global() -> std::sync::MutexGuard<'static, Registry> {
    match global().lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    }
}

/// Per-thread buffered registry; folds into the global one on thread exit.
struct LocalReg {
    reg: Registry,
}

impl Drop for LocalReg {
    fn drop(&mut self) {
        if !self.reg.is_empty() {
            lock_global().merge(&self.reg);
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalReg> = RefCell::new(LocalReg { reg: Registry::default() });
}

/// Hot-path counter bump (buffers in the thread-local registry).  One
/// relaxed atomic load when recording is disabled.
#[inline]
pub fn counter_add(name: &str, v: u64) {
    if !super::enabled() {
        return;
    }
    LOCAL.with(|l| l.borrow_mut().reg.counter_add(name, v));
}

/// Hot-path histogram observation (thread-local buffer).
#[inline]
pub fn observe(name: &str, v: f64) {
    if !super::enabled() {
        return;
    }
    LOCAL.with(|l| l.borrow_mut().reg.observe(name, v));
}

/// Gauge write — straight to the global registry (low-frequency;
/// last-write-wins needs one authoritative copy).
#[inline]
pub fn gauge_set(name: &str, v: f64) {
    if !super::enabled() {
        return;
    }
    lock_global().gauge_set(name, v);
}

/// Fold the calling thread's buffer into the global registry and return a
/// copy of the global state.  Buffers of other live threads fold in when
/// those threads exit (scoped workers already have by the time their
/// fan-out returns).
pub fn snapshot() -> Registry {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        if !l.reg.is_empty() {
            let drained = std::mem::take(&mut l.reg);
            lock_global().merge(&drained);
        }
    });
    lock_global().clone()
}

/// Copy of the global registry WITHOUT touching any thread-local buffer —
/// safe to call from the `/metrics` endpoint thread.
pub fn global_snapshot() -> Registry {
    lock_global().clone()
}

/// Drop the calling thread's buffer and the global registry.
pub fn clear() {
    LOCAL.with(|l| l.borrow_mut().reg = Registry::default());
    *lock_global() = Registry::default();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn obs_histogram_quantiles_and_stats() {
        let mut h = Histogram::default();
        for i in 1..=100u32 {
            h.observe(i as f64 / 1000.0); // 1ms..100ms
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-12);
        let s = h.stats();
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 0.001);
        assert_eq!(s.max, 0.1);
        // Bucket-resolution percentiles are upper bounds within a 2x
        // bracket of the exact value, clamped to [min, max].
        assert!(s.p50 >= 0.050 && s.p50 <= 0.1, "p50 {}", s.p50);
        assert!(s.p99 >= 0.099 && s.p99 <= 0.1, "p99 {}", s.p99);
        assert!(h.quantile(0.0) >= h.min());
        // Non-finite and non-positive observations are safe.
        h.observe(f64::NAN);
        h.observe(0.0);
        assert_eq!(h.count(), 101);
    }

    impl Histogram {
        fn min(&self) -> f64 {
            self.min
        }
    }

    #[test]
    fn obs_histogram_cumulative_buckets_are_monotone() {
        let mut h = Histogram::default();
        for v in [0.5, 1.0, 2.0, 2.0, 1000.0] {
            h.observe(v);
        }
        let buckets = h.cumulative_buckets();
        assert!(!buckets.is_empty());
        for w in buckets.windows(2) {
            assert!(w[0].0 < w[1].0, "bounds ascend");
            assert!(w[0].1 <= w[1].1, "counts are cumulative");
        }
        assert_eq!(buckets.last().unwrap().1, 5);
    }

    fn random_registry(rng: &mut Rng) -> Registry {
        let mut r = Registry::new();
        let names = ["kernel.gemm.flops", "serve.steps", "serve.shed"];
        for _ in 0..rng.below(6) {
            r.counter_add(names[rng.below(names.len())], rng.below(1000) as u64);
        }
        for _ in 0..rng.below(4) {
            r.gauge_set(
                ["serve.pool.occupancy", "serve.queue.depth"][rng.below(2)],
                rng.below(100) as f64 / 4.0,
            );
        }
        for _ in 0..rng.below(12) {
            // Exactly-representable values (k/256 with k < 2^20) keep the
            // f64 sums exact, so merge order cannot perturb them and the
            // associativity check below is an exact equality.
            r.observe(
                ["serve.latency_seconds", "serve.step_seconds"][rng.below(2)],
                rng.below(1 << 20) as f64 / 256.0,
            );
        }
        r
    }

    #[test]
    fn obs_registry_merge_is_associative_property() {
        let mut rng = Rng::new(0x0B5_0B5);
        for _ in 0..200 {
            let a = random_registry(&mut rng);
            let b = random_registry(&mut rng);
            let c = random_registry(&mut rng);
            // (a ⊕ b) ⊕ c
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            // a ⊕ (b ⊕ c)
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            assert_eq!(left, right, "merge must be associative");
        }
        // Identity: merging an empty registry changes nothing.
        let a = random_registry(&mut rng);
        let mut withid = a.clone();
        withid.merge(&Registry::new());
        assert_eq!(withid, a);
    }

    #[test]
    fn obs_registry_thread_buffers_fold_into_snapshot() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        clear();
        std::thread::scope(|scope| {
            for _ in 0..3 {
                scope.spawn(|| {
                    counter_add("kernel.gemm.flops", 100);
                    observe("serve.step_seconds", 0.25);
                });
            }
        });
        counter_add("kernel.gemm.flops", 1);
        gauge_set("serve.pool.occupancy", 0.5);
        crate::obs::set_enabled(false);
        let snap = snapshot();
        clear();
        assert_eq!(snap.counter("kernel.gemm.flops"), 301);
        assert_eq!(snap.gauge("serve.pool.occupancy"), Some(0.5));
        assert_eq!(snap.hist("serve.step_seconds").unwrap().count(), 3);
    }

    #[test]
    fn obs_registry_updates_are_noops_when_disabled() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(false);
        clear();
        counter_add("kernel.gemm.flops", 7);
        gauge_set("serve.pool.occupancy", 0.9);
        observe("serve.latency_seconds", 1.0);
        assert!(snapshot().is_empty());
    }
}
