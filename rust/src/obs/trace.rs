//! Span recording: per-thread rings, parent linkage, global collection.
//!
//! Every thread that records owns a private ring of finished events —
//! pushing is lock-free (a `thread_local` `RefCell`, no atomics beyond the
//! [`super::enabled`] gate and the span-id counter).  When a thread exits
//! (scoped workers join at the end of their fan-out) its ring folds into
//! the global sink under one short lock; [`snapshot_events`] folds the
//! calling thread's ring the same way and returns the merged, time-sorted
//! event list.
//!
//! Parent linkage: each thread keeps a stack of open span ids — a new span
//! parents under the top of the stack.  Crossing a scoped spawn, the
//! spawner captures [`current_context`] and the worker installs it with
//! [`adopt_context`]; an adopted parent seeds the worker's otherwise-empty
//! stack, so `engine.decompose_layer` spans on worker threads still nest
//! under the `engine.compress_model` span of the caller.
//!
//! Rings are bounded ([`THREAD_RING_CAP`] events per thread, overwriting
//! the oldest; [`GLOBAL_CAP`] events in the merged sink, dropping beyond)
//! so tracing a long serve run holds bounded memory; [`dropped_events`]
//! counts what was lost.

use crate::util::timer::monotonic_us;
use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Per-thread finished-event ring capacity (oldest overwritten beyond it).
pub const THREAD_RING_CAP: usize = 1 << 16;

/// Global merged-sink capacity (events beyond it are counted, not kept).
pub const GLOBAL_CAP: usize = 1 << 20;

/// One typed span/event argument (kept out of `String` unless it is one,
/// so recording an integer arg never allocates).
#[derive(Clone, Debug, PartialEq)]
pub enum ArgValue {
    U64(u64),
    F64(f64),
    Str(String),
}

/// One finished trace event: a span (`dur_us` wall-clock) or an instant
/// marker (`instant == true`, `dur_us == 0`).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Dotted name; the prefix before the first `.` is the export category
    /// (`engine.decompose_layer` → cat `engine`).
    pub name: &'static str,
    /// Start, microseconds since the process epoch.
    pub ts_us: u64,
    /// Span duration in microseconds (0 for instants).
    pub dur_us: u64,
    /// Instant marker instead of a duration span?
    pub instant: bool,
    /// Small per-process thread id (assigned on a thread's first record).
    pub tid: u64,
    /// Process-unique span id (instants get one too).
    pub id: u64,
    /// Id of the enclosing span, possibly on another thread; 0 = root.
    pub parent: u64,
    /// Typed arguments (dims, flops, request ids, …).
    pub args: Vec<(&'static str, ArgValue)>,
}

impl TraceEvent {
    /// Export category: the name's first dotted segment.
    pub fn cat(&self) -> &'static str {
        match self.name.split_once('.') {
            Some((cat, _)) => cat,
            None => "misc",
        }
    }

    /// Look up an integer argument by key.
    pub fn arg_u64(&self, key: &str) -> Option<u64> {
        self.args.iter().find_map(|(k, v)| match v {
            ArgValue::U64(n) if *k == key => Some(*n),
            _ => None,
        })
    }

    /// Look up a string argument by key.
    pub fn arg_str(&self, key: &str) -> Option<&str> {
        self.args.iter().find_map(|(k, v)| match v {
            ArgValue::Str(s) if *k == key => Some(s.as_str()),
            _ => None,
        })
    }
}

static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

struct GlobalSink {
    events: Vec<TraceEvent>,
    dropped: u64,
}

fn global() -> &'static Mutex<GlobalSink> {
    static GLOBAL: std::sync::OnceLock<Mutex<GlobalSink>> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(|| Mutex::new(GlobalSink { events: Vec::new(), dropped: 0 }))
}

/// The calling thread's ring.  Dropping it (thread exit) folds the ring
/// into the global sink, so scoped workers publish automatically.
struct ThreadSink {
    tid: u64,
    ring: Vec<TraceEvent>,
    pushed: usize,
    dropped: u64,
}

impl ThreadSink {
    fn new() -> ThreadSink {
        ThreadSink {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            ring: Vec::new(),
            pushed: 0,
            dropped: 0,
        }
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.ring.len() < THREAD_RING_CAP {
            self.ring.push(ev);
        } else {
            self.ring[self.pushed % THREAD_RING_CAP] = ev;
            self.dropped += 1;
        }
        self.pushed += 1;
    }

    fn flush_into_global(&mut self) {
        if self.ring.is_empty() && self.dropped == 0 {
            return;
        }
        let mut g = match global().lock() {
            Ok(g) => g,
            Err(e) => e.into_inner(),
        };
        g.dropped += self.dropped;
        self.dropped = 0;
        for ev in self.ring.drain(..) {
            if g.events.len() < GLOBAL_CAP {
                g.events.push(ev);
            } else {
                g.dropped += 1;
            }
        }
        self.pushed = 0;
    }
}

impl Drop for ThreadSink {
    fn drop(&mut self) {
        self.flush_into_global();
    }
}

thread_local! {
    static SINK: RefCell<ThreadSink> = RefCell::new(ThreadSink::new());
    /// Ids of this thread's open spans, innermost last.
    static STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
    /// Parent adopted from another thread ([`adopt_context`]); seeds the
    /// stack-empty case so cross-thread children still nest.
    static ADOPTED: Cell<u64> = const { Cell::new(0) };
}

fn current_parent() -> u64 {
    STACK
        .with(|s| s.borrow().last().copied())
        .unwrap_or_else(|| ADOPTED.with(|a| a.get()))
}

/// A recording guard: created by [`span`] / [`instant`], records its event
/// on drop.  When recording is disabled the guard is an inert `None` and
/// every method is a no-op on an already-taken branch.
pub struct Span {
    rec: Option<SpanRec>,
}

struct SpanRec {
    name: &'static str,
    id: u64,
    parent: u64,
    ts_us: u64,
    instant: bool,
    args: Vec<(&'static str, ArgValue)>,
}

impl Span {
    /// Is this guard actually recording?  Gate argument formatting on it
    /// so the disabled path never allocates.
    #[inline(always)]
    pub fn is_recording(&self) -> bool {
        self.rec.is_some()
    }

    /// This span's id (0 when not recording) — what children reference.
    pub fn id(&self) -> u64 {
        self.rec.as_ref().map_or(0, |r| r.id)
    }

    /// Attach an integer argument.
    #[inline]
    pub fn arg_u64(&mut self, key: &'static str, v: u64) -> &mut Span {
        if let Some(r) = &mut self.rec {
            r.args.push((key, ArgValue::U64(v)));
        }
        self
    }

    /// Attach a float argument.
    #[inline]
    pub fn arg_f64(&mut self, key: &'static str, v: f64) -> &mut Span {
        if let Some(r) = &mut self.rec {
            r.args.push((key, ArgValue::F64(v)));
        }
        self
    }

    /// Attach a string argument (allocates only while recording).
    #[inline]
    pub fn arg_str(&mut self, key: &'static str, v: &str) -> &mut Span {
        if let Some(r) = &mut self.rec {
            r.args.push((key, ArgValue::Str(v.to_string())));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(rec) = self.rec.take() else { return };
        if !rec.instant {
            STACK.with(|s| {
                let mut s = s.borrow_mut();
                // Well-formed guards drop LIFO; a mem::forget'ed sibling
                // would desync the top, so remove by id to stay robust.
                if let Some(pos) = s.iter().rposition(|&id| id == rec.id) {
                    s.remove(pos);
                }
            });
        }
        let now = monotonic_us();
        let ev = TraceEvent {
            name: rec.name,
            ts_us: rec.ts_us,
            dur_us: if rec.instant { 0 } else { now.saturating_sub(rec.ts_us) },
            instant: rec.instant,
            tid: SINK.with(|s| s.borrow().tid),
            id: rec.id,
            parent: rec.parent,
            args: rec.args,
        };
        SINK.with(|s| s.borrow_mut().push(ev));
    }
}

fn open(name: &'static str, instant: bool) -> Span {
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    let parent = current_parent();
    if !instant {
        STACK.with(|s| s.borrow_mut().push(id));
    }
    Span {
        rec: Some(SpanRec { name, id, parent, ts_us: monotonic_us(), instant, args: Vec::new() }),
    }
}

/// Open a span named `name` (dotted; prefix = export category).  Disabled
/// recording costs one relaxed atomic load and returns an inert guard.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !super::enabled() {
        return Span { rec: None };
    }
    open(name, false)
}

/// Record an instant event (request lifecycle markers and the like).  The
/// guard records on drop, so attach args before letting it go.
#[inline]
pub fn instant(name: &'static str) -> Span {
    if !super::enabled() {
        return Span { rec: None };
    }
    open(name, true)
}

/// A capture of "what span is the caller inside" — hand it to a spawned
/// worker so its spans parent correctly across the thread boundary.
#[derive(Clone, Copy, Debug, Default)]
pub struct Context {
    parent: u64,
}

/// Capture the calling thread's innermost open span (or its own adopted
/// parent) for propagation into a spawn.
#[inline]
pub fn current_context() -> Context {
    if !super::enabled() {
        return Context { parent: 0 };
    }
    Context { parent: current_parent() }
}

/// Guard restoring the previously adopted parent on drop.
pub struct ContextGuard {
    prev: u64,
}

/// Install `ctx` as the calling thread's fallback parent for the guard's
/// lifetime.  Cheap enough to run unconditionally at spawn sites (one
/// thread-local cell swap — no atomics, no allocation).
#[inline]
pub fn adopt_context(ctx: Context) -> ContextGuard {
    let prev = ADOPTED.with(|a| a.replace(ctx.parent));
    ContextGuard { prev }
}

impl Drop for ContextGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        ADOPTED.with(|a| a.set(prev));
    }
}

/// Fold the calling thread's ring into the global sink and return every
/// collected event, sorted by `(ts_us, id)`.  Events recorded by OTHER
/// still-running threads surface only after those threads exit.
pub fn snapshot_events() -> Vec<TraceEvent> {
    SINK.with(|s| s.borrow_mut().flush_into_global());
    let mut g = match global().lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    };
    let mut events = g.events.clone();
    drop(g);
    events.sort_by_key(|e| (e.ts_us, e.id));
    events
}

/// Events lost to ring/sink caps so far (flushed threads only).
pub fn dropped_events() -> u64 {
    match global().lock() {
        Ok(g) => g.dropped,
        Err(e) => e.into_inner().dropped,
    }
}

/// Drop the calling thread's ring and the global sink (see
/// [`super::reset`] for the caveats about other live threads).
pub fn clear() {
    SINK.with(|s| {
        let mut s = s.borrow_mut();
        s.ring.clear();
        s.pushed = 0;
        s.dropped = 0;
    });
    let mut g = match global().lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    };
    g.events.clear();
    g.dropped = 0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_span_records_nesting_and_args() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        clear();
        {
            let mut outer = crate::obs::span("engine.compress_model");
            outer.arg_str("model", "tiny");
            {
                let mut inner = crate::obs::span("kernel.gemm");
                inner.arg_u64("m", 8).arg_u64("k", 4).arg_u64("n", 8);
            }
            let mut mark = crate::obs::instant("serve.request.queued");
            mark.arg_u64("req", 7);
        }
        crate::obs::set_enabled(false);
        let evs = snapshot_events();
        clear();
        assert_eq!(evs.len(), 3);
        let outer = evs.iter().find(|e| e.name == "engine.compress_model").unwrap();
        let inner = evs.iter().find(|e| e.name == "kernel.gemm").unwrap();
        let mark = evs.iter().find(|e| e.name == "serve.request.queued").unwrap();
        assert_eq!(outer.parent, 0);
        assert_eq!(inner.parent, outer.id, "inner span must parent under the open outer");
        assert_eq!(mark.parent, outer.id, "instants parent under the open span too");
        assert!(mark.instant && mark.dur_us == 0);
        assert_eq!(outer.cat(), "engine");
        assert_eq!(inner.cat(), "kernel");
        assert_eq!(inner.arg_u64("m"), Some(8));
        assert_eq!(outer.arg_str("model"), Some("tiny"));
        // The child's window nests inside the parent's.
        assert!(inner.ts_us >= outer.ts_us);
        assert!(inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us);
    }

    #[test]
    fn obs_context_carries_parent_across_threads() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        clear();
        let outer_id;
        {
            let outer = crate::obs::span("engine.outer");
            outer_id = outer.id();
            let ctx = current_context();
            std::thread::scope(|scope| {
                scope.spawn(move || {
                    let _adopt = adopt_context(ctx);
                    let _child = crate::obs::span("engine.worker_job");
                });
            });
        }
        crate::obs::set_enabled(false);
        let evs = snapshot_events();
        clear();
        let child = evs.iter().find(|e| e.name == "engine.worker_job").unwrap();
        assert_eq!(child.parent, outer_id, "cross-thread child must adopt the spawner's span");
        let outer = evs.iter().find(|e| e.name == "engine.outer").unwrap();
        assert_ne!(child.tid, outer.tid, "the worker recorded on its own ring");
    }

    #[test]
    fn obs_thread_ring_is_bounded() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(true);
        clear();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                for _ in 0..THREAD_RING_CAP + 10 {
                    let _sp = crate::obs::instant("serve.tick");
                }
            });
        });
        crate::obs::set_enabled(false);
        let evs = snapshot_events();
        let dropped = dropped_events();
        clear();
        let ticks = evs.iter().filter(|e| e.name == "serve.tick").count();
        assert_eq!(ticks, THREAD_RING_CAP, "ring keeps exactly its capacity");
        assert!(dropped >= 10, "overwritten events must be counted, got {dropped}");
    }

    #[test]
    fn obs_disabled_spans_record_nothing() {
        let _l = crate::obs::test_lock();
        crate::obs::set_enabled(false);
        clear();
        {
            let mut sp = crate::obs::span("kernel.gemm");
            assert!(!sp.is_recording());
            assert_eq!(sp.id(), 0);
            sp.arg_u64("m", 3);
            let _mark = crate::obs::instant("serve.request.queued");
        }
        assert!(snapshot_events().is_empty());
    }
}
