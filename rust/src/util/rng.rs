//! Deterministic pseudo-random number generation.
//!
//! `rand` is unavailable offline; this module provides a PCG-XSH-RR 64/32
//! generator seeded through SplitMix64, plus the distributions the rest of
//! the crate needs (uniform ranges, normals via Box–Muller, shuffles,
//! categorical sampling).  Everything is deterministic given the seed, which
//! the experiment harness relies on for reproducible tables.

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit output with rotation.
/// Small, fast, and statistically solid for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// Create a generator from a seed; the stream constant is fixed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1; // must be odd
        let mut rng = Rng { state, inc };
        rng.next_u32(); // advance away from the seed-correlated state
        rng
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random bits scaled into [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift (unbiased
    /// enough for simulation; exact rejection is overkill here).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same <= 1, "streams should not match: {same}");
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio={ratio}");
    }

    #[test]
    fn fork_gives_independent_streams() {
        let mut parent = Rng::new(1234);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same <= 1);
    }
}
