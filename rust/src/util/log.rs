//! Leveled stderr logging with a global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log levels, ordered by verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(2); // default: Info

/// Set the global verbosity (messages above this level are dropped).
pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

/// Current verbosity level.
pub fn level() -> Level {
    match VERBOSITY.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Emit a message at `level` (module-qualified tag recommended).
pub fn log(lvl: Level, tag: &str, msg: &str) {
    if lvl <= level() {
        let prefix = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{prefix}] {tag}: {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $tag, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $tag, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $tag, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }
}
