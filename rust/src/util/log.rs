//! Leveled stderr logging with monotonic-elapsed timestamps and per-tag
//! filtering.
//!
//! Every line carries seconds since the process epoch (the same
//! [`crate::util::timer`] monotonic clock the tracer stamps spans with),
//! so interleaved subsystem logs line up with `--trace-out` timelines.
//! Verbosity is the global level ([`set_level`]) refined by the
//! `NSVD_LOG` environment variable — a comma list of `tag=level` entries
//! plus an optional bare default, e.g. `NSVD_LOG=debug` or
//! `NSVD_LOG=serve=debug,gemm=warn`.  A tag entry matches every tag it
//! prefixes; the longest match wins.

use crate::util::timer;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

/// Log levels, ordered by verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(2); // default: Info

fn parse_level(s: &str) -> Option<Level> {
    match s.trim().to_ascii_lowercase().as_str() {
        "error" | "e" => Some(Level::Error),
        "warn" | "warning" | "w" => Some(Level::Warn),
        "info" | "i" => Some(Level::Info),
        "debug" | "d" => Some(Level::Debug),
        _ => None,
    }
}

/// A parsed `NSVD_LOG` filter: optional default level plus per-tag
/// overrides (checked by prefix, longest match wins).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Filter {
    pub default: Option<Level>,
    pub tags: Vec<(String, Level)>,
}

/// Parse a filter spec: comma-separated `tag=level` entries, bare entries
/// set the default level, malformed entries are ignored.
pub fn parse_spec(spec: &str) -> Filter {
    let mut f = Filter::default();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('=') {
            Some((tag, lvl)) => {
                if let Some(l) = parse_level(lvl) {
                    f.tags.push((tag.trim().to_string(), l));
                }
            }
            None => {
                if let Some(l) = parse_level(part) {
                    f.default = Some(l);
                }
            }
        }
    }
    f
}

fn filter() -> &'static Mutex<Filter> {
    static FILTER: OnceLock<Mutex<Filter>> = OnceLock::new();
    FILTER.get_or_init(|| {
        let spec = std::env::var("NSVD_LOG").unwrap_or_default();
        Mutex::new(parse_spec(&spec))
    })
}

/// Replace the active tag filter (CLI overrides and tests; the initial
/// filter comes from `NSVD_LOG`).
pub fn set_filter(f: Filter) {
    match filter().lock() {
        Ok(mut g) => *g = f,
        Err(e) => *e.into_inner() = f,
    }
}

/// Set the global verbosity (messages above this level are dropped unless
/// an `NSVD_LOG` entry raises their tag).
pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

/// Current global verbosity level.
pub fn level() -> Level {
    match VERBOSITY.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Effective verbosity for `tag`: the longest matching filter entry, else
/// the filter's default, else the global level.
pub fn tag_level(tag: &str) -> Level {
    let f = match filter().lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    };
    let mut best: Option<(usize, Level)> = None;
    for (t, l) in &f.tags {
        if tag.starts_with(t.as_str()) && best.map_or(true, |(n, _)| t.len() >= n) {
            best = Some((t.len(), *l));
        }
    }
    best.map(|(_, l)| l).or(f.default).unwrap_or_else(level)
}

/// Emit a message at `lvl` (module-qualified tag recommended).  Lines
/// carry monotonic seconds since the process epoch.
pub fn log(lvl: Level, tag: &str, msg: &str) {
    if lvl > tag_level(tag) {
        return;
    }
    let prefix = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{:9.3}s {prefix}] {tag}: {msg}", timer::monotonic_s());
}

#[macro_export]
macro_rules! info {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $tag, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $tag, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($tag:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $tag, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }

    #[test]
    fn parse_spec_tags_default_and_garbage() {
        let f = parse_spec("serve=debug, gemm=warn ,warn,nonsense,oops=loud");
        assert_eq!(f.default, Some(Level::Warn));
        assert_eq!(
            f.tags,
            vec![("serve".to_string(), Level::Debug), ("gemm".to_string(), Level::Warn)]
        );
        assert_eq!(parse_spec(""), Filter::default());
    }

    #[test]
    fn tag_filter_overrides_resolve_by_longest_prefix() {
        // One test mutates the global filter end to end (parallel tests
        // would race a split version of this).
        set_filter(parse_spec("serve=debug,serve.step=error,gemm=warn"));
        assert_eq!(tag_level("serve"), Level::Debug);
        assert_eq!(tag_level("serve.batcher"), Level::Debug);
        assert_eq!(tag_level("serve.step"), Level::Error);
        assert_eq!(tag_level("gemm"), Level::Warn);
        // Unmatched tags fall back to the spec default, then the global.
        set_filter(parse_spec("info,serve=debug"));
        assert_eq!(tag_level("scheduler"), Level::Info);
        set_filter(Filter::default());
        assert_eq!(tag_level("scheduler"), level());
    }
}
