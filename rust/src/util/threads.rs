//! Data-parallel helpers over `std::thread` (rayon/tokio unavailable offline).
//!
//! The testbed is single-core, so these helpers degrade gracefully: with one
//! hardware thread the chunked map runs inline with zero spawn overhead.  On
//! multi-core machines the same API fans out over scoped threads.
//!
//! Every spawn site captures the caller's [`crate::obs`] trace context and
//! adopts it on the worker, so spans recorded inside a fan-out nest under
//! the span that was open at the call site — one cell swap per worker,
//! whether or not recording is enabled.

/// Number of worker threads to use for data-parallel sections.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One global thread budget shared between an outer task fan-out and the
/// parallel GEMMs each task runs underneath it.
///
/// Two layers of this system are data-parallel at once: the compression
/// engine fans layer jobs out over workers, and every job's whitening /
/// decomposition math calls the parallel GEMM kernel
/// ([`crate::linalg::gemm`]); likewise the batched evaluator fans
/// `TokenBatch`es out while each forward pass runs parallel f32 GEMMs.
/// Nesting two independent pools would oversubscribe the machine
/// (`outer × gemm` threads); instead both levels split ONE budget:
///
/// ```
/// use nsvd::util::threads::ThreadBudget;
///
/// let budget = ThreadBudget::new(8);
/// let (outer, inner) = budget.split(3); // 3 jobs on 8 threads
/// assert_eq!((outer, inner), (3, 2));   // 3 job workers × 2 GEMM threads ≤ 8
/// ```
///
/// `outer × inner ≤ total` always holds, and every split leaves at least
/// one thread for each level, so a budget of 1 degrades to fully serial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ThreadBudget {
    total: usize,
}

impl ThreadBudget {
    /// A budget of `total` threads; `0` means "all cores"
    /// ([`default_workers`]).
    pub fn new(total: usize) -> ThreadBudget {
        ThreadBudget { total: if total == 0 { default_workers() } else { total } }
    }

    /// Total threads in the budget (≥ 1).
    pub fn total(&self) -> usize {
        self.total
    }

    /// Workers for an outer fan-out over `jobs` items (never more workers
    /// than items).
    pub fn outer(&self, jobs: usize) -> usize {
        self.total.min(jobs.max(1))
    }

    /// Threads left for each nested parallel section when the outer level
    /// uses `outer_workers`.
    pub fn inner(&self, outer_workers: usize) -> usize {
        (self.total / outer_workers.max(1)).max(1)
    }

    /// The `(outer, inner)` split for a fan-out over `jobs` items, with
    /// `outer × inner ≤ total`.
    pub fn split(&self, jobs: usize) -> (usize, usize) {
        let outer = self.outer(jobs);
        (outer, self.inner(outer))
    }
}

/// Split a flat row-major buffer (`data.len() = rows × width`) into
/// contiguous row-aligned chunks, one scoped thread each, and run `f` on
/// every chunk.  Used by the tournament Jacobi solvers to apply a round's
/// disjoint column-pair rotations: each row is transformed independently,
/// so the result is bit-identical for every worker count.  Runs inline when
/// `workers <= 1`.
pub fn parallel_row_chunks<T: Send, F>(data: &mut [T], width: usize, workers: usize, f: F)
where
    F: Fn(&mut [T]) + Sync,
{
    if width == 0 || data.is_empty() {
        return;
    }
    let rows = data.len() / width;
    let workers = workers.max(1).min(rows);
    if workers <= 1 {
        f(data);
        return;
    }
    let rows_per = rows.div_ceil(workers);
    let ctx = crate::obs::current_context();
    std::thread::scope(|scope| {
        for chunk in data.chunks_mut(rows_per * width) {
            let f = &f;
            scope.spawn(move || {
                let _obs = crate::obs::adopt_context(ctx);
                f(chunk)
            });
        }
    });
}

/// Apply `f(index, &mut item)` to every element, splitting the slice across
/// `workers` scoped threads.  Runs inline when `workers <= 1` or the slice is
/// tiny (spawn cost would dominate).
pub fn parallel_for_each<T: Send, F>(items: &mut [T], workers: usize, f: F)
where
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    if workers <= 1 || n < 2 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    let ctx = crate::obs::current_context();
    std::thread::scope(|scope| {
        for (ci, slice) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let _obs = crate::obs::adopt_context(ctx);
                for (j, item) in slice.iter_mut().enumerate() {
                    f(ci * chunk + j, item);
                }
            });
        }
    });
}

/// Parallel map preserving order.
pub fn parallel_map<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    if workers <= 1 || n < 2 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let ctx = crate::obs::current_context();
    std::thread::scope(|scope| {
        for (ci, (in_chunk, out_chunk)) in
            items.chunks(chunk).zip(out.chunks_mut(chunk)).enumerate()
        {
            let f = &f;
            scope.spawn(move || {
                let _obs = crate::obs::adopt_context(ctx);
                for (j, (t, slot)) in in_chunk.iter().zip(out_chunk.iter_mut()).enumerate() {
                    *slot = Some(f(ci * chunk + j, t));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Like [`parallel_map`] but with dynamic (work-stealing) scheduling: each
/// worker repeatedly claims the next unprocessed index from a shared atomic
/// counter.  Use when item costs are heterogeneous or `workers` does not
/// divide the item count — static chunking would idle workers on the tail
/// while one slow shard dominates wall-clock.  The returned order matches
/// `items` regardless of which worker computed what.
pub fn parallel_map_dynamic<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    let workers = workers.min(n);
    if workers <= 1 || n < 2 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let done: std::sync::Mutex<Vec<(usize, U)>> =
        std::sync::Mutex::new(Vec::with_capacity(n));
    let ctx = crate::obs::current_context();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let _obs = crate::obs::adopt_context(ctx);
                let mut local: Vec<(usize, U)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i, &items[i])));
                }
                done.lock().unwrap().extend(local);
            });
        }
    });
    let mut pairs = done.into_inner().unwrap();
    pairs.sort_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, u)| u).collect()
}

/// A minimal multi-producer work queue with a fixed worker pool, used by the
/// coordinator's scheduler.  Jobs are boxed closures; results are delivered
/// through the closure's own channel/handles.
pub struct WorkerPool {
    sender: Option<std::sync::mpsc::Sender<Box<dyn FnOnce() + Send>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = std::sync::mpsc::channel::<Box<dyn FnOnce() + Send>>();
        let rx = std::sync::Arc::new(std::sync::Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = rx.clone();
                std::thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed: shut down
                    }
                })
            })
            .collect();
        WorkerPool { sender: Some(tx), handles }
    }

    /// Submit a job; it runs on some worker thread (adopting the
    /// submitter's trace context, so job spans nest under the caller).
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let ctx = crate::obs::current_context();
        self.sender
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(move || {
                let _obs = crate::obs::adopt_context(ctx);
                job()
            }))
            .expect("worker pool channel closed");
    }

    /// Wait for all submitted jobs to finish and stop the workers.
    pub fn shutdown(mut self) {
        drop(self.sender.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn thread_budget_split_never_oversubscribes() {
        for total in 1..=9usize {
            let budget = ThreadBudget::new(total);
            assert_eq!(budget.total(), total);
            for jobs in 0..=12usize {
                let (outer, inner) = budget.split(jobs);
                assert!(outer >= 1 && inner >= 1);
                assert!(outer * inner <= total.max(1), "total={total} jobs={jobs}");
                assert!(outer <= jobs.max(1));
            }
        }
        // 0 = all cores.
        assert_eq!(ThreadBudget::new(0).total(), default_workers());
        // Serial budget degrades to (1, 1).
        assert_eq!(ThreadBudget::new(1).split(64), (1, 1));
    }

    #[test]
    fn parallel_row_chunks_covers_all_rows() {
        // 13 rows × 5 cols, 4 workers (non-divisor): every row transformed
        // exactly once, matching the inline (workers = 1) result.
        let width = 5usize;
        let rows = 13usize;
        let base: Vec<f64> = (0..rows * width).map(|i| i as f64).collect();
        let bump = |chunk: &mut [f64]| {
            for row in chunk.chunks_mut(width) {
                for v in row.iter_mut() {
                    *v = 2.0 * *v + 1.0;
                }
            }
        };
        let mut serial = base.clone();
        parallel_row_chunks(&mut serial, width, 1, bump);
        let mut parallel = base.clone();
        parallel_row_chunks(&mut parallel, width, 4, bump);
        assert_eq!(serial, parallel);
        assert_eq!(serial[0], 1.0);
        assert_eq!(serial[rows * width - 1], 2.0 * (rows * width - 1) as f64 + 1.0);
    }

    #[test]
    fn parallel_for_each_touches_everything() {
        let mut xs: Vec<usize> = vec![0; 103];
        parallel_for_each(&mut xs, 4, |i, x| *x = i * 2);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(*x, i * 2);
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<usize> = (0..57).collect();
        let ys = parallel_map(&xs, 3, |_, &x| x * x);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, i * i);
        }
    }

    #[test]
    fn dynamic_map_preserves_order_with_uneven_costs() {
        // 29 items, 4 workers (not a divisor), wildly uneven per-item cost.
        let xs: Vec<usize> = (0..29).collect();
        let ys = parallel_map_dynamic(&xs, 4, |i, &x| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x * 3
        });
        assert_eq!(ys.len(), 29);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, i * 3);
        }
    }

    #[test]
    fn dynamic_map_matches_static_map() {
        let xs: Vec<usize> = (0..64).collect();
        let a = parallel_map(&xs, 3, |i, &x| i + x);
        let b = parallel_map_dynamic(&xs, 5, |i, &x| i + x);
        assert_eq!(a, b);
    }

    #[test]
    fn single_worker_runs_inline() {
        let xs: Vec<usize> = (0..5).collect();
        let ys = parallel_map(&xs, 1, |i, &x| i + x);
        assert_eq!(ys, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn worker_pool_executes_all_jobs() {
        let pool = WorkerPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }
}
