//! Minimal JSON value model, parser, and serializer.
//!
//! `serde_json` is unavailable offline.  This module covers what the crate
//! needs: metrics/report emission, config files, and artifact manifests.
//! It is a strict-enough subset of RFC 8259 (no comments, UTF-8 input,
//! `\uXXXX` escapes supported on parse, basic escapes on write).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Objects use `BTreeMap` so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object value; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Integers print without a trailing ".0" for readability.
                    if x.fract() == 0.0 && x.abs() < 9e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs: parse the low half if present.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // pos already advanced past hex digits
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is valid UTF-8 by construction).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            let x = (d as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + x;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "src={src}");
        }
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn builder_and_pretty_print() {
        let mut o = Json::obj();
        o.set("name", "nsvd").set("ratio", 0.3).set("ok", true);
        let s = o.to_string_pretty();
        assert!(s.contains("\"name\": \"nsvd\""));
        let back = parse(&s).unwrap();
        assert_eq!(back.get("ratio").unwrap().as_f64().unwrap(), 0.3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nulll").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn escapes_on_write() {
        let v = Json::Str("a\"b\\c\nd".into());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }
}
