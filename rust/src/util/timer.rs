//! Wall-clock timing and summary statistics.

use std::time::Instant;

/// The process-wide monotonic epoch: the first call stamps `Instant::now()`
/// and every later call returns the same instant.  [`crate::obs`] trace
/// timestamps and [`crate::util::log`] message stamps both measure from it,
/// so log lines and trace spans of one run share a time axis.
pub fn epoch() -> Instant {
    static EPOCH: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds elapsed since [`epoch`] (monotonic, starts near 0).
pub fn monotonic_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Seconds elapsed since [`epoch`] (monotonic, starts near 0).
pub fn monotonic_s() -> f64 {
    epoch().elapsed().as_secs_f64()
}

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Summary statistics over a sample of observations (latencies, errors, …).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Stats {
    /// Compute from a sample.  Percentiles use nearest-rank on sorted data.
    pub fn from(samples: &[f64]) -> Stats {
        if samples.is_empty() {
            return Stats::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| sorted[(((n as f64) * p).ceil() as usize).clamp(1, n) - 1];
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: pct(0.50),
            p90: pct(0.90),
            p95: pct(0.95),
            p99: pct(0.99),
        }
    }

    /// Human-friendly one-liner with a unit suffix.
    pub fn display(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.4}{u} std={:.4}{u} p50={:.4}{u} p90={:.4}{u} p95={:.4}{u} \
             p99={:.4}{u} max={:.4}{u}",
            self.n, self.mean, self.std, self.p50, self.p90, self.p95, self.p99, self.max,
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_sample() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Stats::from(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p90, 90.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
    }

    #[test]
    fn stats_empty_and_singleton() {
        assert_eq!(Stats::from(&[]).n, 0);
        let s = Stats::from(&[3.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p99, 3.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn monotonic_epoch_is_stable_and_advances() {
        let a = monotonic_us();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = monotonic_us();
        assert!(b > a, "monotonic clock went backwards ({a} -> {b})");
        assert_eq!(epoch(), epoch(), "epoch must be stamped exactly once");
    }

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
