//! Declarative command-line parsing (clap is unavailable offline).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! defaults, required arguments, and auto-generated `--help` text.

use std::collections::BTreeMap;

/// Specification of a single flag.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
    pub required: bool,
}

/// A parsed invocation: positionals plus resolved flags.
#[derive(Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    /// Parse a u64 flag (e.g. sampling seeds, request ids).
    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }

    /// Parse a worker-count flag: `auto` (or `0`) means "let the engine use
    /// all cores" and is returned as `0`; anything else must be a positive
    /// integer thread count.  `None` when the flag is absent or malformed.
    pub fn get_workers(&self, name: &str) -> Option<usize> {
        match self.get(name)? {
            "auto" | "0" => Some(0),
            s => s.parse().ok().filter(|&n| n > 0),
        }
    }

    /// Parse a flag that accepts either the literal `auto` or a float
    /// (e.g. `--alpha auto` vs `--alpha 0.95`): `Some(None)` for `auto`,
    /// `Some(Some(v))` for a number, `None` when absent or malformed.
    pub fn get_f64_or_auto(&self, name: &str) -> Option<Option<f64>> {
        match self.get(name)? {
            "auto" => Some(None),
            s => s.parse().ok().map(Some),
        }
    }

    /// Parse a comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|s| {
                s.split(',')
                    .map(|t| t.trim().to_string())
                    .filter(|t| !t.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }
}

/// A command with flags; `Cli` is a tree of these.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, flags: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.flags.push(FlagSpec { name, help, default, is_switch: false, required: false });
        self
    }

    pub fn required_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_switch: false, required: true });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec { name, help, default: None, is_switch: true, required: false });
        self
    }

    /// Parse `argv` (not including the command name itself).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        // Seed defaults.
        for f in &self.flags {
            if let Some(d) = f.default {
                args.flags.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name} for '{}'", self.name))?;
                if spec.is_switch {
                    if inline_val.is_some() {
                        return Err(format!("--{name} is a switch and takes no value"));
                    }
                    args.switches.insert(name, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} expects a value"))?
                        }
                    };
                    args.flags.insert(name, val);
                }
            } else {
                args.positionals.push(tok.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if f.required && !args.flags.contains_key(f.name) {
                return Err(format!("missing required flag --{} for '{}'", f.name, self.name));
            }
        }
        Ok(args)
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nFlags:\n", self.name, self.about);
        for f in &self.flags {
            let kind = if f.is_switch { "" } else { " <value>" };
            let extra = match (f.required, f.default) {
                (true, _) => " (required)".to_string(),
                (_, Some(d)) => format!(" (default: {d})"),
                _ => String::new(),
            };
            s.push_str(&format!("  --{}{kind}\n      {}{extra}\n", f.name, f.help));
        }
        s
    }
}

/// Top-level CLI: a set of subcommands.
pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli { name, about, commands: Vec::new() }
    }

    pub fn command(mut self, cmd: Command) -> Self {
        self.commands.push(cmd);
        self
    }

    /// Parse full `argv` (including program name at index 0).
    /// Returns `(subcommand, args)`, or an Err with the message to print.
    pub fn parse(&self, argv: &[String]) -> Result<(&Command, Args), String> {
        let sub = argv.get(1).ok_or_else(|| self.help())?;
        if sub == "--help" || sub == "-h" || sub == "help" {
            return Err(self.help());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == sub)
            .ok_or_else(|| format!("unknown command '{sub}'\n\n{}", self.help()))?;
        if argv.iter().any(|a| a == "--help" || a == "-h") {
            return Err(cmd.help());
        }
        let args = cmd.parse(&argv[2..])?;
        Ok((cmd, args))
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nCommands:\n", self.name, self.about);
        for c in &self.commands {
            s.push_str(&format!("  {:<12} {}\n", c.name, c.about));
        }
        s.push_str("\nUse '<command> --help' for details.\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    fn sample() -> Command {
        Command::new("compress", "compress a model")
            .flag("model", "model name", Some("llama-t"))
            .flag("ratio", "compression ratio", Some("0.3"))
            .required_flag("method", "decomposition method")
            .switch("verbose", "more logging")
    }

    #[test]
    fn parses_flags_and_defaults() {
        let cmd = sample();
        let a = cmd.parse(&argv(&["--method", "nsvd-i", "--ratio=0.4"])).unwrap();
        assert_eq!(a.get("model"), Some("llama-t"));
        assert_eq!(a.get_f64("ratio"), Some(0.4));
        assert_eq!(a.get("method"), Some("nsvd-i"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn switch_and_positionals() {
        let cmd = sample();
        let a = cmd
            .parse(&argv(&["--method", "svd", "--verbose", "extra1", "extra2"]))
            .unwrap();
        assert!(a.switch("verbose"));
        assert_eq!(a.positionals, vec!["extra1", "extra2"]);
    }

    #[test]
    fn missing_required_is_error() {
        let cmd = sample();
        assert!(cmd.parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_flag_is_error() {
        let cmd = sample();
        assert!(cmd.parse(&argv(&["--method", "svd", "--nope", "1"])).is_err());
    }

    #[test]
    fn cli_routes_subcommands() {
        let cli = Cli::new("nsvd", "test").command(sample());
        let (cmd, a) = cli
            .parse(&argv(&["nsvd", "compress", "--method", "svd"]))
            .unwrap();
        assert_eq!(cmd.name, "compress");
        assert_eq!(a.get("method"), Some("svd"));
        assert!(cli.parse(&argv(&["nsvd", "bogus"])).is_err());
    }

    #[test]
    fn workers_flag_parses_auto_and_counts() {
        let cmd = Command::new("t", "t").flag("workers", "threads", Some("auto"));
        assert_eq!(cmd.parse(&argv(&[])).unwrap().get_workers("workers"), Some(0));
        assert_eq!(
            cmd.parse(&argv(&["--workers", "0"])).unwrap().get_workers("workers"),
            Some(0)
        );
        assert_eq!(
            cmd.parse(&argv(&["--workers", "8"])).unwrap().get_workers("workers"),
            Some(8)
        );
        assert_eq!(
            cmd.parse(&argv(&["--workers", "lots"])).unwrap().get_workers("workers"),
            None
        );
    }

    #[test]
    fn f64_or_auto_flag() {
        let cmd = Command::new("t", "t").flag("alpha", "k1 share or auto", Some("0.95"));
        assert_eq!(cmd.parse(&argv(&[])).unwrap().get_f64_or_auto("alpha"), Some(Some(0.95)));
        assert_eq!(
            cmd.parse(&argv(&["--alpha", "auto"])).unwrap().get_f64_or_auto("alpha"),
            Some(None)
        );
        assert_eq!(
            cmd.parse(&argv(&["--alpha", "0.8"])).unwrap().get_f64_or_auto("alpha"),
            Some(Some(0.8))
        );
        assert_eq!(
            cmd.parse(&argv(&["--alpha", "lots"])).unwrap().get_f64_or_auto("alpha"),
            None
        );
    }

    #[test]
    fn u64_flag() {
        let cmd = Command::new("t", "t").flag("seed", "sampling seed", Some("0"));
        assert_eq!(cmd.parse(&argv(&[])).unwrap().get_u64("seed"), Some(0));
        assert_eq!(
            cmd.parse(&argv(&["--seed", "18446744073709551615"]))
                .unwrap()
                .get_u64("seed"),
            Some(u64::MAX)
        );
        assert_eq!(cmd.parse(&argv(&["--seed", "-1"])).unwrap().get_u64("seed"), None);
    }

    #[test]
    fn list_flag() {
        let cmd = Command::new("t", "t").flag("sets", "datasets", Some("a,b,c"));
        let a = cmd.parse(&argv(&[])).unwrap();
        assert_eq!(a.get_list("sets"), vec!["a", "b", "c"]);
    }
}
