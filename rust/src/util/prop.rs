//! A tiny randomized property-test driver (proptest is unavailable offline).
//!
//! Usage:
//! ```
//! use nsvd::util::prop::{check, Gen};
//! check("sum is commutative", 100, |g| {
//!     let a = g.f64_in(-10.0, 10.0);
//!     let b = g.f64_in(-10.0, 10.0);
//!     prop_assert(g, (a + b - (b + a)).abs() < 1e-12, "commutativity")
//! });
//! fn prop_assert(_g: &mut Gen, cond: bool, what: &str) -> Result<(), String> {
//!     if cond { Ok(()) } else { Err(what.to_string()) }
//! }
//! ```
//!
//! Each case gets a fresh deterministic seed derived from the case index, so
//! a failure report (`case #17, seed 0x...`) is immediately reproducible.

use super::rng::Rng;

/// Case-local generator handed to the property closure.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Random vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        self.rng.normal_vec(n)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `cases` random cases of `property`.  Panics (test failure) on the
/// first case whose closure returns `Err`, reporting case index and seed.
pub fn check<F>(name: &str, cases: usize, property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, cases, 0xA11CE, property)
}

/// Like [`check`] with an explicit base seed (to reproduce a failure).
pub fn check_seeded<F>(name: &str, cases: usize, base_seed: u64, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::new(seed), case, seed };
        if let Err(msg) = property(&mut g) {
            panic!(
                "property '{name}' failed at case #{case} (seed=0x{seed:x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::Cell::new(0usize);
        check("trivial", 25, |_g| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_case_info() {
        check("fails", 10, |g| {
            if g.case == 3 {
                Err("intentional".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_are_reproducible_per_case() {
        let mut first: Vec<f64> = Vec::new();
        check("record", 5, |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second: Vec<f64> = Vec::new();
        check("record", 5, |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
