//! Offline utility substrate.
//!
//! The build environment has no network access and the registry snapshot only
//! contains the `xla` crate closure, so the conveniences a crate would
//! normally pull from crates.io (`rand`, `serde_json`, `clap`, `rayon`,
//! `proptest`) are implemented here from scratch:
//!
//! * [`rng`] — SplitMix64 / PCG-XSH-RR generators with normal sampling.
//! * [`json`] — a minimal JSON value model with parser and serializer.
//! * [`cli`] — a declarative flag/subcommand parser.
//! * [`threads`] — scoped data-parallel helpers over `std::thread`.
//! * [`timer`] — wall-clock timing and summary statistics.
//! * [`prop`] — a tiny randomized property-test driver with case reporting.
//! * [`log`] — leveled stderr logging.

pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod threads;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
