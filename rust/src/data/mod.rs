//! Corpora loading and batching (token files emitted by python/compile/corpora.py).

pub mod batch;
pub mod corpus;

pub use batch::Batcher;
pub use corpus::Corpus;
