//! Byte-level corpora and batching.
//!
//! The paper evaluates on eight domains (WikiText-2 plus seven OOD sets —
//! multilingual and instruction data); the python side
//! (`python/compile/corpora.py`) tokenizes each into flat byte files with
//! train/test splits, and this module turns them back into model input:
//!
//! * [`corpus`] — the [`Corpus`] token store, the on-disk [`corpus::Registry`]
//!   over `artifacts/corpora/`, and the canonical
//!   [`corpus::DOMAIN_NAMES`] ordering every table iterates in.
//! * [`batch`]  — the [`Batcher`]: random calibration windows (paper §4:
//!   256 sequences) and sequential eval windows, padded into the
//!   fixed-shape `[batch, seq]` token blocks the executables expect.

pub mod batch;
pub mod corpus;

pub use batch::Batcher;
pub use corpus::Corpus;
