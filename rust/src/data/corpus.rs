//! Token-file reader (`NSVDTOK1` format) and the dataset registry.
//!
//! Format (little-endian): magic `NSVDTOK1`, u32 token count, then `count`
//! bytes of token ids (byte-level vocabulary, 256 symbols).  Written once by
//! `python/compile/corpora.py` at `make artifacts`; the same files feed both
//! the JAX pretraining mixture and this evaluation path, so there is no
//! python/rust data skew.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

pub const MAGIC: &[u8; 8] = b"NSVDTOK1";

/// The eight evaluation domains, in the paper's table order.
pub const DOMAIN_NAMES: [&str; 8] = [
    "wiki", "ptb", "c4", "snips", "alpaca", "mctest", "cmrc_cn", "alpaca_jp",
];

/// Human-readable labels matching the paper's dataset columns.
pub fn paper_label(domain: &str) -> &'static str {
    match domain {
        "wiki" => "WikiText-2",
        "ptb" => "PTB",
        "c4" => "C4",
        "snips" => "SNIPS",
        "alpaca" => "AlpacaEval",
        "mctest" => "MCTest",
        "cmrc_cn" => "CMRC (CN)",
        "alpaca_jp" => "AlpacaEval (JP)",
        _ => "?",
    }
}

/// A loaded token stream.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub name: String,
    pub tokens: Vec<u8>,
}

impl Corpus {
    /// Read a `.tok` file.
    pub fn load(name: &str, path: &Path) -> Result<Corpus> {
        let raw = std::fs::read(path)
            .with_context(|| format!("reading corpus {}", path.display()))?;
        if raw.len() < 12 || &raw[..8] != MAGIC {
            bail!("{}: bad NSVDTOK1 magic", path.display());
        }
        let count = u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
        if raw.len() < 12 + count {
            bail!(
                "{}: truncated ({} of {} payload bytes)",
                path.display(),
                raw.len() - 12,
                count
            );
        }
        Ok(Corpus { name: name.to_string(), tokens: raw[12..12 + count].to_vec() })
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Non-overlapping windows of `seq` tokens (evaluation protocol).
    pub fn windows(&self, seq: usize) -> Vec<&[u8]> {
        self.tokens.chunks_exact(seq).collect()
    }
}

/// Dataset registry over the artifacts directory: resolves `(domain, split)`
/// to corpora lazily.
pub struct Registry {
    dir: PathBuf,
}

impl Registry {
    pub fn new(artifacts_dir: &Path) -> Registry {
        Registry { dir: artifacts_dir.join("corpora") }
    }

    pub fn load(&self, domain: &str, split: &str) -> Result<Corpus> {
        let path = self.dir.join(format!("{domain}.{split}.tok"));
        Corpus::load(domain, &path)
    }

    /// All eight evaluation test splits, in paper order.
    pub fn eval_sets(&self) -> Result<Vec<Corpus>> {
        DOMAIN_NAMES.iter().map(|d| self.load(d, "test")).collect()
    }

    /// The calibration source (wiki train split, per the paper's protocol).
    pub fn calibration(&self) -> Result<Corpus> {
        self.load("wiki", "train")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tok(path: &Path, toks: &[u8]) {
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&(toks.len() as u32).to_le_bytes());
        raw.extend_from_slice(toks);
        std::fs::write(path, raw).unwrap();
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("nsvd_corpus_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.tok");
        let toks: Vec<u8> = (0..=255).cycle().take(1000).collect();
        write_tok(&path, &toks);
        let c = Corpus::load("x", &path).unwrap();
        assert_eq!(c.tokens, toks);
        assert_eq!(c.len(), 1000);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let dir = std::env::temp_dir().join("nsvd_corpus_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("bad.tok");
        std::fs::write(&bad, b"WRONGMAG\x10\x00\x00\x00").unwrap();
        assert!(Corpus::load("bad", &bad).is_err());
        let trunc = dir.join("trunc.tok");
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&100u32.to_le_bytes());
        raw.extend_from_slice(&[1, 2, 3]);
        std::fs::write(&trunc, raw).unwrap();
        assert!(Corpus::load("trunc", &trunc).is_err());
    }

    #[test]
    fn windows_are_non_overlapping_and_exact() {
        let c = Corpus { name: "t".into(), tokens: (0..100).collect() };
        let w = c.windows(32);
        assert_eq!(w.len(), 3); // 100 / 32
        assert_eq!(w[0][0], 0);
        assert_eq!(w[1][0], 32);
        assert_eq!(w[2][31], 95);
    }

    #[test]
    fn paper_labels_cover_all_domains() {
        for d in DOMAIN_NAMES {
            assert_ne!(paper_label(d), "?", "missing label for {d}");
        }
    }
}
