//! Batching: fixed-shape [B, T] i32 token batches for the PJRT executables.

use super::corpus::Corpus;
use crate::util::rng::Rng;

/// A [batch, seq] token batch in row-major i32 (the executables' input
/// dtype) with the number of *valid* rows (the rest are padding rows whose
/// loss contribution gets subtracted by the evaluator).
#[derive(Clone, Debug)]
pub struct TokenBatch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub valid_rows: usize,
}

impl TokenBatch {
    pub fn from_rows(rows: &[&[u8]], batch: usize, seq: usize) -> TokenBatch {
        assert!(rows.len() <= batch);
        let mut tokens = vec![0i32; batch * seq];
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), seq);
            for (j, &t) in row.iter().enumerate() {
                tokens[i * seq + j] = t as i32;
            }
        }
        TokenBatch { batch, seq, tokens, valid_rows: rows.len() }
    }
}

/// Deterministic batcher over a corpus.
pub struct Batcher {
    pub batch: usize,
    pub seq: usize,
}

impl Batcher {
    pub fn new(batch: usize, seq: usize) -> Batcher {
        Batcher { batch, seq }
    }

    /// All non-overlapping windows, grouped into batches (evaluation).
    /// The final partial batch is padded with zero rows.
    pub fn eval_batches(&self, corpus: &Corpus, max_windows: usize) -> Vec<TokenBatch> {
        let windows = corpus.windows(self.seq);
        let take = windows.len().min(max_windows);
        windows[..take]
            .chunks(self.batch)
            .map(|rows| TokenBatch::from_rows(rows, self.batch, self.seq))
            .collect()
    }

    /// `n_samples` random windows (calibration protocol: the paper samples
    /// 256 random sequences from the WikiText-2 train split).
    pub fn calibration_batches(
        &self,
        corpus: &Corpus,
        n_samples: usize,
        rng: &mut Rng,
    ) -> Vec<TokenBatch> {
        assert!(corpus.len() >= self.seq, "corpus shorter than one window");
        let rows: Vec<Vec<u8>> = (0..n_samples)
            .map(|_| {
                let start = rng.below(corpus.len() - self.seq + 1);
                corpus.tokens[start..start + self.seq].to_vec()
            })
            .collect();
        rows.chunks(self.batch)
            .map(|chunk| {
                let refs: Vec<&[u8]> = chunk.iter().map(|r| r.as_slice()).collect();
                TokenBatch::from_rows(&refs, self.batch, self.seq)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus(n: usize) -> Corpus {
        Corpus { name: "t".into(), tokens: (0..n).map(|i| (i % 251) as u8).collect() }
    }

    #[test]
    fn eval_batches_cover_windows_in_order() {
        let c = corpus(1000);
        let b = Batcher::new(4, 64);
        let batches = b.eval_batches(&c, usize::MAX);
        // 1000/64 = 15 windows → 4 batches (4+4+4+3).
        assert_eq!(batches.len(), 4);
        assert_eq!(batches[0].valid_rows, 4);
        assert_eq!(batches[3].valid_rows, 3);
        assert_eq!(batches[0].tokens[0], 0);
        assert_eq!(batches[0].tokens[64], 64 % 251);
        // Padding rows are zero.
        let last = &batches[3];
        assert!(last.tokens[3 * 64..].iter().all(|&t| t == 0));
    }

    #[test]
    fn eval_batches_respect_max_windows() {
        let c = corpus(10_000);
        let b = Batcher::new(8, 32);
        let batches = b.eval_batches(&c, 10);
        let rows: usize = batches.iter().map(|b| b.valid_rows).sum();
        assert_eq!(rows, 10);
    }

    #[test]
    fn calibration_is_deterministic_given_seed() {
        let c = corpus(5000);
        let b = Batcher::new(8, 128);
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let b1 = b.calibration_batches(&c, 32, &mut r1);
        let b2 = b.calibration_batches(&c, 32, &mut r2);
        assert_eq!(b1.len(), b2.len());
        for (x, y) in b1.iter().zip(&b2) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn calibration_samples_count() {
        let c = corpus(4000);
        let b = Batcher::new(8, 128);
        let mut rng = Rng::new(7);
        let batches = b.calibration_batches(&c, 256, &mut rng);
        assert_eq!(batches.len(), 32);
        assert!(batches.iter().all(|tb| tb.valid_rows == 8));
    }
}
