//! nsvd — command-line entrypoint for the NSVD compression system.
//!
//! Commands regenerate the paper's experiments (tables 1–6, figure 1, the
//! ASVD-III ablation), run one-off compressions, and drive the serving demo.

use anyhow::Result;
use nsvd::compress::methods::{CompressionSpec, Method};
use nsvd::coordinator::pipeline::{Pipeline, PipelineConfig};
use nsvd::bench::{drive_concurrent_kv, drive_open_loop_kv, goodput_tokens_per_s, OpenLoopTenant};
use nsvd::coordinator::reports::{
    render_latency_block, render_method_block, render_request_timeline, render_tenant_block,
    save_table, MethodRow, Table,
};
use nsvd::coordinator::scheduler::{run_jobs, sweeps, Job};
use nsvd::coordinator::server;
use nsvd::data::corpus::{paper_label, Registry, DOMAIN_NAMES};
use nsvd::model::generate::SampleConfig;
use nsvd::serve::{ChaosConfig, GenConfig};
use nsvd::util::cli::{Cli, Command};
use nsvd::util::timer::Timer;
use std::path::PathBuf;

fn main() {
    let cli = build_cli();
    let argv: Vec<String> = std::env::args().collect();
    let (cmd, args) = match cli.parse(&argv) {
        Ok(x) => x,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let result = match cmd.name {
        "info" => cmd_info(&args),
        "compress" => cmd_compress(&args),
        "table" => cmd_table(&args),
        "figure" => cmd_figure(&args),
        "serve" => cmd_serve(&args),
        "serve-gen" => cmd_serve_gen(&args),
        "e2e" => cmd_e2e(&args),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn build_cli() -> Cli {
    Cli::new("nsvd", "Nested activation-aware decomposition for LLM compression")
        .command(
            Command::new("info", "summarize the artifacts manifest")
                .flag("artifacts", "artifacts directory", Some("artifacts")),
        )
        .command(
            Command::new("compress", "compress one model and report perplexities")
                .flag("artifacts", "artifacts directory", Some("artifacts"))
                .flag("model", "model name", Some("llama-t"))
                .flag("method", "svd | asvd-0 | asvd-i | asvd-ii | asvd-iii | nsvd-i | nsvd-ii | nid-i | nid-ii", Some("nsvd-i"))
                .flag("ratio", "compression ratio (0-1)", Some("0.3"))
                .flag("alpha", "k1 share for nested methods, or 'auto' (per-layer tune)", Some("0.95"))
                .flag("allocate", "rank allocation: uniform (paper protocol) | spectrum (global water-filling)", Some("uniform"))
                .flag("sweep-ratios", "comma-separated ratios: print the budget-vs-perplexity curve instead of one run", None)
                .flag("factor-dtype", "factor storage dtype: f32 | int8 (per-group quantized, native only)", Some("f32"))
                .flag("kv-ratio", "KV-cache latent width as a fraction of the K/V row (<1 compresses the cache; native only)", Some("1.0"))
                .flag("windows", "eval windows per dataset", Some("64"))
                .flag("workers", "decomposition threads (auto = all cores)", Some("auto"))
                .flag("eval-workers", "native-eval batch-scoring threads (auto = all cores)", Some("1"))
                .switch("rsvd", "randomized-SVD fast path (auto-selected per layer)")
                .flag("rsvd-tol", "rsvd certificate: max relative excess error (needs --rsvd)", Some("0.02"))
                .flag("jacobi", "exact-SVD sweep ordering: cyclic | tournament (parallel rounds)", Some("cyclic"))
                .flag("trace-out", "write a Chrome trace-event JSON of the run (Perfetto-loadable)", None)
                .flag("metrics-out", "write the metrics registry as Prometheus text", None)
                .switch("native", "use the native forward instead of PJRT"),
        )
        .command(
            Command::new("table", "regenerate a paper table: 1 | 2 | 3 | 4 | 5 | 6 | ablation")
                .flag("artifacts", "artifacts directory", Some("artifacts"))
                .flag("windows", "eval windows per dataset", Some("64"))
                .flag("ratios", "ratios for table 1", Some("0.1,0.2,0.3,0.4,0.5"))
                .flag("workers", "decomposition threads (auto = all cores)", Some("auto"))
                .flag("eval-workers", "native-eval batch-scoring threads (auto = all cores)", Some("1"))
                .switch("rsvd", "randomized-SVD fast path (auto-selected per layer)")
                .flag("rsvd-tol", "rsvd certificate: max relative excess error (needs --rsvd)", Some("0.02"))
                .flag("jacobi", "exact-SVD sweep ordering: cyclic | tournament (parallel rounds)", Some("cyclic"))
                .switch("native", "use the native forward instead of PJRT"),
        )
        .command(
            Command::new("figure", "regenerate figure 1 (similarity histograms)")
                .flag("artifacts", "artifacts directory", Some("artifacts"))
                .flag("windows", "eval windows per dataset", Some("64")),
        )
        .command(
            Command::new("serve", "serve scoring requests over a compressed model")
                .flag("artifacts", "artifacts directory", Some("artifacts"))
                .flag("model", "model name", Some("llama-t"))
                .flag("method", "compression method", Some("nsvd-i"))
                .flag("ratio", "compression ratio", Some("0.3"))
                .flag("requests", "number of requests", Some("200"))
                .flag("rate", "request rate (rps, 0 = as fast as possible)", Some("0"))
                .flag("max-wait-ms", "batcher max wait", Some("2"))
                .flag("workers", "decomposition threads (auto = all cores)", Some("auto"))
                .flag("eval-workers", "native-eval batch-scoring threads (auto = all cores)", Some("1"))
                .switch("rsvd", "randomized-SVD fast path (auto-selected per layer)")
                .flag("rsvd-tol", "rsvd certificate: max relative excess error (needs --rsvd)", Some("0.02"))
                .flag("jacobi", "exact-SVD sweep ordering: cyclic | tournament (parallel rounds)", Some("cyclic")),
        )
        .command(
            Command::new(
                "serve-gen",
                "continuous-batching generation server over a compressed model",
            )
            .flag("artifacts", "artifacts directory", Some("artifacts"))
            .flag("model", "model name", Some("llama-t"))
            .flag("method", "compression method", Some("nsvd-i"))
            .flag("ratio", "compression ratio", Some("0.3"))
            .flag("factor-dtype", "factor storage dtype: f32 | int8 (per-group quantized, native only)", Some("f32"))
            .flag("kv-ratio", "KV-cache latent width as a fraction of the K/V row (<1 stores rank-wide latents in the paged pool; native only)", Some("1.0"))
            .flag("requests", "total generation requests", Some("32"))
            .flag("clients", "concurrent closed-loop client threads", Some("4"))
            .flag("max-batch", "max sequences decoded per step", Some("8"))
            .flag("pages", "KV pool size in pages (0 = auto: max-batch sequences' worst case)", Some("0"))
            .flag("page-size", "token positions per KV page", Some("16"))
            .flag("prefill-chunk", "max prompt rows fed per sequence per step (0 = whole prompt)", Some("16"))
            .flag("prefix-share", "dedupe common prompt prefixes across requests: on | off", Some("on"))
            .flag("max-new", "new tokens per request", Some("32"))
            .flag("prompt-len", "prompt length (bytes, windowed from the corpus)", Some("16"))
            .flag("temperature", "sampling temperature (0 = greedy)", Some("0.8"))
            .flag("top-k", "top-k sampling cutoff (0 = full distribution)", Some("20"))
            .flag("seed", "base sampling seed (request i uses seed + i)", Some("0"))
            .flag("rate", "open-loop Poisson arrival rate per tenant stream (req/s; 0 = closed-loop clients)", Some("0"))
            .flag("tenants", "open-loop tenant streams; requests split evenly across them (needs --rate > 0)", Some("1"))
            .flag("tenant", "base tenant id stamped on open-loop requests (stream t gets tenant + t)", Some("0"))
            .flag("priority", "scheduling priority stamped on open-loop requests (higher runs first and preempts lower)", Some("0"))
            .flag("deadline-ms", "relative deadline per open-loop request in ms (0 = none; expired requests are killed with DeadlineExceeded)", Some("0"))
            .flag("queue-cap", "bounded admission queue in requests (0 = unbounded; a full queue rejects or sheds the least-urgent work)", Some("0"))
            .flag("chaos-seed", "fault-injection seed (only with --fault-rate > 0)", Some("0"))
            .flag("fault-rate", "injected step-fault and allocation-failure probability in [0,1] (0 disables chaos)", Some("0"))
            .flag("workers", "thread budget for BOTH the compression phase and the batched decode step's GEMMs (auto = all cores)", Some("auto"))
            .flag("eval-workers", "native-eval batch-scoring threads (auto = all cores)", Some("1"))
            .switch("rsvd", "randomized-SVD fast path (auto-selected per layer)")
            .flag("rsvd-tol", "rsvd certificate: max relative excess error (needs --rsvd)", Some("0.02"))
            .flag("jacobi", "exact-SVD sweep ordering: cyclic | tournament (parallel rounds)", Some("cyclic"))
            .flag("trace-out", "write a Chrome trace-event JSON of the run (Perfetto-loadable)", None)
            .flag("metrics-out", "write the metrics registry as Prometheus text", None)
            .flag("metrics-port", "serve a live /metrics scrape endpoint on 127.0.0.1:<port> during the run (0 = off)", Some("0"))
            .switch("native", "calibrate/compress through the native forward instead of PJRT (generation itself is always native)"),
        )
        .command(
            Command::new("e2e", "full pipeline demo: calibrate → compress → evaluate")
                .flag("artifacts", "artifacts directory", Some("artifacts"))
                .flag("model", "model name", Some("llama-t"))
                .flag("method", "compression method", Some("nsvd-i"))
                .flag("ratio", "compression ratio", Some("0.3"))
                .flag("alpha", "k1 share, or 'auto' (per-layer tune)", Some("0.95"))
                .flag("allocate", "rank allocation: uniform | spectrum", Some("uniform"))
                .flag("sweep-ratios", "comma-separated ratios: print the budget-vs-perplexity curve instead of one run", None)
                .flag("factor-dtype", "factor storage dtype: f32 | int8 (per-group quantized, native only)", Some("f32"))
                .flag("kv-ratio", "KV-cache latent width as a fraction of the K/V row (<1 compresses the cache; native only)", Some("1.0"))
                .flag("windows", "eval windows per dataset", Some("32"))
                .flag("workers", "decomposition threads (auto = all cores)", Some("auto"))
                .flag("eval-workers", "native-eval batch-scoring threads (auto = all cores)", Some("1"))
                .switch("rsvd", "randomized-SVD fast path (auto-selected per layer)")
                .flag("rsvd-tol", "rsvd certificate: max relative excess error (needs --rsvd)", Some("0.02"))
                .flag("jacobi", "exact-SVD sweep ordering: cyclic | tournament (parallel rounds)", Some("cyclic"))
                .flag("trace-out", "write a Chrome trace-event JSON of the run (Perfetto-loadable)", None)
                .flag("metrics-out", "write the metrics registry as Prometheus text", None)
                .switch("native", "use the native forward instead of PJRT"),
        )
}

/// Turn observability on when any export flag is present; returns the
/// requested `--trace-out` / `--metrics-out` paths.
fn obs_from(args: &nsvd::util::cli::Args) -> (Option<PathBuf>, Option<PathBuf>) {
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    if trace_out.is_some() || metrics_out.is_some() {
        nsvd::obs::set_enabled(true);
    }
    (trace_out, metrics_out)
}

/// Write the requested observability artifacts at the end of a run.
/// `extra` (an exact end-of-run summary registry) replaces same-named
/// live entries in the Prometheus dump.
fn write_obs_outputs(
    trace_out: &Option<PathBuf>,
    metrics_out: &Option<PathBuf>,
    extra: Option<&nsvd::obs::Registry>,
) -> Result<()> {
    if let Some(p) = trace_out {
        nsvd::obs::export::write_chrome_trace(p)?;
        println!("trace written to {}", p.display());
    }
    if let Some(p) = metrics_out {
        nsvd::obs::export::write_prometheus(p, extra)?;
        println!("metrics written to {}", p.display());
    }
    Ok(())
}

fn pipeline_from(args: &nsvd::util::cli::Args, model: &str) -> Result<Pipeline> {
    let mut cfg = PipelineConfig::default_for_model(model);
    cfg.artifacts_dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    cfg.eval_windows = args.get_usize("windows").unwrap_or(64);
    cfg.use_pjrt = !args.switch("native");
    if let Some(s) = args.get("factor-dtype") {
        cfg.factor_dtype = nsvd::compress::FactorDtype::parse(s)?;
    }
    if args.get("kv-ratio").is_some() {
        let r = args
            .get_f64("kv-ratio")
            .ok_or_else(|| anyhow::anyhow!("--kv-ratio expects a number in (0, 1]"))?;
        anyhow::ensure!(r > 0.0 && r <= 1.0, "--kv-ratio expects a number in (0, 1], got {r}");
        cfg.kv_ratio = r;
    }
    if args.get("workers").is_some() {
        cfg.workers = args.get_workers("workers").ok_or_else(|| {
            anyhow::anyhow!("--workers expects a positive integer or 'auto'")
        })?;
    }
    if args.get("eval-workers").is_some() {
        cfg.eval_workers = args.get_workers("eval-workers").ok_or_else(|| {
            anyhow::anyhow!("--eval-workers expects a positive integer or 'auto'")
        })?;
    }
    if args.switch("rsvd") {
        cfg.svd = nsvd::linalg::rsvd::SvdPolicy::auto();
        if let Some(tol) = args.get_f64("rsvd-tol") {
            cfg.svd.max_rel_err = Some(tol);
        }
    }
    match args.get_or("jacobi", "cyclic") {
        "cyclic" => {}
        "tournament" => {
            cfg.svd.ordering = nsvd::linalg::JacobiOrdering::Tournament;
        }
        other => anyhow::bail!("--jacobi expects 'cyclic' or 'tournament', got '{other}'"),
    }
    if let Some(strategy) = args.get("allocate") {
        cfg.allocate = nsvd::compress::AllocStrategy::parse(strategy)?;
    }
    // `--alpha auto` switches the per-layer split tune on; a numeric value
    // (or the flag's absence) keeps the fixed global α carried by the
    // spec.  One parse, three cases — an out-of-range numeric α would
    // otherwise be silently clamped by split_k into a different
    // experiment than the one requested.
    if args.get("alpha").is_some() {
        match args.get_f64_or_auto("alpha") {
            None => anyhow::bail!("--alpha expects a number in (0, 1] or 'auto'"),
            Some(None) => cfg.alpha_auto = true,
            Some(Some(a)) if !(a > 0.0 && a <= 1.0) => {
                anyhow::bail!("--alpha expects a number in (0, 1] or 'auto', got {a}")
            }
            Some(Some(_)) => {}
        }
    }
    Pipeline::new(cfg)
}

/// The spec's fixed α: the numeric `--alpha` when given, the paper default
/// otherwise (also the fallback the spec carries under `--alpha auto`,
/// where the per-layer tune overrides it).
fn alpha_from(args: &nsvd::util::cli::Args) -> f64 {
    args.get_f64_or_auto("alpha").flatten().unwrap_or(0.95)
}

fn cmd_info(args: &nsvd::util::cli::Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let manifest = nsvd::runtime::artifacts::Manifest::load(&dir)?;
    println!("artifacts: {}", dir.display());
    println!("seq={} eval_batch={}", manifest.seq, manifest.eval_batch);
    println!("\nmodels:");
    for (name, cfg) in &manifest.models {
        println!(
            "  {name:<10} family={:?} d={} L={} heads={} ff={} window={} (arch {})",
            cfg.family, cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.d_ff, cfg.window, cfg.arch
        );
    }
    println!("\nartifacts:");
    for (key, a) in &manifest.artifacts {
        println!("  {key:<24} kind={:<8} file={}", a.kind, a.file);
    }
    Ok(())
}

fn cmd_compress(args: &nsvd::util::cli::Args) -> Result<()> {
    let (trace_out, metrics_out) = obs_from(args);
    let model = args.get_or("model", "llama-t").to_string();
    let mut pipeline = pipeline_from(args, &model)?;
    let spec = CompressionSpec {
        method: Method::parse(args.get_or("method", "nsvd-i"))?,
        ratio: args.get_f64("ratio").unwrap_or(0.3),
        alpha: alpha_from(args),
    };
    let mut sweep: Vec<f64> = Vec::new();
    for s in args.get_list("sweep-ratios") {
        sweep.push(s.parse().map_err(|_| {
            anyhow::anyhow!("--sweep-ratios: '{s}' is not a number (expected e.g. 0.2,0.3,0.5)")
        })?);
    }
    if !sweep.is_empty() {
        let t = Timer::start();
        let points = pipeline.run_budget_sweep(&spec, &sweep)?;
        println!(
            "budget-vs-perplexity sweep — model={model} method={} allocate={} α={}",
            spec.method.label(),
            pipeline.config.allocate.label(),
            if pipeline.config.alpha_auto { "auto".to_string() } else { spec.alpha.to_string() },
        );
        println!(
            "{:>8} {:>10} {:>6} {:>12} {:>14} {:>12}",
            "ratio", "strategy", "dtype", "params", "factor bytes", "pooled ppl"
        );
        for p in &points {
            println!(
                "{:>7.0}% {:>10} {:>6} {:>12} {:>14} {:>12.2}",
                p.ratio * 100.0,
                p.strategy,
                p.dtype,
                p.compressed_params,
                p.factor_bytes,
                p.ppl
            );
        }
        println!("({} points in {:.1}s)", points.len(), t.elapsed_s());
        return write_obs_outputs(&trace_out, &metrics_out, None);
    }
    let t = Timer::start();
    let report = pipeline.run(&spec)?;
    println!(
        "model={} method={} ratio={:.0}% α={} dtype={} params {} → {} ({:.1}% removed, \
         factor bytes {}) in {:.1}s",
        report.model,
        report.method,
        report.ratio * 100.0,
        report.alpha,
        report.dtype,
        report.dense_params,
        report.compressed_params,
        (1.0 - report.compressed_params as f64 / report.dense_params as f64) * 100.0,
        report.factor_bytes,
        t.elapsed_s()
    );
    for r in &report.results {
        println!("  {:<16} ppl {:>10.2}", paper_label(&r.dataset), r.ppl());
    }
    if pipeline.config.kv_ratio < 1.0 {
        // The cache quality row: score the wk/wv-only latent view — exactly
        // what the paged pool serves at this --kv-ratio.
        let kvc = pipeline
            .build_kv_compression(&spec)?
            .expect("kv_ratio < 1 builds factors");
        let results = pipeline.evaluate_kv_view(&kvc)?;
        println!(
            "kv-cache @ {:.0}% latent width: pooled ppl {:.2} (factor bytes {})",
            pipeline.config.kv_ratio * 100.0,
            nsvd::eval::perplexity::pooled_ppl(&results),
            kvc.factor_bytes()
        );
    }
    write_obs_outputs(&trace_out, &metrics_out, None)
}

/// Format job outcomes into table rows (one per method job).
fn rows_from_outcomes(
    outcomes: &[nsvd::coordinator::scheduler::JobOutcome],
) -> Vec<MethodRow> {
    outcomes
        .iter()
        .filter_map(|o| {
            let report = o.result.as_ref().ok()?;
            let ppl: Vec<f64> = DOMAIN_NAMES
                .iter()
                .map(|d| report.ppl(d).unwrap_or(f64::NAN))
                .collect();
            Some(MethodRow {
                label: o.job.name.clone(),
                ppl,
                is_ours: o.job.spec.method.is_nested(),
            })
        })
        .collect()
}

fn baseline_index(rows: &[MethodRow], label_prefix: &str) -> usize {
    rows.iter()
        .position(|r| r.label.starts_with(label_prefix))
        .unwrap_or(0)
}

fn cmd_table(args: &nsvd::util::cli::Args) -> Result<()> {
    let id = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("1");
    match id {
        "1" => {
            let ratios: Vec<f64> = args
                .get_list("ratios")
                .iter()
                .filter_map(|s| s.parse().ok())
                .collect();
            let mut pipeline = pipeline_from(args, "llama-t")?;
            let dense = pipeline.run_dense()?;
            println!(
                "Original: {}",
                dense
                    .results
                    .iter()
                    .map(|r| format!("{}={:.2}", r.dataset, r.ppl()))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
            for &ratio in &ratios {
                let jobs: Vec<Job> = sweeps::table1(&[ratio]);
                let outcomes = run_jobs(&mut pipeline, &jobs);
                let rows = rows_from_outcomes(&outcomes);
                let b = baseline_index(&rows, "ASVD-I@");
                let table = render_method_block(
                    &format!(
                        "Table 1 — LLaMA-7B analog (llama-t), ratio {:.0}%",
                        ratio * 100.0
                    ),
                    &rows,
                    b,
                );
                println!("{}", table.to_markdown());
                save_table(&table, &format!("table1_r{:02.0}", ratio * 100.0))?;
            }
        }
        "2" => {
            let mut pipeline = pipeline_from(args, "llama-t")?;
            let reports = pipeline.similarity_analysis()?;
            let mut table = Table::new(
                "Table 2 — activation similarity vs calibration set (llama-t)",
                std::iter::once("Similarity".to_string())
                    .chain(DOMAIN_NAMES.iter().map(|d| paper_label(d).to_string()))
                    .collect(),
            );
            let mut row = vec!["Mean (std)".to_string()];
            for r in &reports {
                row.push(format!("{:.2} ({:.2})", r.mean, r.std));
            }
            table.push_row(row);
            println!("{}", table.to_markdown());
            save_table(&table, "table2_similarity")?;
        }
        "3" | "4" => {
            let mut pipeline = pipeline_from(args, "llama-t")?;
            let jobs = if id == "3" { sweeps::table3() } else { sweeps::table4() };
            let mut all_jobs = vec![Job::new(Method::AsvdI, 0.30, 1.0)];
            all_jobs.extend(jobs);
            let outcomes = run_jobs(&mut pipeline, &all_jobs);
            let rows = rows_from_outcomes(&outcomes);
            let table = render_method_block(
                &format!("Table {id} — k1 sweep at 30% (llama-t)"),
                &rows,
                0,
            );
            println!("{}", table.to_markdown());
            save_table(&table, &format!("table{id}_k1_sweep"))?;
        }
        "5" | "6" => {
            let models: &[&str] = if id == "5" {
                &["vicuna-t", "mistral-t", "opt-t"]
            } else {
                &["llama-t", "llama-s", "llama-m"]
            };
            for model in models {
                let mut pipeline = pipeline_from(args, model)?;
                let outcomes = run_jobs(&mut pipeline, &sweeps::model_comparison());
                let rows = rows_from_outcomes(&outcomes);
                let b = baseline_index(&rows, "ASVD-I@");
                let table =
                    render_method_block(&format!("Table {id} — {model} at 30%"), &rows, b);
                println!("{}", table.to_markdown());
                save_table(&table, &format!("table{id}_{model}"))?;
            }
        }
        "ablation" => {
            let mut pipeline = pipeline_from(args, "llama-t")?;
            let outcomes = run_jobs(&mut pipeline, &sweeps::ablation());
            let rows = rows_from_outcomes(&outcomes);
            let table = render_method_block(
                "Ablation — ASVD-II vs ASVD-III (failure trial, §3 Theorem 4)",
                &rows,
                0,
            );
            println!("{}", table.to_markdown());
            save_table(&table, "ablation_asvd3")?;
        }
        other => anyhow::bail!("unknown table id '{other}' (use 1-6 or 'ablation')"),
    }
    Ok(())
}

fn cmd_figure(args: &nsvd::util::cli::Args) -> Result<()> {
    let mut pipeline = pipeline_from(args, "llama-t")?;
    let reports = pipeline.similarity_analysis()?;
    for r in &reports {
        println!(
            "--- Figure 1: {} (mean {:.2}, std {:.2}) ---",
            paper_label(&r.dataset),
            r.mean,
            r.std
        );
        println!("{}", r.ascii_histogram(10, 40));
    }
    Ok(())
}

fn cmd_serve(args: &nsvd::util::cli::Args) -> Result<()> {
    let model = args.get_or("model", "llama-t").to_string();
    let mut pipeline = pipeline_from(args, &model)?;
    let spec = CompressionSpec {
        method: Method::parse(args.get_or("method", "nsvd-i"))?,
        ratio: args.get_f64("ratio").unwrap_or(0.3),
        alpha: 0.95,
    };
    println!(
        "compressing {model} with {} at {:.0}%...",
        spec.method.label(),
        spec.ratio * 100.0
    );
    let cm = pipeline.compress(&spec)?;
    let rt = pipeline
        .runtime()
        .ok_or_else(|| anyhow::anyhow!("serving requires the PJRT runtime"))?;
    let eval = rt.serve_evaluator(&model, &cm)?;
    let registry = Registry::new(&PathBuf::from(args.get_or("artifacts", "artifacts")));
    let corpus = registry.load("alpaca", "test")?;

    let n = args.get_usize("requests").unwrap_or(200);
    let rate = args.get_f64("rate").unwrap_or(0.0);
    let policy = server::BatchPolicy {
        max_wait_s: args.get_f64("max-wait-ms").unwrap_or(2.0) / 1e3,
    };
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    let producer = server::spawn_load(corpus.tokens.clone(), eval.seq(), n, rate, req_tx);
    let metrics = server::serve(&eval, req_rx, resp_tx, policy)?;
    producer.join().ok();
    let responses: Vec<_> = resp_rx.iter().collect();
    println!("served {} responses", responses.len());
    println!("{}", metrics.summary());
    let table = render_latency_block(
        "Scoring latency percentiles",
        &[
            ("end-to-end".to_string(), metrics.latency()),
            ("queue wait".to_string(), metrics.queue_wait()),
        ],
    );
    println!("{}", table.to_markdown());
    let mean_ppl: f64 =
        responses.iter().map(|r| r.ppl).sum::<f64>() / responses.len().max(1) as f64;
    println!("mean request ppl: {mean_ppl:.2}");
    Ok(())
}

/// End-of-run observability for `serve-gen`: request timeline + Chrome
/// trace when tracing, Prometheus text stamped with the exact serving
/// summary, endpoint shutdown.
fn finish_obs_serve(
    trace_out: &Option<PathBuf>,
    metrics_out: &Option<PathBuf>,
    endpoint: &mut Option<nsvd::obs::export::MetricsEndpoint>,
    metrics: &nsvd::coordinator::metrics::GenServerMetrics,
) -> Result<()> {
    if nsvd::obs::enabled() && trace_out.is_some() {
        let events = nsvd::obs::trace::snapshot_events();
        println!("{}", render_request_timeline("Request timeline", &events).to_markdown());
    }
    write_obs_outputs(trace_out, metrics_out, Some(&metrics.to_registry()))?;
    if let Some(mut ep) = endpoint.take() {
        ep.stop();
    }
    Ok(())
}

fn cmd_serve_gen(args: &nsvd::util::cli::Args) -> Result<()> {
    let (trace_out, metrics_out) = obs_from(args);
    let metrics_port = args.get_usize("metrics-port").unwrap_or(0);
    if metrics_port > 0 {
        nsvd::obs::set_enabled(true);
    }
    let mut endpoint = if metrics_port > 0 {
        let ep = nsvd::obs::export::MetricsEndpoint::start(metrics_port as u16)?;
        println!("metrics endpoint: http://{}/metrics", ep.addr());
        Some(ep)
    } else {
        None
    };
    let model = args.get_or("model", "llama-t").to_string();
    let mut pipeline = pipeline_from(args, &model)?;
    let spec = CompressionSpec {
        method: Method::parse(args.get_or("method", "nsvd-i"))?,
        ratio: args.get_f64("ratio").unwrap_or(0.3),
        alpha: 0.95,
    };
    println!(
        "compressing {model} with {} at {:.0}% ({} factors)...",
        spec.method.label(),
        spec.ratio * 100.0,
        pipeline.config.factor_dtype.label()
    );
    let cm = pipeline.compress(&spec)?;
    // KV-cache factors (--kv-ratio < 1): calibrated whitened truncation,
    // quantized alongside the weight factors under --factor-dtype int8.
    let kvc = match pipeline.build_kv_compression(&spec)? {
        Some(mut k) => {
            if pipeline.config.factor_dtype == nsvd::compress::FactorDtype::Int8 {
                k.quantize(nsvd::linalg::quant::DEFAULT_GROUP);
            }
            println!(
                "kv-cache: {:.0}% latent width ({} factor bytes)",
                pipeline.config.kv_ratio * 100.0,
                k.factor_bytes()
            );
            Some(k)
        }
        None => None,
    };

    let n = args.get_usize("requests").unwrap_or(32).max(1);
    let clients = args.get_usize("clients").unwrap_or(4).max(1).min(n);
    let prompt_len = args.get_usize("prompt-len").unwrap_or(16).max(1);
    let max_new = args.get_usize("max-new").unwrap_or(32).max(1);
    let max_batch = args.get_usize("max-batch").unwrap_or(8).max(1);
    let page_size = args.get_usize("page-size").unwrap_or(16).max(1);
    // Auto pool size: room for max_batch worst-case sequences — the
    // pre-paging behavior.  Undersize it deliberately (e.g. half) to watch
    // fault-in + preemption sustain more concurrency at equal memory.
    let auto_pages = max_batch * (prompt_len + max_new - 1).div_ceil(page_size);
    let pages = match args.get_usize("pages").unwrap_or(0) {
        0 => auto_pages,
        p => p,
    };
    let prefix_share = match args.get_or("prefix-share", "on") {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--prefix-share must be on|off, got {other}"),
    };
    let fault_rate = args.get_f64("fault-rate").unwrap_or(0.0).clamp(0.0, 1.0);
    let chaos = if fault_rate > 0.0 {
        Some(ChaosConfig {
            seed: args.get_u64("chaos-seed").unwrap_or(0),
            step_fault_rate: fault_rate,
            alloc_fail_rate: fault_rate,
        })
    } else {
        None
    };
    let gen_cfg = GenConfig {
        max_batch,
        pages,
        page_size,
        prefill_chunk: args.get_usize("prefill-chunk").unwrap_or(16),
        prefix_share,
        workers: args.get_workers("workers").unwrap_or(0),
        queue_cap: args.get_usize("queue-cap").unwrap_or(0),
        chaos,
        ..GenConfig::default()
    };
    let sample = SampleConfig {
        temperature: args.get_f64("temperature").unwrap_or(0.8) as f32,
        top_k: args.get_usize("top-k").unwrap_or(20),
        seed: args.get_u64("seed").unwrap_or(0),
    };

    let rate = args.get_f64("rate").unwrap_or(0.0).max(0.0);
    if rate > 0.0 {
        // Open-loop load generation: Poisson arrivals keep offering work
        // no matter how far behind the server falls — the regime where
        // the bounded queue, deadlines, and shedding earn their keep.
        let tenants_n = args.get_usize("tenants").unwrap_or(1).max(1);
        let tenant0 = args.get_usize("tenant").unwrap_or(0) as u32;
        let priority = args.get_usize("priority").unwrap_or(0).min(u8::MAX as usize) as u8;
        let deadline_ms = args.get_f64("deadline-ms").unwrap_or(0.0);
        let specs: Vec<OpenLoopTenant> = (0..tenants_n)
            .map(|t| OpenLoopTenant {
                tenant: tenant0 + t as u32,
                rate,
                requests: n / tenants_n + usize::from(t < n % tenants_n),
                priority,
                deadline: if deadline_ms > 0.0 { Some(deadline_ms / 1e3) } else { None },
                prompt_len: ((prompt_len / 2).max(1), 2 * prompt_len),
                max_new: ((max_new / 2).max(1), 2 * max_new),
            })
            .collect();
        println!(
            "open-loop: {n} requests over {tenants_n} tenant stream(s) at {rate} req/s each \
             (max_batch={}, pages={}x{}, queue_cap={}, deadline_ms={deadline_ms}, \
             fault_rate={fault_rate})...",
            gen_cfg.max_batch, gen_cfg.pages, gen_cfg.page_size, gen_cfg.queue_cap
        );
        let (metrics, client_stats) = drive_open_loop_kv(
            &pipeline.model_cfg,
            &pipeline.weights,
            &cm,
            kvc.as_ref(),
            &gen_cfg,
            sample.seed,
            &specs,
        )?;
        println!("{}", metrics.summary());
        if kvc.is_some() {
            println!("kv pool: {:.0} token slots per GB", metrics.kv_slots_per_gb());
        }
        println!(
            "goodput {:.1} tok/s (completed requests only) vs raw {:.1} tok/s",
            goodput_tokens_per_s(&client_stats, metrics.wall_s),
            metrics.tokens_per_s()
        );
        println!("{}", render_tenant_block("Per-tenant serving", &metrics).to_markdown());
        let table = render_latency_block(
            "Generation latency percentiles",
            &[
                ("end-to-end".to_string(), metrics.latency()),
                ("time-to-first-token".to_string(), metrics.ttft()),
                ("per decode step".to_string(), metrics.step()),
            ],
        );
        println!("{}", table.to_markdown());
        return finish_obs_serve(&trace_out, &metrics_out, &mut endpoint, &metrics);
    }

    let registry = Registry::new(&PathBuf::from(args.get_or("artifacts", "artifacts")));
    let corpus = registry.load("alpaca", "test")?;
    let prompts: Vec<Vec<u8>> = corpus
        .tokens
        .chunks_exact(prompt_len)
        .take(n)
        .map(|w| w.to_vec())
        .collect();
    anyhow::ensure!(!prompts.is_empty(), "corpus too small for --prompt-len {prompt_len}");

    println!(
        "serving {n} requests from {clients} clients \
         (max_batch={}, pages={}x{}, prefill_chunk={}, prefix_share={}, \
         max_new={max_new})...",
        gen_cfg.max_batch, gen_cfg.pages, gen_cfg.page_size, gen_cfg.prefill_chunk,
        gen_cfg.prefix_share
    );
    // Producers fan in over mpsc from `clients` closed-loop threads; the
    // main thread becomes the scheduler and owns the KV pool (shared
    // harness: nsvd::bench::drive_concurrent).
    let (metrics, client_stats) = drive_concurrent_kv(
        &pipeline.model_cfg,
        &pipeline.weights,
        &cm,
        kvc.as_ref(),
        &gen_cfg,
        clients,
        n,
        &|i| {
            (
                prompts[i % prompts.len()].clone(),
                max_new,
                SampleConfig { seed: sample.seed.wrapping_add(i as u64), ..sample },
            )
        },
    )?;
    println!("{}", metrics.summary());
    if kvc.is_some() {
        println!("kv pool: {:.0} token slots per GB", metrics.kv_slots_per_gb());
    }
    println!("clients saw {} completed streams", client_stats.len());
    let table = render_latency_block(
        "Generation latency percentiles",
        &[
            ("end-to-end".to_string(), metrics.latency()),
            ("time-to-first-token".to_string(), metrics.ttft()),
            ("per decode step".to_string(), metrics.step()),
        ],
    );
    println!("{}", table.to_markdown());
    finish_obs_serve(&trace_out, &metrics_out, &mut endpoint, &metrics)
}

fn cmd_e2e(args: &nsvd::util::cli::Args) -> Result<()> {
    println!("== e2e: calibrate → compress → evaluate (see examples/e2e_pipeline.rs) ==");
    cmd_compress(args)
}
