//! Low-rank column interpolative decomposition (ID).
//!
//! The NID variants of the paper perform the nested second stage with an ID
//! instead of an SVD (Martinsson et al., 2011): pick `k` actual columns of
//! `A` (index set `J`) and an interpolation matrix `T` such that
//! `A ≈ A[:, J] · T`.  The column subset is chosen by the rank-revealing
//! column-pivoted QR; `T` solves the triangular interpolation system.
//!
//! Storage at rank k is `m·k + k·n`, the same as an SVD factor pair, so NID
//! achieves the same compression ratio while being cheaper to compute.
//!
//! Stability note: the ID consumes only `R` and the pivot permutation from
//! [`qr_pivoted`] — both of which are bit-identical to the retired
//! unblocked pivoted QR (the blocked compact-WY rebuild only changed how
//! `Q` is *formed*, pinned by `qr::tests`) — so NID factor outputs are
//! unchanged by the level-3 QR substrate.

use super::matrix::Matrix;
use super::qr::qr_pivoted;

/// A rank-k column interpolative decomposition `A ≈ C · T` where
/// `C = A[:, cols]` holds actual columns of A.
#[derive(Clone, Debug)]
pub struct ColumnId {
    /// Indices (into A's columns) of the skeleton columns.
    pub cols: Vec<usize>,
    /// The skeleton matrix `C = A[:, cols]` (m×k).
    pub c: Matrix,
    /// Interpolation matrix (k×n): `A ≈ C · T`, with `T[:, cols] = I`.
    pub t: Matrix,
}

impl ColumnId {
    pub fn reconstruct(&self) -> Matrix {
        self.c.matmul(&self.t)
    }

    pub fn rank(&self) -> usize {
        self.cols.len()
    }
}

/// Compute a rank-k column ID of `a` via column-pivoted QR.
///
/// With `A Π = Q R = Q [R11 R12; 0 R22]`, dropping `R22` gives
/// `A[:, J] ≈ Q1 R11`, and for the remaining columns
/// `A[:, J̄] ≈ Q1 R12 = A[:, J] R11⁻¹ R12`, i.e. `T = [I | R11⁻¹R12] Πᵀ`.
pub fn interpolative(a: &Matrix, k: usize) -> ColumnId {
    let n = a.cols;
    let k = k.min(a.rows).min(n).max(1);
    let (_q, r, perm) = qr_pivoted(a);
    // R11: k×k upper-triangular; R12: k×(n-k).
    let r11 = r.submatrix(0, k, 0, k);
    let r12 = r.submatrix(0, k, k, n);
    // Solve R11 · X = R12 by back substitution, column by column (two
    // reusable buffers instead of two fresh Vecs per column).
    let mut x = Matrix::zeros(k, n - k);
    let mut b = vec![0.0; k];
    let mut col = vec![0.0; k];
    for j in 0..(n - k) {
        r12.col_into(j, &mut b);
        for i in (0..k).rev() {
            let mut s = b[i];
            for l in (i + 1)..k {
                s -= r11[(i, l)] * col[l];
            }
            let d = r11[(i, i)];
            // Guard against exact rank deficiency: a zero pivot means the
            // trailing directions carry no mass; interpolate with 0.
            col[i] = if d.abs() > 1e-300 { s / d } else { 0.0 };
        }
        x.set_col(j, &col);
    }
    // Assemble T in original column order: T[:, perm[j]] = [I | X][:, j].
    let mut t = Matrix::zeros(k, n);
    for j in 0..k {
        t[(j, perm[j])] = 1.0;
    }
    for j in 0..(n - k) {
        for i in 0..k {
            t[(i, perm[k + j])] = x[(i, j)];
        }
    }
    let cols: Vec<usize> = perm[..k].to_vec();
    let c = a.select_cols(&cols);
    ColumnId { cols, c, t }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd_thin;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    #[test]
    fn exact_on_low_rank_input() {
        check("ID exact when k >= rank(A)", 15, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(4, 16);
            let n = g.usize_in(4, 16);
            let r = g.usize_in(1, m.min(n));
            let b = Matrix::randn(m, r, 1.0, &mut rng);
            let c = Matrix::randn(r, n, 1.0, &mut rng);
            let a = b.matmul(&c);
            let id = interpolative(&a, r);
            ok(
                id.reconstruct().dist(&a) < 1e-7 * (1.0 + a.fro_norm()),
                "exact reconstruction",
            )
        });
    }

    #[test]
    fn skeleton_columns_are_actual_columns() {
        let mut rng = Rng::new(15);
        let a = Matrix::randn(10, 12, 1.0, &mut rng);
        let id = interpolative(&a, 5);
        for (jj, &j) in id.cols.iter().enumerate() {
            assert_eq!(id.c.col(jj), a.col(j));
        }
        // T restricted to skeleton columns is the identity.
        for (jj, &j) in id.cols.iter().enumerate() {
            for i in 0..id.rank() {
                let expect = if i == jj { 1.0 } else { 0.0 };
                assert!((id.t[(i, j)] - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn id_error_is_within_factor_of_svd_optimum() {
        // Theory: pivoted-QR ID error ≤ (1 + √(k(n-k))) σ_{k+1}; we assert a
        // loose multiple of the Eckart–Young optimum on random inputs.
        check("ID near-optimality", 10, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(6, 18);
            let n = g.usize_in(6, 18);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let k = g.usize_in(1, m.min(n));
            let id_err = interpolative(&a, k).reconstruct().dist(&a);
            let svd = svd_thin(&a);
            let opt = svd.tail_norm(k);
            let bound = (1.0 + (k as f64 * (n.saturating_sub(k)) as f64).sqrt()) * 4.0;
            ok(
                id_err <= bound * opt + 1e-9,
                &format!("id_err={id_err}, opt={opt}, bound factor={bound}"),
            )
        });
    }

    #[test]
    fn rank_one_id() {
        let mut rng = Rng::new(16);
        let u = Matrix::randn(8, 1, 1.0, &mut rng);
        let v = Matrix::randn(1, 6, 1.0, &mut rng);
        let a = u.matmul(&v);
        let id = interpolative(&a, 1);
        assert!(id.reconstruct().dist(&a) < 1e-9);
    }

    #[test]
    fn requested_rank_is_clamped() {
        let mut rng = Rng::new(17);
        let a = Matrix::randn(4, 6, 1.0, &mut rng);
        let id = interpolative(&a, 100);
        assert_eq!(id.rank(), 4); // min(m, n, k)
    }
}
