//! Cholesky factorization with PSD-safe handling for empirical Grams.
//!
//! The ASVD-I / SVD-LLM whitening needs `S` with `S Sᵀ = X Xᵀ`.  Empirical
//! Gram matrices are only positive *semi*-definite (rank-deficient when the
//! calibration sample is small or features are correlated), so a plain
//! Cholesky breaks down — exactly the weakness the paper's §3 cites when
//! motivating the SVD-based ASVD-II.  We reproduce the standard fix used by
//! SVD-LLM: retry with an increasing diagonal ridge until the factorization
//! succeeds, and report the ridge that was needed.

use super::matrix::Matrix;
use anyhow::{bail, Result};

/// Strict Cholesky: `A = L Lᵀ` with L lower-triangular.
/// Fails if `A` is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        let mut d = a[(j, j)];
        for k in 0..j {
            d -= l[(j, k)] * l[(j, k)];
        }
        if d <= 0.0 || !d.is_finite() {
            bail!("matrix not positive definite at pivot {j} (d={d:.3e})");
        }
        let djj = d.sqrt();
        l[(j, j)] = djj;
        for i in (j + 1)..n {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            l[(i, j)] = s / djj;
        }
    }
    Ok(l)
}

/// PSD-safe Cholesky: adds `ridge = eps * mean(diag)` and doubles it until
/// the factorization succeeds.  Returns `(L, ridge_used)`.
pub fn cholesky_psd(a: &Matrix, base_eps: f64) -> (Matrix, f64) {
    let n = a.rows;
    let mean_diag = (0..n).map(|i| a[(i, i)]).sum::<f64>().max(1e-30) / n as f64;
    let mut eps = base_eps;
    loop {
        let mut aj = a.clone();
        let ridge = eps * mean_diag;
        for i in 0..n {
            aj[(i, i)] += ridge;
        }
        if let Ok(l) = cholesky(&aj) {
            return (l, ridge);
        }
        eps *= 10.0;
        assert!(eps < 1.0, "cholesky_psd failed even with huge ridge");
    }
}

/// Solve `L y = b` (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    y
}

/// Solve `U x = b` (back substitution), U upper-triangular.
pub fn solve_upper(u: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = u.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= u[(i, k)] * x[k];
        }
        x[i] = s / u[(i, i)];
    }
    x
}

/// Inverse of a lower-triangular matrix (column-by-column forward solves).
pub fn invert_lower(l: &Matrix) -> Matrix {
    let n = l.rows;
    let mut inv = Matrix::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        let col = solve_lower(l, &e);
        inv.set_col(j, &col);
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        // B Bᵀ + n·I is safely positive definite.
        let b = Matrix::randn(n, n, 1.0, rng);
        let mut a = b.matmul_nt(&b);
        for i in 0..n {
            a[(i, i)] += n as f64 * 0.1 + 0.5;
        }
        a
    }

    #[test]
    fn cholesky_reconstructs_spd() {
        check("A = LLᵀ", 25, |g| {
            let mut rng = g.rng.fork(0);
            let n = g.usize_in(1, 20);
            let a = random_spd(n, &mut rng);
            let l = cholesky(&a).map_err(|e| e.to_string())?;
            ok(l.matmul_nt(&l).dist(&a) < 1e-8 * (1.0 + a.fro_norm()), "LLᵀ=A")?;
            for i in 0..n {
                ok(l[(i, i)] > 0.0, "positive diagonal")?;
                for j in (i + 1)..n {
                    ok(l[(i, j)] == 0.0, "upper zero")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig -1, 3
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn cholesky_psd_handles_rank_deficient_gram() {
        let mut rng = Rng::new(7);
        // Gram of 3 samples in R^6: rank <= 3.
        let x = Matrix::randn(6, 3, 1.0, &mut rng);
        let gram = x.matmul_nt(&x);
        assert!(cholesky(&gram).is_err(), "strict cholesky should fail");
        let (l, ridge) = cholesky_psd(&gram, 1e-8);
        assert!(ridge > 0.0);
        // LLᵀ ≈ gram + ridge·I.
        let recon = l.matmul_nt(&l);
        let mut target = gram.clone();
        for i in 0..6 {
            target[(i, i)] += ridge;
        }
        assert!(recon.dist(&target) < 1e-7);
    }

    #[test]
    fn triangular_solves_invert() {
        check("solve_lower/upper", 20, |g| {
            let mut rng = g.rng.fork(0);
            let n = g.usize_in(1, 15);
            let a = random_spd(n, &mut rng);
            let l = cholesky(&a).map_err(|e| e.to_string())?;
            let b: Vec<f64> = rng.normal_vec(n);
            let y = solve_lower(&l, &b);
            let ly = l.matvec(&y);
            for i in 0..n {
                ok((ly[i] - b[i]).abs() < 1e-8, "Ly=b")?;
            }
            let u = l.transpose();
            let x = solve_upper(&u, &b);
            let ux = u.matvec(&x);
            for i in 0..n {
                ok((ux[i] - b[i]).abs() < 1e-8, "Ux=b")?;
            }
            Ok(())
        });
    }

    #[test]
    fn invert_lower_gives_inverse() {
        let mut rng = Rng::new(8);
        let a = random_spd(8, &mut rng);
        let l = cholesky(&a).unwrap();
        let linv = invert_lower(&l);
        assert!(l.matmul(&linv).dist(&Matrix::identity(8)) < 1e-8);
        assert!(linv.matmul(&l).dist(&Matrix::identity(8)) < 1e-8);
    }
}
