//! Householder QR, thin QR, LQ, and column-pivoted (rank-revealing) QR.

use super::matrix::Matrix;

/// Thin QR: `A (m×n) = Q (m×r) R (r×n)` with `r = min(m, n)`,
/// Q having orthonormal columns and R upper-triangular.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    let r = m.min(n);
    let mut work = a.clone(); // becomes R in its upper triangle
    // Householder vectors live in one flat arena (stride m; reflector k uses
    // the first m-k entries) with their squared norms cached — the old
    // per-column `Vec` allocations were measurable in the decomposition
    // inner loops that call QR per sketch / per sweep.
    let mut varena = vec![0.0; r * m];
    let mut vnorm2s = vec![0.0; r];
    for k in 0..r {
        // Build the Householder vector for column k below the diagonal.
        let mut norm2 = 0.0;
        for i in k..m {
            let x = work[(i, k)];
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        if norm <= f64::MIN_POSITIVE {
            continue; // zero column: identity reflector (arena stays zero)
        }
        let alpha = if work[(k, k)] >= 0.0 { -norm } else { norm };
        let vnorm2 = {
            let v = &mut varena[k * m..k * m + (m - k)];
            v[0] = work[(k, k)] - alpha;
            for i in (k + 1)..m {
                v[i - k] = work[(i, k)];
            }
            v.iter().map(|x| x * x).sum::<f64>()
        };
        if vnorm2 <= f64::MIN_POSITIVE {
            varena[k * m..k * m + (m - k)].iter_mut().for_each(|x| *x = 0.0);
            continue;
        }
        vnorm2s[k] = vnorm2;
        // Apply H = I - 2 v vᵀ / (vᵀv) to work[k.., k..].
        let v = &varena[k * m..k * m + (m - k)];
        for j in k..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * work[(i, j)];
            }
            let beta = 2.0 * dot / vnorm2;
            for i in k..m {
                work[(i, j)] -= beta * v[i - k];
            }
        }
    }
    // R: upper triangle of work, first r rows.
    let mut rmat = Matrix::zeros(r, n);
    for i in 0..r {
        for j in i..n {
            rmat[(i, j)] = work[(i, j)];
        }
    }
    // Q: apply reflectors in reverse to the first r columns of I.
    let mut q = Matrix::zeros(m, r);
    for i in 0..r {
        q[(i, i)] = 1.0;
    }
    for k in (0..r).rev() {
        let vnorm2 = vnorm2s[k];
        if vnorm2 <= f64::MIN_POSITIVE {
            continue;
        }
        let v = &varena[k * m..k * m + (m - k)];
        for j in 0..r {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[(i, j)];
            }
            let beta = 2.0 * dot / vnorm2;
            for i in k..m {
                q[(i, j)] -= beta * v[i - k];
            }
        }
    }
    (q, rmat)
}

/// LQ decomposition: `A (m×n) = L (m×r) Q (r×n)` with L lower-triangular and
/// Q having orthonormal rows; computed via QR of `Aᵀ`.
pub fn lq(a: &Matrix) -> (Matrix, Matrix) {
    let (q, r) = qr_thin(&a.transpose());
    (r.transpose(), q.transpose())
}

/// Column-pivoted QR: returns `(Q, R, perm)` with `A[:, perm] = Q R` and the
/// diagonal of R non-increasing in magnitude — the rank-revealing property
/// the interpolative decomposition builds on.
pub fn qr_pivoted(a: &Matrix) -> (Matrix, Matrix, Vec<usize>) {
    let (m, n) = (a.rows, a.cols);
    let r = m.min(n);
    let mut work = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut colnorm2: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| work[(i, j)] * work[(i, j)]).sum())
        .collect();
    // Same flat Householder arena as `qr_thin` (no per-column Vec allocs).
    let mut varena = vec![0.0; r * m];
    let mut vnorm2s = vec![0.0; r];
    for k in 0..r {
        // Pivot: bring the column with largest remaining norm to position k.
        let (jmax, _) = colnorm2
            .iter()
            .enumerate()
            .skip(k)
            .fold((k, -1.0), |(bj, bv), (j, &v)| if v > bv { (j, v) } else { (bj, bv) });
        if jmax != k {
            for i in 0..m {
                let t = work[(i, k)];
                work[(i, k)] = work[(i, jmax)];
                work[(i, jmax)] = t;
            }
            perm.swap(k, jmax);
            colnorm2.swap(k, jmax);
        }
        // Householder on column k.
        let mut norm2 = 0.0;
        for i in k..m {
            norm2 += work[(i, k)] * work[(i, k)];
        }
        let norm = norm2.sqrt();
        if norm > f64::MIN_POSITIVE {
            let alpha = if work[(k, k)] >= 0.0 { -norm } else { norm };
            let vnorm2 = {
                let v = &mut varena[k * m..k * m + (m - k)];
                v[0] = work[(k, k)] - alpha;
                for i in (k + 1)..m {
                    v[i - k] = work[(i, k)];
                }
                v.iter().map(|x| x * x).sum::<f64>()
            };
            vnorm2s[k] = vnorm2;
            if vnorm2 > f64::MIN_POSITIVE {
                let v = &varena[k * m..k * m + (m - k)];
                for j in k..n {
                    let mut dot = 0.0;
                    for i in k..m {
                        dot += v[i - k] * work[(i, j)];
                    }
                    let beta = 2.0 * dot / vnorm2;
                    for i in k..m {
                        work[(i, j)] -= beta * v[i - k];
                    }
                }
            }
        }
        // Downdate remaining column norms.
        for j in (k + 1)..n {
            let x = work[(k, j)];
            colnorm2[j] = (colnorm2[j] - x * x).max(0.0);
        }
        colnorm2[k] = 0.0;
    }
    let mut rmat = Matrix::zeros(r, n);
    for i in 0..r {
        for j in i..n {
            rmat[(i, j)] = work[(i, j)];
        }
    }
    let mut q = Matrix::zeros(m, r);
    for i in 0..r {
        q[(i, i)] = 1.0;
    }
    for k in (0..r).rev() {
        let vnorm2 = vnorm2s[k];
        if vnorm2 <= f64::MIN_POSITIVE {
            continue;
        }
        let v = &varena[k * m..k * m + (m - k)];
        for j in 0..r {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[(i, j)];
            }
            let beta = 2.0 * dot / vnorm2;
            for i in k..m {
                q[(i, j)] -= beta * v[i - k];
            }
        }
    }
    (q, rmat, perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    fn orthonormal_cols(q: &Matrix, tol: f64) -> bool {
        let gram = q.matmul_tn(q);
        gram.dist(&Matrix::identity(q.cols)) < tol
    }

    #[test]
    fn qr_reconstructs_random_matrices() {
        check("A = QR", 25, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 20);
            let n = g.usize_in(1, 20);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (q, r) = qr_thin(&a);
            ok(q.matmul(&r).dist(&a) < 1e-9 * (1.0 + a.fro_norm()), "A=QR")?;
            ok(orthonormal_cols(&q, 1e-9), "QᵀQ=I")?;
            // R upper-triangular
            for i in 0..r.rows {
                for j in 0..i.min(r.cols) {
                    ok(r[(i, j)].abs() < 1e-12, "R lower part zero")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        let mut rng = Rng::new(5);
        // Rank-2 matrix 6x4.
        let b = Matrix::randn(6, 2, 1.0, &mut rng);
        let c = Matrix::randn(2, 4, 1.0, &mut rng);
        let a = b.matmul(&c);
        let (q, r) = qr_thin(&a);
        assert!(q.matmul(&r).dist(&a) < 1e-9);
    }

    #[test]
    fn lq_reconstructs_and_orthonormal_rows() {
        check("A = LQ", 20, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 15);
            let n = g.usize_in(1, 15);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (l, q) = lq(&a);
            ok(l.matmul(&q).dist(&a) < 1e-9 * (1.0 + a.fro_norm()), "A=LQ")?;
            let gram = q.matmul_nt(&q);
            ok(gram.dist(&Matrix::identity(q.rows)) < 1e-9, "QQᵀ=I")?;
            // L lower-triangular
            for i in 0..l.rows {
                for j in (i + 1)..l.cols {
                    ok(l[(i, j)].abs() < 1e-12, "L upper part zero")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pivoted_qr_reconstructs_with_permutation() {
        check("A[:,perm] = QR (pivoted)", 20, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(2, 15);
            let n = g.usize_in(2, 15);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (q, r, perm) = qr_pivoted(&a);
            let ap = a.select_cols(&perm);
            ok(q.matmul(&r).dist(&ap) < 1e-9 * (1.0 + a.fro_norm()), "A[:,p]=QR")?;
            ok(orthonormal_cols(&q, 1e-9), "QᵀQ=I")?;
            // Rank-revealing: |R[k,k]| non-increasing.
            let d = r.diagonal();
            for w in d.windows(2) {
                ok(w[0].abs() + 1e-9 >= w[1].abs(), "diag non-increasing")?;
            }
            Ok(())
        });
    }

    #[test]
    fn pivoted_qr_reveals_rank() {
        let mut rng = Rng::new(6);
        let b = Matrix::randn(10, 3, 1.0, &mut rng);
        let c = Matrix::randn(3, 8, 1.0, &mut rng);
        let a = b.matmul(&c); // rank 3
        let (_, r, _) = qr_pivoted(&a);
        let d = r.diagonal();
        assert!(d[2].abs() > 1e-6, "first 3 pivots significant");
        for &x in &d[3..] {
            assert!(x.abs() < 1e-8, "trailing pivots vanish, got {x}");
        }
    }
}
