//! Householder QR, thin QR, LQ, and column-pivoted (rank-revealing) QR.
//!
//! [`qr_thin`] is a **blocked compact-WY** factorization: reflectors are
//! computed one panel ([`QR_NB`] columns) at a time with the classic
//! level-2 loop, then the whole panel is applied to the trailing matrix as
//! `(I − V T Vᵀ)ᵀ A₂ = A₂ − V (Tᵀ (Vᵀ A₂))` — two GEMMs through the tiled
//! kernel plus a small triangular multiply — so the O(mn²) bulk of the
//! factorization rides the kernel layer ([`crate::linalg::gemm`]) instead
//! of one reflector-at-a-time level-2 updates.  Q is accumulated the same
//! way (panels applied to the identity in reverse, two GEMMs each).  The
//! rSVD range finder calls this per sketch; the speedup is tracked by
//! `benches/perf_linalg.rs` against [`qr_thin_unblocked`], the retired
//! level-2 path kept as the parity reference.
//!
//! [`qr_pivoted`] keeps its sequential factorization loop — column
//! pivoting needs the updated column norms after every reflector, which is
//! inherently level-2 — but forms Q through the same blocked compact-WY
//! apply.  Its R, pivot sequence, and therefore everything the column-ID
//! path ([`crate::linalg::id`]) consumes are bit-identical to the retired
//! [`qr_pivoted_unblocked`] (pinned by tests below).

use super::gemm;
use super::matrix::Matrix;

/// Panel width of the blocked QR (columns factored level-2 before each
/// compact-WY trailing update).  32 balances the O(m·NB²) panel work
/// against GEMM efficiency at the d_model..d_ff sizes the engine hits.
pub const QR_NB: usize = 32;

// ---------------------------------------------------------------------------
// Householder + compact-WY building blocks.
// ---------------------------------------------------------------------------

/// Compute the Householder reflector annihilating column `col` of `work`
/// below row `k`, in the normalized convention `H = I − τ u uᵀ` with
/// `u[0] = 1`.  Writes `u` into `u_out` (length `m − k`), sets the column
/// to its post-reflection value `(α, 0, …)ᵀ`, and returns `τ` (0 for a
/// numerically zero column, i.e. `H = I` and the column left untouched).
fn house(work: &mut Matrix, k: usize, col: usize, u_out: &mut [f64]) -> f64 {
    let m = work.rows;
    let mut norm2 = 0.0;
    for i in k..m {
        let x = work[(i, col)];
        norm2 += x * x;
    }
    let norm = norm2.sqrt();
    if norm <= f64::MIN_POSITIVE {
        u_out.iter_mut().for_each(|x| *x = 0.0);
        return 0.0;
    }
    let x0 = work[(k, col)];
    let alpha = if x0 >= 0.0 { -norm } else { norm };
    // v₀ = x₀ − α = x₀ + sign(x₀)·‖x‖ never cancels (|v₀| ≥ ‖x‖ > 0).
    let v0 = x0 - alpha;
    u_out[0] = 1.0;
    let mut unorm2 = 1.0;
    for i in (k + 1)..m {
        let ui = work[(i, col)] / v0;
        u_out[i - k] = ui;
        unorm2 += ui * ui;
    }
    work[(k, col)] = alpha;
    for i in (k + 1)..m {
        work[(i, col)] = 0.0;
    }
    2.0 / unorm2
}

/// Apply `H = I − τ u uᵀ` (acting on rows `k..m`) to columns `cols` of
/// `work` — the level-2 update used inside a panel.
fn apply_house(work: &mut Matrix, k: usize, u: &[f64], tau: f64, cols: std::ops::Range<usize>) {
    let m = work.rows;
    for j in cols {
        let mut dot = 0.0;
        for i in k..m {
            dot += u[i - k] * work[(i, j)];
        }
        let beta = tau * dot;
        for i in k..m {
            work[(i, j)] -= beta * u[i - k];
        }
    }
}

/// Assemble the dense unit-lower-trapezoidal reflector block `V`
/// (`(m − k0) × (k1 − k0)`) for reflectors `k0..k1` stored in the
/// normalized arena (reflector `k` at `varena[k·m ..]`, length `m − k`).
fn panel_v(varena: &[f64], m: usize, k0: usize, k1: usize) -> Matrix {
    let nb = k1 - k0;
    let mut v = Matrix::zeros(m - k0, nb);
    for jj in 0..nb {
        let k = k0 + jj;
        let u = &varena[k * m..k * m + (m - k)];
        for (i, &ui) in u.iter().enumerate() {
            v[(jj + i, jj)] = ui;
        }
    }
    v
}

/// The compact-WY `T` factor (upper triangular, LAPACK `larft` forward
/// columnwise recurrence): `H₁ H₂ ⋯ H_nb = I − V T Vᵀ`.  A zero `τ`
/// yields an all-zero row and column of `T`, i.e. that reflector drops out
/// of the block exactly.
fn build_t(v: &Matrix, taus: &[f64]) -> Matrix {
    let nb = v.cols;
    let mut t = Matrix::zeros(nb, nb);
    let mut w = vec![0.0; nb];
    for j in 0..nb {
        let tau = taus[j];
        if tau == 0.0 {
            continue;
        }
        // w = V[:, 0..j]ᵀ v_j (v_j vanishes above its unit at row j).
        for (p, wp) in w.iter_mut().enumerate().take(j) {
            let mut s = 0.0;
            for i in j..v.rows {
                s += v[(i, p)] * v[(i, j)];
            }
            *wp = s;
        }
        for p in 0..j {
            let mut s = 0.0;
            for l in p..j {
                s += t[(p, l)] * w[l];
            }
            t[(p, j)] = -tau * s;
        }
        t[(j, j)] = tau;
    }
    t
}

/// `−Tᵀ·W` for upper-triangular `T` (the negation folds the block
/// reflector's subtraction into the accumulate-only GEMM that follows).
fn neg_trmm_upper_t(t: &Matrix, w: &Matrix) -> Matrix {
    let nb = t.rows;
    let mut out = Matrix::zeros(nb, w.cols);
    for p in 0..nb {
        for c in 0..w.cols {
            let mut s = 0.0;
            for l in 0..=p {
                s += t[(l, p)] * w[(l, c)];
            }
            out[(p, c)] = -s;
        }
    }
    out
}

/// `−T·W` for upper-triangular `T` (the Q-formation variant: panels are
/// applied un-transposed when accumulating Q).
fn neg_trmm_upper(t: &Matrix, w: &Matrix) -> Matrix {
    let nb = t.rows;
    let mut out = Matrix::zeros(nb, w.cols);
    for p in 0..nb {
        for c in 0..w.cols {
            let mut s = 0.0;
            for l in p..nb {
                s += t[(p, l)] * w[(l, c)];
            }
            out[(p, c)] = -s;
        }
    }
    out
}

/// Accumulate `Q = H₀ H₁ ⋯ H_{r−1} · [I_r; 0]` (m×r, orthonormal columns)
/// by applying the stored reflector panels to the identity in reverse,
/// each as `Q ← Q − V (T (Vᵀ Q))` — two GEMMs per panel on the contiguous
/// trailing row block `Q[k0.., :]`.
fn form_q_blocked(varena: &[f64], taus: &[f64], m: usize, r: usize) -> Matrix {
    let mut q = Matrix::zeros(m, r);
    for i in 0..r {
        q[(i, i)] = 1.0;
    }
    let mut panel_starts: Vec<usize> = (0..r).step_by(QR_NB).collect();
    panel_starts.reverse();
    for k0 in panel_starts {
        let k1 = (k0 + QR_NB).min(r);
        if taus[k0..k1].iter().all(|&t| t == 0.0) {
            continue;
        }
        let v = panel_v(varena, m, k0, k1);
        let t = build_t(&v, &taus[k0..k1]);
        let nb = k1 - k0;
        let rows = m - k0;
        // W = Vᵀ Q[k0.., :] — the trailing rows of Q are contiguous.
        let mut w = Matrix::zeros(nb, r);
        gemm::gemm_tn(nb, rows, r, &v.data, &q.data[k0 * r..], &mut w.data, gemm::workers());
        let w2 = neg_trmm_upper(&t, &w);
        gemm::gemm_nn(rows, nb, r, &v.data, &w2.data, &mut q.data[k0 * r..], gemm::workers());
    }
    q
}

// ---------------------------------------------------------------------------
// Thin QR (blocked) + the retired unblocked reference.
// ---------------------------------------------------------------------------

/// Thin QR: `A (m×n) = Q (m×r) R (r×n)` with `r = min(m, n)`,
/// Q having orthonormal columns and R upper-triangular.  Blocked
/// compact-WY: see the module docs.
pub fn qr_thin(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    let mut sp = crate::obs::span("kernel.qr_thin");
    if sp.is_recording() {
        sp.arg_u64("m", m as u64).arg_u64("n", n as u64);
    }
    let r = m.min(n);
    let mut work = a.clone();
    // Normalized Householder arena (stride m; reflector k uses the first
    // m−k entries, u[0] = 1) plus the τ scalars — everything the compact-WY
    // panels and the blocked Q formation need.
    let mut varena = vec![0.0; r * m];
    let mut taus = vec![0.0; r];
    let mut k0 = 0;
    while k0 < r {
        let k1 = (k0 + QR_NB).min(r);
        // Panel factorization (level 2, panel columns only).
        for k in k0..k1 {
            let tau = house(&mut work, k, k, &mut varena[k * m..k * m + (m - k)]);
            taus[k] = tau;
            if tau != 0.0 && k + 1 < k1 {
                apply_house(&mut work, k, &varena[k * m..k * m + (m - k)], tau, (k + 1)..k1);
            }
        }
        // Compact-WY trailing update: A₂ ← A₂ − V (Tᵀ (Vᵀ A₂)).
        if k1 < n && taus[k0..k1].iter().any(|&t| t != 0.0) {
            let v = panel_v(&varena, m, k0, k1);
            let t = build_t(&v, &taus[k0..k1]);
            let mut a2 = work.submatrix(k0, m, k1, n);
            let w = v.matmul_tn(&a2);
            let w2 = neg_trmm_upper_t(&t, &w);
            gemm::gemm_nn(m - k0, k1 - k0, n - k1, &v.data, &w2.data, &mut a2.data, gemm::workers());
            for i in k0..m {
                for j in k1..n {
                    work[(i, j)] = a2[(i - k0, j - k1)];
                }
            }
        }
        k0 = k1;
    }
    // R: upper triangle of work, first r rows.
    let mut rmat = Matrix::zeros(r, n);
    for i in 0..r {
        for j in i..n {
            rmat[(i, j)] = work[(i, j)];
        }
    }
    let q = form_q_blocked(&varena, &taus, m, r);
    (q, rmat)
}

/// The retired unblocked (level-2) thin QR, kept as the parity reference
/// for the property tests and the speedup baseline for
/// `benches/perf_linalg.rs`.
pub fn qr_thin_unblocked(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    let r = m.min(n);
    let mut work = a.clone(); // becomes R in its upper triangle
    // Householder vectors live in one flat arena (stride m; reflector k uses
    // the first m-k entries) with their squared norms cached.
    let mut varena = vec![0.0; r * m];
    let mut vnorm2s = vec![0.0; r];
    for k in 0..r {
        // Build the Householder vector for column k below the diagonal.
        let mut norm2 = 0.0;
        for i in k..m {
            let x = work[(i, k)];
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        if norm <= f64::MIN_POSITIVE {
            continue; // zero column: identity reflector (arena stays zero)
        }
        let alpha = if work[(k, k)] >= 0.0 { -norm } else { norm };
        let vnorm2 = {
            let v = &mut varena[k * m..k * m + (m - k)];
            v[0] = work[(k, k)] - alpha;
            for i in (k + 1)..m {
                v[i - k] = work[(i, k)];
            }
            v.iter().map(|x| x * x).sum::<f64>()
        };
        if vnorm2 <= f64::MIN_POSITIVE {
            varena[k * m..k * m + (m - k)].iter_mut().for_each(|x| *x = 0.0);
            continue;
        }
        vnorm2s[k] = vnorm2;
        // Apply H = I - 2 v vᵀ / (vᵀv) to work[k.., k..].
        let v = &varena[k * m..k * m + (m - k)];
        for j in k..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * work[(i, j)];
            }
            let beta = 2.0 * dot / vnorm2;
            for i in k..m {
                work[(i, j)] -= beta * v[i - k];
            }
        }
    }
    // R: upper triangle of work, first r rows.
    let mut rmat = Matrix::zeros(r, n);
    for i in 0..r {
        for j in i..n {
            rmat[(i, j)] = work[(i, j)];
        }
    }
    // Q: apply reflectors in reverse to the first r columns of I.
    let mut q = Matrix::zeros(m, r);
    for i in 0..r {
        q[(i, i)] = 1.0;
    }
    for k in (0..r).rev() {
        let vnorm2 = vnorm2s[k];
        if vnorm2 <= f64::MIN_POSITIVE {
            continue;
        }
        let v = &varena[k * m..k * m + (m - k)];
        for j in 0..r {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[(i, j)];
            }
            let beta = 2.0 * dot / vnorm2;
            for i in k..m {
                q[(i, j)] -= beta * v[i - k];
            }
        }
    }
    (q, rmat)
}

/// LQ decomposition: `A (m×n) = L (m×r) Q (r×n)` with L lower-triangular and
/// Q having orthonormal rows; computed via QR of `Aᵀ`.
pub fn lq(a: &Matrix) -> (Matrix, Matrix) {
    let mut sp = crate::obs::span("kernel.lq");
    if sp.is_recording() {
        sp.arg_u64("m", a.rows as u64).arg_u64("n", a.cols as u64);
    }
    let (q, r) = qr_thin(&a.transpose());
    (r.transpose(), q.transpose())
}

// ---------------------------------------------------------------------------
// Column-pivoted QR.
// ---------------------------------------------------------------------------

/// Column-pivoted QR: returns `(Q, R, perm)` with `A[:, perm] = Q R` and the
/// diagonal of R non-increasing in magnitude — the rank-revealing property
/// the interpolative decomposition builds on.
///
/// The factorization loop is sequential (pivot selection needs the updated
/// column norms after every reflector); `R` and `perm` are bit-identical to
/// [`qr_pivoted_unblocked`].  Q is formed through the blocked compact-WY
/// apply ([`form_q_blocked`]), which is where the level-3 speedup lives.
pub fn qr_pivoted(a: &Matrix) -> (Matrix, Matrix, Vec<usize>) {
    let mut sp = crate::obs::span("kernel.qr_pivoted");
    if sp.is_recording() {
        sp.arg_u64("m", a.rows as u64).arg_u64("n", a.cols as u64);
    }
    let (work, varena, vnorm2s, perm) = qr_pivoted_factor(a);
    let (m, n) = (a.rows, a.cols);
    let r = m.min(n);
    let mut rmat = Matrix::zeros(r, n);
    for i in 0..r {
        for j in i..n {
            rmat[(i, j)] = work[(i, j)];
        }
    }
    // Convert the unnormalized arena (v, ‖v‖²) to the normalized one
    // (u = v/v₀, τ = 2v₀²/‖v‖²) the compact-WY panels consume.
    let mut uarena = vec![0.0; r * m];
    let mut taus = vec![0.0; r];
    for k in 0..r {
        let vnorm2 = vnorm2s[k];
        if vnorm2 <= f64::MIN_POSITIVE {
            continue;
        }
        let v = &varena[k * m..k * m + (m - k)];
        let v0 = v[0]; // x₀ + sign(x₀)·‖x‖: never zero when ‖v‖² > 0
        let u = &mut uarena[k * m..k * m + (m - k)];
        u[0] = 1.0;
        for i in 1..v.len() {
            u[i] = v[i] / v0;
        }
        taus[k] = 2.0 * v0 * v0 / vnorm2;
    }
    let q = form_q_blocked(&uarena, &taus, m, r);
    (q, rmat, perm)
}

/// The shared sequential pivoted factorization: returns the reduced
/// `work` (R in its upper triangle), the unnormalized Householder arena +
/// squared norms, and the pivot permutation.
#[allow(clippy::type_complexity)]
fn qr_pivoted_factor(a: &Matrix) -> (Matrix, Vec<f64>, Vec<f64>, Vec<usize>) {
    let (m, n) = (a.rows, a.cols);
    let r = m.min(n);
    let mut work = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut colnorm2: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| work[(i, j)] * work[(i, j)]).sum())
        .collect();
    // Same flat Householder arena as the thin path (no per-column allocs).
    let mut varena = vec![0.0; r * m];
    let mut vnorm2s = vec![0.0; r];
    for k in 0..r {
        // Pivot: bring the column with largest remaining norm to position k.
        let (jmax, _) = colnorm2
            .iter()
            .enumerate()
            .skip(k)
            .fold((k, -1.0), |(bj, bv), (j, &v)| if v > bv { (j, v) } else { (bj, bv) });
        if jmax != k {
            for i in 0..m {
                let t = work[(i, k)];
                work[(i, k)] = work[(i, jmax)];
                work[(i, jmax)] = t;
            }
            perm.swap(k, jmax);
            colnorm2.swap(k, jmax);
        }
        // Householder on column k.
        let mut norm2 = 0.0;
        for i in k..m {
            norm2 += work[(i, k)] * work[(i, k)];
        }
        let norm = norm2.sqrt();
        if norm > f64::MIN_POSITIVE {
            let alpha = if work[(k, k)] >= 0.0 { -norm } else { norm };
            let vnorm2 = {
                let v = &mut varena[k * m..k * m + (m - k)];
                v[0] = work[(k, k)] - alpha;
                for i in (k + 1)..m {
                    v[i - k] = work[(i, k)];
                }
                v.iter().map(|x| x * x).sum::<f64>()
            };
            vnorm2s[k] = vnorm2;
            if vnorm2 > f64::MIN_POSITIVE {
                let v = &varena[k * m..k * m + (m - k)];
                for j in k..n {
                    let mut dot = 0.0;
                    for i in k..m {
                        dot += v[i - k] * work[(i, j)];
                    }
                    let beta = 2.0 * dot / vnorm2;
                    for i in k..m {
                        work[(i, j)] -= beta * v[i - k];
                    }
                }
            }
        }
        // Downdate remaining column norms.
        for j in (k + 1)..n {
            let x = work[(k, j)];
            colnorm2[j] = (colnorm2[j] - x * x).max(0.0);
        }
        colnorm2[k] = 0.0;
    }
    (work, varena, vnorm2s, perm)
}

/// The retired fully-unblocked pivoted QR (reverse reflector-at-a-time Q
/// formation) — the differential reference pinning [`qr_pivoted`]'s pivot
/// agreement and Q parity.
pub fn qr_pivoted_unblocked(a: &Matrix) -> (Matrix, Matrix, Vec<usize>) {
    let (work, varena, vnorm2s, perm) = qr_pivoted_factor(a);
    let (m, n) = (a.rows, a.cols);
    let r = m.min(n);
    let mut rmat = Matrix::zeros(r, n);
    for i in 0..r {
        for j in i..n {
            rmat[(i, j)] = work[(i, j)];
        }
    }
    let mut q = Matrix::zeros(m, r);
    for i in 0..r {
        q[(i, i)] = 1.0;
    }
    for k in (0..r).rev() {
        let vnorm2 = vnorm2s[k];
        if vnorm2 <= f64::MIN_POSITIVE {
            continue;
        }
        let v = &varena[k * m..k * m + (m - k)];
        for j in 0..r {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[(i, j)];
            }
            let beta = 2.0 * dot / vnorm2;
            for i in k..m {
                q[(i, j)] -= beta * v[i - k];
            }
        }
    }
    (q, rmat, perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    fn orthonormal_cols(q: &Matrix, tol: f64) -> bool {
        let gram = q.matmul_tn(q);
        gram.dist(&Matrix::identity(q.cols)) < tol
    }

    #[test]
    fn qr_reconstructs_random_matrices() {
        check("A = QR", 25, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 20);
            let n = g.usize_in(1, 20);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (q, r) = qr_thin(&a);
            ok(q.matmul(&r).dist(&a) < 1e-9 * (1.0 + a.fro_norm()), "A=QR")?;
            ok(orthonormal_cols(&q, 1e-9), "QᵀQ=I")?;
            // R upper-triangular
            for i in 0..r.rows {
                for j in 0..i.min(r.cols) {
                    ok(r[(i, j)].abs() < 1e-12, "R lower part zero")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn blocked_qr_matches_unblocked() {
        // Sizes straddle the QR_NB = 32 panel boundary so multi-panel
        // trailing updates and Q accumulation are exercised; both paths
        // use the same sign convention, so Q and R agree to rounding.
        check("blocked QR == unblocked QR", 20, |g| {
            let mut rng = g.rng.fork(0);
            let m = *g.choose(&[3usize, 8, 31, 33, 40, 70]);
            let n = *g.choose(&[1usize, 5, 32, 45, 64]);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (qb, rb) = qr_thin(&a);
            let (qu, ru) = qr_thin_unblocked(&a);
            let scale = 1.0 + a.fro_norm();
            ok(qb.dist(&qu) < 1e-10 * scale, "Q agree")?;
            ok(rb.dist(&ru) < 1e-10 * scale, "R agree")?;
            // The acceptance bar: orthogonality of the blocked Q at 1e-12.
            ok(orthonormal_cols(&qb, 1e-12), "‖QᵀQ−I‖ ≤ 1e-12")?;
            ok(qb.matmul(&rb).dist(&a) < 1e-11 * scale, "A=QR (blocked)")?;
            Ok(())
        });
    }

    #[test]
    fn qr_handles_rank_deficiency() {
        let mut rng = Rng::new(5);
        // Rank-2 matrix 6x4.
        let b = Matrix::randn(6, 2, 1.0, &mut rng);
        let c = Matrix::randn(2, 4, 1.0, &mut rng);
        let a = b.matmul(&c);
        let (q, r) = qr_thin(&a);
        assert!(q.matmul(&r).dist(&a) < 1e-9);
    }

    #[test]
    fn qr_handles_zero_columns() {
        // A column of exact zeros → τ = 0 reflector must drop out of the
        // compact-WY block without contaminating T.
        let mut rng = Rng::new(7);
        let mut a = Matrix::randn(40, 36, 1.0, &mut rng);
        for i in 0..40 {
            a[(i, 2)] = 0.0;
        }
        let (q, r) = qr_thin(&a);
        assert!(q.matmul(&r).dist(&a) < 1e-9 * (1.0 + a.fro_norm()));
        let (qu, ru) = qr_thin_unblocked(&a);
        assert!(q.dist(&qu) < 1e-9 * (1.0 + a.fro_norm()));
        assert!(r.dist(&ru) < 1e-9 * (1.0 + a.fro_norm()));
    }

    #[test]
    fn lq_reconstructs_and_orthonormal_rows() {
        check("A = LQ", 20, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 15);
            let n = g.usize_in(1, 15);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (l, q) = lq(&a);
            ok(l.matmul(&q).dist(&a) < 1e-9 * (1.0 + a.fro_norm()), "A=LQ")?;
            let gram = q.matmul_nt(&q);
            ok(gram.dist(&Matrix::identity(q.rows)) < 1e-9, "QQᵀ=I")?;
            // L lower-triangular
            for i in 0..l.rows {
                for j in (i + 1)..l.cols {
                    ok(l[(i, j)].abs() < 1e-12, "L upper part zero")?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pivoted_qr_reconstructs_with_permutation() {
        check("A[:,perm] = QR (pivoted)", 20, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(2, 15);
            let n = g.usize_in(2, 15);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (q, r, perm) = qr_pivoted(&a);
            let ap = a.select_cols(&perm);
            ok(q.matmul(&r).dist(&ap) < 1e-9 * (1.0 + a.fro_norm()), "A[:,p]=QR")?;
            ok(orthonormal_cols(&q, 1e-9), "QᵀQ=I")?;
            // Rank-revealing: |R[k,k]| non-increasing.
            let d = r.diagonal();
            for w in d.windows(2) {
                ok(w[0].abs() + 1e-9 >= w[1].abs(), "diag non-increasing")?;
            }
            Ok(())
        });
    }

    #[test]
    fn pivoted_qr_agrees_with_unblocked_reference() {
        // Pivot agreement must be EXACT (the shared factorization makes it
        // structural, and the column-ID's skeleton selection rides on it);
        // R is bit-identical too; Q differs only by blocked-apply rounding.
        check("pivoted QR == unblocked reference", 15, |g| {
            let mut rng = g.rng.fork(0);
            let m = *g.choose(&[4usize, 20, 33, 50]);
            let n = *g.choose(&[3usize, 16, 40, 64]);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (qb, rb, pb) = qr_pivoted(&a);
            let (qu, ru, pu) = qr_pivoted_unblocked(&a);
            ok(pb == pu, "pivot agreement")?;
            ok(rb.data == ru.data, "R bit-identical")?;
            ok(qb.dist(&qu) < 1e-10 * (1.0 + a.fro_norm()), "Q agree")?;
            ok(orthonormal_cols(&qb, 1e-12), "‖QᵀQ−I‖ ≤ 1e-12")?;
            Ok(())
        });
    }

    #[test]
    fn pivoted_qr_reveals_rank() {
        let mut rng = Rng::new(6);
        let b = Matrix::randn(10, 3, 1.0, &mut rng);
        let c = Matrix::randn(3, 8, 1.0, &mut rng);
        let a = b.matmul(&c); // rank 3
        let (_, r, _) = qr_pivoted(&a);
        let d = r.diagonal();
        assert!(d[2].abs() > 1e-6, "first 3 pivots significant");
        for &x in &d[3..] {
            assert!(x.abs() < 1e-8, "trailing pivots vanish, got {x}");
        }
    }
}
