//! Row-major dense f64 matrix; all products are thin wrappers over the
//! unified tiled+packed kernel in [`super::gemm`].

use super::gemm;
use crate::util::rng::Rng;
use std::fmt;
use std::ops::{Add, Index, IndexMut, Mul, Sub};

/// Dense row-major matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Matrix { rows: r, cols: c, data: rows.concat() }
    }

    /// Matrix with iid N(0, std²) entries.
    pub fn randn(rows: usize, cols: usize, std: f64, rng: &mut Rng) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal() * std;
        }
        m
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> Matrix {
        let n = d.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    /// The main diagonal as a vector.
    pub fn diagonal(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Iterate column `j` without allocating (strided walk of the row-major
    /// buffer) — the inner-loop alternative to [`Matrix::col`].
    #[inline]
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = f64> + '_ {
        debug_assert!(j < self.cols);
        self.data
            .get(j..)
            .unwrap_or(&[])
            .iter()
            .step_by(self.cols.max(1))
            .copied()
    }

    /// Copy column `j` into `out` (`out.len() == rows`), no allocation.
    pub fn col_into(&self, j: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.rows);
        for (o, v) in out.iter_mut().zip(self.col_iter(j)) {
            *o = v;
        }
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        self.col_iter(j).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Copy of a rectangular block `[r0, r1) × [c0, c1)`.
    pub fn submatrix(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut m = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            m.row_mut(i - r0)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        m
    }

    /// Keep the first `k` columns.
    pub fn take_cols(&self, k: usize) -> Matrix {
        self.submatrix(0, self.rows, 0, k.min(self.cols))
    }

    /// Select columns by index.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(self.rows, idx.len());
        let w = idx.len();
        for (jj, &j) in idx.iter().enumerate() {
            for (i, v) in self.col_iter(j).enumerate() {
                m.data[i * w + jj] = v;
            }
        }
        m
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut m = Matrix::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            m.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            m.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        m
    }

    pub fn scale(&self, s: f64) -> Matrix {
        let mut m = self.clone();
        for v in m.data.iter_mut() {
            *v *= s;
        }
        m
    }

    /// Scale column `j` by `s[j]` (right-multiplication by diag(s)).
    pub fn scale_cols(&self, s: &[f64]) -> Matrix {
        assert_eq!(s.len(), self.cols);
        let mut m = self.clone();
        for i in 0..m.rows {
            let row = m.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v *= s[j];
            }
        }
        m
    }

    /// Scale row `i` by `s[i]` (left-multiplication by diag(s)).
    pub fn scale_rows(&self, s: &[f64]) -> Matrix {
        assert_eq!(s.len(), self.rows);
        let mut m = self.clone();
        for i in 0..m.rows {
            let si = s[i];
            for v in m.row_mut(i).iter_mut() {
                *v *= si;
            }
        }
        m
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |acc, x| acc.max(x.abs()))
    }

    /// Frobenius norm of `self - other`.
    pub fn dist(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// `self @ other` through the tiled+packed kernel ([`super::gemm`]),
    /// parallel over row blocks when the calling thread's
    /// [`gemm::workers`] share is > 1 (bit-identical either way).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(
            self.cols, other.rows,
            "matmul shape mismatch: {}x{} @ {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut c = Matrix::zeros(m, n);
        gemm::gemm_nn(m, k, n, &self.data, &other.data, &mut c.data, gemm::workers());
        c
    }

    /// `selfᵀ @ other` without materializing the transpose (packing reads
    /// the transposed layout directly).
    pub fn matmul_tn(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let (m, k, n) = (self.cols, self.rows, other.cols);
        let mut c = Matrix::zeros(m, n);
        gemm::gemm_tn(m, k, n, &self.data, &other.data, &mut c.data, gemm::workers());
        c
    }

    /// `self @ otherᵀ` without materializing the transpose (packing reads
    /// the transposed layout directly).
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut c = Matrix::zeros(m, n);
        gemm::gemm_nt(m, k, n, &self.data, &other.data, &mut c.data, gemm::workers());
        c
    }

    /// The Gram matrix `selfᵀ · self` via the packed SYRK kernel
    /// ([`gemm::syrk_tn`]): only the upper triangle is computed (half the
    /// flops of `matmul_tn(self)`) and then mirrored.  Bit-identical to
    /// `self.matmul_tn(self)` — SYRK's upper triangle matches the TN path
    /// exactly, and the TN path's lower triangle is its upper's mirror
    /// (products commute and sum in the same k-order) — at every worker
    /// count.
    pub fn gram(&self) -> Matrix {
        let n = self.cols;
        let mut c = Matrix::zeros(n, n);
        gemm::syrk_tn(n, self.rows, &self.data, &mut c.data, gemm::workers());
        for i in 0..n {
            for j in (i + 1)..n {
                c.data[j * n + i] = c.data[i * n + j];
            }
        }
        c
    }

    /// Matrix-vector product (kernel's unrolled `gemv`).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0; self.rows];
        gemm::gemv(self.rows, self.cols, &self.data, x, &mut y);
        y
    }

    /// Symmetrize in place: `(M + Mᵀ)/2` (used to de-noise Gram matrices).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// Cast to f32 (row-major), for hand-off to the model/runtime layers.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from f32 data (row-major).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Add for &Matrix {
    type Output = Matrix;
    fn add(self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut m = self.clone();
        for (a, b) in m.data.iter_mut().zip(&rhs.data) {
            *a += b;
        }
        m
    }
}

impl Sub for &Matrix {
    type Output = Matrix;
    fn sub(self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut m = self.clone();
        for (a, b) in m.data.iter_mut().zip(&rhs.data) {
            *a -= b;
        }
        m
    }
}

impl Mul for &Matrix {
    type Output = Matrix;
    fn mul(self, rhs: &Matrix) -> Matrix {
        self.matmul(rhs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(5, 7, 1.0, &mut rng);
        let i5 = Matrix::identity(5);
        let i7 = Matrix::identity(7);
        assert!(i5.matmul(&a).dist(&a) < 1e-12);
        assert!(a.matmul(&i7).dist(&a) < 1e-12);
    }

    #[test]
    fn matmul_matches_naive_small() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transposed_variants_agree() {
        check("matmul_tn/nt agree with explicit transpose", 20, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 12);
            let k = g.usize_in(1, 12);
            let n = g.usize_in(1, 12);
            let a = Matrix::randn(k, m, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let tn = a.matmul_tn(&b);
            let explicit = a.transpose().matmul(&b);
            ok(tn.dist(&explicit) < 1e-10, "tn mismatch")?;
            let c = Matrix::randn(m, k, 1.0, &mut rng);
            let d = Matrix::randn(n, k, 1.0, &mut rng);
            let nt = c.matmul_nt(&d);
            let explicit2 = c.matmul(&d.transpose());
            ok(nt.dist(&explicit2) < 1e-10, "nt mismatch")
        });
    }

    #[test]
    fn matmul_associativity_property() {
        check("(AB)C = A(BC)", 15, |g| {
            let mut rng = g.rng.fork(0);
            let (m, k, l, n) = (
                g.usize_in(1, 10),
                g.usize_in(1, 10),
                g.usize_in(1, 10),
                g.usize_in(1, 10),
            );
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, l, 1.0, &mut rng);
            let c = Matrix::randn(l, n, 1.0, &mut rng);
            let left = a.matmul(&b).matmul(&c);
            let right = a.matmul(&b.matmul(&c));
            ok(left.dist(&right) < 1e-9, "associativity")
        });
    }

    #[test]
    fn scale_rows_cols_are_diag_products() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(4, 6, 1.0, &mut rng);
        let s: Vec<f64> = (0..6).map(|i| (i + 1) as f64).collect();
        let r: Vec<f64> = (0..4).map(|i| (i + 1) as f64 * 0.5).collect();
        assert!(a.scale_cols(&s).dist(&a.matmul(&Matrix::diag(&s))) < 1e-12);
        assert!(a.scale_rows(&r).dist(&Matrix::diag(&r).matmul(&a)) < 1e-12);
    }

    #[test]
    fn submatrix_and_concat() {
        let a = Matrix::from_fn(4, 5, |i, j| (i * 5 + j) as f64);
        let s = a.submatrix(1, 3, 2, 5);
        assert_eq!(s.rows, 2);
        assert_eq!(s.cols, 3);
        assert_eq!(s[(0, 0)], 7.0);
        let left = a.take_cols(2);
        let right = a.submatrix(0, 4, 2, 5);
        assert!(left.hcat(&right).dist(&a) < 1e-15);
    }

    #[test]
    fn select_cols_picks_columns() {
        let a = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let s = a.select_cols(&[3, 1]);
        assert_eq!(s.col(0), a.col(3));
        assert_eq!(s.col(1), a.col(1));
    }

    #[test]
    fn fro_norm_matches_definition() {
        let a = Matrix::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0]]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-15);
    }

    #[test]
    fn gram_is_bitwise_matmul_tn() {
        check("AᵀA via SYRK == matmul_tn (bitwise)", 15, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 40);
            let n = g.usize_in(1, 40);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let via_syrk = a.gram();
            let via_tn = a.matmul_tn(&a);
            ok(via_syrk.data == via_tn.data, "gram != matmul_tn")
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(6, 4, 1.0, &mut rng);
        let x: Vec<f64> = rng.normal_vec(4);
        let y = a.matvec(&x);
        let xm = Matrix { rows: 4, cols: 1, data: x };
        let ym = a.matmul(&xm);
        for i in 0..6 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn f32_roundtrip() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(3, 3, 1.0, &mut rng);
        let b = Matrix::from_f32(3, 3, &a.to_f32());
        assert!(a.dist(&b) < 1e-6);
    }
}
