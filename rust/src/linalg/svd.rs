//! Thin SVD via the one-sided Jacobi method, plus truncation helpers.
//!
//! One-sided Jacobi orthogonalizes the columns of `A` by plane rotations; it
//! is simple, numerically robust (high relative accuracy for small singular
//! values), and plenty fast at the matrix sizes this system decomposes
//! (weight matrices up to ~1k on a side).  `svd_thin` handles both tall and
//! wide inputs by transposing internally.
//!
//! Two sweep orderings ([`JacobiOrdering`]):
//!
//! * **Cyclic** (default) — the historical sequential row-cyclic sweep,
//!   bit-identical to the seed pipeline.
//! * **Tournament** — each sweep is `n − 1` rounds of pairwise-disjoint
//!   column pairs (round-robin circle schedule, [`super::jacobi`]).  A
//!   round's rotations touch disjoint columns, so they are computed from
//!   the round-start matrix and dispatched over the caller's worker share;
//!   the fixed schedule makes the result **bit-identical at every worker
//!   count** (pinned below), while rotating in a different order than
//!   `Cyclic` (values agree to convergence tolerance, not bitwise).

use super::jacobi::{apply_col_rotations, tournament_rounds, PAR_MIN_ELEMS};
pub use super::jacobi::JacobiOrdering;
use super::matrix::Matrix;
use crate::util::threads::parallel_map;

/// Thin SVD `A (m×n) = U (m×r) diag(s) Vᵀ (r×n)` with `r = min(m,n)` and
/// singular values in non-increasing order.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f64>,
    pub v: Matrix, // n×r, columns are right singular vectors
}

impl Svd {
    /// Reconstruct `U diag(s) Vᵀ`.
    pub fn reconstruct(&self) -> Matrix {
        self.u.scale_cols(&self.s).matmul_nt(&self.v)
    }

    /// Rank-k truncation (Eckart–Young optimum).
    ///
    /// ```
    /// use nsvd::linalg::{svd_thin, Matrix};
    ///
    /// let a = Matrix::diag(&[3.0, 1.0, 2.0]);
    /// let top2 = svd_thin(&a).truncate(2);
    /// assert_eq!(top2.s.len(), 2);
    /// assert!((top2.s[0] - 3.0).abs() < 1e-12); // sorted: σ₁ = 3
    /// assert!((top2.s[1] - 2.0).abs() < 1e-12); //         σ₂ = 2
    /// // The rank-2 reconstruction drops exactly the σ = 1 direction.
    /// assert!((top2.reconstruct().dist(&a) - 1.0).abs() < 1e-12);
    /// ```
    pub fn truncate(&self, k: usize) -> Svd {
        let k = k.min(self.s.len());
        Svd {
            u: self.u.take_cols(k),
            s: self.s[..k].to_vec(),
            v: self.v.take_cols(k),
        }
    }

    /// Rank-k approximation as a dense matrix.
    pub fn low_rank(&self, k: usize) -> Matrix {
        self.truncate(k).reconstruct()
    }

    /// `√(Σ_{i>k} σ_i²)` — the Eckart–Young optimal error at rank k.
    pub fn tail_norm(&self, k: usize) -> f64 {
        self.s[k.min(self.s.len())..]
            .iter()
            .map(|x| x * x)
            .sum::<f64>()
            .sqrt()
    }

    /// Numerical rank at relative tolerance `rel_tol`.
    pub fn rank(&self, rel_tol: f64) -> usize {
        let smax = self.s.first().copied().unwrap_or(0.0);
        self.s.iter().filter(|&&x| x > rel_tol * smax).count()
    }

    /// Split into balanced factors `(W, Z)` with `W = U diag(√s)`,
    /// `Z = diag(√s) Vᵀ` so that `A ≈ W Z`.  Balancing keeps both factors
    /// at comparable scale, which matters when they are cast to f32.
    pub fn split_balanced(&self) -> (Matrix, Matrix) {
        let sqrt_s: Vec<f64> = self.s.iter().map(|&x| x.max(0.0).sqrt()).collect();
        let w = self.u.scale_cols(&sqrt_s);
        let z = self.v.scale_cols(&sqrt_s).transpose();
        (w, z)
    }
}

/// Compute the thin SVD of `a` by one-sided Jacobi (cyclic ordering,
/// single-threaded — bit-identical to the seed pipeline).
pub fn svd_thin(a: &Matrix) -> Svd {
    svd_thin_ordered(a, JacobiOrdering::Cyclic, 1)
}

/// Thin SVD with an explicit sweep [`JacobiOrdering`] and worker count.
/// `Cyclic` ignores `workers` (the sequential sweep is inherently ordered)
/// and reproduces [`svd_thin`] bit-for-bit; `Tournament` dispatches each
/// round's disjoint column-pair rotations over `workers` scoped threads
/// (callers inside an outer fan-out pass their
/// [`gemm::workers`](super::gemm::workers) share) with a bit-identical
/// result at every worker count.
pub fn svd_thin_ordered(a: &Matrix, ordering: JacobiOrdering, workers: usize) -> Svd {
    let mut sp = crate::obs::span("kernel.jacobi_svd");
    if sp.is_recording() {
        sp.arg_u64("m", a.rows as u64)
            .arg_u64("n", a.cols as u64)
            .arg_u64("workers", workers as u64);
    }
    if a.rows >= a.cols {
        svd_tall(a, ordering, workers)
    } else {
        // A = U S Vᵀ  ⇔  Aᵀ = V S Uᵀ.
        let t = svd_tall(&a.transpose(), ordering, workers);
        Svd { u: t.v, s: t.s, v: t.u }
    }
}

/// Column-pair Gram entries → the rotation `(c, s)` zeroing the pair's
/// off-diagonal Gram entry, or `None` when the pair is already orthogonal
/// to relative tolerance `eps` (the convergence criterion).
fn pair_rotation(w: &[f64], m: usize, p: usize, q: usize, eps: f64) -> Option<(f64, f64)> {
    let wp = &w[p * m..(p + 1) * m];
    let wq = &w[q * m..(q + 1) * m];
    let mut app = 0.0;
    let mut aqq = 0.0;
    let mut apq = 0.0;
    for (xp, xq) in wp.iter().zip(wq.iter()) {
        app += xp * xp;
        aqq += xq * xq;
        apq += xp * xq;
    }
    if apq.abs() <= eps * (app * aqq).sqrt() + 1e-300 {
        return None;
    }
    let tau = (aqq - app) / (2.0 * apq);
    let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
    let c = 1.0 / (1.0 + t * t).sqrt();
    Some((c, c * t))
}

/// Rotate columns `p < q` of the flat column-major buffer in place.
fn rotate_pair(w: &mut [f64], m: usize, p: usize, q: usize, c: f64, s: f64) {
    // p < q, so split at q's start gives disjoint column views.
    let (left, right) = w.split_at_mut(q * m);
    let wp = &mut left[p * m..(p + 1) * m];
    let wq = &mut right[..m];
    for (xp, xq) in wp.iter_mut().zip(wq.iter_mut()) {
        let a_ = *xp;
        let b_ = *xq;
        *xp = c * a_ - s * b_;
        *xq = s * a_ + c * b_;
    }
}

/// One-sided Jacobi on a tall (m ≥ n) matrix.
fn svd_tall(a: &Matrix, ordering: JacobiOrdering, workers: usize) -> Svd {
    let (m, n) = (a.rows, a.cols);
    // Work on columns of W = A; accumulate V as the product of rotations.
    // One flat column-major buffer (column j at `w[j*m..(j+1)*m]`) instead
    // of n separate Vecs: cache-friendly column ops, zero per-column allocs.
    let mut w = vec![0.0; m * n];
    for j in 0..n {
        a.col_into(j, &mut w[j * m..(j + 1) * m]);
    }
    let mut v = Matrix::identity(n);
    // Convergence threshold: 1e-12 relative off-diagonal mass gives ~1e-12
    // reconstruction error — far below the f32 cast applied to the factors —
    // and saves 1-2 Jacobi sweeps vs machine-epsilon termination.
    let eps = 1e-12;
    const MAX_SWEEPS: usize = 60;
    match ordering {
        JacobiOrdering::Cyclic => {
            for _ in 0..MAX_SWEEPS {
                let mut converged = true;
                for p in 0..n.saturating_sub(1) {
                    for q in (p + 1)..n {
                        let Some((c, s)) = pair_rotation(&w, m, p, q, eps) else {
                            continue;
                        };
                        converged = false;
                        rotate_pair(&mut w, m, p, q, c, s);
                        for i in 0..n {
                            let vp = v[(i, p)];
                            let vq = v[(i, q)];
                            v[(i, p)] = c * vp - s * vq;
                            v[(i, q)] = s * vp + c * vq;
                        }
                    }
                }
                if converged {
                    break;
                }
            }
        }
        JacobiOrdering::Tournament => {
            let rounds = tournament_rounds(n);
            for _ in 0..MAX_SWEEPS {
                let mut converged = true;
                for round in &rounds {
                    // A pair's rotation reads only its own two columns, and
                    // a round's pairs are disjoint — so the sequential
                    // in-place path and the buffered parallel path perform
                    // the exact same arithmetic per element.  Small rounds
                    // run inline: a spawn costs more than the rotations.
                    let par = workers > 1 && 2 * m * round.len() >= PAR_MIN_ELEMS;
                    let applied: Vec<(usize, usize, f64, f64)> = if !par {
                        let mut applied = Vec::new();
                        for &(p, q) in round {
                            if let Some((c, s)) = pair_rotation(&w, m, p, q, eps) {
                                rotate_pair(&mut w, m, p, q, c, s);
                                applied.push((p, q, c, s));
                            }
                        }
                        applied
                    } else {
                        let computed = parallel_map(round, workers, |_, &(p, q)| {
                            pair_rotation(&w, m, p, q, eps).map(|(c, s)| {
                                let wp = &w[p * m..(p + 1) * m];
                                let wq = &w[q * m..(q + 1) * m];
                                let mut np = vec![0.0; m];
                                let mut nq = vec![0.0; m];
                                for i in 0..m {
                                    np[i] = c * wp[i] - s * wq[i];
                                    nq[i] = s * wp[i] + c * wq[i];
                                }
                                (p, q, c, s, np, nq)
                            })
                        });
                        let mut applied = Vec::new();
                        for (p, q, c, s, np, nq) in computed.into_iter().flatten() {
                            w[p * m..(p + 1) * m].copy_from_slice(&np);
                            w[q * m..(q + 1) * m].copy_from_slice(&nq);
                            applied.push((p, q, c, s));
                        }
                        applied
                    };
                    if applied.is_empty() {
                        continue;
                    }
                    converged = false;
                    // V ← V·J: disjoint column pairs, row-parallel.
                    apply_col_rotations(&mut v.data, n, &applied, workers);
                }
                if converged {
                    break;
                }
            }
        }
    }
    // Singular values = column norms; U = normalized columns.
    let mut s: Vec<f64> = (0..n)
        .map(|j| w[j * m..(j + 1) * m].iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    // Sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| s[y].partial_cmp(&s[x]).unwrap());
    let mut u = Matrix::zeros(m, n);
    let mut v_sorted = Matrix::zeros(n, n);
    let mut s_sorted = vec![0.0; n];
    for (jj, &j) in order.iter().enumerate() {
        s_sorted[jj] = s[j];
        let norm = if s[j] > 1e-300 { s[j] } else { 1.0 };
        let wj = &w[j * m..(j + 1) * m];
        for (i, &x) in wj.iter().enumerate() {
            u[(i, jj)] = x / norm;
        }
        for i in 0..n {
            v_sorted[(i, jj)] = v[(i, j)];
        }
    }
    s = s_sorted;
    Svd { u, s, v: v_sorted }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    #[test]
    fn svd_reconstructs_random_matrices() {
        check("A = UΣVᵀ", 25, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 24);
            let n = g.usize_in(1, 24);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = svd_thin(&a);
            ok(
                svd.reconstruct().dist(&a) < 1e-9 * (1.0 + a.fro_norm()),
                "UΣVᵀ=A",
            )?;
            // Orthonormality.
            let r = m.min(n);
            ok(
                svd.u.matmul_tn(&svd.u).dist(&Matrix::identity(r)) < 1e-9,
                "UᵀU=I",
            )?;
            ok(
                svd.v.matmul_tn(&svd.v).dist(&Matrix::identity(r)) < 1e-9,
                "VᵀV=I",
            )?;
            // Non-negative, sorted.
            for w in svd.s.windows(2) {
                ok(w[0] + 1e-12 >= w[1], "sorted")?;
            }
            ok(svd.s.iter().all(|&x| x >= 0.0), "nonneg")?;
            Ok(())
        });
    }

    #[test]
    fn singular_values_of_diagonal() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let svd = svd_thin(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-12);
        assert!((svd.s[1] - 2.0).abs() < 1e-12);
        assert!((svd.s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eckart_young_error_equals_tail_norm() {
        check("‖A - A_k‖_F = √Σ_{i>k}σ²", 20, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(2, 20);
            let n = g.usize_in(2, 20);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = svd_thin(&a);
            let k = g.usize_in(1, m.min(n) + 1);
            let err = svd.low_rank(k).dist(&a);
            let tail = svd.tail_norm(k);
            ok((err - tail).abs() < 1e-8 * (1.0 + a.fro_norm()), "EY")
        });
    }

    #[test]
    fn truncation_beats_random_projections() {
        // Eckart–Young optimality sanity: rank-k SVD error ≤ error of any
        // random rank-k factorization we try.
        let mut rng = Rng::new(11);
        let a = Matrix::randn(15, 12, 1.0, &mut rng);
        let svd = svd_thin(&a);
        let k = 4;
        let opt = svd.low_rank(k).dist(&a);
        for _ in 0..10 {
            let w = Matrix::randn(15, k, 1.0, &mut rng);
            let z = Matrix::randn(k, 12, 1.0, &mut rng);
            // Best scaling of the random factorization (least squares in 1 dof).
            let wz = w.matmul(&z);
            let num: f64 = wz.data.iter().zip(&a.data).map(|(x, y)| x * y).sum();
            let den: f64 = wz.data.iter().map(|x| x * x).sum();
            let scaled = wz.scale(num / den.max(1e-30));
            assert!(opt <= scaled.dist(&a) + 1e-9);
        }
    }

    #[test]
    fn rank_detection() {
        let mut rng = Rng::new(12);
        let b = Matrix::randn(16, 3, 1.0, &mut rng);
        let c = Matrix::randn(3, 10, 1.0, &mut rng);
        let a = b.matmul(&c);
        let svd = svd_thin(&a);
        assert_eq!(svd.rank(1e-10), 3);
    }

    #[test]
    fn split_balanced_multiplies_back() {
        let mut rng = Rng::new(13);
        let a = Matrix::randn(9, 14, 1.0, &mut rng);
        let svd = svd_thin(&a).truncate(5);
        let (w, z) = svd.split_balanced();
        assert_eq!(w.cols, 5);
        assert_eq!(z.rows, 5);
        assert!(w.matmul(&z).dist(&svd.reconstruct()) < 1e-10);
        // Balanced: comparable Frobenius norms.
        let ratio = w.fro_norm() / z.fro_norm();
        assert!(ratio > 0.1 && ratio < 10.0, "ratio={ratio}");
    }

    #[test]
    fn wide_matrices_are_handled() {
        let mut rng = Rng::new(14);
        let a = Matrix::randn(5, 20, 1.0, &mut rng);
        let svd = svd_thin(&a);
        assert_eq!(svd.u.rows, 5);
        assert_eq!(svd.u.cols, 5);
        assert_eq!(svd.v.rows, 20);
        assert!(svd.reconstruct().dist(&a) < 1e-9);
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(4, 3);
        let svd = svd_thin(&a);
        assert!(svd.s.iter().all(|&x| x == 0.0));
        assert!(svd.reconstruct().dist(&a) < 1e-15);
    }

    #[test]
    fn ordered_cyclic_is_bit_identical_to_svd_thin() {
        let mut rng = Rng::new(21);
        for (m, n) in [(18usize, 13usize), (9, 16)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let base = svd_thin(&a);
            // Cyclic ignores workers: the sweep is inherently sequential.
            for workers in [1usize, 4] {
                let o = svd_thin_ordered(&a, JacobiOrdering::Cyclic, workers);
                assert_eq!(o.s, base.s);
                assert_eq!(o.u.data, base.u.data);
                assert_eq!(o.v.data, base.v.data);
            }
        }
    }

    #[test]
    fn tournament_matches_cyclic_to_tolerance() {
        check("tournament SVD ≡ cyclic (to tol)", 15, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 30);
            let n = g.usize_in(1, 30);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let cyc = svd_thin(&a);
            let tor = svd_thin_ordered(&a, JacobiOrdering::Tournament, 1);
            ok(
                tor.reconstruct().dist(&a) < 1e-9 * (1.0 + a.fro_norm()),
                "tournament reconstructs",
            )?;
            let r = m.min(n);
            ok(
                tor.u.matmul_tn(&tor.u).dist(&Matrix::identity(r)) < 1e-9,
                "UᵀU=I",
            )?;
            for (sc, st) in cyc.s.iter().zip(&tor.s) {
                ok(
                    (sc - st).abs() < 1e-8 * (1.0 + a.fro_norm()),
                    "singular values agree",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn tournament_bit_identical_across_workers() {
        // The engine's reproducibility contract: a fixed schedule must give
        // the exact same bits no matter how many threads apply it.
        let mut rng = Rng::new(22);
        for (m, n) in [(40usize, 25usize), (31, 31), (20, 33)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let base = svd_thin_ordered(&a, JacobiOrdering::Tournament, 1);
            for workers in [2usize, 3, 4] {
                let par = svd_thin_ordered(&a, JacobiOrdering::Tournament, workers);
                assert_eq!(base.s, par.s, "{m}x{n} w={workers} s");
                assert_eq!(base.u.data, par.u.data, "{m}x{n} w={workers} u");
                assert_eq!(base.v.data, par.v.data, "{m}x{n} w={workers} v");
            }
        }
    }
}
