//! Randomized truncated SVD (Halko–Martinsson–Tropp range finder) and the
//! [`SvdPolicy`] that decides, per matrix, between this fast path and the
//! exact one-sided Jacobi SVD.
//!
//! The decomposition hot path truncates every SVD to a rank `k` far below
//! `min(m, n)` whenever the compression ratio is aggressive or the stage-2
//! residual rank `k₂` is small.  One-sided Jacobi always pays for the full
//! spectrum; the randomized scheme pays only `O(mnl)` with `l = k +
//! oversample`:
//!
//! 1. **sketch** — `Y = A Ω` with a Gaussian `Ω (n×l)`;
//! 2. **power iterations** — `q` rounds of `Y ← A (Aᵀ Y)` with a QR
//!    re-orthonormalization after every half-step (flattens slow spectral
//!    decay);
//! 3. **projection** — `Q = orth(Y)`, `B = Qᵀ A (l×n)`;
//! 4. **small exact SVD** — one-sided Jacobi on `B`, then `U = Q U_B`.
//!
//! Because `Q` has orthonormal columns, the rank-k error splits exactly:
//! `‖A − Ã_k‖²_F = ‖A − QQᵀA‖²_F + ‖B − B_k‖²_F`, and every singular value
//! of `B` is ≤ the matching singular value of `A`, so
//! `tail_B(k) = √(Σ_{k<i≤l} σ̂ᵢ²)` is a LOWER bound on the optimal
//! (Eckart–Young) error.  That gives a cheap *a-posteriori certificate*:
//! if `‖A − Ã_k‖ ≤ (1+ε)·tail_B(k)` the sketch is within `1+ε` of optimal.
//! [`svd_for_rank`] uses the certificate as the relative-error escape hatch
//! — when it fails, the matrix falls back to exact Jacobi, so paper tables
//! stay meaningful no matter what the spectrum looks like.

use super::jacobi::JacobiOrdering;
use super::matrix::Matrix;
use super::qr::qr_thin;
use super::svd::{svd_thin, svd_thin_ordered, Svd};
use crate::util::rng::Rng;

/// Which SVD implementation to use for rank-k truncations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvdMode {
    /// Choose per matrix: randomized when `4k ≤ min(m,n)` (rank well below
    /// the full spectrum), exact Jacobi otherwise.
    Auto,
    /// Always exact one-sided Jacobi (bit-identical to the historical path).
    Exact,
    /// Randomized whenever the sketch fits (`k + oversample < min(m,n)`).
    Randomized,
}

/// Policy threaded from the CLI / `PipelineConfig` down to every per-layer
/// truncated SVD.  [`SvdPolicy::exact`] reproduces the serial pipeline's
/// outputs bit-for-bit; [`SvdPolicy::auto`] enables the randomized fast path
/// with a 2% near-optimality certificate.
#[derive(Clone, Debug)]
pub struct SvdPolicy {
    pub mode: SvdMode,
    /// Extra sketch columns beyond the requested rank (HMT recommend 5–10).
    pub oversample: usize,
    /// Subspace (power) iterations; 1–2 suffice for decaying spectra.
    pub power_iters: usize,
    /// Relative-error escape hatch: fall back to exact Jacobi unless the
    /// randomized result is certified within `(1 + ε)` of the optimal
    /// rank-k Frobenius error.  `None` disables the check (pure fast path).
    pub max_rel_err: Option<f64>,
    /// Sketch seed — fixed so runs are deterministic across worker counts.
    pub seed: u64,
    /// Sweep ordering for the exact Jacobi SVD (the `Exact` mode and every
    /// certificate fallback).  `Cyclic` (default) is bit-identical to the
    /// seed pipeline; `Tournament` parallelizes rotation rounds over the
    /// calling thread's GEMM worker share with a worker-count-independent
    /// result (`--jacobi tournament`).
    pub ordering: JacobiOrdering,
}

impl SvdPolicy {
    /// Exact Jacobi everywhere (the default; bit-identical to the seed path).
    pub fn exact() -> SvdPolicy {
        SvdPolicy {
            mode: SvdMode::Exact,
            oversample: 8,
            power_iters: 2,
            max_rel_err: None,
            seed: 0x5EED_CAFE,
            ordering: JacobiOrdering::Cyclic,
        }
    }

    /// Auto-select with the 2% near-optimality escape hatch.
    pub fn auto() -> SvdPolicy {
        SvdPolicy { mode: SvdMode::Auto, max_rel_err: Some(0.02), ..SvdPolicy::exact() }
    }

    /// Randomized whenever the sketch fits, no certificate (benchmarks).
    pub fn randomized() -> SvdPolicy {
        SvdPolicy { mode: SvdMode::Randomized, ..SvdPolicy::exact() }
    }

    /// Builder: select the Jacobi sweep ordering for the exact paths.
    pub fn with_ordering(mut self, ordering: JacobiOrdering) -> SvdPolicy {
        self.ordering = ordering;
        self
    }

    /// Does this policy route an `m×n` rank-`k` truncation to the sketch?
    pub fn wants_randomized(&self, m: usize, n: usize, k: usize) -> bool {
        let min_dim = m.min(n);
        let fits = k > 0 && k + self.oversample < min_dim;
        match self.mode {
            SvdMode::Exact => false,
            SvdMode::Randomized => fits,
            SvdMode::Auto => fits && 4 * k <= min_dim,
        }
    }
}

/// A randomized rank-k factorization plus its error certificate.
#[derive(Clone, Debug)]
pub struct RsvdResult {
    /// Rank-≤k truncated SVD (`u` m×k, `s`, `v` n×k).
    pub svd: Svd,
    /// `‖A − QQᵀA‖_F` — energy missed by the range finder (exact, via the
    /// norm identity; no extra matmul).
    pub range_residual: f64,
    /// `√(Σ_{k<i≤l} σ̂ᵢ²)` — sketch tail beyond rank k; a lower bound on the
    /// optimal rank-k error because `σᵢ(QᵀA) ≤ σᵢ(A)`.
    pub optimal_lower_bound: f64,
    /// `√(range_residual² + optimal_lower_bound²)` — the EXACT Frobenius
    /// error of `svd` as a rank-k approximation of A.
    pub achieved_err: f64,
}

impl RsvdResult {
    /// Is the factorization certified within `(1+ε)` of Eckart–Young?
    pub fn certified(&self, eps: f64, a_norm: f64) -> bool {
        if self.optimal_lower_bound > 1e-12 * a_norm {
            self.achieved_err <= (1.0 + eps) * self.optimal_lower_bound
        } else {
            // A is (numerically) rank ≤ k: demand the residual itself vanish.
            self.achieved_err <= eps * a_norm + 1e-300
        }
    }
}

/// Orthonormalize the columns of `y` (thin QR, Q only).
fn orth(y: &Matrix) -> Matrix {
    qr_thin(y).0
}

/// Random `m×n` matrix with prescribed geometric singular-value decay
/// `σᵢ = decay^i` (random orthonormal factors).  The spectrum shape of real
/// whitened weights — shared by the rsvd unit tests and the
/// `perf_linalg` bench so both exercise the same certified regime.
pub fn decaying_matrix(m: usize, n: usize, decay: f64, rng: &mut Rng) -> Matrix {
    let r = m.min(n);
    let (qu, _) = qr_thin(&Matrix::randn(m, r, 1.0, rng));
    let (qv, _) = qr_thin(&Matrix::randn(n, r, 1.0, rng));
    let s: Vec<f64> = (0..r).map(|i| decay.powi(i as i32)).collect();
    qu.scale_cols(&s).matmul_nt(&qv)
}

/// Randomized rank-k SVD with diagnostics.  Requires
/// `k + oversample < min(m,n)`; callers should route through
/// [`svd_for_rank`], which enforces that and handles fallback.
pub fn rsvd(a: &Matrix, k: usize, oversample: usize, power_iters: usize, rng: &mut Rng) -> RsvdResult {
    let (m, n) = (a.rows, a.cols);
    let l = (k + oversample).min(m.min(n));
    // Stage A: range finder with power iterations.
    let omega = Matrix::randn(n, l, 1.0, rng);
    let mut q = orth(&a.matmul(&omega)); // m×l
    for _ in 0..power_iters {
        let z = orth(&a.matmul_tn(&q)); // Aᵀ Q, re-orthonormalized: n×l
        q = orth(&a.matmul(&z)); // A Z: m×l
    }
    // Stage B: project and solve the small problem exactly.
    let b = q.matmul_tn(a); // Qᵀ A: l×n
    let sb = svd_thin(&b);
    let k_eff = k.min(sb.s.len());
    let trunc = sb.truncate(k_eff);
    let u = q.matmul(&trunc.u); // m×k
    // Certificate pieces (‖A‖² = ‖QᵀA‖² + ‖A−QQᵀA‖² since Q is orthonormal).
    let a2 = a.fro_norm().powi(2);
    let b2 = b.fro_norm().powi(2);
    let range_residual = (a2 - b2).max(0.0).sqrt();
    let tail = sb.tail_norm(k_eff);
    RsvdResult {
        svd: Svd { u, s: trunc.s, v: trunc.v },
        range_residual,
        optimal_lower_bound: tail,
        achieved_err: (range_residual.powi(2) + tail.powi(2)).sqrt(),
    }
}

/// Rank-k truncated SVD under `policy`: the randomized fast path when the
/// policy selects it (and, if `max_rel_err` is set, the certificate holds),
/// exact one-sided Jacobi otherwise.  The exact branch is bit-identical to
/// `svd_thin(a).truncate(k)`.
pub fn svd_for_rank(a: &Matrix, k: usize, policy: &SvdPolicy) -> Svd {
    // Exact sweeps run under the policy's ordering; the rotation rounds of
    // a tournament sweep draw on the calling thread's GEMM worker share —
    // the same ThreadBudget split the outer engine shards set up.
    let exact = || {
        svd_thin_ordered(a, policy.ordering, crate::linalg::gemm::workers()).truncate(k)
    };
    if !policy.wants_randomized(a.rows, a.cols, k) {
        return exact();
    }
    let mut rng = Rng::new(policy.seed);
    let r = rsvd(a, k, policy.oversample, policy.power_iters, &mut rng);
    if let Some(eps) = policy.max_rel_err {
        if !r.certified(eps, a.fro_norm()) {
            return exact();
        }
    }
    r.svd
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::decaying_matrix as decaying;

    #[test]
    fn rsvd_matches_exact_error_on_decaying_spectra() {
        let mut rng = Rng::new(7);
        // Tall, wide, and square — all shapes the engine hits.
        for (m, n) in [(60usize, 24usize), (24, 60), (40, 40)] {
            let a = decaying(m, n, 0.7, &mut rng);
            let k = 6;
            let exact_err = svd_thin(&a).low_rank(k).dist(&a);
            let r = rsvd(&a, k, 8, 2, &mut rng);
            let rand_err = r.svd.u.scale_cols(&r.svd.s).matmul_nt(&r.svd.v).dist(&a);
            assert!(
                rand_err <= 1.05 * exact_err + 1e-10,
                "{m}x{n}: rsvd err {rand_err} vs exact {exact_err}"
            );
            // The diagnostic error must equal the measured error.
            assert!((r.achieved_err - rand_err).abs() < 1e-8 * (1.0 + rand_err));
        }
    }

    #[test]
    fn rsvd_factors_are_orthonormal_and_sorted() {
        let mut rng = Rng::new(8);
        let a = decaying(50, 30, 0.8, &mut rng);
        let r = rsvd(&a, 5, 6, 2, &mut rng);
        let u = &r.svd.u;
        let v = &r.svd.v;
        assert_eq!(u.cols, 5);
        assert_eq!(v.cols, 5);
        assert!(u.matmul_tn(u).dist(&Matrix::identity(5)) < 1e-9, "UᵀU=I");
        assert!(v.matmul_tn(v).dist(&Matrix::identity(5)) < 1e-9, "VᵀV=I");
        for w in r.svd.s.windows(2) {
            assert!(w[0] + 1e-12 >= w[1], "sorted");
        }
    }

    #[test]
    fn exact_policy_is_bit_identical_to_jacobi() {
        let mut rng = Rng::new(9);
        let a = Matrix::randn(20, 14, 1.0, &mut rng);
        let k = 4;
        let via_policy = svd_for_rank(&a, k, &SvdPolicy::exact());
        let direct = svd_thin(&a).truncate(k);
        assert_eq!(via_policy.s, direct.s);
        assert_eq!(via_policy.u.data, direct.u.data);
        assert_eq!(via_policy.v.data, direct.v.data);
    }

    #[test]
    fn auto_mode_selects_by_rank_ratio() {
        let p = SvdPolicy::auto();
        // Rank well below min(m,n)/4: randomized.
        assert!(p.wants_randomized(256, 128, 16));
        // Rank above min/4: exact.
        assert!(!p.wants_randomized(256, 128, 48));
        // Sketch (k + oversample) would not fit below min(m,n): exact.
        assert!(!p.wants_randomized(10, 10, 2));
        // k = 0 never sketches.
        assert!(!p.wants_randomized(256, 128, 0));
        assert!(!SvdPolicy::exact().wants_randomized(256, 128, 16));
    }

    #[test]
    fn tournament_policy_is_worker_independent() {
        // An exact policy with the tournament ordering must give the same
        // bits whatever GEMM worker share the calling thread advertises.
        let mut rng = Rng::new(14);
        let a = Matrix::randn(30, 20, 1.0, &mut rng);
        let policy = SvdPolicy::exact().with_ordering(JacobiOrdering::Tournament);
        let base = svd_for_rank(&a, 6, &policy);
        let _g = crate::linalg::gemm::scoped_workers(4);
        let par = svd_for_rank(&a, 6, &policy);
        assert_eq!(base.s, par.s);
        assert_eq!(base.u.data, par.u.data);
        assert_eq!(base.v.data, par.v.data);
        // And it still reconstructs like the cyclic truncation does.
        let cyc = svd_for_rank(&a, 6, &SvdPolicy::exact());
        let err_t = base.u.scale_cols(&base.s).matmul_nt(&base.v).dist(&a);
        let err_c = cyc.u.scale_cols(&cyc.s).matmul_nt(&cyc.v).dist(&a);
        assert!((err_t - err_c).abs() < 1e-8 * (1.0 + err_c));
    }

    #[test]
    fn escape_hatch_falls_back_to_exact() {
        // An impossible certificate (ε = 0 on a full-rank matrix) must give
        // exactly the Jacobi answer.
        let mut rng = Rng::new(10);
        let a = Matrix::randn(64, 40, 1.0, &mut rng);
        let k = 5;
        let mut policy = SvdPolicy::randomized();
        policy.max_rel_err = Some(0.0);
        let out = svd_for_rank(&a, k, &policy);
        let exact = svd_thin(&a).truncate(k);
        assert_eq!(out.s, exact.s);
        assert_eq!(out.u.data, exact.u.data);
    }

    #[test]
    fn certificate_accepts_easy_spectra() {
        // Fast decay + power iterations: the certificate must PASS, so the
        // fast path actually runs where it is safe.
        let mut rng = Rng::new(11);
        let a = decaying(80, 48, 0.5, &mut rng);
        let r = rsvd(&a, 6, 8, 2, &mut rng);
        assert!(r.certified(0.02, a.fro_norm()), "2% certificate should hold");
    }

    #[test]
    fn zero_rank_and_degenerate_shapes() {
        let mut rng = Rng::new(12);
        let a = Matrix::randn(9, 5, 1.0, &mut rng);
        let s = svd_for_rank(&a, 0, &SvdPolicy::auto());
        assert_eq!(s.s.len(), 0);
        let z = Matrix::zeros(16, 16);
        let r = rsvd(&z, 2, 4, 1, &mut rng);
        assert!(r.achieved_err < 1e-12);
        assert!(r.certified(0.02, 0.0));
    }
}
