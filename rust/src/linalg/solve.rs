//! General solves, inverses, and the Moore–Penrose pseudo-inverse.

use super::chol::solve_upper;
use super::matrix::Matrix;
use super::qr::qr_thin;
use super::svd::svd_thin;
use anyhow::{bail, Result};

/// Solve the square system `A x = b` via QR (stable for well-conditioned A).
pub fn solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    if a.rows != a.cols {
        bail!("solve expects a square matrix, got {}x{}", a.rows, a.cols);
    }
    let (q, r) = qr_thin(a);
    // x = R⁻¹ Qᵀ b
    let qtb = q.transpose().matvec(b);
    let n = a.cols;
    for i in 0..n {
        if r[(i, i)].abs() < 1e-300 {
            bail!("singular system at pivot {i}");
        }
    }
    Ok(solve_upper(&r, &qtb))
}

/// Least-squares solve `min ‖A x − b‖₂` for tall A via thin QR.
pub fn lstsq(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    if a.rows < a.cols {
        bail!("lstsq expects a tall (m ≥ n) matrix");
    }
    let (q, r) = qr_thin(a);
    let qtb = q.transpose().matvec(b);
    for i in 0..a.cols {
        if r[(i, i)].abs() < 1e-300 {
            bail!("rank-deficient least-squares at pivot {i}");
        }
    }
    Ok(solve_upper(&r, &qtb))
}

/// Inverse of a square matrix via QR (column-by-column solves).
pub fn inverse(a: &Matrix) -> Result<Matrix> {
    let n = a.rows;
    if a.rows != a.cols {
        bail!("inverse expects a square matrix");
    }
    let (q, r) = qr_thin(a);
    for i in 0..n {
        if r[(i, i)].abs() < 1e-300 {
            bail!("matrix is singular at pivot {i}");
        }
    }
    let qt = q.transpose();
    let mut inv = Matrix::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        let qte = qt.matvec(&e);
        let col = solve_upper(&r, &qte);
        inv.set_col(j, &col);
    }
    Ok(inv)
}

/// Moore–Penrose pseudo-inverse via SVD, zeroing singular values below
/// `rel_tol · σ_max`.
pub fn pinv(a: &Matrix, rel_tol: f64) -> Matrix {
    let svd = svd_thin(a);
    let smax = svd.s.first().copied().unwrap_or(0.0);
    let cutoff = smax * rel_tol;
    let inv_s: Vec<f64> = svd
        .s
        .iter()
        .map(|&x| if x > cutoff && x > 0.0 { 1.0 / x } else { 0.0 })
        .collect();
    // A⁺ = V diag(1/σ) Uᵀ
    svd.v.scale_cols(&inv_s).matmul_nt(&svd.u)
}

/// Solve `x L = b` i.e. `Lᵀ xᵀ = bᵀ` for a lower-triangular L (row-vector
/// form used when whitening from the right).
pub fn solve_lower_right(l: &Matrix, b: &[f64]) -> Vec<f64> {
    // x L = b  ⇔  Lᵀ xᵀ = bᵀ, and Lᵀ is upper-triangular.
    solve_upper(&l.transpose(), b)
}

/// Re-export triangular kernels at this level for discoverability.
pub use super::chol::{solve_lower as trisolve_lower, solve_upper as trisolve_upper};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    #[test]
    fn solve_recovers_known_solution() {
        check("A(Ax)⁻¹ roundtrip", 20, |g| {
            let mut rng = g.rng.fork(0);
            let n = g.usize_in(1, 15);
            let mut a = Matrix::randn(n, n, 1.0, &mut rng);
            for i in 0..n {
                a[(i, i)] += n as f64; // diagonally dominant → well-conditioned
            }
            let x_true = rng.normal_vec(n);
            let b = a.matvec(&x_true);
            let x = solve(&a, &b).map_err(|e| e.to_string())?;
            for i in 0..n {
                ok((x[i] - x_true[i]).abs() < 1e-7, "solution mismatch")?;
            }
            Ok(())
        });
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Rng::new(18);
        let mut a = Matrix::randn(10, 10, 1.0, &mut rng);
        for i in 0..10 {
            a[(i, i)] += 10.0;
        }
        let inv = inverse(&a).unwrap();
        assert!(a.matmul(&inv).dist(&Matrix::identity(10)) < 1e-8);
    }

    #[test]
    fn lstsq_matches_normal_equations() {
        let mut rng = Rng::new(19);
        let a = Matrix::randn(20, 6, 1.0, &mut rng);
        let b = rng.normal_vec(20);
        let x = lstsq(&a, &b).unwrap();
        // Normal equations residual: Aᵀ(Ax - b) = 0.
        let ax = a.matvec(&x);
        let resid: Vec<f64> = ax.iter().zip(&b).map(|(p, q)| p - q).collect();
        let at_res = a.transpose().matvec(&resid);
        assert!(at_res.iter().all(|v| v.abs() < 1e-8));
    }

    #[test]
    fn pinv_satisfies_penrose_conditions() {
        check("Penrose: A A⁺ A = A and A⁺ A A⁺ = A⁺", 15, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(2, 14);
            let n = g.usize_in(2, 14);
            let r = g.usize_in(1, m.min(n) + 1).min(m.min(n));
            let b = Matrix::randn(m, r, 1.0, &mut rng);
            let c = Matrix::randn(r, n, 1.0, &mut rng);
            let a = b.matmul(&c); // rank-r, possibly deficient
            let ap = pinv(&a, 1e-12);
            let aapa = a.matmul(&ap).matmul(&a);
            ok(aapa.dist(&a) < 1e-7 * (1.0 + a.fro_norm()), "AA⁺A=A")?;
            let apaap = ap.matmul(&a).matmul(&ap);
            ok(apaap.dist(&ap) < 1e-7 * (1.0 + ap.fro_norm()), "A⁺AA⁺=A⁺")
        });
    }

    #[test]
    fn pinv_of_invertible_is_inverse() {
        let mut rng = Rng::new(20);
        let mut a = Matrix::randn(8, 8, 1.0, &mut rng);
        for i in 0..8 {
            a[(i, i)] += 8.0;
        }
        let inv = inverse(&a).unwrap();
        let p = pinv(&a, 1e-14);
        assert!(inv.dist(&p) < 1e-7);
    }

    #[test]
    fn singular_solve_fails_cleanly() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(solve(&a, &[1.0, 2.0]).is_err());
        assert!(inverse(&a).is_err());
    }

    #[test]
    fn solve_lower_right_is_right_division() {
        let mut rng = Rng::new(21);
        let g = Matrix::randn(6, 12, 1.0, &mut rng);
        let gram = g.matmul_nt(&g);
        let l = crate::linalg::chol::cholesky(&gram).unwrap();
        let b = rng.normal_vec(6);
        let x = solve_lower_right(&l, &b);
        // x L = b
        let xl = l.transpose().matvec(&x); // (x L)ᵀ = Lᵀ xᵀ
        for i in 0..6 {
            assert!((xl[i] - b[i]).abs() < 1e-8);
        }
    }
}
