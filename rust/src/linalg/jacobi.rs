//! Shared machinery for the Jacobi solvers ([`eig`](super::eig) and
//! [`svd`](super::svd)): the sweep-ordering knob, the deterministic
//! round-robin **tournament** schedule, and the row-parallel application of
//! a round's disjoint column-pair rotations.
//!
//! A tournament sweep visits every unordered index pair exactly once, like
//! a cyclic sweep, but groups the pairs into `n − 1` rounds of pairwise
//! **disjoint** pairs (the circle method every round-robin league uses).
//! Disjoint pairs touch disjoint columns, so all of a round's rotations can
//! run concurrently; because the schedule is a pure function of `n` and
//! each matrix element is transformed by exactly one rotation per round (in
//! a fixed order), the result is **bit-identical at every worker count** —
//! the property the compression engine's reproducibility contract demands
//! from every parallel kernel in the substrate.

use crate::util::threads::parallel_row_chunks;

/// Minimum number of touched matrix elements before a rotation pass fans
/// out over threads: a `thread::scope` spawn costs tens of microseconds,
/// so rounds below this bound run inline.  Serial and parallel execution
/// are bit-identical, so gating on problem size (never on worker count
/// alone) cannot change results.  Unit tests override the gate (to 1, so
/// every non-empty round qualifies) and the determinism tests exercise
/// the parallel paths at test-sized matrices.
#[cfg(not(test))]
pub(crate) const PAR_MIN_ELEMS: usize = 1 << 15;
#[cfg(test)]
pub(crate) const PAR_MIN_ELEMS: usize = 1;

/// Rotation-sweep ordering for the Jacobi eigen/SVD solvers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum JacobiOrdering {
    /// Sequential row-cyclic sweeps — the historical default.  The SVD's
    /// cyclic sweep is unchanged from the seed pipeline; the eigensolver's
    /// differs only in its rotation-skip threshold (now norm-relative, see
    /// [`super::eig::sym_eig`]).  Deterministic and independent of worker
    /// count either way.
    #[default]
    Cyclic,
    /// Deterministic round-robin tournament: `n − 1` rounds of disjoint
    /// pairs per sweep, rotations within a round computed from the
    /// round-start matrix and dispatched over the caller's worker share.
    /// Bit-identical across worker counts for a fixed schedule; the
    /// rotation *sequence* differs from `Cyclic`, so singular values /
    /// eigenvalues agree only to convergence tolerance, not bitwise.
    Tournament,
}

/// The circle-method round-robin schedule over `n` players: `n − 1` rounds
/// (n even; a bye pads odd `n`), each a maximal matching of disjoint pairs
/// `(p, q)` with `p < q`; every unordered pair appears in exactly one round.
/// Pure function of `n` — the fixed schedule is what makes the tournament
/// solvers reproducible.
pub fn tournament_rounds(n: usize) -> Vec<Vec<(usize, usize)>> {
    if n < 2 {
        return Vec::new();
    }
    let m = if n % 2 == 0 { n } else { n + 1 }; // pad odd n with a bye
    let mut players: Vec<usize> = (0..m).collect();
    let mut rounds = Vec::with_capacity(m - 1);
    for _ in 0..m - 1 {
        let mut pairs = Vec::with_capacity(m / 2);
        for i in 0..m / 2 {
            let (a, b) = (players[i], players[m - 1 - i]);
            if a < n && b < n {
                pairs.push((a.min(b), a.max(b)));
            }
        }
        rounds.push(pairs);
        // Rotate: pin players[0], shift the rest one slot clockwise.
        let last = players[m - 1];
        for i in (2..m).rev() {
            players[i] = players[i - 1];
        }
        players[1] = last;
    }
    rounds
}

/// Apply one round's column-pair rotations `(p, q, c, s)` to a row-major
/// buffer: for every row, `(x_p, x_q) ← (c·x_p − s·x_q, s·x_p + c·x_q)`.
/// The pairs are disjoint, so each element is touched by exactly one
/// rotation and the per-row loop parallelizes over contiguous row chunks
/// with a bit-identical result at every worker count.  Rounds touching
/// fewer than [`PAR_MIN_ELEMS`] elements run inline — the spawn would
/// cost more than the arithmetic.
pub(crate) fn apply_col_rotations(
    data: &mut [f64],
    width: usize,
    rots: &[(usize, usize, f64, f64)],
    workers: usize,
) {
    let rows = if width == 0 { 0 } else { data.len() / width };
    let workers = if rows * rots.len() * 2 < PAR_MIN_ELEMS { 1 } else { workers };
    parallel_row_chunks(data, width, workers, |chunk| {
        for row in chunk.chunks_mut(width) {
            for &(p, q, c, s) in rots {
                let xp = row[p];
                let xq = row[q];
                row[p] = c * xp - s * xq;
                row[q] = s * xp + c * xq;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tournament_covers_every_pair_exactly_once() {
        for n in [2usize, 3, 4, 5, 8, 9, 16, 17] {
            let rounds = tournament_rounds(n);
            assert_eq!(rounds.len(), if n % 2 == 0 { n - 1 } else { n });
            let mut seen = vec![vec![0usize; n]; n];
            for round in &rounds {
                // Disjoint within a round.
                let mut used = vec![false; n];
                for &(p, q) in round {
                    assert!(p < q && q < n, "n={n}: bad pair ({p},{q})");
                    assert!(!used[p] && !used[q], "n={n}: index reused in round");
                    used[p] = true;
                    used[q] = true;
                    seen[p][q] += 1;
                }
            }
            for p in 0..n {
                for q in (p + 1)..n {
                    assert_eq!(seen[p][q], 1, "n={n}: pair ({p},{q}) seen {} times", seen[p][q]);
                }
            }
        }
    }

    #[test]
    fn tournament_is_deterministic() {
        assert_eq!(tournament_rounds(9), tournament_rounds(9));
        assert!(tournament_rounds(0).is_empty());
        assert!(tournament_rounds(1).is_empty());
    }

    #[test]
    fn col_rotations_match_serial_at_any_worker_count() {
        let width = 10usize;
        let rows = 7usize;
        let base: Vec<f64> = (0..rows * width).map(|i| (i as f64).sin()).collect();
        let rots = vec![(0usize, 3usize, 0.8, 0.6), (1, 9, 0.6, -0.8), (4, 5, 1.0, 0.0)];
        let mut serial = base.clone();
        apply_col_rotations(&mut serial, width, &rots, 1);
        for workers in [2usize, 4] {
            let mut par = base.clone();
            apply_col_rotations(&mut par, width, &rots, workers);
            assert_eq!(serial, par);
        }
        // Untouched columns stay bit-identical to the input.
        for r in 0..rows {
            assert_eq!(serial[r * width + 2], base[r * width + 2]);
        }
    }
}
