//! The unified tiled + packed GEMM kernel — every dense matrix product in
//! the system (f64 whitening/QR/rSVD math and the f32 model forward) runs
//! through here.
//!
//! Design (classic three-level cache blocking, BLIS-style):
//!
//! ```text
//!   for jc in 0..n  step NC        // B column block    → stays in L3
//!     for pc in 0..k step KC       // shared K panel    → packed B in L2/L3
//!       pack B[pc..pc+KC, jc..jc+NC] into NR-wide micro-panels
//!       for ic in 0..m step MC     // A row block       → packed A in L1/L2
//!         pack A[ic..ic+MC, pc..pc+KC] into MR-tall micro-panels
//!         for jr, ir:              // MR×NR register microkernel
//!           C[ir.., jr..] += Apanel · Bpanel
//! ```
//!
//! * **Packing** copies each block into contiguous micro-panels (A: MR-tall,
//!   k-major; B: NR-wide, k-major) so the microkernel streams both operands
//!   with unit stride — and because packing is where layout is resolved, the
//!   same microkernel serves the NN, TN (`Aᵀ·B`), and NT (`A·Bᵀ`) entry
//!   points with zero transpose materialization.
//! * **Microkernel** keeps an `MR×NR = 8×4` accumulator block in registers;
//!   the inner loop is a plain FMA over fixed-size arrays, which LLVM
//!   auto-vectorizes (no intrinsics, so the same source serves f32 and f64
//!   via the [`Scalar`] trait).
//! * **Parallelism** is over rows of C only: B is packed once per (jc, pc)
//!   block — its contents never depend on the row range — then the rows are
//!   split into contiguous MR-aligned chunks, one scoped thread each (the
//!   same `std::thread::scope` substrate as [`crate::util::threads`]), each
//!   packing only its own A panels.  Each C element is computed by exactly
//!   one thread in the same k-order, so the result is **bit-identical for
//!   every worker count** — pinned by the determinism test below and relied
//!   on by the compression engine's bit-exactness contract.
//! * **Accumulation order** per C element is ascending-k within each K
//!   block (into a fresh register accumulator) with blocks folded in
//!   ascending order — for `k ≤ KC` that is term-for-term the order the
//!   retired naive loops used (pinned bit-exactly by a test below), and for
//!   larger k it differs only by the per-block regrouping, far inside every
//!   caller's tolerance.
//!
//! Beyond the general NN/TN/NT products, the kernel exposes a packed
//! **SYRK** entry point ([`syrk_tn`]): `C[upper] += AᵀA`, upper triangle
//! only (half the flops), parallel over diagonal-block column stripes —
//! the Gram-construction primitive behind calibration and whitening.
//!
//! Worker-count plumbing: callers that own a thread budget pass `workers`
//! explicitly; the [`Matrix`](super::matrix::Matrix) wrappers and the f32
//! forward read a per-thread knob ([`workers`]/[`scoped_workers`]), which
//! each worker of an outer parallel section (the compression engine's layer
//! fan-out, the batched evaluator) sets from its [`ThreadBudget`] split so
//! that outer × inner never oversubscribes the machine.
//!
//! [`ThreadBudget`]: crate::util::threads::ThreadBudget

/// Element type the kernel is generic over (f32 for the model/runtime
/// domain, f64 for the decomposition domain).
pub trait Scalar:
    Copy
    + Send
    + Sync
    + PartialEq
    + PartialOrd
    + std::fmt::Debug
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    const ZERO: Self;
    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
}

impl Scalar for f32 {
    const ZERO: f32 = 0.0;
    #[inline]
    fn from_f64(x: f64) -> f32 {
        x as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl Scalar for f64 {
    const ZERO: f64 = 0.0;
    #[inline]
    fn from_f64(x: f64) -> f64 {
        x
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
}

/// Microkernel tile height (rows of C held in registers).
pub const MR: usize = 8;
/// Microkernel tile width (columns of C held in registers).
pub const NR: usize = 4;
/// Row-block size (packed A panel height); multiple of [`MR`].
pub const MC: usize = 64;
/// K-block size (packed panel depth).
pub const KC: usize = 256;
/// Column-block size (packed B panel width); multiple of [`NR`].
pub const NC: usize = 512;
/// Column-stripe width of the SYRK task grid — much narrower than [`NC`]
/// so the triangular column stripes expose parallelism at Gram sizes
/// (`d_model`..`d_ff`); a multiple of [`NR`].
pub const SYRK_NC: usize = 64;

/// Operand layout of a product `C += op(A) · op(B)` (C always m×n row-major).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// `A` m×k, `B` k×n (both row-major).
    NN,
    /// `A` stored k×m, used as `Aᵀ` (no transpose materialized).
    TN,
    /// `B` stored n×k, used as `Bᵀ` (no transpose materialized).
    NT,
}

// ---------------------------------------------------------------------------
// Per-thread worker knob (what Matrix::matmul* and matmul_raw consult).
// ---------------------------------------------------------------------------

thread_local! {
    static GEMM_WORKERS: std::cell::Cell<usize> = std::cell::Cell::new(1);
}

/// Worker threads the wrapper entry points (`Matrix::matmul*`, the f32
/// forward) use *on the calling thread*.  Defaults to 1; results are
/// identical for every value, so this is purely a wall-clock knob.  The
/// knob is thread-local on purpose: each worker of an outer fan-out sets
/// its own inner share, so concurrent pipelines (and concurrent tests)
/// never interfere.
pub fn workers() -> usize {
    GEMM_WORKERS.with(|c| c.get())
}

/// Set this thread's GEMM worker count; returns the previous value.
pub fn set_workers(n: usize) -> usize {
    GEMM_WORKERS.with(|c| c.replace(n.max(1)))
}

/// RAII guard restoring the previous per-thread worker count on drop.
pub struct WorkersGuard {
    prev: usize,
}

/// Set this thread's GEMM worker count for the lifetime of the returned
/// guard.  Outer parallel sections use this to hand their [`ThreadBudget`]
/// remainder to the GEMMs running underneath them.
///
/// [`ThreadBudget`]: crate::util::threads::ThreadBudget
pub fn scoped_workers(n: usize) -> WorkersGuard {
    WorkersGuard { prev: set_workers(n) }
}

impl Drop for WorkersGuard {
    fn drop(&mut self) {
        set_workers(self.prev);
    }
}

// ---------------------------------------------------------------------------
// Public entry points.
// ---------------------------------------------------------------------------

/// `C += A · B` with `A` m×k and `B` k×n, both row-major.
pub fn gemm_nn<T: Scalar>(m: usize, k: usize, n: usize, a: &[T], b: &[T], c: &mut [T], workers: usize) {
    gemm(Layout::NN, m, k, n, a, b, c, workers);
}

/// `C += Aᵀ · B` with `A` stored k×m and `B` k×n (row-major storage).
pub fn gemm_tn<T: Scalar>(m: usize, k: usize, n: usize, a: &[T], b: &[T], c: &mut [T], workers: usize) {
    gemm(Layout::TN, m, k, n, a, b, c, workers);
}

/// `C += A · Bᵀ` with `A` m×k and `B` stored n×k (row-major storage).
pub fn gemm_nt<T: Scalar>(m: usize, k: usize, n: usize, a: &[T], b: &[T], c: &mut [T], workers: usize) {
    gemm(Layout::NT, m, k, n, a, b, c, workers);
}

/// The generic entry point: `C += op(A)·op(B)` per `layout`, fanning row
/// blocks of C out over `workers` scoped threads (1 = fully serial).
pub fn gemm<T: Scalar>(
    layout: Layout,
    m: usize,
    k: usize,
    n: usize,
    a: &[T],
    b: &[T],
    c: &mut [T],
    workers: usize,
) {
    assert_eq!(a.len(), m * k, "gemm: A size mismatch ({layout:?}, m={m} k={k})");
    assert_eq!(b.len(), k * n, "gemm: B size mismatch ({layout:?}, k={k} n={n})");
    assert_eq!(c.len(), m * n, "gemm: C size mismatch (m={m} n={n})");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let row_blocks = m.div_ceil(MR);
    let workers = workers.max(1).min(row_blocks);
    // Pack buffers sized to the actual problem (capped at one full tile):
    // small products — rSVD sketches, low-rank factors — shouldn't pay a
    // full-tile zeroed allocation per call.
    let kc_cap = KC.min(k);
    let nc_cap = NC.min(n.div_ceil(NR) * NR);
    let mut bpack = vec![T::ZERO; kc_cap * nc_cap];
    if workers <= 1 {
        let mut apack = vec![T::ZERO; MC.min(m.div_ceil(MR) * MR) * kc_cap];
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            for pc in (0..k).step_by(KC) {
                let kc = KC.min(k - pc);
                pack_b(layout, b, k, n, pc, kc, jc, nc, &mut bpack);
                gemm_block(layout, 0, k, a, &bpack, &mut apack, c, pc, kc, nc, n, jc);
            }
        }
        return;
    }
    // Parallel path: B is packed ONCE per (jc, pc) block — its contents do
    // not depend on the row range — then contiguous MR-aligned row chunks of
    // C fan out over scoped threads, each packing only its own A panels.
    // Disjoint C slices need no synchronization, and the per-element
    // accumulation order (ascending k) is independent of the worker count.
    let rows_per = row_blocks.div_ceil(workers) * MR;
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b(layout, b, k, n, pc, kc, jc, nc, &mut bpack);
            let bref: &[T] = &bpack;
            std::thread::scope(|scope| {
                for (ci, chunk) in c.chunks_mut(rows_per * n).enumerate() {
                    let row0 = ci * rows_per;
                    scope.spawn(move || {
                        let rows = chunk.len() / n;
                        let mut apack =
                            vec![T::ZERO; MC.min(rows.div_ceil(MR) * MR) * kc];
                        gemm_block(
                            layout, row0, k, a, bref, &mut apack, chunk, pc, kc, nc, n, jc,
                        );
                    });
                }
            });
        }
    }
}

/// Symmetric rank-k update `C[upper] += AᵀA`, with `A` stored k×n row-major
/// (k sample rows of dimension n — the calibration layout) and `C` n×n
/// row-major.
///
/// Only the upper triangle (`j ≥ i`) of `C` is touched — callers that need
/// the full Gram mirror once at the end ([`Matrix::gram`], the calibration
/// collector's finalize) instead of per accumulation, which is where the
/// ~2× flop saving over [`gemm_tn`]`(A, A)` comes from.  The triangle is
/// tiled into [`SYRK_NC`]-wide column stripes (stripe `jc` covers rows
/// `0..jc+nc`); each stripe runs the same packing + microkernel pipeline as
/// [`gemm`] into a private buffer that is folded into `C` with one add per
/// element, so:
///
/// * the per-element accumulation order is fixed (ascending k within K
///   blocks, blocks ascending, one fold into C) — **bit-identical for
///   every worker count**, and bit-identical to the upper triangle of
///   `gemm_tn(A, A)` when `C` starts zeroed;
/// * workers claim stripes dynamically (an atomic cursor): stripes get
///   strictly more expensive left→right, so static chunking would idle the
///   early workers.
///
/// [`Matrix::gram`]: super::matrix::Matrix::gram
pub fn syrk_tn<T: Scalar>(n: usize, k: usize, a: &[T], c: &mut [T], workers: usize) {
    assert_eq!(a.len(), k * n, "syrk: A size mismatch (k={k} n={n})");
    assert_eq!(c.len(), n * n, "syrk: C size mismatch (n={n})");
    if n == 0 || k == 0 {
        return;
    }
    let tasks: Vec<(usize, usize)> = (0..n)
        .step_by(SYRK_NC)
        .map(|jc| (jc, SYRK_NC.min(n - jc)))
        .collect();
    let workers = workers.max(1).min(tasks.len());
    if workers <= 1 {
        for &(jc, nc) in &tasks {
            let stripe = syrk_stripe(n, k, a, jc, nc);
            add_stripe_upper(n, jc, nc, &stripe, c);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let done: std::sync::Mutex<Vec<(usize, Vec<T>)>> =
        std::sync::Mutex::new(Vec::with_capacity(tasks.len()));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut local: Vec<(usize, Vec<T>)> = Vec::new();
                loop {
                    let t = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if t >= tasks.len() {
                        break;
                    }
                    let (jc, nc) = tasks[t];
                    local.push((t, syrk_stripe(n, k, a, jc, nc)));
                }
                done.lock().unwrap().extend(local);
            });
        }
    });
    let mut stripes = done.into_inner().unwrap();
    stripes.sort_by_key(|&(t, _)| t);
    for (t, stripe) in stripes {
        let (jc, nc) = tasks[t];
        add_stripe_upper(n, jc, nc, &stripe, c);
    }
}

/// One SYRK column stripe: rows `0..jc+nc`, columns `jc..jc+nc` of `AᵀA`,
/// accumulated into a fresh `(jc+nc)×nc` row-major buffer through the
/// packed TN pipeline (A plays both operands; no transpose materialized).
fn syrk_stripe<T: Scalar>(n: usize, k: usize, a: &[T], jc: usize, nc: usize) -> Vec<T> {
    let rows = jc + nc;
    let kc_cap = KC.min(k);
    let mut bpack = vec![T::ZERO; kc_cap * nc.div_ceil(NR) * NR];
    let mut apack = vec![T::ZERO; MC.min(rows.div_ceil(MR) * MR) * kc_cap];
    let mut stripe = vec![T::ZERO; rows * nc];
    for pc in (0..k).step_by(KC) {
        let kc = KC.min(k - pc);
        pack_b(Layout::TN, a, k, n, pc, kc, jc, nc, &mut bpack);
        gemm_block(Layout::TN, 0, k, a, &bpack, &mut apack, &mut stripe, pc, kc, nc, nc, 0);
    }
    stripe
}

/// Fold a stripe into `C`'s upper triangle (`j ≥ i` only — the stripe's
/// below-diagonal corner of the diagonal block is dropped, leaving the
/// strict lower triangle of `C` untouched).
fn add_stripe_upper<T: Scalar>(n: usize, jc: usize, nc: usize, stripe: &[T], c: &mut [T]) {
    for i in 0..jc + nc {
        let lo = i.saturating_sub(jc);
        let crow = &mut c[i * n + jc + lo..i * n + jc + nc];
        let srow = &stripe[i * nc + lo..(i + 1) * nc];
        for (cv, sv) in crow.iter_mut().zip(srow) {
            *cv += *sv;
        }
    }
}

/// Matrix–vector product `y += A·x` (`A` m×k row-major).  Four-way unrolled
/// dot products; always single-threaded (the shapes this system hits are
/// memory-bound and too small to amortize a spawn).
pub fn gemv<T: Scalar>(m: usize, k: usize, a: &[T], x: &[T], y: &mut [T]) {
    assert_eq!(a.len(), m * k, "gemv: A size mismatch");
    assert_eq!(x.len(), k, "gemv: x size mismatch");
    assert_eq!(y.len(), m, "gemv: y size mismatch");
    for (i, yi) in y.iter_mut().enumerate() {
        let row = &a[i * k..(i + 1) * k];
        let mut acc = [T::ZERO; 4];
        let mut chunks_a = row.chunks_exact(4);
        let mut chunks_x = x.chunks_exact(4);
        for (ca, cx) in (&mut chunks_a).zip(&mut chunks_x) {
            for l in 0..4 {
                acc[l] += ca[l] * cx[l];
            }
        }
        let mut tail = T::ZERO;
        for (av, xv) in chunks_a.remainder().iter().zip(chunks_x.remainder()) {
            tail += *av * *xv;
        }
        *yi += ((acc[0] + acc[1]) + (acc[2] + acc[3])) + tail;
    }
}

/// The retired naive kernel (k-panel blocked i-k-j loop), kept as the parity
/// reference for the property tests and the speedup baseline for
/// `benches/perf_linalg.rs` / `BENCH_gemm.json`.
pub fn naive_nn<T: Scalar>(m: usize, k: usize, n: usize, a: &[T], b: &[T], c: &mut [T]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    const KB: usize = 64;
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for i in 0..m {
            let a_row = &a[i * k..(i + 1) * k];
            let c_row = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = a_row[kk];
                let b_row = &b[kk * n..(kk + 1) * n];
                for (cv, bv) in c_row.iter_mut().zip(b_row.iter()) {
                    *cv += av * *bv;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// One (jc, pc) block over a row range of C.
// ---------------------------------------------------------------------------

/// Process one packed-B block: walk MC sub-blocks of C rows `[row0,
/// row0 + rows)` (where `rows = c.len() / ldc`; `c` covers exactly that row
/// range and `row0` is only needed to index into `a`), packing A panels into
/// `apack` and running the microkernel against `bpack` (already packed for
/// the `kc`-deep, `nc`-wide operand block).  The output geometry is
/// explicit so SYRK stripes can reuse this: `ldc` is `c`'s row stride and
/// `cj0` the column offset where the `nc`-wide block lands (`gemm` passes
/// `ldc = n`, `cj0 = jc`; a stripe passes `ldc = nc`, `cj0 = 0`).
#[allow(clippy::too_many_arguments)]
fn gemm_block<T: Scalar>(
    layout: Layout,
    row0: usize,
    k: usize,
    a: &[T],
    bpack: &[T],
    apack: &mut [T],
    c: &mut [T],
    pc: usize,
    kc: usize,
    nc: usize,
    ldc: usize,
    cj0: usize,
) {
    // a's leading dimension: k for row-major m×k (NN/NT); for TN the element
    // (i, p) of op(A) lives at a[p * m_full + i], and m_full is recovered
    // from the slice length.
    let m_full = a.len() / k;
    let rows = c.len() / ldc;
    for ic in (0..rows).step_by(MC) {
        let mc = MC.min(rows - ic);
        pack_a(layout, a, m_full, k, row0 + ic, mc, pc, kc, apack);
        for jr in (0..nc).step_by(NR) {
            let nr_eff = NR.min(nc - jr);
            let bmicro = &bpack[(jr / NR) * (kc * NR)..][..kc * NR];
            for ir in (0..mc).step_by(MR) {
                let mr_eff = MR.min(mc - ir);
                let amicro = &apack[(ir / MR) * (kc * MR)..][..kc * MR];
                let mut acc = [[T::ZERO; NR]; MR];
                microkernel(amicro, bmicro, &mut acc);
                for (i, acc_row) in acc.iter().enumerate().take(mr_eff) {
                    let crow = &mut c[(ic + ir + i) * ldc + cj0 + jr..][..nr_eff];
                    for (cv, av) in crow.iter_mut().zip(acc_row.iter()) {
                        *cv += *av;
                    }
                }
            }
        }
    }
}

/// MR×NR register block over one packed-A / packed-B micro-panel pair
/// (`ap.len() == kc·MR`, `bp.len() == kc·NR`).  `chunks_exact` + fixed-size
/// array views make every access provably in-bounds, so LLVM unrolls the
/// `i`/`j` loops and vectorizes the FMA with no bounds checks.
#[inline(always)]
fn microkernel<T: Scalar>(ap: &[T], bp: &[T], acc: &mut [[T; NR]; MR]) {
    for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        let av: &[T; MR] = av.try_into().expect("exact MR chunk");
        let bv: &[T; NR] = bv.try_into().expect("exact NR chunk");
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let ai = av[i];
            for (j, cell) in acc_row.iter_mut().enumerate() {
                *cell += ai * bv[j];
            }
        }
    }
}

/// Pack `op(A)[ic..ic+mc, pc..pc+kc]` into MR-tall k-major micro-panels,
/// zero-padding the last panel so the microkernel never branches on height.
#[allow(clippy::too_many_arguments)]
fn pack_a<T: Scalar>(
    layout: Layout,
    a: &[T],
    m_full: usize,
    k: usize,
    ic: usize,
    mc: usize,
    pc: usize,
    kc: usize,
    apack: &mut [T],
) {
    for ip in 0..mc.div_ceil(MR) {
        let panel = &mut apack[ip * (kc * MR)..(ip + 1) * (kc * MR)];
        let rows_here = MR.min(mc - ip * MR);
        for p in 0..kc {
            let dst = &mut panel[p * MR..(p + 1) * MR];
            for (i, d) in dst.iter_mut().enumerate() {
                *d = if i < rows_here {
                    let r = ic + ip * MR + i;
                    match layout {
                        // op(A)[r, pc+p] for row-major A (NN and NT share it).
                        Layout::NN | Layout::NT => a[r * k + pc + p],
                        // op(A) = Aᵀ with A stored k×m: element at [pc+p, r].
                        Layout::TN => a[(pc + p) * m_full + r],
                    }
                } else {
                    T::ZERO
                };
            }
        }
    }
}

/// Pack `op(B)[pc..pc+kc, jc..jc+nc]` into NR-wide k-major micro-panels,
/// zero-padding the last panel so the microkernel never branches on width.
#[allow(clippy::too_many_arguments)]
fn pack_b<T: Scalar>(
    layout: Layout,
    b: &[T],
    k: usize,
    n: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    bpack: &mut [T],
) {
    for jp in 0..nc.div_ceil(NR) {
        let panel = &mut bpack[jp * (kc * NR)..(jp + 1) * (kc * NR)];
        let cols_here = NR.min(nc - jp * NR);
        for p in 0..kc {
            let dst = &mut panel[p * NR..(p + 1) * NR];
            for (j, d) in dst.iter_mut().enumerate() {
                *d = if j < cols_here {
                    let col = jc + jp * NR + j;
                    match layout {
                        // op(B)[pc+p, col] for row-major k×n B (NN and TN).
                        Layout::NN | Layout::TN => b[(pc + p) * n + col],
                        // op(B) = Bᵀ with B stored n×k: element at [col, pc+p].
                        Layout::NT => b[col * k + pc + p],
                    }
                } else {
                    T::ZERO
                };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Dumb triple-loop reference in the layout's own indexing (independent
    /// of both the tiled kernel and `naive_nn`).
    fn reference<T: Scalar>(layout: Layout, m: usize, k: usize, n: usize, a: &[T], b: &[T]) -> Vec<T> {
        let mut c = vec![T::ZERO; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = T::ZERO;
                for p in 0..k {
                    let av = match layout {
                        Layout::NN | Layout::NT => a[i * k + p],
                        Layout::TN => a[p * m + i],
                    };
                    let bv = match layout {
                        Layout::NN | Layout::TN => b[p * n + j],
                        Layout::NT => b[j * k + p],
                    };
                    acc += av * bv;
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn randn_vec<T: Scalar>(len: usize, rng: &mut Rng) -> Vec<T> {
        (0..len).map(|_| T::from_f64(rng.normal())).collect()
    }

    fn max_abs_diff<T: Scalar>(x: &[T], y: &[T]) -> f64 {
        x.iter()
            .zip(y)
            .map(|(a, b)| (a.to_f64() - b.to_f64()).abs())
            .fold(0.0, f64::max)
    }

    fn check_parity<T: Scalar>(tol: f64, cases: usize, label: &str) {
        check(label, cases, |g| {
            let mut rng = g.rng.fork(0);
            // Shape classes: tall, wide, tiny, and non-multiple-of-tile;
            // dimensions straddle MR/NR/MC boundaries.
            let m = *g.choose(&[1usize, 2, 3, 7, 8, 9, 17, 65, 70]);
            let k = *g.choose(&[1usize, 2, 5, 16, 33, 64, 100]);
            let n = *g.choose(&[1usize, 2, 3, 4, 5, 11, 12, 66]);
            let layout = *g.choose(&[Layout::NN, Layout::TN, Layout::NT]);
            let a: Vec<T> = randn_vec(m * k, &mut rng);
            let b: Vec<T> = randn_vec(k * n, &mut rng);
            let want = reference(layout, m, k, n, &a, &b);
            for workers in [1usize, 4] {
                let mut got = vec![T::ZERO; m * n];
                gemm(layout, m, k, n, &a, &b, &mut got, workers);
                let err = max_abs_diff(&got, &want);
                // Scale the tolerance with the accumulation length.
                if err > tol * (1.0 + k as f64) {
                    return Err(format!(
                        "{layout:?} {m}x{k}x{n} w={workers}: err {err:e}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tiled_matches_reference_f64() {
        check_parity::<f64>(1e-12, 40, "tiled gemm == reference (f64)");
    }

    #[test]
    fn tiled_matches_reference_f32() {
        check_parity::<f32>(1e-4, 40, "tiled gemm == reference (f32)");
    }

    #[test]
    fn tiled_matches_naive_bitwise() {
        // For k ≤ KC (single K block) the tiled kernel performs the exact
        // same ascending-k addition sequence per element as the retired
        // naive loop ⇒ bit-identical output, which is what let the callers
        // rewire without moving any test tolerance.
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[(17usize, 33usize, 12usize), (64, 64, 64), (70, 100, 66)] {
            let a: Vec<f64> = randn_vec(m * k, &mut rng);
            let b: Vec<f64> = randn_vec(k * n, &mut rng);
            let mut c_naive = vec![0.0; m * n];
            naive_nn(m, k, n, &a, &b, &mut c_naive);
            let mut c_tiled = vec![0.0; m * n];
            gemm_nn(m, k, n, &a, &b, &mut c_tiled, 1);
            assert_eq!(c_naive, c_tiled, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let mut rng = Rng::new(8);
        let (m, k, n) = (130usize, 90usize, 75usize);
        let a: Vec<f64> = randn_vec(m * k, &mut rng);
        let b: Vec<f64> = randn_vec(k * n, &mut rng);
        let mut base = vec![0.0; m * n];
        gemm_nn(m, k, n, &a, &b, &mut base, 1);
        for workers in [2usize, 3, 4, 9] {
            let mut c = vec![0.0; m * n];
            gemm_nn(m, k, n, &a, &b, &mut c, workers);
            assert_eq!(base, c, "workers={workers} must be bit-identical");
        }
        let af: Vec<f32> = randn_vec(m * k, &mut rng);
        let bf: Vec<f32> = randn_vec(k * n, &mut rng);
        let mut base_f = vec![0.0f32; m * n];
        gemm_nn(m, k, n, &af, &bf, &mut base_f, 1);
        let mut c_f = vec![0.0f32; m * n];
        gemm_nn(m, k, n, &af, &bf, &mut c_f, 4);
        assert_eq!(base_f, c_f);
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        // k = 0: C untouched (the product is an empty sum).
        let mut c = vec![1.0f64; 6];
        gemm_nn(2, 0, 3, &[], &[], &mut c, 4);
        assert_eq!(c, vec![1.0; 6]);
        // m = 0 / n = 0: nothing to do, must not panic.
        let mut empty: Vec<f64> = Vec::new();
        gemm_nn(0, 5, 3, &[], &vec![0.0; 15], &mut empty, 2);
        gemm_nn(3, 5, 0, &vec![0.0; 15], &[], &mut empty, 2);
        // 1×1×1.
        let mut c1 = vec![0.0f64];
        gemm_nn(1, 1, 1, &[3.0], &[4.0], &mut c1, 4);
        assert_eq!(c1, vec![12.0]);
    }

    #[test]
    fn accumulates_into_existing_c() {
        // gemm is C += A·B, which the nested two-stage apply relies on.
        let mut c = vec![10.0f64; 4];
        gemm_nn(2, 2, 2, &[1.0, 0.0, 0.0, 1.0], &[1.0, 2.0, 3.0, 4.0], &mut c, 1);
        assert_eq!(c, vec![11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn gemv_matches_gemm_column() {
        check("gemv == gemm with n=1", 20, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 40);
            let k = g.usize_in(1, 40);
            let a: Vec<f64> = randn_vec(m * k, &mut rng);
            let x: Vec<f64> = randn_vec(k, &mut rng);
            let mut y = vec![0.0; m];
            gemv(m, k, &a, &x, &mut y);
            let mut want = vec![0.0; m];
            gemm_nn(m, k, 1, &a, &x, &mut want, 1);
            let err = max_abs_diff(&y, &want);
            if err > 1e-12 * (1.0 + k as f64) {
                return Err(format!("{m}x{k}: err {err:e}"));
            }
            Ok(())
        });
    }

    #[test]
    fn syrk_matches_tn_upper_bitwise() {
        // On a zeroed C, the SYRK upper triangle must be BIT-identical to
        // gemm_tn(A, A) at every worker count, across tall/wide/tiny/1×1
        // shapes and k values that straddle the KC block boundary; the
        // strict lower triangle must stay untouched.
        check("syrk == gemm_tn upper (bitwise)", 40, |g| {
            let mut rng = g.rng.fork(0);
            let n = *g.choose(&[1usize, 2, 3, 5, 17, 63, 64, 65, 130]);
            let k = *g.choose(&[1usize, 2, 7, 33, 256, 300]);
            let a: Vec<f64> = randn_vec(k * n, &mut rng);
            let mut want = vec![0.0; n * n];
            gemm_tn(n, k, n, &a, &a, &mut want, 1);
            for workers in [1usize, 4] {
                let mut got = vec![0.0; n * n];
                syrk_tn(n, k, &a, &mut got, workers);
                for i in 0..n {
                    for j in 0..n {
                        if j >= i {
                            if got[i * n + j] != want[i * n + j] {
                                return Err(format!(
                                    "n={n} k={k} w={workers}: ({i},{j}) {} != {}",
                                    got[i * n + j],
                                    want[i * n + j]
                                ));
                            }
                        } else if got[i * n + j] != 0.0 {
                            return Err(format!(
                                "n={n} k={k} w={workers}: lower ({i},{j}) written"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn syrk_accumulates_and_is_worker_deterministic() {
        // C += semantics on a pre-filled C; with k > KC the fold order
        // differs from gemm_tn's per-K-block adds, but must be identical
        // across worker counts (one stripe fold per element).
        let mut rng = Rng::new(15);
        let (n, k) = (97usize, 300usize);
        let a: Vec<f64> = randn_vec(k * n, &mut rng);
        let mut base = vec![3.0; n * n];
        syrk_tn(n, k, &a, &mut base, 1);
        for workers in [2usize, 4, 9] {
            let mut c = vec![3.0; n * n];
            syrk_tn(n, k, &a, &mut c, workers);
            assert_eq!(base, c, "workers={workers} must be bit-identical");
        }
        // Strict lower triangle keeps its prior contents.
        for i in 0..n {
            for j in 0..i {
                assert_eq!(base[i * n + j], 3.0);
            }
        }
        // f32 instantiation (the f32 path has no Gram caller today, but the
        // genericity contract is pinned like the GEMM one).
        let af: Vec<f32> = randn_vec(k * n, &mut rng);
        let mut c1 = vec![0.0f32; n * n];
        let mut c4 = vec![0.0f32; n * n];
        syrk_tn(n, k, &af, &mut c1, 1);
        syrk_tn(n, k, &af, &mut c4, 4);
        assert_eq!(c1, c4);
    }

    #[test]
    fn syrk_degenerate_shapes() {
        // k = 0: empty sum, C untouched.
        let mut c = vec![2.0f64; 9];
        syrk_tn(3, 0, &[], &mut c, 4);
        assert_eq!(c, vec![2.0; 9]);
        // n = 0: nothing to do.
        let mut empty: Vec<f64> = Vec::new();
        syrk_tn(0, 5, &[], &mut empty, 2);
        // 1×1: C[0,0] += Σ a².
        let mut c1 = vec![1.0f64];
        syrk_tn(1, 2, &[3.0, 4.0], &mut c1, 4);
        assert_eq!(c1, vec![26.0]);
    }

    #[test]
    fn scoped_workers_sets_and_restores() {
        let before = workers();
        {
            let _g = scoped_workers(before + 3);
            assert_eq!(workers(), before + 3);
        }
        assert_eq!(workers(), before);
        // 0 clamps to 1 (a GEMM always has at least the calling thread).
        let _g = scoped_workers(0);
        assert_eq!(workers(), 1);
    }
}
