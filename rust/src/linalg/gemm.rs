//! The unified tiled + packed GEMM kernel — every dense matrix product in
//! the system (f64 whitening/QR/rSVD math and the f32 model forward) runs
//! through here.
//!
//! Design (classic three-level cache blocking, BLIS-style):
//!
//! ```text
//!   for jc in 0..n  step NC        // B column block    → stays in L3
//!     for pc in 0..k step KC       // shared K panel    → packed B in L2/L3
//!       pack B[pc..pc+KC, jc..jc+NC] into NR-wide micro-panels
//!       for ic in 0..m step MC     // A row block       → packed A in L1/L2
//!         pack A[ic..ic+MC, pc..pc+KC] into MR-tall micro-panels
//!         for jr, ir:              // MR×NR register microkernel
//!           C[ir.., jr..] += Apanel · Bpanel
//! ```
//!
//! * **Packing** copies each block into contiguous micro-panels (A: MR-tall,
//!   k-major; B: NR-wide, k-major) so the microkernel streams both operands
//!   with unit stride — and because packing is where layout is resolved, the
//!   same microkernel serves the NN, TN (`Aᵀ·B`), and NT (`A·Bᵀ`) entry
//!   points with zero transpose materialization.
//! * **Microkernel** keeps an `MR×NR = 8×4` accumulator block in registers;
//!   the inner loop is a plain FMA over fixed-size arrays, which LLVM
//!   auto-vectorizes (no intrinsics, so the same source serves f32 and f64
//!   via the [`Scalar`] trait).  On top of that portable floor sits a
//!   runtime-dispatched explicit-SIMD tier ([`Isa`]): AVX2 / AVX-512 /
//!   NEON microkernels for f32 (separate mul+add, **never** fused-multiply
//!   -add, so they stay bit-identical to the scalar kernel) and for the
//!   int8 path below.  f64 always takes the auto-vectorized kernel.
//! * **Int8 path** ([`gemm_i8_nn`]): the same blocking and panel packing
//!   over i8 codes quantized per `(row|column, k-group)` by
//!   [`super::quant`], with i32 accumulators and a dequant-fused f32
//!   epilogue (`C += (s_row·s_col)·acc`).  K blocks follow group
//!   boundaries, so each group's integer dot is exact (`group·127² < 2²⁴`
//!   also makes the i32→f32 conversion exact) and order-independent —
//!   bit-identical at every worker count, and per-row independent, by
//!   construction.  Packing is pair-major (`[kc/2][MR|NR][2]`, zero-padded
//!   odd k) so the SIMD kernels can ride exact widening i16 multiply-add
//!   (`pmaddwd` / `smull`+`padd`).
//! * **Parallelism** is over rows of C only: B is packed once per (jc, pc)
//!   block — its contents never depend on the row range — then the rows are
//!   split into contiguous MR-aligned chunks, one scoped thread each (the
//!   same `std::thread::scope` substrate as [`crate::util::threads`]), each
//!   packing only its own A panels.  Each C element is computed by exactly
//!   one thread in the same k-order, so the result is **bit-identical for
//!   every worker count** — pinned by the determinism test below and relied
//!   on by the compression engine's bit-exactness contract.
//! * **Accumulation order** per C element is ascending-k within each K
//!   block (into a fresh register accumulator) with blocks folded in
//!   ascending order — for `k ≤ KC` that is term-for-term the order the
//!   retired naive loops used (pinned bit-exactly by a test below), and for
//!   larger k it differs only by the per-block regrouping, far inside every
//!   caller's tolerance.
//!
//! Beyond the general NN/TN/NT products, the kernel exposes a packed
//! **SYRK** entry point ([`syrk_tn`]): `C[upper] += AᵀA`, upper triangle
//! only (half the flops), parallel over diagonal-block column stripes —
//! the Gram-construction primitive behind calibration and whitening.
//!
//! Worker-count plumbing: callers that own a thread budget pass `workers`
//! explicitly; the [`Matrix`](super::matrix::Matrix) wrappers and the f32
//! forward read a per-thread knob ([`workers`]/[`scoped_workers`]), which
//! each worker of an outer parallel section (the compression engine's layer
//! fan-out, the batched evaluator) sets from its [`ThreadBudget`] split so
//! that outer × inner never oversubscribes the machine.
//!
//! [`ThreadBudget`]: crate::util::threads::ThreadBudget

/// Element type the kernel is generic over (f32 for the model/runtime
/// domain, f64 for the decomposition domain).
pub trait Scalar:
    Copy
    + Send
    + Sync
    + PartialEq
    + PartialOrd
    + std::fmt::Debug
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + 'static
{
    const ZERO: Self;
    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
}

impl Scalar for f32 {
    const ZERO: f32 = 0.0;
    #[inline]
    fn from_f64(x: f64) -> f32 {
        x as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

impl Scalar for f64 {
    const ZERO: f64 = 0.0;
    #[inline]
    fn from_f64(x: f64) -> f64 {
        x
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
}

/// Microkernel tile height (rows of C held in registers).
pub const MR: usize = 8;
/// Microkernel tile width (columns of C held in registers).
pub const NR: usize = 4;
/// Row-block size (packed A panel height); multiple of [`MR`].
pub const MC: usize = 64;
/// K-block size (packed panel depth).
pub const KC: usize = 256;
/// Column-block size (packed B panel width); multiple of [`NR`].
pub const NC: usize = 512;
/// Column-stripe width of the SYRK task grid — much narrower than [`NC`]
/// so the triangular column stripes expose parallelism at Gram sizes
/// (`d_model`..`d_ff`); a multiple of [`NR`].
pub const SYRK_NC: usize = 64;

/// Operand layout of a product `C += op(A) · op(B)` (C always m×n row-major).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// `A` m×k, `B` k×n (both row-major).
    NN,
    /// `A` stored k×m, used as `Aᵀ` (no transpose materialized).
    TN,
    /// `B` stored n×k, used as `Bᵀ` (no transpose materialized).
    NT,
}

// ---------------------------------------------------------------------------
// Per-thread worker knob (what Matrix::matmul* and matmul_raw consult).
// ---------------------------------------------------------------------------

thread_local! {
    static GEMM_WORKERS: std::cell::Cell<usize> = std::cell::Cell::new(1);
}

/// Worker threads the wrapper entry points (`Matrix::matmul*`, the f32
/// forward) use *on the calling thread*.  Defaults to 1; results are
/// identical for every value, so this is purely a wall-clock knob.  The
/// knob is thread-local on purpose: each worker of an outer fan-out sets
/// its own inner share, so concurrent pipelines (and concurrent tests)
/// never interfere.
pub fn workers() -> usize {
    GEMM_WORKERS.with(|c| c.get())
}

/// Set this thread's GEMM worker count; returns the previous value.
pub fn set_workers(n: usize) -> usize {
    GEMM_WORKERS.with(|c| c.replace(n.max(1)))
}

/// RAII guard restoring the previous per-thread worker count on drop.
pub struct WorkersGuard {
    prev: usize,
}

/// Set this thread's GEMM worker count for the lifetime of the returned
/// guard.  Outer parallel sections use this to hand their [`ThreadBudget`]
/// remainder to the GEMMs running underneath them.
///
/// [`ThreadBudget`]: crate::util::threads::ThreadBudget
pub fn scoped_workers(n: usize) -> WorkersGuard {
    WorkersGuard { prev: set_workers(n) }
}

impl Drop for WorkersGuard {
    fn drop(&mut self) {
        set_workers(self.prev);
    }
}

// ---------------------------------------------------------------------------
// Runtime ISA dispatch.
// ---------------------------------------------------------------------------

/// Instruction set the explicit-SIMD microkernels target.  Detected once
/// per process ([`detected_isa`]); overridable per thread ([`scoped_isa`])
/// so the parity tests can force the portable kernel and diff against it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable auto-vectorized kernel — the floor every arch has.
    Scalar,
    /// x86-64 with AVX2 (f32: 8-lane mul+add; int8: `pmaddwd` pairs).
    Avx2,
    /// x86-64 with AVX-512F+BW (compiled only on toolchains ≥ 1.89 — see
    /// `build.rs`; otherwise detection tops out at [`Isa::Avx2`]).
    Avx512,
    /// aarch64 NEON (baseline on every aarch64 target).
    Neon,
}

impl Isa {
    /// Short lowercase label for logs and bench output.
    pub fn label(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }
}

/// Best ISA the running CPU (and toolchain) supports, detected once.
pub fn detected_isa() -> Isa {
    static DETECTED: std::sync::OnceLock<Isa> = std::sync::OnceLock::new();
    *DETECTED.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            #[cfg(nsvd_avx512)]
            if std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx512bw")
            {
                return Isa::Avx512;
            }
            if std::arch::is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
            Isa::Scalar
        }
        #[cfg(target_arch = "aarch64")]
        {
            Isa::Neon
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            Isa::Scalar
        }
    })
}

thread_local! {
    static GEMM_ISA: std::cell::Cell<Option<Isa>> = std::cell::Cell::new(None);
}

/// The ISA the *calling thread's* GEMMs will use: the scoped override if
/// one is active, else [`detected_isa`].  Entry points read this once and
/// pass it down by value, so worker threads spawned inside a GEMM inherit
/// the caller's choice.
pub fn active_isa() -> Isa {
    GEMM_ISA.with(|c| c.get()).unwrap_or_else(detected_isa)
}

/// RAII guard restoring the previous per-thread ISA override on drop.
pub struct IsaGuard {
    prev: Option<Isa>,
}

/// Force this thread's GEMMs onto `isa` for the guard's lifetime — the
/// SIMD-vs-scalar bit-parity tests pin the dispatch contract with it.
/// Forcing an ISA the CPU lacks is undefined; tests only ever force
/// [`Isa::Scalar`] or the detected value.
pub fn scoped_isa(isa: Isa) -> IsaGuard {
    IsaGuard { prev: GEMM_ISA.with(|c| c.replace(Some(isa))) }
}

impl Drop for IsaGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        GEMM_ISA.with(|c| c.set(prev));
    }
}

/// One-line CPU feature summary (dispatch choice + raw detection flags)
/// for CI logs, so every run records which kernel tier it exercised.
pub fn cpu_features() -> String {
    let mut s = format!("dispatch={}", detected_isa().label());
    #[cfg(target_arch = "x86_64")]
    {
        for (name, on) in [
            ("avx2", std::arch::is_x86_feature_detected!("avx2")),
            ("fma", std::arch::is_x86_feature_detected!("fma")),
            ("avx512f", std::arch::is_x86_feature_detected!("avx512f")),
            ("avx512bw", std::arch::is_x86_feature_detected!("avx512bw")),
        ] {
            if on {
                s.push(' ');
                s.push_str(name);
            }
        }
        #[cfg(not(nsvd_avx512))]
        s.push_str(" (avx512 kernels not compiled: toolchain < 1.89)");
    }
    #[cfg(target_arch = "aarch64")]
    s.push_str(" neon");
    s
}

// ---------------------------------------------------------------------------
// Public entry points.
// ---------------------------------------------------------------------------

/// `C += A · B` with `A` m×k and `B` k×n, both row-major.
pub fn gemm_nn<T: Scalar>(m: usize, k: usize, n: usize, a: &[T], b: &[T], c: &mut [T], workers: usize) {
    gemm(Layout::NN, m, k, n, a, b, c, workers);
}

/// `C += Aᵀ · B` with `A` stored k×m and `B` k×n (row-major storage).
pub fn gemm_tn<T: Scalar>(m: usize, k: usize, n: usize, a: &[T], b: &[T], c: &mut [T], workers: usize) {
    gemm(Layout::TN, m, k, n, a, b, c, workers);
}

/// `C += A · Bᵀ` with `A` m×k and `B` stored n×k (row-major storage).
pub fn gemm_nt<T: Scalar>(m: usize, k: usize, n: usize, a: &[T], b: &[T], c: &mut [T], workers: usize) {
    gemm(Layout::NT, m, k, n, a, b, c, workers);
}

/// The generic entry point: `C += op(A)·op(B)` per `layout`, fanning row
/// blocks of C out over `workers` scoped threads (1 = fully serial).
pub fn gemm<T: Scalar>(
    layout: Layout,
    m: usize,
    k: usize,
    n: usize,
    a: &[T],
    b: &[T],
    c: &mut [T],
    workers: usize,
) {
    assert_eq!(a.len(), m * k, "gemm: A size mismatch ({layout:?}, m={m} k={k})");
    assert_eq!(b.len(), k * n, "gemm: B size mismatch ({layout:?}, k={k} n={n})");
    assert_eq!(c.len(), m * n, "gemm: C size mismatch (m={m} n={n})");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let row_blocks = m.div_ceil(MR);
    let workers = workers.max(1).min(row_blocks);
    // ISA is resolved ONCE on the calling thread (so a scoped override on
    // the caller governs the worker threads spawned below too) and passed
    // down by value into the microkernel dispatch.
    let isa = active_isa();
    let mut sp = crate::obs::span("kernel.gemm");
    if sp.is_recording() {
        sp.arg_u64("m", m as u64)
            .arg_u64("k", k as u64)
            .arg_u64("n", n as u64)
            .arg_u64("workers", workers as u64)
            .arg_str("isa", isa.label());
        crate::obs::metrics::counter_add(
            "kernel.gemm.flops",
            2 * (m as u64) * (k as u64) * (n as u64),
        );
        crate::obs::metrics::counter_add(
            "kernel.gemm.bytes",
            ((m * k + k * n + m * n) * std::mem::size_of::<T>()) as u64,
        );
    }
    // Pack buffers sized to the actual problem (capped at one full tile):
    // small products — rSVD sketches, low-rank factors — shouldn't pay a
    // full-tile zeroed allocation per call.
    let kc_cap = KC.min(k);
    let nc_cap = NC.min(n.div_ceil(NR) * NR);
    let mut bpack = vec![T::ZERO; kc_cap * nc_cap];
    if workers <= 1 {
        let mut apack = vec![T::ZERO; MC.min(m.div_ceil(MR) * MR) * kc_cap];
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            for pc in (0..k).step_by(KC) {
                let kc = KC.min(k - pc);
                pack_b(layout, b, k, n, pc, kc, jc, nc, &mut bpack);
                gemm_block(layout, 0, k, a, &bpack, &mut apack, c, pc, kc, nc, n, jc, isa);
            }
        }
        return;
    }
    // Parallel path: B is packed ONCE per (jc, pc) block — its contents do
    // not depend on the row range — then contiguous MR-aligned row chunks of
    // C fan out over scoped threads, each packing only its own A panels.
    // Disjoint C slices need no synchronization, and the per-element
    // accumulation order (ascending k) is independent of the worker count.
    let rows_per = row_blocks.div_ceil(workers) * MR;
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            pack_b(layout, b, k, n, pc, kc, jc, nc, &mut bpack);
            let bref: &[T] = &bpack;
            std::thread::scope(|scope| {
                for (ci, chunk) in c.chunks_mut(rows_per * n).enumerate() {
                    let row0 = ci * rows_per;
                    scope.spawn(move || {
                        let rows = chunk.len() / n;
                        let mut apack =
                            vec![T::ZERO; MC.min(rows.div_ceil(MR) * MR) * kc];
                        gemm_block(
                            layout, row0, k, a, bref, &mut apack, chunk, pc, kc, nc, n, jc, isa,
                        );
                    });
                }
            });
        }
    }
}

/// Symmetric rank-k update `C[upper] += AᵀA`, with `A` stored k×n row-major
/// (k sample rows of dimension n — the calibration layout) and `C` n×n
/// row-major.
///
/// Only the upper triangle (`j ≥ i`) of `C` is touched — callers that need
/// the full Gram mirror once at the end ([`Matrix::gram`], the calibration
/// collector's finalize) instead of per accumulation, which is where the
/// ~2× flop saving over [`gemm_tn`]`(A, A)` comes from.  The triangle is
/// tiled into [`SYRK_NC`]-wide column stripes (stripe `jc` covers rows
/// `0..jc+nc`); each stripe runs the same packing + microkernel pipeline as
/// [`gemm`] into a private buffer that is folded into `C` with one add per
/// element, so:
///
/// * the per-element accumulation order is fixed (ascending k within K
///   blocks, blocks ascending, one fold into C) — **bit-identical for
///   every worker count**, and bit-identical to the upper triangle of
///   `gemm_tn(A, A)` when `C` starts zeroed;
/// * workers claim stripes dynamically (an atomic cursor): stripes get
///   strictly more expensive left→right, so static chunking would idle the
///   early workers.
///
/// [`Matrix::gram`]: super::matrix::Matrix::gram
pub fn syrk_tn<T: Scalar>(n: usize, k: usize, a: &[T], c: &mut [T], workers: usize) {
    assert_eq!(a.len(), k * n, "syrk: A size mismatch (k={k} n={n})");
    assert_eq!(c.len(), n * n, "syrk: C size mismatch (n={n})");
    if n == 0 || k == 0 {
        return;
    }
    let tasks: Vec<(usize, usize)> = (0..n)
        .step_by(SYRK_NC)
        .map(|jc| (jc, SYRK_NC.min(n - jc)))
        .collect();
    let workers = workers.max(1).min(tasks.len());
    let isa = active_isa();
    let mut sp = crate::obs::span("kernel.syrk");
    if sp.is_recording() {
        sp.arg_u64("n", n as u64)
            .arg_u64("k", k as u64)
            .arg_u64("workers", workers as u64)
            .arg_str("isa", isa.label());
        // Upper-triangle update ≈ n(n+1)k MACs → count n²k flops.
        crate::obs::metrics::counter_add(
            "kernel.syrk.flops",
            (n as u64) * (n as u64) * (k as u64),
        );
        crate::obs::metrics::counter_add(
            "kernel.syrk.bytes",
            ((k * n + n * n) * std::mem::size_of::<T>()) as u64,
        );
    }
    if workers <= 1 {
        for &(jc, nc) in &tasks {
            let stripe = syrk_stripe(n, k, a, jc, nc, isa);
            add_stripe_upper(n, jc, nc, &stripe, c);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let done: std::sync::Mutex<Vec<(usize, Vec<T>)>> =
        std::sync::Mutex::new(Vec::with_capacity(tasks.len()));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut local: Vec<(usize, Vec<T>)> = Vec::new();
                loop {
                    let t = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if t >= tasks.len() {
                        break;
                    }
                    let (jc, nc) = tasks[t];
                    local.push((t, syrk_stripe(n, k, a, jc, nc, isa)));
                }
                done.lock().unwrap().extend(local);
            });
        }
    });
    let mut stripes = done.into_inner().unwrap();
    stripes.sort_by_key(|&(t, _)| t);
    for (t, stripe) in stripes {
        let (jc, nc) = tasks[t];
        add_stripe_upper(n, jc, nc, &stripe, c);
    }
}

/// One SYRK column stripe: rows `0..jc+nc`, columns `jc..jc+nc` of `AᵀA`,
/// accumulated into a fresh `(jc+nc)×nc` row-major buffer through the
/// packed TN pipeline (A plays both operands; no transpose materialized).
fn syrk_stripe<T: Scalar>(n: usize, k: usize, a: &[T], jc: usize, nc: usize, isa: Isa) -> Vec<T> {
    let rows = jc + nc;
    let kc_cap = KC.min(k);
    let mut bpack = vec![T::ZERO; kc_cap * nc.div_ceil(NR) * NR];
    let mut apack = vec![T::ZERO; MC.min(rows.div_ceil(MR) * MR) * kc_cap];
    let mut stripe = vec![T::ZERO; rows * nc];
    for pc in (0..k).step_by(KC) {
        let kc = KC.min(k - pc);
        pack_b(Layout::TN, a, k, n, pc, kc, jc, nc, &mut bpack);
        gemm_block(Layout::TN, 0, k, a, &bpack, &mut apack, &mut stripe, pc, kc, nc, nc, 0, isa);
    }
    stripe
}

/// Fold a stripe into `C`'s upper triangle (`j ≥ i` only — the stripe's
/// below-diagonal corner of the diagonal block is dropped, leaving the
/// strict lower triangle of `C` untouched).
fn add_stripe_upper<T: Scalar>(n: usize, jc: usize, nc: usize, stripe: &[T], c: &mut [T]) {
    for i in 0..jc + nc {
        let lo = i.saturating_sub(jc);
        let crow = &mut c[i * n + jc + lo..i * n + jc + nc];
        let srow = &stripe[i * nc + lo..(i + 1) * nc];
        for (cv, sv) in crow.iter_mut().zip(srow) {
            *cv += *sv;
        }
    }
}

/// Matrix–vector product `y += A·x` (`A` m×k row-major).  Four-way unrolled
/// dot products; always single-threaded (the shapes this system hits are
/// memory-bound and too small to amortize a spawn).
pub fn gemv<T: Scalar>(m: usize, k: usize, a: &[T], x: &[T], y: &mut [T]) {
    assert_eq!(a.len(), m * k, "gemv: A size mismatch");
    assert_eq!(x.len(), k, "gemv: x size mismatch");
    assert_eq!(y.len(), m, "gemv: y size mismatch");
    for (i, yi) in y.iter_mut().enumerate() {
        let row = &a[i * k..(i + 1) * k];
        let mut acc = [T::ZERO; 4];
        let mut chunks_a = row.chunks_exact(4);
        let mut chunks_x = x.chunks_exact(4);
        for (ca, cx) in (&mut chunks_a).zip(&mut chunks_x) {
            for l in 0..4 {
                acc[l] += ca[l] * cx[l];
            }
        }
        let mut tail = T::ZERO;
        for (av, xv) in chunks_a.remainder().iter().zip(chunks_x.remainder()) {
            tail += *av * *xv;
        }
        *yi += ((acc[0] + acc[1]) + (acc[2] + acc[3])) + tail;
    }
}

/// The retired naive kernel (k-panel blocked i-k-j loop), kept as the parity
/// reference for the property tests and the speedup baseline for
/// `benches/perf_linalg.rs` / `BENCH_gemm.json`.
pub fn naive_nn<T: Scalar>(m: usize, k: usize, n: usize, a: &[T], b: &[T], c: &mut [T]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    const KB: usize = 64;
    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        for i in 0..m {
            let a_row = &a[i * k..(i + 1) * k];
            let c_row = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = a_row[kk];
                let b_row = &b[kk * n..(kk + 1) * n];
                for (cv, bv) in c_row.iter_mut().zip(b_row.iter()) {
                    *cv += av * *bv;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// One (jc, pc) block over a row range of C.
// ---------------------------------------------------------------------------

/// Process one packed-B block: walk MC sub-blocks of C rows `[row0,
/// row0 + rows)` (where `rows = c.len() / ldc`; `c` covers exactly that row
/// range and `row0` is only needed to index into `a`), packing A panels into
/// `apack` and running the microkernel against `bpack` (already packed for
/// the `kc`-deep, `nc`-wide operand block).  The output geometry is
/// explicit so SYRK stripes can reuse this: `ldc` is `c`'s row stride and
/// `cj0` the column offset where the `nc`-wide block lands (`gemm` passes
/// `ldc = n`, `cj0 = jc`; a stripe passes `ldc = nc`, `cj0 = 0`).
#[allow(clippy::too_many_arguments)]
fn gemm_block<T: Scalar>(
    layout: Layout,
    row0: usize,
    k: usize,
    a: &[T],
    bpack: &[T],
    apack: &mut [T],
    c: &mut [T],
    pc: usize,
    kc: usize,
    nc: usize,
    ldc: usize,
    cj0: usize,
    isa: Isa,
) {
    // a's leading dimension: k for row-major m×k (NN/NT); for TN the element
    // (i, p) of op(A) lives at a[p * m_full + i], and m_full is recovered
    // from the slice length.
    let m_full = a.len() / k;
    let rows = c.len() / ldc;
    for ic in (0..rows).step_by(MC) {
        let mc = MC.min(rows - ic);
        pack_a(layout, a, m_full, k, row0 + ic, mc, pc, kc, apack);
        for jr in (0..nc).step_by(NR) {
            let nr_eff = NR.min(nc - jr);
            let bmicro = &bpack[(jr / NR) * (kc * NR)..][..kc * NR];
            for ir in (0..mc).step_by(MR) {
                let mr_eff = MR.min(mc - ir);
                let amicro = &apack[(ir / MR) * (kc * MR)..][..kc * MR];
                let mut acc = [[T::ZERO; NR]; MR];
                microkernel(amicro, bmicro, &mut acc, isa);
                for (i, acc_row) in acc.iter().enumerate().take(mr_eff) {
                    let crow = &mut c[(ic + ir + i) * ldc + cj0 + jr..][..nr_eff];
                    for (cv, av) in crow.iter_mut().zip(acc_row.iter()) {
                        *cv += *av;
                    }
                }
            }
        }
    }
}

/// MR×NR register block over one packed-A / packed-B micro-panel pair
/// (`ap.len() == kc·MR`, `bp.len() == kc·NR`).  Dispatches f32 panels to
/// the explicit-SIMD kernels when `isa` has one; everything else (and f64
/// always) takes [`microkernel_scalar`].  The SIMD kernels perform the
/// identical per-element operation sequence — ascending-k `mul` then `add`
/// into a zero-initialized accumulator, never FMA — so their output is
/// **bit-identical** to the scalar kernel (pinned by tests below).
#[inline(always)]
fn microkernel<T: Scalar>(ap: &[T], bp: &[T], acc: &mut [[T; NR]; MR], isa: Isa) {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    if isa != Isa::Scalar && std::any::TypeId::of::<T>() == std::any::TypeId::of::<f32>() {
        // T == f32 proven by the TypeId check: reinterpret the panels and
        // the accumulator in place (same layout, same lifetime).
        let apf = unsafe { std::slice::from_raw_parts(ap.as_ptr() as *const f32, ap.len()) };
        let bpf = unsafe { std::slice::from_raw_parts(bp.as_ptr() as *const f32, bp.len()) };
        let accf = unsafe { &mut *(acc as *mut [[T; NR]; MR] as *mut [[f32; NR]; MR]) };
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => return unsafe { microkernel_f32_avx2(apf, bpf, accf) },
            #[cfg(all(target_arch = "x86_64", nsvd_avx512))]
            Isa::Avx512 => return unsafe { microkernel_f32_avx512(apf, bpf, accf) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => return unsafe { microkernel_f32_neon(apf, bpf, accf) },
            _ => {}
        }
    }
    microkernel_scalar(ap, bp, acc)
}

/// The portable auto-vectorized kernel: `chunks_exact` + fixed-size array
/// views make every access provably in-bounds, so LLVM unrolls the `i`/`j`
/// loops and vectorizes the multiply-add with no bounds checks.
#[inline(always)]
fn microkernel_scalar<T: Scalar>(ap: &[T], bp: &[T], acc: &mut [[T; NR]; MR]) {
    for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        let av: &[T; MR] = av.try_into().expect("exact MR chunk");
        let bv: &[T; NR] = bv.try_into().expect("exact NR chunk");
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let ai = av[i];
            for (j, cell) in acc_row.iter_mut().enumerate() {
                *cell += ai * bv[j];
            }
        }
    }
}

/// AVX2 f32 microkernel: one 8-lane vector holds the MR=8 rows of a k-step;
/// each of the NR=4 columns keeps a running-sum register.  Separate
/// `mul_ps`/`add_ps` (no FMA) reproduces the scalar kernel's two-rounding
/// sequence exactly.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn microkernel_f32_avx2(ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::x86_64::*;
    let kc = bp.len() / NR;
    debug_assert_eq!(ap.len(), kc * MR);
    let mut cols = [_mm256_setzero_ps(); NR];
    for p in 0..kc {
        let av = _mm256_loadu_ps(ap.as_ptr().add(p * MR));
        let b = bp.as_ptr().add(p * NR);
        for (j, col) in cols.iter_mut().enumerate() {
            *col = _mm256_add_ps(*col, _mm256_mul_ps(av, _mm256_set1_ps(*b.add(j))));
        }
    }
    let mut t = [0.0f32; MR];
    for (j, col) in cols.iter().enumerate() {
        _mm256_storeu_ps(t.as_mut_ptr(), *col);
        for (i, acc_row) in acc.iter_mut().enumerate() {
            acc_row[j] += t[i];
        }
    }
}

/// AVX-512 f32 microkernel: each zmm holds the 8 rows twice (lane-duped via
/// `shuffle_f32x4`), paired with a two-column blend of broadcast B values —
/// 2 zmm accumulators cover the full 8×4 tile.  AVX512F-only intrinsics;
/// still strictly mul-then-add.
#[cfg(all(target_arch = "x86_64", nsvd_avx512))]
#[target_feature(enable = "avx512f,avx2")]
unsafe fn microkernel_f32_avx512(ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::x86_64::*;
    let kc = bp.len() / NR;
    debug_assert_eq!(ap.len(), kc * MR);
    let mut c01 = _mm512_setzero_ps();
    let mut c23 = _mm512_setzero_ps();
    for p in 0..kc {
        let a8 = _mm512_castps256_ps512(_mm256_loadu_ps(ap.as_ptr().add(p * MR)));
        // Lanes {0,1,0,1}: the 8 rows duplicated into both zmm halves.
        let aa = _mm512_shuffle_f32x4::<0x44>(a8, a8);
        let b = bp.as_ptr().add(p * NR);
        let b01 = _mm512_mask_blend_ps(0xFF00, _mm512_set1_ps(*b), _mm512_set1_ps(*b.add(1)));
        let b23 =
            _mm512_mask_blend_ps(0xFF00, _mm512_set1_ps(*b.add(2)), _mm512_set1_ps(*b.add(3)));
        c01 = _mm512_add_ps(c01, _mm512_mul_ps(aa, b01));
        c23 = _mm512_add_ps(c23, _mm512_mul_ps(aa, b23));
    }
    let mut t = [0.0f32; 16];
    _mm512_storeu_ps(t.as_mut_ptr(), c01);
    for (i, acc_row) in acc.iter_mut().enumerate() {
        acc_row[0] += t[i];
        acc_row[1] += t[MR + i];
    }
    _mm512_storeu_ps(t.as_mut_ptr(), c23);
    for (i, acc_row) in acc.iter_mut().enumerate() {
        acc_row[2] += t[i];
        acc_row[3] += t[MR + i];
    }
}

/// NEON f32 microkernel: the 8 rows split across two q-registers per
/// column; `vmulq`+`vaddq` (never `vfmaq`) for scalar bit-parity.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn microkernel_f32_neon(ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::aarch64::*;
    let kc = bp.len() / NR;
    debug_assert_eq!(ap.len(), kc * MR);
    let mut lo = [vdupq_n_f32(0.0); NR];
    let mut hi = [vdupq_n_f32(0.0); NR];
    for p in 0..kc {
        let a_lo = vld1q_f32(ap.as_ptr().add(p * MR));
        let a_hi = vld1q_f32(ap.as_ptr().add(p * MR + 4));
        let b = bp.as_ptr().add(p * NR);
        for j in 0..NR {
            let bj = vdupq_n_f32(*b.add(j));
            lo[j] = vaddq_f32(lo[j], vmulq_f32(a_lo, bj));
            hi[j] = vaddq_f32(hi[j], vmulq_f32(a_hi, bj));
        }
    }
    let mut t = [0.0f32; 4];
    for j in 0..NR {
        vst1q_f32(t.as_mut_ptr(), lo[j]);
        for i in 0..4 {
            acc[i][j] += t[i];
        }
        vst1q_f32(t.as_mut_ptr(), hi[j]);
        for i in 0..4 {
            acc[4 + i][j] += t[i];
        }
    }
}

/// Pack `op(A)[ic..ic+mc, pc..pc+kc]` into MR-tall k-major micro-panels,
/// zero-padding the last panel so the microkernel never branches on height.
#[allow(clippy::too_many_arguments)]
fn pack_a<T: Scalar>(
    layout: Layout,
    a: &[T],
    m_full: usize,
    k: usize,
    ic: usize,
    mc: usize,
    pc: usize,
    kc: usize,
    apack: &mut [T],
) {
    for ip in 0..mc.div_ceil(MR) {
        let panel = &mut apack[ip * (kc * MR)..(ip + 1) * (kc * MR)];
        let rows_here = MR.min(mc - ip * MR);
        for p in 0..kc {
            let dst = &mut panel[p * MR..(p + 1) * MR];
            for (i, d) in dst.iter_mut().enumerate() {
                *d = if i < rows_here {
                    let r = ic + ip * MR + i;
                    match layout {
                        // op(A)[r, pc+p] for row-major A (NN and NT share it).
                        Layout::NN | Layout::NT => a[r * k + pc + p],
                        // op(A) = Aᵀ with A stored k×m: element at [pc+p, r].
                        Layout::TN => a[(pc + p) * m_full + r],
                    }
                } else {
                    T::ZERO
                };
            }
        }
    }
}

/// Pack `op(B)[pc..pc+kc, jc..jc+nc]` into NR-wide k-major micro-panels,
/// zero-padding the last panel so the microkernel never branches on width.
#[allow(clippy::too_many_arguments)]
fn pack_b<T: Scalar>(
    layout: Layout,
    b: &[T],
    k: usize,
    n: usize,
    pc: usize,
    kc: usize,
    jc: usize,
    nc: usize,
    bpack: &mut [T],
) {
    for jp in 0..nc.div_ceil(NR) {
        let panel = &mut bpack[jp * (kc * NR)..(jp + 1) * (kc * NR)];
        let cols_here = NR.min(nc - jp * NR);
        for p in 0..kc {
            let dst = &mut panel[p * NR..(p + 1) * NR];
            for (j, d) in dst.iter_mut().enumerate() {
                *d = if j < cols_here {
                    let col = jc + jp * NR + j;
                    match layout {
                        // op(B)[pc+p, col] for row-major k×n B (NN and TN).
                        Layout::NN | Layout::TN => b[(pc + p) * n + col],
                        // op(B) = Bᵀ with B stored n×k: element at [col, pc+p].
                        Layout::NT => b[col * k + pc + p],
                    }
                } else {
                    T::ZERO
                };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Int8 quantized path: i8×i8 → i32 with a dequant-fused f32 epilogue.
// ---------------------------------------------------------------------------

/// Quantized product `C += (Aq ∘ Sa) · (Bq ∘ Sb)` where `Aq` is `m×k` i8
/// (activations, scales `Sa` per `(row, k-group)`, row-major `m×n_groups`)
/// and `Bq` is `k×n` i8 (a factor, scales `Sb` per `(k-group, column)`,
/// row-major `n_groups×n`), both produced by [`super::quant`].  `C` is
/// `m×n` f32.
///
/// Same MC/NC blocking and panel packing as [`gemm`], but K blocks follow
/// the `group` boundaries so every block's i32 dot carries exactly one
/// `(Sa, Sb)` pair; the epilogue applies `C += (sa·sb)·(acc as f32)` with
/// groups ascending.  With `group ≤ 128` the group dot fits 2²⁴, so the
/// accumulation AND the i32→f32 conversion are exact, making the result
/// **bit-identical at every worker count** and per-row independent (a
/// batched decode row equals the same row served alone) — pinned against
/// the naive [`gemm_i8_ref`] below, bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_nn(
    m: usize,
    k: usize,
    n: usize,
    aq: &[i8],
    a_scales: &[f32],
    bq: &[i8],
    b_scales: &[f32],
    group: usize,
    c: &mut [f32],
    workers: usize,
) {
    let group = group.clamp(1, super::quant::GROUP_MAX).min(k.max(1));
    let n_groups = k.div_ceil(group);
    assert_eq!(aq.len(), m * k, "gemm_i8: A size mismatch (m={m} k={k})");
    assert_eq!(bq.len(), k * n, "gemm_i8: B size mismatch (k={k} n={n})");
    assert_eq!(c.len(), m * n, "gemm_i8: C size mismatch (m={m} n={n})");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    assert_eq!(a_scales.len(), m * n_groups, "gemm_i8: A scales mismatch");
    assert_eq!(b_scales.len(), n_groups * n, "gemm_i8: B scales mismatch");
    let isa = active_isa();
    let row_blocks = m.div_ceil(MR);
    let workers = workers.max(1).min(row_blocks);
    let mut sp = crate::obs::span("kernel.gemm_i8");
    if sp.is_recording() {
        sp.arg_u64("m", m as u64)
            .arg_u64("k", k as u64)
            .arg_u64("n", n as u64)
            .arg_u64("workers", workers as u64)
            .arg_str("isa", isa.label());
        crate::obs::metrics::counter_add(
            "kernel.gemm_i8.flops",
            2 * (m as u64) * (k as u64) * (n as u64),
        );
        crate::obs::metrics::counter_add(
            "kernel.gemm_i8.bytes",
            (m * k + k * n + 4 * m * n) as u64,
        );
    }
    let kc2_cap = group.div_ceil(2);
    let nc_cap = NC.min(n.div_ceil(NR) * NR);
    let mut bpack = vec![0i8; kc2_cap * 2 * nc_cap];
    if workers <= 1 {
        let mut apack = vec![0i8; MC.min(m.div_ceil(MR) * MR) * kc2_cap * 2];
        for jc in (0..n).step_by(NC) {
            let nc = NC.min(n - jc);
            for g in 0..n_groups {
                let pc = g * group;
                let kc = group.min(k - pc);
                pack_b_i8(bq, n, pc, kc, jc, nc, &mut bpack);
                gemm_i8_block(
                    0, k, n_groups, g, aq, a_scales, b_scales, &bpack, &mut apack, c, pc, kc,
                    nc, n, jc, isa,
                );
            }
        }
        return;
    }
    // Parallel path mirrors the f32 kernel: B packed once per (jc, group)
    // block, disjoint MR-aligned row chunks of C fanned out over scoped
    // threads.  Integer accumulation is exact, so determinism needs no
    // ordering argument at all here — only the epilogue's ascending-g adds,
    // which each element sees exactly once per group regardless of workers.
    let rows_per = row_blocks.div_ceil(workers) * MR;
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for g in 0..n_groups {
            let pc = g * group;
            let kc = group.min(k - pc);
            pack_b_i8(bq, n, pc, kc, jc, nc, &mut bpack);
            let bref: &[i8] = &bpack;
            std::thread::scope(|scope| {
                for (ci, chunk) in c.chunks_mut(rows_per * n).enumerate() {
                    let row0 = ci * rows_per;
                    scope.spawn(move || {
                        let rows = chunk.len() / n;
                        let mut apack =
                            vec![0i8; MC.min(rows.div_ceil(MR) * MR) * kc.div_ceil(2) * 2];
                        gemm_i8_block(
                            row0, k, n_groups, g, aq, a_scales, b_scales, bref, &mut apack,
                            chunk, pc, kc, nc, n, jc, isa,
                        );
                    });
                }
            });
        }
    }
}

/// Naive i8 reference: per `(i, j, group)` an i32 dot followed by the same
/// dequant add the tiled epilogue performs — the bit-exact parity oracle
/// for [`gemm_i8_nn`] (integer dots are order-independent and the f32
/// epilogue adds groups in the same ascending order).
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_ref(
    m: usize,
    k: usize,
    n: usize,
    aq: &[i8],
    a_scales: &[f32],
    bq: &[i8],
    b_scales: &[f32],
    group: usize,
    c: &mut [f32],
) {
    let group = group.clamp(1, super::quant::GROUP_MAX).min(k.max(1));
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let n_groups = k.div_ceil(group);
    for i in 0..m {
        for j in 0..n {
            for g in 0..n_groups {
                let p0 = g * group;
                let p1 = (p0 + group).min(k);
                let mut acc: i32 = 0;
                for p in p0..p1 {
                    acc += aq[i * k + p] as i32 * bq[p * n + j] as i32;
                }
                c[i * n + j] +=
                    (a_scales[i * n_groups + g] * b_scales[g * n + j]) * acc as f32;
            }
        }
    }
}

/// One packed-B int8 block over a row range of C (geometry as
/// [`gemm_block`], specialized to NN and a single k-group per call).
#[allow(clippy::too_many_arguments)]
fn gemm_i8_block(
    row0: usize,
    k: usize,
    n_groups: usize,
    g: usize,
    aq: &[i8],
    a_scales: &[f32],
    b_scales: &[f32],
    bpack: &[i8],
    apack: &mut [i8],
    c: &mut [f32],
    pc: usize,
    kc: usize,
    nc: usize,
    ldc: usize,
    cj0: usize,
    isa: Isa,
) {
    let kc2 = kc.div_ceil(2);
    let rows = c.len() / ldc;
    for ic in (0..rows).step_by(MC) {
        let mc = MC.min(rows - ic);
        pack_a_i8(aq, k, row0 + ic, mc, pc, kc, apack);
        for jr in (0..nc).step_by(NR) {
            let nr_eff = NR.min(nc - jr);
            let bmicro = &bpack[(jr / NR) * (kc2 * NR * 2)..][..kc2 * NR * 2];
            for ir in (0..mc).step_by(MR) {
                let mr_eff = MR.min(mc - ir);
                let amicro = &apack[(ir / MR) * (kc2 * MR * 2)..][..kc2 * MR * 2];
                let mut acc = [[0i32; NR]; MR];
                microkernel_i8(amicro, bmicro, &mut acc, isa);
                for (i, acc_row) in acc.iter().enumerate().take(mr_eff) {
                    let sa = a_scales[(row0 + ic + ir + i) * n_groups + g];
                    let crow = &mut c[(ic + ir + i) * ldc + cj0 + jr..][..nr_eff];
                    for (j, cv) in crow.iter_mut().enumerate() {
                        let sb = b_scales[g * ldc + cj0 + jr + j];
                        *cv += (sa * sb) * acc_row[j] as f32;
                    }
                }
            }
        }
    }
}

/// Pack `Aq[ic..ic+mc, pc..pc+kc]` into MR-tall **pair-major** micro-panels
/// (`[kc/2][MR][2]` per panel, odd k zero-padded): each row contributes
/// adjacent k-pairs so the SIMD kernels can widen i8→i16 and ride exact
/// `pmaddwd`-style pair dots.
fn pack_a_i8(a: &[i8], k: usize, ic: usize, mc: usize, pc: usize, kc: usize, apack: &mut [i8]) {
    let kc2 = kc.div_ceil(2);
    for ip in 0..mc.div_ceil(MR) {
        let panel = &mut apack[ip * (kc2 * MR * 2)..(ip + 1) * (kc2 * MR * 2)];
        let rows_here = MR.min(mc - ip * MR);
        for p2 in 0..kc2 {
            let dst = &mut panel[p2 * MR * 2..(p2 + 1) * MR * 2];
            for i in 0..MR {
                for h in 0..2 {
                    let p = 2 * p2 + h;
                    dst[i * 2 + h] = if i < rows_here && p < kc {
                        a[(ic + ip * MR + i) * k + pc + p]
                    } else {
                        0
                    };
                }
            }
        }
    }
}

/// Pack `Bq[pc..pc+kc, jc..jc+nc]` into NR-wide pair-major micro-panels
/// (`[kc/2][NR][2]`, odd k zero-padded), mirroring [`pack_a_i8`].
fn pack_b_i8(b: &[i8], n: usize, pc: usize, kc: usize, jc: usize, nc: usize, bpack: &mut [i8]) {
    let kc2 = kc.div_ceil(2);
    for jp in 0..nc.div_ceil(NR) {
        let panel = &mut bpack[jp * (kc2 * NR * 2)..(jp + 1) * (kc2 * NR * 2)];
        let cols_here = NR.min(nc - jp * NR);
        for p2 in 0..kc2 {
            let dst = &mut panel[p2 * NR * 2..(p2 + 1) * NR * 2];
            for j in 0..NR {
                for h in 0..2 {
                    let p = 2 * p2 + h;
                    dst[j * 2 + h] = if j < cols_here && p < kc {
                        b[(pc + p) * n + jc + jp * NR + j]
                    } else {
                        0
                    };
                }
            }
        }
    }
}

/// The i16 word holding column `j`'s k-pair `(b0, b1)` of a pair-major B
/// step: little-endian `(b1 << 16) | b0` with each byte sign-extended to
/// i16 — what `pmaddwd`/`smull` consume after broadcasting.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
fn bpair_word(b: &[i8], j: usize) -> i32 {
    let b0 = b[2 * j] as i16 as u16 as u32;
    let b1 = b[2 * j + 1] as i16 as u16 as u32;
    (b0 | (b1 << 16)) as i32
}

/// i8 microkernel dispatch over one pair-major panel pair
/// (`ap.len() == kc2·MR·2`, `bp.len() == kc2·NR·2`).  All tiers compute
/// the identical exact integer sums, so the choice is invisible to output.
#[inline(always)]
fn microkernel_i8(ap: &[i8], bp: &[i8], acc: &mut [[i32; NR]; MR], isa: Isa) {
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => return unsafe { microkernel_i8_avx2(ap, bp, acc) },
        #[cfg(all(target_arch = "x86_64", nsvd_avx512))]
        Isa::Avx512 => return unsafe { microkernel_i8_avx512(ap, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => return unsafe { microkernel_i8_neon(ap, bp, acc) },
        _ => {}
    }
    microkernel_i8_scalar(ap, bp, acc)
}

/// Portable i8 kernel: widen to i32 and multiply-accumulate the pair
/// layout directly (LLVM auto-vectorizes the fixed-extent loops).
#[inline(always)]
fn microkernel_i8_scalar(ap: &[i8], bp: &[i8], acc: &mut [[i32; NR]; MR]) {
    for (av, bv) in ap.chunks_exact(MR * 2).zip(bp.chunks_exact(NR * 2)) {
        for (i, acc_row) in acc.iter_mut().enumerate() {
            let a0 = av[i * 2] as i32;
            let a1 = av[i * 2 + 1] as i32;
            for (j, cell) in acc_row.iter_mut().enumerate() {
                *cell += a0 * bv[j * 2] as i32 + a1 * bv[j * 2 + 1] as i32;
            }
        }
    }
}

/// AVX2 i8 kernel: one 128-bit load holds the 8 rows × 2 k-steps of a pair
/// step; sign-extend to 16×i16, `pmaddwd` against the broadcast column
/// pair-word → 8 exact per-row pair dots per instruction.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn microkernel_i8_avx2(ap: &[i8], bp: &[i8], acc: &mut [[i32; NR]; MR]) {
    use std::arch::x86_64::*;
    let kc2 = bp.len() / (NR * 2);
    debug_assert_eq!(ap.len(), kc2 * MR * 2);
    let mut cols = [_mm256_setzero_si256(); NR];
    for p2 in 0..kc2 {
        let a16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
            ap.as_ptr().add(p2 * MR * 2) as *const __m128i
        ));
        let b = &bp[p2 * NR * 2..(p2 + 1) * NR * 2];
        for (j, col) in cols.iter_mut().enumerate() {
            let bv = _mm256_set1_epi32(bpair_word(b, j));
            *col = _mm256_add_epi32(*col, _mm256_madd_epi16(a16, bv));
        }
    }
    let mut t = [0i32; MR];
    for (j, col) in cols.iter().enumerate() {
        _mm256_storeu_si256(t.as_mut_ptr() as *mut __m256i, *col);
        for (i, acc_row) in acc.iter_mut().enumerate() {
            acc_row[j] += t[i];
        }
    }
}

/// AVX-512 i8 kernel: two pair steps (32 bytes of packed A) widen at once;
/// the two column pair-words blend into one zmm so `madd_epi16` covers
/// both steps; an AVX2 step handles an odd trailing pair.
#[cfg(all(target_arch = "x86_64", nsvd_avx512))]
#[target_feature(enable = "avx512f,avx512bw,avx2")]
unsafe fn microkernel_i8_avx512(ap: &[i8], bp: &[i8], acc: &mut [[i32; NR]; MR]) {
    use std::arch::x86_64::*;
    let kc2 = bp.len() / (NR * 2);
    debug_assert_eq!(ap.len(), kc2 * MR * 2);
    let mut cols = [_mm512_setzero_si512(); NR];
    for q in 0..kc2 / 2 {
        let a16 = _mm512_cvtepi8_epi16(_mm256_loadu_si256(
            ap.as_ptr().add(q * 2 * MR * 2) as *const __m256i
        ));
        let b = &bp[q * 2 * NR * 2..(q * 2 + 2) * NR * 2];
        for (j, col) in cols.iter_mut().enumerate() {
            let w0 = _mm512_set1_epi32(bpair_word(b, j));
            let w1 = _mm512_set1_epi32(bpair_word(&b[NR * 2..], j));
            let bv = _mm512_mask_blend_epi32(0xFF00, w0, w1);
            *col = _mm512_add_epi32(*col, _mm512_madd_epi16(a16, bv));
        }
    }
    let mut t = [0i32; MR];
    for (j, col) in cols.iter().enumerate() {
        let mut s = _mm256_add_epi32(
            _mm512_castsi512_si256(*col),
            _mm512_extracti64x4_epi64::<1>(*col),
        );
        if kc2 % 2 == 1 {
            let p2 = kc2 - 1;
            let a16 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                ap.as_ptr().add(p2 * MR * 2) as *const __m128i
            ));
            let bv = _mm256_set1_epi32(bpair_word(&bp[p2 * NR * 2..], j));
            s = _mm256_add_epi32(s, _mm256_madd_epi16(a16, bv));
        }
        _mm256_storeu_si256(t.as_mut_ptr() as *mut __m256i, s);
        for (i, acc_row) in acc.iter_mut().enumerate() {
            acc_row[j] += t[i];
        }
    }
}

/// NEON i8 kernel: `vmovl_s8` widening, widening `vmull_s16` pair products
/// folded with `vpaddq_s32` → 4 exact per-row pair dots per fold, two
/// q-registers covering the 8 rows.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn microkernel_i8_neon(ap: &[i8], bp: &[i8], acc: &mut [[i32; NR]; MR]) {
    use std::arch::aarch64::*;
    let kc2 = bp.len() / (NR * 2);
    debug_assert_eq!(ap.len(), kc2 * MR * 2);
    let mut lo = [vdupq_n_s32(0); NR];
    let mut hi = [vdupq_n_s32(0); NR];
    for p2 in 0..kc2 {
        let a8 = vld1q_s8(ap.as_ptr().add(p2 * MR * 2));
        let a_lo = vmovl_s8(vget_low_s8(a8)); // rows 0..4 as 4 (i16,i16) pairs
        let a_hi = vmovl_s8(vget_high_s8(a8)); // rows 4..8
        let b = &bp[p2 * NR * 2..(p2 + 1) * NR * 2];
        for j in 0..NR {
            let bv = vreinterpretq_s16_s32(vdupq_n_s32(bpair_word(b, j)));
            let p0 = vmull_s16(vget_low_s16(a_lo), vget_low_s16(bv));
            let p1 = vmull_s16(vget_high_s16(a_lo), vget_high_s16(bv));
            lo[j] = vaddq_s32(lo[j], vpaddq_s32(p0, p1));
            let p2v = vmull_s16(vget_low_s16(a_hi), vget_low_s16(bv));
            let p3 = vmull_s16(vget_high_s16(a_hi), vget_high_s16(bv));
            hi[j] = vaddq_s32(hi[j], vpaddq_s32(p2v, p3));
        }
    }
    let mut t = [0i32; 4];
    for j in 0..NR {
        vst1q_s32(t.as_mut_ptr(), lo[j]);
        for i in 0..4 {
            acc[i][j] += t[i];
        }
        vst1q_s32(t.as_mut_ptr(), hi[j]);
        for i in 0..4 {
            acc[4 + i][j] += t[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Dumb triple-loop reference in the layout's own indexing (independent
    /// of both the tiled kernel and `naive_nn`).
    fn reference<T: Scalar>(layout: Layout, m: usize, k: usize, n: usize, a: &[T], b: &[T]) -> Vec<T> {
        let mut c = vec![T::ZERO; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = T::ZERO;
                for p in 0..k {
                    let av = match layout {
                        Layout::NN | Layout::NT => a[i * k + p],
                        Layout::TN => a[p * m + i],
                    };
                    let bv = match layout {
                        Layout::NN | Layout::TN => b[p * n + j],
                        Layout::NT => b[j * k + p],
                    };
                    acc += av * bv;
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn randn_vec<T: Scalar>(len: usize, rng: &mut Rng) -> Vec<T> {
        (0..len).map(|_| T::from_f64(rng.normal())).collect()
    }

    fn max_abs_diff<T: Scalar>(x: &[T], y: &[T]) -> f64 {
        x.iter()
            .zip(y)
            .map(|(a, b)| (a.to_f64() - b.to_f64()).abs())
            .fold(0.0, f64::max)
    }

    fn check_parity<T: Scalar>(tol: f64, cases: usize, label: &str) {
        check(label, cases, |g| {
            let mut rng = g.rng.fork(0);
            // Shape classes: tall, wide, tiny, and non-multiple-of-tile;
            // dimensions straddle MR/NR/MC boundaries.
            let m = *g.choose(&[1usize, 2, 3, 7, 8, 9, 17, 65, 70]);
            let k = *g.choose(&[1usize, 2, 5, 16, 33, 64, 100]);
            let n = *g.choose(&[1usize, 2, 3, 4, 5, 11, 12, 66]);
            let layout = *g.choose(&[Layout::NN, Layout::TN, Layout::NT]);
            let a: Vec<T> = randn_vec(m * k, &mut rng);
            let b: Vec<T> = randn_vec(k * n, &mut rng);
            let want = reference(layout, m, k, n, &a, &b);
            for workers in [1usize, 4] {
                let mut got = vec![T::ZERO; m * n];
                gemm(layout, m, k, n, &a, &b, &mut got, workers);
                let err = max_abs_diff(&got, &want);
                // Scale the tolerance with the accumulation length.
                if err > tol * (1.0 + k as f64) {
                    return Err(format!(
                        "{layout:?} {m}x{k}x{n} w={workers}: err {err:e}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tiled_matches_reference_f64() {
        check_parity::<f64>(1e-12, 40, "tiled gemm == reference (f64)");
    }

    #[test]
    fn tiled_matches_reference_f32() {
        check_parity::<f32>(1e-4, 40, "tiled gemm == reference (f32)");
    }

    #[test]
    fn tiled_matches_naive_bitwise() {
        // For k ≤ KC (single K block) the tiled kernel performs the exact
        // same ascending-k addition sequence per element as the retired
        // naive loop ⇒ bit-identical output, which is what let the callers
        // rewire without moving any test tolerance.
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[(17usize, 33usize, 12usize), (64, 64, 64), (70, 100, 66)] {
            let a: Vec<f64> = randn_vec(m * k, &mut rng);
            let b: Vec<f64> = randn_vec(k * n, &mut rng);
            let mut c_naive = vec![0.0; m * n];
            naive_nn(m, k, n, &a, &b, &mut c_naive);
            let mut c_tiled = vec![0.0; m * n];
            gemm_nn(m, k, n, &a, &b, &mut c_tiled, 1);
            assert_eq!(c_naive, c_tiled, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let mut rng = Rng::new(8);
        let (m, k, n) = (130usize, 90usize, 75usize);
        let a: Vec<f64> = randn_vec(m * k, &mut rng);
        let b: Vec<f64> = randn_vec(k * n, &mut rng);
        let mut base = vec![0.0; m * n];
        gemm_nn(m, k, n, &a, &b, &mut base, 1);
        for workers in [2usize, 3, 4, 9] {
            let mut c = vec![0.0; m * n];
            gemm_nn(m, k, n, &a, &b, &mut c, workers);
            assert_eq!(base, c, "workers={workers} must be bit-identical");
        }
        let af: Vec<f32> = randn_vec(m * k, &mut rng);
        let bf: Vec<f32> = randn_vec(k * n, &mut rng);
        let mut base_f = vec![0.0f32; m * n];
        gemm_nn(m, k, n, &af, &bf, &mut base_f, 1);
        let mut c_f = vec![0.0f32; m * n];
        gemm_nn(m, k, n, &af, &bf, &mut c_f, 4);
        assert_eq!(base_f, c_f);
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        // k = 0: C untouched (the product is an empty sum).
        let mut c = vec![1.0f64; 6];
        gemm_nn(2, 0, 3, &[], &[], &mut c, 4);
        assert_eq!(c, vec![1.0; 6]);
        // m = 0 / n = 0: nothing to do, must not panic.
        let mut empty: Vec<f64> = Vec::new();
        gemm_nn(0, 5, 3, &[], &vec![0.0; 15], &mut empty, 2);
        gemm_nn(3, 5, 0, &vec![0.0; 15], &[], &mut empty, 2);
        // 1×1×1.
        let mut c1 = vec![0.0f64];
        gemm_nn(1, 1, 1, &[3.0], &[4.0], &mut c1, 4);
        assert_eq!(c1, vec![12.0]);
    }

    #[test]
    fn accumulates_into_existing_c() {
        // gemm is C += A·B, which the nested two-stage apply relies on.
        let mut c = vec![10.0f64; 4];
        gemm_nn(2, 2, 2, &[1.0, 0.0, 0.0, 1.0], &[1.0, 2.0, 3.0, 4.0], &mut c, 1);
        assert_eq!(c, vec![11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn gemv_matches_gemm_column() {
        check("gemv == gemm with n=1", 20, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(1, 40);
            let k = g.usize_in(1, 40);
            let a: Vec<f64> = randn_vec(m * k, &mut rng);
            let x: Vec<f64> = randn_vec(k, &mut rng);
            let mut y = vec![0.0; m];
            gemv(m, k, &a, &x, &mut y);
            let mut want = vec![0.0; m];
            gemm_nn(m, k, 1, &a, &x, &mut want, 1);
            let err = max_abs_diff(&y, &want);
            if err > 1e-12 * (1.0 + k as f64) {
                return Err(format!("{m}x{k}: err {err:e}"));
            }
            Ok(())
        });
    }

    #[test]
    fn syrk_matches_tn_upper_bitwise() {
        // On a zeroed C, the SYRK upper triangle must be BIT-identical to
        // gemm_tn(A, A) at every worker count, across tall/wide/tiny/1×1
        // shapes and k values that straddle the KC block boundary; the
        // strict lower triangle must stay untouched.
        check("syrk == gemm_tn upper (bitwise)", 40, |g| {
            let mut rng = g.rng.fork(0);
            let n = *g.choose(&[1usize, 2, 3, 5, 17, 63, 64, 65, 130]);
            let k = *g.choose(&[1usize, 2, 7, 33, 256, 300]);
            let a: Vec<f64> = randn_vec(k * n, &mut rng);
            let mut want = vec![0.0; n * n];
            gemm_tn(n, k, n, &a, &a, &mut want, 1);
            for workers in [1usize, 4] {
                let mut got = vec![0.0; n * n];
                syrk_tn(n, k, &a, &mut got, workers);
                for i in 0..n {
                    for j in 0..n {
                        if j >= i {
                            if got[i * n + j] != want[i * n + j] {
                                return Err(format!(
                                    "n={n} k={k} w={workers}: ({i},{j}) {} != {}",
                                    got[i * n + j],
                                    want[i * n + j]
                                ));
                            }
                        } else if got[i * n + j] != 0.0 {
                            return Err(format!(
                                "n={n} k={k} w={workers}: lower ({i},{j}) written"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn syrk_accumulates_and_is_worker_deterministic() {
        // C += semantics on a pre-filled C; with k > KC the fold order
        // differs from gemm_tn's per-K-block adds, but must be identical
        // across worker counts (one stripe fold per element).
        let mut rng = Rng::new(15);
        let (n, k) = (97usize, 300usize);
        let a: Vec<f64> = randn_vec(k * n, &mut rng);
        let mut base = vec![3.0; n * n];
        syrk_tn(n, k, &a, &mut base, 1);
        for workers in [2usize, 4, 9] {
            let mut c = vec![3.0; n * n];
            syrk_tn(n, k, &a, &mut c, workers);
            assert_eq!(base, c, "workers={workers} must be bit-identical");
        }
        // Strict lower triangle keeps its prior contents.
        for i in 0..n {
            for j in 0..i {
                assert_eq!(base[i * n + j], 3.0);
            }
        }
        // f32 instantiation (the f32 path has no Gram caller today, but the
        // genericity contract is pinned like the GEMM one).
        let af: Vec<f32> = randn_vec(k * n, &mut rng);
        let mut c1 = vec![0.0f32; n * n];
        let mut c4 = vec![0.0f32; n * n];
        syrk_tn(n, k, &af, &mut c1, 1);
        syrk_tn(n, k, &af, &mut c4, 4);
        assert_eq!(c1, c4);
    }

    #[test]
    fn syrk_degenerate_shapes() {
        // k = 0: empty sum, C untouched.
        let mut c = vec![2.0f64; 9];
        syrk_tn(3, 0, &[], &mut c, 4);
        assert_eq!(c, vec![2.0; 9]);
        // n = 0: nothing to do.
        let mut empty: Vec<f64> = Vec::new();
        syrk_tn(0, 5, &[], &mut empty, 2);
        // 1×1: C[0,0] += Σ a².
        let mut c1 = vec![1.0f64];
        syrk_tn(1, 2, &[3.0, 4.0], &mut c1, 4);
        assert_eq!(c1, vec![26.0]);
    }

    #[test]
    fn scoped_workers_sets_and_restores() {
        let before = workers();
        {
            let _g = scoped_workers(before + 3);
            assert_eq!(workers(), before + 3);
        }
        assert_eq!(workers(), before);
        // 0 clamps to 1 (a GEMM always has at least the calling thread).
        let _g = scoped_workers(0);
        assert_eq!(workers(), 1);
    }

    #[test]
    fn scoped_isa_sets_and_restores() {
        let base = active_isa();
        {
            let _g = scoped_isa(Isa::Scalar);
            assert_eq!(active_isa(), Isa::Scalar);
        }
        assert_eq!(active_isa(), base);
        assert_eq!(active_isa(), detected_isa());
        // The CI feature line always mentions the dispatch choice.
        assert!(cpu_features().contains(detected_isa().label()));
    }

    #[test]
    fn simd_f32_matches_scalar_bitwise() {
        // Whatever ISA dispatch picked, the f32 output must be BIT-identical
        // to the forced-scalar kernel on all three layouts at workers {1,4}
        // — the contract that lets every f32 caller (forward, serve, eval)
        // keep its pinned outputs across machines.  On a machine without
        // SIMD this degenerates to scalar-vs-scalar, which is fine: the
        // contract is "dispatch never changes bits", not "SIMD ran".
        check("simd f32 == scalar f32 (bitwise)", 40, |g| {
            let mut rng = g.rng.fork(0);
            let m = *g.choose(&[1usize, 3, 8, 17, 65, 70]);
            let k = *g.choose(&[1usize, 2, 5, 33, 100, 300]);
            let n = *g.choose(&[1usize, 2, 4, 11, 66]);
            let layout = *g.choose(&[Layout::NN, Layout::TN, Layout::NT]);
            let a: Vec<f32> = randn_vec(m * k, &mut rng);
            let b: Vec<f32> = randn_vec(k * n, &mut rng);
            let mut want = vec![0.0f32; m * n];
            {
                let _g = scoped_isa(Isa::Scalar);
                gemm(layout, m, k, n, &a, &b, &mut want, 1);
            }
            for workers in [1usize, 4] {
                let mut got = vec![0.0f32; m * n];
                gemm(layout, m, k, n, &a, &b, &mut got, workers);
                if got != want {
                    return Err(format!(
                        "{layout:?} {m}x{k}x{n} w={workers} isa={}: bits differ",
                        detected_isa().label()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn simd_f32_syrk_matches_scalar_bitwise() {
        let mut rng = Rng::new(21);
        for &(n, k) in &[(17usize, 33usize), (65, 300), (130, 64)] {
            let a: Vec<f32> = randn_vec(k * n, &mut rng);
            let mut want = vec![0.0f32; n * n];
            {
                let _g = scoped_isa(Isa::Scalar);
                syrk_tn(n, k, &a, &mut want, 1);
            }
            for workers in [1usize, 4] {
                let mut got = vec![0.0f32; n * n];
                syrk_tn(n, k, &a, &mut got, workers);
                assert_eq!(got, want, "syrk n={n} k={k} w={workers}");
            }
        }
    }

    fn rand_i8(len: usize, rng: &mut Rng) -> Vec<i8> {
        // Full code range ±127 — exercises the widest pair products the
        // kernel can see (127·127 per term).
        (0..len).map(|_| (rng.normal() * 60.0).clamp(-127.0, 127.0) as i8).collect()
    }

    fn rand_scales(len: usize, rng: &mut Rng) -> Vec<f32> {
        (0..len).map(|_| (rng.normal().abs() * 0.05 + 1e-4) as f32).collect()
    }

    #[test]
    fn int8_tiled_matches_ref_exactly() {
        // The tiled int8 kernel (whatever ISA dispatched, plus forced
        // scalar) must be BIT-identical to the naive i32 reference at
        // workers {1,4}: integer group dots are exact, the i32→f32 convert
        // is exact for group ≤ 128, and the epilogue adds groups in the
        // same ascending order.
        check("int8 tiled == ref (bitwise)", 40, |g| {
            let mut rng = g.rng.fork(0);
            let m = *g.choose(&[1usize, 3, 8, 17, 65, 70]);
            let k = *g.choose(&[1usize, 2, 5, 33, 100, 129, 300]);
            let n = *g.choose(&[1usize, 2, 4, 11, 66]);
            let group = *g.choose(&[1usize, 2, 64, 128]);
            let n_groups = k.div_ceil(group.min(k));
            let aq = rand_i8(m * k, &mut rng);
            let bq = rand_i8(k * n, &mut rng);
            let sa = rand_scales(m * n_groups, &mut rng);
            let sb = rand_scales(n_groups * n, &mut rng);
            let mut want = vec![0.0f32; m * n];
            gemm_i8_ref(m, k, n, &aq, &sa, &bq, &sb, group, &mut want);
            for (workers, isa) in [(1usize, None), (4, None), (1, Some(Isa::Scalar))] {
                let _g = isa.map(scoped_isa);
                let mut got = vec![0.0f32; m * n];
                gemm_i8_nn(m, k, n, &aq, &sa, &bq, &sb, group, &mut got, workers);
                if got != want {
                    return Err(format!(
                        "{m}x{k}x{n} group={group} w={workers} isa={isa:?}: bits differ"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn int8_rows_are_independent() {
        // Row r of a batched product equals the same row computed alone —
        // the property that makes batched int8 decode bit-identical to the
        // single-request reference in serve.
        let mut rng = Rng::new(33);
        let (m, k, n, group) = (7usize, 200usize, 13usize, 128usize);
        let n_groups = k.div_ceil(group);
        let aq = rand_i8(m * k, &mut rng);
        let bq = rand_i8(k * n, &mut rng);
        let sa = rand_scales(m * n_groups, &mut rng);
        let sb = rand_scales(n_groups * n, &mut rng);
        let mut full = vec![0.0f32; m * n];
        gemm_i8_nn(m, k, n, &aq, &sa, &bq, &sb, group, &mut full, 4);
        for r in 0..m {
            let mut solo = vec![0.0f32; n];
            gemm_i8_nn(
                1,
                k,
                n,
                &aq[r * k..(r + 1) * k],
                &sa[r * n_groups..(r + 1) * n_groups],
                &bq,
                &sb,
                group,
                &mut solo,
                1,
            );
            assert_eq!(&full[r * n..(r + 1) * n], &solo[..], "row {r}");
        }
    }

    #[test]
    fn int8_accumulates_and_handles_degenerate_shapes() {
        // C += semantics.
        let mut c = vec![10.0f32; 1];
        gemm_i8_nn(1, 2, 1, &[2, 3], &[0.5], &[4, 5], &[2.0], 2, &mut c, 1);
        // 10 + (0.5·2.0)·(2·4 + 3·5) = 10 + 23 = 33.
        assert_eq!(c, vec![33.0]);
        // k = 0 / m = 0 / n = 0: no-ops.
        let mut c0 = vec![1.0f32; 4];
        gemm_i8_nn(2, 0, 2, &[], &[], &[], &[], 64, &mut c0, 2);
        assert_eq!(c0, vec![1.0; 4]);
        let mut empty: Vec<f32> = Vec::new();
        gemm_i8_nn(0, 3, 2, &[], &[], &[0; 6], &[1.0; 2], 64, &mut empty, 2);
        gemm_i8_nn(2, 3, 0, &[0; 6], &[1.0; 2], &[], &[], 64, &mut empty, 2);
    }
}
