//! Symmetric per-group int8 quantization of low-rank factors (and, at GEMM
//! entry, of activations) — the storage side of the quantized kernel path.
//!
//! NSVD's factors are the serving-critical payload: every decode step in
//! `serve/step.rs` multiplies activations against `P₁/Q₁/P₂/Q₂`.  Storing
//! them as int8 with one f32 scale per `(column, k-group)` cuts factor
//! bytes ~4× on top of the rank reduction and widens the effective SIMD
//! lanes of the integer microkernel in [`super::gemm`].
//!
//! Scheme (symmetric, absmax, ASVD-Q-style — see METHODS.md):
//!
//! * A factor `W` (`k×n`, row-major, applied as `X·W`) is split along `k`
//!   into groups of [`DEFAULT_GROUP`]; each `(group g, column j)` gets
//!   `scale = absmax / 127` and `q = rne(w / scale)` clamped to ±127, so
//!   the representable range is exactly the observed range and zero maps
//!   to zero (no zero-points — the dequant epilogue stays one multiply).
//! * Activations are quantized the same way per `(row, k-group)` at GEMM
//!   entry ([`quantize_row_groups`]) — dynamic, per-row independent, so a
//!   batched decode row quantizes identically to the same row alone (the
//!   serve batching bit-parity contract survives quantization).
//! * Rounding is **round-to-nearest-even** ([`rne`]) — the IEEE default,
//!   so the pinned round-trip bound below is tight and platform-stable.
//!
//! Why group ≤ [`GROUP_MAX`] matters for the kernel contract: with
//! `|q| ≤ 127`, a per-group i8·i8 dot is at most `group · 127² ≤ 2 097 152
//! < 2²⁴`, so the i32 group accumulator is exact **and** its `i32 → f32`
//! conversion in the dequant epilogue is exact.  Integer accumulation is
//! order-independent, which is what makes the int8 GEMM bit-identical at
//! every worker count (and batched == single-row) by construction.

use super::gemm;

/// Default quantization group length along `k`.  128 keeps the per-group
/// i32 dot exactly representable in f32 (`128·127² < 2²⁴`) while holding
/// scale overhead to `4/128` of the int8 payload per column — the knob
/// that keeps total int8 bytes ≤ 0.27× the f32 factor bytes at realistic
/// layer shapes (pinned in `compress::lowrank`).
pub const DEFAULT_GROUP: usize = 128;

/// Largest group the int8 kernel accepts: `1024 · 127² < 2³¹` keeps the
/// i32 accumulator safe, though only groups ≤ 128 also keep the f32
/// epilogue conversion exact (larger groups stay correct to f32 rounding).
pub const GROUP_MAX: usize = 1024;

/// Round half-to-even (banker's rounding), the IEEE-754 default mode.
/// Hand-rolled on `trunc` so it carries no MSRV requirement.
#[inline]
pub fn rne(x: f32) -> f32 {
    let t = x.trunc();
    let d = x - t;
    if d.abs() == 0.5 {
        // Tie: pick the even neighbour of the two candidates t and t ± 1.
        if (t as i64) % 2 == 0 {
            t
        } else {
            t + d.signum()
        }
    } else {
        // No tie: plain nearest.
        (x + 0.5 * x.signum()).trunc()
    }
}

/// An int8-quantized `rows×cols` matrix (row-major codes) with one f32
/// scale per `(k-group, column)`: `w[p, j] ≈ data[p, j] · scales[p/group, j]`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantMatrix {
    /// Quantized dimension length (`k`, the contraction axis).
    pub rows: usize,
    /// Output dimension length.
    pub cols: usize,
    /// Group length along `rows`; the last group may be short.
    pub group: usize,
    /// Row-major int8 codes, `rows · cols` entries in `[-127, 127]`.
    pub data: Vec<i8>,
    /// Row-major `n_groups × cols` dequantization scales.
    pub scales: Vec<f32>,
}

impl QuantMatrix {
    /// Number of k-groups (`ceil(rows / group)`).
    pub fn n_groups(&self) -> usize {
        self.rows.div_ceil(self.group)
    }

    /// Storage footprint in bytes: 1 byte per code + 4 per scale.
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len()
    }

    /// Reconstruct the f32 matrix (`rows × cols`, row-major).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for p in 0..self.rows {
            let g = p / self.group;
            for j in 0..self.cols {
                out[p * self.cols + j] =
                    self.data[p * self.cols + j] as f32 * self.scales[g * self.cols + j];
            }
        }
        out
    }

    /// Worst-case absolute round-trip error for `(group g, column j)`:
    /// half a quantization step.  Symmetric absmax scaling never clamps
    /// (the largest-magnitude entry maps to exactly ±127), so nearest
    /// rounding is the only error source: `|w − q·s| ≤ s/2`.
    pub fn error_bound(&self, g: usize, j: usize) -> f32 {
        0.5 * self.scales[g * self.cols + j]
    }
}

/// Quantize a `rows×cols` row-major f32 matrix per `(column, k-group)`.
///
/// `group` is clamped to `[1, GROUP_MAX]`; all-zero groups get scale 1.0
/// (codes are all zero, so any nonzero scale round-trips exactly).
pub fn quantize_columns(w: &[f32], rows: usize, cols: usize, group: usize) -> QuantMatrix {
    assert_eq!(w.len(), rows * cols, "quantize_columns: shape mismatch");
    let group = group.clamp(1, GROUP_MAX);
    let n_groups = rows.div_ceil(group);
    let mut data = vec![0i8; rows * cols];
    let mut scales = vec![1.0f32; n_groups * cols];
    for g in 0..n_groups {
        let p0 = g * group;
        let p1 = (p0 + group).min(rows);
        for j in 0..cols {
            let mut amax = 0.0f32;
            for p in p0..p1 {
                amax = amax.max(w[p * cols + j].abs());
            }
            if amax > 0.0 {
                let scale = amax / 127.0;
                scales[g * cols + j] = scale;
                let inv = 1.0 / scale;
                for p in p0..p1 {
                    let q = rne(w[p * cols + j] * inv).clamp(-127.0, 127.0);
                    data[p * cols + j] = q as i8;
                }
            }
        }
    }
    QuantMatrix { rows, cols, group, data, scales }
}

/// Quantize activations `x` (`rows×k`, row-major) per `(row, k-group)` —
/// the dynamic half of the int8 GEMM.  Returns `(codes, scales)` with
/// `codes` row-major `rows×k` and `scales` row-major `rows×n_groups`, the
/// exact layouts [`gemm::gemm_i8_nn`] consumes for its A operand.
pub fn quantize_row_groups(x: &[f32], rows: usize, k: usize, group: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(x.len(), rows * k, "quantize_row_groups: shape mismatch");
    let group = group.clamp(1, GROUP_MAX);
    let n_groups = k.div_ceil(group);
    let mut codes = vec![0i8; rows * k];
    let mut scales = vec![1.0f32; rows * n_groups];
    for i in 0..rows {
        let row = &x[i * k..(i + 1) * k];
        let crow = &mut codes[i * k..(i + 1) * k];
        for g in 0..n_groups {
            let p0 = g * group;
            let p1 = (p0 + group).min(k);
            let amax = row[p0..p1].iter().fold(0.0f32, |m, v| m.max(v.abs()));
            if amax > 0.0 {
                let scale = amax / 127.0;
                scales[i * n_groups + g] = scale;
                let inv = 1.0 / scale;
                for p in p0..p1 {
                    crow[p] = rne(row[p] * inv).clamp(-127.0, 127.0) as i8;
                }
            }
        }
    }
    (codes, scales)
}

/// Quantized product `C += X · W` with f32 activations `x` (`m×k`) and a
/// pre-quantized weight factor `w` (`k×n`): quantizes `x` per row-group on
/// the fly, runs the packed i8×i8→i32 kernel, and dequantizes in the fused
/// f32 epilogue.  This is the apply path `compress::lowrank` rides for
/// every forward/decode GEMM when `--factor-dtype int8` is active.
pub fn matmul_quant(x: &[f32], m: usize, w: &QuantMatrix, c: &mut [f32], workers: usize) {
    assert_eq!(x.len(), m * w.rows, "matmul_quant: X shape mismatch");
    let (xq, xs) = quantize_row_groups(x, m, w.rows, w.group);
    gemm::gemm_i8_nn(
        m, w.rows, w.cols, &xq, &xs, &w.data, &w.scales, w.group, c, workers,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn rne_rounds_half_to_even() {
        for (x, want) in [
            (0.5f32, 0.0f32),
            (1.5, 2.0),
            (2.5, 2.0),
            (-0.5, 0.0),
            (-1.5, -2.0),
            (-2.5, -2.0),
            (0.49, 0.0),
            (0.51, 1.0),
            (-3.2, -3.0),
            (126.5, 126.0),
            (127.5, 128.0),
            (0.0, 0.0),
        ] {
            assert_eq!(rne(x), want, "rne({x})");
        }
    }

    #[test]
    fn quant_roundtrip_within_per_group_bound() {
        // |w − dequant(quant(w))| ≤ scale/2 per element — the pinned error
        // bound (absmax symmetric scaling never clamps, so rounding is the
        // only error source).
        check("quant round-trip ≤ bound", 40, |g| {
            let mut rng = g.rng.fork(0);
            let rows = g.usize_in(1, 200);
            let cols = g.usize_in(1, 12);
            let group = *g.choose(&[1usize, 3, 64, 128, 200]);
            let amp = *g.choose(&[1e-3f64, 1.0, 40.0]);
            let w: Vec<f32> = (0..rows * cols).map(|_| (rng.normal() * amp) as f32).collect();
            let q = quantize_columns(&w, rows, cols, group);
            let back = q.dequantize();
            for p in 0..rows {
                for j in 0..cols {
                    let err = (w[p * cols + j] - back[p * cols + j]).abs();
                    let bound = q.error_bound(p / q.group, j) * (1.0 + 1e-6);
                    if err > bound {
                        return Err(format!(
                            "({p},{j}) rows={rows} group={group}: err {err:e} > bound {bound:e}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quant_extremes_map_to_pm_127() {
        // The largest-magnitude entry of every group quantizes to exactly
        // ±127 (defines the scale), and an all-zero group stays zero with
        // scale 1.
        let w = vec![0.0f32, -2.0, 1.0, 0.0, 0.0, 0.0];
        let q = quantize_columns(&w, 6, 1, 3);
        assert_eq!(q.n_groups(), 2);
        // Group 0: amax 2 → scale 2/127; −2 → −127, 1 → rne(63.5) = 64.
        assert_eq!(q.scales[0], 2.0 / 127.0);
        assert_eq!(&q.data[..3], &[0, -127, 64]);
        // Group 1 is all-zero: codes stay 0 under the sentinel scale 1.
        assert_eq!(q.scales[1], 1.0);
        assert_eq!(&q.data[3..], &[0, 0, 0]);
    }

    #[test]
    fn quant_bytes_accounting() {
        let w = vec![1.0f32; 256 * 8];
        let q = quantize_columns(&w, 256, 8, 128);
        // 2048 codes + 2 groups × 8 cols scales.
        assert_eq!(q.bytes(), 256 * 8 + 4 * 2 * 8);
    }

    #[test]
    fn row_group_quant_matches_column_quant_transposed_semantics() {
        // quantize_row_groups on X must equal quantize_columns on Xᵀ,
        // group-for-group — one scheme, two layouts.
        let mut rng = Rng::new(3);
        let (rows, k, group) = (5usize, 70usize, 32usize);
        let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
        let (codes, scales) = quantize_row_groups(&x, rows, k, group);
        let mut xt = vec![0.0f32; k * rows];
        for i in 0..rows {
            for p in 0..k {
                xt[p * rows + i] = x[i * k + p];
            }
        }
        let qt = quantize_columns(&xt, k, rows, group);
        let n_groups = k.div_ceil(group);
        for i in 0..rows {
            for p in 0..k {
                assert_eq!(codes[i * k + p], qt.data[p * rows + i]);
            }
            for g in 0..n_groups {
                assert_eq!(scales[i * n_groups + g], qt.scales[g * rows + i]);
            }
        }
    }

    #[test]
    fn matmul_quant_close_to_f32_product() {
        // End-to-end: X·W through the int8 kernel lands within the additive
        // error budget of quantizing both operands.
        let mut rng = Rng::new(9);
        let (m, k, n) = (7usize, 150usize, 11usize);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let q = quantize_columns(&w, k, n, DEFAULT_GROUP);
        let mut got = vec![0.0f32; m * n];
        matmul_quant(&x, m, &q, &mut got, 2);
        let mut want = vec![0.0f32; m * n];
        gemm::gemm_nn(m, k, n, &x, &w, &mut want, 1);
        // Per-term error ≈ (sx/2)|w| + (sw/2)|x| with s ≈ amax/127; a loose
        // but safe budget is k · (amax_x · amax_w) · (2/127 + 1/127²).
        let ax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let aw = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let budget = k as f32 * ax * aw * (2.0 / 127.0 + 1.0 / (127.0 * 127.0));
        for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w_).abs() <= budget,
                "elem {i}: {g} vs {w_} (budget {budget})"
            );
        }
    }
}
