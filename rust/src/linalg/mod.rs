//! Dense f64 linear algebra, implemented from scratch.
//!
//! The paper's method is linear algebra: activation-aware whitening needs the
//! Cholesky factor or eigendecomposition of the Gram matrix `X Xᵀ`, the
//! decomposition itself needs truncated SVD, and the NID variants need a
//! rank-revealing (column-pivoted) QR for the interpolative decomposition.
//! No BLAS/LAPACK binding is available offline, so everything lives here:
//!
//! * [`gemm`]   — the unified tiled+packed GEMM kernel (MC/KC/NC cache
//!   blocking, MR×NR register microkernel, A/B panel packing), generic over
//!   f32/f64 via [`gemm::Scalar`], row-parallel over scoped threads.  Every
//!   product below — and the f32 model forward — runs through it.
//! * [`matrix`] — row-major [`Matrix`]; its `matmul`/`matmul_tn`/
//!   `matmul_nt`/`matvec` are thin wrappers over the kernel's NN/TN/NT/gemv
//!   entry points.
//! * [`qr`] — blocked compact-WY Householder QR (panel factorization +
//!   two-GEMM trailing updates), thin QR, LQ, and column-pivoted QR.
//! * [`chol`] — Cholesky factorization with PSD-safe ridge handling.
//! * [`eig`] — Jacobi symmetric eigendecomposition (cyclic or parallel
//!   tournament ordering).
//! * [`svd`] — one-sided Jacobi SVD + truncation (Eckart–Young), same
//!   ordering choices.
//! * [`jacobi`] — the shared ordering knob, the deterministic round-robin
//!   tournament schedule, and the row-parallel rotation apply.
//! * [`rsvd`] — randomized range-finder SVD (the truncation fast path) and
//!   the [`rsvd::SvdPolicy`] that arbitrates between it and exact Jacobi.
//! * [`id`] — low-rank column interpolative decomposition.
//! * [`solve`] — triangular solves, inverses, pseudo-inverse.
//! * [`quant`] — symmetric per-group int8 quantization of low-rank factors
//!   and activations, feeding the kernel's i8×i8→i32 microkernel path
//!   ([`gemm::gemm_i8_nn`]) with a dequant-fused f32 epilogue.
//!
//! Numerical conventions: decompositions run in f64 (the whitening transform
//! inverts triangular/eigen factors, where f32 demonstrably breaks the
//! σ_j = loss correspondence of Theorem 2); model math elsewhere is f32.

pub mod chol;
pub mod eig;
pub mod gemm;
pub mod id;
pub mod jacobi;
pub mod matrix;
pub mod qr;
pub mod quant;
pub mod rsvd;
pub mod solve;
pub mod svd;

pub use chol::cholesky;
pub use eig::{sym_eig, sym_eig_ordered};
pub use gemm::Scalar;
pub use id::interpolative;
pub use jacobi::JacobiOrdering;
pub use matrix::Matrix;
pub use qr::{lq, qr_thin};
pub use quant::QuantMatrix;
pub use rsvd::{svd_for_rank, SvdPolicy};
pub use svd::{svd_thin, svd_thin_ordered, Svd};
