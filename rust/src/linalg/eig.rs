//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! ASVD-II whitens with `S = P Λ^{1/2}` where `P Λ Pᵀ` is the spectral
//! decomposition of the Gram `X Xᵀ`.  Jacobi is the right tool here: the
//! Grams are small (n ≤ a few hundred), symmetric PSD, and Jacobi delivers
//! high relative accuracy on the small eigenvalues that decide whether a
//! pseudo-inverse is needed — precisely the regime the paper's §3 discusses.

use super::matrix::Matrix;

/// Result of a symmetric eigendecomposition `A = P Λ Pᵀ`.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Eigenvalues in non-increasing order.
    pub values: Vec<f64>,
    /// Column `j` of `vectors` is the eigenvector for `values[j]`.
    pub vectors: Matrix,
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
///
/// Sweeps rotate away off-diagonal mass until `off(A) < tol·‖A‖_F`.
pub fn sym_eig(a: &Matrix) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    m.symmetrize();
    let mut p = Matrix::identity(n);
    if n <= 1 {
        return SymEig { values: m.diagonal(), vectors: p };
    }
    let norm = m.fro_norm().max(f64::MIN_POSITIVE);
    let tol = 1e-14 * norm;
    const MAX_SWEEPS: usize = 60;
    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if (2.0 * off).sqrt() < tol {
            break;
        }
        for i in 0..n - 1 {
            for j in (i + 1)..n {
                let apq = m[(i, j)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(i, i)];
                let aqq = m[(j, j)];
                // Stable rotation computation (Golub & Van Loan §8.5).
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply the rotation J(i, j, θ): A ← Jᵀ A J.
                for k in 0..n {
                    let aki = m[(k, i)];
                    let akj = m[(k, j)];
                    m[(k, i)] = c * aki - s * akj;
                    m[(k, j)] = s * aki + c * akj;
                }
                for k in 0..n {
                    let aik = m[(i, k)];
                    let ajk = m[(j, k)];
                    m[(i, k)] = c * aik - s * ajk;
                    m[(j, k)] = s * aik + c * ajk;
                }
                // Accumulate eigenvectors: P ← P J.
                for k in 0..n {
                    let pki = p[(k, i)];
                    let pkj = p[(k, j)];
                    p[(k, i)] = c * pki - s * pkj;
                    p[(k, j)] = s * pki + c * pkj;
                }
            }
        }
    }
    // Sort by eigenvalue, descending.
    let mut order: Vec<usize> = (0..n).collect();
    let diag = m.diagonal();
    order.sort_by(|&x, &y| diag[y].partial_cmp(&diag[x]).unwrap());
    let values: Vec<f64> = order.iter().map(|&k| diag[k]).collect();
    let vectors = p.select_cols(&order);
    SymEig { values, vectors }
}

impl SymEig {
    /// Reconstruct `P Λ Pᵀ`.
    pub fn reconstruct(&self) -> Matrix {
        let pl = self.vectors.scale_cols(&self.values);
        pl.matmul_nt(&self.vectors)
    }

    /// The whitening factor `S = P Λ^{1/2}` with eigenvalues clamped at zero
    /// (PSD projection).  This is the ASVD-II transform.
    pub fn sqrt_factor(&self) -> Matrix {
        let sqrt_vals: Vec<f64> = self.values.iter().map(|&v| v.max(0.0).sqrt()).collect();
        self.vectors.scale_cols(&sqrt_vals)
    }

    /// Pseudo-inverse of the whitening factor: `S⁺ = Λ^{-1/2} Pᵀ`, with
    /// eigenvalues below `rel_tol·λ_max` treated as zero.
    pub fn sqrt_factor_pinv(&self, rel_tol: f64) -> Matrix {
        let lmax = self.values.first().copied().unwrap_or(0.0).max(0.0);
        let cutoff = lmax * rel_tol;
        let inv_sqrt: Vec<f64> = self
            .values
            .iter()
            .map(|&v| if v > cutoff && v > 0.0 { 1.0 / v.sqrt() } else { 0.0 })
            .collect();
        // Λ^{-1/2} Pᵀ = (P Λ^{-1/2})ᵀ
        self.vectors.scale_cols(&inv_sqrt).transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    #[test]
    fn diagonalizes_known_matrix() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
        assert!(e.reconstruct().dist(&a) < 1e-12);
    }

    #[test]
    fn eig_reconstructs_random_symmetric() {
        check("A = PΛPᵀ", 20, |g| {
            let mut rng = g.rng.fork(0);
            let n = g.usize_in(1, 25);
            let b = Matrix::randn(n, n, 1.0, &mut rng);
            let mut a = &b + &b.transpose();
            a.symmetrize();
            let e = sym_eig(&a);
            ok(e.reconstruct().dist(&a) < 1e-8 * (1.0 + a.fro_norm()), "PΛPᵀ=A")?;
            // P orthonormal.
            let gram = e.vectors.matmul_tn(&e.vectors);
            ok(gram.dist(&Matrix::identity(n)) < 1e-9, "PᵀP=I")?;
            // Sorted descending.
            for w in e.values.windows(2) {
                ok(w[0] + 1e-10 >= w[1], "sorted")?;
            }
            Ok(())
        });
    }

    #[test]
    fn trace_and_fro_norm_invariants() {
        check("trace = Σλ, ‖A‖²_F = Σλ²", 15, |g| {
            let mut rng = g.rng.fork(0);
            let n = g.usize_in(2, 20);
            let b = Matrix::randn(n, n, 1.0, &mut rng);
            let mut a = &b + &b.transpose();
            a.symmetrize();
            let e = sym_eig(&a);
            let tr: f64 = (0..n).map(|i| a[(i, i)]).sum();
            let sum_l: f64 = e.values.iter().sum();
            ok((tr - sum_l).abs() < 1e-8 * (1.0 + tr.abs()), "trace")?;
            let f2 = a.fro_norm().powi(2);
            let sum_l2: f64 = e.values.iter().map(|l| l * l).sum();
            ok((f2 - sum_l2).abs() < 1e-7 * (1.0 + f2), "fro")
        });
    }

    #[test]
    fn sqrt_factor_squares_to_gram() {
        let mut rng = Rng::new(9);
        let x = Matrix::randn(10, 30, 1.0, &mut rng);
        let gram = x.matmul_nt(&x); // full-rank PSD
        let e = sym_eig(&gram);
        let s = e.sqrt_factor();
        assert!(s.matmul_nt(&s).dist(&gram) < 1e-8 * gram.fro_norm());
    }

    #[test]
    fn pinv_handles_rank_deficiency() {
        let mut rng = Rng::new(10);
        // Rank-3 Gram in R^8.
        let x = Matrix::randn(8, 3, 1.0, &mut rng);
        let gram = x.matmul_nt(&x);
        let e = sym_eig(&gram);
        let s = e.sqrt_factor();
        let sp = e.sqrt_factor_pinv(1e-12);
        // S S⁺ projects onto the column space: S S⁺ S = S.
        let ssp_s = s.matmul(&sp).matmul(&s);
        assert!(ssp_s.dist(&s) < 1e-7 * (1.0 + s.fro_norm()));
    }

    #[test]
    fn handles_trivial_sizes() {
        let a = Matrix::from_rows(&[vec![4.0]]);
        let e = sym_eig(&a);
        assert_eq!(e.values, vec![4.0]);
        let z = Matrix::zeros(3, 3);
        let ez = sym_eig(&z);
        assert!(ez.values.iter().all(|&v| v.abs() < 1e-15));
    }
}
