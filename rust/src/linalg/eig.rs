//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! ASVD-II whitens with `S = P Λ^{1/2}` where `P Λ Pᵀ` is the spectral
//! decomposition of the Gram `X Xᵀ`.  Jacobi is the right tool here: the
//! Grams are small (n ≤ a few hundred), symmetric PSD, and Jacobi delivers
//! high relative accuracy on the small eigenvalues that decide whether a
//! pseudo-inverse is needed — precisely the regime the paper's §3 discusses.
//!
//! Orderings ([`JacobiOrdering`], shared with the SVD): `Cyclic` is the
//! sequential historical default; `Tournament` runs each sweep as `n − 1`
//! rounds of disjoint pairs with the round's rotation angles frozen at
//! round start.  For the two-sided update `A ← Jᵀ A J` a round is applied
//! as a column pass (`A·J`, row-parallel over chunks) followed by a row
//! pass (`Jᵀ·`, parallel over the disjoint row pairs), so every element is
//! transformed in a fixed order and the result is bit-identical at every
//! worker count.  Within a round, the entry `(i, j)` targeted by a rotation
//! is touched by no other pair (rows/columns of disjoint pairs), so frozen
//! angles still annihilate exactly the entries they were computed for.

use super::jacobi::{apply_col_rotations, tournament_rounds, JacobiOrdering, PAR_MIN_ELEMS};
use super::matrix::Matrix;
use crate::util::threads::parallel_map;

/// Result of a symmetric eigendecomposition `A = P Λ Pᵀ`.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Eigenvalues in non-increasing order.
    pub values: Vec<f64>,
    /// Column `j` of `vectors` is the eigenvector for `values[j]`.
    pub vectors: Matrix,
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix — the
/// sequential historical ordering.  (Note: results are deterministic, but
/// not bit-identical to the pre-SYRK seed in every edge case — the
/// rotation-skip threshold is now relative to the matrix norm, so
/// rotations on entries below `1e-18·‖A‖_F`, which the retired absolute
/// `1e-300` cutoff still performed, are skipped as numerically irrelevant.)
///
/// Sweeps rotate away off-diagonal mass until `off(A) < tol·‖A‖_F`.
pub fn sym_eig(a: &Matrix) -> SymEig {
    sym_eig_ordered(a, JacobiOrdering::Cyclic, 1)
}

/// Jacobi eigendecomposition with an explicit sweep [`JacobiOrdering`] and
/// worker count (`Cyclic` ignores `workers`; `Tournament` dispatches each
/// round over them with a worker-count-independent result).
pub fn sym_eig_ordered(a: &Matrix, ordering: JacobiOrdering, workers: usize) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig needs a square matrix");
    let mut sp = crate::obs::span("kernel.jacobi_eig");
    if sp.is_recording() {
        sp.arg_u64("n", a.rows as u64).arg_u64("workers", workers as u64);
    }
    let n = a.rows;
    let mut m = a.clone();
    m.symmetrize();
    let mut p = Matrix::identity(n);
    if n <= 1 {
        return SymEig { values: m.diagonal(), vectors: p };
    }
    // Extreme-scale lift: the sweep machinery squares entries (convergence
    // mass, and implicitly the skip test), so norms below ~1e-154 underflow
    // to a spurious "converged" and norms above ~1e154 overflow to a
    // never-converging `inf`.  Multiplying by a power of two is exact for
    // every entry in range, so lifting the matrix to norm ≈ 1 and dividing
    // the eigenvalues back changes no bits for ordinary-scaled Grams
    // (`lift = 1.0` there) while making tiny/huge-scaled ones converge in
    // the usual sweep count.
    let raw_norm = m.fro_norm();
    let lift = if raw_norm > 0.0 && !(1e-130..=1e130).contains(&raw_norm) {
        (2.0f64).powi(-(raw_norm.log2().floor() as i32))
    } else {
        1.0
    };
    if lift != 1.0 {
        for v in m.data.iter_mut() {
            *v *= lift;
        }
    }
    let norm = m.fro_norm().max(f64::MIN_POSITIVE);
    let tol = 1e-14 * norm;
    // Rotation-skip threshold, relative to the (rotation-invariant)
    // Frobenius norm like the convergence test.  The retired absolute
    // `1e-300` cutoff could stall or silently mis-converge tiny-scaled
    // Grams: entries sat below the cutoff while carrying all of the
    // matrix's structure.  1e-18 is ≪ tol/n, so skipped rotations can
    // never hold `off(A)` above the convergence threshold.
    let skip = 1e-18 * norm;
    match ordering {
        JacobiOrdering::Cyclic => cyclic_sweeps(&mut m, &mut p, tol, skip),
        JacobiOrdering::Tournament => tournament_sweeps(&mut m, &mut p, tol, skip, workers),
    }
    // Sort by eigenvalue, descending (un-lifting exactly: 1/lift is a
    // power of two too).
    let mut order: Vec<usize> = (0..n).collect();
    let diag = m.diagonal();
    order.sort_by(|&x, &y| diag[y].partial_cmp(&diag[x]).unwrap());
    let values: Vec<f64> = order.iter().map(|&k| diag[k] / lift).collect();
    let vectors = p.select_cols(&order);
    SymEig { values, vectors }
}

const MAX_SWEEPS: usize = 60;

/// Stable rotation for off-diagonal entry `apq` with diagonal `(app, aqq)`
/// (Golub & Van Loan §8.5).
#[inline]
fn eig_rotation(apq: f64, app: f64, aqq: f64) -> (f64, f64) {
    let theta = (aqq - app) / (2.0 * apq);
    let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
    let c = 1.0 / (1.0 + t * t).sqrt();
    (c, t * c)
}

/// The historical sequential row-cyclic sweep loop.
fn cyclic_sweeps(m: &mut Matrix, p: &mut Matrix, tol: f64, skip: f64) {
    let n = m.rows;
    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if (2.0 * off).sqrt() < tol {
            break;
        }
        for i in 0..n - 1 {
            for j in (i + 1)..n {
                let apq = m[(i, j)];
                if apq.abs() <= skip {
                    continue;
                }
                let (c, s) = eig_rotation(apq, m[(i, i)], m[(j, j)]);
                // Apply the rotation J(i, j, θ): A ← Jᵀ A J.
                for k in 0..n {
                    let aki = m[(k, i)];
                    let akj = m[(k, j)];
                    m[(k, i)] = c * aki - s * akj;
                    m[(k, j)] = s * aki + c * akj;
                }
                for k in 0..n {
                    let aik = m[(i, k)];
                    let ajk = m[(j, k)];
                    m[(i, k)] = c * aik - s * ajk;
                    m[(j, k)] = s * aik + c * ajk;
                }
                // Accumulate eigenvectors: P ← P J.
                for k in 0..n {
                    let pki = p[(k, i)];
                    let pkj = p[(k, j)];
                    p[(k, i)] = c * pki - s * pkj;
                    p[(k, j)] = s * pki + c * pkj;
                }
            }
        }
    }
}

/// Tournament sweeps: per round, freeze the rotation angles from the
/// round-start matrix, then apply all disjoint rotations as a column pass
/// (`A·J`), a row pass (`Jᵀ·`), and the eigenvector column pass (`P·J`).
/// Each pass transforms every element exactly once in a fixed per-element
/// order, so the result is bit-identical at every worker count.
fn tournament_sweeps(m: &mut Matrix, p: &mut Matrix, tol: f64, skip: f64, workers: usize) {
    let n = m.rows;
    let rounds = tournament_rounds(n);
    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if (2.0 * off).sqrt() < tol {
            break;
        }
        for round in &rounds {
            let mut rots: Vec<(usize, usize, f64, f64)> = Vec::with_capacity(round.len());
            for &(i, j) in round {
                let apq = m[(i, j)];
                if apq.abs() <= skip {
                    continue;
                }
                let (c, s) = eig_rotation(apq, m[(i, i)], m[(j, j)]);
                rots.push((i, j, c, s));
            }
            if rots.is_empty() {
                continue;
            }
            apply_col_rotations(&mut m.data, n, &rots, workers);
            apply_row_rotations(m, &rots, workers);
            apply_col_rotations(&mut p.data, n, &rots, workers);
        }
    }
}

/// Row pass `Jᵀ·A` for one round: each rotation rewrites its own row pair,
/// and pairs are disjoint — sequentially in place, or in parallel via
/// per-pair row buffers (identical arithmetic per element either way;
/// small rounds run inline, a spawn costs more than the rotations).
fn apply_row_rotations(m: &mut Matrix, rots: &[(usize, usize, f64, f64)], workers: usize) {
    let n = m.cols;
    if workers <= 1 || rots.len() < 2 || 2 * n * rots.len() < PAR_MIN_ELEMS {
        for &(i, j, c, s) in rots {
            for k in 0..n {
                let aik = m[(i, k)];
                let ajk = m[(j, k)];
                m[(i, k)] = c * aik - s * ajk;
                m[(j, k)] = s * aik + c * ajk;
            }
        }
        return;
    }
    let mref: &Matrix = m;
    let new_rows = parallel_map(rots, workers, |_, &(i, j, c, s)| {
        let ri = mref.row(i);
        let rj = mref.row(j);
        let mut ni = vec![0.0; n];
        let mut nj = vec![0.0; n];
        for k in 0..n {
            ni[k] = c * ri[k] - s * rj[k];
            nj[k] = s * ri[k] + c * rj[k];
        }
        (i, j, ni, nj)
    });
    for (i, j, ni, nj) in new_rows {
        m.row_mut(i).copy_from_slice(&ni);
        m.row_mut(j).copy_from_slice(&nj);
    }
}

impl SymEig {
    /// Reconstruct `P Λ Pᵀ`.
    pub fn reconstruct(&self) -> Matrix {
        let pl = self.vectors.scale_cols(&self.values);
        pl.matmul_nt(&self.vectors)
    }

    /// The whitening factor `S = P Λ^{1/2}` with eigenvalues clamped at zero
    /// (PSD projection).  This is the ASVD-II transform.
    pub fn sqrt_factor(&self) -> Matrix {
        let sqrt_vals: Vec<f64> = self.values.iter().map(|&v| v.max(0.0).sqrt()).collect();
        self.vectors.scale_cols(&sqrt_vals)
    }

    /// Pseudo-inverse of the whitening factor: `S⁺ = Λ^{-1/2} Pᵀ`, with
    /// eigenvalues below `rel_tol·λ_max` treated as zero.
    pub fn sqrt_factor_pinv(&self, rel_tol: f64) -> Matrix {
        let lmax = self.values.first().copied().unwrap_or(0.0).max(0.0);
        let cutoff = lmax * rel_tol;
        let inv_sqrt: Vec<f64> = self
            .values
            .iter()
            .map(|&v| if v > cutoff && v > 0.0 { 1.0 / v.sqrt() } else { 0.0 })
            .collect();
        // Λ^{-1/2} Pᵀ = (P Λ^{-1/2})ᵀ
        self.vectors.scale_cols(&inv_sqrt).transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    #[test]
    fn diagonalizes_known_matrix() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
        assert!(e.reconstruct().dist(&a) < 1e-12);
    }

    #[test]
    fn eig_reconstructs_random_symmetric() {
        check("A = PΛPᵀ", 20, |g| {
            let mut rng = g.rng.fork(0);
            let n = g.usize_in(1, 25);
            let b = Matrix::randn(n, n, 1.0, &mut rng);
            let mut a = &b + &b.transpose();
            a.symmetrize();
            let e = sym_eig(&a);
            ok(e.reconstruct().dist(&a) < 1e-8 * (1.0 + a.fro_norm()), "PΛPᵀ=A")?;
            // P orthonormal.
            let gram = e.vectors.matmul_tn(&e.vectors);
            ok(gram.dist(&Matrix::identity(n)) < 1e-9, "PᵀP=I")?;
            // Sorted descending.
            for w in e.values.windows(2) {
                ok(w[0] + 1e-10 >= w[1], "sorted")?;
            }
            Ok(())
        });
    }

    #[test]
    fn trace_and_fro_norm_invariants() {
        check("trace = Σλ, ‖A‖²_F = Σλ²", 15, |g| {
            let mut rng = g.rng.fork(0);
            let n = g.usize_in(2, 20);
            let b = Matrix::randn(n, n, 1.0, &mut rng);
            let mut a = &b + &b.transpose();
            a.symmetrize();
            let e = sym_eig(&a);
            let tr: f64 = (0..n).map(|i| a[(i, i)]).sum();
            let sum_l: f64 = e.values.iter().sum();
            ok((tr - sum_l).abs() < 1e-8 * (1.0 + tr.abs()), "trace")?;
            let f2 = a.fro_norm().powi(2);
            let sum_l2: f64 = e.values.iter().map(|l| l * l).sum();
            ok((f2 - sum_l2).abs() < 1e-7 * (1.0 + f2), "fro")
        });
    }

    #[test]
    fn sqrt_factor_squares_to_gram() {
        let mut rng = Rng::new(9);
        let x = Matrix::randn(10, 30, 1.0, &mut rng);
        let gram = x.matmul_nt(&x); // full-rank PSD
        let e = sym_eig(&gram);
        let s = e.sqrt_factor();
        assert!(s.matmul_nt(&s).dist(&gram) < 1e-8 * gram.fro_norm());
    }

    #[test]
    fn pinv_handles_rank_deficiency() {
        let mut rng = Rng::new(10);
        // Rank-3 Gram in R^8.
        let x = Matrix::randn(8, 3, 1.0, &mut rng);
        let gram = x.matmul_nt(&x);
        let e = sym_eig(&gram);
        let s = e.sqrt_factor();
        let sp = e.sqrt_factor_pinv(1e-12);
        // S S⁺ projects onto the column space: S S⁺ S = S.
        let ssp_s = s.matmul(&sp).matmul(&s);
        assert!(ssp_s.dist(&s) < 1e-7 * (1.0 + s.fro_norm()));
    }

    #[test]
    fn handles_trivial_sizes() {
        let a = Matrix::from_rows(&[vec![4.0]]);
        let e = sym_eig(&a);
        assert_eq!(e.values, vec![4.0]);
        let z = Matrix::zeros(3, 3);
        let ez = sym_eig(&z);
        assert!(ez.values.iter().all(|&v| v.abs() < 1e-15));
    }

    #[test]
    fn tiny_scaled_gram_converges() {
        // Regression: the retired absolute rotation-skip (|apq| < 1e-300)
        // stalled matrices whose entries all sit below the cutoff.  The
        // relative skip must diagonalize them in the usual sweep count.
        let s = 1e-301;
        let a = Matrix::from_rows(&[vec![2.0 * s, 1.0 * s], vec![1.0 * s, 2.0 * s]]);
        for ordering in [JacobiOrdering::Cyclic, JacobiOrdering::Tournament] {
            let e = sym_eig_ordered(&a, ordering, 1);
            assert!(
                (e.values[0] - 3.0 * s).abs() < 1e-10 * s,
                "{ordering:?}: λ₁ = {} (want {})",
                e.values[0],
                3.0 * s
            );
            assert!((e.values[1] - 1.0 * s).abs() < 1e-10 * s);
            assert!(e.reconstruct().dist(&a) < 1e-12 * a.fro_norm());
        }
    }

    #[test]
    fn tournament_eig_matches_cyclic_to_tolerance() {
        check("tournament eig ≡ cyclic (to tol)", 12, |g| {
            let mut rng = g.rng.fork(0);
            let n = g.usize_in(1, 30);
            let b = Matrix::randn(n, n, 1.0, &mut rng);
            let mut a = &b + &b.transpose();
            a.symmetrize();
            let cyc = sym_eig(&a);
            let tor = sym_eig_ordered(&a, JacobiOrdering::Tournament, 1);
            ok(
                tor.reconstruct().dist(&a) < 1e-8 * (1.0 + a.fro_norm()),
                "tournament reconstructs",
            )?;
            let gram = tor.vectors.matmul_tn(&tor.vectors);
            ok(gram.dist(&Matrix::identity(n)) < 1e-9, "PᵀP=I")?;
            for (vc, vt) in cyc.values.iter().zip(&tor.values) {
                ok(
                    (vc - vt).abs() < 1e-8 * (1.0 + a.fro_norm()),
                    "eigenvalues agree",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn tournament_eig_bit_identical_across_workers() {
        let mut rng = Rng::new(33);
        for n in [17usize, 30, 41] {
            let b = Matrix::randn(n, n, 1.0, &mut rng);
            let mut a = &b + &b.transpose();
            a.symmetrize();
            let base = sym_eig_ordered(&a, JacobiOrdering::Tournament, 1);
            for workers in [2usize, 4] {
                let par = sym_eig_ordered(&a, JacobiOrdering::Tournament, workers);
                assert_eq!(base.values, par.values, "n={n} w={workers} values");
                assert_eq!(base.vectors.data, par.vectors.data, "n={n} w={workers} vectors");
            }
        }
    }
}
