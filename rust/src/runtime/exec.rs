//! Execution layer: HLO text → compiled PJRT executables → batched calls.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Weights are uploaded to device buffers ONCE per evaluator and reused for
//! every batch (`execute_b`), so the request path does token upload + one
//! execution + two-scalar download only.

use super::artifacts::{ArtifactMeta, Manifest};
use crate::calib::collector::TapStats;
use crate::compress::lowrank::CompressedModel;
use crate::compress::ranks;
use crate::data::batch::TokenBatch;
use crate::model::weights::Weights;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Shared PJRT client + manifest + compiled-executable cache.
///
/// Compilation dominates sweep setup (seconds per artifact), but the
/// executable is identical across every method/ratio job — only the factor
/// BUFFERS change.  The cache makes the Nth job's setup buffer-upload-only.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    exe_cache: std::cell::RefCell<
        std::collections::HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>,
    >,
}

impl Runtime {
    /// CPU PJRT client over an artifacts directory.
    pub fn open(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        manifest.verify_files()?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, exe_cache: Default::default() })
    }

    fn compile(&self, meta: &ArtifactMeta) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exe_cache.borrow().get(&meta.key) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(meta);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", meta.key))?,
        );
        self.exe_cache
            .borrow_mut()
            .insert(meta.key.clone(), exe.clone());
        Ok(exe)
    }

    /// Upload the weight tensors in `meta.params` order.
    fn weight_buffers(&self, meta: &ArtifactMeta, weights: &Weights) -> Result<Vec<xla::PjRtBuffer>> {
        let mut bufs = Vec::with_capacity(meta.params.len());
        for name in &meta.params {
            let t = weights.get(name)?;
            bufs.push(
                self.client
                    .buffer_from_host_buffer(&t.data, &t.dims, None)
                    .with_context(|| format!("uploading {name}"))?,
            );
        }
        Ok(bufs)
    }

    /// Build a dense evaluator for a model.
    pub fn dense_evaluator(&self, model: &str, batch: usize) -> Result<DenseEvaluator> {
        let cfg = self.manifest.model(model)?;
        let meta = self.manifest.artifact(&cfg.arch, "dense", batch)?.clone();
        let weights = Weights::load(&self.manifest.weights_path(model)?)?;
        let exe = self.compile(&meta)?;
        let wbufs = self.weight_buffers(&meta, &weights)?;
        Ok(DenseEvaluator { client: self.client.clone(), meta, exe, wbufs })
    }

    /// Build a gram collector runner for a model.
    pub fn gram_runner(&self, model: &str) -> Result<GramRunner> {
        let cfg = self.manifest.model(model)?;
        let batch = self.manifest.eval_batch;
        let meta = self.manifest.artifact(&cfg.arch, "gram", batch)?.clone();
        let weights = Weights::load(&self.manifest.weights_path(model)?)?;
        let exe = self.compile(&meta)?;
        let wbufs = self.weight_buffers(&meta, &weights)?;
        Ok(GramRunner { client: self.client.clone(), meta, exe, wbufs })
    }

    /// Build a low-rank evaluator from a compressed model.  Factors are
    /// zero-padded to the executable's fixed ranks and uploaded once.
    pub fn lowrank_evaluator(
        &self,
        model: &str,
        batch: usize,
        compressed: &CompressedModel,
    ) -> Result<LowRankEvaluator> {
        let cfg = self.manifest.model(model)?;
        let meta = self.manifest.artifact(&cfg.arch, "lowrank", batch)?.clone();
        let weights = Weights::load(&self.manifest.weights_path(model)?)?;
        let exe = self.compile(&meta)?;
        let mut bufs = self.weight_buffers(&meta, &weights)?;
        for wname in &meta.factor_order {
            let layer = compressed
                .get(wname)
                .ok_or_else(|| anyhow::anyhow!("compressed model missing layer {wname}"))?;
            let (k1m, k2m) = meta
                .factor_ranks
                .get(wname)
                .copied()
                .unwrap_or_else(|| ranks::max_ranks(layer.n_out, layer.n_in));
            let padded = layer.pad_to(k1m, k2m);
            let quads: [(&[f32], [usize; 2]); 4] = [
                (&padded.p1, [padded.n_in, k1m]),
                (&padded.q1, [k1m, padded.n_out]),
                (&padded.p2, [padded.n_in, k2m]),
                (&padded.q2, [k2m, padded.n_out]),
            ];
            for (data, dims) in quads {
                bufs.push(self.client.buffer_from_host_buffer(data, &dims, None)?);
            }
        }
        Ok(LowRankEvaluator { client: self.client.clone(), meta, exe, bufs })
    }
}

/// Upload one token batch as an i32 device buffer.
fn token_buffer(
    client: &xla::PjRtClient,
    meta: &ArtifactMeta,
    tb: &TokenBatch,
) -> Result<xla::PjRtBuffer> {
    if tb.batch != meta.batch || tb.seq != meta.seq {
        bail!(
            "batch shape [{}, {}] does not match artifact {} ([{}, {}])",
            tb.batch, tb.seq, meta.key, meta.batch, meta.seq
        );
    }
    Ok(client.buffer_from_host_buffer(&tb.tokens, &[tb.batch, tb.seq], None)?)
}

/// Result of a loss-style executable: (sum_nll, token_count).
#[derive(Clone, Copy, Debug, Default)]
pub struct LossOutput {
    pub sum_nll: f64,
    pub count: f64,
}

/// Fold per-batch losses in batch order (the same merge the parallel native
/// path performs, so the two backends stay interchangeable in
/// `eval::perplexity`).  One implementation for both evaluators.
fn fold_losses(
    tbs: &[TokenBatch],
    mut loss: impl FnMut(&TokenBatch) -> Result<LossOutput>,
) -> Result<LossOutput> {
    let mut folded = LossOutput::default();
    for tb in tbs {
        debug_assert_eq!(tb.valid_rows, tb.batch);
        let out = loss(tb)?;
        folded.sum_nll += out.sum_nll;
        folded.count += out.count;
    }
    Ok(folded)
}

fn run_loss(
    client: &xla::PjRtClient,
    exe: &xla::PjRtLoadedExecutable,
    meta: &ArtifactMeta,
    wbufs: &[xla::PjRtBuffer],
    tb: &TokenBatch,
) -> Result<(LossOutput, Vec<xla::Literal>)> {
    let tok = token_buffer(client, meta, tb)?;
    let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + wbufs.len());
    args.push(&tok);
    args.extend(wbufs.iter());
    let result = exe.execute_b(&args)?;
    let lit = result[0][0].to_literal_sync()?;
    let mut parts = lit.to_tuple()?;
    if parts.len() < 2 {
        bail!("{}: expected ≥2 outputs, got {}", meta.key, parts.len());
    }
    let rest = parts.split_off(2);
    let sum_nll = parts[0].to_vec::<f32>()?[0] as f64;
    let count = parts[1].to_vec::<f32>()?[0] as f64;
    Ok((LossOutput { sum_nll, count }, rest))
}

/// Correct the (sum_nll, count) of a padded batch: the executable reduces
/// over ALL rows, so we subtract nothing but rescale the count — callers with
/// padding instead evaluate padding-row NLL too.  To keep exactness we only
/// allow padding on dense/lowrank eval by computing per-batch on full rows.
/// (Eval batches from `Batcher` only pad the FINAL batch; the evaluator
/// handles that by re-running the final partial batch with valid rows only
/// through a smaller logical count.)  See `eval::perplexity`.
pub fn scale_for_padding(out: LossOutput, valid_rows: usize, batch: usize) -> LossOutput {
    if valid_rows == batch {
        return out;
    }
    // Padding rows are all-zero token rows; their NLL is well-defined and
    // NOT zero, so we cannot subtract exactly.  The evaluator therefore
    // drops padded batches from the PJRT path and scores them natively.
    // This function is only used for throughput accounting.
    LossOutput { sum_nll: out.sum_nll, count: out.count * valid_rows as f64 / batch as f64 }
}

/// Dense-model evaluator (device-resident weights).
pub struct DenseEvaluator {
    client: xla::PjRtClient,
    pub meta: ArtifactMeta,
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    wbufs: Vec<xla::PjRtBuffer>,
}

impl DenseEvaluator {
    /// (sum_nll, count) over a FULL batch.
    pub fn loss(&self, tb: &TokenBatch) -> Result<LossOutput> {
        let (out, _) = run_loss(&self.client, &self.exe, &self.meta, &self.wbufs, tb)?;
        Ok(out)
    }

    /// Score a run of batches and fold their loss outputs.  PJRT pins the
    /// client + executable to the owning thread (neither is `Send`), so
    /// the batches execute back-to-back here.
    pub fn loss_batches(&self, tbs: &[TokenBatch]) -> Result<LossOutput> {
        fold_losses(tbs, |tb| self.loss(tb))
    }
}

/// Gram-collection runner: accumulates TapStats over calibration batches.
pub struct GramRunner {
    client: xla::PjRtClient,
    pub meta: ArtifactMeta,
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    wbufs: Vec<xla::PjRtBuffer>,
}

impl GramRunner {
    /// Run one batch; merge tap reductions into `stats`.
    pub fn accumulate(&self, tb: &TokenBatch, stats: &mut TapStats) -> Result<LossOutput> {
        let (out, rest) = run_loss(&self.client, &self.exe, &self.meta, &self.wbufs, tb)?;
        let taps = &self.meta.taps;
        if rest.len() != 2 * taps.len() {
            bail!(
                "{}: expected {} tap outputs, got {}",
                self.meta.key,
                2 * taps.len(),
                rest.len()
            );
        }
        let rows = tb.batch * tb.seq;
        for (i, tap) in taps.iter().enumerate() {
            let gram: Vec<f32> = rest[i].to_vec::<f32>()?;
            let abs: Vec<f32> = rest[taps.len() + i].to_vec::<f32>()?;
            let dim = abs.len();
            if gram.len() != dim * dim {
                bail!("tap {tap}: gram size {} != {dim}²", gram.len());
            }
            stats.accumulate_reduced(tap, &gram, &abs, rows, dim);
        }
        Ok(out)
    }
}

/// Low-rank (compressed) model evaluator.
pub struct LowRankEvaluator {
    client: xla::PjRtClient,
    pub meta: ArtifactMeta,
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    bufs: Vec<xla::PjRtBuffer>,
}

impl LowRankEvaluator {
    pub fn loss(&self, tb: &TokenBatch) -> Result<LossOutput> {
        let (out, _) = run_loss(&self.client, &self.exe, &self.meta, &self.bufs, tb)?;
        Ok(out)
    }

    /// Batched scoring; see [`DenseEvaluator::loss_batches`].
    pub fn loss_batches(&self, tbs: &[TokenBatch]) -> Result<LossOutput> {
        fold_losses(tbs, |tb| self.loss(tb))
    }
}

/// Serving evaluator: per-row (nll, count) outputs over the factored model —
/// the dynamic batcher's engine (padding rows are simply discarded).
pub struct ServeEvaluator {
    client: xla::PjRtClient,
    pub meta: ArtifactMeta,
    exe: std::rc::Rc<xla::PjRtLoadedExecutable>,
    bufs: Vec<xla::PjRtBuffer>,
}

impl Runtime {
    /// Build the serving evaluator from a compressed model.
    pub fn serve_evaluator(
        &self,
        model: &str,
        compressed: &CompressedModel,
    ) -> Result<ServeEvaluator> {
        let cfg = self.manifest.model(model)?;
        let batch = self.manifest.eval_batch;
        let meta = self.manifest.artifact(&cfg.arch, "serve", batch)?.clone();
        let exe = self.compile(&meta)?;
        let weights = Weights::load(&self.manifest.weights_path(model)?)?;
        let mut bufs = self.weight_buffers(&meta, &weights)?;
        for wname in &meta.factor_order {
            let layer = compressed
                .get(wname)
                .ok_or_else(|| anyhow::anyhow!("compressed model missing layer {wname}"))?;
            let (k1m, k2m) = meta
                .factor_ranks
                .get(wname)
                .copied()
                .unwrap_or_else(|| ranks::max_ranks(layer.n_out, layer.n_in));
            let padded = layer.pad_to(k1m, k2m);
            let quads: [(&[f32], [usize; 2]); 4] = [
                (&padded.p1, [padded.n_in, k1m]),
                (&padded.q1, [k1m, padded.n_out]),
                (&padded.p2, [padded.n_in, k2m]),
                (&padded.q2, [k2m, padded.n_out]),
            ];
            for (data, dims) in quads {
                bufs.push(self.client.buffer_from_host_buffer(data, &dims, None)?);
            }
        }
        Ok(ServeEvaluator { client: self.client.clone(), meta, exe, bufs })
    }
}

impl ServeEvaluator {
    pub fn batch(&self) -> usize {
        self.meta.batch
    }

    pub fn seq(&self) -> usize {
        self.meta.seq
    }

    /// Score a batch; returns per-row (nll, token_count).
    pub fn score(&self, tb: &TokenBatch) -> Result<Vec<(f64, f64)>> {
        let tok = token_buffer(&self.client, &self.meta, tb)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.bufs.len());
        args.push(&tok);
        args.extend(self.bufs.iter());
        let result = self.exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != 2 {
            bail!("{}: expected 2 row outputs, got {}", self.meta.key, parts.len());
        }
        let nll: Vec<f32> = parts[0].to_vec::<f32>()?;
        let cnt: Vec<f32> = parts[1].to_vec::<f32>()?;
        Ok(nll
            .iter()
            .zip(&cnt)
            .map(|(&a, &b)| (a as f64, b as f64))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_for_padding_full_batch_is_identity() {
        let out = LossOutput { sum_nll: 10.0, count: 100.0 };
        let s = scale_for_padding(out, 8, 8);
        assert_eq!(s.count, 100.0);
        let s2 = scale_for_padding(out, 4, 8);
        assert_eq!(s2.count, 50.0);
    }
}
