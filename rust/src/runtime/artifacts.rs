//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime (file names, parameter order, factor shapes, tap order).

use crate::model::config::ModelConfig;
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered executable's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub key: String,
    pub file: String,
    pub kind: String, // "dense" | "gram" | "lowrank"
    pub arch: String,
    pub batch: usize,
    pub seq: usize,
    /// Weight tensor names in parameter order (after the tokens arg).
    pub params: Vec<String>,
    /// Gram artifacts: tap names in output order.
    pub taps: Vec<String>,
    /// Lowrank artifacts: compressible weight names in factor-arg order.
    pub factor_order: Vec<String>,
    /// Lowrank artifacts: padded (k1max, k2max) per weight.
    pub factor_ranks: BTreeMap<String, (usize, usize)>,
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seq: usize,
    pub eval_batch: usize,
    pub models: BTreeMap<String, ModelConfig>,
    pub weight_files: BTreeMap<String, String>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(artifacts_dir, &doc)
    }

    pub fn from_json(dir: &Path, doc: &Json) -> Result<Manifest> {
        let seq = doc.get("seq").and_then(Json::as_usize).unwrap_or(128);
        let eval_batch = doc.get("eval_batch").and_then(Json::as_usize).unwrap_or(8);
        let mut models = BTreeMap::new();
        let mut weight_files = BTreeMap::new();
        if let Some(Json::Obj(m)) = doc.get("models") {
            for (name, meta) in m {
                models.insert(name.clone(), ModelConfig::from_manifest(name, meta)?);
                if let Some(w) = meta.get("weights").and_then(Json::as_str) {
                    weight_files.insert(name.clone(), w.to_string());
                }
            }
        }
        let mut artifacts = BTreeMap::new();
        if let Some(Json::Obj(arts)) = doc.get("artifacts") {
            for (key, meta) in arts {
                let str_list = |k: &str| -> Vec<String> {
                    meta.get(k)
                        .and_then(Json::as_arr)
                        .map(|a| {
                            a.iter()
                                .filter_map(|v| v.as_str().map(str::to_string))
                                .collect()
                        })
                        .unwrap_or_default()
                };
                let mut factor_ranks = BTreeMap::new();
                if let Some(Json::Obj(fr)) = meta.get("factor_ranks") {
                    for (w, v) in fr {
                        if let Some(arr) = v.as_arr() {
                            if arr.len() == 2 {
                                factor_ranks.insert(
                                    w.clone(),
                                    (
                                        arr[0].as_usize().unwrap_or(1),
                                        arr[1].as_usize().unwrap_or(1),
                                    ),
                                );
                            }
                        }
                    }
                }
                artifacts.insert(
                    key.clone(),
                    ArtifactMeta {
                        key: key.clone(),
                        file: meta
                            .get("file")
                            .and_then(Json::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        kind: meta
                            .get("kind")
                            .and_then(Json::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        arch: meta
                            .get("arch")
                            .and_then(Json::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        batch: meta.get("batch").and_then(Json::as_usize).unwrap_or(1),
                        seq: meta.get("seq").and_then(Json::as_usize).unwrap_or(seq),
                        params: str_list("params"),
                        taps: str_list("taps"),
                        factor_order: str_list("factor_order"),
                        factor_ranks,
                    },
                );
            }
        }
        Ok(Manifest { dir: dir.to_path_buf(), seq, eval_batch, models, weight_files, artifacts })
    }

    pub fn model(&self, name: &str) -> Result<&ModelConfig> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not in manifest"))
    }

    pub fn weights_path(&self, model: &str) -> Result<PathBuf> {
        let rel = self
            .weight_files
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("no weights for '{model}'"))?;
        Ok(self.dir.join(rel))
    }

    /// Artifact for `(arch, kind, batch)`.
    pub fn artifact(&self, arch: &str, kind: &str, batch: usize) -> Result<&ArtifactMeta> {
        let key = format!("{arch}_{kind}_b{batch}");
        self.artifacts
            .get(&key)
            .ok_or_else(|| anyhow::anyhow!("artifact '{key}' not in manifest"))
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Sanity-check that every referenced file exists on disk.
    pub fn verify_files(&self) -> Result<()> {
        for meta in self.artifacts.values() {
            let p = self.hlo_path(meta);
            if !p.exists() {
                bail!("missing artifact file {}", p.display());
            }
        }
        for model in self.weight_files.keys() {
            let p = self.weights_path(model)?;
            if !p.exists() {
                bail!("missing weights {}", p.display());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Json {
        json::parse(
            r#"{
            "seq": 128, "eval_batch": 8,
            "models": {
                "llama-t": {
                    "family": "llama", "arch": "llama-t", "d_model": 128,
                    "n_layers": 4, "n_heads": 4, "d_ff": 256, "max_seq": 128,
                    "window": 0, "vocab": 256, "weights": "models/llama-t.nsvdw",
                    "linear_shapes": {"blocks.0.attn.wq": [128, 128]}
                }
            },
            "artifacts": {
                "llama-t_dense_b8": {
                    "file": "llama-t_dense_b8.hlo.txt", "kind": "dense",
                    "arch": "llama-t", "batch": 8, "seq": 128,
                    "params": ["blocks.0.attn.wq", "tok_emb"],
                    "outputs": ["sum_nll", "count"]
                },
                "llama-t_lowrank_b8": {
                    "file": "llama-t_lowrank_b8.hlo.txt", "kind": "lowrank",
                    "arch": "llama-t", "batch": 8, "seq": 128,
                    "params": ["tok_emb"],
                    "factor_order": ["blocks.0.attn.wq"],
                    "factor_ranks": {"blocks.0.attn.wq": [57, 15]}
                }
            }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_models_and_artifacts() {
        let m = Manifest::from_json(Path::new("/tmp/x"), &sample_manifest()).unwrap();
        assert_eq!(m.seq, 128);
        let cfg = m.model("llama-t").unwrap();
        assert_eq!(cfg.d_model, 128);
        let a = m.artifact("llama-t", "dense", 8).unwrap();
        assert_eq!(a.params.len(), 2);
        let lr = m.artifact("llama-t", "lowrank", 8).unwrap();
        assert_eq!(lr.factor_ranks["blocks.0.attn.wq"], (57, 15));
        assert!(m.artifact("llama-t", "dense", 99).is_err());
    }

    #[test]
    fn weights_path_joins_dir() {
        let m = Manifest::from_json(Path::new("/art"), &sample_manifest()).unwrap();
        assert_eq!(
            m.weights_path("llama-t").unwrap(),
            PathBuf::from("/art/models/llama-t.nsvdw")
        );
    }
}
