//! PJRT runtime: artifact registry + executors over the AOT HLO text.
//!
//! * [`artifacts`] — parses `artifacts/manifest.json` (models, artifact
//!   files, parameter order contracts).
//! * [`exec`]      — the execution layer: loads HLO text, compiles once per
//!   artifact, keeps weights device-resident, and marshals batches.
//!
//! Python never runs here: the HLO text was produced at `make artifacts`.

pub mod artifacts;
pub mod exec;

pub use artifacts::Manifest;
pub use exec::{DenseEvaluator, GramRunner, LowRankEvaluator, Runtime};
