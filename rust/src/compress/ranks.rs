//! Rank budgeting: compression ratio → per-layer (k₁, k₂).
//!
//! A dense weight of shape m×n stores `mn` parameters; a rank-k factor pair
//! stores `(m+n)k`.  The paper's "compression ratio ρ" removes ρ of the
//! parameters, so `k = ⌊(1-ρ)·mn/(m+n)⌋`, applied layer-wise (every
//! compressible weight is compressed at the same ratio, as in SVD-LLM's
//! protocol).  NSVD splits the same budget as `k₁ = round(α·k)`,
//! `k₂ = k - k₁` (paper §4.2 sweeps α from 0.80 to 0.99).
//!
//! This module owns the *per-layer* arithmetic; the cross-layer
//! spectrum-driven allocator that replaces the uniform protocol with one
//! global budget lives in [`crate::compress::allocate`].
//!
//! The padded maxima (`k1_max`, `k2_max`) must match
//! `python/compile/model.py::max_ranks` — they define the fixed shapes of the
//! low-rank PJRT executable.

/// Rank plan for one weight matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RankPlan {
    pub k: usize,
    pub k1: usize,
    pub k2: usize,
}

/// Total rank budget at compression ratio `ratio` for an m×n weight:
/// `k = ⌊(1-ρ)·mn/(m+n)⌋`, at least 1.
///
/// ```
/// use nsvd::compress::ranks::k_budget;
///
/// // 128×128 at ρ = 30%: a rank-44 pair stores (128+128)·44 = 11264 of the
/// // original 16384 parameters — 31.25% removed (the floor rounds down).
/// assert_eq!(k_budget(128, 128, 0.30), 44);
/// // Extreme ratios still leave rank 1.
/// assert_eq!(k_budget(16, 16, 0.999), 1);
/// ```
pub fn k_budget(m: usize, n: usize, ratio: f64) -> usize {
    let k = ((1.0 - ratio) * (m * n) as f64 / (m + n) as f64).floor() as usize;
    k.max(1)
}

/// Split a total rank into the nested pair: `k₁ = round(α·k)` clamped to
/// `[1, k]`, `k₂ = k − k₁`.  `alpha = 1.0` reproduces the non-nested
/// baselines (k₂ = 0).
pub fn split_k(k: usize, alpha: f64) -> RankPlan {
    let k1 = ((alpha * k as f64).round() as usize).clamp(1, k);
    RankPlan { k, k1, k2: k - k1 }
}

/// The full per-layer plan: budget at `ratio`, split at `alpha`.
///
/// ```
/// use nsvd::compress::ranks::plan;
///
/// let p = plan(128, 128, 0.30, 0.95);
/// assert_eq!((p.k, p.k1, p.k2), (44, 42, 2)); // round(0.95·44) = 42
/// assert_eq!(p.k1 + p.k2, p.k);               // the split is exact
/// // α = 1 is the non-nested baseline.
/// assert_eq!(plan(128, 128, 0.30, 1.0).k2, 0);
/// ```
pub fn plan(m: usize, n: usize, ratio: f64, alpha: f64) -> RankPlan {
    split_k(k_budget(m, n, ratio), alpha)
}

/// Padded executable ranks; MUST match python `model.max_ranks(n_in, n_out)`
/// (verified against it by `max_ranks_match_python_contract` here and
/// `test_max_ranks_match_rust_contract` on the python side).  The python
/// side passes `(n_in, n_out)` where this side usually passes
/// `(m, n) = (n_out, n_in)`; the formula is symmetric in the swap, so the
/// two agree.  `k1_max` is the largest stage-1 rank any experiment uses
/// (the ρ = 10% budget); `k2_max` caps stage 2 at the α = 0.75 share.
pub fn max_ranks(m: usize, n: usize) -> (usize, usize) {
    let kmax = ((1.0 - 0.10) * (m * n) as f64 / (m + n) as f64) as usize;
    let k1max = kmax.max(1);
    let k2max = ((0.25 * kmax as f64).ceil() as usize).max(1);
    (k1max, k2max)
}

/// Largest total rank `k` whose `(k₁, k₂)` split at `alpha` fits the padded
/// executable maxima [`max_ranks`] — the per-layer cap the spectrum
/// allocator must respect on the PJRT path, where factors are marshaled
/// into fixed-shape buffers ([`crate::compress::lowrank::CompressedLayer::pad_to`]).
///
/// Note the cap can exceed `k1_max`: a nested split at α < 1 parks part of
/// the total rank in the stage-2 buffer (e.g. α = 0.80 fits
/// `k ≈ 1.25·k_max` as `k₁ = k_max`, `k₂ = 0.25·k_max`).  Rank 1 always
/// fits.
pub fn max_k_for_alpha(m: usize, n: usize, alpha: f64) -> usize {
    let (k1m, k2m) = max_ranks(m, n);
    let mut k = (k1m + k2m).min(m.min(n));
    while k > 1 {
        let p = split_k(k, alpha);
        if p.k1 <= k1m && p.k2 <= k2m {
            return k;
        }
        k -= 1;
    }
    1
}

/// Parameters stored by a nested factorization of an m×n weight.
pub fn factored_params(m: usize, n: usize, plan: &RankPlan) -> usize {
    (m + n) * (plan.k1 + plan.k2)
}

/// Achieved compression ratio of a plan (fraction of parameters removed).
pub fn achieved_ratio(m: usize, n: usize, plan: &RankPlan) -> f64 {
    1.0 - factored_params(m, n, plan) as f64 / (m * n) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn budget_matches_hand_computation() {
        // 128×128 at 30%: 0.7·16384/256 = 44.8 → 44.
        assert_eq!(k_budget(128, 128, 0.30), 44);
        // 10%: 57.6 → 57 (the padded k1max).
        assert_eq!(k_budget(128, 128, 0.10), 57);
    }

    #[test]
    fn max_ranks_match_python_contract() {
        assert_eq!(max_ranks(128, 128), (57, 15));
        assert_eq!(max_ranks(128, 256), (76, 19));
        assert_eq!(max_ranks(256, 128), (76, 19)); // symmetric
    }

    #[test]
    fn plan_splits_budget_exactly() {
        check("k1 + k2 = k for all α", 50, |g| {
            let m = g.usize_in(8, 512);
            let n = g.usize_in(8, 512);
            let ratio = g.f64_in(0.05, 0.6);
            let alpha = *g.choose(&[0.80, 0.85, 0.90, 0.95, 0.99, 1.0]);
            let p = plan(m, n, ratio, alpha);
            if p.k1 + p.k2 != p.k {
                return Err(format!("{p:?}"));
            }
            if p.k1 == 0 {
                return Err("k1 must be ≥ 1".into());
            }
            Ok(())
        });
    }

    #[test]
    fn alpha_one_is_non_nested() {
        let p = plan(128, 128, 0.30, 1.0);
        assert_eq!(p.k2, 0);
        assert_eq!(p.k1, p.k);
    }

    #[test]
    fn achieved_ratio_is_close_to_requested() {
        check("achieved ratio ≈ requested", 40, |g| {
            let m = g.usize_in(64, 512);
            let n = g.usize_in(64, 512);
            let ratio = g.f64_in(0.1, 0.5);
            let p = plan(m, n, ratio, 0.95);
            let achieved = achieved_ratio(m, n, &p);
            // Floor quantization costs at most (m+n)/(m·n) in ratio.
            let quantum = (m + n) as f64 / (m * n) as f64;
            if (achieved - ratio).abs() > quantum + 1e-9 {
                return Err(format!("requested {ratio}, achieved {achieved}"));
            }
            Ok(())
        });
    }

    #[test]
    fn plans_fit_within_padded_maxima() {
        // Every experiment configuration must fit the padded executable.
        for &(m, n) in &[(128usize, 128usize), (128, 256), (256, 128), (384, 128), (128, 384)] {
            let (k1m, k2m) = max_ranks(m, n);
            for &ratio in &[0.10, 0.20, 0.30, 0.40, 0.50] {
                for &alpha in &[0.80, 0.85, 0.90, 0.95, 0.99, 1.0] {
                    let p = plan(m, n, ratio, alpha);
                    assert!(p.k1 <= k1m, "k1 {} > k1max {k1m} (m={m},n={n},ρ={ratio},α={alpha})", p.k1);
                    assert!(p.k2 <= k2m, "k2 {} > k2max {k2m} (m={m},n={n},ρ={ratio},α={alpha})", p.k2);
                }
            }
        }
    }

    #[test]
    fn max_k_for_alpha_is_tight_and_safe() {
        check("max_k fits, max_k + 1 does not (or is dim-capped)", 40, |g| {
            let m = g.usize_in(16, 384);
            let n = g.usize_in(16, 384);
            let alpha = *g.choose(&[0.80, 0.85, 0.90, 0.95, 0.99, 1.0]);
            let (k1m, k2m) = max_ranks(m, n);
            let k = max_k_for_alpha(m, n, alpha);
            let p = split_k(k, alpha);
            if p.k1 > k1m || p.k2 > k2m {
                return Err(format!("cap {k} does not fit: {p:?} vs ({k1m},{k2m})"));
            }
            if k < (k1m + k2m).min(m.min(n)) {
                // Tight: one more rank must overflow a padded buffer.
                let q = split_k(k + 1, alpha);
                if q.k1 <= k1m && q.k2 <= k2m {
                    return Err(format!("cap {k} not tight: {q:?} also fits"));
                }
            }
            // Every standard-protocol plan respects the cap.
            for &ratio in &[0.10, 0.30, 0.50] {
                if plan(m, n, ratio, alpha).k > k {
                    return Err(format!("uniform plan exceeds cap {k} at ρ={ratio}"));
                }
            }
            Ok(())
        });
    }
}
