//! Rank budgeting: compression ratio → per-layer (k₁, k₂).
//!
//! A dense weight of shape m×n stores `mn` parameters; a rank-k factor pair
//! stores `(m+n)k`.  The paper's "compression ratio ρ" removes ρ of the
//! parameters, so `k = ⌊(1-ρ)·mn/(m+n)⌋`, applied layer-wise (every
//! compressible weight is compressed at the same ratio, as in SVD-LLM's
//! protocol).  NSVD splits the same budget as `k₁ = round(α·k)`,
//! `k₂ = k - k₁` (paper §4.2 sweeps α from 0.80 to 0.99).
//!
//! The padded maxima (`k1_max`, `k2_max`) must match
//! `python/compile/model.py::max_ranks` — they define the fixed shapes of the
//! low-rank PJRT executable.

/// Rank plan for one weight matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RankPlan {
    pub k: usize,
    pub k1: usize,
    pub k2: usize,
}

/// Total rank budget at compression ratio `ratio` for an m×n weight.
pub fn k_budget(m: usize, n: usize, ratio: f64) -> usize {
    let k = ((1.0 - ratio) * (m * n) as f64 / (m + n) as f64).floor() as usize;
    k.max(1)
}

/// Split the budget: `k₁ = round(α·k)` (≥1), `k₂ = k - k₁`.
/// `alpha = 1.0` reproduces the non-nested baselines (k₂ = 0).
pub fn plan(m: usize, n: usize, ratio: f64, alpha: f64) -> RankPlan {
    let k = k_budget(m, n, ratio);
    let k1 = ((alpha * k as f64).round() as usize).clamp(1, k);
    RankPlan { k, k1, k2: k - k1 }
}

/// Padded executable ranks; MUST match python `model.max_ranks(n_in, n_out)`.
/// Note the python side passes (n_in, n_out) and the formula is symmetric.
pub fn max_ranks(m: usize, n: usize) -> (usize, usize) {
    let kmax = ((1.0 - 0.10) * (m * n) as f64 / (m + n) as f64) as usize;
    let k1max = kmax.max(1);
    let k2max = ((0.25 * kmax as f64).ceil() as usize).max(1);
    (k1max, k2max)
}

/// Parameters stored by a nested factorization of an m×n weight.
pub fn factored_params(m: usize, n: usize, plan: &RankPlan) -> usize {
    (m + n) * (plan.k1 + plan.k2)
}

/// Achieved compression ratio of a plan (fraction of parameters removed).
pub fn achieved_ratio(m: usize, n: usize, plan: &RankPlan) -> f64 {
    1.0 - factored_params(m, n, plan) as f64 / (m * n) as f64
}

/// Global (adaptive) rank allocation — the extension the ASVD line of work
/// motivates: instead of compressing every layer at the same ratio, spend a
/// single global parameter budget where the whitened spectra say the mass
/// is.
///
/// Greedy water-filling: each layer ℓ offers marginal gains
/// `σ²_{ℓ,k+1} / cost_ℓ` where `cost_ℓ = (m_ℓ + n_ℓ)` parameters per rank
/// unit (Theorem 2: keeping singular value σ removes exactly σ² of squared
/// activation-weighted loss).  Ranks are granted to the best offer until the
/// budget is spent.  Every layer keeps at least rank 1.
pub fn allocate_global(
    layers: &[(usize, usize, Vec<f64>)], // (m, n, whitened singular values desc)
    ratio: f64,
    alpha: f64,
) -> Vec<RankPlan> {
    let total_dense: usize = layers.iter().map(|(m, n, _)| m * n).sum();
    let budget = ((1.0 - ratio) * total_dense as f64) as usize;
    let mut ks: Vec<usize> = vec![1; layers.len()];
    let mut spent: usize = layers.iter().map(|(m, n, _)| m + n).sum();
    // Greedy: repeatedly grant one rank to the layer with the best
    // marginal (loss removed per parameter spent).
    loop {
        let mut best: Option<(usize, f64)> = None;
        for (i, (m, n, s)) in layers.iter().enumerate() {
            let k = ks[i];
            if k >= s.len() || k >= *m.min(n) {
                continue;
            }
            let cost = m + n;
            if spent + cost > budget {
                continue;
            }
            let gain = s[k] * s[k] / cost as f64;
            if best.map(|(_, g)| gain > g).unwrap_or(true) {
                best = Some((i, gain));
            }
        }
        match best {
            Some((i, _)) => {
                ks[i] += 1;
                spent += layers[i].0 + layers[i].1;
            }
            None => break,
        }
    }
    ks.iter()
        .map(|&k| {
            let k1 = ((alpha * k as f64).round() as usize).clamp(1, k);
            RankPlan { k, k1, k2: k - k1 }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn budget_matches_hand_computation() {
        // 128×128 at 30%: 0.7·16384/256 = 44.8 → 44.
        assert_eq!(k_budget(128, 128, 0.30), 44);
        // 10%: 57.6 → 57 (the padded k1max).
        assert_eq!(k_budget(128, 128, 0.10), 57);
    }

    #[test]
    fn max_ranks_match_python_contract() {
        assert_eq!(max_ranks(128, 128), (57, 15));
        assert_eq!(max_ranks(128, 256), (76, 19));
        assert_eq!(max_ranks(256, 128), (76, 19)); // symmetric
    }

    #[test]
    fn plan_splits_budget_exactly() {
        check("k1 + k2 = k for all α", 50, |g| {
            let m = g.usize_in(8, 512);
            let n = g.usize_in(8, 512);
            let ratio = g.f64_in(0.05, 0.6);
            let alpha = *g.choose(&[0.80, 0.85, 0.90, 0.95, 0.99, 1.0]);
            let p = plan(m, n, ratio, alpha);
            if p.k1 + p.k2 != p.k {
                return Err(format!("{p:?}"));
            }
            if p.k1 == 0 {
                return Err("k1 must be ≥ 1".into());
            }
            Ok(())
        });
    }

    #[test]
    fn alpha_one_is_non_nested() {
        let p = plan(128, 128, 0.30, 1.0);
        assert_eq!(p.k2, 0);
        assert_eq!(p.k1, p.k);
    }

    #[test]
    fn achieved_ratio_is_close_to_requested() {
        check("achieved ratio ≈ requested", 40, |g| {
            let m = g.usize_in(64, 512);
            let n = g.usize_in(64, 512);
            let ratio = g.f64_in(0.1, 0.5);
            let p = plan(m, n, ratio, 0.95);
            let achieved = achieved_ratio(m, n, &p);
            // Floor quantization costs at most (m+n)/(m·n) in ratio.
            let quantum = (m + n) as f64 / (m * n) as f64;
            if (achieved - ratio).abs() > quantum + 1e-9 {
                return Err(format!("requested {ratio}, achieved {achieved}"));
            }
            Ok(())
        });
    }

    #[test]
    fn global_allocation_respects_budget_and_prefers_heavy_spectra() {
        // Layer 0 has a flat spectrum (all directions matter); layer 1 decays
        // fast (rank-2-ish).  Global allocation should give layer 0 more rank.
        let flat: Vec<f64> = vec![1.0; 64];
        let decayed: Vec<f64> = (0..64).map(|i| 2.0f64.powi(-(i as i32))).collect();
        let layers = vec![(64usize, 64usize, flat), (64, 64, decayed)];
        let plans = allocate_global(&layers, 0.5, 1.0);
        let spent: usize = plans.iter().enumerate().map(|(i, p)| {
            (layers[i].0 + layers[i].1) * p.k
        }).sum();
        let budget = ((1.0 - 0.5) * (2 * 64 * 64) as f64) as usize;
        assert!(spent <= budget, "spent {spent} > budget {budget}");
        assert!(plans[0].k > plans[1].k, "flat spectrum should win ranks: {plans:?}");
        assert!(plans.iter().all(|p| p.k >= 1));
    }

    #[test]
    fn global_allocation_matches_uniform_on_identical_layers() {
        check("identical layers → near-uniform global ranks", 10, |g| {
            let n = g.usize_in(16, 64);
            let s: Vec<f64> = (0..n).map(|i| 1.0 / (i + 1) as f64).collect();
            let layers = vec![(n, n, s.clone()), (n, n, s.clone()), (n, n, s)];
            let plans = allocate_global(&layers, 0.4, 1.0);
            let ks: Vec<usize> = plans.iter().map(|p| p.k).collect();
            let spread = ks.iter().max().unwrap() - ks.iter().min().unwrap();
            if spread > 1 {
                return Err(format!("identical layers diverged: {ks:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn plans_fit_within_padded_maxima() {
        // Every experiment configuration must fit the padded executable.
        for &(m, n) in &[(128usize, 128usize), (128, 256), (256, 128), (384, 128), (128, 384)] {
            let (k1m, k2m) = max_ranks(m, n);
            for &ratio in &[0.10, 0.20, 0.30, 0.40, 0.50] {
                for &alpha in &[0.80, 0.85, 0.90, 0.95, 0.99, 1.0] {
                    let p = plan(m, n, ratio, alpha);
                    assert!(p.k1 <= k1m, "k1 {} > k1max {k1m} (m={m},n={n},ρ={ratio},α={alpha})", p.k1);
                    assert!(p.k2 <= k2m, "k2 {} > k2max {k2m} (m={m},n={n},ρ={ratio},α={alpha})", p.k2);
                }
            }
        }
    }
}
