//! KV-cache factorization: build [`KvCompression`] from a model's `wk`/`wv`
//! weights with the repo's whitened truncation, per the ASVD KV-cache
//! recipe (arXiv:2312.05821) mapped onto the NSVD whitener.
//!
//! For each layer the K and V projection weights are factored at a latent
//! rank `r ≈ kv_ratio · d`:
//!
//! 1. **input-side scaling** — the same stage-1 whitener the weight
//!    compression path uses ([`Whitener`], built from the `attn_in`
//!    calibration Gram): decompose `A·S` with `A = Wᵀ`, un-whiten the right
//!    factor.  This is ASVD's "input scaling" generalized from a diagonal
//!    to the full Cholesky/eigen whitener (see METHODS.md);
//! 2. **query-side scaling** (`wk` only) — ASVD scales the K projection's
//!    *output* dims by the magnitude of the query channels they dot
//!    against, so directions the queries actually probe survive
//!    truncation.  Here the proxy is the column norms of `wq`: rows of `A`
//!    are scaled by `s_j = ‖wq[:, j]‖₂` (normalized to mean 1, clamped)
//!    before the whitened SVD, and the corresponding `up` columns are
//!    unscaled by `1/s_j` after — an exact change of basis, so only the
//!    truncation (not the reconstruction) is affected;
//! 3. **balanced split** — `proj = Z₁ᵀ` and `up = W₁ᵀ` exactly as
//!    `methods::compress_layer_with_policy` builds its stage-1 factors, so
//!    the latent path inherits the pipeline's numerics.
//!
//! Rank allocation is uniform (`round(ratio·d)` per projection) or
//! spectrum-aware ([`crate::compress::allocate::kv_latent_ranks`]:
//! water-fill the same latent budget by whitened marginal gain).
//!
//! The plain variant ([`compress_kv_plain`]) uses the identity whitener and
//! no query scaling — no calibration pass needed — which is what the serve
//! fuzz battery and `serve-gen --kv-ratio` build from raw weights.

use super::allocate::{kv_latent_ranks, kv_uniform_rank, LayerProfile};
use super::whiten::Whitener;
use crate::linalg::matrix::Matrix;
use crate::linalg::rsvd::{svd_for_rank, SvdPolicy};
use crate::model::config::ModelConfig;
use crate::model::kvc::{KvCompression, KvLayer, KvProj};
use crate::model::weights::{Tensor, Weights};
use anyhow::{bail, Result};

/// How to build the KV factorization.
#[derive(Clone, Debug)]
pub struct KvBuildSpec {
    /// Latent width as a fraction of the full K/V row (`r/d`); `>= 1.0`
    /// yields the identity compression.
    pub ratio: f64,
    /// Spectrum-aware per-projection rank allocation under the shared
    /// latent budget (vs uniform `round(ratio·d)` everywhere).
    pub spectrum: bool,
    /// ASVD query-side scaling of `wk` rows by the `wq` column-norm proxy.
    pub query_scale: bool,
}

impl KvBuildSpec {
    pub fn new(ratio: f64) -> KvBuildSpec {
        KvBuildSpec { ratio, spectrum: false, query_scale: false }
    }
}

fn wk_name(layer: usize) -> String {
    format!("blocks.{layer}.attn.wk")
}

fn wv_name(layer: usize) -> String {
    format!("blocks.{layer}.attn.wv")
}

/// Query-magnitude proxy for ASVD's query-side scaling: the ℓ₂ norm of
/// each `wq` output column, normalized to mean 1 and clamped away from
/// zero (a never-probed output dim must not blow up the inverse scale).
fn query_scales(wq: &Tensor) -> Vec<f64> {
    let (n_in, n_out) = (wq.dims[0], wq.dims[1]);
    let mut s = vec![0.0f64; n_out];
    for i in 0..n_in {
        for (j, sj) in s.iter_mut().enumerate() {
            let v = wq.data[i * n_out + j] as f64;
            *sj += v * v;
        }
    }
    for v in s.iter_mut() {
        *v = v.sqrt();
    }
    let mean = s.iter().sum::<f64>() / s.len().max(1) as f64;
    let mean = if mean > 0.0 { mean } else { 1.0 };
    let floor = 1e-6 * s.iter().cloned().fold(0.0, f64::max).max(1e-12);
    for v in s.iter_mut() {
        *v = (*v / mean).max(floor / mean);
    }
    s
}

/// Factor one projection weight (`[n_in, n_out]`, python convention) at
/// `rank`: whitened truncated SVD with optional ASVD row scaling, balanced
/// `√Σ` split, factors returned as `(proj [n_in, rank], up [rank, n_out])`
/// so `w ≈ proj · up`.
fn factor_weight(
    weight: &Tensor,
    w1: &Whitener,
    row_scale: Option<&[f64]>,
    rank: usize,
    svd: &SvdPolicy,
) -> (Vec<f32>, Vec<f32>) {
    let (n_in, n_out) = (weight.dims[0], weight.dims[1]);
    // Paper convention: A = Wᵀ is m×n with m = n_out, n = n_in.
    let mut a = Matrix::from_f32(n_in, n_out, &weight.data).transpose();
    if let Some(s) = row_scale {
        for i in 0..a.rows {
            for j in 0..a.cols {
                a[(i, j)] *= s[i];
            }
        }
    }
    let aw = w1.whiten(&a);
    let svd1 = svd_for_rank(&aw, rank, svd);
    let sqrt_s: Vec<f64> = svd1.s.iter().map(|x| x.max(0.0).sqrt()).collect();
    // W₁ = U√Σ [m, r]; undo the row scaling here so reconstruction is exact
    // in the scaled basis' inverse.
    let mut w_fac = svd1.u.scale_cols(&sqrt_s);
    if let Some(s) = row_scale {
        for i in 0..w_fac.rows {
            for j in 0..w_fac.cols {
                w_fac[(i, j)] /= s[i];
            }
        }
    }
    // Z₁ = √Σ Vᵀ S⁻¹ [r, n].
    let z_fac = w1.unwhiten_rows(&svd1.v.scale_cols(&sqrt_s).transpose());
    // Row convention: proj = Z₁ᵀ [n_in, r], up = W₁ᵀ [r, n_out].
    (z_fac.transpose().to_f32(), w_fac.transpose().to_f32())
}

/// Build the KV compression with per-layer whiteners supplied by the
/// caller (`whitener(layer)` returns the `attn_in` tap whitener, or `None`
/// for identity).  This is the full-control entry the pipeline uses;
/// [`compress_kv_plain`] is the calibration-free variant.
pub fn compress_kv_with(
    cfg: &ModelConfig,
    weights: &Weights,
    whitener: &dyn Fn(usize) -> Option<std::sync::Arc<Whitener>>,
    spec: &KvBuildSpec,
    svd: &SvdPolicy,
) -> Result<KvCompression> {
    if !(spec.ratio > 0.0) {
        bail!("--kv-ratio must be > 0 (got {})", spec.ratio);
    }
    if spec.ratio >= 1.0 {
        return Ok(KvCompression::identity(cfg.n_layers));
    }
    let identity = Whitener::identity();
    // Gather the 2L projection entries (wk, wv per layer) with their
    // whiteners, in a fixed interleaved order for the rank allocator.
    let mut entries: Vec<(usize, bool, &Tensor)> = Vec::new(); // (layer, is_k, weight)
    for i in 0..cfg.n_layers {
        entries.push((i, true, weights.get(&wk_name(i))?));
        entries.push((i, false, weights.get(&wv_name(i))?));
    }
    let whiteners: Vec<Option<std::sync::Arc<Whitener>>> =
        (0..cfg.n_layers).map(|i| whitener(i)).collect();
    let w_of = |layer: usize| -> &Whitener {
        whiteners[layer].as_deref().unwrap_or(&identity)
    };
    // Per-entry latent ranks: uniform, or water-filled over the whitened
    // K/V spectra under the same total latent budget.
    let ranks: Vec<usize> = if spec.spectrum {
        let profiles: Vec<LayerProfile> = entries
            .iter()
            .map(|&(layer, is_k, w)| LayerProfile {
                name: if is_k { wk_name(layer) } else { wv_name(layer) },
                m: w.dims[1],
                n: w.dims[0],
                spectrum: super::allocate::whitened_spectrum(w, w_of(layer)),
            })
            .collect();
        kv_latent_ranks(&profiles, spec.ratio)
    } else {
        entries
            .iter()
            .map(|&(_, _, w)| kv_uniform_rank(spec.ratio, w.dims[0].min(w.dims[1])))
            .collect()
    };
    let mut kvc = KvCompression {
        layers: (0..cfg.n_layers).map(|_| KvLayer::default()).collect(),
    };
    for (&(layer, is_k, w), &rank) in entries.iter().zip(&ranks) {
        let (n_in, n_out) = (w.dims[0], w.dims[1]);
        if rank >= n_in.min(n_out) {
            continue; // full rank: identity is cheaper and exact
        }
        let scales = if is_k && spec.query_scale {
            Some(query_scales(weights.get(&format!("blocks.{layer}.attn.wq"))?))
        } else {
            None
        };
        let (proj, up) = factor_weight(w, w_of(layer), scales.as_deref(), rank, svd);
        let p = KvProj::new(n_in, rank, n_out, proj, up);
        if is_k {
            kvc.layers[layer].k = Some(p);
        } else {
            kvc.layers[layer].v = Some(p);
        }
    }
    Ok(kvc)
}

/// Calibration-free KV factorization: plain truncated SVD of `wk`/`wv` at
/// uniform rank `round(ratio·d)` per layer — deterministic from the
/// weights alone.  The serve fuzz battery and `serve-gen --kv-ratio` build
/// their factors here; the pipeline's calibrated path goes through
/// [`compress_kv_with`].
pub fn compress_kv_plain(
    cfg: &ModelConfig,
    weights: &Weights,
    ratio: f64,
    svd: &SvdPolicy,
) -> Result<KvCompression> {
    compress_kv_with(cfg, weights, &|_| None, &KvBuildSpec::new(ratio), svd)
}

/// View the KV factors as a [`CompressedModel`] with `wk`/`wv`-only
/// entries (`P₁ = proj`, `Q₁ = up`, `k₂ = 0`): replacing those two weights
/// in a full forward is numerically *exactly* what routing the cache
/// through the latents does, so the existing perplexity evaluator measures
/// KV-compression quality unchanged — the pooled-ppl-vs-kv-ratio rows of
/// `--sweep-ratios` evaluate this view.  Always uses the f32 factors (the
/// quality estimate, not the serving dtype).
pub fn kv_override_model(kvc: &KvCompression) -> super::lowrank::CompressedModel {
    use super::lowrank::{CompressedLayer, CompressedModel};
    let mut cm = CompressedModel::default();
    for (i, layer) in kvc.layers.iter().enumerate() {
        for (proj, name) in [(&layer.k, wk_name(i)), (&layer.v, wv_name(i))] {
            if let Some(p) = proj {
                let p1 = Matrix::from_f32(p.n_in, p.rank, &p.proj);
                let q1 = Matrix::from_f32(p.rank, p.d_out, &p.up);
                let p2 = Matrix::zeros(p.n_in, 0);
                let q2 = Matrix::zeros(0, p.d_out);
                cm.insert(&name, CompressedLayer::from_matrices(&p1, &q1, &p2, &q2));
            }
        }
    }
    cm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::whiten::CalibStats;
    use crate::linalg::svd::svd_thin;
    use crate::model::forward::matmul_raw;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn tensor_from(a: &Matrix) -> Tensor {
        Tensor { dims: vec![a.rows, a.cols], data: a.to_f32() }
    }

    /// Anisotropic calibration stats (outlier dims — the LLM regime).
    fn aniso_stats(n: usize, samples: usize, rng: &mut Rng) -> (CalibStats, Matrix) {
        let mut x = Matrix::randn(samples, n, 1.0, rng);
        for i in 0..samples {
            for j in 0..n {
                if j % 5 == 0 {
                    x[(i, j)] *= 6.0;
                }
            }
        }
        let mut stats = CalibStats::new(n);
        stats.gram = x.gram();
        stats.rows = samples;
        (stats, x)
    }

    /// Satellite: the latent round-trip error on activations is bounded by
    /// the truncation tail — `‖x(W − proj·up)‖_F ≤ ‖x‖_F · tail(r)` with
    /// the plain (identity-whitened) factorization, where `tail(r)` is the
    /// Eckart–Young optimum `√(Σ_{i≥r} σᵢ²)`.  Ties the `attend_row`
    /// numerics to the METHODS.md error decomposition.
    #[test]
    fn kv_compress_roundtrip_error_bounded_by_whitened_tail() {
        check("‖x·E‖ ≤ ‖x‖·tail", 10, |g| {
            let mut rng = g.rng.fork(0);
            let d = g.usize_in(8, 24);
            let rank = g.usize_in(1, d - 1);
            let w_m = Matrix::randn(d, d, 1.0, &mut rng);
            let w = tensor_from(&w_m);
            let (proj, up) =
                factor_weight(&w, &Whitener::identity(), None, rank, &SvdPolicy::exact());
            let rows = g.usize_in(1, 6);
            let x: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32).collect();
            // Latent path: x → proj → up.
            let lat = matmul_raw(&x, rows, d, &proj, rank);
            let rec = matmul_raw(&lat, rows, rank, &up, d);
            // Dense path: x @ W.
            let dense = matmul_raw(&x, rows, d, &w.data, d);
            let err_sq: f64 = dense
                .iter()
                .zip(&rec)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            let x_norm_sq: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
            // Tail of σ(A) with A = Wᵀ (same singular values as W).
            let tail = svd_thin(&w_m).tail_norm(rank);
            let bound = x_norm_sq.sqrt() * tail * 1.001 + 1e-3;
            if err_sq.sqrt() > bound {
                return Err(format!(
                    "d={d} r={rank}: err {} > bound {bound}",
                    err_sq.sqrt()
                ));
            }
            Ok(())
        });
    }

    /// The whitened factorization beats the plain one on activation-
    /// weighted loss when activations are anisotropic — the reason the
    /// cache factors ride the calibration whitener at all.
    #[test]
    fn kv_compress_whitened_beats_plain_on_activation_loss() {
        check("whitened ≤ plain on ‖X·E‖", 5, |g| {
            let mut rng = g.rng.fork(0);
            let d = 16;
            let rank = g.usize_in(2, 6);
            let (stats, x) = aniso_stats(d, 80, &mut rng);
            let w_m = Matrix::randn(d, d, 1.0, &mut rng);
            let w = tensor_from(&w_m);
            let chol = Whitener::cholesky(&stats);
            let loss = |proj: &[f32], up: &[f32]| -> f64 {
                let xf = x.to_f32();
                let rows = x.rows;
                let lat = matmul_raw(&xf, rows, d, proj, rank);
                let rec = matmul_raw(&lat, rows, rank, up, d);
                let dense = matmul_raw(&xf, rows, d, &w.data, d);
                dense.iter().zip(&rec).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
            };
            let (pp, pu) = factor_weight(&w, &Whitener::identity(), None, rank, &SvdPolicy::exact());
            let (wp, wu) = factor_weight(&w, &chol, None, rank, &SvdPolicy::exact());
            let plain = loss(&pp, &pu);
            let whitened = loss(&wp, &wu);
            if whitened > plain * 1.001 {
                return Err(format!("whitened {whitened} > plain {plain}"));
            }
            Ok(())
        });
    }

    /// Query-side scaling is an exact change of basis: at full rank the
    /// scaled factorization still reconstructs the weight.
    #[test]
    fn kv_compress_query_scaling_is_exact_at_full_rank() {
        let mut rng = Rng::new(11);
        let d = 12;
        let w_m = Matrix::randn(d, d, 1.0, &mut rng);
        let w = tensor_from(&w_m);
        let wq = tensor_from(&Matrix::randn(d, d, 1.0, &mut rng));
        let s = query_scales(&wq);
        assert_eq!(s.len(), d);
        assert!(s.iter().all(|&v| v > 0.0));
        let (proj, up) = factor_weight(&w, &Whitener::identity(), Some(&s), d, &SvdPolicy::exact());
        // proj @ up must equal W to f32/SVD rounding.
        let rec = matmul_raw(&proj, d, d, &up, d);
        let max_diff = w
            .data
            .iter()
            .zip(&rec)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "full-rank scaled reconstruction off by {max_diff}");
    }

    #[test]
    fn kv_compress_ratio_one_is_identity_and_half_halves_widths() {
        let (cfg, w) = crate::bench::tiny_model("llama-t", 5);
        let id = compress_kv_plain(&cfg, &w, 1.0, &SvdPolicy::exact()).unwrap();
        assert!(id.is_identity());
        let half = compress_kv_plain(&cfg, &w, 0.5, &SvdPolicy::exact()).unwrap();
        assert!(!half.is_identity());
        let d = cfg.d_model;
        for i in 0..cfg.n_layers {
            assert_eq!(half.width_k(i, d), d / 2, "layer {i} k width");
            assert_eq!(half.width_v(i, d), d / 2, "layer {i} v width");
        }
        assert!(compress_kv_plain(&cfg, &w, 0.0, &SvdPolicy::exact()).is_err());
    }

    /// The CompressedModel view stores exactly the KV factors, so the
    /// sweep's quality rows evaluate the same numbers the cache serves.
    #[test]
    fn kv_compress_override_model_matches_latent_path() {
        let (cfg, w) = crate::bench::tiny_model("llama-t", 7);
        let kvc = compress_kv_plain(&cfg, &w, 0.25, &SvdPolicy::exact()).unwrap();
        let cm = kv_override_model(&kvc);
        let mut rng = Rng::new(9);
        let d = cfg.d_model;
        let x: Vec<f32> = (0..3 * d).map(|_| rng.normal() as f32).collect();
        use crate::model::forward::LinearOverride;
        for i in 0..cfg.n_layers {
            let p = kvc.layers[i].k.as_ref().unwrap();
            let lat = p.project(&x, 3);
            let rec = p.reconstruct(&lat, 3);
            let via_cm = cm.apply(&wk_name(i), &x, 3, d).expect("wk is overridden");
            let max_diff = rec
                .iter()
                .zip(&via_cm)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // Same factors, same GEMM kernel; the only difference is the
            // CompressedLayer's (empty) stage-2 accumulation.
            assert!(max_diff < 1e-5, "layer {i}: override diverged by {max_diff}");
        }
        assert!(cm.apply("blocks.0.attn.wq", &x, 3, d).is_none(), "only wk/wv");
    }

    /// Spectrum-aware ranks stay on the latent budget and respect caps.
    #[test]
    fn kv_compress_spectrum_build_meets_budget() {
        let (cfg, w) = crate::bench::tiny_model("llama-t", 13);
        let spec = KvBuildSpec { ratio: 0.25, spectrum: true, query_scale: true };
        let kvc = compress_kv_with(&cfg, &w, &|_| None, &spec, &SvdPolicy::exact()).unwrap();
        let d = cfg.d_model;
        let uniform_latents: usize = 2 * cfg.n_layers * kv_uniform_rank(0.25, d);
        let got_latents: usize = (0..cfg.n_layers)
            .map(|i| kvc.width_k(i, d) + kvc.width_v(i, d))
            .sum();
        assert!(
            got_latents <= uniform_latents,
            "spectrum allocation overspent: {got_latents} > {uniform_latents}"
        );
        assert!(kvc.factor_bytes() > 0);
    }
}
