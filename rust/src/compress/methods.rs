//! The decomposition methods: SVD, ASVD-0/I/II/III, NSVD-I/II, NID-I/II.
//!
//! All methods consume the dense weight (python convention `W [n_in,
//! n_out]`, i.e. the paper's `A = Wᵀ`), the calibration stats of the tap
//! feeding it, and a [`RankPlan`]; they produce a [`CompressedLayer`] with
//! the SAME stored parameter count `(m+n)(k₁+k₂)` — the paper's like-for-like
//! comparison contract.
//!
//! Stage 1 (Eq. 5a): truncated SVD of the whitened `A S` at rank k₁,
//! un-whitened on the right.  Stage 2 (Eq. 5b): plain truncated SVD (NSVD) or
//! column interpolative decomposition (NID) of the *residual* `A − Ã₁` at
//! rank k₂ — re-anchoring the factors to the original weight, which is what
//! rescues out-of-distribution activations.

use super::lowrank::CompressedLayer;
use super::ranks::RankPlan;
use super::whiten::{CalibStats, Whitener};
use crate::linalg::id::interpolative;
use crate::linalg::matrix::Matrix;
use crate::linalg::rsvd::{svd_for_rank, SvdPolicy};
use crate::model::weights::Tensor;
use anyhow::{bail, Result};

/// The method zoo (paper Tables 1–6 plus the §3 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Plain truncated SVD of the weight (no activation awareness).
    Svd,
    /// ASVD-0: diagonal abs-mean scaling (Yuan et al., 2023).
    Asvd0,
    /// ASVD-I = SVD-LLM: Cholesky whitening (Theorem 2).
    AsvdI,
    /// ASVD-II: eigen whitening with pseudo-inverse (Theorem 3).
    AsvdII,
    /// ASVD-III: γ-scaled rotation (Theorem 4, failure-trial ablation).
    AsvdIII,
    /// NSVD-I: nested, stage 1 Cholesky, stage 2 SVD (the contribution).
    NsvdI,
    /// NSVD-II: nested, stage 1 eigen, stage 2 SVD.
    NsvdII,
    /// NID-I: nested, stage 1 Cholesky, stage 2 interpolative.
    NidI,
    /// NID-II: nested, stage 1 eigen, stage 2 interpolative.
    NidII,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "svd" => Method::Svd,
            "asvd-0" | "asvd0" => Method::Asvd0,
            "asvd-i" | "asvd1" | "svd-llm" => Method::AsvdI,
            "asvd-ii" | "asvd2" => Method::AsvdII,
            "asvd-iii" | "asvd3" => Method::AsvdIII,
            "nsvd-i" | "nsvd1" => Method::NsvdI,
            "nsvd-ii" | "nsvd2" => Method::NsvdII,
            "nid-i" | "nid1" => Method::NidI,
            "nid-ii" | "nid2" => Method::NidII,
            _ => bail!("unknown method '{s}'"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            Method::Svd => "SVD",
            Method::Asvd0 => "ASVD-0",
            Method::AsvdI => "ASVD-I",
            Method::AsvdII => "ASVD-II",
            Method::AsvdIII => "ASVD-III",
            Method::NsvdI => "NSVD-I",
            Method::NsvdII => "NSVD-II",
            Method::NidI => "NID-I",
            Method::NidII => "NID-II",
        }
    }

    /// Nested methods consume the (k₁, k₂) split; baselines use k₁ = k.
    pub fn is_nested(self) -> bool {
        matches!(self, Method::NsvdI | Method::NsvdII | Method::NidI | Method::NidII)
    }

    /// Cache key for the stage-1 whitener: methods sharing a key produce
    /// identical whiteners from the same stats.
    pub fn whitener_kind(self) -> &'static str {
        match self {
            Method::Svd => "identity",
            Method::Asvd0 => "diag",
            Method::AsvdI | Method::NsvdI | Method::NidI => "chol",
            Method::AsvdII | Method::NsvdII | Method::NidII => "eig",
            Method::AsvdIII => "eig-gamma",
        }
    }

    /// Stage-2 flavor for nested methods.
    fn stage2_is_id(self) -> bool {
        matches!(self, Method::NidI | Method::NidII)
    }

    /// Build the stage-1 whitening transform for this method.
    /// Whiteners depend only on (method-class, tap stats) — NOT on the
    /// compression ratio or α — so callers sweeping ratios should build them
    /// once per tap via [`Method::whitener_kind`] and reuse (see
    /// `coordinator::pipeline`'s whitener cache).
    pub fn stage1_whitener(self, stats: &CalibStats) -> Whitener {
        match self {
            Method::Svd => Whitener::identity(),
            Method::Asvd0 => Whitener::diag(stats),
            Method::AsvdI | Method::NsvdI | Method::NidI => Whitener::cholesky(stats),
            Method::AsvdII | Method::NsvdII | Method::NidII => Whitener::eigen(stats),
            Method::AsvdIII => Whitener::eigen_gamma(stats),
        }
    }

    /// All methods in the paper's Table 1 row order.
    pub fn table1() -> [Method; 6] {
        [Method::Svd, Method::Asvd0, Method::AsvdI, Method::AsvdII, Method::NsvdI, Method::NsvdII]
    }
}

/// Full compression request.
#[derive(Clone, Copy, Debug)]
pub struct CompressionSpec {
    pub method: Method,
    /// Fraction of parameters removed (paper's 10%–50%).
    pub ratio: f64,
    /// k₁ share for nested methods (paper default 0.95).
    pub alpha: f64,
}

impl CompressionSpec {
    pub fn new(method: Method, ratio: f64) -> CompressionSpec {
        CompressionSpec { method, ratio, alpha: 0.95 }
    }

    /// Effective α: baselines always use the whole budget in stage 1.
    pub fn effective_alpha(&self) -> f64 {
        if self.method.is_nested() {
            self.alpha
        } else {
            1.0
        }
    }
}

/// Decompose one weight.  `weight` is `[n_in, n_out]` (python convention);
/// `stats` is the calibration accumulator of the tap feeding this weight.
pub fn compress_layer(
    weight: &Tensor,
    stats: &CalibStats,
    spec: &CompressionSpec,
    plan: &RankPlan,
) -> Result<CompressedLayer> {
    if weight.dims.len() != 2 {
        bail!("compress_layer expects a 2-D weight");
    }
    let n_in = weight.dims[0];
    if stats.dim() != n_in {
        bail!("stats dim {} != weight n_in {n_in}", stats.dim());
    }
    let w1 = spec.method.stage1_whitener(stats);
    compress_layer_with(weight, &w1, spec, plan)
}

/// Like [`compress_layer`] with a pre-built (cacheable) stage-1 whitener —
/// whiteners are ratio/α-independent, so sweeps reuse them across jobs.
/// Uses the exact Jacobi SVD; the engine routes through
/// [`compress_layer_with_policy`] to enable the randomized fast path.
pub fn compress_layer_with(
    weight: &Tensor,
    w1: &Whitener,
    spec: &CompressionSpec,
    plan: &RankPlan,
) -> Result<CompressedLayer> {
    compress_layer_with_policy(weight, w1, spec, plan, &SvdPolicy::exact())
}

/// Full-control variant: both truncated SVDs (stage-1 whitened, stage-2
/// residual) go through `svd` — [`SvdPolicy::exact`] is bit-identical to the
/// historical `svd_thin(..).truncate(k)` path, [`SvdPolicy::auto`] enables
/// the certified randomized fast path for ranks well below `min(m,n)`.
pub fn compress_layer_with_policy(
    weight: &Tensor,
    w1: &Whitener,
    spec: &CompressionSpec,
    plan: &RankPlan,
    svd: &SvdPolicy,
) -> Result<CompressedLayer> {
    let (n_in, n_out) = (weight.dims[0], weight.dims[1]);
    // Paper convention: A = Wᵀ is m×n with m = n_out, n = n_in.
    let a = Matrix::from_f32(n_in, n_out, &weight.data).transpose();

    // ---- Stage 1: activation-aware truncated SVD at rank k1 ----
    let aw = w1.whiten(&a);
    let svd1 = svd_for_rank(&aw, plan.k1, svd);
    // Ã₁ = U_k √Σ · √Σ Vᵀ_k S⁻¹  (balanced split).
    let sqrt_s: Vec<f64> = svd1.s.iter().map(|x| x.max(0.0).sqrt()).collect();
    let w1_fac = svd1.u.scale_cols(&sqrt_s); // [m, k1]
    let z1_fac = w1.unwhiten_rows(&svd1.v.scale_cols(&sqrt_s).transpose()); // [k1, n]
    // Row convention factors: P1 = Z1ᵀ [n_in, k1], Q1 = W1ᵀ [k1, n_out].
    let p1 = z1_fac.transpose();
    let q1 = w1_fac.transpose();

    // ---- Stage 2: residual decomposition at rank k2 (nested only) ----
    let (p2, q2) = if plan.k2 == 0 {
        (Matrix::zeros(n_in, 0), Matrix::zeros(0, n_out))
    } else {
        let a1 = w1_fac.matmul(&z1_fac); // Ã₁ in paper convention [m, n]
        let resid = &a - &a1;
        if spec.method.stage2_is_id() {
            // Column ID of the residual: R ≈ C T, C = actual columns [m, k2],
            // T [k2, n].  Row factors: P2 = Tᵀ [n, k2], Q2 = Cᵀ [k2, m].
            let id = interpolative(&resid, plan.k2);
            (id.t.transpose(), id.c.transpose())
        } else {
            let svd2 = svd_for_rank(&resid, plan.k2, svd);
            let sqrt2: Vec<f64> = svd2.s.iter().map(|x| x.max(0.0).sqrt()).collect();
            let w2 = svd2.u.scale_cols(&sqrt2); // [m, k2]
            let z2 = svd2.v.scale_cols(&sqrt2).transpose(); // [k2, n]
            (z2.transpose(), w2.transpose())
        }
    };
    Ok(CompressedLayer::from_matrices(&p1, &q1, &p2, &q2))
}

/// Error report for a compressed layer (used by tests and ablation benches).
#[derive(Clone, Copy, Debug)]
pub struct LayerError {
    /// Plain Frobenius error ‖W − W̃‖_F.
    pub fro: f64,
    /// Activation-weighted error ‖(A − Ã)X‖_F.
    pub activation: f64,
}

/// Compute both error metrics of a compressed layer vs the dense weight.
pub fn layer_error(weight: &Tensor, stats: &CalibStats, layer: &CompressedLayer) -> LayerError {
    let w = Matrix::from_f32(weight.dims[0], weight.dims[1], &weight.data);
    let recon_t = layer.reconstruct();
    let recon = Matrix::from_f32(recon_t.dims[0], recon_t.dims[1], &recon_t.data);
    let err_w = &w - &recon; // [n_in, n_out]
    // Paper convention error: E = (W − W̃)ᵀ, loss² = tr(E G Eᵀ).
    let e = err_w.transpose();
    let act = super::whiten::activation_loss_sq(&e, &stats.gram).max(0.0).sqrt();
    LayerError { fro: err_w.fro_norm(), activation: act }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd_thin;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Synthetic calibration stats with controllable anisotropy: activations
    /// are drawn with per-dimension scales `scales`, so the Gram concentrates
    /// where scales are large — a controllable stand-in for "activation
    /// distribution".
    fn stats_with_scales(scales: &[f64], samples: usize, rng: &mut Rng) -> (CalibStats, Matrix) {
        let n = scales.len();
        let mut x = Matrix::randn(samples, n, 1.0, rng);
        for i in 0..samples {
            for j in 0..n {
                x[(i, j)] *= scales[j];
            }
        }
        let mut stats = CalibStats::new(n);
        stats.gram = x.matmul_tn(&x);
        for i in 0..samples {
            for j in 0..n {
                stats.abs_sum[j] += x[(i, j)].abs();
            }
        }
        stats.rows = samples;
        (stats, x)
    }

    fn tensor_from(a: &Matrix) -> Tensor {
        Tensor { dims: vec![a.rows, a.cols], data: a.to_f32() }
    }

    #[test]
    fn all_methods_produce_exact_budget() {
        check("params == (m+n)(k1+k2)", 9, |g| {
            let mut rng = g.rng.fork(0);
            let n_in = g.usize_in(8, 20);
            let n_out = g.usize_in(8, 20);
            let scales: Vec<f64> = (0..n_in).map(|_| rng.range_f64(0.5, 2.0)).collect();
            let (stats, _) = stats_with_scales(&scales, n_in + 10, &mut rng);
            let w = tensor_from(&Matrix::randn(n_in, n_out, 1.0, &mut rng));
            for m in [
                Method::Svd, Method::Asvd0, Method::AsvdI, Method::AsvdII,
                Method::AsvdIII, Method::NsvdI, Method::NsvdII, Method::NidI, Method::NidII,
            ] {
                let spec = CompressionSpec { method: m, ratio: 0.3, alpha: 0.9 };
                let plan = super::super::ranks::plan(n_out, n_in, 0.3, spec.effective_alpha());
                let layer = compress_layer(&w, &stats, &spec, &plan).unwrap();
                if layer.params() != (n_in + n_out) * plan.k {
                    return Err(format!("{}: {} != {}", m.label(), layer.params(),
                        (n_in + n_out) * plan.k));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn plain_svd_achieves_eckart_young() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(16, 12, 1.0, &mut rng);
        let w = tensor_from(&a);
        let (stats, _) = stats_with_scales(&vec![1.0; 16], 40, &mut rng);
        let spec = CompressionSpec::new(Method::Svd, 0.3);
        let plan = super::super::ranks::plan(12, 16, 0.3, 1.0);
        let layer = compress_layer(&w, &stats, &spec, &plan).unwrap();
        let err = layer_error(&w, &stats, &layer);
        let svd = svd_thin(&a);
        // f32 cast costs a little; allow small slack.
        assert!(
            (err.fro - svd.tail_norm(plan.k)).abs() < 1e-3 * (1.0 + svd.s[0]),
            "fro {} vs tail {}", err.fro, svd.tail_norm(plan.k)
        );
    }

    #[test]
    fn asvd1_beats_svd_on_activation_loss() {
        // The whole point of activation-aware whitening: on anisotropic
        // activations the Cholesky method has lower ‖(A-Ã)X‖ than plain SVD.
        check("ASVD-I ≤ SVD on activation loss", 7, |g| {
            let mut rng = g.rng.fork(0);
            let n_in = 16;
            let n_out = 12;
            // Strongly anisotropic activations (outlier dims) — the LLM regime.
            let scales: Vec<f64> = (0..n_in)
                .map(|j| if j % 5 == 0 { rng.range_f64(4.0, 8.0) } else { rng.range_f64(0.2, 1.0) })
                .collect();
            let (stats, _) = stats_with_scales(&scales, 64, &mut rng);
            let w = tensor_from(&Matrix::randn(n_in, n_out, 1.0, &mut rng));
            let plan = super::super::ranks::plan(n_out, n_in, 0.4, 1.0);
            let svd_err = layer_error(&w, &stats,
                &compress_layer(&w, &stats, &CompressionSpec::new(Method::Svd, 0.4), &plan).unwrap());
            let asvd_err = layer_error(&w, &stats,
                &compress_layer(&w, &stats, &CompressionSpec::new(Method::AsvdI, 0.4), &plan).unwrap());
            if asvd_err.activation > svd_err.activation * 1.001 {
                return Err(format!(
                    "asvd {} > svd {}", asvd_err.activation, svd_err.activation
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn asvd1_and_asvd2_are_equivalent_on_full_rank() {
        let mut rng = Rng::new(2);
        let (stats, _) = stats_with_scales(&vec![1.0; 10], 50, &mut rng);
        let w = tensor_from(&Matrix::randn(10, 14, 1.0, &mut rng));
        let plan = super::super::ranks::plan(14, 10, 0.3, 1.0);
        let l1 = compress_layer(&w, &stats, &CompressionSpec::new(Method::AsvdI, 0.3), &plan).unwrap();
        let l2 = compress_layer(&w, &stats, &CompressionSpec::new(Method::AsvdII, 0.3), &plan).unwrap();
        let r1 = l1.reconstruct();
        let r2 = l2.reconstruct();
        let max_diff = r1.data.iter().zip(&r2.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2e-3, "Theorem 3(ii) violated: max diff {max_diff}");
    }

    #[test]
    fn nsvd_beats_asvd_on_out_of_distribution_activations() {
        // The paper's central claim, in miniature: calibrate on distribution
        // A, evaluate the weighted error under distribution B with a very
        // different activation profile.  NSVD's residual stage must help.
        check("NSVD-I ≤ ASVD-I on OOD activation loss", 5, |g| {
            let mut rng = g.rng.fork(0);
            let n_in = 20;
            let n_out = 16;
            // Calibration: first half of dims hot.  OOD: second half hot.
            let cal_scales: Vec<f64> =
                (0..n_in).map(|j| if j < n_in / 2 { 5.0 } else { 0.3 }).collect();
            let ood_scales: Vec<f64> =
                (0..n_in).map(|j| if j >= n_in / 2 { 5.0 } else { 0.3 }).collect();
            let (cal, _) = stats_with_scales(&cal_scales, 80, &mut rng);
            let (ood, _) = stats_with_scales(&ood_scales, 80, &mut rng);
            let w = tensor_from(&Matrix::randn(n_in, n_out, 1.0, &mut rng));
            let plan_a = super::super::ranks::plan(n_out, n_in, 0.4, 1.0);
            let asvd = compress_layer(&w, &cal, &CompressionSpec::new(Method::AsvdI, 0.4), &plan_a).unwrap();
            let spec_n = CompressionSpec { method: Method::NsvdI, ratio: 0.4, alpha: 0.8 };
            let plan_n = super::super::ranks::plan(n_out, n_in, 0.4, 0.8);
            let nsvd = compress_layer(&w, &cal, &spec_n, &plan_n).unwrap();
            let asvd_ood = layer_error(&w, &ood, &asvd).activation;
            let nsvd_ood = layer_error(&w, &ood, &nsvd).activation;
            if nsvd_ood > asvd_ood * 1.02 {
                return Err(format!("nsvd {nsvd_ood} > asvd {asvd_ood} on OOD"));
            }
            Ok(())
        });
    }

    #[test]
    fn nid_skeleton_columns_come_from_residual() {
        let mut rng = Rng::new(3);
        let (stats, _) = stats_with_scales(&vec![1.0; 12], 40, &mut rng);
        let w = tensor_from(&Matrix::randn(12, 10, 1.0, &mut rng));
        let spec = CompressionSpec { method: Method::NidI, ratio: 0.3, alpha: 0.8 };
        let plan = super::super::ranks::plan(10, 12, 0.3, 0.8);
        assert!(plan.k2 > 0);
        let layer = compress_layer(&w, &stats, &spec, &plan).unwrap();
        assert_eq!(layer.k2, plan.k2);
        let err = layer_error(&w, &stats, &layer);
        assert!(err.fro.is_finite() && err.activation.is_finite());
    }

    #[test]
    fn policy_exact_matches_legacy_path_bitwise() {
        let mut rng = Rng::new(6);
        let (stats, _) = stats_with_scales(&vec![1.0; 12], 40, &mut rng);
        let w = tensor_from(&Matrix::randn(12, 16, 1.0, &mut rng));
        let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.3, alpha: 0.9 };
        let plan = super::super::ranks::plan(16, 12, 0.3, 0.9);
        let w1 = spec.method.stage1_whitener(&stats);
        let legacy = compress_layer_with(&w, &w1, &spec, &plan).unwrap();
        let via =
            compress_layer_with_policy(&w, &w1, &spec, &plan, &SvdPolicy::exact()).unwrap();
        assert_eq!(legacy.p1, via.p1);
        assert_eq!(legacy.q1, via.q1);
        assert_eq!(legacy.p2, via.p2);
        assert_eq!(legacy.q2, via.q2);
    }

    #[test]
    fn rsvd_policy_stays_within_certificate_of_exact() {
        // With the escape hatch at ε, the randomized path either certifies
        // near-optimality or falls back — so the layer error can exceed the
        // exact path's by at most the slack (plus the shared f32 cast).
        let mut rng = Rng::new(7);
        let (stats, _) = stats_with_scales(&vec![1.0; 40], 120, &mut rng);
        let w = tensor_from(&Matrix::randn(40, 56, 1.0, &mut rng));
        let spec = CompressionSpec::new(Method::AsvdI, 0.0);
        let plan = super::super::ranks::RankPlan { k: 6, k1: 6, k2: 0 };
        let w1 = spec.method.stage1_whitener(&stats);
        let mut policy = SvdPolicy::randomized();
        policy.max_rel_err = Some(0.05);
        let exact = compress_layer_with(&w, &w1, &spec, &plan).unwrap();
        let fast = compress_layer_with_policy(&w, &w1, &spec, &plan, &policy).unwrap();
        let e_exact = layer_error(&w, &stats, &exact);
        let e_fast = layer_error(&w, &stats, &fast);
        assert!(
            e_fast.activation <= 1.06 * e_exact.activation + 1e-3,
            "rsvd loss {} vs exact {}",
            e_fast.activation,
            e_exact.activation
        );
        assert_eq!(fast.params(), exact.params());
    }

    #[test]
    fn method_parsing() {
        assert_eq!(Method::parse("nsvd-i").unwrap(), Method::NsvdI);
        assert_eq!(Method::parse("SVD-LLM").unwrap(), Method::AsvdI);
        assert_eq!(Method::parse("asvd2").unwrap(), Method::AsvdII);
        assert!(Method::parse("bogus").is_err());
    }

    #[test]
    fn nested_alpha_one_equals_baseline() {
        // NSVD with k2 = 0 degenerates to its stage-1 baseline.
        let mut rng = Rng::new(4);
        let (stats, _) = stats_with_scales(&vec![1.0; 8], 30, &mut rng);
        let w = tensor_from(&Matrix::randn(8, 8, 1.0, &mut rng));
        let plan = super::super::ranks::plan(8, 8, 0.3, 1.0);
        let spec_n = CompressionSpec { method: Method::NsvdI, ratio: 0.3, alpha: 1.0 };
        let nsvd = compress_layer(&w, &stats, &spec_n, &plan).unwrap();
        let asvd = compress_layer(&w, &stats, &CompressionSpec::new(Method::AsvdI, 0.3), &plan).unwrap();
        let d: f32 = nsvd.reconstruct().data.iter()
            .zip(&asvd.reconstruct().data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(d < 1e-5, "α=1 NSVD should equal ASVD, diff {d}");
    }
}
