//! Activation-aware whitening transforms (paper §3).
//!
//! Each variant supplies `S` (and its inverse action) with which the weight
//! is transformed before truncated SVD: decompose `AS`, then un-whiten the
//! right factor with `S⁻¹` (or `S⁺`).  Variants:
//!
//! * [`Whitener::Identity`] — plain SVD (no activation awareness).
//! * [`Whitener::Diag`]     — ASVD-0: `S = diag(mean |xᵢ|)` (Yuan et al.).
//! * [`Whitener::Chol`]     — ASVD-I / SVD-LLM: `S S ᵀ = XXᵀ` via Cholesky
//!   (PSD-safe ridge, reported), Theorem 2.
//! * [`Whitener::Eig`]      — ASVD-II: `S = P Λ^{1/2}` from the spectral
//!   decomposition, pseudo-inverse for rank-deficient Grams, Theorem 3.
//! * [`Whitener::EigGamma`] — ASVD-III (failure-trial ablation): `S = P·γ`
//!   with `γ = max(Λ^{1/2})`, Theorem 4.

use crate::linalg::chol::{cholesky_psd, invert_lower};
use crate::linalg::eig::{sym_eig, SymEig};
use crate::linalg::gemm;
use crate::linalg::matrix::Matrix;

/// Pending-row threshold at which [`CalibStats::push_rows`] flushes its
/// buffer through the SYRK kernel: large enough to amortize packing, small
/// enough to bound buffer memory at `FLUSH_ROWS × dim` f64s.
const FLUSH_ROWS: usize = 256;

/// Calibration statistics for one tap (accumulated over batches).
///
/// Raw activation rows are buffered (`pending`, row-major) and flushed
/// through the packed SYRK kernel ([`gemm::syrk_tn`]) every `FLUSH_ROWS`
/// rows: the Gram's **upper triangle** accumulates `XᵀX` batch-wise, and
/// [`CalibStats::finalize`] mirrors it down once at the end of collection —
/// instead of the retired per-call scalar triple loop, which mirrored on
/// every accumulate and bypassed the kernel layer entirely.  Consumers of
/// `gram` (whiteners, similarity, activation loss) must only see finalized
/// stats; every collection path calls finalize after its last batch.
#[derive(Clone, Debug)]
pub struct CalibStats {
    /// `Σ x xᵀ` over all calibration rows — [n, n].
    pub gram: Matrix,
    /// `Σ |x|` per dimension — length n.
    pub abs_sum: Vec<f64>,
    /// Number of accumulated rows.
    pub rows: usize,
    /// Buffered activation rows awaiting a SYRK flush (row-major, f64).
    pending: Vec<f64>,
}

impl CalibStats {
    pub fn new(n: usize) -> CalibStats {
        CalibStats { gram: Matrix::zeros(n, n), abs_sum: vec![0.0; n], rows: 0, pending: Vec::new() }
    }

    pub fn dim(&self) -> usize {
        self.gram.rows
    }

    /// Buffer `rows` activation rows (`x` row-major `rows × dim`, f32 as
    /// the forward produces them): abs-sums update immediately, the Gram
    /// update is deferred to a SYRK flush.  The flush check runs per row,
    /// so the buffer never grows past `FLUSH_ROWS` rows even when a single
    /// call delivers a much larger block.
    pub fn push_rows(&mut self, x: &[f32], rows: usize) {
        let dim = self.dim();
        assert_eq!(x.len(), rows * dim, "push_rows: row block size mismatch");
        let cap = FLUSH_ROWS * dim.max(1);
        for r in 0..rows {
            for (i, &v) in x[r * dim..(r + 1) * dim].iter().enumerate() {
                let v = v as f64;
                self.abs_sum[i] += v.abs();
                self.pending.push(v);
            }
            if self.pending.len() >= cap {
                self.flush();
            }
        }
        self.rows += rows;
    }

    /// Flush buffered rows into the Gram's upper triangle via the packed
    /// SYRK kernel, parallel over the calling thread's GEMM worker share
    /// (bit-identical at every worker count).
    pub fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let dim = self.dim();
        let rows = self.pending.len() / dim;
        gemm::syrk_tn(dim, rows, &self.pending, &mut self.gram.data, gemm::workers());
        self.pending.clear();
    }

    /// Flush pending rows and mirror the upper triangle down, making
    /// `gram` the full symmetric `XᵀX`.  Idempotent; must run before the
    /// Gram is consumed.
    pub fn finalize(&mut self) {
        self.flush();
        let n = self.dim();
        for i in 0..n {
            for j in (i + 1)..n {
                let v = self.gram[(i, j)];
                self.gram[(j, i)] = v;
            }
        }
    }

    /// Merge another accumulator by reference (clones the other side; kept
    /// for callers that cannot give up ownership — the fan-in path uses
    /// [`CalibStats::merge_from`]).
    pub fn merge(&mut self, other: &CalibStats) {
        self.merge_from(other.clone());
    }

    /// Owned merge — the no-clone calibration fan-in path
    /// ([`crate::calib::collector::TapStats::merge`] moves vacant entries
    /// wholesale and calls this for occupied ones, so nothing is cloned
    /// either way).  Grams and abs-sums add; pending row buffers
    /// concatenate (`self` rows first; flushed on the next
    /// flush/finalize).
    pub fn merge_from(&mut self, other: CalibStats) {
        assert_eq!(self.dim(), other.dim());
        self.gram = &self.gram + &other.gram;
        for (a, b) in self.abs_sum.iter_mut().zip(&other.abs_sum) {
            *a += b;
        }
        self.rows += other.rows;
        if self.pending.is_empty() {
            self.pending = other.pending;
        } else {
            self.pending.extend_from_slice(&other.pending);
        }
    }

    /// Per-dimension mean absolute activation (the ASVD-0 scale).
    pub fn abs_mean(&self) -> Vec<f64> {
        let r = self.rows.max(1) as f64;
        self.abs_sum.iter().map(|&s| s / r).collect()
    }

    /// RMS activation profile `√(diag(G)/rows)` — the similarity feature
    /// used for Table 2 / Figure 1.
    pub fn rms_profile(&self) -> Vec<f64> {
        let r = self.rows.max(1) as f64;
        self.gram.diagonal().iter().map(|&d| (d.max(0.0) / r).sqrt()).collect()
    }
}

/// A whitening transform.
pub enum Whitener {
    Identity,
    /// diag scale s (clamped away from zero) and its reciprocal.
    Diag { s: Vec<f64> },
    /// Lower-triangular Cholesky factor and the ridge that was added.
    Chol { l: Matrix, ridge: f64 },
    /// Spectral decomposition of the Gram.
    Eig { eig: SymEig },
    /// ASVD-III: rotation P scaled by γ = max eigenvalue^{1/2}.
    EigGamma { eig: SymEig, gamma: f64 },
}

impl Whitener {
    /// Build the whitener required by a method from calibration stats.
    pub fn identity() -> Whitener {
        Whitener::Identity
    }

    /// ASVD-0 whitener: `S = diag(mean |xᵢ|)` from the calibration profile.
    ///
    /// ```
    /// use nsvd::compress::whiten::{CalibStats, Whitener};
    /// use nsvd::linalg::Matrix;
    ///
    /// let mut stats = CalibStats::new(2);
    /// stats.abs_sum = vec![4.0, 1.0]; // dim 0 fires 4× harder
    /// stats.rows = 2;
    /// let w = Whitener::diag(&stats);
    /// // Whitening scales each input column by its mean |activation|.
    /// let aw = w.whiten(&Matrix::identity(2));
    /// assert!((aw[(0, 0)] - 2.0).abs() < 1e-12);
    /// assert!((aw[(1, 1)] - 0.5).abs() < 1e-12);
    /// // unwhiten ∘ whiten is the identity (S is invertible).
    /// assert!(w.unwhiten_rows(&aw).dist(&Matrix::identity(2)) < 1e-12);
    /// ```
    pub fn diag(stats: &CalibStats) -> Whitener {
        let mut s = stats.abs_mean();
        // Clamp: a dimension never activated in calibration must not blow up
        // the inverse scale (same guard ASVD uses).
        let max = s.iter().cloned().fold(0.0, f64::max).max(1e-12);
        for v in s.iter_mut() {
            *v = v.max(1e-6 * max);
        }
        Whitener::Diag { s }
    }

    pub fn cholesky(stats: &CalibStats) -> Whitener {
        let (l, ridge) = cholesky_psd(&stats.gram, 1e-8);
        Whitener::Chol { l, ridge }
    }

    pub fn eigen(stats: &CalibStats) -> Whitener {
        Whitener::Eig { eig: sym_eig(&stats.gram) }
    }

    pub fn eigen_gamma(stats: &CalibStats) -> Whitener {
        let eig = sym_eig(&stats.gram);
        let gamma = eig.values.first().copied().unwrap_or(0.0).max(1e-30).sqrt();
        Whitener::EigGamma { eig, gamma }
    }

    /// `A S` — the whitened matrix handed to the SVD (A is m×n, S n×n).
    pub fn whiten(&self, a: &Matrix) -> Matrix {
        match self {
            Whitener::Identity => a.clone(),
            Whitener::Diag { s } => a.scale_cols(s),
            Whitener::Chol { l, .. } => a.matmul(l),
            Whitener::Eig { eig } => a.matmul(&eig.sqrt_factor()),
            Whitener::EigGamma { eig, gamma } => a.matmul(&eig.vectors).scale(*gamma),
        }
    }

    /// Given the truncated right factor `Vᵀ_k` of the whitened matrix
    /// (k×n, rows = right singular vectors), return `Vᵀ_k S⁻¹` — the
    /// un-whitened right factor of the approximation of A.
    pub fn unwhiten_rows(&self, vt: &Matrix) -> Matrix {
        match self {
            Whitener::Identity => vt.clone(),
            Whitener::Diag { s } => {
                let inv: Vec<f64> = s.iter().map(|&x| 1.0 / x).collect();
                vt.scale_cols(&inv)
            }
            Whitener::Chol { l, .. } => vt.matmul(&invert_lower(l)),
            // Tolerance matched to the Cholesky ridge scale (1e-8·mean diag):
            // eigendirections carrying less relative mass are null-space, not
            // signal — inverting them amplifies calibration noise into the
            // un-whitened factors (visible as OOD perplexity blow-ups).
            Whitener::Eig { eig } => vt.matmul(&eig.sqrt_factor_pinv(1e-8)),
            Whitener::EigGamma { eig, gamma } => {
                vt.matmul(&eig.vectors.transpose()).scale(1.0 / gamma)
            }
        }
    }

    /// `‖S‖²_F = tr(S·Sᵀ)` of the n×n whitening transform, in closed form
    /// (no materialization): `n` for identity, `Σsᵢ²` for diag, `‖L‖²_F =
    /// tr(G + ridge·I)` for Cholesky, `Σ λ₊` for eigen, `γ²·n` for the
    /// γ-scaled rotation (P orthogonal).  Used by the per-layer α tune to
    /// put activation-weighted and plain residual energies in the same
    /// units without an O(n³) `whiten(I)` product.
    pub fn fro_norm_sq(&self, n: usize) -> f64 {
        match self {
            Whitener::Identity => n as f64,
            Whitener::Diag { s } => s.iter().map(|x| x * x).sum(),
            Whitener::Chol { l, .. } => l.fro_norm().powi(2),
            Whitener::Eig { eig } => eig.values.iter().map(|&v| v.max(0.0)).sum(),
            Whitener::EigGamma { gamma, .. } => gamma * gamma * n as f64,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Whitener::Identity => "identity",
            Whitener::Diag { .. } => "diag(abs-mean)",
            Whitener::Chol { .. } => "cholesky",
            Whitener::Eig { .. } => "eigen",
            Whitener::EigGamma { .. } => "eigen-gamma",
        }
    }
}

/// Activation-weighted squared loss `‖E·X‖²_F = tr(E G Eᵀ)` where E = A - Ã
/// is in the paper's row convention (E is m×n, G = XXᵀ is n×n).
pub fn activation_loss_sq(err: &Matrix, gram: &Matrix) -> f64 {
    let eg = err.matmul(gram);
    eg.data.iter().zip(&err.data).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd_thin;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ok(cond: bool, what: &str) -> Result<(), String> {
        if cond {
            Ok(())
        } else {
            Err(what.to_string())
        }
    }

    /// Full-rank calibration stats from random activations; returns (stats, X).
    fn random_stats(n: usize, samples: usize, rng: &mut Rng) -> (CalibStats, Matrix) {
        let x = Matrix::randn(samples, n, 1.0, rng); // rows = activations
        let mut stats = CalibStats::new(n);
        // XᵀX in row convention = paper's XXᵀ, via the SYRK kernel.
        stats.gram = x.gram();
        for i in 0..samples {
            for j in 0..n {
                stats.abs_sum[j] += x[(i, j)].abs();
            }
        }
        stats.rows = samples;
        (stats, x)
    }

    #[test]
    fn merge_accumulates() {
        let mut rng = Rng::new(1);
        let (mut s1, _) = random_stats(6, 20, &mut rng);
        let (s2, _) = random_stats(6, 30, &mut rng);
        let g1 = s1.gram.clone();
        s1.merge(&s2);
        assert_eq!(s1.rows, 50);
        assert!((&s1.gram - &g1).dist(&s2.gram) < 1e-12);
    }

    #[test]
    fn push_rows_flush_finalize_matches_direct_gram() {
        let mut rng = Rng::new(5);
        let n = 9;
        let rows = 700; // > 2×FLUSH_ROWS: exercises the periodic flushes
        let xf: Vec<f32> = (0..rows * n).map(|_| rng.normal() as f32).collect();
        let mut stats = CalibStats::new(n);
        stats.push_rows(&xf[..300 * n], 300);
        stats.push_rows(&xf[300 * n..], rows - 300);
        stats.finalize();
        let x = Matrix::from_f32(rows, n, &xf);
        let want = x.gram();
        assert_eq!(stats.rows, rows);
        assert!(stats.gram.dist(&want) < 1e-9 * (1.0 + want.fro_norm()));
        // Finalize leaves an exactly symmetric Gram and is idempotent.
        for i in 0..n {
            for j in 0..n {
                assert_eq!(stats.gram[(i, j)], stats.gram[(j, i)]);
            }
        }
        let g = stats.gram.clone();
        stats.finalize();
        assert_eq!(stats.gram.data, g.data);
    }

    #[test]
    fn whiten_unwhiten_roundtrip_identity() {
        // For any whitener W: unwhiten_rows(whiten(A) 's Vᵀ) must satisfy
        // U Σ (Vᵀ S⁻¹) = A when no truncation happens (full rank).
        check("UΣVᵀS⁻¹ = A (no truncation)", 12, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(4, 12);
            let n = g.usize_in(4, 12);
            let (stats, _) = random_stats(n, n + 8, &mut rng); // full-rank gram
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            for w in [
                Whitener::identity(),
                Whitener::diag(&stats),
                Whitener::cholesky(&stats),
                Whitener::eigen(&stats),
                Whitener::eigen_gamma(&stats),
            ] {
                let aw = w.whiten(&a);
                let svd = svd_thin(&aw);
                let vt = svd.v.transpose();
                let right = w.unwhiten_rows(&vt);
                let recon = svd.u.scale_cols(&svd.s).matmul(&right);
                ok(
                    recon.dist(&a) < 1e-6 * (1.0 + a.fro_norm()),
                    &format!("{} roundtrip", w.kind()),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn theorem2_truncation_loss_equals_sigma_tail() {
        // ASVD-I/II core claim: with S from the Gram, the activation-weighted
        // loss of rank-k truncation equals √(Σ_{i>k} σᵢ²) of AS.
        check("‖(A-Ã)X‖_F = tail(σ)", 10, |g| {
            let mut rng = g.rng.fork(0);
            let m = g.usize_in(4, 10);
            let n = g.usize_in(4, 10);
            let (stats, _x) = random_stats(n, n + 10, &mut rng);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let k = g.usize_in(1, m.min(n));
            for w in [Whitener::cholesky(&stats), Whitener::eigen(&stats)] {
                let aw = w.whiten(&a);
                let svd = svd_thin(&aw);
                let trunc = svd.truncate(k);
                let right = w.unwhiten_rows(&trunc.v.transpose());
                let a_tilde = trunc.u.scale_cols(&trunc.s).matmul(&right);
                let err = &a - &a_tilde;
                let loss = activation_loss_sq(&err, &stats.gram).max(0.0).sqrt();
                let tail = svd.tail_norm(k);
                // Cholesky adds a tiny ridge → tolerance scaled to norms.
                let tol = 1e-4 * (1.0 + svd.s[0]);
                ok(
                    (loss - tail).abs() < tol,
                    &format!("{}: loss={loss:.6} tail={tail:.6}", w.kind()),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn chol_and_eig_give_equivalent_approximations() {
        // Theorem 3(ii): ASVD-I and ASVD-II are equivalent on full-rank X.
        let mut rng = Rng::new(2);
        let (stats, _) = random_stats(8, 40, &mut rng);
        let a = Matrix::randn(6, 8, 1.0, &mut rng);
        let k = 3;
        let mut recons = Vec::new();
        for w in [Whitener::cholesky(&stats), Whitener::eigen(&stats)] {
            let aw = w.whiten(&a);
            let svd = svd_thin(&aw).truncate(k);
            let right = w.unwhiten_rows(&svd.v.transpose());
            recons.push(svd.u.scale_cols(&svd.s).matmul(&right));
        }
        assert!(
            recons[0].dist(&recons[1]) < 1e-4 * (1.0 + recons[0].fro_norm()),
            "chol vs eig dist {}",
            recons[0].dist(&recons[1])
        );
    }

    #[test]
    fn eig_handles_rank_deficient_gram() {
        // Calibration with fewer samples than dims: Gram is singular.  ASVD-II
        // must still work (pseudo-inverse); ASVD-I needs its ridge.
        let mut rng = Rng::new(3);
        let (stats, _) = random_stats(10, 4, &mut rng); // rank ≤ 4
        let a = Matrix::randn(5, 10, 1.0, &mut rng);
        let w = Whitener::eigen(&stats);
        let aw = w.whiten(&a);
        let svd = svd_thin(&aw).truncate(3);
        let right = w.unwhiten_rows(&svd.v.transpose());
        let recon = svd.u.scale_cols(&svd.s).matmul(&right);
        assert!(recon.data.iter().all(|v| v.is_finite()));
        let wc = Whitener::cholesky(&stats);
        if let Whitener::Chol { ridge, .. } = &wc {
            assert!(*ridge > 0.0, "ridge must engage on singular gram");
        }
    }

    #[test]
    fn diag_whitener_clamps_dead_dimensions() {
        let mut stats = CalibStats::new(4);
        stats.rows = 10;
        stats.abs_sum = vec![10.0, 0.0, 5.0, 20.0]; // dim 1 never fires
        let w = Whitener::diag(&stats);
        if let Whitener::Diag { s } = &w {
            assert!(s[1] > 0.0);
        }
        let a = Matrix::identity(4);
        let aw = w.whiten(&a);
        assert!(aw.data.iter().all(|v| v.is_finite()));
        let back = w.unwhiten_rows(&aw);
        assert!(back.dist(&Matrix::identity(4)) < 1e-9);
    }

    #[test]
    fn fro_norm_sq_matches_materialized_transform() {
        let mut rng = Rng::new(6);
        let n = 7;
        let (stats, _) = random_stats(n, n + 12, &mut rng);
        for w in [
            Whitener::identity(),
            Whitener::diag(&stats),
            Whitener::cholesky(&stats),
            Whitener::eigen(&stats),
            Whitener::eigen_gamma(&stats),
        ] {
            let direct = w.whiten(&Matrix::identity(n)).fro_norm().powi(2);
            let closed = w.fro_norm_sq(n);
            assert!(
                (direct - closed).abs() < 1e-9 * (1.0 + direct),
                "{}: materialized {direct} vs closed form {closed}",
                w.kind()
            );
        }
    }

    #[test]
    fn activation_loss_matches_direct_computation() {
        let mut rng = Rng::new(4);
        let x = Matrix::randn(30, 6, 1.0, &mut rng);
        let gram = x.matmul_tn(&x);
        let e = Matrix::randn(4, 6, 1.0, &mut rng);
        // Direct: ‖E Xᵀ‖²_F (paper's EX with X = n×p = xᵀ).
        let ext = e.matmul_nt(&x);
        let direct = ext.fro_norm().powi(2);
        let via_gram = activation_loss_sq(&e, &gram);
        assert!((direct - via_gram).abs() < 1e-6 * (1.0 + direct));
    }
}
