//! Parallel sharded compression engine.
//!
//! The paper's pipeline decomposes every linear layer *independently* — the
//! only shared state is the per-tap whitener, which depends on (method
//! class, calibration Gram) and nothing else.  The engine exploits exactly
//! that structure:
//!
//! 1. **whitener phase** — the distinct taps a model needs are computed
//!    once each (fanned out over the worker pool: eigendecomposition /
//!    Cholesky of a `d_ff`-sized Gram is seconds of work) and published
//!    read-only behind [`Arc`]s;
//! 2. **shard phase** — the layer jobs fan out over scoped worker threads
//!    with dynamic scheduling ([`parallel_map_dynamic`]): workers claim the
//!    next unprocessed layer, so heterogeneous layer costs (d_ff MLP
//!    weights vs d_model attention weights) and worker counts that don't
//!    divide the layer count still keep every core busy; each job runs
//!    with the shared whiteners and the configured [`SvdPolicy`];
//! 3. **assembly** — results come back in deterministic layer order and are
//!    folded into a [`CompressedModel`].
//!
//! Every per-layer decomposition is a pure function of `(weight, whitener,
//! spec, plan, policy)`, so the output is **identical for any worker
//! count** — `workers = 1` reproduces the historical serial loop
//! bit-for-bit (pinned by `sharded_engine_matches_serial_loop` below).
//!
//! The whitener cache is keyed `(whitener kind, tap)` and owned by the
//! caller, so ratio/α sweeps across jobs still pay zero whitening cost —
//! the same contract the serial pipeline had, now `Send`-safe via [`Arc`].
//!
//! Global rank allocation rides the same phases: [`CompressionEngine::profile_spectra`]
//! fans the per-layer whitened-spectrum jobs over the pool,
//! [`CompressionEngine::plan_model`] turns the profiles into per-layer
//! [`RankPlan`]s (the cross-layer water-filling itself is serial and
//! deterministic — see [`crate::compress::allocate`]), and
//! [`CompressionEngine::compress_model_planned`] decomposes under those
//! plans.  [`CompressionEngine::compress_model`] is the uniform-protocol
//! wrapper and stays bit-identical to the pre-allocator engine.
//!
//! Threading: the engine owns ONE [`ThreadBudget`] and splits it between
//! the layer fan-out and the parallel GEMM kernel each job's whitening /
//! SVD math runs on (`outer × inner ≤ total`) — nesting two independent
//! pools would oversubscribe the machine.  Since the GEMM kernel is
//! bit-identical for every worker count, the split never affects results.

use crate::calib::collector::TapStats;
use crate::compress::allocate::{self, AllocConfig, AllocStrategy, LayerProfile};
use crate::compress::lowrank::CompressedModel;
use crate::compress::methods::{compress_layer_with_policy, CompressionSpec};
use crate::compress::ranks::{self, RankPlan};
use crate::compress::whiten::{CalibStats, Whitener};
use crate::linalg::rsvd::SvdPolicy;
use crate::model::config::ModelConfig;
use crate::model::weights::{Tensor, Weights};
use crate::linalg::gemm;
use crate::util::threads::{parallel_map_dynamic, ThreadBudget};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Whitener cache shared across sweep jobs: `(whitener kind, tap)` →
/// read-only whitener.  `Arc` (not `Rc`) so shards on other threads can
/// hold it.
pub type WhitenerCache = HashMap<(String, String), Arc<Whitener>>;

/// Engine knobs, threaded from the CLI through `PipelineConfig`.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads for whitening + decomposition; `0` = all cores.
    pub workers: usize,
    /// Truncated-SVD policy applied to every stage-1/stage-2 decomposition.
    pub svd: SvdPolicy,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig { workers: 0, svd: SvdPolicy::exact() }
    }
}

impl EngineConfig {
    /// Resolve `workers = 0` to the machine's available parallelism
    /// (same resolution as [`EngineConfig::thread_budget`]).
    pub fn effective_workers(&self) -> usize {
        self.thread_budget().total()
    }

    /// The engine's one thread budget, split between the layer fan-out and
    /// the parallel GEMMs inside each job (see [`ThreadBudget`]) — nesting
    /// two pools would oversubscribe the machine.
    pub fn thread_budget(&self) -> ThreadBudget {
        ThreadBudget::new(self.workers)
    }
}

/// One per-layer decomposition job (borrowed weight, shared whitener).
struct LayerJob<'a> {
    name: &'a str,
    tensor: &'a Tensor,
    whitener: Arc<Whitener>,
    plan: RankPlan,
}

/// The sharded compression engine.  Stateless apart from its config; all
/// model state is borrowed per call so one engine can serve many sweeps.
pub struct CompressionEngine {
    pub config: EngineConfig,
}

impl CompressionEngine {
    pub fn new(config: EngineConfig) -> CompressionEngine {
        CompressionEngine { config }
    }

    /// Decompose every compressible weight of `model_cfg` under `spec`,
    /// fanning layer shards out over the worker pool.  `cache` carries
    /// whiteners across calls (ratio/α sweeps reuse them for free).
    ///
    /// Uses the paper's uniform per-layer rank protocol
    /// ([`allocate::uniform_plans`]) — bit-identical to the pre-allocator
    /// engine.  Globally allocated or α-tuned plans go through
    /// [`CompressionEngine::plan_model`] +
    /// [`CompressionEngine::compress_model_planned`].
    pub fn compress_model(
        &self,
        model_cfg: &ModelConfig,
        weights: &Weights,
        stats: &TapStats,
        spec: &CompressionSpec,
        cache: &mut WhitenerCache,
    ) -> Result<CompressedModel> {
        let plans =
            allocate::uniform_plans(&model_cfg.linear_shapes, spec.ratio, spec.effective_alpha());
        self.compress_model_planned(model_cfg, weights, stats, spec, &plans, cache)
    }

    /// Phase 1: make sure `cache` holds one whitener per distinct tap of
    /// `model_cfg` for `spec.method`'s whitener class, building missing
    /// ones in parallel over the engine pool.
    fn ensure_whiteners(
        &self,
        model_cfg: &ModelConfig,
        stats: &TapStats,
        spec: &CompressionSpec,
        cache: &mut WhitenerCache,
    ) -> Result<()> {
        let budget = self.config.thread_budget();
        let kind = spec.method.whitener_kind().to_string();
        let mut missing: Vec<(String, &CalibStats)> = Vec::new();
        for (name, _, _) in &model_cfg.linear_shapes {
            let tap = ModelConfig::tap_for_linear(name);
            let key = (kind.clone(), tap.clone());
            if cache.contains_key(&key) || missing.iter().any(|(t, _)| *t == tap) {
                continue;
            }
            let tap_stats = stats
                .taps
                .get(&tap)
                .ok_or_else(|| anyhow::anyhow!("no calibration stats for {name} (tap {tap})"))?;
            missing.push((tap, tap_stats));
        }
        let method = spec.method;
        // One budget, two levels: `outer` whitener jobs in flight, each
        // handing `inner` threads to the GEMMs under its eigen/Cholesky
        // math (the knob is thread-local, so it is set inside the job).
        let (outer, inner) = budget.split(missing.len());
        let built = parallel_map_dynamic(&missing, outer, |_, pair| {
            let _gemm_threads = gemm::scoped_workers(inner);
            let mut sp = crate::obs::span("engine.whiten");
            if sp.is_recording() {
                sp.arg_str("tap", &pair.0);
            }
            Arc::new(method.stage1_whitener(pair.1))
        });
        for ((tap, _), whitener) in missing.into_iter().zip(built) {
            cache.insert((kind.clone(), tap), whitener);
        }
        Ok(())
    }

    /// The whitener for `name` under `spec.method`'s class; phase 1 must
    /// have populated the cache.
    fn whitener_for(
        spec: &CompressionSpec,
        cache: &WhitenerCache,
        name: &str,
    ) -> Arc<Whitener> {
        let tap = ModelConfig::tap_for_linear(name);
        cache
            .get(&(spec.method.whitener_kind().to_string(), tap))
            .expect("ensure_whiteners populated every tap")
            .clone()
    }

    /// Decompose every layer with an explicit per-layer [`RankPlan`]
    /// (aligned with `model_cfg.linear_shapes`) — the planned entry point
    /// the global allocator feeds.  [`CompressionEngine::compress_model`]
    /// is this with the uniform plans.
    pub fn compress_model_planned(
        &self,
        model_cfg: &ModelConfig,
        weights: &Weights,
        stats: &TapStats,
        spec: &CompressionSpec,
        plans: &[RankPlan],
        cache: &mut WhitenerCache,
    ) -> Result<CompressedModel> {
        anyhow::ensure!(
            plans.len() == model_cfg.linear_shapes.len(),
            "plan count {} != layer count {}",
            plans.len(),
            model_cfg.linear_shapes.len()
        );
        let budget = self.config.thread_budget();
        let mut outer_sp = crate::obs::span("engine.compress_model");
        if outer_sp.is_recording() {
            outer_sp
                .arg_u64("layers", model_cfg.linear_shapes.len() as u64)
                .arg_u64("workers", budget.total() as u64);
        }
        self.ensure_whiteners(model_cfg, stats, spec, cache)?;

        // ---- Phase 2: shard the layer jobs across the workers ----
        let mut jobs: Vec<LayerJob> = Vec::with_capacity(model_cfg.linear_shapes.len());
        for ((name, _, _), plan) in model_cfg.linear_shapes.iter().zip(plans) {
            jobs.push(LayerJob {
                name: name.as_str(),
                tensor: weights.get(name)?,
                whitener: Self::whitener_for(spec, cache, name),
                plan: *plan,
            });
        }
        let spec = *spec;
        let svd = &self.config.svd;
        // Same split for the layer shards: outer × inner ≤ budget.total().
        let (outer, inner) = budget.split(jobs.len());
        let results = parallel_map_dynamic(&jobs, outer, |_, job| {
            let _gemm_threads = gemm::scoped_workers(inner);
            let mut sp = crate::obs::span("engine.decompose_layer");
            if sp.is_recording() {
                sp.arg_str("layer", job.name)
                    .arg_u64("k1", job.plan.k1 as u64)
                    .arg_u64("k2", job.plan.k2 as u64);
            }
            compress_layer_with_policy(job.tensor, &job.whitener, &spec, &job.plan, svd)
                .with_context(|| format!("compressing {}", job.name))
        });

        // ---- Phase 3: deterministic assembly (order preserved by the map) ----
        let mut cm = CompressedModel::default();
        for (job, layer) in jobs.iter().zip(results) {
            cm.insert(job.name, layer?);
        }
        Ok(cm)
    }

    /// Profile every layer's whitened singular spectrum `σ(A·S)` in
    /// parallel over the engine pool — the (pure, per-layer) first phase of
    /// global allocation.  Profiles come back in `linear_shapes` order and
    /// are identical at every worker count.
    pub fn profile_spectra(
        &self,
        model_cfg: &ModelConfig,
        weights: &Weights,
        stats: &TapStats,
        spec: &CompressionSpec,
        cache: &mut WhitenerCache,
    ) -> Result<Vec<LayerProfile>> {
        let budget = self.config.thread_budget();
        self.ensure_whiteners(model_cfg, stats, spec, cache)?;
        let mut jobs: Vec<(&str, &Tensor, Arc<Whitener>, usize, usize)> =
            Vec::with_capacity(model_cfg.linear_shapes.len());
        for (name, n_in, n_out) in &model_cfg.linear_shapes {
            jobs.push((
                name.as_str(),
                weights.get(name)?,
                Self::whitener_for(spec, cache, name),
                *n_out, // paper-convention m
                *n_in,  // paper-convention n
            ));
        }
        let mut outer_sp = crate::obs::span("engine.profile_spectra");
        if outer_sp.is_recording() {
            outer_sp.arg_u64("layers", jobs.len() as u64);
        }
        let (outer, inner) = budget.split(jobs.len());
        let spectra = parallel_map_dynamic(&jobs, outer, |_, job| {
            let _gemm_threads = gemm::scoped_workers(inner);
            let mut sp = crate::obs::span("engine.profile");
            if sp.is_recording() {
                sp.arg_str("layer", job.0);
            }
            allocate::whitened_spectrum(job.1, &job.2)
        });
        Ok(jobs
            .iter()
            .zip(spectra)
            .map(|(job, spectrum)| LayerProfile {
                name: job.0.to_string(),
                m: job.3,
                n: job.4,
                spectrum,
            })
            .collect())
    }

    /// Produce the per-layer [`RankPlan`]s for `spec` under `alloc`:
    ///
    /// * total ranks — uniform per-layer budgets, or the global
    ///   spectrum-driven allocation (profile in parallel, then
    ///   [`allocate::spectrum_ranks`] serially, so plans are identical at
    ///   every worker count);
    /// * splits — the fixed `spec` α, or the per-layer
    ///   [`allocate::tune_alpha`] mini-sweep (`alloc.alpha_auto`, nested
    ///   methods only), fanned out over the pool.
    pub fn plan_model(
        &self,
        model_cfg: &ModelConfig,
        weights: &Weights,
        stats: &TapStats,
        spec: &CompressionSpec,
        alloc: &AllocConfig,
        cache: &mut WhitenerCache,
    ) -> Result<Vec<RankPlan>> {
        self.plan_model_with_profiles(model_cfg, weights, stats, spec, alloc, None, cache)
    }

    /// [`CompressionEngine::plan_model`] with optionally pre-computed layer
    /// profiles.  Spectra depend only on `(weights, whitener kind)` — not
    /// on the ratio — so callers sweeping budgets (the pipeline's
    /// ratio-per-point sweep) profile once and pass `Some(profiles)` to
    /// every point; `None` profiles on the spot (spectrum strategy only).
    pub fn plan_model_with_profiles(
        &self,
        model_cfg: &ModelConfig,
        weights: &Weights,
        stats: &TapStats,
        spec: &CompressionSpec,
        alloc: &AllocConfig,
        profiles: Option<&[LayerProfile]>,
        cache: &mut WhitenerCache,
    ) -> Result<Vec<RankPlan>> {
        let budget = self.config.thread_budget();
        let _alloc_sp = crate::obs::span("engine.allocate");
        self.ensure_whiteners(model_cfg, stats, spec, cache)?;
        let ks: Vec<usize> = match alloc.strategy {
            AllocStrategy::Uniform => model_cfg
                .linear_shapes
                .iter()
                .map(|(_, n_in, n_out)| ranks::k_budget(*n_out, *n_in, spec.ratio))
                .collect(),
            AllocStrategy::Spectrum => match profiles {
                Some(p) => allocate::spectrum_ranks(p, spec.ratio, alloc.k_caps.as_deref()),
                None => {
                    let p = self.profile_spectra(model_cfg, weights, stats, spec, cache)?;
                    allocate::spectrum_ranks(&p, spec.ratio, alloc.k_caps.as_deref())
                }
            },
        };
        if !(alloc.alpha_auto && spec.method.is_nested()) {
            let alpha = spec.effective_alpha();
            return Ok(ks.iter().map(|&k| ranks::split_k(k, alpha)).collect());
        }
        // Per-layer α tune: pure per-layer jobs over the same pool.
        let mut jobs: Vec<(&str, &Tensor, Arc<Whitener>, usize)> =
            Vec::with_capacity(model_cfg.linear_shapes.len());
        for ((name, _, _), &k) in model_cfg.linear_shapes.iter().zip(&ks) {
            jobs.push((name.as_str(), weights.get(name)?, Self::whitener_for(spec, cache, name), k));
        }
        let (outer, inner) = budget.split(jobs.len());
        let svd = &self.config.svd;
        let (method, ratio) = (spec.method, spec.ratio);
        let tuned = parallel_map_dynamic(&jobs, outer, |_, job| {
            let _gemm_threads = gemm::scoped_workers(inner);
            let mut sp = crate::obs::span("engine.tune_alpha");
            if sp.is_recording() {
                sp.arg_str("layer", job.0).arg_u64("k", job.3 as u64);
            }
            allocate::tune_alpha(job.1, &job.2, method, ratio, job.3, svd)
                .with_context(|| format!("tuning α for {}", job.0))
        });
        tuned.into_iter().collect()
    }
}

/// The historical serial loop, kept as the engine's differential-testing
/// reference: per-tap whitener cache, one layer at a time, exact Jacobi.
/// `compress_model` with any worker count and [`SvdPolicy::exact`] must
/// reproduce this bit-for-bit (pinned by the tests below and by
/// `benches/perf_decompose.rs`, which also times the two against each
/// other).
pub fn compress_model_serial(
    model_cfg: &ModelConfig,
    weights: &Weights,
    stats: &TapStats,
    spec: &CompressionSpec,
) -> Result<CompressedModel> {
    let mut whiteners: HashMap<String, Whitener> = HashMap::new();
    let mut cm = CompressedModel::default();
    for (name, n_in, n_out) in &model_cfg.linear_shapes {
        let tap = ModelConfig::tap_for_linear(name);
        let tap_stats = stats
            .taps
            .get(&tap)
            .ok_or_else(|| anyhow::anyhow!("no calibration stats for {name} (tap {tap})"))?;
        let whitener = whiteners
            .entry(tap)
            .or_insert_with(|| spec.method.stage1_whitener(tap_stats));
        let plan = ranks::plan(*n_out, *n_in, spec.ratio, spec.effective_alpha());
        let layer = crate::compress::methods::compress_layer_with(
            weights.get(name)?,
            whitener,
            spec,
            &plan,
        )
        .with_context(|| format!("compressing {name}"))?;
        cm.insert(name, layer);
    }
    Ok(cm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::methods::Method;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Rng;

    /// A 2-block llama-style toy model small enough for exhaustive checks.
    fn tiny_model(rng: &mut Rng) -> (ModelConfig, Weights, TapStats) {
        let (d, f, blocks) = (10usize, 14usize, 2usize);
        let mut linear_shapes = Vec::new();
        for i in 0..blocks {
            for leaf in ["wq", "wk", "wv", "wo"] {
                linear_shapes.push((format!("blocks.{i}.attn.{leaf}"), d, d));
            }
            linear_shapes.push((format!("blocks.{i}.mlp.w_gate"), d, f));
            linear_shapes.push((format!("blocks.{i}.mlp.w_up"), d, f));
            linear_shapes.push((format!("blocks.{i}.mlp.w_down"), f, d));
        }
        linear_shapes.sort_by(|a, b| a.0.cmp(&b.0));
        let cfg = ModelConfig {
            name: "tiny".into(),
            family: crate::model::config::Family::Llama,
            arch: "tiny".into(),
            d_model: d,
            n_layers: blocks,
            n_heads: 2,
            d_ff: f,
            max_seq: 16,
            window: 0,
            vocab: 32,
            linear_shapes,
        };
        let mut weights = Weights::default();
        for (name, n_in, n_out) in &cfg.linear_shapes {
            weights.tensors.insert(
                name.clone(),
                Tensor {
                    dims: vec![*n_in, *n_out],
                    data: Matrix::randn(*n_in, *n_out, 0.5, rng).to_f32(),
                },
            );
        }
        let mut stats = TapStats::default();
        for tap in cfg.tap_names() {
            let dim = if tap.ends_with("mlp_down_in") { f } else { d };
            let rows = 3 * dim;
            let x: Vec<f32> = (0..rows * dim).map(|_| rng.normal() as f32).collect();
            stats.accumulate(&tap, &x, rows, dim);
        }
        stats.finalize();
        (cfg, weights, stats)
    }

    fn assert_identical(a: &CompressedModel, b: &CompressedModel) {
        assert_eq!(a.layers.len(), b.layers.len());
        for (name, la) in &a.layers {
            let lb = b.get(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(la.p1, lb.p1, "{name} p1");
            assert_eq!(la.q1, lb.q1, "{name} q1");
            assert_eq!(la.p2, lb.p2, "{name} p2");
            assert_eq!(la.q2, lb.q2, "{name} q2");
        }
    }

    #[test]
    fn sharded_engine_matches_serial_loop() {
        let mut rng = Rng::new(21);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        // α = 0.8 so k₂ > 0 at toy dimensions — stage 2 must shard too.
        for method in [Method::NsvdI, Method::AsvdII, Method::NidI] {
            let spec = CompressionSpec { method, ratio: 0.3, alpha: 0.8 };
            let serial = compress_model_serial(&cfg, &weights, &stats, &spec).unwrap();
            for workers in [1usize, 4] {
                let engine = CompressionEngine::new(EngineConfig {
                    workers,
                    svd: SvdPolicy::exact(),
                });
                let mut cache = WhitenerCache::default();
                let sharded = engine
                    .compress_model(&cfg, &weights, &stats, &spec, &mut cache)
                    .unwrap();
                assert_identical(&serial, &sharded);
            }
        }
    }

    #[test]
    fn whitener_cache_is_reused_across_sweep_jobs() {
        let mut rng = Rng::new(22);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        let engine = CompressionEngine::new(EngineConfig { workers: 2, ..Default::default() });
        let mut cache = WhitenerCache::default();
        let spec1 = CompressionSpec { method: Method::NsvdI, ratio: 0.2, alpha: 0.9 };
        engine.compress_model(&cfg, &weights, &stats, &spec1, &mut cache).unwrap();
        // 4 taps per block × 2 blocks, but wq/wk/wv share attn_in → 8 taps.
        assert_eq!(cache.len(), 8);
        let snapshot: Vec<*const Whitener> =
            cache.values().map(|w| Arc::as_ptr(w)).collect();
        // A second job at a different ratio must reuse the same whiteners.
        let spec2 = CompressionSpec { method: Method::NsvdI, ratio: 0.4, alpha: 0.9 };
        engine.compress_model(&cfg, &weights, &stats, &spec2, &mut cache).unwrap();
        assert_eq!(cache.len(), 8);
        let after: Vec<*const Whitener> = cache.values().map(|w| Arc::as_ptr(w)).collect();
        assert_eq!(snapshot, after, "whiteners must not be rebuilt");
    }

    #[test]
    fn auto_policy_equals_exact_when_sketch_cannot_fit() {
        // At toy dimensions the auto gate (4k ≤ min(m,n)) never fires, so
        // auto and exact must agree bit-for-bit.
        let mut rng = Rng::new(23);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        let spec = CompressionSpec { method: Method::NsvdII, ratio: 0.3, alpha: 0.95 };
        let mut c1 = WhitenerCache::default();
        let mut c2 = WhitenerCache::default();
        let exact = CompressionEngine::new(EngineConfig { workers: 2, svd: SvdPolicy::exact() })
            .compress_model(&cfg, &weights, &stats, &spec, &mut c1)
            .unwrap();
        let auto = CompressionEngine::new(EngineConfig { workers: 2, svd: SvdPolicy::auto() })
            .compress_model(&cfg, &weights, &stats, &spec, &mut c2)
            .unwrap();
        assert_identical(&exact, &auto);
    }

    #[test]
    fn rsvd_engine_run_preserves_budget_and_finiteness() {
        let mut rng = Rng::new(24);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.3, alpha: 0.8 };
        let mut policy = SvdPolicy::randomized();
        policy.oversample = 2;
        policy.max_rel_err = Some(0.05);
        let engine = CompressionEngine::new(EngineConfig { workers: 3, svd: policy });
        let mut cache = WhitenerCache::default();
        let cm = engine.compress_model(&cfg, &weights, &stats, &spec, &mut cache).unwrap();
        let exact = compress_model_serial(&cfg, &weights, &stats, &spec).unwrap();
        assert_eq!(cm.params(), exact.params(), "like-for-like budget");
        for layer in cm.layers.values() {
            assert!(layer.p1.iter().all(|v| v.is_finite()));
            assert!(layer.q1.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn planned_uniform_is_bit_identical_to_compress_model() {
        let mut rng = Rng::new(26);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.3, alpha: 0.8 };
        let engine = CompressionEngine::new(EngineConfig { workers: 2, ..Default::default() });
        let plans = crate::compress::allocate::uniform_plans(
            &cfg.linear_shapes,
            spec.ratio,
            spec.effective_alpha(),
        );
        let mut c1 = WhitenerCache::default();
        let mut c2 = WhitenerCache::default();
        let direct = engine.compress_model(&cfg, &weights, &stats, &spec, &mut c1).unwrap();
        let planned = engine
            .compress_model_planned(&cfg, &weights, &stats, &spec, &plans, &mut c2)
            .unwrap();
        assert_identical(&direct, &planned);
    }

    #[test]
    fn spectrum_allocation_is_worker_independent_and_beats_uniform() {
        // The acceptance pin: on the tiny model, spectrum allocation at the
        // uniform parameter budget (i) spends no more parameters, (ii) has
        // total whitened tail error ≤ the uniform plan, and (iii) produces
        // bit-identical plans and factors at every worker count.
        let mut rng = Rng::new(27);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.4, alpha: 0.95 };
        let alloc = AllocConfig { strategy: AllocStrategy::Spectrum, ..Default::default() };

        let mut runs: Vec<(Vec<RankPlan>, CompressedModel)> = Vec::new();
        for workers in [1usize, 4] {
            let engine = CompressionEngine::new(EngineConfig {
                workers,
                svd: SvdPolicy::exact(),
            });
            let mut cache = WhitenerCache::default();
            let profiles =
                engine.profile_spectra(&cfg, &weights, &stats, &spec, &mut cache).unwrap();
            let plans =
                engine.plan_model(&cfg, &weights, &stats, &spec, &alloc, &mut cache).unwrap();
            let cm = engine
                .compress_model_planned(&cfg, &weights, &stats, &spec, &plans, &mut cache)
                .unwrap();

            // (i) like-for-like budget vs uniform.
            let mut c2 = WhitenerCache::default();
            let uniform =
                engine.compress_model(&cfg, &weights, &stats, &spec, &mut c2).unwrap();
            assert!(
                cm.params() <= uniform.params(),
                "spectrum {} params > uniform {}",
                cm.params(),
                uniform.params()
            );

            // (ii) total whitened tail error no worse than uniform.
            let ks: Vec<usize> = plans.iter().map(|p| p.k).collect();
            let uks: Vec<usize> = crate::compress::allocate::uniform_plans(
                &cfg.linear_shapes,
                spec.ratio,
                spec.effective_alpha(),
            )
            .iter()
            .map(|p| p.k)
            .collect();
            let ts = crate::compress::allocate::total_tail_sq(&profiles, &ks);
            let tu = crate::compress::allocate::total_tail_sq(&profiles, &uks);
            assert!(ts <= tu + 1e-12 * (1.0 + tu), "spectrum tail {ts} > uniform {tu}");

            runs.push((plans, cm));
        }
        // (iii) identical at every worker count.
        assert_eq!(runs[0].0, runs[1].0, "plans diverged across worker counts");
        assert_identical(&runs[0].1, &runs[1].1);
    }

    #[test]
    fn auto_alpha_allocation_is_deterministic_and_budget_exact() {
        let mut rng = Rng::new(28);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.3, alpha: 0.95 };
        let alloc = AllocConfig {
            strategy: AllocStrategy::Uniform,
            alpha_auto: true,
            k_caps: None,
        };
        let mut runs: Vec<Vec<RankPlan>> = Vec::new();
        for workers in [1usize, 4] {
            let engine = CompressionEngine::new(EngineConfig {
                workers,
                svd: SvdPolicy::exact(),
            });
            let mut cache = WhitenerCache::default();
            let plans =
                engine.plan_model(&cfg, &weights, &stats, &spec, &alloc, &mut cache).unwrap();
            // Auto-α keeps each layer's uniform total rank; only the split moves.
            for ((_, n_in, n_out), plan) in cfg.linear_shapes.iter().zip(&plans) {
                let uniform = ranks::plan(*n_out, *n_in, spec.ratio, spec.alpha);
                assert_eq!(plan.k, uniform.k, "auto-α must not change the total rank");
                assert_eq!(plan.k1 + plan.k2, plan.k);
                assert!(plan.k1 >= 1);
            }
            runs.push(plans);
        }
        assert_eq!(runs[0], runs[1], "auto-α plans diverged across worker counts");
    }

    #[test]
    fn missing_tap_stats_is_a_clean_error() {
        let mut rng = Rng::new(25);
        let (cfg, weights, _) = tiny_model(&mut rng);
        let engine = CompressionEngine::new(EngineConfig::default());
        let spec = CompressionSpec::new(Method::AsvdI, 0.3);
        let err = engine
            .compress_model(&cfg, &weights, &TapStats::default(), &spec, &mut Default::default())
            .unwrap_err();
        assert!(err.to_string().contains("no calibration stats"));
    }
}
