//! Parallel sharded compression engine.
//!
//! The paper's pipeline decomposes every linear layer *independently* — the
//! only shared state is the per-tap whitener, which depends on (method
//! class, calibration Gram) and nothing else.  The engine exploits exactly
//! that structure:
//!
//! 1. **whitener phase** — the distinct taps a model needs are computed
//!    once each (fanned out over the worker pool: eigendecomposition /
//!    Cholesky of a `d_ff`-sized Gram is seconds of work) and published
//!    read-only behind [`Arc`]s;
//! 2. **shard phase** — the layer jobs fan out over scoped worker threads
//!    with dynamic scheduling ([`parallel_map_dynamic`]): workers claim the
//!    next unprocessed layer, so heterogeneous layer costs (d_ff MLP
//!    weights vs d_model attention weights) and worker counts that don't
//!    divide the layer count still keep every core busy; each job runs
//!    with the shared whiteners and the configured [`SvdPolicy`];
//! 3. **assembly** — results come back in deterministic layer order and are
//!    folded into a [`CompressedModel`].
//!
//! Every per-layer decomposition is a pure function of `(weight, whitener,
//! spec, plan, policy)`, so the output is **identical for any worker
//! count** — `workers = 1` reproduces the historical serial loop
//! bit-for-bit (pinned by `sharded_engine_matches_serial_loop` below).
//!
//! The whitener cache is keyed `(whitener kind, tap)` and owned by the
//! caller, so ratio/α sweeps across jobs still pay zero whitening cost —
//! the same contract the serial pipeline had, now `Send`-safe via [`Arc`].
//!
//! Threading: the engine owns ONE [`ThreadBudget`] and splits it between
//! the layer fan-out and the parallel GEMM kernel each job's whitening /
//! SVD math runs on (`outer × inner ≤ total`) — nesting two independent
//! pools would oversubscribe the machine.  Since the GEMM kernel is
//! bit-identical for every worker count, the split never affects results.

use crate::calib::collector::TapStats;
use crate::compress::lowrank::CompressedModel;
use crate::compress::methods::{compress_layer_with_policy, CompressionSpec};
use crate::compress::ranks::{self, RankPlan};
use crate::compress::whiten::{CalibStats, Whitener};
use crate::linalg::rsvd::SvdPolicy;
use crate::model::config::ModelConfig;
use crate::model::weights::{Tensor, Weights};
use crate::linalg::gemm;
use crate::util::threads::{parallel_map_dynamic, ThreadBudget};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Whitener cache shared across sweep jobs: `(whitener kind, tap)` →
/// read-only whitener.  `Arc` (not `Rc`) so shards on other threads can
/// hold it.
pub type WhitenerCache = HashMap<(String, String), Arc<Whitener>>;

/// Engine knobs, threaded from the CLI through `PipelineConfig`.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads for whitening + decomposition; `0` = all cores.
    pub workers: usize,
    /// Truncated-SVD policy applied to every stage-1/stage-2 decomposition.
    pub svd: SvdPolicy,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig { workers: 0, svd: SvdPolicy::exact() }
    }
}

impl EngineConfig {
    /// Resolve `workers = 0` to the machine's available parallelism
    /// (same resolution as [`EngineConfig::thread_budget`]).
    pub fn effective_workers(&self) -> usize {
        self.thread_budget().total()
    }

    /// The engine's one thread budget, split between the layer fan-out and
    /// the parallel GEMMs inside each job (see [`ThreadBudget`]) — nesting
    /// two pools would oversubscribe the machine.
    pub fn thread_budget(&self) -> ThreadBudget {
        ThreadBudget::new(self.workers)
    }
}

/// One per-layer decomposition job (borrowed weight, shared whitener).
struct LayerJob<'a> {
    name: &'a str,
    tensor: &'a Tensor,
    whitener: Arc<Whitener>,
    plan: RankPlan,
}

/// The sharded compression engine.  Stateless apart from its config; all
/// model state is borrowed per call so one engine can serve many sweeps.
pub struct CompressionEngine {
    pub config: EngineConfig,
}

impl CompressionEngine {
    pub fn new(config: EngineConfig) -> CompressionEngine {
        CompressionEngine { config }
    }

    /// Decompose every compressible weight of `model_cfg` under `spec`,
    /// fanning layer shards out over the worker pool.  `cache` carries
    /// whiteners across calls (ratio/α sweeps reuse them for free).
    pub fn compress_model(
        &self,
        model_cfg: &ModelConfig,
        weights: &Weights,
        stats: &TapStats,
        spec: &CompressionSpec,
        cache: &mut WhitenerCache,
    ) -> Result<CompressedModel> {
        let budget = self.config.thread_budget();
        let kind = spec.method.whitener_kind().to_string();

        // ---- Phase 1: one whitener per distinct tap, in parallel ----
        let mut missing: Vec<(String, &CalibStats)> = Vec::new();
        for (name, _, _) in &model_cfg.linear_shapes {
            let tap = ModelConfig::tap_for_linear(name);
            let key = (kind.clone(), tap.clone());
            if cache.contains_key(&key) || missing.iter().any(|(t, _)| *t == tap) {
                continue;
            }
            let tap_stats = stats
                .taps
                .get(&tap)
                .ok_or_else(|| anyhow::anyhow!("no calibration stats for {name} (tap {tap})"))?;
            missing.push((tap, tap_stats));
        }
        let method = spec.method;
        // One budget, two levels: `outer` whitener jobs in flight, each
        // handing `inner` threads to the GEMMs under its eigen/Cholesky
        // math (the knob is thread-local, so it is set inside the job).
        let (outer, inner) = budget.split(missing.len());
        let built = parallel_map_dynamic(&missing, outer, |_, pair| {
            let _gemm_threads = gemm::scoped_workers(inner);
            Arc::new(method.stage1_whitener(pair.1))
        });
        for ((tap, _), whitener) in missing.into_iter().zip(built) {
            cache.insert((kind.clone(), tap), whitener);
        }

        // ---- Phase 2: shard the layer jobs across the workers ----
        let mut jobs: Vec<LayerJob> = Vec::with_capacity(model_cfg.linear_shapes.len());
        for (name, n_in, n_out) in &model_cfg.linear_shapes {
            let tap = ModelConfig::tap_for_linear(name);
            let whitener = cache
                .get(&(kind.clone(), tap))
                .expect("phase 1 populated every tap")
                .clone();
            jobs.push(LayerJob {
                name: name.as_str(),
                tensor: weights.get(name)?,
                whitener,
                plan: ranks::plan(*n_out, *n_in, spec.ratio, spec.effective_alpha()),
            });
        }
        let spec = *spec;
        let svd = &self.config.svd;
        // Same split for the layer shards: outer × inner ≤ budget.total().
        let (outer, inner) = budget.split(jobs.len());
        let results = parallel_map_dynamic(&jobs, outer, |_, job| {
            let _gemm_threads = gemm::scoped_workers(inner);
            compress_layer_with_policy(job.tensor, &job.whitener, &spec, &job.plan, svd)
                .with_context(|| format!("compressing {}", job.name))
        });

        // ---- Phase 3: deterministic assembly (order preserved by the map) ----
        let mut cm = CompressedModel::default();
        for (job, layer) in jobs.iter().zip(results) {
            cm.insert(job.name, layer?);
        }
        Ok(cm)
    }
}

/// The historical serial loop, kept as the engine's differential-testing
/// reference: per-tap whitener cache, one layer at a time, exact Jacobi.
/// `compress_model` with any worker count and [`SvdPolicy::exact`] must
/// reproduce this bit-for-bit (pinned by the tests below and by
/// `benches/perf_decompose.rs`, which also times the two against each
/// other).
pub fn compress_model_serial(
    model_cfg: &ModelConfig,
    weights: &Weights,
    stats: &TapStats,
    spec: &CompressionSpec,
) -> Result<CompressedModel> {
    let mut whiteners: HashMap<String, Whitener> = HashMap::new();
    let mut cm = CompressedModel::default();
    for (name, n_in, n_out) in &model_cfg.linear_shapes {
        let tap = ModelConfig::tap_for_linear(name);
        let tap_stats = stats
            .taps
            .get(&tap)
            .ok_or_else(|| anyhow::anyhow!("no calibration stats for {name} (tap {tap})"))?;
        let whitener = whiteners
            .entry(tap)
            .or_insert_with(|| spec.method.stage1_whitener(tap_stats));
        let plan = ranks::plan(*n_out, *n_in, spec.ratio, spec.effective_alpha());
        let layer = crate::compress::methods::compress_layer_with(
            weights.get(name)?,
            whitener,
            spec,
            &plan,
        )
        .with_context(|| format!("compressing {name}"))?;
        cm.insert(name, layer);
    }
    Ok(cm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::methods::Method;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Rng;

    /// A 2-block llama-style toy model small enough for exhaustive checks.
    fn tiny_model(rng: &mut Rng) -> (ModelConfig, Weights, TapStats) {
        let (d, f, blocks) = (10usize, 14usize, 2usize);
        let mut linear_shapes = Vec::new();
        for i in 0..blocks {
            for leaf in ["wq", "wk", "wv", "wo"] {
                linear_shapes.push((format!("blocks.{i}.attn.{leaf}"), d, d));
            }
            linear_shapes.push((format!("blocks.{i}.mlp.w_gate"), d, f));
            linear_shapes.push((format!("blocks.{i}.mlp.w_up"), d, f));
            linear_shapes.push((format!("blocks.{i}.mlp.w_down"), f, d));
        }
        linear_shapes.sort_by(|a, b| a.0.cmp(&b.0));
        let cfg = ModelConfig {
            name: "tiny".into(),
            family: crate::model::config::Family::Llama,
            arch: "tiny".into(),
            d_model: d,
            n_layers: blocks,
            n_heads: 2,
            d_ff: f,
            max_seq: 16,
            window: 0,
            vocab: 32,
            linear_shapes,
        };
        let mut weights = Weights::default();
        for (name, n_in, n_out) in &cfg.linear_shapes {
            weights.tensors.insert(
                name.clone(),
                Tensor {
                    dims: vec![*n_in, *n_out],
                    data: Matrix::randn(*n_in, *n_out, 0.5, rng).to_f32(),
                },
            );
        }
        let mut stats = TapStats::default();
        for tap in cfg.tap_names() {
            let dim = if tap.ends_with("mlp_down_in") { f } else { d };
            let rows = 3 * dim;
            let x: Vec<f32> = (0..rows * dim).map(|_| rng.normal() as f32).collect();
            stats.accumulate(&tap, &x, rows, dim);
        }
        stats.finalize();
        (cfg, weights, stats)
    }

    fn assert_identical(a: &CompressedModel, b: &CompressedModel) {
        assert_eq!(a.layers.len(), b.layers.len());
        for (name, la) in &a.layers {
            let lb = b.get(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(la.p1, lb.p1, "{name} p1");
            assert_eq!(la.q1, lb.q1, "{name} q1");
            assert_eq!(la.p2, lb.p2, "{name} p2");
            assert_eq!(la.q2, lb.q2, "{name} q2");
        }
    }

    #[test]
    fn sharded_engine_matches_serial_loop() {
        let mut rng = Rng::new(21);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        // α = 0.8 so k₂ > 0 at toy dimensions — stage 2 must shard too.
        for method in [Method::NsvdI, Method::AsvdII, Method::NidI] {
            let spec = CompressionSpec { method, ratio: 0.3, alpha: 0.8 };
            let serial = compress_model_serial(&cfg, &weights, &stats, &spec).unwrap();
            for workers in [1usize, 4] {
                let engine = CompressionEngine::new(EngineConfig {
                    workers,
                    svd: SvdPolicy::exact(),
                });
                let mut cache = WhitenerCache::default();
                let sharded = engine
                    .compress_model(&cfg, &weights, &stats, &spec, &mut cache)
                    .unwrap();
                assert_identical(&serial, &sharded);
            }
        }
    }

    #[test]
    fn whitener_cache_is_reused_across_sweep_jobs() {
        let mut rng = Rng::new(22);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        let engine = CompressionEngine::new(EngineConfig { workers: 2, ..Default::default() });
        let mut cache = WhitenerCache::default();
        let spec1 = CompressionSpec { method: Method::NsvdI, ratio: 0.2, alpha: 0.9 };
        engine.compress_model(&cfg, &weights, &stats, &spec1, &mut cache).unwrap();
        // 4 taps per block × 2 blocks, but wq/wk/wv share attn_in → 8 taps.
        assert_eq!(cache.len(), 8);
        let snapshot: Vec<*const Whitener> =
            cache.values().map(|w| Arc::as_ptr(w)).collect();
        // A second job at a different ratio must reuse the same whiteners.
        let spec2 = CompressionSpec { method: Method::NsvdI, ratio: 0.4, alpha: 0.9 };
        engine.compress_model(&cfg, &weights, &stats, &spec2, &mut cache).unwrap();
        assert_eq!(cache.len(), 8);
        let after: Vec<*const Whitener> = cache.values().map(|w| Arc::as_ptr(w)).collect();
        assert_eq!(snapshot, after, "whiteners must not be rebuilt");
    }

    #[test]
    fn auto_policy_equals_exact_when_sketch_cannot_fit() {
        // At toy dimensions the auto gate (4k ≤ min(m,n)) never fires, so
        // auto and exact must agree bit-for-bit.
        let mut rng = Rng::new(23);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        let spec = CompressionSpec { method: Method::NsvdII, ratio: 0.3, alpha: 0.95 };
        let mut c1 = WhitenerCache::default();
        let mut c2 = WhitenerCache::default();
        let exact = CompressionEngine::new(EngineConfig { workers: 2, svd: SvdPolicy::exact() })
            .compress_model(&cfg, &weights, &stats, &spec, &mut c1)
            .unwrap();
        let auto = CompressionEngine::new(EngineConfig { workers: 2, svd: SvdPolicy::auto() })
            .compress_model(&cfg, &weights, &stats, &spec, &mut c2)
            .unwrap();
        assert_identical(&exact, &auto);
    }

    #[test]
    fn rsvd_engine_run_preserves_budget_and_finiteness() {
        let mut rng = Rng::new(24);
        let (cfg, weights, stats) = tiny_model(&mut rng);
        let spec = CompressionSpec { method: Method::NsvdI, ratio: 0.3, alpha: 0.8 };
        let mut policy = SvdPolicy::randomized();
        policy.oversample = 2;
        policy.max_rel_err = Some(0.05);
        let engine = CompressionEngine::new(EngineConfig { workers: 3, svd: policy });
        let mut cache = WhitenerCache::default();
        let cm = engine.compress_model(&cfg, &weights, &stats, &spec, &mut cache).unwrap();
        let exact = compress_model_serial(&cfg, &weights, &stats, &spec).unwrap();
        assert_eq!(cm.params(), exact.params(), "like-for-like budget");
        for layer in cm.layers.values() {
            assert!(layer.p1.iter().all(|v| v.is_finite()));
            assert!(layer.q1.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn missing_tap_stats_is_a_clean_error() {
        let mut rng = Rng::new(25);
        let (cfg, weights, _) = tiny_model(&mut rng);
        let engine = CompressionEngine::new(EngineConfig::default());
        let spec = CompressionSpec::new(Method::AsvdI, 0.3);
        let err = engine
            .compress_model(&cfg, &weights, &TapStats::default(), &spec, &mut Default::default())
            .unwrap_err();
        assert!(err.to_string().contains("no calibration stats"));
    }
}
