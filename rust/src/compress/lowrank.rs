//! Factored layer representation and the padded marshaling contract.
//!
//! A compressed weight `W [n_in, n_out]` (python storage convention) is held
//! as four f32 factors applied row-wise:
//!
//! ```text
//!   y = (x @ P1) @ Q1 + (x @ P2) @ Q2
//!   P1 [n_in, k1]  Q1 [k1, n_out]   — stage 1 (activation-aware)
//!   P2 [n_in, k2]  Q2 [k2, n_out]   — stage 2 (residual; empty for ASVD)
//! ```
//!
//! In the paper's column convention (`A = Wᵀ`), `Q1ᵀ = W̃₁`, `P1ᵀ = Z̃₁`, so
//! this is exactly Eq. 6.  `pad_to` zero-extends the factors to the fixed
//! executable ranks — the zero block contributes nothing to the product,
//! which test `padding_is_semantically_invisible` pins.

use crate::linalg::matrix::Matrix;
use crate::model::forward::LinearOverride;
use crate::model::weights::Tensor;
use std::collections::BTreeMap;

/// One compressed linear layer (f32 factors, runtime representation).
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    pub n_in: usize,
    pub n_out: usize,
    pub k1: usize,
    pub k2: usize,
    /// Row-major f32 factor data.
    pub p1: Vec<f32>, // [n_in, k1]
    pub q1: Vec<f32>, // [k1, n_out]
    pub p2: Vec<f32>, // [n_in, k2]
    pub q2: Vec<f32>, // [k2, n_out]
}

impl CompressedLayer {
    /// Build from f64 factor matrices (decomposition output).
    /// `p1` is [n_in, k1], `q1` [k1, n_out], `p2` [n_in, k2], `q2` [k2, n_out].
    pub fn from_matrices(p1: &Matrix, q1: &Matrix, p2: &Matrix, q2: &Matrix) -> CompressedLayer {
        assert_eq!(p1.cols, q1.rows);
        assert_eq!(p2.cols, q2.rows);
        assert_eq!(p1.rows, p2.rows);
        assert_eq!(q1.cols, q2.cols);
        CompressedLayer {
            n_in: p1.rows,
            n_out: q1.cols,
            k1: p1.cols,
            k2: p2.cols,
            p1: p1.to_f32(),
            q1: q1.to_f32(),
            p2: p2.to_f32(),
            q2: q2.to_f32(),
        }
    }

    /// Stored parameter count.
    pub fn params(&self) -> usize {
        (self.n_in + self.n_out) * (self.k1 + self.k2)
    }

    /// Native apply: `x [rows, n_in] → y [rows, n_out]`.
    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        use crate::model::forward::matmul_raw;
        let h1 = matmul_raw(x, rows, self.n_in, &self.p1, self.k1);
        let mut y = matmul_raw(&h1, rows, self.k1, &self.q1, self.n_out);
        if self.k2 > 0 {
            let h2 = matmul_raw(x, rows, self.n_in, &self.p2, self.k2);
            let y2 = matmul_raw(&h2, rows, self.k2, &self.q2, self.n_out);
            for (a, b) in y.iter_mut().zip(&y2) {
                *a += b;
            }
        }
        y
    }

    /// Reconstruct the dense weight `W̃ = P1 Q1 + P2 Q2` as a Tensor
    /// (for error metrics and the native-forward materialized path).
    pub fn reconstruct(&self) -> Tensor {
        use crate::model::forward::matmul_raw;
        let mut w = matmul_raw(&self.p1, self.n_in, self.k1, &self.q1, self.n_out);
        if self.k2 > 0 {
            let w2 = matmul_raw(&self.p2, self.n_in, self.k2, &self.q2, self.n_out);
            for (a, b) in w.iter_mut().zip(&w2) {
                *a += b;
            }
        }
        Tensor { dims: vec![self.n_in, self.n_out], data: w }
    }

    /// Zero-pad factors to `(k1_max, k2_max)` — the executable's fixed shape.
    pub fn pad_to(&self, k1_max: usize, k2_max: usize) -> CompressedLayer {
        assert!(self.k1 <= k1_max && self.k2 <= k2_max,
            "ranks ({}, {}) exceed padded maxima ({k1_max}, {k2_max})", self.k1, self.k2);
        let pad_cols = |src: &[f32], rows: usize, from: usize, to: usize| {
            let mut out = vec![0.0f32; rows * to];
            for r in 0..rows {
                out[r * to..r * to + from].copy_from_slice(&src[r * from..(r + 1) * from]);
            }
            out
        };
        let pad_rows = |src: &[f32], from: usize, to: usize, cols: usize| {
            let mut out = vec![0.0f32; to * cols];
            out[..from * cols].copy_from_slice(&src[..from * cols]);
            out
        };
        CompressedLayer {
            n_in: self.n_in,
            n_out: self.n_out,
            k1: k1_max,
            k2: k2_max,
            p1: pad_cols(&self.p1, self.n_in, self.k1, k1_max),
            q1: pad_rows(&self.q1, self.k1, k1_max, self.n_out),
            p2: pad_cols(&self.p2, self.n_in, self.k2, k2_max),
            q2: pad_rows(&self.q2, self.k2, k2_max, self.n_out),
        }
    }
}

/// A full compressed model: per-weight factored layers.
#[derive(Clone, Debug, Default)]
pub struct CompressedModel {
    pub layers: BTreeMap<String, CompressedLayer>,
}

impl CompressedModel {
    pub fn insert(&mut self, name: &str, layer: CompressedLayer) {
        self.layers.insert(name.to_string(), layer);
    }

    pub fn get(&self, name: &str) -> Option<&CompressedLayer> {
        self.layers.get(name)
    }

    /// Total stored parameters across factored layers.
    pub fn params(&self) -> usize {
        self.layers.values().map(|l| l.params()).sum()
    }
}

impl LinearOverride for CompressedModel {
    fn apply(&self, name: &str, x: &[f32], rows: usize, in_dim: usize) -> Option<Vec<f32>> {
        self.layers.get(name).map(|layer| {
            debug_assert_eq!(layer.n_in, in_dim);
            layer.apply(x, rows)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_layer(n_in: usize, n_out: usize, k1: usize, k2: usize, rng: &mut Rng) -> CompressedLayer {
        let p1 = Matrix::randn(n_in, k1, 1.0, rng);
        let q1 = Matrix::randn(k1, n_out, 1.0, rng);
        let p2 = Matrix::randn(n_in, k2, 1.0, rng);
        let q2 = Matrix::randn(k2, n_out, 1.0, rng);
        CompressedLayer::from_matrices(&p1, &q1, &p2, &q2)
    }

    #[test]
    fn apply_matches_reconstructed_dense() {
        check("apply == x @ reconstruct()", 15, |g| {
            let mut rng = g.rng.fork(0);
            let n_in = g.usize_in(2, 24);
            let n_out = g.usize_in(2, 24);
            let k1 = g.usize_in(1, 8);
            let k2 = g.usize_in(0, 4);
            let layer = random_layer(n_in, n_out, k1, k2, &mut rng);
            let rows = g.usize_in(1, 10);
            let x: Vec<f32> = (0..rows * n_in).map(|_| rng.normal() as f32).collect();
            let y = layer.apply(&x, rows);
            let w = layer.reconstruct();
            let y_dense = crate::model::forward::matmul_raw(&x, rows, n_in, &w.data, n_out);
            for (a, b) in y.iter().zip(&y_dense) {
                if (a - b).abs() > 1e-3 {
                    return Err(format!("apply mismatch {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn padding_is_semantically_invisible() {
        check("pad_to preserves the function", 15, |g| {
            let mut rng = g.rng.fork(0);
            let layer = random_layer(12, 10, 4, 2, &mut rng);
            let padded = layer.pad_to(9, 5);
            assert_eq!(padded.k1, 9);
            assert_eq!(padded.k2, 5);
            let rows = g.usize_in(1, 6);
            let x: Vec<f32> = (0..rows * 12).map(|_| rng.normal() as f32).collect();
            let y0 = layer.apply(&x, rows);
            let y1 = padded.apply(&x, rows);
            for (a, b) in y0.iter().zip(&y1) {
                if (a - b).abs() > 1e-4 {
                    return Err(format!("padding changed output: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "exceed padded maxima")]
    fn pad_rejects_oversized_ranks() {
        let mut rng = Rng::new(1);
        let layer = random_layer(8, 8, 6, 2, &mut rng);
        let _ = layer.pad_to(4, 2);
    }

    #[test]
    fn params_accounting() {
        let mut rng = Rng::new(2);
        let layer = random_layer(100, 60, 10, 3, &mut rng);
        assert_eq!(layer.params(), 160 * 13);
        let mut model = CompressedModel::default();
        model.insert("a", layer.clone());
        model.insert("b", layer);
        assert_eq!(model.params(), 2 * 160 * 13);
    }

    #[test]
    fn zero_k2_layer_skips_stage2() {
        let mut rng = Rng::new(3);
        let layer = random_layer(6, 6, 3, 0, &mut rng);
        let x: Vec<f32> = (0..12).map(|_| rng.normal() as f32).collect();
        let y = layer.apply(&x, 2);
        assert_eq!(y.len(), 12);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn linear_override_routes_by_name() {
        let mut rng = Rng::new(4);
        let mut model = CompressedModel::default();
        model.insert("blocks.0.attn.wq", random_layer(8, 8, 2, 1, &mut rng));
        let x = vec![1.0f32; 8];
        assert!(model.apply("blocks.0.attn.wq", &x, 1, 8).is_some());
        assert!(model.apply("blocks.0.attn.wk", &x, 1, 8).is_none());
    }
}
