//! Factored layer representation and the padded marshaling contract.
//!
//! A compressed weight `W [n_in, n_out]` (python storage convention) is held
//! as four f32 factors applied row-wise:
//!
//! ```text
//!   y = (x @ P1) @ Q1 + (x @ P2) @ Q2
//!   P1 [n_in, k1]  Q1 [k1, n_out]   — stage 1 (activation-aware)
//!   P2 [n_in, k2]  Q2 [k2, n_out]   — stage 2 (residual; empty for ASVD)
//! ```
//!
//! In the paper's column convention (`A = Wᵀ`), `Q1ᵀ = W̃₁`, `P1ᵀ = Z̃₁`, so
//! this is exactly Eq. 6.  `pad_to` zero-extends the factors to the fixed
//! executable ranks — the zero block contributes nothing to the product,
//! which test `padding_is_semantically_invisible` pins.
//!
//! **Factor dtype.** Factors are produced in f32 and may be re-encoded to
//! per-group symmetric int8 ([`CompressedLayer::quantize`]): codes + f32
//! scales per `(k-group, column)` in a [`QuantMatrix`] each, ~0.26× the
//! f32 bytes at realistic shapes (pinned below at ≤ 0.27×).  A quantized
//! layer applies through the integer kernel ([`quant::matmul_quant`] →
//! `gemm_i8_nn`), which is bit-identical at every worker count and
//! per-row independent — so batched serve decode over int8 factors equals
//! the single-request reference bit-for-bit, same as the f32 contract.

use crate::linalg::matrix::Matrix;
use crate::linalg::quant::{self, QuantMatrix};
use crate::model::forward::LinearOverride;
use crate::model::weights::Tensor;
use std::collections::BTreeMap;

/// Storage dtype for compressed factors — the `--factor-dtype` knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FactorDtype {
    /// Plain f32 factors (the default; bit-exact apply).
    #[default]
    F32,
    /// Per-group symmetric int8 codes + f32 scales (native path only).
    Int8,
}

impl FactorDtype {
    /// Parse a CLI value (`f32` | `int8`).
    pub fn parse(s: &str) -> crate::Result<FactorDtype> {
        match s {
            "f32" => Ok(FactorDtype::F32),
            "int8" => Ok(FactorDtype::Int8),
            other => anyhow::bail!("unknown factor dtype '{other}' (expected f32 | int8)"),
        }
    }

    /// Lowercase label for tables and bench rows.
    pub fn label(self) -> &'static str {
        match self {
            FactorDtype::F32 => "f32",
            FactorDtype::Int8 => "int8",
        }
    }
}

/// Int8 encodings of the four factors (present iff the layer was
/// quantized; the f32 vectors are dropped to realize the byte saving).
#[derive(Clone, Debug)]
pub struct QuantFactors {
    pub p1: QuantMatrix, // [n_in, k1]
    pub q1: QuantMatrix, // [k1, n_out]
    pub p2: QuantMatrix, // [n_in, k2]
    pub q2: QuantMatrix, // [k2, n_out]
}

/// One compressed linear layer (f32 factors, runtime representation).
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    pub n_in: usize,
    pub n_out: usize,
    pub k1: usize,
    pub k2: usize,
    /// Row-major f32 factor data (empty when `quant` is present).
    pub p1: Vec<f32>, // [n_in, k1]
    pub q1: Vec<f32>, // [k1, n_out]
    pub p2: Vec<f32>, // [n_in, k2]
    pub q2: Vec<f32>, // [k2, n_out]
    /// Int8 factor encodings; `Some` ⇔ the layer is quantized.
    pub quant: Option<QuantFactors>,
}

impl CompressedLayer {
    /// Build from f64 factor matrices (decomposition output).
    /// `p1` is [n_in, k1], `q1` [k1, n_out], `p2` [n_in, k2], `q2` [k2, n_out].
    pub fn from_matrices(p1: &Matrix, q1: &Matrix, p2: &Matrix, q2: &Matrix) -> CompressedLayer {
        assert_eq!(p1.cols, q1.rows);
        assert_eq!(p2.cols, q2.rows);
        assert_eq!(p1.rows, p2.rows);
        assert_eq!(q1.cols, q2.cols);
        CompressedLayer {
            n_in: p1.rows,
            n_out: q1.cols,
            k1: p1.cols,
            k2: p2.cols,
            p1: p1.to_f32(),
            q1: q1.to_f32(),
            p2: p2.to_f32(),
            q2: q2.to_f32(),
            quant: None,
        }
    }

    /// Stored parameter count (dtype-independent rank accounting; byte
    /// footprints come from [`CompressedLayer::factor_bytes`]).
    pub fn params(&self) -> usize {
        (self.n_in + self.n_out) * (self.k1 + self.k2)
    }

    /// Whether the factors are stored as int8 codes.
    pub fn is_quantized(&self) -> bool {
        self.quant.is_some()
    }

    /// Storage footprint of the factors in bytes: `4·params` for f32,
    /// codes + scales for int8.
    pub fn factor_bytes(&self) -> usize {
        match &self.quant {
            None => 4 * self.params(),
            Some(q) => q.p1.bytes() + q.q1.bytes() + q.p2.bytes() + q.q2.bytes(),
        }
    }

    /// Re-encode the factors as per-group symmetric int8 (group length
    /// along the contraction axis; use [`quant::DEFAULT_GROUP`] unless
    /// you have a reason).  The f32 vectors are dropped — that is the
    /// memory saving — so this is a storage decision, not a view.
    pub fn quantize(&self, group: usize) -> CompressedLayer {
        assert!(!self.is_quantized(), "layer already quantized");
        CompressedLayer {
            n_in: self.n_in,
            n_out: self.n_out,
            k1: self.k1,
            k2: self.k2,
            p1: Vec::new(),
            q1: Vec::new(),
            p2: Vec::new(),
            q2: Vec::new(),
            quant: Some(QuantFactors {
                p1: quant::quantize_columns(&self.p1, self.n_in, self.k1, group),
                q1: quant::quantize_columns(&self.q1, self.k1, self.n_out, group),
                p2: quant::quantize_columns(&self.p2, self.n_in, self.k2, group),
                q2: quant::quantize_columns(&self.q2, self.k2, self.n_out, group),
            }),
        }
    }

    /// Native apply: `x [rows, n_in] → y [rows, n_out]`.  Quantized layers
    /// route through the int8 kernel; both paths honour the per-thread
    /// GEMM worker knob and are bit-identical across worker counts.
    pub fn apply(&self, x: &[f32], rows: usize) -> Vec<f32> {
        if let Some(q) = &self.quant {
            return self.apply_quant(q, x, rows);
        }
        use crate::model::forward::matmul_raw;
        let h1 = matmul_raw(x, rows, self.n_in, &self.p1, self.k1);
        let mut y = matmul_raw(&h1, rows, self.k1, &self.q1, self.n_out);
        if self.k2 > 0 {
            let h2 = matmul_raw(x, rows, self.n_in, &self.p2, self.k2);
            let y2 = matmul_raw(&h2, rows, self.k2, &self.q2, self.n_out);
            for (a, b) in y.iter_mut().zip(&y2) {
                *a += b;
            }
        }
        y
    }

    /// Int8 apply: activations are quantized per `(row, k-group)` once per
    /// stage input (x is shared by P1/P2, which use the same group), each
    /// product runs i8×i8→i32 with the dequant-fused epilogue.  Per-row
    /// independence of both the dynamic quantization and the integer GEMM
    /// keeps batched == single-row bit-identical.
    fn apply_quant(&self, q: &QuantFactors, x: &[f32], rows: usize) -> Vec<f32> {
        use crate::linalg::gemm;
        let workers = gemm::workers();
        let (xq, xs) = quant::quantize_row_groups(x, rows, self.n_in, q.p1.group);
        let mut h1 = vec![0.0f32; rows * self.k1];
        gemm::gemm_i8_nn(
            rows, self.n_in, self.k1, &xq, &xs, &q.p1.data, &q.p1.scales, q.p1.group, &mut h1,
            workers,
        );
        let mut y = vec![0.0f32; rows * self.n_out];
        quant::matmul_quant(&h1, rows, &q.q1, &mut y, workers);
        if self.k2 > 0 {
            debug_assert_eq!(q.p2.group, q.p1.group, "stage factors share one group");
            let mut h2 = vec![0.0f32; rows * self.k2];
            gemm::gemm_i8_nn(
                rows, self.n_in, self.k2, &xq, &xs, &q.p2.data, &q.p2.scales, q.p2.group,
                &mut h2, workers,
            );
            let mut y2 = vec![0.0f32; rows * self.n_out];
            quant::matmul_quant(&h2, rows, &q.q2, &mut y2, workers);
            for (a, b) in y.iter_mut().zip(&y2) {
                *a += b;
            }
        }
        y
    }

    /// Reconstruct the dense weight `W̃ = P1 Q1 + P2 Q2` as a Tensor
    /// (for error metrics and the native-forward materialized path).
    /// Quantized layers dequantize their factors first.
    pub fn reconstruct(&self) -> Tensor {
        use crate::model::forward::matmul_raw;
        if let Some(q) = &self.quant {
            let (p1, q1, p2, q2) =
                (q.p1.dequantize(), q.q1.dequantize(), q.p2.dequantize(), q.q2.dequantize());
            let mut w = matmul_raw(&p1, self.n_in, self.k1, &q1, self.n_out);
            if self.k2 > 0 {
                let w2 = matmul_raw(&p2, self.n_in, self.k2, &q2, self.n_out);
                for (a, b) in w.iter_mut().zip(&w2) {
                    *a += b;
                }
            }
            return Tensor { dims: vec![self.n_in, self.n_out], data: w };
        }
        let mut w = matmul_raw(&self.p1, self.n_in, self.k1, &self.q1, self.n_out);
        if self.k2 > 0 {
            let w2 = matmul_raw(&self.p2, self.n_in, self.k2, &self.q2, self.n_out);
            for (a, b) in w.iter_mut().zip(&w2) {
                *a += b;
            }
        }
        Tensor { dims: vec![self.n_in, self.n_out], data: w }
    }

    /// Zero-pad factors to `(k1_max, k2_max)` — the executable's fixed shape.
    /// PJRT marshaling only; quantized layers never take this path (the
    /// int8 dtype is gated to the native backend).
    pub fn pad_to(&self, k1_max: usize, k2_max: usize) -> CompressedLayer {
        assert!(!self.is_quantized(), "pad_to: quantized layers are native-only");
        assert!(self.k1 <= k1_max && self.k2 <= k2_max,
            "ranks ({}, {}) exceed padded maxima ({k1_max}, {k2_max})", self.k1, self.k2);
        let pad_cols = |src: &[f32], rows: usize, from: usize, to: usize| {
            let mut out = vec![0.0f32; rows * to];
            for r in 0..rows {
                out[r * to..r * to + from].copy_from_slice(&src[r * from..(r + 1) * from]);
            }
            out
        };
        let pad_rows = |src: &[f32], from: usize, to: usize, cols: usize| {
            let mut out = vec![0.0f32; to * cols];
            out[..from * cols].copy_from_slice(&src[..from * cols]);
            out
        };
        CompressedLayer {
            n_in: self.n_in,
            n_out: self.n_out,
            k1: k1_max,
            k2: k2_max,
            p1: pad_cols(&self.p1, self.n_in, self.k1, k1_max),
            q1: pad_rows(&self.q1, self.k1, k1_max, self.n_out),
            p2: pad_cols(&self.p2, self.n_in, self.k2, k2_max),
            q2: pad_rows(&self.q2, self.k2, k2_max, self.n_out),
            quant: None,
        }
    }
}

/// A full compressed model: per-weight factored layers.
#[derive(Clone, Debug, Default)]
pub struct CompressedModel {
    pub layers: BTreeMap<String, CompressedLayer>,
}

impl CompressedModel {
    pub fn insert(&mut self, name: &str, layer: CompressedLayer) {
        self.layers.insert(name.to_string(), layer);
    }

    pub fn get(&self, name: &str) -> Option<&CompressedLayer> {
        self.layers.get(name)
    }

    /// Total stored parameters across factored layers.
    pub fn params(&self) -> usize {
        self.layers.values().map(|l| l.params()).sum()
    }

    /// Total factor storage in bytes (dtype-aware; scales included).
    pub fn factor_bytes(&self) -> usize {
        self.layers.values().map(|l| l.factor_bytes()).sum()
    }

    /// Quantize every layer's factors to per-group int8 (see
    /// [`CompressedLayer::quantize`]).
    pub fn quantize(&self, group: usize) -> CompressedModel {
        CompressedModel {
            layers: self
                .layers
                .iter()
                .map(|(name, layer)| (name.clone(), layer.quantize(group)))
                .collect(),
        }
    }

    /// Whether every layer stores int8 factors (false for an empty model).
    pub fn is_quantized(&self) -> bool {
        !self.layers.is_empty() && self.layers.values().all(|l| l.is_quantized())
    }
}

impl LinearOverride for CompressedModel {
    fn apply(&self, name: &str, x: &[f32], rows: usize, in_dim: usize) -> Option<Vec<f32>> {
        self.layers.get(name).map(|layer| {
            debug_assert_eq!(layer.n_in, in_dim);
            layer.apply(x, rows)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_layer(n_in: usize, n_out: usize, k1: usize, k2: usize, rng: &mut Rng) -> CompressedLayer {
        let p1 = Matrix::randn(n_in, k1, 1.0, rng);
        let q1 = Matrix::randn(k1, n_out, 1.0, rng);
        let p2 = Matrix::randn(n_in, k2, 1.0, rng);
        let q2 = Matrix::randn(k2, n_out, 1.0, rng);
        CompressedLayer::from_matrices(&p1, &q1, &p2, &q2)
    }

    #[test]
    fn apply_matches_reconstructed_dense() {
        check("apply == x @ reconstruct()", 15, |g| {
            let mut rng = g.rng.fork(0);
            let n_in = g.usize_in(2, 24);
            let n_out = g.usize_in(2, 24);
            let k1 = g.usize_in(1, 8);
            let k2 = g.usize_in(0, 4);
            let layer = random_layer(n_in, n_out, k1, k2, &mut rng);
            let rows = g.usize_in(1, 10);
            let x: Vec<f32> = (0..rows * n_in).map(|_| rng.normal() as f32).collect();
            let y = layer.apply(&x, rows);
            let w = layer.reconstruct();
            let y_dense = crate::model::forward::matmul_raw(&x, rows, n_in, &w.data, n_out);
            for (a, b) in y.iter().zip(&y_dense) {
                if (a - b).abs() > 1e-3 {
                    return Err(format!("apply mismatch {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn padding_is_semantically_invisible() {
        check("pad_to preserves the function", 15, |g| {
            let mut rng = g.rng.fork(0);
            let layer = random_layer(12, 10, 4, 2, &mut rng);
            let padded = layer.pad_to(9, 5);
            assert_eq!(padded.k1, 9);
            assert_eq!(padded.k2, 5);
            let rows = g.usize_in(1, 6);
            let x: Vec<f32> = (0..rows * 12).map(|_| rng.normal() as f32).collect();
            let y0 = layer.apply(&x, rows);
            let y1 = padded.apply(&x, rows);
            for (a, b) in y0.iter().zip(&y1) {
                if (a - b).abs() > 1e-4 {
                    return Err(format!("padding changed output: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "exceed padded maxima")]
    fn pad_rejects_oversized_ranks() {
        let mut rng = Rng::new(1);
        let layer = random_layer(8, 8, 6, 2, &mut rng);
        let _ = layer.pad_to(4, 2);
    }

    #[test]
    fn params_accounting() {
        let mut rng = Rng::new(2);
        let layer = random_layer(100, 60, 10, 3, &mut rng);
        assert_eq!(layer.params(), 160 * 13);
        let mut model = CompressedModel::default();
        model.insert("a", layer.clone());
        model.insert("b", layer);
        assert_eq!(model.params(), 2 * 160 * 13);
    }

    #[test]
    fn zero_k2_layer_skips_stage2() {
        let mut rng = Rng::new(3);
        let layer = random_layer(6, 6, 3, 0, &mut rng);
        let x: Vec<f32> = (0..12).map(|_| rng.normal() as f32).collect();
        let y = layer.apply(&x, 2);
        assert_eq!(y.len(), 12);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn linear_override_routes_by_name() {
        let mut rng = Rng::new(4);
        let mut model = CompressedModel::default();
        model.insert("blocks.0.attn.wq", random_layer(8, 8, 2, 1, &mut rng));
        let x = vec![1.0f32; 8];
        assert!(model.apply("blocks.0.attn.wq", &x, 1, 8).is_some());
        assert!(model.apply("blocks.0.attn.wk", &x, 1, 8).is_none());
    }

    #[test]
    fn quantized_apply_close_to_f32_apply() {
        // The int8 path approximates the f32 apply within the additive
        // quantization budget (both factor and activation quantization,
        // two stages) — loose bound, but catches any scale/layout slip.
        check("int8 apply ≈ f32 apply", 10, |g| {
            let mut rng = g.rng.fork(0);
            let n_in = g.usize_in(8, 64);
            let n_out = g.usize_in(8, 64);
            let k1 = g.usize_in(2, 12);
            let k2 = g.usize_in(0, 4);
            let layer = random_layer(n_in, n_out, k1, k2, &mut rng);
            let qlayer = layer.quantize(crate::linalg::quant::DEFAULT_GROUP);
            let rows = g.usize_in(1, 6);
            let x: Vec<f32> = (0..rows * n_in).map(|_| rng.normal() as f32).collect();
            let y = layer.apply(&x, rows);
            let yq = qlayer.apply(&x, rows);
            // Each quantized operand carries ~amax/254 relative rms error
            // (~2%); two chained stages plus activation quantization land
            // well under 10% relative Frobenius error on random normals —
            // while any scale/layout slip produces O(100%).
            let num: f64 = y.iter().zip(&yq).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            let den: f64 = y.iter().map(|a| (*a as f64).powi(2)).sum();
            let rel = num.sqrt() / den.sqrt().max(1e-12);
            if rel > 0.10 {
                return Err(format!(
                    "int8 apply drifted: rel Frobenius err {rel:.4} ({n_in}x{n_out} k1={k1} k2={k2})"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn quantized_batched_apply_is_row_independent() {
        // Batched apply row r == the same row applied alone, bit-for-bit
        // (dynamic per-row activation quantization + integer GEMM) — the
        // property serve decode's batching contract rides on.
        let mut rng = Rng::new(6);
        let layer = random_layer(160, 48, 10, 3, &mut rng).quantize(crate::linalg::quant::DEFAULT_GROUP);
        let rows = 5;
        let x: Vec<f32> = (0..rows * 160).map(|_| rng.normal() as f32).collect();
        for workers in [1usize, 4] {
            let _g = crate::linalg::gemm::scoped_workers(workers);
            let batched = layer.apply(&x, rows);
            for r in 0..rows {
                let solo = layer.apply(&x[r * 160..(r + 1) * 160], 1);
                assert_eq!(&batched[r * 48..(r + 1) * 48], &solo[..], "row {r} w={workers}");
            }
        }
    }

    #[test]
    fn quantized_bytes_at_most_27_percent_of_f32() {
        // The acceptance pin: int8 factor storage (codes + scales) ≤ 0.27×
        // the f32 bytes at equal ranks, at realistic layer shapes (at tiny
        // test shapes the per-column scale overhead dominates — rank and
        // width must amortize it, which real models do).
        let mut rng = Rng::new(7);
        let mut model = CompressedModel::default();
        model.insert("a", random_layer(256, 256, 85, 4, &mut rng));
        model.insert("b", random_layer(384, 256, 100, 8, &mut rng));
        let qmodel = model.quantize(crate::linalg::quant::DEFAULT_GROUP);
        assert!(qmodel.is_quantized());
        assert_eq!(qmodel.params(), model.params(), "rank accounting is dtype-free");
        let f32_bytes = model.factor_bytes();
        let int8_bytes = qmodel.factor_bytes();
        assert_eq!(f32_bytes, 4 * model.params());
        assert!(
            (int8_bytes as f64) <= 0.27 * f32_bytes as f64,
            "int8 {int8_bytes} vs f32 {f32_bytes} = {:.4}×",
            int8_bytes as f64 / f32_bytes as f64
        );
    }

    #[test]
    fn quantized_reconstruct_close_to_f32_reconstruct() {
        let mut rng = Rng::new(8);
        let layer = random_layer(40, 30, 6, 2, &mut rng);
        let w = layer.reconstruct();
        let wq = layer.quantize(64).reconstruct();
        assert_eq!(wq.dims, w.dims);
        let num: f64 = w.data.iter().zip(&wq.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = w.data.iter().map(|a| (*a as f64).powi(2)).sum();
        assert!(num.sqrt() <= 0.10 * den.sqrt(), "rel err {:.4}", num.sqrt() / den.sqrt());
    }

    #[test]
    #[should_panic(expected = "native-only")]
    fn pad_rejects_quantized_layers() {
        let mut rng = Rng::new(9);
        let layer = random_layer(16, 16, 3, 1, &mut rng).quantize(8);
        let _ = layer.pad_to(4, 2);
    }

    #[test]
    fn factor_dtype_parses_and_labels() {
        assert_eq!(FactorDtype::parse("f32").unwrap(), FactorDtype::F32);
        assert_eq!(FactorDtype::parse("int8").unwrap(), FactorDtype::Int8);
        assert!(FactorDtype::parse("int4").is_err());
        assert_eq!(FactorDtype::Int8.label(), "int8");
        assert_eq!(FactorDtype::default(), FactorDtype::F32);
    }
}
