//! The paper's compression methods and the engine that runs them at scale.
//!
//! * [`ranks`]   — per-layer parameter budgeting: compression ratio → (k₁, k₂).
//! * [`allocate`] — global spectrum-driven rank allocation: one parameter
//!                 budget water-filled across layers by whitened marginal
//!                 gain, plus the per-layer α auto-tune (`--allocate
//!                 spectrum`, `--alpha auto`; uniform stays the default and
//!                 bit-identical to the paper protocol).
//! * [`whiten`]  — activation-aware whitening transforms built from the
//!                 calibration Gram (ASVD-0 diag, ASVD-I Cholesky, ASVD-II
//!                 eigen, ASVD-III γ-scaled rotation).
//! * [`methods`] — SVD / ASVD-0 / ASVD-I / ASVD-II / ASVD-III / NSVD-I/II /
//!                 NID-I/II, all producing [`lowrank::CompressedLayer`]s.
//! * [`engine`]  — the parallel sharded compression engine: per-tap
//!                 whiteners computed once and shared via `Arc`, layer jobs
//!                 fanned out over scoped worker threads, truncated SVDs
//!                 routed through the [`crate::linalg::rsvd::SvdPolicy`]
//!                 fast path.
//! * [`kv`]      — KV-cache factorization: whitened, ASVD-style
//!                 query-scaled low-rank factors of `wk`/`wv` whose latents
//!                 the paged serving cache stores per token (`--kv-ratio`).
//! * [`lowrank`] — factored layer representation, padded marshaling for the
//!                 fixed-shape PJRT executable, native apply + reconstruction,
//!                 and the [`lowrank::FactorDtype`] storage knob (f32 or
//!                 per-group int8 riding the integer GEMM kernel).

pub mod allocate;
pub mod engine;
pub mod kv;
pub mod lowrank;
pub mod methods;
pub mod ranks;
pub mod whiten;

pub use allocate::{AllocConfig, AllocStrategy, LayerProfile};
pub use kv::{compress_kv_plain, compress_kv_with, kv_override_model, KvBuildSpec};
pub use engine::{CompressionEngine, EngineConfig, WhitenerCache};
pub use lowrank::{CompressedLayer, CompressedModel, FactorDtype, QuantFactors};
pub use methods::{compress_layer, CompressionSpec, Method};
pub use ranks::RankPlan;
