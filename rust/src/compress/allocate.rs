//! Global spectrum-driven rank allocation.
//!
//! The paper's protocol compresses every layer at the same ratio
//! (`ranks::plan` per layer, the **uniform** strategy), but the ASVD line of
//! work (Yuan et al., 2023) shows per-layer rank budgets chosen by
//! sensitivity materially beat uniform allocation.  This module spends one
//! global `(m+n)·k` parameter budget across all layers where the whitened
//! spectra say the activation-weighted mass is:
//!
//! 1. **profile** (parallel) — every layer's whitened singular spectrum
//!    `σ(A·S)` is computed on the sharded engine pool
//!    ([`crate::compress::engine::CompressionEngine::profile_spectra`]);
//!    by Theorem 2, keeping direction `i` of the whitened matrix removes
//!    exactly `σᵢ²` of squared activation-weighted loss, so the spectrum is
//!    a complete per-layer sensitivity profile;
//! 2. **allocate** (serial, deterministic) — [`spectrum_ranks`] runs a
//!    greedy water-filling pass over the marginal gains `σ²_{ℓ,k} / cost_ℓ`
//!    (`cost_ℓ = m_ℓ + n_ℓ` parameters per rank unit) against the budget the
//!    uniform plan would spend, so the two strategies are compared at the
//!    SAME total parameter count;
//! 3. **split** — each granted total rank is split into the nested
//!    `(k₁, k₂)` pair, either with the fixed α
//!    ([`crate::compress::ranks::split_k`]) or per layer via the
//!    [`tune_alpha`] mini-sweep (`--alpha auto`).
//!
//! Because the profile phase is a pure per-layer function and the allocation
//! phase is serial, the resulting plans — and therefore the compressed
//! model — are **identical at every worker count**.  Uniform mode bypasses
//! this module's allocator entirely and stays bit-identical to the
//! historical per-layer planner.
//!
//! ## Guarantees
//!
//! * **budget** ([`spectrum_ranks`] and [`allocate_spectrum`]) —
//!   `Σ cost_ℓ·k_ℓ ≤ budget`, and when some layer is still below its cap
//!   the unspent remainder is smaller than one layer's cost ("within one
//!   layer's granularity");
//! * **monotone** ([`allocate_spectrum`]) — a larger budget never shrinks
//!   any layer's rank: grants are a budget-independent priority sequence
//!   and the spend is its longest affordable prefix.  [`spectrum_ranks`]
//!   does NOT inherit this across ratios — its uniform fallback (next
//!   bullet) can reshuffle ranks between two nearby budgets;
//! * **never worse than uniform** ([`spectrum_ranks`]) — the total whitened truncation error
//!   `Σ_ℓ Σ_{i>k_ℓ} σ²_{ℓ,i}` is ≤ the uniform plan's at the same budget.
//!   The greedy prefix can occasionally lose (its early stop strands
//!   budget behind one expensive layer — a few percent of random
//!   instances); [`spectrum_ranks`] compares both totals and returns the
//!   uniform ranks in exactly those cases, making the guarantee
//!   unconditional.

use super::methods::{compress_layer_with_policy, CompressionSpec, Method};
use super::ranks::{self, RankPlan};
use super::whiten::Whitener;
use crate::linalg::eig::sym_eig;
use crate::linalg::matrix::Matrix;
use crate::linalg::rsvd::SvdPolicy;
use crate::model::weights::Tensor;
use anyhow::{bail, Result};

/// How the global parameter budget is distributed across layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocStrategy {
    /// Paper protocol: every layer compressed at the same ratio
    /// (`k = ⌊(1-ρ)·mn/(m+n)⌋` per layer).  The default; bit-identical to
    /// the pre-allocator planner.
    Uniform,
    /// Spectrum-driven water-filling: one global budget, spent greedily by
    /// whitened marginal gain per parameter.
    Spectrum,
}

impl AllocStrategy {
    /// Parse the `--allocate` CLI value.
    pub fn parse(s: &str) -> Result<AllocStrategy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "uniform" => AllocStrategy::Uniform,
            "spectrum" => AllocStrategy::Spectrum,
            _ => bail!("unknown allocation strategy '{s}' (use 'uniform' or 'spectrum')"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            AllocStrategy::Uniform => "uniform",
            AllocStrategy::Spectrum => "spectrum",
        }
    }
}

/// Allocation knobs threaded from the pipeline into
/// [`crate::compress::engine::CompressionEngine::plan_model`].
#[derive(Clone, Debug, Default)]
pub struct AllocConfig {
    pub strategy: AllocStrategy,
    /// Replace the single global α with a per-layer (k₁, k₂) split chosen
    /// by [`tune_alpha`] (nested methods only).
    pub alpha_auto: bool,
    /// Optional per-layer cap on the total rank `k`, aligned with
    /// `ModelConfig::linear_shapes`.  The pipeline passes the
    /// padded-executable caps ([`ranks::max_k_for_alpha`]) on the PJRT path
    /// so spectrum-allocated factors always fit the fixed-shape executable;
    /// `None` caps only at `min(m, n)`.
    pub k_caps: Option<Vec<usize>>,
}

impl Default for AllocStrategy {
    fn default() -> AllocStrategy {
        AllocStrategy::Uniform
    }
}

/// One layer's profiling output: the whitened singular spectrum.
#[derive(Clone, Debug)]
pub struct LayerProfile {
    /// Weight name (`blocks.i.attn.wq`, …).
    pub name: String,
    /// Paper-convention row count of `A = Wᵀ` (`m = n_out`).
    pub m: usize,
    /// Paper-convention column count (`n = n_in`; the whitened/calibrated
    /// dimension).
    pub n: usize,
    /// Whitened singular values `σ(A·S)`, non-increasing, length
    /// `min(m, n)`.
    pub spectrum: Vec<f64>,
}

impl LayerProfile {
    /// Parameters one rank unit stores: `m + n`.
    pub fn cost(&self) -> usize {
        self.m + self.n
    }

    /// Largest meaningful rank: `min(m, n)` (capped by the profiled
    /// spectrum length).
    pub fn max_rank(&self) -> usize {
        self.m.min(self.n).min(self.spectrum.len())
    }

    /// `Σ_{i≥k} σᵢ²` — the squared activation-weighted loss of truncating
    /// this layer at rank `k` (Theorem 2).
    pub fn tail_sq(&self, k: usize) -> f64 {
        self.spectrum[k.min(self.spectrum.len())..].iter().map(|s| s * s).sum()
    }
}

/// Whitened singular spectrum of one weight: `σ(A·S)` with `A = Wᵀ`.
///
/// Computed as the square roots of the eigenvalues of the whitened Gram
/// `(AS)ᵀ(AS)` — the Gram goes through the packed SYRK kernel and the
/// symmetric Jacobi eigensolver, which is cheaper than a full one-sided
/// Jacobi SVD of `AS` (no singular vectors are needed for allocation) and
/// bit-identical at every worker count.  Values are clamped at zero and
/// truncated to `min(m, n)` (the Gram is n×n but has rank ≤ min(m, n)).
pub fn whitened_spectrum(weight: &Tensor, w1: &Whitener) -> Vec<f64> {
    let (n_in, n_out) = (weight.dims[0], weight.dims[1]);
    let a = Matrix::from_f32(n_in, n_out, &weight.data).transpose(); // m×n
    let aw = w1.whiten(&a);
    let eig = sym_eig(&aw.gram());
    let r = aw.rows.min(aw.cols);
    eig.values.iter().take(r).map(|&v| v.max(0.0).sqrt()).collect()
}

/// The uniform per-layer plans — the paper's protocol, one
/// [`ranks::plan`] per `(name, n_in, n_out)` entry of
/// `ModelConfig::linear_shapes`.  This is the exact computation the engine
/// performed before the allocator existed; `--allocate uniform` routes
/// through it unchanged (pinned bit-identical by the engine tests).
pub fn uniform_plans(shapes: &[(String, usize, usize)], ratio: f64, alpha: f64) -> Vec<RankPlan> {
    shapes
        .iter()
        .map(|(_, n_in, n_out)| ranks::plan(*n_out, *n_in, ratio, alpha))
        .collect()
}

/// The global parameter budget the uniform plan spends at `ratio`:
/// `Σ_ℓ (m_ℓ+n_ℓ)·k_ℓ` — the like-for-like total the spectrum allocator is
/// held to (α does not change it: `(m+n)(k₁+k₂) = (m+n)k`).
pub fn uniform_budget(profiles: &[LayerProfile], ratio: f64) -> usize {
    profiles
        .iter()
        .map(|p| p.cost() * ranks::k_budget(p.m, p.n, ratio))
        .sum()
}

/// Greedy water-filling of `budget` parameters over the profiled layers;
/// returns each layer's total rank `k` (every layer keeps at least 1).
///
/// The grant sequence — layer ℓ's `k→k+1` step offers marginal gain
/// `σ²_{ℓ,k} / cost_ℓ` — is materialized and sorted once
/// (gain desc, then layer index, then rank: fully deterministic), which
/// makes it **budget-independent**; the allocation is then the longest
/// prefix of that sequence whose cumulative cost fits the budget.  Stopping
/// at the first unaffordable grant (rather than skipping it and continuing
/// with cheaper layers) is what makes the allocation *monotone in the
/// budget* — a skip policy can starve a cheap layer under a LARGER budget —
/// at the price of leaving less than one layer-cost of the budget unspent.
///
/// ```
/// use nsvd::compress::allocate::{allocate_spectrum, LayerProfile};
///
/// // Layer 0: flat spectrum (every direction matters); layer 1: one
/// // dominant direction.  Same shape, so same cost per rank.
/// let flat = LayerProfile {
///     name: "flat".into(), m: 8, n: 8, spectrum: vec![1.0; 8],
/// };
/// let spiked = LayerProfile {
///     name: "spiked".into(), m: 8, n: 8,
///     spectrum: vec![1.0, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01, 0.01],
/// };
/// let ks = allocate_spectrum(&[flat, spiked], 6 * 16, None);
/// // 6 rank units fit the budget: the flat layer wins all the extras.
/// assert_eq!(ks, vec![5, 1]);
/// ```
pub fn allocate_spectrum(
    profiles: &[LayerProfile],
    budget: usize,
    k_caps: Option<&[usize]>,
) -> Vec<usize> {
    let cap = |i: usize| {
        let c = k_caps.and_then(|c| c.get(i).copied()).unwrap_or(usize::MAX);
        profiles[i].max_rank().min(c).max(1)
    };
    // Floor: every layer keeps rank 1 (same guarantee as `ranks::plan`).
    let mut ks: Vec<usize> = vec![1; profiles.len()];
    let mut spent: usize = profiles.iter().map(|p| p.cost()).sum();
    // Budget-independent priority sequence of grants.
    struct Grant {
        gain: f64,
        layer: usize,
        k: usize,
    }
    let mut grants: Vec<Grant> = Vec::new();
    for (i, p) in profiles.iter().enumerate() {
        for k in 1..cap(i) {
            grants.push(Grant { gain: p.spectrum[k] * p.spectrum[k] / p.cost() as f64, layer: i, k });
        }
    }
    // Non-increasing spectra mean per-layer gains are non-increasing, so the
    // (gain desc, layer, k) order lists each layer's grants in rank order.
    grants.sort_by(|a, b| {
        b.gain.total_cmp(&a.gain).then(a.layer.cmp(&b.layer)).then(a.k.cmp(&b.k))
    });
    for g in &grants {
        let cost = profiles[g.layer].cost();
        if spent + cost > budget {
            break; // prefix stop: keeps the allocation monotone in budget
        }
        debug_assert_eq!(ks[g.layer], g.k, "grants must arrive in rank order");
        ks[g.layer] += 1;
        spent += cost;
    }
    ks
}

/// Total squared whitened truncation error of an allocation:
/// `Σ_ℓ Σ_{i≥k_ℓ} σ²_{ℓ,i}` (the quantity water-filling minimizes).
pub fn total_tail_sq(profiles: &[LayerProfile], ks: &[usize]) -> f64 {
    profiles.iter().zip(ks).map(|(p, &k)| p.tail_sq(k)).sum()
}

/// Uniform KV latent rank at `ratio`: `round(ratio · max_rank)` clamped to
/// `[1, max_rank]` — the per-projection cache width `--kv-ratio` names
/// (`r/d` of the full row).
pub fn kv_uniform_rank(ratio: f64, max_rank: usize) -> usize {
    ((ratio * max_rank as f64).round() as usize).clamp(1, max_rank.max(1))
}

/// Spectrum-aware KV latent ranks: water-fill the **latent budget**
/// (`Σ_e cost_e · round(ratio · max_rank_e)` — what uniform `--kv-ratio`
/// would spend across the profiled K/V projections) by whitened marginal
/// gain, so layers whose K/V spectra decay slowly keep wider latents and
/// fast-decaying layers give ranks up.  Same never-worse-than-uniform
/// fallback as [`spectrum_ranks`]: when the greedy prefix strands budget,
/// the uniform ranks are returned, making the guarantee unconditional.
///
/// Entries align with `profiles` (the caller interleaves wk/wv per layer);
/// every entry keeps rank ≥ 1 and ≤ its `max_rank`.
pub fn kv_latent_ranks(profiles: &[LayerProfile], ratio: f64) -> Vec<usize> {
    let uniform: Vec<usize> =
        profiles.iter().map(|p| kv_uniform_rank(ratio, p.max_rank())).collect();
    let budget: usize =
        profiles.iter().zip(&uniform).map(|(p, &r)| p.cost() * r).sum();
    let greedy = allocate_spectrum(profiles, budget, None);
    if total_tail_sq(profiles, &greedy) <= total_tail_sq(profiles, &uniform) {
        greedy
    } else {
        uniform
    }
}

/// Spectrum-driven per-layer total ranks at compression ratio `ratio`,
/// spending exactly the budget the uniform plan would
/// ([`uniform_budget`]) — never more, so uniform and spectrum runs compare
/// like for like.
///
/// Guaranteed no worse than uniform: when the greedy allocation's total
/// whitened tail error exceeds the uniform plan's (the monotone prefix
/// stop can strand budget behind one expensive layer — observed on a few
/// percent of random instances), the uniform ranks are returned instead.
/// Both totals are computed from the profiles, so the check is exact,
/// deterministic, and costs one pass.
pub fn spectrum_ranks(
    profiles: &[LayerProfile],
    ratio: f64,
    k_caps: Option<&[usize]>,
) -> Vec<usize> {
    let cap = |i: usize| {
        let c = k_caps.and_then(|c| c.get(i).copied()).unwrap_or(usize::MAX);
        profiles[i].max_rank().min(c).max(1)
    };
    // `k_budget < min(m,n)` always, so the cap only ever binds when the
    // caller passes explicit `k_caps` (the PJRT padded maxima).
    let uniform: Vec<usize> = profiles
        .iter()
        .enumerate()
        .map(|(i, p)| ranks::k_budget(p.m, p.n, ratio).min(cap(i)))
        .collect();
    let budget = uniform_budget(profiles, ratio);
    let greedy = allocate_spectrum(profiles, budget, k_caps);
    if total_tail_sq(profiles, &greedy) <= total_tail_sq(profiles, &uniform) {
        greedy
    } else {
        uniform
    }
}

/// The α candidates of the per-layer auto-tune — the paper's §4.2 sweep
/// grid (Table 3 sweeps these global α values; `--alpha auto` picks one
/// *per layer* instead).
pub const ALPHA_GRID: [f64; 5] = [0.80, 0.85, 0.90, 0.95, 0.99];

/// Per-layer α auto-tune: decompose the layer at every distinct
/// `(k₁, k₂)` split the [`ALPHA_GRID`] induces at total rank `k`, score
/// each candidate, and return the winning plan.
///
/// The score blends the two failure modes the paper's nested design trades
/// off, both computed from the true residual `E = A − Ã`:
///
/// * **in-distribution**: the activation-weighted energy `‖E·S‖²_F`
///   (= `tr(E·G·Eᵀ)`, since `S·Sᵀ = G` for the nested methods' Cholesky
///   and eigen whiteners) — what stage 1 minimizes;
/// * **out-of-distribution**: the plain energy `‖E‖²_F`, the
///   distribution-free worst-case proxy stage 2's weight anchoring exists
///   to control (§3: "handling unseen activations").
///
/// The ID term is rescaled by `n / ‖S‖²_F` so both terms have the same
/// units (for an isotropically random `E`, `E[‖E·S‖²] = ‖E‖²·‖S‖²/n`), and
/// the blend weights them equally.  The tune is a pure function of
/// `(weight, whitener, k, policy)`, so plans are identical at every worker
/// count; ties keep the smallest α in grid order.
///
/// Cost: ≤ `ALPHA_GRID.len()` extra per-layer decompositions, run inside
/// the engine's parallel planning pass.
pub fn tune_alpha(
    weight: &Tensor,
    w1: &Whitener,
    method: Method,
    ratio: f64,
    k: usize,
    svd: &SvdPolicy,
) -> Result<RankPlan> {
    let (n_in, n_out) = (weight.dims[0], weight.dims[1]);
    let a = Matrix::from_f32(n_in, n_out, &weight.data).transpose(); // m×n
    // ‖S‖²_F = tr(S·Sᵀ) = tr(G), in closed form from the whitener's factor.
    let s_norm_sq = w1.fro_norm_sq(n_in);
    let id_scale = n_in as f64 / s_norm_sq.max(1e-300);
    let mut seen: Vec<(usize, usize)> = Vec::new();
    let mut best: Option<(f64, RankPlan)> = None;
    for &alpha in ALPHA_GRID.iter() {
        let plan = ranks::split_k(k, alpha);
        if seen.contains(&(plan.k1, plan.k2)) {
            continue; // small k collapses grid neighbors onto one split
        }
        seen.push((plan.k1, plan.k2));
        let spec = CompressionSpec { method, ratio, alpha };
        let layer = compress_layer_with_policy(weight, w1, &spec, &plan, svd)?;
        let recon = layer.reconstruct();
        let err = &a - &Matrix::from_f32(n_in, n_out, &recon.data).transpose();
        let id = w1.whiten(&err).fro_norm().powi(2);
        let ood = err.fro_norm().powi(2);
        let score = id * id_scale + ood;
        if best.as_ref().map(|(s, _)| score < *s).unwrap_or(true) {
            best = Some((score, plan));
        }
    }
    Ok(best.expect("ALPHA_GRID is non-empty").1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::whiten::CalibStats;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Random profile with a geometrically decaying spectrum; `decay` near
    /// 1.0 is flat (rank-hungry), near 0 concentrates on one direction.
    fn profile(name: &str, m: usize, n: usize, decay: f64, scale: f64) -> LayerProfile {
        let r = m.min(n);
        LayerProfile {
            name: name.into(),
            m,
            n,
            spectrum: (0..r).map(|i| scale * decay.powi(i as i32)).collect(),
        }
    }

    fn random_profiles(g: &mut crate::util::prop::Gen) -> Vec<LayerProfile> {
        let layers = g.usize_in(2, 6);
        (0..layers)
            .map(|i| {
                let m = g.usize_in(8, 48);
                let n = g.usize_in(8, 48);
                let decay = g.f64_in(0.3, 0.99);
                let scale = g.f64_in(0.1, 10.0);
                profile(&format!("l{i}"), m, n, decay, scale)
            })
            .collect()
    }

    fn spend(profiles: &[LayerProfile], ks: &[usize]) -> usize {
        profiles.iter().zip(ks).map(|(p, &k)| p.cost() * k).sum()
    }

    #[test]
    fn strategy_parses() {
        assert_eq!(AllocStrategy::parse("uniform").unwrap(), AllocStrategy::Uniform);
        assert_eq!(AllocStrategy::parse("SPECTRUM").unwrap(), AllocStrategy::Spectrum);
        assert!(AllocStrategy::parse("greedy").is_err());
        assert_eq!(AllocStrategy::default(), AllocStrategy::Uniform);
    }

    #[test]
    fn allocation_budget_is_exact_within_one_layer() {
        check("Σ cost·k ≤ budget, slack < one layer", 40, |g| {
            let profiles = random_profiles(g);
            let floor: usize = profiles.iter().map(|p| p.cost()).sum();
            let max_spend: usize = profiles.iter().map(|p| p.cost() * p.max_rank()).sum();
            let budget = g.usize_in(floor, max_spend + floor);
            let ks = allocate_spectrum(&profiles, budget, None);
            let spent = spend(&profiles, &ks);
            if spent > budget {
                return Err(format!("spent {spent} > budget {budget}"));
            }
            let saturated = ks
                .iter()
                .enumerate()
                .all(|(i, &k)| k >= profiles[i].max_rank());
            let max_cost = profiles.iter().map(|p| p.cost()).max().unwrap();
            if !saturated && budget - spent >= max_cost {
                return Err(format!(
                    "unspent {} ≥ max layer cost {max_cost} with headroom left",
                    budget - spent
                ));
            }
            if ks.iter().any(|&k| k < 1) {
                return Err("every layer must keep rank ≥ 1".into());
            }
            Ok(())
        });
    }

    #[test]
    fn allocation_is_monotone_in_budget() {
        check("larger budget never shrinks a layer", 40, |g| {
            let profiles = random_profiles(g);
            let floor: usize = profiles.iter().map(|p| p.cost()).sum();
            let max_spend: usize = profiles.iter().map(|p| p.cost() * p.max_rank()).sum();
            let b1 = g.usize_in(floor, max_spend);
            let b2 = g.usize_in(b1, max_spend + floor);
            let k1 = allocate_spectrum(&profiles, b1, None);
            let k2 = allocate_spectrum(&profiles, b2, None);
            for (i, (a, b)) in k1.iter().zip(&k2).enumerate() {
                if b < a {
                    return Err(format!(
                        "layer {i} shrank {a} → {b} when budget grew {b1} → {b2}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn spectrum_never_loses_to_uniform_at_same_budget() {
        check("tail(spectrum) ≤ tail(uniform), spend ≤ uniform spend", 40, |g| {
            let profiles = random_profiles(g);
            let ratio = g.f64_in(0.1, 0.6);
            let ks = spectrum_ranks(&profiles, ratio, None);
            let uniform: Vec<usize> = profiles
                .iter()
                .map(|p| ranks::k_budget(p.m, p.n, ratio))
                .collect();
            let budget = uniform_budget(&profiles, ratio);
            if spend(&profiles, &ks) > budget {
                return Err("spectrum overspent the uniform budget".into());
            }
            let ts = total_tail_sq(&profiles, &ks);
            let tu = total_tail_sq(&profiles, &uniform);
            if ts > tu + 1e-12 * (1.0 + tu) {
                return Err(format!("spectrum tail {ts} > uniform tail {tu}"));
            }
            Ok(())
        });
    }

    #[test]
    fn allocation_prefers_heavy_spectra() {
        // Flat spectrum (all directions matter) vs fast decay: the flat
        // layer must win the extra ranks.
        let profiles = vec![
            profile("flat", 64, 64, 1.0, 1.0),
            profile("decayed", 64, 64, 0.5, 1.0),
        ];
        let ks = spectrum_ranks(&profiles, 0.5, None);
        assert!(ks[0] > ks[1], "flat spectrum should win ranks: {ks:?}");
        assert!(ks.iter().all(|&k| k >= 1));
    }

    #[test]
    fn identical_layers_allocate_near_uniformly() {
        check("identical layers stay within one rank", 10, |g| {
            let n = g.usize_in(16, 64);
            let p = profile("l", n, n, 0.9, 1.0);
            let profiles = vec![p.clone(), p.clone(), p];
            let ks = spectrum_ranks(&profiles, 0.4, None);
            let spread = ks.iter().max().unwrap() - ks.iter().min().unwrap();
            if spread > 1 {
                return Err(format!("identical layers diverged: {ks:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn k_caps_bind_the_allocation() {
        let profiles = vec![
            profile("hot", 32, 32, 1.0, 10.0),
            profile("cold", 32, 32, 0.4, 1.0),
        ];
        let caps = vec![4usize, 4];
        let ks = allocate_spectrum(&profiles, usize::MAX, Some(&caps[..]));
        assert!(ks.iter().zip(&caps).all(|(k, c)| k <= c), "caps violated: {ks:?}");
        // Without caps the same (infinite) budget saturates max_rank.
        let free = allocate_spectrum(&profiles, usize::MAX, None);
        assert_eq!(free, vec![32, 32]);
    }

    #[test]
    fn kv_compress_latent_ranks_meet_budget_and_never_lose_to_uniform() {
        check("kv latent ranks: spend ≤ budget, tail ≤ uniform", 40, |g| {
            let profiles = random_profiles(g);
            let ratio = g.f64_in(0.1, 0.9);
            let uniform: Vec<usize> = profiles
                .iter()
                .map(|p| kv_uniform_rank(ratio, p.max_rank()))
                .collect();
            let budget: usize =
                profiles.iter().zip(&uniform).map(|(p, &r)| p.cost() * r).sum();
            let ks = kv_latent_ranks(&profiles, ratio);
            if spend(&profiles, &ks) > budget {
                return Err(format!(
                    "kv ranks overspent: {} > {budget}",
                    spend(&profiles, &ks)
                ));
            }
            let ts = total_tail_sq(&profiles, &ks);
            let tu = total_tail_sq(&profiles, &uniform);
            if ts > tu + 1e-12 * (1.0 + tu) {
                return Err(format!("kv spectrum tail {ts} > uniform tail {tu}"));
            }
            for (i, (&k, p)) in ks.iter().zip(&profiles).enumerate() {
                if k < 1 || k > p.max_rank() {
                    return Err(format!("entry {i}: rank {k} outside [1, {}]", p.max_rank()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kv_compress_uniform_rank_clamps() {
        assert_eq!(kv_uniform_rank(0.5, 128), 64);
        assert_eq!(kv_uniform_rank(0.25, 128), 32);
        assert_eq!(kv_uniform_rank(1.0, 128), 128);
        assert_eq!(kv_uniform_rank(0.0, 128), 1);
        assert_eq!(kv_uniform_rank(0.004, 128), 1, "rounds to 1, not 0");
        assert_eq!(kv_uniform_rank(2.0, 16), 16, "never exceeds max_rank");
    }

    #[test]
    fn tune_alpha_splits_the_budget_exactly_and_deterministically() {
        let mut rng = Rng::new(31);
        let (n_in, n_out) = (14usize, 10usize);
        let x = Matrix::randn(3 * n_in, n_in, 1.0, &mut rng);
        let mut stats = CalibStats::new(n_in);
        stats.gram = x.gram();
        stats.rows = 3 * n_in;
        let w1 = Whitener::cholesky(&stats);
        let weight = Tensor {
            dims: vec![n_in, n_out],
            data: Matrix::randn(n_in, n_out, 1.0, &mut rng).to_f32(),
        };
        for k in [2usize, 5, 8] {
            let plan =
                tune_alpha(&weight, &w1, Method::NsvdI, 0.3, k, &SvdPolicy::exact()).unwrap();
            assert_eq!(plan.k, k);
            assert_eq!(plan.k1 + plan.k2, k, "split must consume the whole budget");
            assert!(plan.k1 >= 1);
            let again =
                tune_alpha(&weight, &w1, Method::NsvdI, 0.3, k, &SvdPolicy::exact()).unwrap();
            assert_eq!(plan, again, "tune must be deterministic");
        }
    }
}
