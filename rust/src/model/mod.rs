//! Transformer model substrate: configs, NSVDW weights, native forward.
//!
//! The native f32 forward is the **parity oracle** for the PJRT path: an
//! integration test pins `forward::loss` against the executed HLO artifact,
//! which transitively validates the whole python→HLO→rust chain.  It also
//! serves evaluation when artifacts are absent.

pub mod config;
pub mod forward;
pub mod generate;
pub mod kvc;
pub mod weights;

pub use config::{Family, ModelConfig};
pub use kvc::KvCompression;
pub use weights::Weights;
