//! Autoregressive generation with a KV cache — the deployment-side feature
//! that makes the compressed model usable beyond scoring.
//!
//! The cache stores per-layer K/V rows ([t, heads, hd]) so each new token
//! costs one forward step over a single row instead of re-running the whole
//! prefix.  Works with any [`LinearOverride`] (dense or compressed), so the
//! NSVD-compressed model generates through the exact same code path.

use super::config::{Family, ModelConfig};
use super::forward::{matmul_f32, LinearOverride};
use super::kvc::KvCompression;
use super::weights::Weights;
use crate::util::rng::Rng;
use anyhow::Result;

/// Per-layer key/value cache.  Row widths are per layer: `d_model` for an
/// uncompressed layer, the latent rank for a layer under KV-cache
/// compression ([`KvCache::with_kvc`] — see [`crate::model::kvc`]).
pub struct KvCache {
    /// [layer][t * width] rows, appended per step.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    pub len: usize,
    /// Per-layer stored K row width.
    wk: Vec<usize>,
    /// Per-layer stored V row width.
    wv: Vec<usize>,
}

impl KvCache {
    /// Cache sized for the model's configured maximum sequence length
    /// (`cfg.max_seq`).  Use [`KvCache::with_capacity`] when the caller
    /// knows the exact prompt + generation length.
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::with_capacity(cfg, cfg.max_seq)
    }

    /// Preallocate per-layer K/V storage for `max_len` positions so the hot
    /// decode loop never reallocates mid-generation.  `max_len` is a
    /// capacity hint, not a hard limit — pushing past it still works (the
    /// backing `Vec`s grow), it just pays the reallocation the hint was
    /// meant to avoid.
    pub fn with_capacity(cfg: &ModelConfig, max_len: usize) -> KvCache {
        KvCache::with_kvc(cfg, max_len, None)
    }

    /// Cache whose per-layer row widths follow `kvc`: compressed layers
    /// store rank-wide latents (pre-RoPE), identity layers full `d_model`
    /// rows.  `None` is exactly [`KvCache::with_capacity`].
    pub fn with_kvc(cfg: &ModelConfig, max_len: usize, kvc: Option<&KvCompression>) -> KvCache {
        let d = cfg.d_model;
        let wk: Vec<usize> =
            (0..cfg.n_layers).map(|l| kvc.map_or(d, |c| c.width_k(l, d))).collect();
        let wv: Vec<usize> =
            (0..cfg.n_layers).map(|l| kvc.map_or(d, |c| c.width_v(l, d))).collect();
        KvCache {
            k: wk.iter().map(|w| Vec::with_capacity(max_len * w)).collect(),
            v: wv.iter().map(|w| Vec::with_capacity(max_len * w)).collect(),
            len: 0,
            wk,
            wv,
        }
    }

    fn push(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.wk[layer]);
        debug_assert_eq!(v_row.len(), self.wv[layer]);
        self.k[layer].extend_from_slice(k_row);
        self.v[layer].extend_from_slice(v_row);
    }

    /// Contiguous K rows `[0, t_now)` of `layer` ([t_now * width]).
    fn k_hist(&self, layer: usize, t_now: usize) -> &[f32] {
        &self.k[layer][..t_now * self.wk[layer]]
    }

    /// Contiguous V rows `[0, t_now)` of `layer` ([t_now * width]).
    fn v_hist(&self, layer: usize, t_now: usize) -> &[f32] {
        &self.v[layer][..t_now * self.wv[layer]]
    }
}

pub(crate) fn rmsnorm_row(x: &mut [f32], w: &[f32]) {
    let d = x.len();
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for (v, &g) in x.iter_mut().zip(w) {
        *v *= inv * g;
    }
}

pub(crate) fn layernorm_row(x: &mut [f32], w: &[f32], b: &[f32]) {
    let d = x.len();
    let mu: f32 = x.iter().sum::<f32>() / d as f32;
    let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for j in 0..d {
        x[j] = (x[j] - mu) * inv * w[j] + b[j];
    }
}

pub(crate) fn rope_row(x: &mut [f32], heads: usize, hd: usize, pos: usize) {
    let half = hd / 2;
    for h in 0..heads {
        let base = h * hd;
        for i in 0..half {
            let freq = 1.0 / 10000f32.powf(i as f32 / half as f32);
            let (s, c) = (pos as f32 * freq).sin_cos();
            let x1 = x[base + i];
            let x2 = x[base + half + i];
            x[base + i] = x1 * c - x2 * s;
            x[base + half + i] = x2 * c + x1 * s;
        }
    }
}

/// Causal attention of ONE query row over a contiguous K/V history.
///
/// `q` is the RoPE'd query row (`[heads * hd]`), `k_hist`/`v_hist` are the
/// first `t_now` cached rows of one layer (`[t_now * heads * hd]`), and the
/// scores run over positions `[lo, t_now)` (sliding window already folded
/// into `lo`).  Results accumulate into `att` (caller zeroes it).
///
/// This is the single implementation shared by the sequential
/// [`decode_step`] and the batched step of the generation server
/// ([`crate::serve::step::decode_step_batched`]) — sharing it (and the
/// exact float-op order inside) is what makes the batched path
/// bit-identical to the sequential one per request.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_row(
    q: &[f32],
    k_hist: &[f32],
    v_hist: &[f32],
    heads: usize,
    hd: usize,
    scale: f32,
    lo: usize,
    t_now: usize,
    att: &mut [f32],
) {
    let d = heads * hd;
    // One scores buffer reused across heads (clear keeps the capacity);
    // the per-element float-op order is untouched.
    let mut scores = Vec::with_capacity(t_now - lo);
    for hh in 0..heads {
        let qoff = hh * hd;
        scores.clear();
        let mut max_s = f32::NEG_INFINITY;
        for si in lo..t_now {
            let krow = &k_hist[si * d..(si + 1) * d];
            let mut dot = 0.0f32;
            for u in 0..hd {
                dot += q[qoff + u] * krow[qoff + u];
            }
            let s = dot * scale;
            max_s = max_s.max(s);
            scores.push(s);
        }
        let mut denom = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - max_s).exp();
            denom += *s;
        }
        for (idx, si) in (lo..t_now).enumerate() {
            let w = scores[idx] / denom;
            let vrow = &v_hist[si * d..(si + 1) * d];
            for u in 0..hd {
                att[qoff + u] += w * vrow[qoff + u];
            }
        }
    }
}

/// One incremental decode step: feed token at position `pos`, return logits.
/// Delegates to [`decode_step_kv`] with no KV-cache compression.
///
/// LOCKSTEP WARNING: the generation server's batched twin
/// ([`crate::serve::step::decode_step_batched`]) mirrors this function
/// operation-for-operation and is pinned bit-identical per request by the
/// serve parity tests — any model change here must be made there too.
pub fn decode_step(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    cache: &mut KvCache,
    token: u8,
    pos: usize,
) -> Result<Vec<f32>> {
    decode_step_kv(cfg, weights, overrides, None, cache, token, pos)
}

/// [`decode_step`] with optional KV-cache compression: a compressed
/// layer's K/V projection is REPLACED by the fused down-projection
/// ([`crate::model::kvc::KvProj::project`] — the latent is what the cache
/// stores, pre-RoPE), and at attention time the whole latent history is
/// up-projected and (for RoPE families, K only) rotated per absolute
/// position.  `cache` must have been built with the same compression
/// ([`KvCache::with_kvc`]).  This is the single-request **parity oracle**
/// for the batched server path
/// ([`crate::serve::step::decode_step_batched_kv`]): both reconstruct
/// latents through the same row-independent GEMMs, so they agree
/// bit-for-bit per request.  With `kvc` `None` (or all-identity) this is
/// bit-identical to the uncompressed decode — it IS the same code path.
pub fn decode_step_kv(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    kvc: Option<&KvCompression>,
    cache: &mut KvCache,
    token: u8,
    pos: usize,
) -> Result<Vec<f32>> {
    let d = cfg.d_model;
    let heads = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let mut x = weights.get("tok_emb")?.row(token as usize).to_vec();
    if cfg.family == Family::Opt {
        let pos_emb = weights.get("pos_emb")?;
        for j in 0..d {
            x[j] += pos_emb.at2(pos.min(cfg.max_seq - 1), j);
        }
    }
    let lin = |name: &str, h: &[f32]| -> Result<Vec<f32>> {
        if let Some(y) = overrides.apply(name, h, 1, h.len()) {
            return Ok(y);
        }
        Ok(matmul_f32(h, 1, h.len(), weights.get(name)?))
    };
    for i in 0..cfg.n_layers {
        let kp = kvc.and_then(|c| c.layers.get(i)).and_then(|l| l.k.as_ref());
        let vp = kvc.and_then(|c| c.layers.get(i)).and_then(|l| l.v.as_ref());
        let mut h = x.clone();
        match cfg.family {
            Family::Opt => layernorm_row(
                &mut h,
                &weights.get(&format!("blocks.{i}.attn_norm.w"))?.data,
                &weights.get(&format!("blocks.{i}.attn_norm.b"))?.data,
            ),
            _ => rmsnorm_row(&mut h, &weights.get(&format!("blocks.{i}.attn_norm.w"))?.data),
        }
        let mut q = lin(&format!("blocks.{i}.attn.wq"), &h)?;
        if cfg.family.uses_rope() {
            rope_row(&mut q, heads, hd, pos);
        }
        // Fused down-projection: the latent GEMM *replaces* the dense K/V
        // projection (and any weight-compression override of it) — the
        // cache stores the latent, pre-RoPE (RoPE is a per-position map in
        // d-space and cannot live in latent space).
        let k = match kp {
            Some(p) => p.project(&h, 1),
            None => {
                let mut k = lin(&format!("blocks.{i}.attn.wk"), &h)?;
                if cfg.family.uses_rope() {
                    rope_row(&mut k, heads, hd, pos);
                }
                k
            }
        };
        let v = match vp {
            Some(p) => p.project(&h, 1),
            None => lin(&format!("blocks.{i}.attn.wv"), &h)?,
        };
        cache.push(i, &k, &v);
        // Attention over the cache (sliding window if configured).
        // Compressed layers up-project the latent history first; K rows
        // are then RoPE'd at their absolute positions.
        let t_now = pos + 1;
        let lo = if cfg.window > 0 { t_now.saturating_sub(cfg.window) } else { 0 };
        let mut att = vec![0.0f32; d];
        let k_store: Vec<f32>;
        let v_store: Vec<f32>;
        let k_hist: &[f32] = match kp {
            Some(p) => {
                debug_assert_eq!(p.d_out, d, "K up-projection must restore d_model");
                let mut full = p.reconstruct(cache.k_hist(i, t_now), t_now);
                if cfg.family.uses_rope() {
                    for (j, krow) in full.chunks_mut(d).enumerate() {
                        rope_row(krow, heads, hd, j);
                    }
                }
                k_store = full;
                &k_store
            }
            None => cache.k_hist(i, t_now),
        };
        let v_hist: &[f32] = match vp {
            Some(p) => {
                debug_assert_eq!(p.d_out, d, "V up-projection must restore d_model");
                v_store = p.reconstruct(cache.v_hist(i, t_now), t_now);
                &v_store
            }
            None => cache.v_hist(i, t_now),
        };
        attend_row(&q, k_hist, v_hist, heads, hd, scale, lo, t_now, &mut att);
        let o = lin(&format!("blocks.{i}.attn.wo"), &att)?;
        for (xv, ov) in x.iter_mut().zip(&o) {
            *xv += ov;
        }
        let mut h = x.clone();
        match cfg.family {
            Family::Opt => layernorm_row(
                &mut h,
                &weights.get(&format!("blocks.{i}.mlp_norm.w"))?.data,
                &weights.get(&format!("blocks.{i}.mlp_norm.b"))?.data,
            ),
            _ => rmsnorm_row(&mut h, &weights.get(&format!("blocks.{i}.mlp_norm.w"))?.data),
        }
        let m = if cfg.family == Family::Opt {
            let mut u = lin(&format!("blocks.{i}.mlp.fc1"), &h)?;
            for uv in u.iter_mut() {
                *uv = uv.max(0.0);
            }
            lin(&format!("blocks.{i}.mlp.fc2"), &u)?
        } else {
            let mut g = lin(&format!("blocks.{i}.mlp.w_gate"), &h)?;
            let u = lin(&format!("blocks.{i}.mlp.w_up"), &h)?;
            for (gv, uv) in g.iter_mut().zip(&u) {
                let sg = *gv / (1.0 + (-*gv).exp());
                *gv = sg * uv;
            }
            lin(&format!("blocks.{i}.mlp.w_down"), &g)?
        };
        for (xv, mv) in x.iter_mut().zip(&m) {
            *xv += mv;
        }
    }
    match cfg.family {
        Family::Opt => layernorm_row(
            &mut x,
            &weights.get("final_norm.w")?.data,
            &weights.get("final_norm.b")?.data,
        ),
        _ => rmsnorm_row(&mut x, &weights.get("final_norm.w")?.data),
    }
    cache.len = pos + 1;
    Ok(matmul_f32(&x, 1, d, weights.get("lm_head")?))
}

/// Sampling configuration.
#[derive(Clone, Copy, Debug)]
pub struct SampleConfig {
    pub temperature: f32,
    /// Top-k cutoff (0 = full distribution).
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SampleConfig {
    fn default() -> Self {
        SampleConfig { temperature: 0.9, top_k: 40, seed: 0 }
    }
}

/// Generate `n_new` tokens after `prompt` (greedy when temperature == 0).
/// Delegates to [`generate_kv`] with no KV-cache compression.
pub fn generate(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    prompt: &[u8],
    n_new: usize,
    sample: SampleConfig,
) -> Result<Vec<u8>> {
    generate_kv(cfg, weights, overrides, None, prompt, n_new, sample)
}

/// [`generate`] through a compressed KV cache (see [`decode_step_kv`]) —
/// the single-request reference the serve fuzz battery compares the
/// batched, paged, compressed server output against, bit for bit.
pub fn generate_kv(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    kvc: Option<&KvCompression>,
    prompt: &[u8],
    n_new: usize,
    sample: SampleConfig,
) -> Result<Vec<u8>> {
    assert!(!prompt.is_empty(), "prompt must be non-empty");
    // The final sampled token is never fed back (its logits would be
    // discarded), so the cache holds prompt + n_new - 1 positions and the
    // last loop iteration skips the decode — same tokens, one fewer full
    // transformer step per request.  The generation server's batched path
    // makes the same skip.
    let mut cache = KvCache::with_kvc(cfg, prompt.len() + n_new.saturating_sub(1), kvc);
    let mut rng = Rng::new(sample.seed);
    let mut logits = Vec::new();
    for (pos, &t) in prompt.iter().enumerate() {
        logits = decode_step_kv(cfg, weights, overrides, kvc, &mut cache, t, pos)?;
    }
    let mut out = Vec::with_capacity(n_new);
    let mut pos = prompt.len();
    for i in 0..n_new {
        let next = sample_token(&logits, sample, &mut rng);
        out.push(next);
        if i + 1 < n_new {
            logits = decode_step_kv(cfg, weights, overrides, kvc, &mut cache, next, pos)?;
            pos += 1;
        }
    }
    Ok(out)
}

/// Sample the next token from `logits` under `sc` (greedy when
/// `temperature <= 0`, top-k softmax otherwise).  Pure function of
/// `(logits, sc, rng state)` — the generation server gives every request
/// its own seeded [`Rng`] so co-batched neighbors can never perturb a
/// request's sampling stream.
pub fn sample_token(logits: &[f32], sc: SampleConfig, rng: &mut Rng) -> u8 {
    if sc.temperature <= 0.0 {
        let (mut best, mut best_v) = (0usize, f32::NEG_INFINITY);
        for (i, &l) in logits.iter().enumerate() {
            if l > best_v {
                best = i;
                best_v = l;
            }
        }
        return best as u8;
    }
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    let k = if sc.top_k == 0 { logits.len() } else { sc.top_k.min(logits.len()) };
    let top = &idx[..k];
    let max = logits[top[0]];
    let weights: Vec<f64> = top
        .iter()
        .map(|&i| (((logits[i] - max) / sc.temperature) as f64).exp())
        .collect();
    top[rng.categorical(&weights)] as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{forward_logits, random_weights, NoOverride};

    fn tiny() -> (ModelConfig, Weights) {
        let mut cfg = ModelConfig::builtin("llama-t").unwrap();
        cfg.n_layers = 2;
        cfg.linear_shapes
            .retain(|(n, _, _)| n.contains("blocks.0") || n.contains("blocks.1"));
        let w = random_weights(&cfg, 21);
        (cfg, w)
    }

    #[test]
    fn decode_matches_batch_forward() {
        // Incremental KV-cached decoding must reproduce the batched forward's
        // last-position logits exactly (same math, different dataflow).
        let (cfg, w) = tiny();
        let tokens: Vec<u8> = vec![10, 200, 37, 99, 4, 150, 7, 61];
        let t = tokens.len();
        let toks_i32: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
        let batch = forward_logits(&cfg, &w, &NoOverride, &toks_i32, 1, t, None).unwrap();
        let mut cache = KvCache::new(&cfg);
        let mut last = Vec::new();
        for (pos, &tok) in tokens.iter().enumerate() {
            last = decode_step(&cfg, &w, &NoOverride, &mut cache, tok, pos).unwrap();
        }
        let v = cfg.vocab;
        let batch_last = &batch.logits[(t - 1) * v..t * v];
        for (a, b) in last.iter().zip(batch_last) {
            assert!((a - b).abs() < 5e-4, "decode {a} vs batch {b}");
        }
    }

    #[test]
    fn decode_matches_batch_forward_all_families() {
        for name in ["opt-t", "mistral-t"] {
            let mut cfg = ModelConfig::builtin(name).unwrap();
            cfg.n_layers = 2;
            cfg.linear_shapes
                .retain(|(n, _, _)| n.contains("blocks.0") || n.contains("blocks.1"));
            // Mistral window smaller than the sequence to exercise the
            // sliding-window cache path.
            if name == "mistral-t" {
                cfg.window = 4;
            }
            let w = random_weights(&cfg, 22);
            let tokens: Vec<u8> = (0..10).map(|i| (i * 37 % 251) as u8).collect();
            let toks_i32: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
            let batch =
                forward_logits(&cfg, &w, &NoOverride, &toks_i32, 1, tokens.len(), None).unwrap();
            let mut cache = KvCache::new(&cfg);
            let mut last = Vec::new();
            for (pos, &tok) in tokens.iter().enumerate() {
                last = decode_step(&cfg, &w, &NoOverride, &mut cache, tok, pos).unwrap();
            }
            let v = cfg.vocab;
            let batch_last = &batch.logits[(tokens.len() - 1) * v..tokens.len() * v];
            for (a, b) in last.iter().zip(batch_last) {
                assert!((a - b).abs() < 5e-4, "{name}: decode {a} vs batch {b}");
            }
        }
    }

    #[test]
    fn kv_cache_preallocates_capacity() {
        // The hot decode loop must never reallocate: with_capacity reserves
        // max_len rows per layer up front, and new() defaults to max_seq.
        let (cfg, _w) = tiny();
        let c = KvCache::with_capacity(&cfg, 40);
        assert_eq!(c.k.len(), cfg.n_layers);
        assert!(c.k.iter().all(|v| v.capacity() >= 40 * cfg.d_model));
        assert!(c.v.iter().all(|v| v.capacity() >= 40 * cfg.d_model));
        assert_eq!(c.len, 0);
        let c = KvCache::new(&cfg);
        assert!(c.k.iter().all(|v| v.capacity() >= cfg.max_seq * cfg.d_model));
    }

    /// The `--kv-ratio 1.0` pin at the oracle level: the identity
    /// compression takes literally the uncompressed code path, so logits
    /// and sampled tokens are bit-identical to plain `generate`.
    #[test]
    fn kv_compress_identity_generation_is_bit_identical() {
        let (cfg, w) = tiny();
        let id = KvCompression::identity(cfg.n_layers);
        let sc = SampleConfig { temperature: 0.8, top_k: 16, seed: 5 };
        let plain = generate(&cfg, &w, &NoOverride, b"parity", 10, sc).unwrap();
        let via_kv = generate_kv(&cfg, &w, &NoOverride, Some(&id), b"parity", 10, sc).unwrap();
        assert_eq!(plain, via_kv);
        // And step-level logits agree bit-for-bit.
        let mut c0 = KvCache::new(&cfg);
        let mut c1 = KvCache::with_kvc(&cfg, cfg.max_seq, Some(&id));
        for (pos, &t) in b"parity".iter().enumerate() {
            let a = decode_step(&cfg, &w, &NoOverride, &mut c0, t, pos).unwrap();
            let b = decode_step_kv(&cfg, &w, &NoOverride, Some(&id), &mut c1, t, pos).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "pos {pos}");
            }
        }
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let (cfg, w) = tiny();
        let sc = SampleConfig { temperature: 0.0, top_k: 0, seed: 1 };
        let a = generate(&cfg, &w, &NoOverride, b"hello", 12, sc).unwrap();
        let b = generate(&cfg, &w, &NoOverride, b"hello", 12, sc).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
    }

    #[test]
    fn sampling_respects_top_k_one() {
        // top_k=1 with temperature > 0 degenerates to greedy.
        let (cfg, w) = tiny();
        let greedy = generate(
            &cfg, &w, &NoOverride, b"abc", 8,
            SampleConfig { temperature: 0.0, top_k: 0, seed: 7 },
        )
        .unwrap();
        let topk1 = generate(
            &cfg, &w, &NoOverride, b"abc", 8,
            SampleConfig { temperature: 1.0, top_k: 1, seed: 7 },
        )
        .unwrap();
        assert_eq!(greedy, topk1);
    }
}
