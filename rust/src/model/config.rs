//! Model configurations (mirrors python/compile/model.py CONFIGS).

use crate::util::json::Json;
use anyhow::{bail, Result};

/// Transformer family — decides norm type, MLP type, and position encoding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// RMSNorm + SwiGLU + RoPE (LLaMA / Vicuna).
    Llama,
    /// LayerNorm + ReLU MLP + learned absolute positions (OPT).
    Opt,
    /// LLaMA block + sliding-window attention (Mistral).
    Mistral,
}

impl Family {
    pub fn parse(s: &str) -> Result<Family> {
        Ok(match s {
            "llama" => Family::Llama,
            "opt" => Family::Opt,
            "mistral" => Family::Mistral,
            _ => bail!("unknown family '{s}'"),
        })
    }

    pub fn uses_rope(self) -> bool {
        matches!(self, Family::Llama | Family::Mistral)
    }
}

/// Static model description (matches the python side field-for-field).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub family: Family,
    /// Architecture key — vicuna-t shares llama-t's lowered artifacts.
    pub arch: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub window: usize, // 0 = full causal
    pub vocab: usize,
    /// [in, out] shapes of every compressible linear weight.
    pub linear_shapes: Vec<(String, usize, usize)>,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parse from the manifest's `models.<name>` object.
    pub fn from_manifest(name: &str, meta: &Json) -> Result<ModelConfig> {
        let get = |k: &str| -> Result<usize> {
            meta.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("model {name}: missing field {k}"))
        };
        let family = Family::parse(
            meta.get("family").and_then(Json::as_str).unwrap_or_default(),
        )?;
        let arch = meta
            .get("arch")
            .and_then(Json::as_str)
            .unwrap_or(name)
            .to_string();
        let mut linear_shapes = Vec::new();
        if let Some(Json::Obj(shapes)) = meta.get("linear_shapes") {
            for (k, v) in shapes {
                let arr = v.as_arr().unwrap_or(&[]);
                if arr.len() == 2 {
                    linear_shapes.push((
                        k.clone(),
                        arr[0].as_usize().unwrap_or(0),
                        arr[1].as_usize().unwrap_or(0),
                    ));
                }
            }
        }
        linear_shapes.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(ModelConfig {
            name: name.to_string(),
            family,
            arch,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
            max_seq: get("max_seq")?,
            window: get("window")?,
            vocab: get("vocab")?,
            linear_shapes,
        })
    }

    /// The calibration tap feeding a compressible weight (mirrors
    /// `model.tap_for_linear` on the python side).
    pub fn tap_for_linear(name: &str) -> String {
        let parts: Vec<&str> = name.rsplitn(3, '.').collect();
        // name = "blocks.{i}.attn.wq" → parts = ["wq", "attn", "blocks.{i}"]
        let leaf = parts[0];
        let block = parts[2];
        match leaf {
            "wq" | "wk" | "wv" => format!("{block}.attn_in"),
            "wo" => format!("{block}.attn_out_in"),
            "w_gate" | "w_up" | "fc1" => format!("{block}.mlp_in"),
            _ => format!("{block}.mlp_down_in"), // w_down / fc2
        }
    }

    /// Tap names in artifact output order (mirrors `model.tap_names`).
    pub fn tap_names(&self) -> Vec<String> {
        let mut taps = Vec::new();
        for i in 0..self.n_layers {
            taps.push(format!("blocks.{i}.attn_in"));
            taps.push(format!("blocks.{i}.attn_out_in"));
            taps.push(format!("blocks.{i}.mlp_in"));
            taps.push(format!("blocks.{i}.mlp_down_in"));
        }
        taps
    }

    /// Total parameters in the compressible weights.
    pub fn compressible_params(&self) -> usize {
        self.linear_shapes.iter().map(|(_, a, b)| a * b).sum()
    }

    /// Built-in config table for tests / native-only runs (no manifest).
    pub fn builtin(name: &str) -> Result<ModelConfig> {
        let (family, d, l, h, f, w) = match name {
            "llama-t" | "vicuna-t" => (Family::Llama, 128, 4, 4, 256, 0),
            "llama-s" => (Family::Llama, 160, 5, 5, 320, 0),
            "llama-m" => (Family::Llama, 192, 6, 6, 384, 0),
            "opt-t" => (Family::Opt, 128, 4, 4, 384, 0),
            "mistral-t" => (Family::Mistral, 128, 4, 4, 256, 32),
            _ => bail!("unknown builtin model '{name}'"),
        };
        let arch = if name == "vicuna-t" { "llama-t" } else { name };
        let mut linear_shapes = Vec::new();
        for i in 0..l {
            for leaf in ["wq", "wk", "wv", "wo"] {
                linear_shapes.push((format!("blocks.{i}.attn.{leaf}"), d, d));
            }
            if family == Family::Opt {
                linear_shapes.push((format!("blocks.{i}.mlp.fc1"), d, f));
                linear_shapes.push((format!("blocks.{i}.mlp.fc2"), f, d));
            } else {
                linear_shapes.push((format!("blocks.{i}.mlp.w_gate"), d, f));
                linear_shapes.push((format!("blocks.{i}.mlp.w_up"), d, f));
                linear_shapes.push((format!("blocks.{i}.mlp.w_down"), f, d));
            }
        }
        linear_shapes.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(ModelConfig {
            name: name.to_string(),
            family,
            arch: arch.to_string(),
            d_model: d,
            n_layers: l,
            n_heads: h,
            d_ff: f,
            max_seq: 128,
            window: w,
            vocab: 256,
            linear_shapes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_configs_parse() {
        for name in ["llama-t", "llama-s", "llama-m", "vicuna-t", "opt-t", "mistral-t"] {
            let cfg = ModelConfig::builtin(name).unwrap();
            assert_eq!(cfg.d_model % cfg.n_heads, 0, "{name}");
            assert!(!cfg.linear_shapes.is_empty());
        }
        assert!(ModelConfig::builtin("nope").is_err());
    }

    #[test]
    fn vicuna_shares_llama_arch() {
        let v = ModelConfig::builtin("vicuna-t").unwrap();
        assert_eq!(v.arch, "llama-t");
        let l = ModelConfig::builtin("llama-t").unwrap();
        assert_eq!(v.d_model, l.d_model);
    }

    #[test]
    fn tap_mapping_matches_python() {
        assert_eq!(
            ModelConfig::tap_for_linear("blocks.2.attn.wq"),
            "blocks.2.attn_in"
        );
        assert_eq!(
            ModelConfig::tap_for_linear("blocks.0.attn.wo"),
            "blocks.0.attn_out_in"
        );
        assert_eq!(
            ModelConfig::tap_for_linear("blocks.3.mlp.w_gate"),
            "blocks.3.mlp_in"
        );
        assert_eq!(
            ModelConfig::tap_for_linear("blocks.1.mlp.w_down"),
            "blocks.1.mlp_down_in"
        );
        assert_eq!(
            ModelConfig::tap_for_linear("blocks.1.mlp.fc2"),
            "blocks.1.mlp_down_in"
        );
    }

    #[test]
    fn tap_names_order() {
        let cfg = ModelConfig::builtin("llama-t").unwrap();
        let taps = cfg.tap_names();
        assert_eq!(taps.len(), 16);
        assert_eq!(taps[0], "blocks.0.attn_in");
        assert_eq!(taps[5], "blocks.1.attn_out_in");
    }

    #[test]
    fn linear_shapes_sorted_and_sized() {
        let cfg = ModelConfig::builtin("llama-t").unwrap();
        let names: Vec<&str> = cfg.linear_shapes.iter().map(|(n, _, _)| n.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        // 7 weights per block × 4 blocks.
        assert_eq!(cfg.linear_shapes.len(), 28);
        assert_eq!(cfg.compressible_params(), 4 * (4 * 128 * 128 + 3 * 128 * 256));
    }

    #[test]
    fn from_manifest_roundtrip() {
        let json_text = r#"{
            "family": "llama", "arch": "llama-t", "d_model": 128,
            "n_layers": 4, "n_heads": 4, "d_ff": 256, "max_seq": 128,
            "window": 0, "vocab": 256,
            "linear_shapes": {"blocks.0.attn.wq": [128, 128]}
        }"#;
        let meta = crate::util::json::parse(json_text).unwrap();
        let cfg = ModelConfig::from_manifest("llama-t", &meta).unwrap();
        assert_eq!(cfg.family, Family::Llama);
        assert_eq!(cfg.linear_shapes.len(), 1);
        assert_eq!(cfg.head_dim(), 32);
    }
}
