//! Native f32 transformer forward — the parity oracle for the PJRT path.
//!
//! Must match `python/compile/model.py::_forward` op-for-op: RMSNorm /
//! LayerNorm epsilons, the split-halves RoPE convention, the additive -1e30
//! mask, and the SwiGLU/ReLU MLP variants.  An integration test executes the
//! lowered HLO artifact and asserts the two losses agree to f32 tolerance.

use super::config::{Family, ModelConfig};
use super::weights::{Tensor, Weights};
use anyhow::Result;

/// Overrides the dense apply for compressed layers.
pub trait LinearOverride {
    /// If `name` is compressed, compute `x @ W̃[name]` ([rows, in] →
    /// [rows, out]) and return it; `None` falls back to the dense weight.
    fn apply(&self, name: &str, x: &[f32], rows: usize, in_dim: usize) -> Option<Vec<f32>>;
}

/// No-op override (dense forward).
pub struct NoOverride;
impl LinearOverride for NoOverride {
    fn apply(&self, _: &str, _: &[f32], _: usize, _: usize) -> Option<Vec<f32>> {
        None
    }
}

/// Observes tap activations (native calibration fallback + similarity).
pub type TapSink<'a> = dyn FnMut(&str, &[f32], usize, usize) + 'a;

/// f32 matmul: x [rows, k] @ w [k, n] → [rows, n], through the tiled kernel.
pub fn matmul_f32(x: &[f32], rows: usize, k: usize, w: &Tensor) -> Vec<f32> {
    assert_eq!(w.dims.len(), 2);
    assert_eq!(w.dims[0], k, "matmul: x cols {} vs w rows {}", k, w.dims[0]);
    let n = w.dims[1];
    matmul_raw(x, rows, k, &w.data, n)
}

/// f32 matmul over raw slices: x [rows, k] @ w [k, n] — the f32
/// instantiation of the unified tiled+packed kernel
/// ([`crate::linalg::gemm`]), row-parallel when the calling thread's
/// [`gemm::workers`](crate::linalg::gemm::workers) share is > 1 (set by the
/// batched evaluator's `ThreadBudget` split; bit-identical either way).
pub fn matmul_raw(x: &[f32], rows: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
    use crate::linalg::gemm;
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(w.len(), k * n);
    let mut out = vec![0.0f32; rows * n];
    gemm::gemm_nn(rows, k, n, x, w, &mut out, gemm::workers());
    out
}

fn rmsnorm(x: &mut [f32], rows: usize, d: usize, w: &[f32]) {
    for i in 0..rows {
        let row = &mut x[i * d..(i + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for (v, &g) in row.iter_mut().zip(w.iter()) {
            *v *= inv * g;
        }
    }
}

fn layernorm(x: &mut [f32], rows: usize, d: usize, w: &[f32], b: &[f32]) {
    for i in 0..rows {
        let row = &mut x[i * d..(i + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for j in 0..d {
            row[j] = (row[j] - mu) * inv * w[j] + b[j];
        }
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// RoPE cos/sin tables [seq, head_dim] (split-halves convention, must match
/// `model.rope_tables`).
fn rope_tables(seq: usize, head_dim: usize) -> (Vec<f32>, Vec<f32>) {
    let half = head_dim / 2;
    let mut cos = vec![0.0f32; seq * head_dim];
    let mut sin = vec![0.0f32; seq * head_dim];
    for t in 0..seq {
        for i in 0..half {
            let freq = 1.0 / 10000f32.powf(i as f32 / half as f32);
            let angle = t as f32 * freq;
            let (s, c) = angle.sin_cos();
            cos[t * head_dim + i] = c;
            cos[t * head_dim + half + i] = c;
            sin[t * head_dim + i] = s;
            sin[t * head_dim + half + i] = s;
        }
    }
    (cos, sin)
}

/// Apply RoPE in place to q or k laid out as [b, t, heads, hd].
fn apply_rope(x: &mut [f32], b: usize, t: usize, heads: usize, hd: usize, cos: &[f32], sin: &[f32]) {
    let half = hd / 2;
    for bi in 0..b {
        for ti in 0..t {
            for h in 0..heads {
                let base = ((bi * t + ti) * heads + h) * hd;
                let crow = &cos[ti * hd..(ti + 1) * hd];
                let srow = &sin[ti * hd..(ti + 1) * hd];
                // rotate_half: [-x2, x1]
                let mut rotated = vec![0.0f32; hd];
                for i in 0..half {
                    rotated[i] = -x[base + half + i];
                    rotated[half + i] = x[base + i];
                }
                for i in 0..hd {
                    x[base + i] = x[base + i] * crow[i] + rotated[i] * srow[i];
                }
            }
        }
    }
}

/// Forward pass state: logits [b, t, vocab].
pub struct ForwardOutput {
    pub logits: Vec<f32>,
    pub b: usize,
    pub t: usize,
    pub vocab: usize,
}

/// Run the forward pass.  `tokens` is row-major [b, t] (values < vocab).
pub fn forward_logits(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    tokens: &[i32],
    b: usize,
    t: usize,
    mut taps: Option<&mut TapSink>,
) -> Result<ForwardOutput> {
    let d = cfg.d_model;
    let heads = cfg.n_heads;
    let hd = cfg.head_dim();
    let rows = b * t;
    let tok_emb = weights.get("tok_emb")?;
    let mut x = vec![0.0f32; rows * d];
    for (r, &tok) in tokens.iter().enumerate().take(rows) {
        let tok = tok as usize;
        x[r * d..(r + 1) * d].copy_from_slice(tok_emb.row(tok));
    }
    if cfg.family == Family::Opt {
        let pos_emb = weights.get("pos_emb")?;
        for bi in 0..b {
            for ti in 0..t {
                let r = bi * t + ti;
                for j in 0..d {
                    x[r * d + j] += pos_emb.at2(ti, j);
                }
            }
        }
    }
    let (cos, sin) = rope_tables(t, hd);
    let scale = 1.0 / (hd as f32).sqrt();

    let lin = |name: &str, h: &[f32], rows: usize, in_dim: usize,
                   weights: &Weights, taps: &mut Option<&mut TapSink>|
     -> Result<Vec<f32>> {
        if let Some(sink) = taps.as_mut() {
            sink(&ModelConfig::tap_for_linear(name), h, rows, in_dim);
        }
        if let Some(y) = overrides.apply(name, h, rows, in_dim) {
            return Ok(y);
        }
        Ok(matmul_f32(h, rows, in_dim, weights.get(name)?))
    };

    for i in 0..cfg.n_layers {
        // ---- attention ----
        let mut h = x.clone();
        match cfg.family {
            Family::Opt => layernorm(
                &mut h, rows, d,
                &weights.get(&format!("blocks.{i}.attn_norm.w"))?.data,
                &weights.get(&format!("blocks.{i}.attn_norm.b"))?.data,
            ),
            _ => rmsnorm(&mut h, rows, d, &weights.get(&format!("blocks.{i}.attn_norm.w"))?.data),
        }
        let mut q = lin(&format!("blocks.{i}.attn.wq"), &h, rows, d, weights, &mut taps)?;
        let mut k = lin(&format!("blocks.{i}.attn.wk"), &h, rows, d, weights, &mut taps)?;
        let v = lin(&format!("blocks.{i}.attn.wv"), &h, rows, d, weights, &mut taps)?;
        if cfg.family.uses_rope() {
            apply_rope(&mut q, b, t, heads, hd, &cos, &sin);
            apply_rope(&mut k, b, t, heads, hd, &cos, &sin);
        }
        // attention per (batch, head)
        let mut att = vec![0.0f32; rows * d];
        for bi in 0..b {
            for hh in 0..heads {
                for ti in 0..t {
                    let qoff = ((bi * t + ti) * heads + hh) * hd;
                    // scores over allowed keys
                    let lo = if cfg.window > 0 {
                        ti.saturating_sub(cfg.window - 1)
                    } else {
                        0
                    };
                    let mut scores = Vec::with_capacity(ti - lo + 1);
                    let mut max_s = f32::NEG_INFINITY;
                    for si in lo..=ti {
                        let koff = ((bi * t + si) * heads + hh) * hd;
                        let mut dot = 0.0f32;
                        for u in 0..hd {
                            dot += q[qoff + u] * k[koff + u];
                        }
                        let s = dot * scale;
                        max_s = max_s.max(s);
                        scores.push(s);
                    }
                    let mut denom = 0.0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - max_s).exp();
                        denom += *s;
                    }
                    let out_off = ((bi * t + ti) * heads + hh) * hd;
                    for (idx, si) in (lo..=ti).enumerate() {
                        let w = scores[idx] / denom;
                        let voff = ((bi * t + si) * heads + hh) * hd;
                        for u in 0..hd {
                            att[out_off + u] += w * v[voff + u];
                        }
                    }
                }
            }
        }
        let o = lin(&format!("blocks.{i}.attn.wo"), &att, rows, d, weights, &mut taps)?;
        for (xv, ov) in x.iter_mut().zip(o.iter()) {
            *xv += ov;
        }
        // ---- MLP ----
        let mut h = x.clone();
        match cfg.family {
            Family::Opt => layernorm(
                &mut h, rows, d,
                &weights.get(&format!("blocks.{i}.mlp_norm.w"))?.data,
                &weights.get(&format!("blocks.{i}.mlp_norm.b"))?.data,
            ),
            _ => rmsnorm(&mut h, rows, d, &weights.get(&format!("blocks.{i}.mlp_norm.w"))?.data),
        }
        let m = if cfg.family == Family::Opt {
            let mut u = lin(&format!("blocks.{i}.mlp.fc1"), &h, rows, d, weights, &mut taps)?;
            for uv in u.iter_mut() {
                *uv = uv.max(0.0);
            }
            lin(&format!("blocks.{i}.mlp.fc2"), &u, rows, cfg.d_ff, weights, &mut taps)?
        } else {
            let mut g = lin(&format!("blocks.{i}.mlp.w_gate"), &h, rows, d, weights, &mut taps)?;
            let u = lin(&format!("blocks.{i}.mlp.w_up"), &h, rows, d, weights, &mut taps)?;
            for (gv, uv) in g.iter_mut().zip(u.iter()) {
                *gv = silu(*gv) * uv;
            }
            lin(&format!("blocks.{i}.mlp.w_down"), &g, rows, cfg.d_ff, weights, &mut taps)?
        };
        for (xv, mv) in x.iter_mut().zip(m.iter()) {
            *xv += mv;
        }
    }
    match cfg.family {
        Family::Opt => layernorm(
            &mut x, rows, d,
            &weights.get("final_norm.w")?.data,
            &weights.get("final_norm.b")?.data,
        ),
        _ => rmsnorm(&mut x, rows, d, &weights.get("final_norm.w")?.data),
    }
    let logits = matmul_f32(&x, rows, d, weights.get("lm_head")?);
    Ok(ForwardOutput { logits, b, t, vocab: cfg.vocab })
}

/// Next-token (sum_nll, token_count) over `valid_rows` of the batch —
/// identical reduction to `model._nll`.
pub fn nll_from_logits(out: &ForwardOutput, tokens: &[i32], valid_rows: usize) -> (f64, usize) {
    let (t, v) = (out.t, out.vocab);
    let mut sum_nll = 0.0f64;
    let mut count = 0usize;
    for bi in 0..valid_rows.min(out.b) {
        for ti in 0..t - 1 {
            let row = &out.logits[((bi * t) + ti) * v..((bi * t) + ti + 1) * v];
            let target = tokens[bi * t + ti + 1] as usize;
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse: f32 = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
            sum_nll += (lse - row[target]) as f64;
            count += 1;
        }
    }
    (sum_nll, count)
}

/// Convenience: forward + NLL in one call (dense or overridden).
pub fn loss(
    cfg: &ModelConfig,
    weights: &Weights,
    overrides: &dyn LinearOverride,
    tokens: &[i32],
    b: usize,
    t: usize,
    valid_rows: usize,
) -> Result<(f64, usize)> {
    let out = forward_logits(cfg, weights, overrides, tokens, b, t, None)?;
    Ok(nll_from_logits(&out, tokens, valid_rows))
}

/// Synthetic random weights for a config — used by unit tests, property
/// tests, and the perf benches that need a model without artifacts.
pub fn random_weights(cfg: &ModelConfig, seed: u64) -> Weights {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut w = Weights::default();
    let d = cfg.d_model;
    let add = |w: &mut Weights, name: &str, dims: Vec<usize>, scale: f64, rng: &mut Rng| {
        let count: usize = dims.iter().product();
        let data: Vec<f32> = (0..count).map(|_| (rng.normal() * scale) as f32).collect();
        w.set(name, Tensor { dims, data });
    };
    add(&mut w, "tok_emb", vec![cfg.vocab, d], 0.02, &mut rng);
    add(&mut w, "lm_head", vec![d, cfg.vocab], 0.02, &mut rng);
    if cfg.family == Family::Opt {
        add(&mut w, "pos_emb", vec![cfg.max_seq, d], 0.02, &mut rng);
    }
    for (name, n_in, n_out) in &cfg.linear_shapes {
        add(&mut w, name, vec![*n_in, *n_out], 1.0 / (*n_in as f64).sqrt(), &mut rng);
    }
    for i in 0..cfg.n_layers {
        for pre in ["attn_norm", "mlp_norm"] {
            w.set(
                &format!("blocks.{i}.{pre}.w"),
                Tensor { dims: vec![d], data: vec![1.0; d] },
            );
            if cfg.family == Family::Opt {
                w.set(
                    &format!("blocks.{i}.{pre}.b"),
                    Tensor { dims: vec![d], data: vec![0.0; d] },
                );
            }
        }
    }
    w.set("final_norm.w", Tensor { dims: vec![d], data: vec![1.0; d] });
    if cfg.family == Family::Opt {
        w.set("final_norm.b", Tensor { dims: vec![d], data: vec![0.0; d] });
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg(family: &str) -> ModelConfig {
        let name = match family {
            "opt" => "opt-t",
            "mistral" => "mistral-t",
            _ => "llama-t",
        };
        let mut cfg = ModelConfig::builtin(name).unwrap();
        // Shrink for test speed.
        cfg.n_layers = 2;
        cfg.linear_shapes.retain(|(n, _, _)| n.contains("blocks.0") || n.contains("blocks.1"));
        cfg
    }

    fn toks(b: usize, t: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..b * t).map(|_| rng.below(256) as i32).collect()
    }

    #[test]
    fn random_init_loss_near_uniform() {
        for fam in ["llama", "opt", "mistral"] {
            let cfg = tiny_cfg(fam);
            let w = random_weights(&cfg, 1);
            let tokens = toks(2, 24, 2);
            let (nll, count) = loss(&cfg, &w, &NoOverride, &tokens, 2, 24, 2).unwrap();
            let mean = nll / count as f64;
            // ln(256) ≈ 5.545 at uniform.
            assert!((4.0..7.0).contains(&mean), "{fam}: mean nll {mean}");
            assert_eq!(count, 2 * 23);
        }
    }

    #[test]
    fn causality_future_token_does_not_change_past() {
        let cfg = tiny_cfg("llama");
        let w = random_weights(&cfg, 3);
        let mut tokens = toks(1, 16, 4);
        let out_a = forward_logits(&cfg, &w, &NoOverride, &tokens, 1, 16, None).unwrap();
        tokens[10] = (tokens[10] + 7) % 256;
        let out_b = forward_logits(&cfg, &w, &NoOverride, &tokens, 1, 16, None).unwrap();
        let v = cfg.vocab;
        for ti in 0..10 {
            for j in 0..v {
                let a = out_a.logits[ti * v + j];
                let bv = out_b.logits[ti * v + j];
                assert!((a - bv).abs() < 1e-5, "past logit changed at t={ti}");
            }
        }
        let mut changed = false;
        for ti in 10..16 {
            for j in 0..v {
                if (out_a.logits[ti * v + j] - out_b.logits[ti * v + j]).abs() > 1e-4 {
                    changed = true;
                }
            }
        }
        assert!(changed, "future logits should change");
    }

    #[test]
    fn sliding_window_changes_long_range_only() {
        let mut cfg_full = tiny_cfg("llama");
        let mut cfg_win = tiny_cfg("mistral");
        cfg_full.n_layers = 2;
        cfg_win.n_layers = 2;
        // Same weights work for both (same shapes).
        let w = random_weights(&cfg_full, 5);
        let tokens = toks(1, 64, 6);
        let a = forward_logits(&cfg_full, &w, &NoOverride, &tokens, 1, 64, None).unwrap();
        let b = forward_logits(&cfg_win, &w, &NoOverride, &tokens, 1, 64, None).unwrap();
        let v = cfg_full.vocab;
        // Positions < window (32) see identical context.
        for ti in 0..32 {
            for j in 0..v {
                assert!(
                    (a.logits[ti * v + j] - b.logits[ti * v + j]).abs() < 1e-4,
                    "pos {ti} should match"
                );
            }
        }
        let diff: f32 = (32 * v..64 * v)
            .map(|i| (a.logits[i] - b.logits[i]).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-6, "windowed positions should differ");
    }

    #[test]
    fn taps_fire_for_every_linear_class() {
        let cfg = tiny_cfg("llama");
        let w = random_weights(&cfg, 7);
        let tokens = toks(1, 8, 8);
        let mut seen: Vec<String> = Vec::new();
        {
            let mut sink = |tap: &str, _x: &[f32], rows: usize, dim: usize| {
                assert_eq!(rows, 8);
                assert!(dim == cfg.d_model || dim == cfg.d_ff);
                seen.push(tap.to_string());
            };
            forward_logits(&cfg, &w, &NoOverride, &tokens, 1, 8, Some(&mut sink)).unwrap();
        }
        // 7 linears per llama block over 2 blocks = 14 tap events.
        assert_eq!(seen.len(), 14);
        assert!(seen.contains(&"blocks.0.attn_in".to_string()));
        assert!(seen.contains(&"blocks.1.mlp_down_in".to_string()));
    }

    #[test]
    fn override_replaces_dense_apply() {
        struct ZeroWq;
        impl LinearOverride for ZeroWq {
            fn apply(&self, name: &str, _x: &[f32], rows: usize, _in: usize) -> Option<Vec<f32>> {
                if name.ends_with("attn.wq") {
                    Some(vec![0.0; rows * 128])
                } else {
                    None
                }
            }
        }
        let cfg = tiny_cfg("llama");
        let w = random_weights(&cfg, 9);
        let tokens = toks(1, 8, 10);
        let a = forward_logits(&cfg, &w, &NoOverride, &tokens, 1, 8, None).unwrap();
        let b = forward_logits(&cfg, &w, &ZeroWq, &tokens, 1, 8, None).unwrap();
        let diff: f32 = a
            .logits
            .iter()
            .zip(&b.logits)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max);
        assert!(diff > 1e-6, "override should change the output");
    }

    #[test]
    fn matmul_raw_matches_reference() {
        let mut rng = Rng::new(11);
        let (m, k, n) = (7, 13, 9);
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let out = matmul_raw(&x, m, k, &w, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += x[i * k + kk] * w[kk * n + j];
                }
                assert!((out[i * n + j] - acc).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn nll_ignores_padding_rows() {
        let cfg = tiny_cfg("llama");
        let w = random_weights(&cfg, 12);
        let mut tokens = toks(2, 8, 13);
        // Second row is padding garbage; valid_rows = 1 must ignore it.
        let (nll1, c1) = loss(&cfg, &w, &NoOverride, &tokens, 2, 8, 1).unwrap();
        for t in tokens.iter_mut().skip(8) {
            *t = 0;
        }
        let (nll2, c2) = loss(&cfg, &w, &NoOverride, &tokens, 2, 8, 1).unwrap();
        assert_eq!(c1, 7);
        assert_eq!(c1, c2);
        assert!((nll1 - nll2).abs() < 1e-6);
    }
}
