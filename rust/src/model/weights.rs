//! NSVDW weight-file reader (format written by python/compile/weights_io.py).
//!
//! Layout (little-endian):
//!   magic b"NSVDW001" · u32 n_tensors · repeat { u16 name_len · name ·
//!   u8 ndim · u32[ndim] dims · f32[prod dims] row-major data }

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

pub const MAGIC: &[u8; 8] = b"NSVDW001";

/// A named f32 tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.dims.len(), 2);
        let c = self.dims[1];
        &self.data[i * c..(i + 1) * c]
    }
}

/// A complete weight set (sorted name → tensor).
#[derive(Clone, Debug, Default)]
pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(path: &Path) -> Result<Weights> {
        let raw = std::fs::read(path)
            .with_context(|| format!("reading weights {}", path.display()))?;
        Self::parse(&raw).with_context(|| path.display().to_string())
    }

    pub fn parse(raw: &[u8]) -> Result<Weights> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > raw.len() {
                bail!("truncated NSVDW at byte {}", *pos);
            }
            let s = &raw[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 8)? != MAGIC {
            bail!("bad NSVDW magic");
        }
        let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len =
                u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
            let name = std::str::from_utf8(take(&mut pos, name_len)?)
                .context("non-utf8 tensor name")?
                .to_string();
            let ndim = take(&mut pos, 1)?[0] as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(
                    u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize,
                );
            }
            let count: usize = dims.iter().product::<usize>().max(1);
            let bytes = take(&mut pos, 4 * count)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            tensors.insert(name, Tensor { dims, data });
        }
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing tensor '{name}'"))
    }

    /// Names in sorted order — the artifact parameter order contract.
    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.data.len()).sum()
    }

    /// Replace a tensor (used when materializing compressed weights for the
    /// native forward).
    pub fn set(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bytes() -> Vec<u8> {
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&2u32.to_le_bytes());
        // tensor "a": shape [2, 2], data 1..4
        raw.extend_from_slice(&1u16.to_le_bytes());
        raw.extend_from_slice(b"a");
        raw.push(2);
        raw.extend_from_slice(&2u32.to_le_bytes());
        raw.extend_from_slice(&2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        // tensor "b": shape [3], data 5,6,7
        raw.extend_from_slice(&1u16.to_le_bytes());
        raw.extend_from_slice(b"b");
        raw.push(1);
        raw.extend_from_slice(&3u32.to_le_bytes());
        for v in [5.0f32, 6.0, 7.0] {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        raw
    }

    #[test]
    fn parse_sample() {
        let w = Weights::parse(&sample_bytes()).unwrap();
        assert_eq!(w.names(), vec!["a", "b"]);
        let a = w.get("a").unwrap();
        assert_eq!(a.dims, vec![2, 2]);
        assert_eq!(a.at2(1, 0), 3.0);
        assert_eq!(a.row(1), &[3.0, 4.0]);
        assert_eq!(w.total_params(), 7);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(Weights::parse(b"WRONG!!!").is_err());
        let mut raw = sample_bytes();
        raw.truncate(raw.len() - 3);
        assert!(Weights::parse(&raw).is_err());
    }

    #[test]
    fn set_replaces_tensor() {
        let mut w = Weights::parse(&sample_bytes()).unwrap();
        w.set("a", Tensor { dims: vec![1], data: vec![9.0] });
        assert_eq!(w.get("a").unwrap().data, vec![9.0]);
    }
}
