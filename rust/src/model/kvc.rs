//! Runtime K/V-cache compression: per-layer low-rank projections that let
//! the serving cache store **rank-r latents** per position instead of full
//! `d`-wide K/V rows.
//!
//! A [`KvProj`] is a rank-r factorization of one K or V projection weight
//! `w ≈ proj · up` (`proj` is `[n_in, r]`, `up` is `[r, d_out]`).  The
//! down-projection is *fused*: instead of computing the full `d_out`-wide
//! row and shrinking it, the decode step multiplies the normed hidden state
//! by `proj` directly — one GEMM of width `r` replaces the width-`d_out`
//! K/V GEMM, and the latent it produces is what the paged pool stores.  At
//! attention time the gathered latent span is up-projected through `up`
//! (one extra small GEMM per step, batched over the span) and — for RoPE
//! families — rotated per absolute position, because RoPE is a nonlinear
//! per-position map in `d`-space and therefore cannot live in latent space.
//!
//! **Determinism contract.**  Both GEMM paths here (`f32`
//! [`crate::model::forward::matmul_raw`] and int8
//! [`crate::linalg::quant::matmul_quant`]) are row-independent: row `i` of
//! a batched product is bit-identical to the same row computed alone, at
//! every worker count.  So a latent stored once is reconstructed
//! bit-identically no matter which span gathers it — the batched server
//! ([`crate::serve::step::decode_step_batched_kv`]) up-projects per-page
//! spans while the single-request oracle
//! ([`crate::model::generate::generate_kv`]) up-projects the whole history,
//! and both see the same bits per row.  This is what extends the serve
//! bit-parity contract through cache compression.
//!
//! `None` entries mean *identity*: that layer's K or V keeps the full-width
//! uncompressed path, bit-identical to the pre-compression cache by
//! construction.  A `--kv-ratio` of 1.0 produces all-`None` layers
//! ([`KvCompression::identity`]).
//!
//! The factorization itself (whitened, ASVD-style query-scaled) lives in
//! `compress::kv`; this module is runtime-only so `model/` keeps its
//! no-`compress/`-dependency layering.

use crate::linalg::quant::{matmul_quant, quantize_columns, QuantMatrix};
use crate::model::forward::matmul_raw;

/// Rank-r factorization of one K or V projection: `w ≈ proj · up`.
#[derive(Clone, Debug)]
pub struct KvProj {
    /// Input width of the fused down-projection (the model `d_model`).
    pub n_in: usize,
    /// Latent rank `r` — the per-position cache width for this projection.
    pub rank: usize,
    /// Reconstructed width (the original projection's output dim).
    pub d_out: usize,
    /// Fused down-projection factor, row-major `[n_in, rank]` — replaces
    /// the dense K/V weight in the decode step.
    pub proj: Vec<f32>,
    /// Up-projection factor, row-major `[rank, d_out]` — applied to
    /// gathered latent spans at attention time.
    pub up: Vec<f32>,
    /// Optional per-group int8 factors (`--factor-dtype int8`
    /// composition).  Latents in the pool stay f32; only the two factor
    /// GEMMs route through the integer kernel.
    pub quant: Option<KvProjQuant>,
}

/// Int8-quantized factor pair of a [`KvProj`].
#[derive(Clone, Debug)]
pub struct KvProjQuant {
    pub proj: QuantMatrix,
    pub up: QuantMatrix,
}

impl KvProj {
    /// Build from row-major factors (`proj` `[n_in, rank]`, `up`
    /// `[rank, d_out]`).
    pub fn new(n_in: usize, rank: usize, d_out: usize, proj: Vec<f32>, up: Vec<f32>) -> KvProj {
        assert_eq!(proj.len(), n_in * rank, "KvProj: proj shape mismatch");
        assert_eq!(up.len(), rank * d_out, "KvProj: up shape mismatch");
        KvProj { n_in, rank, d_out, proj, up, quant: None }
    }

    /// Fused down-projection: `x [rows, n_in] → latents [rows, rank]`.
    /// Row-independent and bit-identical at every worker count (f32 and
    /// int8 paths both).
    pub fn project(&self, x: &[f32], rows: usize) -> Vec<f32> {
        debug_assert_eq!(x.len(), rows * self.n_in);
        match &self.quant {
            Some(q) => {
                let mut out = vec![0.0f32; rows * self.rank];
                matmul_quant(x, rows, &q.proj, &mut out, crate::linalg::gemm::workers());
                out
            }
            None => matmul_raw(x, rows, self.n_in, &self.proj, self.rank),
        }
    }

    /// Up-projection of a gathered latent span:
    /// `latents [rows, rank] → rows of width d_out`.
    pub fn reconstruct(&self, latents: &[f32], rows: usize) -> Vec<f32> {
        debug_assert_eq!(latents.len(), rows * self.rank);
        match &self.quant {
            Some(q) => {
                let mut out = vec![0.0f32; rows * self.d_out];
                matmul_quant(latents, rows, &q.up, &mut out, crate::linalg::gemm::workers());
                out
            }
            None => matmul_raw(latents, rows, self.rank, &self.up, self.d_out),
        }
    }

    /// Quantize both factors to per-group int8 (idempotent).
    pub fn quantize(&mut self, group: usize) {
        if self.quant.is_none() {
            self.quant = Some(KvProjQuant {
                proj: quantize_columns(&self.proj, self.n_in, self.rank, group),
                up: quantize_columns(&self.up, self.rank, self.d_out, group),
            });
        }
    }

    /// Stored factor parameter count `(n_in + d_out) · rank`.
    pub fn params(&self) -> usize {
        (self.n_in + self.d_out) * self.rank
    }

    /// Factor storage bytes under the active dtype (int8 codes + f32
    /// scales when quantized, 4 bytes per f32 element otherwise).
    pub fn factor_bytes(&self) -> usize {
        match &self.quant {
            Some(q) => q.proj.bytes() + q.up.bytes(),
            None => 4 * (self.proj.len() + self.up.len()),
        }
    }
}

/// One layer's optional K and V compressions (`None` = identity,
/// full-width uncompressed cache for that projection).
#[derive(Clone, Debug, Default)]
pub struct KvLayer {
    pub k: Option<KvProj>,
    pub v: Option<KvProj>,
}

/// Per-layer K/V cache compression for a whole model.
#[derive(Clone, Debug, Default)]
pub struct KvCompression {
    /// One entry per transformer layer, in layer order.
    pub layers: Vec<KvLayer>,
}

impl KvCompression {
    /// The identity compression: every layer keeps the full-width cache.
    /// This is what `--kv-ratio 1.0` resolves to, and it is bit-identical
    /// to the uncompressed pool by construction.
    pub fn identity(n_layers: usize) -> KvCompression {
        KvCompression { layers: (0..n_layers).map(|_| KvLayer::default()).collect() }
    }

    /// True when no layer carries a projection (the `--kv-ratio 1.0`
    /// degenerate case).
    pub fn is_identity(&self) -> bool {
        self.layers.iter().all(|l| l.k.is_none() && l.v.is_none())
    }

    /// Cached K width of `layer`: the latent rank, or `d` when identity.
    pub fn width_k(&self, layer: usize, d: usize) -> usize {
        self.layers.get(layer).and_then(|l| l.k.as_ref()).map_or(d, |p| p.rank)
    }

    /// Cached V width of `layer`: the latent rank, or `d` when identity.
    pub fn width_v(&self, layer: usize, d: usize) -> usize {
        self.layers.get(layer).and_then(|l| l.v.as_ref()).map_or(d, |p| p.rank)
    }

    /// Total stored factor parameters across all layers.
    pub fn params(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.k.as_ref().map_or(0, KvProj::params) + l.v.as_ref().map_or(0, KvProj::params)
            })
            .sum()
    }

    /// Total factor storage bytes across all layers (dtype-aware).
    pub fn factor_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.k.as_ref().map_or(0, KvProj::factor_bytes)
                    + l.v.as_ref().map_or(0, KvProj::factor_bytes)
            })
            .sum()
    }

    /// Quantize every projection's factors to per-group int8.
    pub fn quantize(&mut self, group: usize) {
        for l in self.layers.iter_mut() {
            if let Some(p) = l.k.as_mut() {
                p.quantize(group);
            }
            if let Some(p) = l.v.as_mut() {
                p.quantize(group);
            }
        }
    }

    /// True when any projection carries int8 factors.
    pub fn is_quantized(&self) -> bool {
        self.layers.iter().any(|l| {
            l.k.as_ref().is_some_and(|p| p.quant.is_some())
                || l.v.as_ref().is_some_and(|p| p.quant.is_some())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_proj(n_in: usize, rank: usize, d_out: usize, seed: u64) -> KvProj {
        let mut rng = Rng::new(seed);
        let proj: Vec<f32> =
            (0..n_in * rank).map(|_| (rng.normal() * 0.3) as f32).collect();
        let up: Vec<f32> =
            (0..rank * d_out).map(|_| (rng.normal() * 0.3) as f32).collect();
        KvProj::new(n_in, rank, d_out, proj, up)
    }

    /// Row-independence is the foundation of the cache-parity contract:
    /// row i of a batched project/reconstruct must be bit-identical to the
    /// same row pushed through alone.
    #[test]
    fn kv_compress_projection_rows_are_batch_invariant() {
        let p = random_proj(16, 5, 16, 3);
        let mut rng = Rng::new(4);
        let rows = 7;
        let x: Vec<f32> = (0..rows * 16).map(|_| rng.normal() as f32).collect();
        let batched = p.project(&x, rows);
        for r in 0..rows {
            let single = p.project(&x[r * 16..(r + 1) * 16], 1);
            assert_eq!(&batched[r * 5..(r + 1) * 5], &single[..], "project row {r}");
        }
        let rec_b = p.reconstruct(&batched, rows);
        for r in 0..rows {
            let single = p.reconstruct(&batched[r * 5..(r + 1) * 5], 1);
            assert_eq!(&rec_b[r * 16..(r + 1) * 16], &single[..], "reconstruct row {r}");
        }
    }

    /// Int8 factors keep the same row-independence (per-row activation
    /// quantization + order-independent integer accumulation).
    #[test]
    fn kv_compress_int8_projection_rows_are_batch_invariant() {
        let mut p = random_proj(16, 6, 16, 5);
        p.quantize(4);
        assert!(p.quant.is_some());
        let mut rng = Rng::new(6);
        let rows = 5;
        let x: Vec<f32> = (0..rows * 16).map(|_| rng.normal() as f32).collect();
        let batched = p.project(&x, rows);
        for r in 0..rows {
            let single = p.project(&x[r * 16..(r + 1) * 16], 1);
            assert_eq!(&batched[r * 6..(r + 1) * 6], &single[..], "int8 project row {r}");
        }
    }

    #[test]
    fn kv_compress_identity_and_widths() {
        let mut kvc = KvCompression::identity(3);
        assert!(kvc.is_identity());
        assert_eq!(kvc.width_k(0, 32), 32);
        assert_eq!(kvc.width_v(2, 32), 32);
        assert_eq!(kvc.params(), 0);
        assert_eq!(kvc.factor_bytes(), 0);
        kvc.layers[1].k = Some(random_proj(32, 8, 32, 7));
        assert!(!kvc.is_identity());
        assert_eq!(kvc.width_k(1, 32), 8);
        assert_eq!(kvc.width_v(1, 32), 32);
        assert_eq!(kvc.params(), (32 + 32) * 8);
        assert_eq!(kvc.factor_bytes(), 4 * (32 * 8 + 8 * 32));
    }

    #[test]
    fn kv_compress_quantize_shrinks_factor_bytes() {
        let mut kvc = KvCompression::identity(2);
        kvc.layers[0].k = Some(random_proj(64, 16, 64, 9));
        kvc.layers[1].v = Some(random_proj(64, 16, 64, 10));
        let f32_bytes = kvc.factor_bytes();
        kvc.quantize(crate::linalg::quant::DEFAULT_GROUP);
        assert!(kvc.is_quantized());
        let q_bytes = kvc.factor_bytes();
        assert!(
            q_bytes * 2 < f32_bytes,
            "int8 factors must at least halve storage: {q_bytes} vs {f32_bytes}"
        );
    }
}
