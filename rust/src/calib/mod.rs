//! Calibration: activation Gram collection and similarity analysis.
//!
//! * [`collector`] — accumulates per-tap [`crate::compress::whiten::CalibStats`]
//!   over calibration batches, either through the PJRT gram artifact (primary)
//!   or the native forward's tap sink (fallback / parity).
//! * [`similarity`] — Table 2 / Figure 1: cosine similarity between the
//!   calibration activation profile and each evaluation set's profile.

pub mod collector;
pub mod similarity;

pub use collector::TapStats;
pub use similarity::{SimilarityReport, similarity_stats};
