//! Activation-similarity analysis — Table 2 and Figure 1.
//!
//! The paper measures cosine similarity between the activations induced by
//! the calibration set and by each evaluation set.  We reduce each tap's
//! activations to its RMS profile `√(diag(XᵀX)/rows)` (the per-dimension
//! energy signature); per-tap cosine similarities between the calibration
//! profile and the eval profile give a distribution over taps, whose
//! mean/std is Table 2 and whose histogram is Figure 1.

use super::collector::TapStats;
use crate::util::timer::Stats;

/// Similarity distribution of one evaluation set vs the calibration set.
#[derive(Clone, Debug)]
pub struct SimilarityReport {
    pub dataset: String,
    /// Per-tap cosine similarities (one entry per tap, model order).
    pub per_tap: Vec<f64>,
    pub mean: f64,
    pub std: f64,
}

/// Cosine similarity between two non-negative profiles.
pub fn cosine(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na * nb)
}

/// Compare an evaluation set's tap stats against the calibration stats.
///
/// The per-tap feature is the full normalized Gram `G/‖G‖_F` (not just its
/// diagonal): two domains whose activations carry energy in the same
/// *dimensions* but along different *directions* still read as dissimilar —
/// this is the structure the whitening transform actually consumes.
pub fn similarity_stats(dataset: &str, calib: &TapStats, eval: &TapStats) -> SimilarityReport {
    let mut per_tap = Vec::new();
    for (tap, cal_stats) in &calib.taps {
        if let Some(eval_stats) = eval.taps.get(tap) {
            let a = normalized_gram(cal_stats);
            let b = normalized_gram(eval_stats);
            per_tap.push(cosine(&a, &b));
        }
    }
    let s = Stats::from(&per_tap);
    SimilarityReport { dataset: dataset.to_string(), per_tap, mean: s.mean, std: s.std }
}

/// Flattened Frobenius-normalized Gram of a tap.
fn normalized_gram(stats: &crate::compress::whiten::CalibStats) -> Vec<f64> {
    let norm = stats.gram.fro_norm().max(1e-30);
    stats.gram.data.iter().map(|&v| v / norm).collect()
}

impl SimilarityReport {
    /// Histogram over [0, 1] with `bins` buckets — the Figure 1 series.
    pub fn histogram(&self, bins: usize) -> Vec<usize> {
        let mut h = vec![0usize; bins];
        for &s in &self.per_tap {
            let idx = ((s.clamp(0.0, 1.0)) * bins as f64) as usize;
            h[idx.min(bins - 1)] += 1;
        }
        h
    }

    /// ASCII rendering of the histogram (Figure 1 as text).
    pub fn ascii_histogram(&self, bins: usize, width: usize) -> String {
        let h = self.histogram(bins);
        let max = h.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &count) in h.iter().enumerate() {
            let lo = i as f64 / bins as f64;
            let hi = (i + 1) as f64 / bins as f64;
            let bar = "█".repeat(count * width / max);
            out.push_str(&format!("{lo:.2}-{hi:.2} |{bar:<width$}| {count}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::whiten::CalibStats;

    fn stats_with_profile(profile: &[f64], rows: usize) -> CalibStats {
        let n = profile.len();
        let mut s = CalibStats::new(n);
        for i in 0..n {
            s.gram[(i, i)] = profile[i] * profile[i] * rows as f64;
            s.abs_sum[i] = profile[i] * rows as f64;
        }
        s.rows = rows;
        s
    }

    fn tapstats(profiles: &[(&str, Vec<f64>)]) -> TapStats {
        let mut t = TapStats::default();
        for (name, p) in profiles {
            t.taps.insert(name.to_string(), stats_with_profile(p, 10));
        }
        t
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn identical_profiles_give_similarity_one() {
        let cal = tapstats(&[("a", vec![1.0, 2.0, 3.0]), ("b", vec![2.0, 2.0, 1.0])]);
        let rep = similarity_stats("self", &cal, &cal);
        assert_eq!(rep.per_tap.len(), 2);
        assert!((rep.mean - 1.0).abs() < 1e-9);
        assert!(rep.std < 1e-9);
    }

    #[test]
    fn disjoint_profiles_give_low_similarity() {
        let cal = tapstats(&[("a", vec![5.0, 5.0, 0.0, 0.0])]);
        let ood = tapstats(&[("a", vec![0.0, 0.0, 5.0, 5.0])]);
        let rep = similarity_stats("ood", &cal, &ood);
        assert!(rep.mean < 0.05, "mean {}", rep.mean);
    }

    #[test]
    fn histogram_bins_cover_range() {
        let rep = SimilarityReport {
            dataset: "t".into(),
            per_tap: vec![0.05, 0.5, 0.51, 0.95, 1.0],
            mean: 0.6,
            std: 0.3,
        };
        let h = rep.histogram(10);
        assert_eq!(h.iter().sum::<usize>(), 5);
        assert_eq!(h[0], 1);
        assert_eq!(h[5], 2);
        assert_eq!(h[9], 2); // 0.95 and the clamped 1.0
        let ascii = rep.ascii_histogram(10, 20);
        assert!(ascii.lines().count() == 10);
    }

    #[test]
    fn missing_taps_are_skipped() {
        let cal = tapstats(&[("a", vec![1.0, 1.0]), ("b", vec![1.0, 2.0])]);
        let eval = tapstats(&[("a", vec![1.0, 1.0])]);
        let rep = similarity_stats("partial", &cal, &eval);
        assert_eq!(rep.per_tap.len(), 1);
    }
}
