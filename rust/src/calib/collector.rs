//! Per-tap calibration statistics accumulation.
//!
//! The paper's protocol: 256 random sequences from the WikiText-2 train
//! split flow through the dense model; every compressible linear's input
//! activations are reduced to a Gram matrix `XᵀX` and an abs-sum vector.
//! Streaming accumulation (Gram of stacked rows = sum of per-batch Grams) is
//! pinned by a python-side test and re-verified here.
//!
//! Raw activation blocks are no longer reduced by a scalar `O(rows·dim²)`
//! triple loop: [`TapStats::accumulate`] buffers rows inside each tap's
//! [`CalibStats`] and flushes them through the packed SYRK kernel
//! (`linalg/gemm.rs::syrk_tn`, upper triangle only), and
//! [`TapStats::finalize`] mirrors the triangles once after the last batch —
//! so Gram construction inherits the kernel layer's tiling, threads, and
//! worker-count bit-determinism.

use crate::compress::whiten::CalibStats;
use crate::model::config::ModelConfig;
use crate::model::forward::{self, NoOverride};
use crate::model::weights::Weights;
use anyhow::Result;
use std::collections::btree_map::Entry;
use std::collections::BTreeMap;

/// Per-tap statistics for a model.
#[derive(Clone, Debug, Default)]
pub struct TapStats {
    pub taps: BTreeMap<String, CalibStats>,
}

impl TapStats {
    /// Stats for the tap feeding weight `name`.
    pub fn for_linear(&self, name: &str) -> Option<&CalibStats> {
        self.taps.get(&ModelConfig::tap_for_linear(name))
    }

    /// Merge another collection into this one, consuming it: vacant taps
    /// are **moved** in (no per-tap clone on the fan-in path), existing
    /// taps fold Grams/abs-sums/pending buffers together
    /// ([`CalibStats::merge_from`]).
    pub fn merge(&mut self, other: TapStats) {
        for (tap, stats) in other.taps {
            match self.taps.entry(tap) {
                Entry::Occupied(mut e) => e.get_mut().merge_from(stats),
                Entry::Vacant(e) => {
                    e.insert(stats);
                }
            }
        }
    }

    /// Accumulate one raw activation block `x [rows, dim]` into a tap.
    ///
    /// Rows are buffered and flushed through SYRK in batches; call
    /// [`TapStats::finalize`] after the last batch, before the Grams are
    /// consumed.
    pub fn accumulate(&mut self, tap: &str, x: &[f32], rows: usize, dim: usize) {
        let stats = self
            .taps
            .entry(tap.to_string())
            .or_insert_with(|| CalibStats::new(dim));
        assert_eq!(stats.dim(), dim, "tap {tap} dim changed");
        stats.push_rows(x, rows);
    }

    /// Flush every tap's pending rows and mirror the SYRK-built upper
    /// triangles into full symmetric Grams.  Idempotent.
    pub fn finalize(&mut self) {
        let mut sp = crate::obs::span("calib.finalize");
        if sp.is_recording() {
            sp.arg_u64("taps", self.taps.len() as u64);
        }
        for stats in self.taps.values_mut() {
            stats.finalize();
        }
    }

    /// Accumulate pre-reduced Gram/abs-sum blocks (the PJRT artifact path:
    /// the gram executable returns per-batch reductions).
    pub fn accumulate_reduced(
        &mut self,
        tap: &str,
        gram_block: &[f32],
        abs_block: &[f32],
        rows: usize,
        dim: usize,
    ) {
        let stats = self
            .taps
            .entry(tap.to_string())
            .or_insert_with(|| CalibStats::new(dim));
        assert_eq!(gram_block.len(), dim * dim);
        assert_eq!(abs_block.len(), dim);
        for i in 0..dim {
            stats.abs_sum[i] += abs_block[i] as f64;
            for j in 0..dim {
                stats.gram[(i, j)] += gram_block[i * dim + j] as f64;
            }
        }
        stats.rows += rows;
    }
}

/// Collect calibration stats with the native forward (fallback path and the
/// parity oracle for the PJRT gram executable).
pub fn collect_native(
    cfg: &ModelConfig,
    weights: &Weights,
    batches: &[crate::data::batch::TokenBatch],
) -> Result<TapStats> {
    let mut outer_sp = crate::obs::span("calib.collect");
    if outer_sp.is_recording() {
        outer_sp.arg_u64("batches", batches.len() as u64);
    }
    let mut stats = TapStats::default();
    for tb in batches {
        // Note: padding rows would pollute the Gram; calibration batches are
        // always full (asserted here).
        assert_eq!(tb.valid_rows, tb.batch, "calibration batches must be full");
        // A tap fires once per linear it feeds (attn_in feeds wq/wk/wv); the
        // activation is identical, so record it ONCE per batch — mirrors the
        // `if tap not in grams` guard in model.loss_and_grams_fn.
        let mut seen: std::collections::BTreeSet<String> = Default::default();
        let mut sink = |tap: &str, x: &[f32], rows: usize, dim: usize| {
            if seen.insert(tap.to_string()) {
                stats.accumulate(tap, x, rows, dim);
            }
        };
        forward::forward_logits(
            cfg, weights, &NoOverride, &tb.tokens, tb.batch, tb.seq, Some(&mut sink),
        )?;
    }
    stats.finalize();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batch::Batcher;
    use crate::data::corpus::Corpus;
    use crate::model::forward::random_weights;
    use crate::util::rng::Rng;

    #[test]
    fn accumulate_matches_reduced_path() {
        let mut rng = Rng::new(1);
        let dim = 6;
        let rows = 10;
        let x: Vec<f32> = (0..rows * dim).map(|_| rng.normal() as f32).collect();
        // Raw accumulation (buffered; finalize flushes + mirrors).
        let mut raw = TapStats::default();
        raw.accumulate("t", &x, rows, dim);
        raw.finalize();
        // Reduced accumulation from an externally computed Gram.
        let mut gram = vec![0.0f32; dim * dim];
        let mut abs = vec![0.0f32; dim];
        for r in 0..rows {
            for i in 0..dim {
                abs[i] += x[r * dim + i].abs();
                for j in 0..dim {
                    gram[i * dim + j] += x[r * dim + i] * x[r * dim + j];
                }
            }
        }
        let mut red = TapStats::default();
        red.accumulate_reduced("t", &gram, &abs, rows, dim);
        let a = &raw.taps["t"];
        let b = &red.taps["t"];
        assert_eq!(a.rows, b.rows);
        assert!(a.gram.dist(&b.gram) < 1e-3);
        for (x, y) in a.abs_sum.iter().zip(&b.abs_sum) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn collect_native_produces_all_taps() {
        let mut cfg = crate::model::config::ModelConfig::builtin("llama-t").unwrap();
        cfg.n_layers = 2;
        cfg.linear_shapes
            .retain(|(n, _, _)| n.contains("blocks.0") || n.contains("blocks.1"));
        let w = random_weights(&cfg, 2);
        let corpus = Corpus {
            name: "c".into(),
            tokens: (0..4096).map(|i| (i % 251) as u8).collect(),
        };
        let mut rng = Rng::new(3);
        let batches = Batcher::new(4, 32).calibration_batches(&corpus, 8, &mut rng);
        let stats = collect_native(&cfg, &w, &batches).unwrap();
        assert_eq!(stats.taps.len(), 8); // 4 taps × 2 layers
        for (tap, s) in &stats.taps {
            assert_eq!(s.rows, 8 * 32, "tap {tap}");
            // Gram PSD-ish: diagonal non-negative.
            for d in s.gram.diagonal() {
                assert!(d >= 0.0);
            }
        }
        // for_linear resolves through the tap map.
        assert!(stats.for_linear("blocks.0.attn.wq").is_some());
        assert!(stats.for_linear("blocks.1.mlp.w_down").is_some());
    }

    #[test]
    fn merge_is_additive_in_rows() {
        let mut rng = Rng::new(4);
        let x1: Vec<f32> = (0..5 * 4).map(|_| rng.normal() as f32).collect();
        let x2: Vec<f32> = (0..7 * 4).map(|_| rng.normal() as f32).collect();
        let mut a = TapStats::default();
        a.accumulate("t", &x1, 5, 4);
        let mut b = TapStats::default();
        b.accumulate("t", &x2, 7, 4);
        let mut whole = TapStats::default();
        let mut xall = x1.clone();
        xall.extend_from_slice(&x2);
        whole.accumulate("t", &xall, 12, 4);
        whole.finalize();
        a.merge(b); // consumes b: vacant taps move, occupied taps fold
        a.finalize();
        assert_eq!(a.taps["t"].rows, 12);
        assert!(a.taps["t"].gram.dist(&whole.taps["t"].gram) < 1e-4);
    }

    #[test]
    fn merge_moves_vacant_taps_and_folds_occupied() {
        let mut rng = Rng::new(5);
        let xa: Vec<f32> = (0..6 * 3).map(|_| rng.normal() as f32).collect();
        let xb: Vec<f32> = (0..4 * 3).map(|_| rng.normal() as f32).collect();
        let mut a = TapStats::default();
        a.accumulate("shared", &xa, 6, 3);
        let mut b = TapStats::default();
        b.accumulate("shared", &xb, 4, 3);
        b.accumulate("only_b", &xb, 4, 3);
        a.merge(b);
        a.finalize();
        assert_eq!(a.taps.len(), 2);
        assert_eq!(a.taps["shared"].rows, 10);
        assert_eq!(a.taps["only_b"].rows, 4);
        // The moved tap carries its data intact.
        let mut direct = TapStats::default();
        direct.accumulate("only_b", &xb, 4, 3);
        direct.finalize();
        assert!(a.taps["only_b"].gram.dist(&direct.taps["only_b"].gram) < 1e-6);
    }
}
