//! Perplexity evaluation (the metric of every table in the paper).
//!
//! `ppl = exp(Σ nll / Σ tokens)` over non-overlapping windows of a test
//! split.  Two backends:
//!
//! * **PJRT** (primary): fixed-shape executables; only FULL batches are
//!   scored (the executable reduces over all rows, so a padded row would
//!   contaminate the sum).  The window count is chosen to be a multiple of
//!   the batch size, which drops at most `batch-1` tail windows — the same
//!   protocol for every method, so comparisons are exact.
//! * **native** (fallback + parity oracle): scores any batch shape.
//!
//! Batches are independent, so the native backend scores them
//! **concurrently**: [`evaluate_with_workers`] fans `TokenBatch`es out over
//! the worker pool and folds the per-batch `(nll, tokens)` pairs in batch
//! order — the same merge [`PerplexityResult::merge`] performs — so the sum
//! is bit-identical for every worker count.  One [`ThreadBudget`] is split
//! between the batch fan-out and the parallel f32 GEMMs inside each forward
//! pass (no nested-pool oversubscription).  PJRT executables are pinned to
//! the thread that owns the client (they are not `Send`), so that path
//! scores batches back-to-back via the evaluators' batched entry points.
//!
//! [`ThreadBudget`]: crate::util::threads::ThreadBudget

use crate::compress::lowrank::CompressedModel;
use crate::data::batch::{Batcher, TokenBatch};
use crate::data::corpus::Corpus;
use crate::linalg::gemm;
use crate::model::config::ModelConfig;
use crate::model::forward::{self, LinearOverride, NoOverride};
use crate::model::weights::Weights;
use crate::util::threads::{parallel_map, ThreadBudget};
use anyhow::Result;

/// Perplexity outcome for one (model, method, dataset) cell.
#[derive(Clone, Debug)]
pub struct PerplexityResult {
    pub dataset: String,
    pub sum_nll: f64,
    pub tokens: f64,
}

impl PerplexityResult {
    pub fn ppl(&self) -> f64 {
        (self.sum_nll / self.tokens.max(1.0)).exp()
    }

    pub fn merge(&mut self, other: &PerplexityResult) {
        self.sum_nll += other.sum_nll;
        self.tokens += other.tokens;
    }
}

/// Pool per-dataset results into one scalar perplexity:
/// `exp(Σ nll / Σ tokens)` over every dataset — the y-axis of the
/// budget-vs-perplexity sweeps (`Pipeline::run_budget_sweep`, the
/// `perf_allocate` bench).  Token-weighted, i.e. the same pooling
/// [`PerplexityResult::merge`] performs, NOT the mean of per-dataset
/// perplexities (which would over-weight short domains).
pub fn pooled_ppl(results: &[PerplexityResult]) -> f64 {
    let sum_nll: f64 = results.iter().map(|r| r.sum_nll).sum();
    let tokens: f64 = results.iter().map(|r| r.tokens).sum();
    (sum_nll / tokens.max(1.0)).exp()
}

/// Which execution engine scores batches.
pub enum EvalBackend<'a> {
    /// Dense PJRT evaluator.
    PjrtDense(&'a crate::runtime::exec::DenseEvaluator),
    /// Low-rank PJRT evaluator.
    PjrtLowRank(&'a crate::runtime::exec::LowRankEvaluator),
    /// Native forward with optional compressed override.
    Native {
        cfg: &'a ModelConfig,
        weights: &'a Weights,
        compressed: Option<&'a CompressedModel>,
    },
}

impl<'a> EvalBackend<'a> {
    /// (sum_nll, token_count) for one batch.
    pub fn loss(&self, tb: &TokenBatch) -> Result<(f64, f64)> {
        match self {
            EvalBackend::PjrtDense(e) => {
                debug_assert_eq!(tb.valid_rows, tb.batch);
                let out = e.loss(tb)?;
                Ok((out.sum_nll, out.count))
            }
            EvalBackend::PjrtLowRank(e) => {
                debug_assert_eq!(tb.valid_rows, tb.batch);
                let out = e.loss(tb)?;
                Ok((out.sum_nll, out.count))
            }
            EvalBackend::Native { cfg, weights, compressed } => {
                let ov: &dyn LinearOverride = match compressed {
                    Some(c) => *c,
                    None => &NoOverride,
                };
                let (nll, count) =
                    forward::loss(cfg, weights, ov, &tb.tokens, tb.batch, tb.seq, tb.valid_rows)?;
                Ok((nll, count as f64))
            }
        }
    }

    fn pjrt_full_batches_only(&self) -> bool {
        !matches!(self, EvalBackend::Native { .. })
    }
}

/// Evaluate perplexity of `backend` on a corpus (single-threaded; see
/// [`evaluate_with_workers`] for the batch-parallel native path).
///
/// `max_windows` bounds eval cost; it is rounded DOWN to a multiple of the
/// batch size on PJRT backends (identical window set for every method).
pub fn evaluate(
    backend: &EvalBackend,
    corpus: &Corpus,
    batch: usize,
    seq: usize,
    max_windows: usize,
) -> Result<PerplexityResult> {
    evaluate_with_workers(backend, corpus, batch, seq, max_windows, 1)
}

/// Evaluate perplexity, scoring independent `TokenBatch`es concurrently.
///
/// `workers` is the eval thread budget (`0` = all cores), split between the
/// batch fan-out and the parallel GEMMs inside each forward pass.  The
/// result is **bit-identical for every worker count**: each batch's loss is
/// a pure function, the GEMM kernel is deterministic, and partial sums are
/// folded in batch order.  PJRT backends ignore `workers` (the client and
/// executables are not `Send`) and score batches sequentially on the
/// calling thread.
pub fn evaluate_with_workers(
    backend: &EvalBackend,
    corpus: &Corpus,
    batch: usize,
    seq: usize,
    max_windows: usize,
    workers: usize,
) -> Result<PerplexityResult> {
    let batcher = Batcher::new(batch, seq);
    let mut batches = batcher.eval_batches(corpus, max_windows);
    if backend.pjrt_full_batches_only() {
        batches.retain(|tb| tb.valid_rows == tb.batch);
    }
    let mut outer_sp = crate::obs::span("eval.perplexity");
    if outer_sp.is_recording() {
        outer_sp
            .arg_str("dataset", &corpus.name)
            .arg_u64("batches", batches.len() as u64)
            .arg_u64("workers", workers as u64);
    }
    let mut out = PerplexityResult { dataset: corpus.name.clone(), sum_nll: 0.0, tokens: 0.0 };
    match backend {
        EvalBackend::Native { cfg, weights, compressed } => {
            // Destructure to `Sync` references before crossing threads (the
            // enum itself is not `Sync`: the PJRT variants hold Rc-backed
            // evaluators).
            let (cfg, weights, compressed) = (*cfg, *weights, *compressed);
            let budget = ThreadBudget::new(workers); // 0 = all cores
            let (outer, inner) = budget.split(batches.len());
            let partials = parallel_map(&batches, outer, |bi, tb| {
                let _gemm_threads = gemm::scoped_workers(inner);
                let mut sp = crate::obs::span("eval.batch");
                if sp.is_recording() {
                    sp.arg_u64("batch", bi as u64).arg_u64("rows", tb.valid_rows as u64);
                }
                let ov: &dyn LinearOverride = match compressed {
                    Some(c) => c,
                    None => &NoOverride,
                };
                forward::loss(cfg, weights, ov, &tb.tokens, tb.batch, tb.seq, tb.valid_rows)
            });
            for r in partials {
                let (nll, count) = r?;
                out.sum_nll += nll;
                out.tokens += count as f64;
            }
        }
        EvalBackend::PjrtDense(e) => {
            let folded = e.loss_batches(&batches)?;
            out.sum_nll = folded.sum_nll;
            out.tokens = folded.count;
        }
        EvalBackend::PjrtLowRank(e) => {
            let folded = e.loss_batches(&batches)?;
            out.sum_nll = folded.sum_nll;
            out.tokens = folded.count;
        }
    }
    Ok(out)
}

/// Convenience: native evaluation of a (possibly compressed) model.
pub fn evaluate_native(
    cfg: &ModelConfig,
    weights: &Weights,
    compressed: Option<&CompressedModel>,
    corpus: &Corpus,
    batch: usize,
    seq: usize,
    max_windows: usize,
) -> Result<PerplexityResult> {
    evaluate(
        &EvalBackend::Native { cfg, weights, compressed },
        corpus,
        batch,
        seq,
        max_windows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::random_weights;

    fn tiny() -> (ModelConfig, Weights) {
        let mut cfg = ModelConfig::builtin("llama-t").unwrap();
        cfg.n_layers = 2;
        cfg.linear_shapes
            .retain(|(n, _, _)| n.contains("blocks.0") || n.contains("blocks.1"));
        let w = random_weights(&cfg, 1);
        (cfg, w)
    }

    fn corpus(n: usize) -> Corpus {
        Corpus { name: "t".into(), tokens: (0..n).map(|i| (i * 31 % 251) as u8).collect() }
    }

    #[test]
    fn random_model_ppl_near_vocab_size() {
        let (cfg, w) = tiny();
        let c = corpus(2048);
        let r = evaluate_native(&cfg, &w, None, &c, 4, 32, 16).unwrap();
        // Random-init model ≈ uniform: ppl ≈ 256 (generously bounded).
        assert!(r.ppl() > 50.0 && r.ppl() < 800.0, "ppl {}", r.ppl());
        assert_eq!(r.tokens, 16.0 * 31.0);
    }

    #[test]
    fn merge_pools_token_counts() {
        let mut a = PerplexityResult { dataset: "d".into(), sum_nll: 10.0, tokens: 5.0 };
        let b = PerplexityResult { dataset: "d".into(), sum_nll: 20.0, tokens: 10.0 };
        a.merge(&b);
        assert_eq!(a.sum_nll, 30.0);
        assert_eq!(a.tokens, 15.0);
        assert!((a.ppl() - 2.0f64.exp()).abs() < 1e-12);
    }

    #[test]
    fn pooled_ppl_is_token_weighted_merge() {
        let a = PerplexityResult { dataset: "a".into(), sum_nll: 10.0, tokens: 5.0 };
        let b = PerplexityResult { dataset: "b".into(), sum_nll: 20.0, tokens: 10.0 };
        // Same pooling as merging the two results into one.
        assert!((pooled_ppl(&[a.clone(), b]) - 2.0f64.exp()).abs() < 1e-12);
        // A single dataset pools to its own perplexity.
        assert!((pooled_ppl(&[a.clone()]) - a.ppl()).abs() < 1e-12);
        // Empty input degrades to exp(0) rather than NaN.
        assert_eq!(pooled_ppl(&[]), 1.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let (cfg, w) = tiny();
        let c = corpus(4096);
        let r1 = evaluate_native(&cfg, &w, None, &c, 4, 32, 12).unwrap();
        let r2 = evaluate_native(&cfg, &w, None, &c, 4, 32, 12).unwrap();
        assert_eq!(r1.sum_nll, r2.sum_nll);
    }

    #[test]
    fn parallel_eval_is_bit_identical_to_serial() {
        let (cfg, w) = tiny();
        let c = corpus(4096);
        let backend = EvalBackend::Native { cfg: &cfg, weights: &w, compressed: None };
        let serial = evaluate_with_workers(&backend, &c, 4, 32, 12, 1).unwrap();
        for workers in [2usize, 4] {
            let par = evaluate_with_workers(&backend, &c, 4, 32, 12, workers).unwrap();
            assert_eq!(serial.sum_nll, par.sum_nll, "workers={workers}");
            assert_eq!(serial.tokens, par.tokens, "workers={workers}");
        }
    }

    #[test]
    fn compressed_override_changes_ppl() {
        use crate::compress::methods::{compress_layer, CompressionSpec, Method};
        use crate::compress::ranks;
        use crate::compress::whiten::CalibStats;
        let (cfg, w) = tiny();
        let c = corpus(2048);
        let dense = evaluate_native(&cfg, &w, None, &c, 4, 32, 8).unwrap();
        // Aggressive plain-SVD compression of every layer.
        let mut cm = CompressedModel::default();
        for (name, n_in, n_out) in &cfg.linear_shapes {
            let t = w.get(name).unwrap();
            let mut stats = CalibStats::new(*n_in);
            stats.rows = 1;
            for i in 0..*n_in {
                stats.gram[(i, i)] = 1.0;
                stats.abs_sum[i] = 1.0;
            }
            let spec = CompressionSpec::new(Method::Svd, 0.6);
            let plan = ranks::plan(*n_out, *n_in, 0.6, 1.0);
            cm.insert(name, compress_layer(t, &stats, &spec, &plan).unwrap());
        }
        let comp = evaluate_native(&cfg, &w, Some(&cm), &c, 4, 32, 8).unwrap();
        assert!(comp.sum_nll.is_finite());
        assert_ne!(dense.sum_nll, comp.sum_nll);
    }
}
