//! Evaluation: perplexity over the eight domains.
//!
//! One module, one metric: token-level perplexity `exp(Σ nll / Σ tokens)`
//! accumulated over sequential eval windows.  [`perplexity::evaluate`] is
//! generic over an [`EvalBackend`] so the SAME scoring loop runs against
//! the PJRT dense executable, the PJRT low-rank executable (compressed
//! models), or the pure-native forward — which is how the integration
//! tests pin PJRT and native to each other.  The native backend scores
//! independent batches concurrently ([`perplexity::evaluate_with_workers`],
//! bit-identical at every worker count).  Results arrive as
//! [`PerplexityResult`] rows, one per dataset, in the order the paper's
//! tables print them.

pub mod perplexity;

pub use perplexity::{evaluate, evaluate_native, evaluate_with_workers, EvalBackend, PerplexityResult};
