//! Evaluation: perplexity over the eight domains.

pub mod perplexity;

pub use perplexity::{EvalBackend, PerplexityResult, evaluate_native};
