//! Criterion-free benchmark harness.
//!
//! `criterion` is unavailable offline, so `cargo bench` targets are declared
//! with `harness = false` and drive this module instead.  It provides:
//!
//! * warmup + timed iterations with robust statistics ([`Bencher`]),
//! * throughput annotation,
//! * a `--filter` / `--quick` command line compatible with `cargo bench -- x`,
//! * machine-readable JSON output next to human tables
//!   (`target/bench-results/<suite>.json`) so EXPERIMENTS.md entries can be
//!   regenerated.
//!
//! Paper-table benches print the reproduced table rows as part of the run.

use crate::util::json::Json;
use crate::util::timer::{Stats, Timer};

/// Locate the artifacts directory for benches that need the real system.
/// Returns `None` (benches print a SKIP notice) when `make artifacts` has
/// not been run.
pub fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Eval windows used by the paper-table benches; reduced in quick mode.
pub fn table_windows(quick: bool) -> usize {
    if quick {
        16
    } else {
        48
    }
}

/// NSVD-shaped low-rank override with random factors at the exact ranks a
/// `ratio` compression with k₁ share `alpha` stores (per-layer plan from
/// [`crate::compress::ranks::plan`], the paper protocol) — the synthetic
/// model the artifact-free serving bench and example share.  Throughput
/// shape only, not fitted quality: factor variance is scaled so the
/// reconstructed product matches `random_weights`' `1/√n_in` layers and
/// activations stay sane through the nonlinearity.
pub fn synthetic_nsvd(
    cfg: &crate::model::ModelConfig,
    ratio: f64,
    alpha: f64,
    seed: u64,
) -> crate::compress::CompressedModel {
    use crate::compress::{ranks, CompressedLayer, CompressedModel};
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut cm = CompressedModel::default();
    for (name, n_in, n_out) in &cfg.linear_shapes {
        let (m, n) = (*n_in, *n_out);
        let plan = ranks::plan(m, n, ratio, alpha);
        // Per-branch product variance 1/(m·branches), so the SUM of the
        // independent P1·Q1 + P2·Q2 branches matches random_weights' 1/m
        // weight variance: each factor element gets std (m·k·branches)^-¼
        // (k·std⁴ per branch = 1/(m·branches)).
        let branches = if plan.k2 > 0 { 2.0 } else { 1.0 };
        let std =
            |k: usize| (1.0 / ((m * k.max(1)) as f64 * branches)).powf(0.25);
        let p1 = Matrix::randn(m, plan.k1, std(plan.k1), &mut rng);
        let q1 = Matrix::randn(plan.k1, n, std(plan.k1), &mut rng);
        let p2 = Matrix::randn(m, plan.k2, std(plan.k2), &mut rng);
        let q2 = Matrix::randn(plan.k2, n, std(plan.k2), &mut rng);
        cm.insert(name, CompressedLayer::from_matrices(&p1, &q1, &p2, &q2));
    }
    cm
}

/// [`synthetic_nsvd`] with the factors quantized to per-group int8
/// ([`crate::linalg::quant::DEFAULT_GROUP`]): the model the int8 serving
/// benches and the serve parity tests decode through, so the `--factor-dtype
/// int8` path is exercised without artifacts.
pub fn synthetic_nsvd_int8(
    cfg: &crate::model::ModelConfig,
    ratio: f64,
    alpha: f64,
    seed: u64,
) -> crate::compress::CompressedModel {
    synthetic_nsvd(cfg, ratio, alpha, seed).quantize(crate::linalg::quant::DEFAULT_GROUP)
}

/// A 2-layer cut of a builtin model family with `random_weights` — the
/// fast fixture behind the serve parity tests (`serve::test_util`) and
/// `perf_serve`'s parity smoke, kept in one place so the two suites can
/// never drift apart.  `mistral-t` gets `window = 4` so the
/// sliding-window cache path runs.
pub fn tiny_model(name: &str, seed: u64) -> (crate::model::ModelConfig, crate::model::Weights) {
    let mut cfg = crate::model::ModelConfig::builtin(name).expect("builtin model");
    cfg.n_layers = 2;
    cfg.linear_shapes
        .retain(|(n, _, _)| n.starts_with("blocks.0.") || n.starts_with("blocks.1."));
    if name == "mistral-t" {
        cfg.window = 4;
    }
    let w = crate::model::forward::random_weights(&cfg, seed);
    (cfg, w)
}

/// Drive the generation server with a preloaded batch of `(prompt,
/// max_new, sample)` requests on the calling thread: send everything,
/// close the channel, serve to completion, and return each request's
/// streamed tokens (request order) plus the server metrics.  The shared
/// harness behind the serve parity tests and `perf_serve`
/// (`examples/serving_throughput.rs` keeps its own concurrent
/// closed-loop clients — that concurrency is what it demonstrates).
pub fn drive_preloaded(
    cfg: &crate::model::ModelConfig,
    weights: &crate::model::Weights,
    overrides: &dyn crate::model::forward::LinearOverride,
    gen: &crate::serve::GenConfig,
    reqs: Vec<(Vec<u8>, usize, crate::model::generate::SampleConfig)>,
) -> (Vec<Vec<u8>>, crate::coordinator::metrics::GenServerMetrics) {
    drive_preloaded_kv(cfg, weights, overrides, None, gen, reqs)
}

/// [`drive_preloaded`] against a KV-compressed server
/// ([`crate::serve::serve_generation_kv`]): the pool stores rank-wide
/// latents built by `kvc` and every request's streamed bits must equal a
/// single-request [`crate::model::generate::generate_kv`] run under the
/// same factors.  `kvc` `None` is exactly [`drive_preloaded`].
pub fn drive_preloaded_kv(
    cfg: &crate::model::ModelConfig,
    weights: &crate::model::Weights,
    overrides: &dyn crate::model::forward::LinearOverride,
    kvc: Option<&crate::model::KvCompression>,
    gen: &crate::serve::GenConfig,
    reqs: Vec<(Vec<u8>, usize, crate::model::generate::SampleConfig)>,
) -> (Vec<Vec<u8>>, crate::coordinator::metrics::GenServerMetrics) {
    use crate::serve::{collect_stream, serve_generation_kv, stream_channel, GenRequest};
    let (tx, rx) = std::sync::mpsc::channel();
    let mut streams = Vec::new();
    for (i, (prompt, max_new, sample)) in reqs.into_iter().enumerate() {
        let (stream, events) = stream_channel();
        tx.send(GenRequest::new(i as u64, prompt, max_new, sample, stream))
            .expect("request channel open");
        streams.push(events);
    }
    drop(tx);
    let metrics = serve_generation_kv(cfg, weights, overrides, kvc, gen, rx)
        .expect("serve_generation_kv");
    let outs = streams.iter().map(|rx| collect_stream(rx).0).collect();
    (outs, metrics)
}

/// Drive the generation server with `clients` concurrent closed-loop
/// client threads on top of the calling thread (which becomes the
/// scheduler): client `c` sends requests `c, c+clients, …` of
/// `0..total`, each built by `make(i) -> (prompt, max_new, sample)`, and
/// sends the next only after the previous stream finishes.  Returns the
/// server metrics plus every [`crate::serve::DoneStats`] the clients
/// collected.  The shared harness behind `serve-gen` and
/// `examples/serving_throughput.rs`.
pub fn drive_concurrent(
    cfg: &crate::model::ModelConfig,
    weights: &crate::model::Weights,
    overrides: &dyn crate::model::forward::LinearOverride,
    gen: &crate::serve::GenConfig,
    clients: usize,
    total: usize,
    make: &(dyn Fn(usize) -> (Vec<u8>, usize, crate::model::generate::SampleConfig) + Sync),
) -> crate::Result<(
    crate::coordinator::metrics::GenServerMetrics,
    Vec<crate::serve::DoneStats>,
)> {
    drive_concurrent_kv(cfg, weights, overrides, None, gen, clients, total, make)
}

/// [`drive_concurrent`] against a KV-compressed server: the pool stores
/// rank-wide latents built by `kvc` (`None` = the uncompressed pool).
/// The harness behind `serve-gen --kv-ratio`.
#[allow(clippy::too_many_arguments)]
pub fn drive_concurrent_kv(
    cfg: &crate::model::ModelConfig,
    weights: &crate::model::Weights,
    overrides: &dyn crate::model::forward::LinearOverride,
    kvc: Option<&crate::model::KvCompression>,
    gen: &crate::serve::GenConfig,
    clients: usize,
    total: usize,
    make: &(dyn Fn(usize) -> (Vec<u8>, usize, crate::model::generate::SampleConfig) + Sync),
) -> crate::Result<(
    crate::coordinator::metrics::GenServerMetrics,
    Vec<crate::serve::DoneStats>,
)> {
    use crate::serve::{collect_stream, serve_generation_kv, stream_channel, GenRequest};
    let clients = clients.max(1).min(total.max(1));
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    std::thread::scope(|scope| {
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        for c in 0..clients {
            let req_tx = req_tx.clone();
            let done_tx = done_tx.clone();
            scope.spawn(move || {
                let mut i = c;
                while i < total {
                    let (prompt, max_new, sample) = make(i);
                    let (stream, events) = stream_channel();
                    let req = GenRequest::new(i as u64, prompt, max_new, sample, stream);
                    if req_tx.send(req).is_err() {
                        return;
                    }
                    let (_tokens, stats) = collect_stream(&events);
                    if let Some(s) = stats {
                        let _ = done_tx.send(s);
                    }
                    i += clients;
                }
            });
        }
        drop(done_tx);
        drop(req_tx);
        let metrics = serve_generation_kv(cfg, weights, overrides, kvc, gen, req_rx)?;
        Ok((metrics, done_rx.iter().collect()))
    })
}

/// One tenant's traffic pattern for [`drive_open_loop`].
#[derive(Clone, Debug)]
pub struct OpenLoopTenant {
    /// Tenant id stamped on every request (buckets the server metrics).
    pub tenant: u32,
    /// Mean Poisson arrival rate, requests per second; `0.0` offers the
    /// whole load up front as one burst.
    pub rate: f64,
    /// Total requests this tenant submits.
    pub requests: usize,
    /// Scheduling priority stamped on every request (higher wins).
    pub priority: u8,
    /// Relative deadline in the server's configured clock units, if any.
    pub deadline: Option<f64>,
    /// Prompt length range `[lo, hi)` in bytes.
    pub prompt_len: (usize, usize),
    /// Output budget range `[lo, hi)` in tokens.
    pub max_new: (usize, usize),
}

/// Drive the generation server with **open-loop** (Poisson) clients: one
/// thread per tenant draws exponential interarrival gaps from its `rate`
/// and keeps sending regardless of how the server is keeping up.  Unlike
/// the closed-loop [`drive_concurrent`], offered load does not fall when
/// the server saturates — which is exactly the regime the bounded-queue
/// overload policy is measured against.  Prompt bytes, lengths, and
/// per-request sampling seeds all derive from `seed`, so `(seed,
/// tenants)` names one reproducible workload.  Returns the server metrics
/// plus every [`crate::serve::DoneStats`] the clients collected.
pub fn drive_open_loop(
    cfg: &crate::model::ModelConfig,
    weights: &crate::model::Weights,
    overrides: &dyn crate::model::forward::LinearOverride,
    gen: &crate::serve::GenConfig,
    seed: u64,
    tenants: &[OpenLoopTenant],
) -> crate::Result<(
    crate::coordinator::metrics::GenServerMetrics,
    Vec<crate::serve::DoneStats>,
)> {
    drive_open_loop_kv(cfg, weights, overrides, None, gen, seed, tenants)
}

/// [`drive_open_loop`] against a KV-compressed server (`kvc` `None` is
/// exactly [`drive_open_loop`]).
pub fn drive_open_loop_kv(
    cfg: &crate::model::ModelConfig,
    weights: &crate::model::Weights,
    overrides: &dyn crate::model::forward::LinearOverride,
    kvc: Option<&crate::model::KvCompression>,
    gen: &crate::serve::GenConfig,
    seed: u64,
    tenants: &[OpenLoopTenant],
) -> crate::Result<(
    crate::coordinator::metrics::GenServerMetrics,
    Vec<crate::serve::DoneStats>,
)> {
    use crate::serve::{collect_stream, serve_generation_kv, stream_channel, GenRequest};
    use crate::util::rng::Rng;
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    std::thread::scope(|scope| {
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        for (t_idx, spec) in tenants.iter().enumerate() {
            let req_tx = req_tx.clone();
            let done_tx = done_tx.clone();
            scope.spawn(move || {
                let mut rng =
                    Rng::new(seed ^ (t_idx as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
                let mut streams = Vec::new();
                for k in 0..spec.requests {
                    if spec.rate > 0.0 {
                        // Exponential interarrival gap of a Poisson process
                        // (capped so a pathological draw cannot hang a run).
                        let gap = -(1.0 - rng.f64()).ln() / spec.rate;
                        std::thread::sleep(std::time::Duration::from_secs_f64(gap.min(2.0)));
                    }
                    let (plo, phi) = spec.prompt_len;
                    let plen = rng.range(plo.max(1), phi.max(plo.max(1) + 1));
                    let prompt: Vec<u8> = (0..plen).map(|_| rng.below(251) as u8).collect();
                    let (nlo, nhi) = spec.max_new;
                    let max_new = rng.range(nlo.max(1), nhi.max(nlo.max(1) + 1));
                    let sample = crate::model::generate::SampleConfig {
                        seed: seed ^ (((t_idx as u64) << 32) | k as u64),
                        ..Default::default()
                    };
                    let (stream, events) = stream_channel();
                    let mut req = GenRequest::new(
                        ((t_idx as u64) << 32) | k as u64,
                        prompt,
                        max_new,
                        sample,
                        stream,
                    );
                    req.tenant = spec.tenant;
                    req.priority = spec.priority;
                    req.deadline = spec.deadline;
                    if req_tx.send(req).is_err() {
                        break;
                    }
                    streams.push(events);
                }
                // Open loop: the whole load is offered before any stream is
                // drained (token channels are unbounded, so the server never
                // blocks on an undrained client).
                drop(req_tx);
                for events in &streams {
                    let (_tokens, stats) = collect_stream(events);
                    if let Some(stats) = stats {
                        let _ = done_tx.send(stats);
                    }
                }
            });
        }
        drop(done_tx);
        drop(req_tx);
        let metrics = serve_generation_kv(cfg, weights, overrides, kvc, gen, req_rx)?;
        Ok((metrics, done_rx.iter().collect()))
    })
}

/// Goodput: tokens generated by requests that ran to **completion**, per
/// second of server wall time.  Work spent on shed, deadline-killed,
/// faulted, or cancelled requests counts toward raw throughput but not
/// goodput — the gap between the two is what the overload sweep plots.
pub fn goodput_tokens_per_s(stats: &[crate::serve::DoneStats], wall_s: f64) -> f64 {
    if wall_s <= 0.0 {
        return 0.0;
    }
    let toks: usize = stats
        .iter()
        .filter(|s| s.finish == crate::serve::FinishReason::Completed)
        .map(|s| s.generated)
        .sum();
    toks as f64 / wall_s
}

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub stats: Stats,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<f64>,
    /// Optional free-form metrics attached to this benchmark (e.g. the
    /// perplexity numbers of the paper table the bench regenerates).
    pub extra: Vec<(String, f64)>,
}

/// A benchmark suite: collects measurements, prints a table, writes JSON.
pub struct Suite {
    pub name: String,
    filter: Option<String>,
    quick: bool,
    results: Vec<Measurement>,
}

impl Suite {
    /// Parse `cargo bench` style args: any positional is a substring filter;
    /// `--quick` cuts iteration counts (used by `cargo test --benches`).
    pub fn from_args(name: &str) -> Suite {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let quick = argv.iter().any(|a| a == "--quick") || std::env::var("NSVD_BENCH_QUICK").is_ok();
        let filter = argv
            .iter()
            .find(|a| !a.starts_with("--"))
            .cloned();
        Suite { name: name.to_string(), filter, quick, results: Vec::new() }
    }

    pub fn quick(&self) -> bool {
        self.quick
    }

    /// Should this benchmark run under the current filter?
    pub fn enabled(&self, bench_name: &str) -> bool {
        match &self.filter {
            Some(f) => bench_name.contains(f.as_str()),
            None => true,
        }
    }

    /// Time `f`, which performs ONE iteration per call.
    /// `iters` is scaled down in quick mode.
    pub fn bench<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) {
        if !self.enabled(name) {
            return;
        }
        let iters = if self.quick { iters.clamp(1, 3) } else { iters.max(1) };
        // Warmup: one iteration (compilation caches, page faults).
        f();
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Timer::start();
            f();
            samples.push(t.elapsed_s());
        }
        let stats = Stats::from(&samples);
        println!(
            "bench {:<40} {}",
            format!("{}::{}", self.name, name),
            stats.display("s")
        );
        self.results.push(Measurement {
            name: name.to_string(),
            stats,
            items: None,
            extra: Vec::new(),
        });
    }

    /// Like [`bench`] but annotates items/second throughput.
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        iters: usize,
        items_per_iter: f64,
        mut f: F,
    ) {
        if !self.enabled(name) {
            return;
        }
        self.bench(name, iters, &mut f);
        if let Some(m) = self.results.last_mut() {
            m.items = Some(items_per_iter);
            if m.stats.mean > 0.0 {
                println!(
                    "      {:<40} throughput: {:.1} items/s",
                    "", items_per_iter / m.stats.mean
                );
            }
        }
    }

    /// Mean seconds of a completed measurement, by name.
    pub fn mean_of(&self, bench: &str) -> Option<f64> {
        self.results
            .iter()
            .rev()
            .find(|m| m.name == bench)
            .map(|m| m.stats.mean)
    }

    /// Attach a named metric to the most recent measurement (or a standalone
    /// record when no timing applies, e.g. accuracy rows of a paper table).
    pub fn record_metric(&mut self, bench: &str, key: &str, value: f64) {
        if let Some(m) = self.results.iter_mut().rev().find(|m| m.name == bench) {
            m.extra.push((key.to_string(), value));
        } else {
            self.results.push(Measurement {
                name: bench.to_string(),
                stats: Stats::default(),
                items: None,
                extra: vec![(key.to_string(), value)],
            });
        }
    }

    /// Write a stable summary of the measurements whose name starts with
    /// `prefix` to `path` — used by `perf_linalg` to keep a top-level
    /// `BENCH_gemm.json` (GFLOP/s per shape, speedup vs the naive kernel)
    /// next to `target/bench-results/`, so the perf trajectory is tracked
    /// across PRs instead of buried in per-run output.  `items` is
    /// interpreted as FLOPs per iteration, so `items_per_s` is reported as
    /// `gflops`.  Call before [`Suite::finish`] (which consumes the suite).
    pub fn write_summary(&self, path: &std::path::Path, prefix: &str) {
        let mut arr = Vec::new();
        for m in self.results.iter().filter(|m| m.name.starts_with(prefix)) {
            let mut o = Json::obj();
            o.set("name", m.name.as_str()).set("mean_s", m.stats.mean);
            if let Some(items) = m.items {
                if m.stats.mean > 0.0 {
                    o.set("gflops", items / m.stats.mean / 1e9);
                }
            }
            for (k, v) in &m.extra {
                o.set(k, *v);
            }
            arr.push(o);
        }
        let mut doc = Json::obj();
        doc.set("suite", self.name.as_str())
            .set("quick", if self.quick { 1.0 } else { 0.0 })
            .set("results", Json::Arr(arr));
        if let Err(e) = std::fs::write(path, doc.to_string_pretty()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("summary written to {}", path.display());
        }
    }

    /// Write results as JSON under `target/bench-results/` and finish.
    pub fn finish(self) {
        let mut arr = Vec::new();
        for m in &self.results {
            let mut o = Json::obj();
            o.set("name", m.name.as_str())
                .set("mean_s", m.stats.mean)
                .set("std_s", m.stats.std)
                .set("p50_s", m.stats.p50)
                .set("p99_s", m.stats.p99)
                .set("n", m.stats.n);
            if let Some(items) = m.items {
                o.set("items_per_iter", items);
                if m.stats.mean > 0.0 {
                    o.set("items_per_s", items / m.stats.mean);
                }
            }
            if !m.extra.is_empty() {
                let mut e = Json::obj();
                for (k, v) in &m.extra {
                    e.set(k, *v);
                }
                o.set("metrics", e);
            }
            arr.push(o);
        }
        let mut doc = Json::obj();
        doc.set("suite", self.name.as_str()).set("results", Json::Arr(arr));
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.name));
        if let Err(e) = std::fs::write(&path, doc.to_string_pretty()) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("bench results written to {}", path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let mut suite = Suite {
            name: "t".into(),
            filter: None,
            quick: true,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        suite.bench("spin", 3, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(suite.results.len(), 1);
        assert!(suite.results[0].stats.n >= 1);
    }

    #[test]
    fn filter_gates_benches() {
        let suite = Suite {
            name: "t".into(),
            filter: Some("svd".into()),
            quick: true,
            results: Vec::new(),
        };
        assert!(suite.enabled("nsvd_decompose"));
        assert!(!suite.enabled("matmul"));
    }

    #[test]
    fn write_summary_filters_by_prefix() {
        let mut suite = Suite {
            name: "t".into(),
            filter: None,
            quick: true,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        suite.bench_throughput("gemm_x", 2, 1e9, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
        });
        suite.bench("other", 2, || {});
        // temp_dir, not target/: the package-root target dir need not exist
        // (e.g. CARGO_TARGET_DIR pointing elsewhere).
        let path = std::env::temp_dir().join("nsvd-test-bench-summary.json");
        suite.write_summary(&path, "gemm");
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("gemm_x"));
        assert!(!body.contains("other"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_metric_creates_standalone_entry() {
        let mut suite = Suite {
            name: "t".into(),
            filter: None,
            quick: true,
            results: Vec::new(),
        };
        suite.record_metric("table1/wiki", "ppl", 7.07);
        assert_eq!(suite.results.len(), 1);
        assert_eq!(suite.results[0].extra[0].1, 7.07);
    }
}
