//! # nsvd — Nested Activation-Aware Decomposition for LLM compression
//!
//! A full-system reproduction of *"Large Language Model Compression via the
//! Nested Activation-Aware Decomposition"* (Lu et al., 2025) on a three-layer
//! Rust + JAX + Pallas architecture:
//!
//! * **L1** — Pallas kernels (build-time Python, `python/compile/kernels/`)
//! * **L2** — JAX model definitions lowered AOT to HLO text
//!   (`python/compile/model.py`, `aot.py`)
//! * **L3** — this crate: the post-training compression pipeline, the PJRT
//!   runtime that executes the AOT artifacts, and the serving coordinator.
//!
//! The public API is organised bottom-up:
//!
//! * [`util`] — PRNG, JSON, CLI, threading, timing (offline substrate).
//! * [`linalg`] — dense f64 linear algebra: QR, LQ, Cholesky, symmetric
//!   eigendecomposition, SVD, interpolative decomposition, and the
//!   randomized truncated-SVD fast path ([`linalg::rsvd`]).
//! * [`data`] — byte-level corpora, splits, batching.
//! * [`model`] — transformer configs, NSVDW weight loading, native forward.
//! * [`compress`] — the paper's methods: SVD, ASVD-0/I/II/III, NSVD-I/II,
//!   NID-I/II, rank budgeting, the global spectrum-driven rank allocator
//!   ([`compress::allocate`]), padded low-rank layers, and the parallel
//!   sharded decomposition engine ([`compress::engine`]).
//! * [`calib`] — activation Gram collection + similarity analysis.
//! * [`eval`] — perplexity evaluation.
//! * [`runtime`] — PJRT client, artifact registry, executors.
//! * [`coordinator`] — pipeline orchestration, scheduler, scoring serving,
//!   reports.
//! * [`serve`] — the continuous-batching **generation** server: slotted KV
//!   pool, step-level batch scheduler, batched decode through the GEMM
//!   layer, per-request token streaming.
//! * [`bench`] — the criterion-free benchmark harness used by `cargo bench`.
//! * [`obs`] — zero-dependency observability: tracing spans, the metrics
//!   registry, Chrome-trace / Prometheus export (disabled by default,
//!   gated on one relaxed atomic).
//!
//! New readers: start with the repo-root `README.md` (quickstart, layout),
//! `ARCHITECTURE.md` (layering, data flow, where the engine and rsvd fast
//! path sit), and `METHODS.md` (the paper-to-code map: every equation and
//! theorem linked to its implementing function and pinning test); then
//! come back here for API-level docs.

pub mod bench;
pub mod calib;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
