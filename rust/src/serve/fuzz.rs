//! Randomized serve-schedule fuzzing: the lockdown harness for the paged
//! serving stack's bit-parity contract.
//!
//! Each case derives a whole serving scenario from one seed — a request
//! mix with shared and distinct prompt prefixes (single- and multi-page),
//! admission staggered by a narrow `max_batch`, clients that hang up
//! mid-stream, and pool sizes tight enough to force preemption — serves
//! it, and checks every request's token stream bit-equal to a fresh
//! sequential [`generate`] run (a bit-equal *prefix* of it, for clients
//! that cancelled).  The scheduler is free to pick any page size, chunk
//! split, sharing, or preemption schedule; none of it may leak into the
//! tokens.
//!
//! A failure panics with the exact `(seed, page_size, workers)` triple, so
//! any red run reproduces with a one-line `run_case(seed, ps, w)` call.
//!
//! The default test covers the fixed 32-seed grid with the
//! `page_size × workers` combos round-robined across seeds; the `#[ignore]`d
//! full grid runs every seed against every combo (32 × {1,4,16} × {1,4}).

use super::batcher::{serve_generation, GenConfig, GenRequest};
use super::stream::{stream_channel, FinishReason, StreamEvent};
use crate::model::forward::NoOverride;
use crate::model::generate::{generate, SampleConfig};
use crate::util::rng::Rng;
use std::sync::mpsc::channel;
use std::time::Instant;

const FAMILIES: [&str; 3] = ["llama-t", "opt-t", "mistral-t"];
const PAGE_SIZES: [usize; 3] = [1, 4, 16];
const WORKER_COUNTS: [usize; 2] = [1, 4];
const SEEDS: u64 = 32;

struct FuzzReq {
    prompt: Vec<u8>,
    max_new: usize,
    sample: SampleConfig,
    /// Tokens the client reads before hanging up (`>= max_new` reads the
    /// whole stream and waits for Done).
    consume: usize,
}

/// Run one seeded scenario end to end; `Err` carries the divergence
/// detail (the caller adds the reproducing triple).
fn run_case(seed: u64, page_size: usize, workers: usize) -> Result<(), String> {
    let mut rng = Rng::new(seed ^ 0x5EED_F00D);
    let family = FAMILIES[rng.below(FAMILIES.len())];
    let (cfg, w) = super::test_util::tiny(family, 47);
    // Base prefixes some requests share (multi-page when the draw is long
    // enough) — the trie only ever sees full pages, so sharing kicks in
    // exactly when a base spans one.
    let n_bases = 1 + rng.below(3);
    let bases: Vec<Vec<u8>> = (0..n_bases)
        .map(|_| {
            let len = rng.below(2 * page_size + 4);
            (0..len).map(|_| rng.below(256) as u8).collect()
        })
        .collect();
    let n_req = 3 + rng.below(5);
    let reqs: Vec<FuzzReq> = (0..n_req)
        .map(|_| {
            let mut prompt: Vec<u8> = if rng.below(2) == 0 {
                bases[rng.below(n_bases)].clone()
            } else {
                Vec::new()
            };
            let tail = 1 + rng.below(page_size + 3);
            prompt.extend((0..tail).map(|_| rng.below(256) as u8));
            let max_new = 1 + rng.below(6);
            // Biased toward reading everything; 0 = hang up before the
            // first token even arrives.
            let consume = rng.below(max_new + 2).min(max_new);
            let sample = SampleConfig {
                temperature: 0.5 + 0.1 * rng.below(8) as f32,
                top_k: 4 + rng.below(20),
                seed: rng.next_u64(),
            };
            FuzzReq { prompt, max_new, sample, consume }
        })
        .collect();
    // Feasible for every request by construction (no rejections), but
    // often tight enough that concurrent sequences fight for pages and
    // the scheduler must evict prefixes / preempt.
    let worst = reqs
        .iter()
        .map(|r| (r.prompt.len() + r.max_new - 1).div_ceil(page_size))
        .max()
        .expect("non-empty mix");
    let gen = GenConfig {
        max_batch: 1 + rng.below(4),
        pages: worst + rng.below(2 * worst + 2),
        page_size,
        prefill_chunk: [0usize, 1, 2, 5][rng.below(4)],
        prefix_share: rng.below(2) == 0,
        workers,
    };
    let expect: Vec<Vec<u8>> = reqs
        .iter()
        .map(|r| {
            generate(&cfg, &w, &NoOverride, &r.prompt, r.max_new, r.sample)
                .expect("sequential generate")
        })
        .collect();
    // Serve on this thread; one client thread per request so hang-ups
    // happen while the server is mid-schedule.
    let (tx, rx) = channel();
    let (metrics, results) = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            let (stream, events) = stream_channel();
            tx.send(GenRequest {
                id: i as u64,
                prompt: r.prompt.clone(),
                max_new: r.max_new,
                sample: r.sample,
                stream,
                enqueued: Instant::now(),
            })
            .expect("request channel open");
            let (consume, max_new) = (r.consume, r.max_new);
            handles.push(scope.spawn(move || {
                let mut got = Vec::new();
                let mut finish = None;
                if consume < max_new {
                    // Read a prefix, then hang up mid-stream (dropping
                    // `events` on return is the cancellation).
                    while got.len() < consume {
                        match events.recv() {
                            Ok(StreamEvent::Token { byte, .. }) => got.push(byte),
                            Ok(StreamEvent::Done(d)) => {
                                finish = Some(d.finish);
                                break;
                            }
                            Err(_) => break,
                        }
                    }
                } else {
                    for event in events.iter() {
                        match event {
                            StreamEvent::Token { byte, .. } => got.push(byte),
                            StreamEvent::Done(d) => {
                                finish = Some(d.finish);
                                break;
                            }
                        }
                    }
                }
                (got, finish)
            }));
        }
        drop(tx);
        let metrics = serve_generation(&cfg, &w, &NoOverride, &gen, rx).expect("serve_generation");
        let results: Vec<(Vec<u8>, Option<FinishReason>)> =
            handles.into_iter().map(|h| h.join().expect("client thread")).collect();
        (metrics, results)
    });
    for (i, (got, finish)) in results.iter().enumerate() {
        let want = &expect[i];
        let r = &reqs[i];
        if r.consume >= r.max_new {
            if got != want {
                return Err(format!(
                    "{family}: request {i} diverged: got {got:?}, want {want:?} \
                     (gen={gen:?})"
                ));
            }
            if *finish != Some(FinishReason::Completed) {
                return Err(format!("{family}: request {i} finished {finish:?}, want Completed"));
            }
        } else {
            // A cancelled client must have seen exactly its consumed
            // prefix of the sequential output — never a wrong token.
            if got.len() != r.consume || got[..] != want[..got.len()] {
                return Err(format!(
                    "{family}: cancelled request {i} stream {got:?} is not the \
                     {}-token prefix of {want:?} (gen={gen:?})",
                    r.consume
                ));
            }
        }
    }
    if metrics.rejected != 0 {
        return Err(format!("{family}: {} feasible requests rejected", metrics.rejected));
    }
    if metrics.completed != n_req {
        return Err(format!(
            "{family}: {} of {n_req} requests retired (gen={gen:?})",
            metrics.completed
        ));
    }
    Ok(())
}

fn combo(seed: u64) -> (usize, usize) {
    let ps = PAGE_SIZES[(seed as usize) % PAGE_SIZES.len()];
    let w = WORKER_COUNTS[(seed as usize / PAGE_SIZES.len()) % WORKER_COUNTS.len()];
    (ps, w)
}

/// The CI-default grid: all 32 seeds, with the 6 `page_size × workers`
/// combos round-robined so every combo sees 5+ distinct scenarios.
#[test]
fn serve_fuzz_schedule_parity_quick_grid() {
    for seed in 0..SEEDS {
        let (ps, w) = combo(seed);
        if let Err(msg) = run_case(seed, ps, w) {
            panic!(
                "serve fuzz failed: seed={seed} page_size={ps} workers={w}: {msg}\n\
                 reproduce with serve::fuzz::run_case({seed}, {ps}, {w})"
            );
        }
    }
}

/// Int8-quantized factors through the full batched serving stack: every
/// `(max_batch, page_size, workers)` combination must reproduce the
/// sequential int8 [`generate`] run bit-for-bit — the serving-layer pin of
/// the integer kernel's determinism contract (group ≤ 128 keeps every
/// group dot exact in i32 and f32, so batching/paging/threading cannot
/// perturb a single logit).  The dense fuzz grid above never touches the
/// quantized path, so its f32 streams are byte-identical to the pre-int8
/// behavior by construction.
#[test]
fn serve_int8_batched_decode_matches_sequential_generate() {
    use crate::bench::{drive_preloaded, synthetic_nsvd_int8};
    let (cfg, w) = super::test_util::tiny("llama-t", 47);
    let cm = synthetic_nsvd_int8(&cfg, 0.30, 0.95, 9);
    assert!(cm.is_quantized(), "fixture must exercise the int8 path");
    let (n_req, prompt_len, max_new) = (6usize, 5usize, 6usize);
    let prompt =
        |i: usize| -> Vec<u8> { (0..prompt_len).map(|t| ((t * 31 + i * 7) % 256) as u8).collect() };
    let sample = |i: usize| SampleConfig { temperature: 0.8, top_k: 16, seed: i as u64 };
    let expect: Vec<Vec<u8>> = (0..n_req)
        .map(|i| {
            generate(&cfg, &w, &cm, &prompt(i), max_new, sample(i))
                .expect("sequential int8 generate")
        })
        .collect();
    for &b in &[1usize, 3, 8] {
        for &page_size in &PAGE_SIZES {
            for &workers in &WORKER_COUNTS {
                let gen = GenConfig {
                    max_batch: b,
                    pages: n_req * (prompt_len + max_new - 1).div_ceil(page_size),
                    page_size,
                    prefill_chunk: 2,
                    prefix_share: true,
                    workers,
                };
                let reqs = (0..n_req).map(|i| (prompt(i), max_new, sample(i))).collect();
                let (outs, metrics) = drive_preloaded(&cfg, &w, &cm, &gen, reqs);
                assert_eq!(metrics.completed, n_req, "b={b} ps={page_size} w={workers}");
                for (i, out) in outs.iter().enumerate() {
                    assert_eq!(
                        *out, expect[i],
                        "int8 serve parity: b={b} page_size={page_size} \
                         workers={workers} request {i}"
                    );
                }
            }
        }
    }
}

/// Every seed against every combo — 192 served scenarios.  Slow by
/// design; run explicitly with `cargo test -q serve_fuzz -- --ignored`.
#[test]
#[ignore = "full 32-seed x {1,4,16} pages x {1,4} workers grid; run with --ignored"]
fn serve_fuzz_schedule_parity_full_grid() {
    for seed in 0..SEEDS {
        for &ps in &PAGE_SIZES {
            for &w in &WORKER_COUNTS {
                if let Err(msg) = run_case(seed, ps, w) {
                    panic!(
                        "serve fuzz failed: seed={seed} page_size={ps} workers={w}: {msg}\n\
                         reproduce with serve::fuzz::run_case({seed}, {ps}, {w})"
                    );
                }
            }
        }
    }
}
