//! Randomized serve-schedule fuzzing: the lockdown harness for the paged
//! serving stack's bit-parity contract.
//!
//! Each case derives a whole serving scenario from one seed — a request
//! mix with shared and distinct prompt prefixes (single- and multi-page),
//! admission staggered by a narrow `max_batch`, clients that hang up
//! mid-stream, and pool sizes tight enough to force preemption — serves
//! it, and checks every request's token stream bit-equal to a fresh
//! sequential [`generate`] run (a bit-equal *prefix* of it, for clients
//! that cancelled).  The scheduler is free to pick any page size, chunk
//! split, sharing, or preemption schedule; none of it may leak into the
//! tokens.
//!
//! A failure panics with the exact `(seed, page_size, workers)` triple, so
//! any red run reproduces with a one-line `run_case(seed, ps, w)` call.
//!
//! The default test covers the fixed 32-seed grid with the
//! `page_size × workers` combos round-robined across seeds; the `#[ignore]`d
//! full grid runs every seed against every combo (32 × {1,4,16} × {1,4}).
//!
//! The **chaos grid** ([`run_chaos_case`]) layers seeded fault injection
//! on top of the same scenario generator: injected step faults, simulated
//! allocation failures, slow / stalled / hung-up clients, mixed priorities
//! and dead-on-arrival deadlines.  Its contract is the robustness side of
//! the same coin: every surviving request streams a bit-exact (prefix of
//! the) sequential output, every casualty ends with exactly one Done
//! carrying the correct terminal [`FinishReason`], and `serve_generation`
//! itself always returns `Ok`.
//!
//! The **kv-ratio grids** run the same scenario generator against a
//! KV-compressed server ([`serve_generation_kv`]): ratio 1.0 must be
//! bit-identical to the plain server (identity short-circuit), lower
//! ratios bit-equal to the compressed single-request [`generate_kv`]
//! oracle — through every page size, worker count, preemption schedule,
//! and chaos fault, plus an int8-factor composition pin.

use super::batcher::{serve_generation_kv, GenConfig, GenRequest};
use super::chaos::ChaosConfig;
use super::stream::{stream_channel, FinishReason, StreamEvent};
use crate::compress::kv::compress_kv_plain;
use crate::linalg::rsvd::SvdPolicy;
use crate::model::config::ModelConfig;
use crate::model::forward::NoOverride;
use crate::model::generate::{generate, generate_kv, SampleConfig};
use crate::model::kvc::KvCompression;
use crate::model::weights::Weights;
use crate::util::rng::Rng;
use std::sync::mpsc::channel;
use std::time::Duration;

const FAMILIES: [&str; 3] = ["llama-t", "opt-t", "mistral-t"];
const PAGE_SIZES: [usize; 3] = [1, 4, 16];
const WORKER_COUNTS: [usize; 2] = [1, 4];
const FAULT_RATES: [f64; 3] = [0.0, 0.05, 0.2];
/// The `--kv-ratio` axis: 1.0 pins the identity short-circuit against the
/// plain [`generate`] oracle, the compressed points against
/// [`generate_kv`] under the same factors.
const KV_RATIOS: [f64; 3] = [1.0, 0.5, 0.25];
const SEEDS: u64 = 32;

/// Build the fuzz case's KV compression for one `kv_ratio` draw: `None`
/// is the legacy uncompressed server, 1.0 the identity object (same page
/// layout, literally the uncompressed code path), anything lower a real
/// whitener-free factorization.
fn case_kvc(cfg: &ModelConfig, w: &Weights, kv_ratio: Option<f64>) -> Option<KvCompression> {
    match kv_ratio {
        None => None,
        Some(r) if r >= 1.0 => Some(KvCompression::identity(cfg.n_layers)),
        Some(r) => Some(
            compress_kv_plain(cfg, w, r, &SvdPolicy::exact()).expect("kv factorization"),
        ),
    }
}

struct FuzzReq {
    prompt: Vec<u8>,
    max_new: usize,
    sample: SampleConfig,
    /// Tokens the client reads before hanging up (`>= max_new` reads the
    /// whole stream and waits for Done).
    consume: usize,
}

/// Run one seeded scenario end to end; `Err` carries the divergence
/// detail (the caller adds the reproducing tuple).  `kv_ratio` `None`
/// serves uncompressed; `Some(r)` serves through compressed KV latents
/// and checks the streams against the compressed sequential oracle.
fn run_case(
    seed: u64,
    page_size: usize,
    workers: usize,
    kv_ratio: Option<f64>,
) -> Result<(), String> {
    let mut rng = Rng::new(seed ^ 0x5EED_F00D);
    let family = FAMILIES[rng.below(FAMILIES.len())];
    let (cfg, w) = super::test_util::tiny(family, 47);
    let kvc = case_kvc(&cfg, &w, kv_ratio);
    // Base prefixes some requests share (multi-page when the draw is long
    // enough) — the trie only ever sees full pages, so sharing kicks in
    // exactly when a base spans one.
    let n_bases = 1 + rng.below(3);
    let bases: Vec<Vec<u8>> = (0..n_bases)
        .map(|_| {
            let len = rng.below(2 * page_size + 4);
            (0..len).map(|_| rng.below(256) as u8).collect()
        })
        .collect();
    let n_req = 3 + rng.below(5);
    let reqs: Vec<FuzzReq> = (0..n_req)
        .map(|_| {
            let mut prompt: Vec<u8> = if rng.below(2) == 0 {
                bases[rng.below(n_bases)].clone()
            } else {
                Vec::new()
            };
            let tail = 1 + rng.below(page_size + 3);
            prompt.extend((0..tail).map(|_| rng.below(256) as u8));
            let max_new = 1 + rng.below(6);
            // Biased toward reading everything; 0 = hang up before the
            // first token even arrives.
            let consume = rng.below(max_new + 2).min(max_new);
            let sample = SampleConfig {
                temperature: 0.5 + 0.1 * rng.below(8) as f32,
                top_k: 4 + rng.below(20),
                seed: rng.next_u64(),
            };
            FuzzReq { prompt, max_new, sample, consume }
        })
        .collect();
    // Feasible for every request by construction (no rejections), but
    // often tight enough that concurrent sequences fight for pages and
    // the scheduler must evict prefixes / preempt.
    let worst = reqs
        .iter()
        .map(|r| (r.prompt.len() + r.max_new - 1).div_ceil(page_size))
        .max()
        .expect("non-empty mix");
    let gen = GenConfig {
        max_batch: 1 + rng.below(4),
        pages: worst + rng.below(2 * worst + 2),
        page_size,
        prefill_chunk: [0usize, 1, 2, 5][rng.below(4)],
        prefix_share: rng.below(2) == 0,
        workers,
        ..GenConfig::default()
    };
    let expect: Vec<Vec<u8>> = reqs
        .iter()
        .map(|r| match (&kvc, kv_ratio) {
            (Some(c), Some(ratio)) if ratio < 1.0 => {
                generate_kv(&cfg, &w, &NoOverride, Some(c), &r.prompt, r.max_new, r.sample)
                    .expect("sequential compressed generate")
            }
            // Identity (and uncompressed): the PLAIN oracle — kv-ratio
            // 1.0 must be bit-identical to the uncompressed server.
            _ => generate(&cfg, &w, &NoOverride, &r.prompt, r.max_new, r.sample)
                .expect("sequential generate"),
        })
        .collect();
    // Serve on this thread; one client thread per request so hang-ups
    // happen while the server is mid-schedule.
    let (tx, rx) = channel();
    let (metrics, results) = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            let (stream, events) = stream_channel();
            tx.send(GenRequest::new(i as u64, r.prompt.clone(), r.max_new, r.sample, stream))
                .expect("request channel open");
            let (consume, max_new) = (r.consume, r.max_new);
            handles.push(scope.spawn(move || {
                let mut got = Vec::new();
                let mut finish = None;
                if consume < max_new {
                    // Read a prefix, then hang up mid-stream (dropping
                    // `events` on return is the cancellation).
                    while got.len() < consume {
                        match events.recv() {
                            Ok(StreamEvent::Token { byte, .. }) => got.push(byte),
                            Ok(StreamEvent::Done(d)) => {
                                finish = Some(d.finish);
                                break;
                            }
                            Err(_) => break,
                        }
                    }
                } else {
                    for event in events.iter() {
                        match event {
                            StreamEvent::Token { byte, .. } => got.push(byte),
                            StreamEvent::Done(d) => {
                                finish = Some(d.finish);
                                break;
                            }
                        }
                    }
                }
                (got, finish)
            }));
        }
        drop(tx);
        let metrics = serve_generation_kv(&cfg, &w, &NoOverride, kvc.as_ref(), &gen, rx)
            .expect("serve_generation_kv");
        let results: Vec<(Vec<u8>, Option<FinishReason>)> =
            handles.into_iter().map(|h| h.join().expect("client thread")).collect();
        (metrics, results)
    });
    for (i, (got, finish)) in results.iter().enumerate() {
        let want = &expect[i];
        let r = &reqs[i];
        if r.consume >= r.max_new {
            if got != want {
                return Err(format!(
                    "{family}: request {i} diverged: got {got:?}, want {want:?} \
                     (gen={gen:?})"
                ));
            }
            if *finish != Some(FinishReason::Completed) {
                return Err(format!("{family}: request {i} finished {finish:?}, want Completed"));
            }
        } else {
            // A cancelled client must have seen exactly its consumed
            // prefix of the sequential output — never a wrong token.
            if got.len() != r.consume || got[..] != want[..got.len()] {
                return Err(format!(
                    "{family}: cancelled request {i} stream {got:?} is not the \
                     {}-token prefix of {want:?} (gen={gen:?})",
                    r.consume
                ));
            }
        }
    }
    if metrics.rejected != 0 {
        return Err(format!("{family}: {} feasible requests rejected", metrics.rejected));
    }
    if metrics.completed != n_req {
        return Err(format!(
            "{family}: {} of {n_req} requests retired (gen={gen:?})",
            metrics.completed
        ));
    }
    Ok(())
}

fn combo(seed: u64) -> (usize, usize) {
    let ps = PAGE_SIZES[(seed as usize) % PAGE_SIZES.len()];
    let w = WORKER_COUNTS[(seed as usize / PAGE_SIZES.len()) % WORKER_COUNTS.len()];
    (ps, w)
}

struct ChaosReq {
    prompt: Vec<u8>,
    max_new: usize,
    sample: SampleConfig,
    /// Tokens the client reads before hanging up (`>= max_new` reads the
    /// whole stream and then drains the closed channel, so stray
    /// post-Done events are caught).
    consume: usize,
    /// Client-side stall between reads — slow and stalled consumers must
    /// never perturb the schedule or the bytes (token channels are
    /// unbounded, so the server never blocks on them).
    delay: Duration,
    /// Stamped `deadline = Some(0.0)`: must be killed in the queue with
    /// `DeadlineExceeded` before producing a single token.
    dead_on_arrival: bool,
    priority: u8,
    tenant: u32,
}

/// One seeded chaos scenario: the parity mix of [`run_case`] plus injected
/// step faults and allocation failures at `fault_rate`, mixed priorities,
/// slow / stalled / hung-up clients, and the occasional dead-on-arrival
/// deadline.  Checks, per request: survivors are bit-exact (prefixes of)
/// the sequential [`generate`] output, casualties get exactly one Done
/// with the right [`FinishReason`], and nothing arrives after Done.
/// Globally: the scheduler returns `Ok`, sheds/rejects nothing (the queue
/// is unbounded and the mix feasible), kills exactly the dead-on-arrival
/// requests, and buckets every terminal into its tenant.
fn run_chaos_case(
    seed: u64,
    page_size: usize,
    workers: usize,
    fault_rate: f64,
    kv_ratio: Option<f64>,
) -> Result<(), String> {
    let mut rng = Rng::new(seed ^ 0xC4A0_55ED);
    let family = FAMILIES[rng.below(FAMILIES.len())];
    let (cfg, w) = super::test_util::tiny(family, 47);
    let kvc = case_kvc(&cfg, &w, kv_ratio);
    let n_bases = 1 + rng.below(2);
    let bases: Vec<Vec<u8>> = (0..n_bases)
        .map(|_| {
            let len = rng.below(2 * page_size + 4);
            (0..len).map(|_| rng.below(256) as u8).collect()
        })
        .collect();
    let n_req = 3 + rng.below(5);
    let reqs: Vec<ChaosReq> = (0..n_req)
        .map(|i| {
            let mut prompt: Vec<u8> = if rng.below(2) == 0 {
                bases[rng.below(n_bases)].clone()
            } else {
                Vec::new()
            };
            let tail = 1 + rng.below(page_size + 3);
            prompt.extend((0..tail).map(|_| rng.below(256) as u8));
            let max_new = 1 + rng.below(6);
            let dead_on_arrival = rng.below(8) == 0;
            let consume = if dead_on_arrival {
                max_new // full reader: the DeadlineExceeded Done must arrive
            } else {
                rng.below(max_new + 2).min(max_new)
            };
            let delay = Duration::from_millis([0, 0, 1, 4][rng.below(4)]);
            let sample = SampleConfig {
                temperature: 0.5 + 0.1 * rng.below(8) as f32,
                top_k: 4 + rng.below(20),
                seed: rng.next_u64(),
            };
            ChaosReq {
                prompt,
                max_new,
                sample,
                consume,
                delay,
                dead_on_arrival,
                priority: rng.below(2) as u8,
                tenant: (i % 2) as u32,
            }
        })
        .collect();
    let worst = reqs
        .iter()
        .map(|r| (r.prompt.len() + r.max_new - 1).div_ceil(page_size))
        .max()
        .expect("non-empty mix");
    let gen = GenConfig {
        max_batch: 1 + rng.below(4),
        pages: worst + rng.below(2 * worst + 2),
        page_size,
        prefill_chunk: [0usize, 1, 2, 5][rng.below(4)],
        prefix_share: rng.below(2) == 0,
        workers,
        chaos: Some(ChaosConfig {
            seed: seed ^ 0xFA17_0001,
            step_fault_rate: fault_rate,
            alloc_fail_rate: fault_rate,
        }),
        ..GenConfig::default()
    };
    let expect: Vec<Vec<u8>> = reqs
        .iter()
        .map(|r| match (&kvc, kv_ratio) {
            (Some(c), Some(ratio)) if ratio < 1.0 => {
                generate_kv(&cfg, &w, &NoOverride, Some(c), &r.prompt, r.max_new, r.sample)
                    .expect("sequential compressed generate")
            }
            _ => generate(&cfg, &w, &NoOverride, &r.prompt, r.max_new, r.sample)
                .expect("sequential generate"),
        })
        .collect();
    let (tx, rx) = channel();
    let (metrics, results) = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            let (stream, events) = stream_channel();
            let mut req = GenRequest::new(i as u64, r.prompt.clone(), r.max_new, r.sample, stream);
            req.tenant = r.tenant;
            req.priority = r.priority;
            req.deadline = if r.dead_on_arrival { Some(0.0) } else { None };
            tx.send(req).expect("request channel open");
            let (consume, max_new, delay) = (r.consume, r.max_new, r.delay);
            handles.push(scope.spawn(move || {
                let mut got = Vec::new();
                let mut finish = None;
                let mut dones = 0usize;
                let mut after_done = 0usize;
                if consume < max_new {
                    // Slow reader that hangs up mid-stream (dropping
                    // `events` on return is the cancellation) — unless a
                    // terminal event beats it to the punch.
                    while got.len() < consume {
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                        match events.recv() {
                            Ok(StreamEvent::Token { byte, .. }) => got.push(byte),
                            Ok(StreamEvent::Done(d)) => {
                                finish = Some(d.finish);
                                dones += 1;
                                break;
                            }
                            Err(_) => break,
                        }
                    }
                } else {
                    // Full reader: drain until the server closes the
                    // channel, counting Done events and anything after.
                    for event in events.iter() {
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                        match event {
                            StreamEvent::Token { byte, .. } => {
                                if dones > 0 {
                                    after_done += 1;
                                } else {
                                    got.push(byte);
                                }
                            }
                            StreamEvent::Done(d) => {
                                if dones == 0 {
                                    finish = Some(d.finish);
                                } else {
                                    after_done += 1;
                                }
                                dones += 1;
                            }
                        }
                    }
                }
                (got, finish, dones, after_done)
            }));
        }
        drop(tx);
        let metrics = serve_generation_kv(&cfg, &w, &NoOverride, kvc.as_ref(), &gen, rx)
            .expect("serve_generation_kv");
        let results: Vec<(Vec<u8>, Option<FinishReason>, usize, usize)> =
            handles.into_iter().map(|h| h.join().expect("client thread")).collect();
        (metrics, results)
    });
    let mut dead_on_arrival_n = 0usize;
    for (i, (got, finish, dones, after_done)) in results.iter().enumerate() {
        let want = &expect[i];
        let r = &reqs[i];
        if *after_done != 0 {
            return Err(format!("{family}: request {i} saw {after_done} events after Done"));
        }
        if r.consume >= r.max_new && *dones != 1 {
            return Err(format!(
                "{family}: request {i} saw {dones} Done events, want exactly 1 (gen={gen:?})"
            ));
        }
        if r.dead_on_arrival {
            dead_on_arrival_n += 1;
            if *finish != Some(FinishReason::DeadlineExceeded) || !got.is_empty() {
                return Err(format!(
                    "{family}: dead-on-arrival request {i} finished {finish:?} \
                     with {} tokens, want DeadlineExceeded with 0",
                    got.len()
                ));
            }
            continue;
        }
        match finish {
            Some(FinishReason::Completed) => {
                if got != want {
                    return Err(format!(
                        "{family}: request {i} diverged: got {got:?}, want {want:?} (gen={gen:?})"
                    ));
                }
            }
            Some(FinishReason::Faulted) => {
                if fault_rate == 0.0 {
                    return Err(format!("{family}: request {i} faulted at fault_rate 0"));
                }
                if got.len() > want.len() || got[..] != want[..got.len()] {
                    return Err(format!(
                        "{family}: faulted request {i} stream {got:?} is not a \
                         prefix of {want:?} (gen={gen:?})"
                    ));
                }
            }
            None => {
                // Hung-up client: it must have read exactly its consumed
                // prefix of the sequential output — never a wrong token.
                if got.len() != r.consume || got[..] != want[..got.len()] {
                    return Err(format!(
                        "{family}: cancelled request {i} stream {got:?} is not the \
                         {}-token prefix of {want:?} (gen={gen:?})",
                        r.consume
                    ));
                }
            }
            other => {
                return Err(format!(
                    "{family}: request {i} got incoherent terminal {other:?} (gen={gen:?})"
                ));
            }
        }
    }
    if metrics.rejected != 0 || metrics.shed != 0 {
        return Err(format!(
            "{family}: feasible unbounded-queue mix saw rejected={} shed={}",
            metrics.rejected, metrics.shed
        ));
    }
    if metrics.deadline_exceeded != dead_on_arrival_n {
        return Err(format!(
            "{family}: deadline_exceeded={} but {dead_on_arrival_n} requests were dead on arrival",
            metrics.deadline_exceeded
        ));
    }
    if metrics.completed != n_req - dead_on_arrival_n {
        return Err(format!(
            "{family}: {} of {} admitted requests retired (gen={gen:?})",
            metrics.completed,
            n_req - dead_on_arrival_n
        ));
    }
    if fault_rate == 0.0 && metrics.faulted != 0 {
        return Err(format!("{family}: faulted={} at fault_rate 0", metrics.faulted));
    }
    let bucketed: usize = metrics.tenants.values().map(|t| t.requests).sum();
    if bucketed != n_req {
        return Err(format!(
            "{family}: tenant buckets hold {bucketed} terminals, want {n_req}"
        ));
    }
    Ok(())
}

/// The CI-default grid: all 32 seeds, with the 6 `page_size × workers`
/// combos round-robined so every combo sees 5+ distinct scenarios.
#[test]
fn serve_fuzz_schedule_parity_quick_grid() {
    for seed in 0..SEEDS {
        let (ps, w) = combo(seed);
        if let Err(msg) = run_case(seed, ps, w, None) {
            panic!(
                "serve fuzz failed: seed={seed} page_size={ps} workers={w}: {msg}\n\
                 reproduce with serve::fuzz::run_case({seed}, {ps}, {w}, None)"
            );
        }
    }
}

/// The kv-ratio CI grid: a seed subset with `page_size × workers` combos
/// round-robined and the kv-ratio cycling through {1.0, 0.5, 0.25} —
/// every served stream bit-equal to the single-request compressed-KV
/// [`generate_kv`] oracle (plain [`generate`] at ratio 1.0) through
/// chunked prefill, prefix sharing, preemption, and cancellation.
#[test]
fn serve_fuzz_kv_compress_schedule_parity_quick_grid() {
    for seed in 0..12u64 {
        let (ps, w) = combo(seed);
        let ratio = KV_RATIOS[(seed as usize) % KV_RATIOS.len()];
        if let Err(msg) = run_case(seed, ps, w, Some(ratio)) {
            panic!(
                "serve kv fuzz failed: seed={seed} page_size={ps} workers={w} \
                 kv_ratio={ratio}: {msg}\n\
                 reproduce with serve::fuzz::run_case({seed}, {ps}, {w}, Some({ratio}))"
            );
        }
    }
}

/// Every seed against every `page_size × workers × kv_ratio` cell — the
/// exhaustive compressed-cache parity battery.  Slow by design; run with
/// `cargo test -q serve_fuzz_kv_compress -- --ignored`.
#[test]
#[ignore = "full 32-seed x {1,4,16} pages x {1,4} workers x {1.0,0.5,0.25} kv-ratios grid"]
fn serve_fuzz_kv_compress_schedule_parity_full_grid() {
    for seed in 0..SEEDS {
        for &ps in &PAGE_SIZES {
            for &w in &WORKER_COUNTS {
                for &ratio in &KV_RATIOS {
                    if let Err(msg) = run_case(seed, ps, w, Some(ratio)) {
                        panic!(
                            "serve kv fuzz failed: seed={seed} page_size={ps} \
                             workers={w} kv_ratio={ratio}: {msg}\n\
                             reproduce with serve::fuzz::run_case({seed}, {ps}, {w}, Some({ratio}))"
                        );
                    }
                }
            }
        }
    }
}

/// Chaos × compression: injected step faults and allocation failures over
/// a compressed pool — survivors stay bit-exact against the compressed
/// oracle, casualties get one correct terminal, watchdog re-execution
/// reconstructs the same latent bits.
#[test]
fn serve_fuzz_kv_compress_chaos_quick() {
    for seed in 0..9u64 {
        let (ps, w) = combo(seed);
        let rate = FAULT_RATES[(seed as usize) % FAULT_RATES.len()];
        let ratio = KV_RATIOS[(seed as usize + 1) % KV_RATIOS.len()];
        if let Err(msg) = run_chaos_case(seed, ps, w, rate, Some(ratio)) {
            panic!(
                "serve kv chaos fuzz failed: seed={seed} page_size={ps} workers={w} \
                 fault_rate={rate} kv_ratio={ratio}: {msg}\n\
                 reproduce with serve::fuzz::run_chaos_case({seed}, {ps}, {w}, {rate}, Some({ratio}))"
            );
        }
    }
}

/// Int8-quantized KV factors through the whole serving stack: the served
/// streams must equal the sequential [`generate_kv`] run under the SAME
/// quantized factors at every `(max_batch, page_size, workers)` — the
/// PR-7 composition pin (factor GEMMs route through `gemm_i8_nn`, pool
/// latents stay f32, no silent wrong numbers).
#[test]
fn serve_fuzz_kv_compress_int8_serve_matches_sequential() {
    use crate::bench::drive_preloaded_kv;
    let (cfg, w) = super::test_util::tiny("llama-t", 47);
    let mut kvc =
        compress_kv_plain(&cfg, &w, 0.5, &SvdPolicy::exact()).expect("kv factorization");
    kvc.quantize(crate::linalg::quant::DEFAULT_GROUP);
    assert!(kvc.is_quantized(), "fixture must exercise the int8 factor path");
    let (n_req, prompt_len, max_new) = (4usize, 5usize, 5usize);
    let prompt =
        |i: usize| -> Vec<u8> { (0..prompt_len).map(|t| ((t * 31 + i * 7) % 256) as u8).collect() };
    let sample = |i: usize| SampleConfig { temperature: 0.8, top_k: 16, seed: i as u64 };
    let expect: Vec<Vec<u8>> = (0..n_req)
        .map(|i| {
            generate_kv(&cfg, &w, &NoOverride, Some(&kvc), &prompt(i), max_new, sample(i))
                .expect("sequential int8-kv generate")
        })
        .collect();
    for &b in &[1usize, 4] {
        for &page_size in &[1usize, 4] {
            for &workers in &WORKER_COUNTS {
                let gen = GenConfig {
                    max_batch: b,
                    pages: n_req * (prompt_len + max_new - 1).div_ceil(page_size),
                    page_size,
                    prefill_chunk: 2,
                    prefix_share: true,
                    workers,
                    ..GenConfig::default()
                };
                let reqs = (0..n_req).map(|i| (prompt(i), max_new, sample(i))).collect();
                let (outs, metrics) =
                    drive_preloaded_kv(&cfg, &w, &NoOverride, Some(&kvc), &gen, reqs);
                assert_eq!(metrics.completed, n_req, "b={b} ps={page_size} w={workers}");
                for (i, out) in outs.iter().enumerate() {
                    assert_eq!(
                        *out, expect[i],
                        "int8 kv serve parity: b={b} page_size={page_size} \
                         workers={workers} request {i}"
                    );
                }
            }
        }
    }
}

/// Int8-quantized factors through the full batched serving stack: every
/// `(max_batch, page_size, workers)` combination must reproduce the
/// sequential int8 [`generate`] run bit-for-bit — the serving-layer pin of
/// the integer kernel's determinism contract (group ≤ 128 keeps every
/// group dot exact in i32 and f32, so batching/paging/threading cannot
/// perturb a single logit).  The dense fuzz grid above never touches the
/// quantized path, so its f32 streams are byte-identical to the pre-int8
/// behavior by construction.
#[test]
fn serve_int8_batched_decode_matches_sequential_generate() {
    use crate::bench::{drive_preloaded, synthetic_nsvd_int8};
    let (cfg, w) = super::test_util::tiny("llama-t", 47);
    let cm = synthetic_nsvd_int8(&cfg, 0.30, 0.95, 9);
    assert!(cm.is_quantized(), "fixture must exercise the int8 path");
    let (n_req, prompt_len, max_new) = (6usize, 5usize, 6usize);
    let prompt =
        |i: usize| -> Vec<u8> { (0..prompt_len).map(|t| ((t * 31 + i * 7) % 256) as u8).collect() };
    let sample = |i: usize| SampleConfig { temperature: 0.8, top_k: 16, seed: i as u64 };
    let expect: Vec<Vec<u8>> = (0..n_req)
        .map(|i| {
            generate(&cfg, &w, &cm, &prompt(i), max_new, sample(i))
                .expect("sequential int8 generate")
        })
        .collect();
    for &b in &[1usize, 3, 8] {
        for &page_size in &PAGE_SIZES {
            for &workers in &WORKER_COUNTS {
                let gen = GenConfig {
                    max_batch: b,
                    pages: n_req * (prompt_len + max_new - 1).div_ceil(page_size),
                    page_size,
                    prefill_chunk: 2,
                    prefix_share: true,
                    workers,
                    ..GenConfig::default()
                };
                let reqs = (0..n_req).map(|i| (prompt(i), max_new, sample(i))).collect();
                let (outs, metrics) = drive_preloaded(&cfg, &w, &cm, &gen, reqs);
                assert_eq!(metrics.completed, n_req, "b={b} ps={page_size} w={workers}");
                for (i, out) in outs.iter().enumerate() {
                    assert_eq!(
                        *out, expect[i],
                        "int8 serve parity: b={b} page_size={page_size} \
                         workers={workers} request {i}"
                    );
                }
            }
        }
    }
}

/// The chaos CI grid: all 32 seeds with the `page_size × workers` combos
/// round-robined and the fault rate cycling through {0, 0.05, 0.2} —
/// surviving requests stay bit-exact, every casualty gets exactly one
/// correct terminal event, and the scheduler never panics.
#[test]
fn serve_chaos_grid_quick() {
    for seed in 0..SEEDS {
        let (ps, w) = combo(seed);
        let rate = FAULT_RATES[(seed as usize) % FAULT_RATES.len()];
        if let Err(msg) = run_chaos_case(seed, ps, w, rate, None) {
            panic!(
                "serve chaos fuzz failed: seed={seed} page_size={ps} workers={w} \
                 fault_rate={rate}: {msg}\n\
                 reproduce with serve::fuzz::run_chaos_case({seed}, {ps}, {w}, {rate}, None)"
            );
        }
    }
}

/// Every chaos seed against every combo and fault rate — 576 served
/// scenarios.  Slow by design; run explicitly with
/// `cargo test -q serve_chaos -- --ignored`.
#[test]
#[ignore = "full 32-seed x {1,4,16} pages x {1,4} workers x {0,0.05,0.2} rates grid"]
fn serve_chaos_grid_full() {
    for seed in 0..SEEDS {
        for &ps in &PAGE_SIZES {
            for &w in &WORKER_COUNTS {
                for &rate in &FAULT_RATES {
                    if let Err(msg) = run_chaos_case(seed, ps, w, rate, None) {
                        panic!(
                            "serve chaos fuzz failed: seed={seed} page_size={ps} \
                             workers={w} fault_rate={rate}: {msg}\n\
                             reproduce with serve::fuzz::run_chaos_case({seed}, {ps}, {w}, {rate}, None)"
                        );
                    }
                }
            }
        }
    }
}

/// Observability on/off bit-identity: a slice of the same fuzz grid
/// (which pins every served stream to the sequential oracle) must pass
/// with tracing and metrics RECORDING — instrumentation wraps timing and
/// metadata only and can never reorder a float op.  Also asserts the run
/// actually recorded kernel- and serve-layer spans, so the pin cannot rot
/// into a no-op if span sites move.
#[test]
fn serve_obs_on_off_bit_identity_quick() {
    let _guard = crate::obs::test_lock();
    crate::obs::reset();
    crate::obs::set_enabled(true);
    let result = (0..4u64).try_for_each(|seed| {
        let (ps, w) = combo(seed);
        let ratio = [None, Some(0.5)][(seed as usize) % 2];
        run_case(seed, ps, w, ratio).map_err(|msg| {
            format!(
                "obs-enabled serve fuzz failed: seed={seed} page_size={ps} \
                 workers={w} kv_ratio={ratio:?}: {msg}"
            )
        })
    });
    let events = crate::obs::trace::snapshot_events();
    let cats: std::collections::BTreeSet<&str> = events.iter().map(|e| e.cat()).collect();
    crate::obs::set_enabled(false);
    crate::obs::reset();
    if let Err(msg) = result {
        panic!("{msg}");
    }
    assert!(cats.contains("kernel"), "expected kernel spans, got {cats:?}");
    assert!(cats.contains("serve"), "expected serve spans, got {cats:?}");
}

/// Every seed against every combo — 192 served scenarios.  Slow by
/// design; run explicitly with `cargo test -q serve_fuzz -- --ignored`.
#[test]
#[ignore = "full 32-seed x {1,4,16} pages x {1,4} workers grid; run with --ignored"]
fn serve_fuzz_schedule_parity_full_grid() {
    for seed in 0..SEEDS {
        for &ps in &PAGE_SIZES {
            for &w in &WORKER_COUNTS {
                if let Err(msg) = run_case(seed, ps, w, None) {
                    panic!(
                        "serve fuzz failed: seed={seed} page_size={ps} workers={w}: {msg}\n\
                         reproduce with serve::fuzz::run_case({seed}, {ps}, {w}, None)"
                    );
                }
            }
        }
    }
}
