//! Slotted KV pool: fixed-capacity per-slot K/V storage with O(1) recycle.
//!
//! Each slot holds one sequence's per-layer key/value rows in storage
//! preallocated for `cap` positions, so the decode hot loop never allocates
//! and a finished sequence's slot is recycled with a free-list push —
//! no zeroing, no reallocation (`len` guards stale rows).  The pool is
//! owned by the scheduler thread ([`super::batcher::serve_generation`]);
//! it is deliberately not `Sync` — all mutation happens between decode
//! steps on that one thread.

use crate::model::config::ModelConfig;

/// Fixed-capacity slotted K/V storage for concurrent sequences.
#[derive(Debug)]
pub struct KvPool {
    layers: usize,
    cap: usize,
    d: usize,
    /// `[slot * layers + layer]` → row storage `[cap * d_model]`.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Valid rows per slot (identical across that slot's layers).
    len: Vec<usize>,
    /// LIFO free list — `acquire`/`release` are O(1).
    free: Vec<usize>,
}

impl KvPool {
    /// Pool with `slots` sequences of at most `cap` positions each.
    /// Allocates everything up front: `2 · slots · layers · cap · d_model`
    /// f32s.
    pub fn new(cfg: &ModelConfig, slots: usize, cap: usize) -> KvPool {
        assert!(slots > 0, "KvPool needs at least one slot");
        assert!(cap > 0, "KvPool needs capacity for at least one position");
        let d = cfg.d_model;
        let layers = cfg.n_layers;
        KvPool {
            layers,
            cap,
            d,
            k: (0..slots * layers).map(|_| vec![0.0f32; cap * d]).collect(),
            v: (0..slots * layers).map(|_| vec![0.0f32; cap * d]).collect(),
            len: vec![0; slots],
            free: (0..slots).rev().collect(),
        }
    }

    /// Total slot count.
    pub fn slots(&self) -> usize {
        self.len.len()
    }

    /// Maximum positions per slot.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Slots currently on the free list.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Slots currently held by sequences.
    pub fn in_use(&self) -> usize {
        self.slots() - self.free.len()
    }

    /// Valid rows currently stored in `slot`.
    pub fn len(&self, slot: usize) -> usize {
        self.len[slot]
    }

    /// Claim a free slot (its length reset to 0), or `None` when the pool
    /// is fully occupied.  O(1).
    pub fn acquire(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.len[slot] = 0;
        Some(slot)
    }

    /// Return `slot` to the free list.  O(1); the storage is retained and
    /// overwritten by the next occupant (`len` guards stale rows).
    pub fn release(&mut self, slot: usize) {
        debug_assert!(
            !self.free.contains(&slot),
            "double release of KV slot {slot}"
        );
        self.len[slot] = 0;
        self.free.push(slot);
    }

    /// Write the K/V rows for `(slot, layer)` at position `pos`.
    /// Positions must be written contiguously per slot; `set_len` commits
    /// the step's new length once every layer has been written.
    pub fn push_row(&mut self, slot: usize, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(
            pos < self.cap,
            "KV slot {slot} overflow: position {pos} >= capacity {}",
            self.cap
        );
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let idx = slot * self.layers + layer;
        self.k[idx][pos * self.d..(pos + 1) * self.d].copy_from_slice(k_row);
        self.v[idx][pos * self.d..(pos + 1) * self.d].copy_from_slice(v_row);
    }

    /// Commit `slot`'s valid-row count after a decode step.
    pub fn set_len(&mut self, slot: usize, len: usize) {
        assert!(len <= self.cap, "KV slot {slot}: len {len} > capacity {}", self.cap);
        self.len[slot] = len;
    }

    /// Contiguous K rows `[0, t_now)` of `(slot, layer)` — the same view
    /// `KvCache::k_hist` gives the sequential decoder.
    pub fn k_hist(&self, slot: usize, layer: usize, t_now: usize) -> &[f32] {
        &self.k[slot * self.layers + layer][..t_now * self.d]
    }

    /// Contiguous V rows `[0, t_now)` of `(slot, layer)`.
    pub fn v_hist(&self, slot: usize, layer: usize, t_now: usize) -> &[f32] {
        &self.v[slot * self.layers + layer][..t_now * self.d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        let mut cfg = ModelConfig::builtin("llama-t").unwrap();
        cfg.n_layers = 2;
        cfg
    }

    #[test]
    fn serve_pool_acquire_release_recycles() {
        let cfg = cfg();
        let mut pool = KvPool::new(&cfg, 3, 8);
        assert_eq!(pool.free_count(), 3);
        let a = pool.acquire().unwrap();
        let b = pool.acquire().unwrap();
        let c = pool.acquire().unwrap();
        assert_eq!(pool.acquire(), None, "exhausted pool must refuse");
        assert_eq!(pool.in_use(), 3);
        // Release the middle one; the next acquire reuses it (LIFO).
        pool.release(b);
        assert_eq!(pool.free_count(), 1);
        let b2 = pool.acquire().unwrap();
        assert_eq!(b2, b);
        assert_ne!(b2, a);
        assert_ne!(b2, c);
    }

    #[test]
    fn serve_pool_roundtrip_and_len_reset() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut pool = KvPool::new(&cfg, 2, 4);
        let s = pool.acquire().unwrap();
        let k0: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let v0: Vec<f32> = (0..d).map(|i| -(i as f32)).collect();
        for layer in 0..2 {
            pool.push_row(s, layer, 0, &k0, &v0);
        }
        pool.set_len(s, 1);
        assert_eq!(pool.len(s), 1);
        assert_eq!(pool.k_hist(s, 1, 1), &k0[..]);
        assert_eq!(pool.v_hist(s, 0, 1), &v0[..]);
        // Recycle: the stale row must be invisible to the next occupant.
        pool.release(s);
        let s2 = pool.acquire().unwrap();
        assert_eq!(s2, s);
        assert_eq!(pool.len(s2), 0);
        assert!(pool.k_hist(s2, 0, 0).is_empty());
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn serve_pool_rejects_overflow() {
        let cfg = cfg();
        let d = cfg.d_model;
        let mut pool = KvPool::new(&cfg, 1, 2);
        let s = pool.acquire().unwrap();
        let row = vec![0.0f32; d];
        pool.push_row(s, 0, 2, &row, &row);
    }
}
